"""L1 — fused GRU cell as Pallas kernels.

The paper (Lackinger et al., 2024) trains a 2-layer GRU (hidden 128) for
traffic-flow prediction on every FL device; the GRU cell is the compute
hot-spot of both the training and the inference path. This module provides:

  * ``gru_cell_fwd_pallas``  — the fused forward cell. One ``pallas_call``
    computes all three gates and the state update for a hidden-dimension
    tile, so no ``[B, 3H]`` pre-activation tensor is ever materialized in
    HBM. The grid tiles the hidden dimension in ``block_h``-wide blocks
    (MXU-friendly; 128 by default), with the weight tiles
    ``[I, block_h]`` / ``[H, block_h]`` staged into VMEM per grid step via
    ``BlockSpec``.

  * ``gru_gate_grads_pallas`` — the fused backward *gate-gradient* kernel:
    all elementwise gradient algebra of the cell (8 intermediate tensors in
    a naive implementation) fused into one pass over each hidden tile.

  * ``gru_cell`` — a ``jax.custom_vjp`` wrapper: forward runs the Pallas
    fused cell, backward runs the Pallas gate-grad kernel followed by the
    weight/input GEMMs in plain jnp (XLA fuses those fine; the GEMM is not
    where fusion wins — the elementwise gate algebra is).

Hardware adaptation (GPU paper -> TPU thinking, see DESIGN.md): instead of
threadblock tiles in shared memory, ``BlockSpec`` expresses the HBM->VMEM
schedule; gate math targets the MXU via ``[B, I] x [I, block_h]`` matmuls
with f32 accumulation.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers the kernel to plain HLO that the
rust runtime executes. Real-TPU perf is estimated in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default hidden-dimension tile. 128 matches the MXU systolic array width
# and the paper's hidden size, so the paper model runs as a single tile per
# grid step while larger models pipeline tiles through VMEM.
DEFAULT_BLOCK_H = 128


def _pick_block_h(hidden: int, block_h: int | None) -> int:
    """Choose a hidden tile size that divides ``hidden``."""
    if block_h is None:
        block_h = min(hidden, DEFAULT_BLOCK_H)
    if hidden % block_h != 0:
        # Fall back to the largest divisor of ``hidden`` not above block_h.
        for cand in range(min(block_h, hidden), 0, -1):
            if hidden % cand == 0:
                block_h = cand
                break
    return block_h


def _fwd_kernel(x_ref, h_ref, wi_ref, wh_ref, bi_ref, bh_ref,
                o_ref, r_ref, z_ref, n_ref, hn_ref, *, block_h: int):
    """Fused GRU cell forward for one hidden tile.

    Refs (VMEM tiles staged by BlockSpec):
      x_ref  [B, I]        full input (shared across tiles)
      h_ref  [B, H]        full previous hidden (the h-side GEMM needs it all)
      wi_ref [3, I, Hb]    per-gate input-weight columns of this tile
      wh_ref [3, H, Hb]    per-gate hidden-weight columns of this tile
      bi_ref [3, Hb], bh_ref [3, Hb]
      outputs: new hidden tile + residuals (r, z, n, hn_pre), each [B, Hb].
    """
    j = pl.program_id(0)
    x = x_ref[...]
    h = h_ref[...]

    # Gate pre-activations for this hidden tile: two GEMMs per gate,
    # f32 accumulation on the MXU.
    pre_i_r = jnp.dot(x, wi_ref[0], preferred_element_type=jnp.float32)
    pre_i_z = jnp.dot(x, wi_ref[1], preferred_element_type=jnp.float32)
    pre_i_n = jnp.dot(x, wi_ref[2], preferred_element_type=jnp.float32)
    pre_h_r = jnp.dot(h, wh_ref[0], preferred_element_type=jnp.float32)
    pre_h_z = jnp.dot(h, wh_ref[1], preferred_element_type=jnp.float32)
    pre_h_n = jnp.dot(h, wh_ref[2], preferred_element_type=jnp.float32)

    r = jax.nn.sigmoid(pre_i_r + bi_ref[0][None, :] + pre_h_r + bh_ref[0][None, :])
    z = jax.nn.sigmoid(pre_i_z + bi_ref[1][None, :] + pre_h_z + bh_ref[1][None, :])
    hn_pre = pre_h_n + bh_ref[2][None, :]
    n = jnp.tanh(pre_i_n + bi_ref[2][None, :] + r * hn_pre)

    # This tile's slice of the previous hidden state for the convex update.
    h_blk = jax.lax.dynamic_slice_in_dim(h, j * block_h, block_h, axis=1)
    o_ref[...] = (1.0 - z) * n + z * h_blk
    r_ref[...] = r
    z_ref[...] = z
    n_ref[...] = n
    hn_ref[...] = hn_pre


def gru_cell_fwd_pallas(x, h, wi, wh, bi, bh, *, block_h: int | None = None):
    """Fused GRU cell forward. Returns (h_new, r, z, n, hn_pre).

    Tiles the hidden dimension into ``block_h``-wide blocks. See module
    docstring for shapes.
    """
    b, _i = x.shape
    hidden = h.shape[1]
    hb = _pick_block_h(hidden, block_h)
    grid = (hidden // hb,)
    dt = x.dtype

    out_shapes = [jax.ShapeDtypeStruct((b, hidden), dt) for _ in range(5)]
    tile = pl.BlockSpec((b, hb), lambda j: (0, j))
    return pl.pallas_call(
        functools.partial(_fwd_kernel, block_h=hb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, x.shape[1]), lambda j: (0, 0)),        # x (full)
            pl.BlockSpec((b, hidden), lambda j: (0, 0)),            # h (full)
            pl.BlockSpec((3, x.shape[1], hb), lambda j: (0, 0, j)),  # wi tile
            pl.BlockSpec((3, hidden, hb), lambda j: (0, 0, j)),      # wh tile
            pl.BlockSpec((3, hb), lambda j: (0, j)),                 # bi tile
            pl.BlockSpec((3, hb), lambda j: (0, j)),                 # bh tile
        ],
        out_specs=[tile, tile, tile, tile, tile],
        out_shape=out_shapes,
        interpret=True,
        name="gru_cell_fwd",
    )(x, h, wi, wh, bi, bh)


def _bwd_gate_kernel(g_ref, h_ref, r_ref, z_ref, n_ref, hn_ref,
                     drp_ref, dzp_ref, dnp_ref, dhnp_ref, dhd_ref):
    """Fused elementwise gate-gradient algebra for one hidden tile."""
    g = g_ref[...]
    h = h_ref[...]
    r = r_ref[...]
    z = z_ref[...]
    n = n_ref[...]
    hn_pre = hn_ref[...]

    dn = g * (1.0 - z)
    dz = g * (h - n)
    dh_direct = g * z
    dn_pre = dn * (1.0 - n * n)
    dhn_pre = dn_pre * r
    dr = dn_pre * hn_pre
    drp_ref[...] = dr * r * (1.0 - r)
    dzp_ref[...] = dz * z * (1.0 - z)
    dnp_ref[...] = dn_pre
    dhnp_ref[...] = dhn_pre
    dhd_ref[...] = dh_direct


def gru_gate_grads_pallas(g, h, r, z, n, hn_pre, *, block_h: int | None = None):
    """Fused backward gate gradients (all inputs/outputs [B, H]).

    Returns (dr_pre, dz_pre, dn_pre, dhn_pre, dh_direct).
    """
    b, hidden = g.shape
    hb = _pick_block_h(hidden, block_h)
    grid = (hidden // hb,)
    tile = pl.BlockSpec((b, hb), lambda j: (0, j))
    out_shapes = [jax.ShapeDtypeStruct((b, hidden), g.dtype) for _ in range(5)]
    return pl.pallas_call(
        _bwd_gate_kernel,
        grid=grid,
        in_specs=[tile] * 6,
        out_specs=[tile] * 5,
        out_shape=out_shapes,
        interpret=True,
        name="gru_gate_grads",
    )(g, h, r, z, n, hn_pre)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def gru_cell(x, h, wi, wh, bi, bh, block_h=None):
    """GRU cell step with a Pallas fused forward and hand-derived VJP.

    Differentiable wrt all six tensor arguments. ``block_h`` is static.
    """
    h_new, _r, _z, _n, _hn = gru_cell_fwd_pallas(x, h, wi, wh, bi, bh,
                                                 block_h=block_h)
    return h_new


def _gru_cell_fwd(x, h, wi, wh, bi, bh, block_h):
    h_new, r, z, n, hn_pre = gru_cell_fwd_pallas(x, h, wi, wh, bi, bh,
                                                 block_h=block_h)
    return h_new, (x, h, wi, wh, r, z, n, hn_pre)


def _gru_cell_bwd(block_h, res, g):
    x, h, wi, wh, r, z, n, hn_pre = res
    dr_pre, dz_pre, dn_pre, dhn_pre, dh_direct = gru_gate_grads_pallas(
        g, h, r, z, n, hn_pre, block_h=block_h)

    # GEMM stage of the backward pass (plain jnp; XLA fuses/fissions these).
    # Input gradient: sum over gates of dpre_g @ Wi[g]^T.
    dx = (dr_pre @ wi[0].T + dz_pre @ wi[1].T + dn_pre @ wi[2].T)
    # Hidden gradient: direct path + h-side GEMM transposes.
    dh = (dh_direct + dr_pre @ wh[0].T + dz_pre @ wh[1].T
          + dhn_pre @ wh[2].T)
    # Weight gradients.
    dwi = jnp.stack([x.T @ dr_pre, x.T @ dz_pre, x.T @ dn_pre])
    dwh = jnp.stack([h.T @ dr_pre, h.T @ dz_pre, h.T @ dhn_pre])
    dbi = jnp.stack([dr_pre.sum(0), dz_pre.sum(0), dn_pre.sum(0)])
    dbh = jnp.stack([dr_pre.sum(0), dz_pre.sum(0), dhn_pre.sum(0)])
    return dx, dh, dwi, dwh, dbi, dbh


gru_cell.defvjp(_gru_cell_fwd, _gru_cell_bwd)


def vmem_footprint_bytes(batch: int, in_dim: int, hidden: int,
                         block_h: int | None = None,
                         dtype_bytes: int = 4) -> dict:
    """Static VMEM footprint estimate for one forward grid step.

    Used by the perf analysis in EXPERIMENTS.md §Perf: interpret mode gives
    no TPU wallclock, so we reason about the HBM<->VMEM schedule
    structurally. Returns a breakdown dict in bytes.
    """
    hb = _pick_block_h(hidden, block_h)
    parts = {
        "x": batch * in_dim * dtype_bytes,
        "h_full": batch * hidden * dtype_bytes,
        "wi_tile": 3 * in_dim * hb * dtype_bytes,
        "wh_tile": 3 * hidden * hb * dtype_bytes,
        "bias_tiles": 2 * 3 * hb * dtype_bytes,
        "outputs": 5 * batch * hb * dtype_bytes,
    }
    parts["total"] = sum(parts.values())
    parts["block_h"] = hb
    parts["grid"] = hidden // hb
    return parts
