"""Pure-jnp reference oracle for the fused GRU cell kernel.

This module is the correctness ground truth for
``kernels.gru_cell`` (the Pallas implementation). It is used by pytest
(``python/tests/test_kernel.py``) to validate both the forward fused cell
and the custom-VJP backward pass, and by ``model_ref`` variants used in
end-to-end numeric checks.

Conventions (match torch.nn.GRU):
    r = sigmoid(x @ Wi[0] + bi[0] + h @ Wh[0] + bh[0])
    z = sigmoid(x @ Wi[1] + bi[1] + h @ Wh[1] + bh[1])
    n = tanh   (x @ Wi[2] + bi[2] + r * (h @ Wh[2] + bh[2]))
    h' = (1 - z) * n + z * h

Shapes:
    x  : [B, I]      input at one timestep
    h  : [B, H]      previous hidden state
    wi : [3, I, H]   stacked input->gate weights  (r, z, n)
    wh : [3, H, H]   stacked hidden->gate weights (r, z, n)
    bi : [3, H]      input biases
    bh : [3, H]      hidden biases
"""

import jax
import jax.numpy as jnp


def gru_cell_ref(x, h, wi, wh, bi, bh):
    """One GRU cell step, pure jnp. Returns the new hidden state [B, H]."""
    pre_i = jnp.einsum("bi,gih->gbh", x, wi) + bi[:, None, :]
    pre_h = jnp.einsum("bh,ghk->gbk", h, wh) + bh[:, None, :]
    r = jax.nn.sigmoid(pre_i[0] + pre_h[0])
    z = jax.nn.sigmoid(pre_i[1] + pre_h[1])
    n = jnp.tanh(pre_i[2] + r * pre_h[2])
    return (1.0 - z) * n + z * h


def gru_cell_ref_residuals(x, h, wi, wh, bi, bh):
    """Like :func:`gru_cell_ref` but also returns the residual tensors the
    Pallas forward kernel emits: (h', r, z, n, hn_pre)."""
    pre_i = jnp.einsum("bi,gih->gbh", x, wi) + bi[:, None, :]
    pre_h = jnp.einsum("bh,ghk->gbk", h, wh) + bh[:, None, :]
    r = jax.nn.sigmoid(pre_i[0] + pre_h[0])
    z = jax.nn.sigmoid(pre_i[1] + pre_h[1])
    hn_pre = pre_h[2]
    n = jnp.tanh(pre_i[2] + r * hn_pre)
    h_new = (1.0 - z) * n + z * h
    return h_new, r, z, n, hn_pre


def gru_gate_grads_ref(g, h_blk, r, z, n, hn_pre):
    """Reference for the fused backward *gate-gradient* kernel.

    Given the upstream gradient ``g = dL/dh'`` and the forward residuals,
    computes the pre-activation gate gradients that feed the (jnp) GEMMs of
    the backward pass.

    Returns (dr_pre, dz_pre, dn_pre, dhn_pre, dh_direct), all [B, H].
    """
    dn = g * (1.0 - z)
    dz = g * (h_blk - n)
    dh_direct = g * z
    dn_pre = dn * (1.0 - n * n)
    dhn_pre = dn_pre * r
    dr = dn_pre * hn_pre
    dr_pre = dr * r * (1.0 - r)
    dz_pre = dz * z * (1.0 - z)
    return dr_pre, dz_pre, dn_pre, dhn_pre, dh_direct


def gru_forward_ref(layer_params, head, x):
    """Multi-layer GRU forward over a sequence, pure jnp.

    Args:
        layer_params: list of (wi, wh, bi, bh) per layer.
        head: (w_out [H, O], b_out [O]).
        x: [B, T, I] input sequence.
    Returns:
        y: [B, O] prediction from the final hidden state of the last layer.
    """
    b = x.shape[0]
    hs = [jnp.zeros((b, wh.shape[1]), x.dtype) for (_, wh, _, _) in layer_params]

    def step(hs, x_t):
        inp = x_t
        new_hs = []
        for (wi, wh, bi, bh), h in zip(layer_params, hs):
            h_new = gru_cell_ref(inp, h, wi, wh, bi, bh)
            new_hs.append(h_new)
            inp = h_new
        return new_hs, None

    hs, _ = jax.lax.scan(step, hs, jnp.swapaxes(x, 0, 1))
    w_out, b_out = head
    return hs[-1] @ w_out + b_out


def mse_ref(layer_params, head, x, y):
    """Mean squared error of the reference forward pass."""
    pred = gru_forward_ref(layer_params, head, x)
    return jnp.mean((pred - y) ** 2)
