"""AOT compile path: lower L2/L1 to HLO text artifacts for the rust runtime.

Emits, per model variant (``paper``, ``small``):

    artifacts/train_step_<v>.hlo.txt   (params.., x[B,T,I], y[B,O], lr) ->
                                       (params.., loss)
    artifacts/predict_<v>.hlo.txt      (params.., x[1,T,I]) -> (y[1,O],)
    artifacts/predict_b8_<v>.hlo.txt   (params.., x[8,T,I]) -> (y[8,O],)
                                       -- used by the L3 dynamic batcher
    artifacts/eval_<v>.hlo.txt         (params.., x[Be,T,I], y[Be,O]) -> (mse,)
    artifacts/params_init_<v>.bin      flat f32 LE initial parameters
    artifacts/manifest.json            shapes / ABI / file index

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Python runs only here, at build time (``make artifacts``); the rust binary
is self-contained afterwards.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M

SERVE_BATCH = 8  # L3 dynamic batcher max batch; predict_b8 artifact


def to_hlo_text(lowered) -> str:
    """jax lowering -> XLA HLO text via stablehlo (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _specs(cfg: M.ModelConfig):
    """ShapeDtypeStructs for the parameter ABI."""
    return [jax.ShapeDtypeStruct(s, jnp.float32)
            for _, s in cfg.param_shapes()]


def lower_variant(cfg: M.ModelConfig, out_dir: str) -> dict:
    """Lower all artifacts of one model variant; return its manifest entry."""
    f32 = jnp.float32
    pspecs = _specs(cfg)
    n = len(pspecs)

    x_train = jax.ShapeDtypeStruct((cfg.train_batch, cfg.seq_len, cfg.in_dim), f32)
    y_train = jax.ShapeDtypeStruct((cfg.train_batch, cfg.out_dim), f32)
    x_pred1 = jax.ShapeDtypeStruct((1, cfg.seq_len, cfg.in_dim), f32)
    x_pred8 = jax.ShapeDtypeStruct((SERVE_BATCH, cfg.seq_len, cfg.in_dim), f32)
    x_eval = jax.ShapeDtypeStruct((cfg.eval_batch, cfg.seq_len, cfg.in_dim), f32)
    y_eval = jax.ShapeDtypeStruct((cfg.eval_batch, cfg.out_dim), f32)
    lr = jax.ShapeDtypeStruct((), f32)

    def train_fn(*args):
        return M.train_step(cfg, list(args[:n]), args[n], args[n + 1], args[n + 2])

    def predict_fn(*args):
        return M.predict(cfg, list(args[:n]), args[n])

    def eval_fn(*args):
        return M.eval_mse(cfg, list(args[:n]), args[n], args[n + 1])

    artifacts = {}

    def emit(name, fn, specs):
        path = os.path.join(out_dir, f"{name}_{cfg.name}.hlo.txt")
        text = to_hlo_text(jax.jit(fn).lower(*specs))
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        artifacts[name] = {"file": os.path.basename(path), "sha256_16": digest}
        print(f"  {name}_{cfg.name}: {len(text)} chars")

    emit("train_step", train_fn, pspecs + [x_train, y_train, lr])
    emit("predict", predict_fn, pspecs + [x_pred1])
    emit("predict_b8", predict_fn, pspecs + [x_pred8])
    emit("eval", eval_fn, pspecs + [x_eval, y_eval])

    # Initial parameters, shared bit-exactly between python tests and rust.
    params = M.init_params(cfg, jax.random.PRNGKey(42))
    flat = np.concatenate([np.asarray(p, dtype=np.float32).ravel()
                           for p in params])
    pbin = os.path.join(out_dir, f"params_init_{cfg.name}.bin")
    flat.astype("<f4").tofile(pbin)

    return {
        "hidden": cfg.hidden,
        "layers": cfg.layers,
        "in_dim": cfg.in_dim,
        "out_dim": cfg.out_dim,
        "seq_len": cfg.seq_len,
        "train_batch": cfg.train_batch,
        "eval_batch": cfg.eval_batch,
        "serve_batch": SERVE_BATCH,
        "param_count": cfg.param_count(),
        "model_bytes": cfg.model_bytes(),
        "params": [{"name": nm, "shape": list(sh)}
                   for nm, sh in cfg.param_shapes()],
        "params_init": os.path.basename(pbin),
        "artifacts": artifacts,
        # Positional ABI (documented for the rust runtime):
        "abi": {
            "train_step": "params.., x[B,T,I], y[B,O], lr -> (params.., loss)",
            "predict": "params.., x[1,T,I] -> (y,)",
            "predict_b8": f"params.., x[{SERVE_BATCH},T,I] -> (y,)",
            "eval": "params.., x[Be,T,I], y[Be,O] -> (mse,)",
        },
    }


def emit_oracle(cfg: M.ModelConfig, out_dir: str) -> dict:
    """Golden input/output vectors for the rust runtime integration tests.

    Runs predict / train_step / eval in jax on deterministic inputs and
    dumps flattened f32 values to JSON. ``rust/tests/runtime_roundtrip.rs``
    loads the artifacts through PJRT and asserts allclose against these.
    """
    params = M.init_params(cfg, jax.random.PRNGKey(42))
    kx, ky, kp = jax.random.split(jax.random.PRNGKey(7), 3)
    x_t = jax.random.normal(kx, (cfg.train_batch, cfg.seq_len, cfg.in_dim),
                            jnp.float32)
    y_t = jax.random.normal(ky, (cfg.train_batch, cfg.out_dim), jnp.float32)
    x_p = jax.random.normal(kp, (1, cfg.seq_len, cfg.in_dim), jnp.float32)
    lr = jnp.float32(0.01)

    pred = M.predict(cfg, params, x_p)[0]
    ts = M.train_step(cfg, params, x_t, y_t, lr)
    x_e = jnp.tile(x_t, (max(1, cfg.eval_batch // cfg.train_batch), 1, 1)
                   )[: cfg.eval_batch]
    y_e = jnp.tile(y_t, (max(1, cfg.eval_batch // cfg.train_batch), 1)
                   )[: cfg.eval_batch]
    mse = M.eval_mse(cfg, params, x_e, y_e)[0]

    def flat(a):
        return [float(v) for v in np.asarray(a, dtype=np.float32).ravel()]

    oracle = {
        "lr": float(lr),
        "x_train": flat(x_t), "y_train": flat(y_t),
        "x_pred": flat(x_p), "pred": flat(pred),
        "x_eval": flat(x_e), "y_eval": flat(y_e), "mse": float(mse),
        "train_loss": float(ts[-1]),
        # first/last updated parameter arrays keep the file small while
        # still pinning both ends of the output tuple
        "new_params_first": flat(ts[0]),
        "new_params_last": flat(ts[len(ts) - 2]),
    }
    path = os.path.join(out_dir, f"oracle_{cfg.name}.json")
    with open(path, "w") as f:
        json.dump(oracle, f)
    return {"file": os.path.basename(path)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/manifest.json",
                    help="manifest path; artifacts land in its directory")
    ap.add_argument("--variants", default="small,paper")
    args = ap.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"format": 1, "models": {}}
    for name in args.variants.split(","):
        cfg = M.VARIANTS[name.strip()]
        print(f"lowering variant '{cfg.name}' "
              f"({cfg.param_count()} params, {cfg.model_bytes()} bytes)")
        entry = lower_variant(cfg, out_dir)
        entry["oracle"] = emit_oracle(cfg, out_dir)
        manifest["models"][cfg.name] = entry

    with open(args.out, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    sys.exit(main())
