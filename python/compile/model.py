"""L2 — the paper's GRU traffic-flow forecasting model in JAX.

Defines the multi-layer GRU (the paper: hidden 128, 2 layers, lr 1e-4,
batch 16 — §V-B1), its forward pass built on the L1 Pallas fused cell
(``kernels.gru_cell``), the MSE loss, and the SGD ``train_step`` with
forward+backward. Everything here runs at *build time only*: ``aot.py``
lowers these functions to HLO text which the rust runtime executes.

Parameter layout (flat order, recorded in the artifact manifest):
    for each layer l in 0..L:
        wi_l [3, I_l, H]   (I_0 = in_dim, I_{l>0} = H)
        wh_l [3, H, H]
        bi_l [3, H]
        bh_l [3, H]
    w_out [H, out_dim]
    b_out [out_dim]

All functions below take/return parameters as a flat list in this order so
the AOT artifacts have a stable positional ABI for the rust side.
"""

import dataclasses
import math

import jax
import jax.numpy as jnp

from compile.kernels.gru_cell import gru_cell
from compile.kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture + lowering configuration for one model variant."""

    name: str
    in_dim: int = 1
    hidden: int = 128
    layers: int = 2
    out_dim: int = 1
    seq_len: int = 12
    train_batch: int = 16
    eval_batch: int = 64
    block_h: int | None = None  # Pallas hidden tile; None = auto

    @property
    def n_param_arrays(self) -> int:
        return 4 * self.layers + 2

    def param_shapes(self):
        """Flat list of (name, shape) in ABI order."""
        shapes = []
        for l in range(self.layers):
            in_l = self.in_dim if l == 0 else self.hidden
            shapes.append((f"wi_{l}", (3, in_l, self.hidden)))
            shapes.append((f"wh_{l}", (3, self.hidden, self.hidden)))
            shapes.append((f"bi_{l}", (3, self.hidden)))
            shapes.append((f"bh_{l}", (3, self.hidden)))
        shapes.append(("w_out", (self.hidden, self.out_dim)))
        shapes.append(("b_out", (self.out_dim,)))
        return shapes

    def param_count(self) -> int:
        return sum(math.prod(s) for _, s in self.param_shapes())

    def model_bytes(self) -> int:
        """Serialized (f32) model size — the paper's cost-model payload."""
        return 4 * self.param_count()


# The paper's model: 2-layer GRU, hidden 128 -> ~594 KB serialized (§V-D).
PAPER = ModelConfig(name="paper", hidden=128, layers=2, seq_len=12,
                    train_batch=16)
# A small variant for fast tests (python unit tests + rust integration).
SMALL = ModelConfig(name="small", hidden=8, layers=1, seq_len=6,
                    train_batch=4, eval_batch=8, block_h=4)

VARIANTS = {c.name: c for c in (PAPER, SMALL)}


def init_params(cfg: ModelConfig, key) -> list:
    """Glorot-ish uniform initialization, returned as the flat ABI list."""
    params = []
    for l in range(cfg.layers):
        in_l = cfg.in_dim if l == 0 else cfg.hidden
        key, k1, k2 = jax.random.split(key, 3)
        s_i = 1.0 / math.sqrt(max(in_l, 1))
        s_h = 1.0 / math.sqrt(cfg.hidden)
        params.append(jax.random.uniform(k1, (3, in_l, cfg.hidden),
                                         minval=-s_i, maxval=s_i))
        params.append(jax.random.uniform(k2, (3, cfg.hidden, cfg.hidden),
                                         minval=-s_h, maxval=s_h))
        params.append(jnp.zeros((3, cfg.hidden)))
        params.append(jnp.zeros((3, cfg.hidden)))
    key, k3 = jax.random.split(key)
    s_o = 1.0 / math.sqrt(cfg.hidden)
    params.append(jax.random.uniform(k3, (cfg.hidden, cfg.out_dim),
                                     minval=-s_o, maxval=s_o))
    params.append(jnp.zeros((cfg.out_dim,)))
    return [p.astype(jnp.float32) for p in params]


def _split_params(cfg: ModelConfig, flat):
    """Flat ABI list -> (layer_params, head)."""
    layers = []
    i = 0
    for _ in range(cfg.layers):
        layers.append(tuple(flat[i:i + 4]))
        i += 4
    head = (flat[i], flat[i + 1])
    return layers, head


def forward(cfg: ModelConfig, flat_params, x):
    """Model forward pass using the Pallas fused cell.

    x: [B, T, in_dim] -> y_hat [B, out_dim].
    """
    layer_params, head = _split_params(cfg, flat_params)
    b = x.shape[0]
    h0 = [jnp.zeros((b, cfg.hidden), x.dtype) for _ in range(cfg.layers)]

    def step(hs, x_t):
        inp = x_t
        new_hs = []
        for (wi, wh, bi, bh), h in zip(layer_params, hs):
            h_new = gru_cell(inp, h, wi, wh, bi, bh, cfg.block_h)
            new_hs.append(h_new)
            inp = h_new
        return new_hs, None

    hs, _ = jax.lax.scan(step, h0, jnp.swapaxes(x, 0, 1))
    w_out, b_out = head
    return hs[-1] @ w_out + b_out


def forward_ref(cfg: ModelConfig, flat_params, x):
    """Pure-jnp forward (oracle) with the same ABI."""
    layer_params, head = _split_params(cfg, flat_params)
    return kref.gru_forward_ref([tuple(p) for p in layer_params], head, x)


def mse_loss(cfg: ModelConfig, flat_params, x, y):
    pred = forward(cfg, flat_params, x)
    return jnp.mean((pred - y) ** 2)


def train_step(cfg: ModelConfig, flat_params, x, y, lr):
    """One SGD step. Returns (new_flat_params..., loss).

    This is the artifact the rust FL clients execute for each local batch;
    FedAvg over the resulting parameter blocks happens on the rust side.
    """
    loss, grads = jax.value_and_grad(
        lambda ps: mse_loss(cfg, ps, x, y))(list(flat_params))
    new_params = [p - lr * g for p, g in zip(flat_params, grads)]
    return tuple(new_params) + (loss,)


def predict(cfg: ModelConfig, flat_params, x):
    """Inference entry point (serving path artifact)."""
    return (forward(cfg, flat_params, x),)


def eval_mse(cfg: ModelConfig, flat_params, x, y):
    """Batched evaluation MSE (per-client test metric for Fig. 6)."""
    return (mse_loss(cfg, flat_params, x, y),)
