"""AOT pipeline tests: manifest integrity, HLO text validity, and a full
python-side round trip — compile the emitted HLO text back with the local
XLA CPU client and check its numerics against the jax model. This is the
same load path the rust runtime uses (text -> HloModuleProto -> compile).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
HAVE_ARTIFACTS = os.path.exists(os.path.join(ART, "manifest.json"))

needs_artifacts = pytest.mark.skipif(
    not HAVE_ARTIFACTS, reason="run `make artifacts` first")


def load_manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


class TestHloText:
    def test_to_hlo_text_simple(self):
        lowered = jax.jit(lambda a, b: (a @ b + 2.0,)).lower(
            jax.ShapeDtypeStruct((2, 2), jnp.float32),
            jax.ShapeDtypeStruct((2, 2), jnp.float32))
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert "ENTRY" in text

    def test_hlo_text_parses_back(self):
        lowered = jax.jit(lambda a: (a * 2.0,)).lower(
            jax.ShapeDtypeStruct((3,), jnp.float32))
        text = aot.to_hlo_text(lowered)
        # The same entry the rust side uses: parse text -> module proto.
        mod = xc._xla.hlo_module_from_text(text)
        assert mod is not None


@needs_artifacts
class TestManifest:
    def test_models_present(self):
        m = load_manifest()
        assert set(m["models"]) >= {"small", "paper"}

    def test_all_artifact_files_exist(self):
        m = load_manifest()
        for model in m["models"].values():
            for art in model["artifacts"].values():
                assert os.path.exists(os.path.join(ART, art["file"]))
            assert os.path.exists(os.path.join(ART, model["params_init"]))

    def test_param_block_size(self):
        m = load_manifest()
        for name, model in m["models"].items():
            cfg = M.VARIANTS[name]
            pbin = os.path.join(ART, model["params_init"])
            n_floats = os.path.getsize(pbin) // 4
            assert n_floats == cfg.param_count()
            assert model["param_count"] == cfg.param_count()

    def test_declared_shapes_match_config(self):
        m = load_manifest()
        for name, model in m["models"].items():
            cfg = M.VARIANTS[name]
            declared = [(p["name"], tuple(p["shape"])) for p in model["params"]]
            assert declared == cfg.param_shapes()

    def test_paper_model_bytes(self):
        m = load_manifest()
        assert m["models"]["paper"]["model_bytes"] == M.PAPER.model_bytes()


def _parse_hlo(hlo_path):
    """Parse emitted HLO text back into a module — the exact entry point the
    rust runtime uses (``HloModuleProto::from_text_file``). Full
    execute-level numeric round-trips happen in the rust integration tests
    (``rust/tests/runtime_roundtrip.rs``) against the jax oracle values
    exported below."""
    with open(hlo_path) as f:
        text = f.read()
    return xc._xla.hlo_module_from_text(text), text


@needs_artifacts
class TestRoundTrip:
    def _entry_body(self, text):
        """Lines of the ENTRY computation (this HLO text style puts the
        signature in the body: ``%pN = ... parameter(N)`` + ``ROOT tuple``)."""
        lines = text.splitlines()
        start = next(i for i, l in enumerate(lines)
                     if l.strip().startswith("ENTRY"))
        body = []
        for l in lines[start + 1:]:
            if l.strip() == "}":
                break
            body.append(l)
        return body

    def test_predict_small_parses_with_right_arity(self):
        cfg = M.SMALL
        m = load_manifest()["models"]["small"]
        path = os.path.join(ART, m["artifacts"]["predict"]["file"])
        mod, text = _parse_hlo(path)
        n_inputs = sum("parameter(" in l for l in self._entry_body(text))
        # n param arrays + x
        assert n_inputs == cfg.n_param_arrays + 1

    def test_train_step_small_parses(self):
        m = load_manifest()["models"]["small"]
        path = os.path.join(ART, m["artifacts"]["train_step"]["file"])
        mod, text = _parse_hlo(path)
        assert "HloModule" in text

    def test_all_artifacts_parse(self):
        m = load_manifest()
        for model in m["models"].values():
            for art in model["artifacts"].values():
                mod, text = _parse_hlo(os.path.join(ART, art["file"]))
                assert mod is not None

    def test_train_step_output_tuple_arity(self):
        cfg = M.SMALL
        m = load_manifest()["models"]["small"]
        path = os.path.join(ART, m["artifacts"]["train_step"]["file"])
        _, text = _parse_hlo(path)
        root = next(l for l in self._entry_body(text) if "ROOT" in l)
        ret = root.split("tuple(")[0]
        # params.. + loss scalar outputs
        assert ret.count("f32") == cfg.n_param_arrays + 1

    def test_params_init_bin_matches_jax_init(self):
        cfg = M.SMALL
        m = load_manifest()["models"]["small"]
        flat = np.fromfile(os.path.join(ART, m["params_init"]), dtype="<f4")
        params = M.init_params(cfg, jax.random.PRNGKey(42))
        want = np.concatenate([np.asarray(p).ravel() for p in params])
        np.testing.assert_array_equal(flat, want)
