"""L1 correctness: Pallas fused GRU cell vs the pure-jnp oracle.

This is the core numeric signal for the whole stack: the AOT artifacts the
rust runtime executes lower through exactly these kernels. Hypothesis
sweeps shapes (batch, input dim, hidden dim, tile size); explicit tests pin
edge cases (tile == hidden, non-divisible tile fallback, single row).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gru_cell as K
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")


def make_inputs(b, i, h, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(ks[0], (b, i), jnp.float32)
    hp = jax.random.normal(ks[1], (b, h), jnp.float32)
    wi = jax.random.normal(ks[2], (3, i, h), jnp.float32) * 0.3
    wh = jax.random.normal(ks[3], (3, h, h), jnp.float32) * 0.3
    bi = jax.random.normal(ks[4], (3, h), jnp.float32) * 0.1
    bh = jax.random.normal(ks[5], (3, h), jnp.float32) * 0.1
    return x, hp, wi, wh, bi, bh


def assert_fwd_matches(b, i, h, block_h, seed=0, tol=1e-5):
    args = make_inputs(b, i, h, seed)
    got = K.gru_cell_fwd_pallas(*args, block_h=block_h)
    want = R.gru_cell_ref_residuals(*args)
    for g, w, name in zip(got, want, ["h_new", "r", "z", "n", "hn_pre"]):
        np.testing.assert_allclose(g, w, rtol=tol, atol=tol, err_msg=name)


class TestForwardExplicit:
    def test_single_tile(self):
        assert_fwd_matches(4, 3, 8, block_h=8)

    def test_multi_tile(self):
        assert_fwd_matches(4, 3, 8, block_h=4)

    def test_tile_of_one(self):
        assert_fwd_matches(2, 2, 4, block_h=1)

    def test_batch_of_one(self):
        assert_fwd_matches(1, 5, 16, block_h=8)

    def test_paper_shape_layer0(self):
        # Layer 0 of the paper model: in_dim=1, hidden=128, one MXU tile.
        assert_fwd_matches(16, 1, 128, block_h=128, tol=1e-4)

    def test_paper_shape_layer1(self):
        # Layer 1: 128 -> 128 with 64-wide tiles (two grid steps).
        assert_fwd_matches(16, 128, 128, block_h=64, tol=1e-4)

    def test_block_h_auto(self):
        assert_fwd_matches(3, 4, 32, block_h=None)

    def test_non_divisible_block_falls_back(self):
        # hidden=12, block 8 -> largest divisor <= 8 is 6.
        assert K.__dict__["_pick_block_h"](12, 8) == 6
        assert_fwd_matches(2, 3, 12, block_h=8)

    def test_pick_block_h_divides(self):
        for hidden in [1, 2, 6, 12, 128, 96]:
            for req in [None, 1, 5, 8, 128]:
                hb = K._pick_block_h(hidden, req)
                assert hidden % hb == 0
                assert 1 <= hb <= hidden

    def test_deterministic(self):
        args = make_inputs(4, 3, 8, seed=7)
        a = K.gru_cell_fwd_pallas(*args, block_h=4)
        b = K.gru_cell_fwd_pallas(*args, block_h=4)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)

    def test_tile_size_invariance(self):
        # Same numerics regardless of tiling decomposition.
        args = make_inputs(4, 3, 24, seed=3)
        outs = [K.gru_cell_fwd_pallas(*args, block_h=hb)[0]
                for hb in (24, 12, 8, 4)]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-6)

    def test_output_in_convex_range(self):
        # h' is a convex combination of n in (-1,1) and previous h.
        args = make_inputs(8, 4, 16, seed=11)
        h_new = K.gru_cell_fwd_pallas(*args, block_h=8)[0]
        h_prev = args[1]
        hi = np.maximum(np.abs(np.asarray(h_prev)), 1.0)
        assert np.all(np.abs(np.asarray(h_new)) <= hi + 1e-6)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 8),
    i=st.integers(1, 16),
    hpow=st.integers(0, 5),
    blk=st.sampled_from([None, 1, 2, 4, 8, 16]),
    seed=st.integers(0, 10_000),
)
def test_forward_matches_ref_hypothesis(b, i, hpow, blk, seed):
    h = 2 ** hpow
    assert_fwd_matches(b, i, h, block_h=blk, seed=seed, tol=2e-5)


class TestGateGrads:
    def test_matches_ref(self):
        b, h = 4, 8
        ks = jax.random.split(jax.random.PRNGKey(1), 6)
        g = jax.random.normal(ks[0], (b, h))
        hb = jax.random.normal(ks[1], (b, h))
        r = jax.nn.sigmoid(jax.random.normal(ks[2], (b, h)))
        z = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h)))
        n = jnp.tanh(jax.random.normal(ks[4], (b, h)))
        hn = jax.random.normal(ks[5], (b, h))
        got = K.gru_gate_grads_pallas(g, hb, r, z, n, hn, block_h=4)
        want = R.gru_gate_grads_ref(g, hb, r, z, n, hn)
        for a, w in zip(got, want):
            np.testing.assert_allclose(a, w, rtol=1e-5, atol=1e-6)

    def test_zero_upstream_gives_zero(self):
        b, h = 3, 4
        zeros = jnp.zeros((b, h))
        r = z = jnp.full((b, h), 0.5)
        n = hn = jnp.zeros((b, h))
        got = K.gru_gate_grads_pallas(zeros, zeros, r, z, n, hn, block_h=2)
        for a in got:
            np.testing.assert_array_equal(a, np.zeros((b, h)))


class TestCustomVJP:
    def grads(self, fn, args):
        return jax.grad(lambda *a: jnp.sum(fn(*a) ** 2),
                        argnums=tuple(range(6)))(*args)

    def assert_grads_match(self, b, i, h, blk, seed=0, tol=1e-4):
        args = make_inputs(b, i, h, seed)
        gk = jax.grad(
            lambda *a: jnp.sum(K.gru_cell(*a, blk) ** 2),
            argnums=tuple(range(6)))(*args)
        gr = self.grads(R.gru_cell_ref, args)
        names = ["dx", "dh", "dwi", "dwh", "dbi", "dbh"]
        for a, w, name in zip(gk, gr, names):
            np.testing.assert_allclose(a, w, rtol=tol, atol=tol, err_msg=name)

    def test_small(self):
        self.assert_grads_match(4, 3, 8, 4)

    def test_single_tile(self):
        self.assert_grads_match(2, 5, 8, 8)

    def test_paper_layer0(self):
        self.assert_grads_match(8, 1, 128, 128, tol=5e-4)

    def test_value_unchanged_by_vjp_wrapper(self):
        args = make_inputs(4, 3, 8, seed=5)
        a = K.gru_cell(*args, 4)
        b = K.gru_cell_fwd_pallas(*args, block_h=4)[0]
        np.testing.assert_array_equal(a, b)

    def test_finite_difference_x(self):
        # Directional finite-difference check on dx, independent of the ref.
        args = make_inputs(2, 3, 4, seed=9)
        x = args[0]
        rest = args[1:]

        def f(xv):
            return jnp.sum(K.gru_cell(xv, *rest, 4) ** 2)

        g = jax.grad(f)(x)
        v = jax.random.normal(jax.random.PRNGKey(123), x.shape)
        eps = 1e-3
        fd = (f(x + eps * v) - f(x - eps * v)) / (2 * eps)
        np.testing.assert_allclose(jnp.vdot(g, v), fd, rtol=2e-2, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 4),
    i=st.integers(1, 8),
    hpow=st.integers(1, 4),
    seed=st.integers(0, 1000),
)
def test_vjp_matches_ref_hypothesis(b, i, hpow, seed):
    h = 2 ** hpow
    args = make_inputs(b, i, h, seed)
    gk = jax.grad(lambda *a: jnp.mean(K.gru_cell(*a, None) ** 2),
                  argnums=tuple(range(6)))(*args)
    gr = jax.grad(lambda *a: jnp.mean(R.gru_cell_ref(*a) ** 2),
                  argnums=tuple(range(6)))(*args)
    for a, w in zip(gk, gr):
        np.testing.assert_allclose(a, w, rtol=5e-4, atol=5e-5)


class TestVmemFootprint:
    def test_paper_model_fits_vmem(self):
        # A TPU core has ~16 MiB VMEM; the paper model tile must fit easily.
        fp = K.vmem_footprint_bytes(16, 128, 128, 128)
        assert fp["total"] < 16 * 1024 * 1024
        assert fp["grid"] == 1

    def test_tiling_reduces_footprint(self):
        big = K.vmem_footprint_bytes(16, 512, 512, 512)
        small = K.vmem_footprint_bytes(16, 512, 512, 128)
        assert small["total"] < big["total"]
        assert small["grid"] == 4

    def test_breakdown_sums(self):
        fp = K.vmem_footprint_bytes(8, 32, 64, 32)
        parts = [v for k, v in fp.items()
                 if k not in ("total", "block_h", "grid")]
        assert sum(parts) == fp["total"]
