"""L2 correctness: model forward / loss / train_step vs the pure-jnp oracle,
plus training-dynamics sanity (loss decreases on a learnable series).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

SMALL = M.SMALL


def make_batch(cfg, b=None, seed=0):
    b = b or cfg.train_batch
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (b, cfg.seq_len, cfg.in_dim), jnp.float32)
    y = jax.random.normal(ky, (b, cfg.out_dim), jnp.float32)
    return x, y


class TestParamABI:
    def test_shapes_small(self):
        shapes = dict(SMALL.param_shapes())
        assert shapes["wi_0"] == (3, 1, 8)
        assert shapes["wh_0"] == (3, 8, 8)
        assert shapes["w_out"] == (8, 1)
        assert SMALL.n_param_arrays == 6

    def test_paper_model_size_matches_paper(self):
        # §V-D: "size in serialized format is 594 KB". Ours: 598,020 bytes.
        assert abs(M.PAPER.model_bytes() - 594 * 1024) < 12 * 1024
        assert M.PAPER.n_param_arrays == 10

    def test_init_matches_declared_shapes(self):
        for cfg in (M.SMALL, M.PAPER):
            params = M.init_params(cfg, jax.random.PRNGKey(0))
            assert len(params) == cfg.n_param_arrays
            for p, (_, s) in zip(params, cfg.param_shapes()):
                assert p.shape == s
                assert p.dtype == jnp.float32

    def test_param_count(self):
        params = M.init_params(SMALL, jax.random.PRNGKey(0))
        assert sum(p.size for p in params) == SMALL.param_count()


class TestForward:
    def test_matches_ref_small(self):
        params = M.init_params(SMALL, jax.random.PRNGKey(1))
        x, _ = make_batch(SMALL)
        got = M.forward(SMALL, params, x)
        want = M.forward_ref(SMALL, params, x)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_matches_ref_two_layers(self):
        cfg = M.ModelConfig(name="t2", hidden=8, layers=2, seq_len=4,
                            train_batch=3, block_h=4)
        params = M.init_params(cfg, jax.random.PRNGKey(2))
        x, _ = make_batch(cfg)
        np.testing.assert_allclose(
            M.forward(cfg, params, x), M.forward_ref(cfg, params, x),
            rtol=1e-5, atol=1e-6)

    def test_output_shape(self):
        params = M.init_params(SMALL, jax.random.PRNGKey(0))
        x, _ = make_batch(SMALL, b=7)
        assert M.forward(SMALL, params, x).shape == (7, SMALL.out_dim)

    def test_batch_independence(self):
        # Prediction for a row must not depend on other rows in the batch.
        params = M.init_params(SMALL, jax.random.PRNGKey(3))
        x, _ = make_batch(SMALL, b=4, seed=5)
        full = M.forward(SMALL, params, x)
        row0 = M.forward(SMALL, params, x[:1])
        np.testing.assert_allclose(full[:1], row0, rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), b=st.integers(1, 6))
def test_forward_matches_ref_hypothesis(seed, b):
    params = M.init_params(SMALL, jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (b, SMALL.seq_len, SMALL.in_dim))
    np.testing.assert_allclose(
        M.forward(SMALL, params, x), M.forward_ref(SMALL, params, x),
        rtol=2e-5, atol=2e-6)


class TestTrainStep:
    def test_returns_params_and_loss(self):
        params = M.init_params(SMALL, jax.random.PRNGKey(0))
        x, y = make_batch(SMALL)
        out = M.train_step(SMALL, params, x, y, jnp.float32(0.01))
        assert len(out) == SMALL.n_param_arrays + 1
        assert out[-1].shape == ()
        for p, q in zip(params, out[:-1]):
            assert p.shape == q.shape

    def test_zero_lr_is_identity(self):
        params = M.init_params(SMALL, jax.random.PRNGKey(0))
        x, y = make_batch(SMALL)
        out = M.train_step(SMALL, params, x, y, jnp.float32(0.0))
        for p, q in zip(params, out[:-1]):
            np.testing.assert_array_equal(p, q)

    def test_loss_is_mse_of_forward(self):
        params = M.init_params(SMALL, jax.random.PRNGKey(0))
        x, y = make_batch(SMALL)
        out = M.train_step(SMALL, params, x, y, jnp.float32(0.01))
        pred = M.forward(SMALL, params, x)
        np.testing.assert_allclose(out[-1], jnp.mean((pred - y) ** 2),
                                   rtol=1e-6)

    def test_grad_matches_ref_model(self):
        params = M.init_params(SMALL, jax.random.PRNGKey(4))
        x, y = make_batch(SMALL, seed=9)

        def loss_ref(ps):
            pred = M.forward_ref(SMALL, ps, x)
            return jnp.mean((pred - y) ** 2)

        gref = jax.grad(loss_ref)(list(params))
        lr = 0.05
        out = M.train_step(SMALL, params, x, y, jnp.float32(lr))
        for p, q, g in zip(params, out[:-1], gref):
            np.testing.assert_allclose(q, p - lr * g, rtol=1e-4, atol=1e-5)

    def test_training_reduces_loss(self):
        # Learnable toy task: predict the mean of the last 3 inputs.
        cfg = SMALL
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        key = jax.random.PRNGKey(77)
        step = jax.jit(lambda ps, x, y: M.train_step(cfg, ps, x, y,
                                                     jnp.float32(0.05)))
        first = last = None
        for i in range(60):
            key, kx = jax.random.split(key)
            x = jax.random.normal(kx, (cfg.train_batch, cfg.seq_len,
                                       cfg.in_dim))
            y = jnp.mean(x[:, -3:, 0], axis=1, keepdims=True)
            out = step(params, x, y)
            params, loss = list(out[:-1]), float(out[-1])
            if first is None:
                first = loss
            last = loss
        assert last < first * 0.7, (first, last)

    def test_eval_mse_matches_manual(self):
        params = M.init_params(SMALL, jax.random.PRNGKey(0))
        x, y = make_batch(SMALL, b=SMALL.eval_batch)
        (mse,) = M.eval_mse(SMALL, params, x, y)
        pred = M.forward(SMALL, params, x)
        np.testing.assert_allclose(mse, jnp.mean((pred - y) ** 2), rtol=1e-6)

    def test_predict_wraps_forward(self):
        params = M.init_params(SMALL, jax.random.PRNGKey(0))
        x, _ = make_batch(SMALL, b=1)
        (p,) = M.predict(SMALL, params, x)
        np.testing.assert_array_equal(p, M.forward(SMALL, params, x))
