//! Fig. 9 — communication-cost savings of HFLOP vs standard FL for
//! increasing edge-node density, plus the paper's absolute traffic
//! volumes for the use-case topology (4 edges, 20 devices, 594 KB GRU).
//!
//! Run: `cargo run --release --example cost_savings -- --n 200 --reps 10`

use hflop::cli;
use hflop::experiments::fig9;
use hflop::metrics::export::{ascii_table, ResultsWriter};

fn main() -> anyhow::Result<()> {
    hflop::init_logging();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(&argv)?;

    let cfg = fig9::Fig9Config {
        n_devices: args.usize_or("n", 200)?,
        reps: args.usize_or("reps", 10)?,
        rounds: args.usize_or("rounds", 100)?,
        seed: args.u64_or("seed", 9)?,
        ..Default::default()
    };
    println!(
        "Fig. 9 sweep: {} devices, densities {:?}, {} reps, {} rounds, l=2, 594 KB model",
        cfg.n_devices, cfg.densities, cfg.reps, cfg.rounds
    );
    let rows = fig9::run(&cfg)?;
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{}", r.m),
                format!("{:.2} ± {:.2}", r.hflop_savings_pct, r.hflop_ci95),
                format!("{:.2} ± {:.2}", r.uncap_savings_pct, r.uncap_ci95),
            ]
        })
        .collect();
    println!(
        "{}",
        ascii_table(&["edge hosts", "HFLOP savings % vs FL", "uncap. savings % vs FL"], &table)
    );

    let (flat, hflop, uncap) = fig9::absolute_reference(args.u64_or("seed", 9)?)?;
    println!("absolute traffic until convergence (20 devices, 4 edges, 100 rounds):");
    println!("  ours : flat {flat:.2} GB | HFLOP {hflop:.2} GB | uncapacitated {uncap:.2} GB");
    println!("  paper: flat 2.37 GB | HFLOP 0.53 GB | uncapacitated 0.24 GB");

    let out = ResultsWriter::default_dir()?;
    out.write_csv(
        "fig9_example.csv",
        &["m", "hflop_savings_pct", "hflop_ci95", "uncap_savings_pct", "uncap_ci95"],
        &rows
            .iter()
            .map(|r| vec![r.m as f64, r.hflop_savings_pct, r.hflop_ci95, r.uncap_savings_pct, r.uncap_ci95])
            .collect::<Vec<_>>(),
    )?;
    println!("wrote results/fig9_example.csv");
    Ok(())
}
