//! Registry tour: enumerate every registered experiment (the same list
//! `hflop experiment --list` prints), show a generated parameter schema,
//! and run one experiment end-to-end through the `Experiment` trait —
//! the exact code path the CLI and the sweep engine use.
//!
//! Run: `cargo run --release --example experiments`

use hflop::config::params::{Params, Value};
use hflop::experiments::registry::{self, render_help, ExperimentCtx};

fn main() -> anyhow::Result<()> {
    hflop::init_logging();

    println!("registered experiments:");
    for e in registry::REGISTRY {
        println!("  {:<14} {} ({} params)", e.name(), e.describe(), e.param_schema().len());
    }

    let scenario = registry::lookup("scenario")?;
    println!("\ngenerated help for 'scenario':\n{}", render_help(scenario));

    // Run it through the trait with a couple of overrides — identical to
    // `hflop experiment scenario --clients 12 --edges 3 --weeks 5`.
    let mut params = Params::defaults(scenario.param_schema());
    params.set("clients", Value::Int(12))?;
    params.set("edges", Value::Int(3))?;
    params.set("weeks", Value::Int(5))?;
    let report = scenario.run(&mut ExperimentCtx::new(params))?;
    println!("report summary:\n{}", report.to_json().to_pretty());
    println!(
        "({} tables; the CLI would write {}.json + one CSV per table)",
        report.tables.len(),
        report.stem
    );
    Ok(())
}
