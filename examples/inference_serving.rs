//! Inference serving experiments (Fig. 7 + Fig. 8) plus the *real* PJRT
//! serving hot path.
//!
//! Part 1 — Fig. 7: response-time distributions for flat FL /
//!   location-clustered HFL / HFLOP under the paper's latency assumptions
//!   (cloud RTT U(50,100) ms, edge RTT U(8,10) ms), with ASCII histograms.
//! Part 2 — Fig. 8: end-to-end latency vs edge→cloud speedup at rates λ
//!   and λ×10; reports the crossover (paper: flat FL wins above 14.25%).
//! Part 3 — real serving: the dynamic batcher executing the GRU
//!   `predict` artifacts through PJRT, reporting measured service times —
//!   the numbers that justify the simulation's service-time scale.
//!
//! Run: `cargo run --release --example inference_serving`

use hflop::experiments::{fig7, fig8, Scenario, ScenarioConfig};
use hflop::inference::serving::{BatchingServer, InferenceRequest};
use hflop::metrics::export::{ascii_table, ResultsWriter};
use hflop::runtime::{Engine, Manifest, Preload};
use hflop::util::rng::Rng;
use hflop::util::stats::Histogram;

fn main() -> anyhow::Result<()> {
    hflop::init_logging();
    let out = ResultsWriter::default_dir()?;

    let sc = Scenario::build(ScenarioConfig {
        n_clients: 20,
        n_edges: 4,
        weeks: 5,
        balanced_clients: false,
        ..Default::default()
    })?;

    // ---- Fig. 7 ----------------------------------------------------------
    println!("== Fig. 7: inference response times while training ==");
    let r = fig7::run(&sc, &fig7::Fig7Config::default());
    let rows = vec![
        ("flat", &r.flat, "79.07 ± 15.94"),
        ("hier", &r.location, "17.72 ± 24.26"),
        ("hflop", &r.hflop, "9.89 ± 4.63"),
    ];
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|(name, o, paper)| {
            vec![
                name.to_string(),
                format!("{:.2} ± {:.2}", o.latency.mean(), o.latency.std()),
                paper.to_string(),
                format!("{:.1} / {:.1}", o.percentiles.p50(), o.percentiles.p99()),
                format!("{:.1}%", 100.0 * o.spill_fraction()),
                format!("{}", o.total()),
            ]
        })
        .collect();
    println!(
        "{}",
        ascii_table(
            &["setup", "ours (ms)", "paper (ms)", "p50/p99", "spill", "requests"],
            &table
        )
    );

    for (name, o, _) in &rows {
        let mut h = Histogram::new(0.0, 120.0, 12);
        // Reservoir sample (bounded memory) — still renders the Fig. 7
        // distribution shape.
        for &s in o.samples.as_slice() {
            h.push(s);
        }
        println!("{name} response-time histogram (ms):\n{}", h.render(40));
    }

    // ---- Fig. 8 ----------------------------------------------------------
    println!("== Fig. 8: end-to-end latency vs cloud speedup ==");
    for (panel, scale) in [("a", 1.0), ("b", 10.0)] {
        let cfg = fig8::Fig8Config { lambda_scale: scale, ..Default::default() };
        let rows = fig8::run(&sc, &cfg);
        let cx = fig8::crossover(&rows);
        println!("fig8{panel}: lambda x{scale}  crossover = {cx:?}  (paper 8b: 0.1425)");
        for r in rows.iter().step_by(4) {
            println!(
                "  speedup {:>4.0}%: flat {:>7.2} ms | hier {:>7.2} ms | hflop {:>7.2} ms",
                r.speedup * 100.0,
                r.flat_ms,
                r.location_ms,
                r.hflop_ms
            );
        }
        out.write_csv(
            &format!("fig8{panel}_example.csv"),
            &["speedup", "flat_ms", "location_ms", "hflop_ms"],
            &rows
                .iter()
                .map(|r| vec![r.speedup, r.flat_ms, r.location_ms, r.hflop_ms])
                .collect::<Vec<_>>(),
        )?;
    }

    // ---- Real serving hot path -------------------------------------------
    println!("== Real PJRT serving (dynamic batcher, GRU predict artifact) ==");
    match Manifest::load_default() {
        Err(e) => println!("(skipping: {e})"),
        Ok(manifest) => {
            let engine = Engine::new(&manifest, "paper", Preload::Serving)?;
            let params = manifest.load_init_params(engine.variant())?;
            let seq = engine.variant().seq_len;
            let mut server = BatchingServer::new(&engine, params);
            let mut rng = Rng::new(1);
            let clock = hflop::util::WallClock::start();
            for id in 0..2048u64 {
                let window: Vec<f32> = (0..seq).map(|_| rng.normal() as f32).collect();
                server.submit(InferenceRequest { id, window }, clock.elapsed_s())?;
            }
            server.flush(clock.elapsed_s())?;
            let s = &server.stats;
            println!(
                "batched: {} requests / {} batches | mean batch exec {:.3} ms | throughput {:.0} req/s",
                s.requests,
                s.batches,
                s.batch_exec_ms.mean(),
                s.exec_throughput_rps()
            );
            // Singles for comparison (B=1 artifact).
            let mut single = BatchingServer::new(&engine, manifest.load_init_params(engine.variant())?);
            for id in 0..256u64 {
                let window: Vec<f32> = (0..seq).map(|_| rng.normal() as f32).collect();
                single.submit(InferenceRequest { id, window }, clock.elapsed_s())?;
                single.flush(clock.elapsed_s())?;
            }
            println!(
                "unbatched: mean exec {:.3} ms | throughput {:.0} req/s  (batching speedup: {:.2}x per request)",
                single.stats.batch_exec_ms.mean(),
                single.stats.exec_throughput_rps(),
                single.stats.batch_exec_ms.mean()
                    / (s.batch_exec_ms.mean() / engine.variant().serve_batch as f64)
            );
        }
    }
    Ok(())
}
