//! Joint-timeline co-simulation: training, serving and the orchestrator
//! on one event-driven kernel (`experiments::interference`).
//!
//! Runs the four scenario presets — steady load, diurnal surge, edge
//! failure, retrain burst — and reports per-preset serving quality,
//! training activity and orchestrator reactions, plus the latency
//! timeline around the edge-failure event (degradation + recovery after
//! the mid-run plan swap).
//!
//! Run: `cargo run --release --example interference`

use hflop::experiments::interference::{run, InterferenceConfig, Preset, EDGE_FAILURE_AT_FRAC};
use hflop::experiments::{Scenario, ScenarioConfig};
use hflop::metrics::export::ascii_table;

fn main() -> anyhow::Result<()> {
    hflop::init_logging();

    let sc = Scenario::build(ScenarioConfig {
        n_clients: 20,
        n_edges: 4,
        weeks: 5,
        balanced_clients: false,
        ..Default::default()
    })?;
    println!(
        "scenario: {} devices, {} edges, HFLOP cost {:.1} (optimal = {})",
        sc.topo.n_devices(),
        sc.topo.n_edges(),
        sc.hflop_cost,
        sc.hflop_optimal
    );

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut failure_timeline = None;
    let mut failure_at_s = 0.0;
    for preset in Preset::ALL {
        let cfg = InterferenceConfig { preset, ..Default::default() };
        let out = run(&sc, &cfg)?;
        rows.push(vec![
            preset.name().to_string(),
            format!("{}", out.serving.total()),
            format!("{:.2}", out.serving.latency.mean()),
            format!("{:.1}", out.serving.percentiles.p99()),
            format!("{:.1}%", 100.0 * out.serving.spill_fraction()),
            format!("{}", out.rounds_completed),
            format!("{}", out.plan_swaps),
            format!("{}", out.retrain_triggers),
            format!("{}", out.events_cancelled),
        ]);
        if preset == Preset::EdgeFailure {
            failure_at_s = EDGE_FAILURE_AT_FRAC * cfg.duration_s;
            failure_timeline = Some(out);
        }
    }
    println!(
        "{}",
        ascii_table(
            &[
                "preset", "requests", "mean ms", "p99 ms", "spill", "rounds", "swaps",
                "retrains", "cancelled"
            ],
            &rows
        )
    );

    if let Some(out) = failure_timeline {
        println!("edge-failure latency timeline (bucket mean, ms):");
        let w = out.timeline.width_s();
        for (i, b) in out.timeline.buckets().iter().enumerate() {
            if b.count() == 0 {
                continue;
            }
            let bar = "#".repeat((b.mean() / 2.0).min(60.0) as usize);
            let (t0, t1) = (i as f64 * w, (i + 1) as f64 * w);
            println!("  [{t0:>5.0}s..{t1:>5.0}s) {:>8.2}  {bar}", b.mean());
        }
        println!(
            "  (failure at {failure_at_s:.0}s; the re-solve installs a new plan: \
             {} swap(s), {} stale timer(s) cancelled)",
            out.plan_swaps,
            out.events_cancelled
        );
    }
    Ok(())
}
