//! Fig. 2 — time to solve HFLOP optimally for growing instance sizes
//! (mean + 95% CI), plus the exact-vs-heuristic ablation (§IV-C /
//! DESIGN.md §6): optimality gap and speed of greedy + local search
//! against the exact branch & bound.
//!
//! Run: `cargo run --release --example solver_scaling -- --reps 5`

use hflop::cli;
use hflop::experiments::fig2;
use hflop::hflop::InstanceBuilder;
use hflop::metrics::export::{ascii_table, ResultsWriter};
use hflop::solver::{branch_and_bound, local_search::{local_search, LocalSearchOptions}, greedy::greedy, BbOptions};

fn main() -> anyhow::Result<()> {
    hflop::init_logging();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(&argv)?;
    let reps = args.usize_or("reps", 5)?;
    let time_limit = args.f64_or("time-limit", 60.0)?;

    println!("== Fig. 2: exact HFLOP solve times (in-tree B&B + simplex, 1 core) ==");
    let rows = fig2::run(&fig2::default_sweep(), reps, time_limit, 1000);
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{}", r.n),
                format!("{}", r.m),
                format!("{:.4}", r.mean_s),
                format!("{:.4}", r.ci95_s),
                format!("{:.0}", r.mean_nodes),
                format!("{}", r.all_optimal),
            ]
        })
        .collect();
    println!(
        "{}",
        ascii_table(&["devices", "edges", "mean s", "ci95 s", "B&B nodes", "all optimal"], &table)
    );
    println!("paper (CPLEX, 8 cores): minutes at 10,000 x 100; the reproduced claim is the");
    println!("super-linear growth shape and practicality at orchestration-relevant sizes.\n");

    // ---- ablation: exact vs greedy vs local search ------------------------
    println!("== Ablation: heuristics vs exact (unit-cost family) ==");
    let mut ab = Vec::new();
    for (n, m) in [(20, 4), (40, 6), (80, 8)] {
        let mut gap_g = 0.0;
        let mut gap_l = 0.0;
        let mut t_e = 0.0;
        let mut t_g = 0.0;
        let mut t_l = 0.0;
        for rep in 0..reps as u64 {
            let inst = InstanceBuilder::unit_cost(n, m, 500 + rep).build();
            let (e, te) = hflop::util::time_it(|| {
                branch_and_bound(
                    &inst,
                    &BbOptions {
                        time_limit_s: (time_limit > 0.0).then_some(time_limit),
                        ..Default::default()
                    },
                )
            });
            let (g, tg) = hflop::util::time_it(|| greedy(&inst));
            let (l, tl) = hflop::util::time_it(|| local_search(&inst, &LocalSearchOptions::default()));
            gap_g += (g.cost - e.cost) / e.cost;
            gap_l += (l.cost - e.cost) / e.cost;
            t_e += te;
            t_g += tg;
            t_l += tl;
        }
        let r = reps as f64;
        ab.push(vec![
            format!("{n}x{m}"),
            format!("{:.3}", t_e / r),
            format!("{:.4}", t_g / r),
            format!("{:.2}%", 100.0 * gap_g / r),
            format!("{:.4}", t_l / r),
            format!("{:.2}%", 100.0 * gap_l / r),
        ]);
    }
    println!(
        "{}",
        ascii_table(
            &["size", "exact s", "greedy s", "greedy gap", "lsearch s", "lsearch gap"],
            &ab
        )
    );

    let out = ResultsWriter::default_dir()?;
    out.write_csv(
        "fig2_example.csv",
        &["n", "m", "mean_s", "ci95_s", "mean_nodes"],
        &rows
            .iter()
            .map(|r| vec![r.n as f64, r.m as f64, r.mean_s, r.ci95_s, r.mean_nodes])
            .collect::<Vec<_>>(),
    )?;
    println!("wrote results/fig2_example.csv");
    Ok(())
}
