//! Deterministic parallel scenario sweep (`experiments::sweep`).
//!
//! Runs the smoke grid (all four interference presets × seeds × both
//! local-search engines) twice — serially and on the scoped worker pool
//! — verifies the two matrices are byte-identical, reports the speedup,
//! and writes the combined artifact to `results/BENCH_sweep.json`.
//!
//! Run: `cargo run --release --example sweep`

use hflop::experiments::sweep::{run_grid, SweepGrid};
use hflop::metrics::export::{ascii_table, ResultsWriter, SCHEMA_VERSION};
use hflop::util::json::Json;
use hflop::util::pool;
use hflop::util::time_it;

fn main() -> anyhow::Result<()> {
    hflop::init_logging();

    // Built-in grids are declarative: one registered experiment × axis
    // overrides × a seed range (`SweepGrid::by_name` lists them; any
    // registry experiment sweeps the same way via `SweepGrid::custom`).
    let grid = SweepGrid::smoke(2026);
    let workers = pool::default_workers();
    println!(
        "sweep '{}': {} cells ({} rows x {} seeds x {} modes x {} envs), {} workers",
        grid.name,
        grid.n_cells(),
        grid.rows.len(),
        grid.n_seeds,
        grid.modes.len(),
        grid.envs.len(),
        workers
    );

    let (serial, serial_s) = time_it(|| run_grid(&grid, 1));
    let serial = serial?;
    let (parallel, parallel_s) = time_it(|| run_grid(&grid, workers));
    let parallel = parallel?;

    let identical = serial.to_json().to_pretty() == parallel.to_json().to_pretty();
    println!(
        "serial {serial_s:.2}s | {workers}-worker {parallel_s:.2}s | speedup {:.2}x | \
         bit-identical: {identical}",
        serial_s / parallel_s.max(1e-9)
    );
    anyhow::ensure!(identical, "worker count changed the matrix — determinism bug");

    println!(
        "{}",
        ascii_table(
            &["row", "cells", "requests", "mean ms", "p99 ms", "rounds", "swaps"],
            &parallel.summary_rows()
        )
    );

    let out = ResultsWriter::default_dir()?;
    let path = out.write_json(
        "BENCH_sweep.json",
        &Json::obj(vec![
            ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
            ("matrix", parallel.to_json()),
            (
                "timing",
                Json::obj(vec![
                    ("workers", Json::Num(workers as f64)),
                    ("serial_wall_s", Json::Num(serial_s)),
                    ("parallel_wall_s", Json::Num(parallel_s)),
                    ("speedup", Json::Num(serial_s / parallel_s.max(1e-9))),
                    ("total_cell_wall_s", Json::Num(parallel.total_cell_wall_s())),
                ]),
            ),
        ]),
    )?;
    println!("wrote {}", path.display());
    Ok(())
}
