//! **End-to-end driver** (Fig. 6 + §V-B): continual hierarchical FL on
//! synthetic METR-LA traffic, training the real 2-layer GRU through the
//! AOT Pallas/JAX artifacts via PJRT — all three setups (flat FL,
//! location-clustered HFL, HFLOP HFL) — logging per-round loss/MSE curves
//! and communication cost.
//!
//! This is the run recorded in EXPERIMENTS.md: it proves the full stack
//! composes (L3 rust coordinator -> PJRT -> L2 jax train_step -> L1
//! Pallas fused GRU cell) on a real workload.
//!
//! Paper-scale is 20 clients x 100 rounds x 5 epochs x full windows; on
//! this 1-core testbed the default is scaled (20 clients, 30 rounds,
//! 1 epoch x 8 batches — a few thousand real train steps). Flags:
//!   --rounds R --epochs E --batches B --clients N --variant small|paper
//!   --setups flat,hier,hflop   --mode single (only §V-B1 CL table)
//!
//! Run: `cargo run --release --example continual_traffic -- --rounds 30`

use hflop::cli;
use hflop::config::Setup;
use hflop::data::window::ContinualWindow;
use hflop::experiments::{fig6, Scenario, ScenarioConfig};
use hflop::fl::FlConfig;
use hflop::metrics::export::{ascii_table, ResultsWriter};
use hflop::runtime::{Engine, Manifest, Preload};

fn main() -> anyhow::Result<()> {
    hflop::init_logging();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(&argv)?;

    let manifest = Manifest::load_default()?;
    let variant = args.str_or("variant", "paper");
    let engine = Engine::new(&manifest, &variant, Preload::Training)?;
    let init = manifest.load_init_params(engine.variant())?;
    println!(
        "engine: {} | model '{}': {} params ({} bytes)",
        engine.platform(),
        variant,
        engine.variant().param_count,
        engine.variant().model_bytes
    );

    let sc = Scenario::build(ScenarioConfig {
        n_clients: args.usize_or("clients", 20)?,
        n_edges: args.usize_or("edges", 4)?,
        weeks: args.usize_or("weeks", 8)?,
        seed: args.u64_or("seed", 42)?,
        ..Default::default()
    })?;
    println!(
        "scenario: {} clients on {} sensors, {} edges, HFLOP cost {:.1} (optimal={})",
        sc.cfg.n_clients,
        sc.dataset.n_sensors(),
        sc.cfg.n_edges,
        sc.hflop_cost,
        sc.hflop_optimal
    );

    let fl = FlConfig {
        epochs: args.usize_or("epochs", 1)?,
        batches_per_epoch: args.usize_or("batches", 8)?,
        l: args.usize_or("l", 2)?,
        lr: args.f64_or("lr", 1e-3)? as f32,
        rounds: args.usize_or("rounds", 30)?,
        eval_every: 1,
    };
    let window = ContinualWindow::paper(sc.dataset.n_steps, args.usize_or("shift", 288)?);

    let setups: Vec<Setup> = args
        .str_or("setups", "flat,hier,hflop")
        .split(',')
        .map(Setup::parse)
        .collect::<Result<_, _>>()?;

    let out = ResultsWriter::default_dir()?;
    let mut table = Vec::new();
    let mut csv_rows: Vec<Vec<f64>> = Vec::new();
    for (si, &setup) in setups.iter().enumerate() {
        let t0 = std::time::Instant::now();
        let run = fig6::run_setup(&sc, &engine, setup, fl.clone(), window.clone(), init.clone(), 7)?;
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "[{}] {} rounds in {:.1}s — first-round MSE {:.5}, final MSE {:.5}, converged@{:?}, comm {:.4} GB",
            setup.name(),
            fl.rounds,
            wall,
            run.curves.mean_at(0),
            run.mean_final_mse,
            run.rounds_to_converge,
            run.ledger.total_gb()
        );
        // Loss curve (mean over clients), ten-round granularity.
        let curve: Vec<String> = (0..run.curves.n_rounds())
            .step_by((run.curves.n_rounds() / 10).max(1))
            .map(|r| format!("{:.4}", run.curves.mean_at(r)))
            .collect();
        println!("    mse curve: {}", curve.join(" -> "));
        table.push(vec![
            setup.name().to_string(),
            format!("{:.5}", run.curves.mean_at(0)),
            format!("{:.5}", run.mean_final_mse),
            format!("{:?}", run.rounds_to_converge),
            format!("{:.4}", run.ledger.total_gb()),
            format!("{:.1}", wall),
        ]);
        for round in 0..run.curves.n_rounds() {
            csv_rows.push(vec![si as f64, round as f64, run.curves.mean_at(round) as f64]);
        }
    }
    println!(
        "{}",
        ascii_table(
            &["setup", "first_mse", "final_mse", "converged@", "comm_gb", "wall_s"],
            &table
        )
    );
    out.write_csv("fig6_e2e.csv", &["setup", "round", "mean_mse"], &csv_rows)?;
    println!("wrote results/fig6_e2e.csv");
    println!(
        "paper Fig. 6: all three setups converge to comparable MSE (~20 rounds), hierarchy does not hurt accuracy"
    );
    Ok(())
}
