//! Quickstart: the library in five minutes.
//!
//! 1. Build an HFLOP instance and solve it exactly.
//! 2. Turn the solution into an FL hierarchy.
//! 3. Load the AOT model artifacts through PJRT and run a few training
//!    rounds + one real inference (requires `make artifacts`).
//!
//! Run: `cargo run --release --example quickstart`

use hflop::data::window::ContinualWindow;
use hflop::experiments::{Scenario, ScenarioConfig};
use hflop::fl::{ContinualHfl, FlConfig, Hierarchy};
use hflop::hflop::InstanceBuilder;
use hflop::runtime::{Engine, Manifest, Preload};
use hflop::solver::{self, SolveOptions};

fn main() -> anyhow::Result<()> {
    hflop::init_logging();

    // --- 1. HFLOP: place aggregators, assign devices --------------------
    // 20 devices, 4 candidate edge hosts, the paper's §V-D cost topology.
    let inst = InstanceBuilder::unit_cost(20, 4, 42).build();
    let sol = solver::solve(&inst, &SolveOptions::exact())?;
    println!(
        "HFLOP: communication cost {:.1}, {} aggregators open, optimal = {}",
        sol.cost,
        sol.assignment.n_open(),
        sol.proven_optimal
    );

    // --- 2. Solution -> FL hierarchy ------------------------------------
    let hierarchy = Hierarchy::from_assignment(&sol.assignment);
    println!(
        "hierarchy: {} clusters, {} participating devices",
        hierarchy.n_clusters(),
        hierarchy.n_participants()
    );

    // --- 3. Real training through the PJRT runtime ----------------------
    let Ok(manifest) = Manifest::load_default() else {
        println!("(run `make artifacts` to enable the PJRT part)");
        return Ok(());
    };
    let engine = Engine::new(&manifest, "small", Preload::All)?;
    println!("PJRT platform: {}", engine.platform());

    // Synthetic traffic world: 8 clients, 2 edges (fast demo scale).
    let sc = Scenario::build(ScenarioConfig {
        n_clients: 8,
        n_edges: 2,
        weeks: 5,
        ..Default::default()
    })?;
    let init = manifest.load_init_params(engine.variant())?;
    let fl = FlConfig { epochs: 1, batches_per_epoch: 2, l: 2, lr: 1e-2, rounds: 6, eval_every: 1 };
    let window = ContinualWindow::paper(sc.dataset.n_steps, 288);
    let clients =
        hflop::experiments::fig6::build_clients(&sc, &engine, window.train_range(), 7);
    let mut sys = ContinualHfl::new(
        &engine,
        hflop::experiments::fig6::hierarchy_for(&sc, hflop::config::Setup::Hflop),
        clients,
        window,
        fl,
        init.clone(),
        Some(&sc.inst),
    );
    sys.run()?;
    println!(
        "trained 6 rounds: mean val MSE {:.5} -> {:.5}, comm {:.4} GB",
        sys.curves.mean_at(0),
        sys.curves.converged_mean(2),
        sys.ledger.total_gb()
    );

    // --- 4. One real inference ------------------------------------------
    let window_in = vec![0.0f32; engine.variant().seq_len];
    let pred = engine.predict(&sys.global_params, &window_in)?;
    println!("inference on trained global model: {:.4}", pred[0]);
    Ok(())
}
