//! Artifact manifest: the contract between the python AOT pipeline
//! (`python/compile/aot.py`) and this runtime.
//!
//! `artifacts/manifest.json` records, per model variant, the parameter
//! ABI (array names + shapes, flat order), tensor shapes for each
//! artifact entry point, and the artifact file names. The rust side never
//! hard-codes shapes: everything flows from here.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One parameter array in the flat ABI.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One model variant (e.g. "paper", "small").
#[derive(Debug, Clone)]
pub struct Variant {
    pub name: String,
    pub hidden: usize,
    pub layers: usize,
    pub in_dim: usize,
    pub out_dim: usize,
    pub seq_len: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub serve_batch: usize,
    pub param_count: usize,
    /// Serialized f32 model size in bytes — the paper's cost payload.
    pub model_bytes: usize,
    pub params: Vec<ParamSpec>,
    /// artifact name ("train_step", "predict", ...) -> file name.
    pub artifacts: BTreeMap<String, String>,
    pub params_init_file: String,
    pub oracle_file: Option<String>,
}

impl Variant {
    /// Byte offsets of each parameter array in the flat f32 block.
    pub fn offsets(&self) -> Vec<usize> {
        let mut offs = Vec::with_capacity(self.params.len());
        let mut acc = 0usize;
        for p in &self.params {
            offs.push(acc);
            acc += p.numel();
        }
        offs
    }

    pub fn total_elems(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }
}

/// The parsed manifest + its directory (for resolving artifact files).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub variants: BTreeMap<String, Variant>,
}

impl Manifest {
    /// Default artifact location: `$HFLOP_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("HFLOP_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn load_default() -> anyhow::Result<Manifest> {
        Self::load(&Self::default_dir())
    }

    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> anyhow::Result<Manifest> {
        let root = Json::parse(text)?;
        let models = root
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'models'"))?;

        let mut variants = BTreeMap::new();
        for (name, v) in models {
            let num = |k: &str| -> anyhow::Result<usize> {
                v.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("variant {name}: missing {k}"))
            };
            let params = v
                .get("params")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("variant {name}: missing params"))?
                .iter()
                .map(|p| -> anyhow::Result<ParamSpec> {
                    Ok(ParamSpec {
                        name: p
                            .get("name")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow::anyhow!("param missing name"))?
                            .to_string(),
                        shape: p
                            .get("shape")
                            .and_then(Json::as_arr)
                            .ok_or_else(|| anyhow::anyhow!("param missing shape"))?
                            .iter()
                            .map(|d| d.as_usize().unwrap_or(0))
                            .collect(),
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()?;

            let artifacts = v
                .get("artifacts")
                .and_then(Json::as_obj)
                .ok_or_else(|| anyhow::anyhow!("variant {name}: missing artifacts"))?
                .iter()
                .filter_map(|(k, a)| {
                    a.get("file").and_then(Json::as_str).map(|f| (k.clone(), f.to_string()))
                })
                .collect();

            let variant = Variant {
                name: name.clone(),
                hidden: num("hidden")?,
                layers: num("layers")?,
                in_dim: num("in_dim")?,
                out_dim: num("out_dim")?,
                seq_len: num("seq_len")?,
                train_batch: num("train_batch")?,
                eval_batch: num("eval_batch")?,
                serve_batch: num("serve_batch")?,
                param_count: num("param_count")?,
                model_bytes: num("model_bytes")?,
                params,
                artifacts,
                params_init_file: v
                    .get("params_init")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("variant {name}: missing params_init"))?
                    .to_string(),
                oracle_file: v
                    .path(&["oracle", "file"])
                    .and_then(Json::as_str)
                    .map(String::from),
            };
            anyhow::ensure!(
                variant.total_elems() == variant.param_count,
                "variant {name}: declared param_count {} != shape sum {}",
                variant.param_count,
                variant.total_elems()
            );
            variants.insert(name.clone(), variant);
        }
        Ok(Manifest { dir: dir.to_path_buf(), variants })
    }

    pub fn variant(&self, name: &str) -> anyhow::Result<&Variant> {
        self.variants
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown model variant '{name}'"))
    }

    pub fn artifact_path(&self, variant: &Variant, artifact: &str) -> anyhow::Result<PathBuf> {
        let file = variant
            .artifacts
            .get(artifact)
            .ok_or_else(|| anyhow::anyhow!("variant {} has no artifact '{artifact}'", variant.name))?;
        Ok(self.dir.join(file))
    }

    /// Load the initial parameter block (little-endian f32 file).
    pub fn load_init_params(&self, variant: &Variant) -> anyhow::Result<Vec<f32>> {
        let path = self.dir.join(&variant.params_init_file);
        let bytes = std::fs::read(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        anyhow::ensure!(
            bytes.len() == 4 * variant.total_elems(),
            "params file size {} != expected {}",
            bytes.len(),
            4 * variant.total_elems()
        );
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "format": 1,
        "models": {
            "toy": {
                "hidden": 8, "layers": 1, "in_dim": 1, "out_dim": 1,
                "seq_len": 6, "train_batch": 4, "eval_batch": 8,
                "serve_batch": 8, "param_count": 273, "model_bytes": 1092,
                "params": [
                    {"name": "wi_0", "shape": [3, 1, 8]},
                    {"name": "wh_0", "shape": [3, 8, 8]},
                    {"name": "bi_0", "shape": [3, 8]},
                    {"name": "bh_0", "shape": [3, 8]},
                    {"name": "w_out", "shape": [8, 1]},
                    {"name": "b_out", "shape": [1]}
                ],
                "params_init": "params_init_toy.bin",
                "oracle": {"file": "oracle_toy.json"},
                "artifacts": {
                    "train_step": {"file": "train_step_toy.hlo.txt", "sha256_16": "x"},
                    "predict": {"file": "predict_toy.hlo.txt", "sha256_16": "y"}
                }
            }
        }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let v = m.variant("toy").unwrap();
        assert_eq!(v.hidden, 8);
        assert_eq!(v.params.len(), 6);
        assert_eq!(v.total_elems(), 24 + 192 + 24 + 24 + 8 + 1);
        assert_eq!(v.param_count, 273);
        assert_eq!(v.oracle_file.as_deref(), Some("oracle_toy.json"));
    }

    #[test]
    fn offsets_are_cumulative() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let v = m.variant("toy").unwrap();
        let offs = v.offsets();
        assert_eq!(offs[0], 0);
        assert_eq!(offs[1], 24);
        assert_eq!(offs[2], 24 + 192);
        assert_eq!(*offs.last().unwrap() + 1, v.total_elems());
    }

    #[test]
    fn artifact_path_resolution() {
        let m = Manifest::parse(SAMPLE, Path::new("/x/y")).unwrap();
        let v = m.variant("toy").unwrap();
        let p = m.artifact_path(v, "predict").unwrap();
        assert_eq!(p, PathBuf::from("/x/y/predict_toy.hlo.txt"));
        assert!(m.artifact_path(v, "nope").is_err());
    }

    #[test]
    fn unknown_variant_errors() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp")).unwrap();
        assert!(m.variant("missing").is_err());
    }

    #[test]
    fn param_count_mismatch_rejected() {
        let bad = SAMPLE.replace("\"param_count\": 273", "\"param_count\": 999");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn parses_real_manifest_when_present() {
        let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            let v = m.variant("paper").unwrap();
            assert_eq!(v.hidden, 128);
            assert_eq!(v.layers, 2);
            // §V-D: 594 KB serialized model (ours: 598,020 bytes).
            assert!((v.model_bytes as i64 - 594 * 1024).abs() < 16 * 1024);
            let params = m.load_init_params(v).unwrap();
            assert_eq!(params.len(), v.param_count);
        }
    }
}
