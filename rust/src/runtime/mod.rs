//! Model-execution runtime: the bridge from the rust coordinator (L3) to
//! the AOT-compiled JAX/Pallas artifacts (L2/L1).
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (the python↔rust ABI).
//! * [`engine`] — PJRT CPU client; compiles HLO text once, executes
//!   `train_step` / `predict` / `eval` with flat f32 parameter blocks.
//!
//! The `xla` FFI types are not `Send`; systems that need cross-thread
//! access construct the [`engine::Engine`] inside a dedicated runtime
//! thread (see `fl::runtime_actor`).

pub mod engine;
pub mod manifest;

pub use engine::{Engine, Preload};
pub use manifest::{Manifest, ParamSpec, Variant};
