//! PJRT execution engine: loads the AOT HLO-text artifacts, compiles them
//! once on the CPU PJRT client, and exposes typed entry points
//! (`train_step`, `predict`, `eval_mse`) over flat f32 parameter blocks.
//!
//! This is the only place the `xla` crate is touched, and only when the
//! `pjrt` cargo feature is on. The offline image carries no vendored
//! xla-rs, so the default build compiles a stub [`Engine`] with the same
//! surface that errors at construction — the solver/simulation stack (and
//! everything driven by [`crate::fl::MockRuntime`]) stays fully buildable
//! and testable without the native toolchain. `--features pjrt` alone
//! does not compile: vendor xla-rs and add `xla = { path = ... }` to
//! rust/Cargo.toml first (the feature deliberately declares no optional
//! dependency because none is resolvable offline).
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax ≥ 0.5
//! serialized protos use 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

use super::manifest::{Manifest, Variant};
use anyhow::Result;

/// Which artifacts to compile at engine construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Preload {
    /// All entry points (training runtime).
    All,
    /// Only `predict`/`predict_b8` (serving runtime).
    Serving,
    /// Only `train_step` + `eval` (training without serving).
    Training,
}

#[cfg(feature = "pjrt")]
mod imp {
    use std::collections::BTreeMap;
    use std::path::Path;

    use anyhow::{Context, Result};

    use super::super::manifest::{Manifest, Variant};
    use super::Preload;

    /// Compiled executables for one model variant.
    pub struct Engine {
        client: xla::PjRtClient,
        variant: Variant,
        executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    }

    impl Engine {
        /// Build an engine for `variant_name`, compiling the selected
        /// artifacts. Compilation happens once; execution reuses executables.
        pub fn new(manifest: &Manifest, variant_name: &str, preload: Preload) -> Result<Engine> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let variant = manifest.variant(variant_name)?.clone();

            let wanted: Vec<&str> = match preload {
                Preload::All => vec!["train_step", "predict", "predict_b8", "eval"],
                Preload::Serving => vec!["predict", "predict_b8"],
                Preload::Training => vec!["train_step", "eval"],
            };

            let mut executables = BTreeMap::new();
            for name in wanted {
                let path = manifest.artifact_path(&variant, name)?;
                let exe = Self::compile_artifact(&client, &path)
                    .with_context(|| format!("compiling artifact '{name}'"))?;
                executables.insert(name.to_string(), exe);
            }
            Ok(Engine { client, variant, executables })
        }

        fn compile_artifact(
            client: &xla::PjRtClient,
            path: &Path,
        ) -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))
        }

        pub fn variant(&self) -> &Variant {
            &self.variant
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Split a flat parameter block into per-array literals (ABI order).
        fn param_literals(&self, flat: &[f32]) -> Result<Vec<xla::Literal>> {
            anyhow::ensure!(
                flat.len() == self.variant.total_elems(),
                "param block len {} != expected {}",
                flat.len(),
                self.variant.total_elems()
            );
            let offsets = self.variant.offsets();
            let mut lits = Vec::with_capacity(self.variant.params.len());
            for (spec, &off) in self.variant.params.iter().zip(&offsets) {
                let chunk = &flat[off..off + spec.numel()];
                let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(chunk)
                    .reshape(&dims)
                    .map_err(|e| anyhow::anyhow!("reshaping {}: {e:?}", spec.name))?;
                lits.push(lit);
            }
            Ok(lits)
        }

        fn tensor_literal(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
            let expect: i64 = dims.iter().product();
            anyhow::ensure!(
                data.len() as i64 == expect,
                "tensor data len {} != shape {:?}",
                data.len(),
                dims
            );
            xla::Literal::vec1(data)
                .reshape(dims)
                .map_err(|e| anyhow::anyhow!("reshape {dims:?}: {e:?}"))
        }

        /// Execute an artifact with the given inputs; decompose the result
        /// tuple (all artifacts are lowered with `return_tuple=True`).
        fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let exe = self
                .executables
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not preloaded"))?;
            let result = exe
                .execute::<xla::Literal>(inputs)
                .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetching {name} result: {e:?}"))?;
            lit.to_tuple().map_err(|e| anyhow::anyhow!("untupling {name}: {e:?}"))
        }

        /// One SGD step on a batch. `x` is `[B*T*in_dim]` row-major,
        /// `y` is `[B*out_dim]`. Returns (new params, loss).
        pub fn train_step(
            &self,
            params: &[f32],
            x: &[f32],
            y: &[f32],
            lr: f32,
        ) -> Result<(Vec<f32>, f32)> {
            let v = &self.variant;
            let mut inputs = self.param_literals(params)?;
            inputs.push(Self::tensor_literal(
                x,
                &[v.train_batch as i64, v.seq_len as i64, v.in_dim as i64],
            )?);
            inputs.push(Self::tensor_literal(y, &[v.train_batch as i64, v.out_dim as i64])?);
            inputs.push(xla::Literal::scalar(lr));

            let outs = self.execute("train_step", &inputs)?;
            anyhow::ensure!(
                outs.len() == v.params.len() + 1,
                "train_step returned {} outputs, expected {}",
                outs.len(),
                v.params.len() + 1
            );
            let mut flat = Vec::with_capacity(v.total_elems());
            for lit in &outs[..v.params.len()] {
                flat.extend(lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?);
            }
            let loss = outs[v.params.len()]
                .get_first_element::<f32>()
                .map_err(|e| anyhow::anyhow!("{e:?}"))?;
            Ok((flat, loss))
        }

        /// Single-request prediction: `x` is `[T*in_dim]`. Returns `[out_dim]`.
        pub fn predict(&self, params: &[f32], x: &[f32]) -> Result<Vec<f32>> {
            let v = &self.variant;
            let mut inputs = self.param_literals(params)?;
            inputs.push(Self::tensor_literal(x, &[1, v.seq_len as i64, v.in_dim as i64])?);
            let outs = self.execute("predict", &inputs)?;
            outs[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))
        }

        /// Batched prediction for the dynamic batcher: `x` is
        /// `[serve_batch*T*in_dim]`. Returns `[serve_batch*out_dim]`.
        pub fn predict_batch(&self, params: &[f32], x: &[f32]) -> Result<Vec<f32>> {
            let v = &self.variant;
            let mut inputs = self.param_literals(params)?;
            inputs.push(Self::tensor_literal(
                x,
                &[v.serve_batch as i64, v.seq_len as i64, v.in_dim as i64],
            )?);
            let outs = self.execute("predict_b8", &inputs)?;
            outs[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))
        }

        /// Evaluation MSE over one eval batch. `x` `[Be*T*in_dim]`, `y` `[Be*out_dim]`.
        pub fn eval_mse(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<f32> {
            let v = &self.variant;
            let mut inputs = self.param_literals(params)?;
            inputs.push(Self::tensor_literal(
                x,
                &[v.eval_batch as i64, v.seq_len as i64, v.in_dim as i64],
            )?);
            inputs.push(Self::tensor_literal(y, &[v.eval_batch as i64, v.out_dim as i64])?);
            let outs = self.execute("eval", &inputs)?;
            outs[0].get_first_element::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn tensor_literal_validates_length() {
            assert!(Engine::tensor_literal(&[1.0, 2.0], &[3]).is_err());
            assert!(Engine::tensor_literal(&[1.0, 2.0, 3.0], &[3]).is_ok());
            assert!(Engine::tensor_literal(&[1.0; 6], &[2, 3]).is_ok());
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use anyhow::Result;

    use super::super::manifest::{Manifest, Variant};
    use super::Preload;

    const UNAVAILABLE: &str = "PJRT/XLA execution is unavailable in this build: the crate was \
         compiled with the stub engine. Enabling it needs both a vendored xla-rs (add \
         `xla = { path = ... }` to rust/Cargo.toml [dependencies]) and `--features pjrt` — \
         the feature flag alone will not compile";

    /// Stub engine: same surface as the PJRT engine, errors at
    /// construction. Keeps every consumer (FL round engine, batching
    /// server, CLI) compiling in artifact-less environments; the
    /// `MockRuntime` path covers their tests.
    pub struct Engine {
        variant: Variant,
    }

    impl Engine {
        pub fn new(_manifest: &Manifest, _variant_name: &str, _preload: Preload) -> Result<Engine> {
            anyhow::bail!(UNAVAILABLE)
        }

        pub fn variant(&self) -> &Variant {
            &self.variant
        }

        pub fn platform(&self) -> String {
            "stub (no pjrt feature)".to_string()
        }

        pub fn train_step(
            &self,
            _params: &[f32],
            _x: &[f32],
            _y: &[f32],
            _lr: f32,
        ) -> Result<(Vec<f32>, f32)> {
            anyhow::bail!(UNAVAILABLE)
        }

        pub fn predict(&self, _params: &[f32], _x: &[f32]) -> Result<Vec<f32>> {
            anyhow::bail!(UNAVAILABLE)
        }

        pub fn predict_batch(&self, _params: &[f32], _x: &[f32]) -> Result<Vec<f32>> {
            anyhow::bail!(UNAVAILABLE)
        }

        pub fn eval_mse(&self, _params: &[f32], _x: &[f32], _y: &[f32]) -> Result<f32> {
            anyhow::bail!(UNAVAILABLE)
        }
    }
}

pub use imp::Engine;

// Compile-surface check: the stub and the real engine expose the same
// entry points, so downstream code can't drift onto one of them.
#[allow(dead_code)]
fn _surface_check(manifest: &Manifest, name: &str) -> Result<()> {
    let e = Engine::new(manifest, name, Preload::Serving)?;
    let _: &Variant = e.variant();
    let _: String = e.platform();
    let _ = e.predict(&[], &[])?;
    let _ = e.predict_batch(&[], &[])?;
    let _ = e.train_step(&[], &[], &[], 0.0)?;
    let _ = e.eval_mse(&[], &[], &[])?;
    Ok(())
}
