//! General-purpose orchestrator (GPO) mock — the Kubernetes stand-in.
//!
//! The paper's HFL-specific orchestrator treats the GPO as (i) a source of
//! infrastructure truth (which nodes exist, their resource state) and
//! (ii) the executor of containerized deployments. This mock provides the
//! same interface in-process, plus fault injection for re-clustering
//! tests.

use std::collections::BTreeMap;

use crate::topology::GeoPoint;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    Device,
    EdgeHost,
    Cloud,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    Ready,
    Failed,
}

/// One registered node.
#[derive(Debug, Clone)]
pub struct NodeInfo {
    pub id: usize,
    pub kind: NodeKind,
    pub location: GeoPoint,
    /// Inference processing capacity (req/s); devices: own λ context.
    pub capacity: f64,
    pub state: NodeState,
}

/// A deployment the GPO has been instructed to realize.
#[derive(Debug, Clone, PartialEq)]
pub enum Deployment {
    Aggregator { edge_id: usize },
    FlClient { device_id: usize, aggregator_edge: Option<usize> },
    InferenceAgent { node_id: usize, kind: NodeKind },
}

/// The GPO mock: inventory + deployment ledger + event log.
#[derive(Debug, Default)]
pub struct Gpo {
    devices: BTreeMap<usize, NodeInfo>,
    edges: BTreeMap<usize, NodeInfo>,
    deployments: Vec<Deployment>,
    pub events: Vec<String>,
}

impl Gpo {
    pub fn new() -> Gpo {
        Gpo::default()
    }

    pub fn register_device(&mut self, id: usize, location: GeoPoint) {
        self.devices.insert(
            id,
            NodeInfo { id, kind: NodeKind::Device, location, capacity: 0.0, state: NodeState::Ready },
        );
    }

    pub fn register_edge(&mut self, id: usize, location: GeoPoint, capacity: f64) {
        self.edges.insert(
            id,
            NodeInfo { id, kind: NodeKind::EdgeHost, location, capacity, state: NodeState::Ready },
        );
    }

    /// Fault injection: mark a node failed and log the event.
    pub fn fail_edge(&mut self, id: usize) {
        if let Some(n) = self.edges.get_mut(&id) {
            n.state = NodeState::Failed;
            self.events.push(format!("edge {id} failed"));
        }
    }

    pub fn recover_edge(&mut self, id: usize) {
        if let Some(n) = self.edges.get_mut(&id) {
            n.state = NodeState::Ready;
            self.events.push(format!("edge {id} recovered"));
        }
    }

    /// Update an edge host's available inference capacity (e.g. another
    /// workload landed on the node) — §VI "environment dynamics".
    pub fn set_edge_capacity(&mut self, id: usize, capacity: f64) {
        if let Some(n) = self.edges.get_mut(&id) {
            n.capacity = capacity;
            self.events.push(format!("edge {id} capacity -> {capacity}"));
        }
    }

    /// Ready edge hosts (what the learning controller may place on).
    pub fn ready_edges(&self) -> Vec<&NodeInfo> {
        self.edges.values().filter(|n| n.state == NodeState::Ready).collect()
    }

    pub fn ready_devices(&self) -> Vec<&NodeInfo> {
        self.devices.values().filter(|n| n.state == NodeState::Ready).collect()
    }

    pub fn edge(&self, id: usize) -> Option<&NodeInfo> {
        self.edges.get(&id)
    }

    /// Realize a deployment plan (records it; in a real system this would
    /// drive the container orchestrator).
    pub fn apply_deployments(&mut self, deps: Vec<Deployment>) {
        self.events.push(format!("applied {} deployments", deps.len()));
        self.deployments = deps;
    }

    pub fn deployments(&self) -> &[Deployment] {
        &self.deployments
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> GeoPoint {
        GeoPoint { lat: 34.1, lon: -118.3 }
    }

    #[test]
    fn register_and_query() {
        let mut g = Gpo::new();
        g.register_device(0, p());
        g.register_edge(0, p(), 10.0);
        g.register_edge(1, p(), 20.0);
        assert_eq!(g.ready_devices().len(), 1);
        assert_eq!(g.ready_edges().len(), 2);
    }

    #[test]
    fn failure_removes_from_ready_set() {
        let mut g = Gpo::new();
        g.register_edge(0, p(), 10.0);
        g.register_edge(1, p(), 10.0);
        g.fail_edge(0);
        let ready: Vec<usize> = g.ready_edges().iter().map(|n| n.id).collect();
        assert_eq!(ready, vec![1]);
        g.recover_edge(0);
        assert_eq!(g.ready_edges().len(), 2);
        assert_eq!(g.events.len(), 2);
    }

    #[test]
    fn capacity_update_logged() {
        let mut g = Gpo::new();
        g.register_edge(3, p(), 10.0);
        g.set_edge_capacity(3, 4.0);
        assert_eq!(g.edge(3).unwrap().capacity, 4.0);
        assert!(g.events[0].contains("capacity"));
    }

    #[test]
    fn deployments_recorded() {
        let mut g = Gpo::new();
        g.apply_deployments(vec![
            Deployment::Aggregator { edge_id: 1 },
            Deployment::FlClient { device_id: 0, aggregator_edge: Some(1) },
        ]);
        assert_eq!(g.deployments().len(), 2);
    }
}
