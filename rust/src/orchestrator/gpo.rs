//! General-purpose orchestrator (GPO) mock — the Kubernetes stand-in.
//!
//! The paper's HFL-specific orchestrator treats the GPO as (i) a source of
//! infrastructure truth (which nodes exist, their resource state) and
//! (ii) the executor of containerized deployments. This mock provides the
//! same interface in-process, plus fault injection for re-clustering
//! tests.

use std::collections::{BTreeMap, BTreeSet};

use crate::topology::GeoPoint;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    Device,
    EdgeHost,
    Cloud,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    Ready,
    Failed,
}

/// One registered node.
#[derive(Debug, Clone)]
pub struct NodeInfo {
    pub id: usize,
    pub kind: NodeKind,
    pub location: GeoPoint,
    /// Inference processing capacity (req/s); devices: own λ context.
    pub capacity: f64,
    pub state: NodeState,
}

/// A deployment the GPO has been instructed to realize.
#[derive(Debug, Clone, PartialEq)]
pub enum Deployment {
    Aggregator { edge_id: usize },
    FlClient { device_id: usize, aggregator_edge: Option<usize> },
    InferenceAgent { node_id: usize, kind: NodeKind },
}

/// The GPO mock: inventory + deployment ledger + event log, with
/// epoch-stamped dirty tracking for warm-start re-orchestration
/// (DESIGN.md §10). The epoch bumps on every *effective* inventory
/// mutation (registration, liveness flip, actual capacity change); the
/// dirty sets accumulate which nodes changed since the orchestrator last
/// installed a plan and called [`clear_dirty`](Gpo::clear_dirty).
#[derive(Debug, Default)]
pub struct Gpo {
    devices: BTreeMap<usize, NodeInfo>,
    edges: BTreeMap<usize, NodeInfo>,
    deployments: Vec<Deployment>,
    pub events: Vec<String>,
    epoch: u64,
    dirty_devices: BTreeSet<usize>,
    dirty_edges: BTreeSet<usize>,
}

impl Gpo {
    pub fn new() -> Gpo {
        Gpo::default()
    }

    pub fn register_device(&mut self, id: usize, location: GeoPoint) {
        self.devices.insert(
            id,
            NodeInfo { id, kind: NodeKind::Device, location, capacity: 0.0, state: NodeState::Ready },
        );
        self.epoch += 1;
        self.dirty_devices.insert(id);
    }

    pub fn register_edge(&mut self, id: usize, location: GeoPoint, capacity: f64) {
        self.edges.insert(
            id,
            NodeInfo { id, kind: NodeKind::EdgeHost, location, capacity, state: NodeState::Ready },
        );
        self.epoch += 1;
        self.dirty_edges.insert(id);
    }

    /// Fault injection: mark a node failed and log the event.
    pub fn fail_edge(&mut self, id: usize) {
        if let Some(n) = self.edges.get_mut(&id) {
            if n.state != NodeState::Failed {
                n.state = NodeState::Failed;
                self.epoch += 1;
                self.dirty_edges.insert(id);
            }
            self.events.push(format!("edge {id} failed"));
        }
    }

    pub fn recover_edge(&mut self, id: usize) {
        if let Some(n) = self.edges.get_mut(&id) {
            if n.state != NodeState::Ready {
                n.state = NodeState::Ready;
                self.epoch += 1;
                self.dirty_edges.insert(id);
            }
            self.events.push(format!("edge {id} recovered"));
        }
    }

    /// Update an edge host's available inference capacity (e.g. another
    /// workload landed on the node) — §VI "environment dynamics".
    pub fn set_edge_capacity(&mut self, id: usize, capacity: f64) {
        if let Some(n) = self.edges.get_mut(&id) {
            if n.capacity.to_bits() != capacity.to_bits() {
                n.capacity = capacity;
                self.epoch += 1;
                self.dirty_edges.insert(id);
            }
            self.events.push(format!("edge {id} capacity -> {capacity}"));
        }
    }

    /// Monotone change stamp: unchanged epoch ⇒ the inventory is
    /// byte-identical to the last time the caller looked.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Devices changed since the last [`clear_dirty`](Gpo::clear_dirty).
    pub fn dirty_devices(&self) -> &BTreeSet<usize> {
        &self.dirty_devices
    }

    /// Edges changed since the last [`clear_dirty`](Gpo::clear_dirty).
    pub fn dirty_edges(&self) -> &BTreeSet<usize> {
        &self.dirty_edges
    }

    /// Forget accumulated dirt — the orchestrator calls this when a plan
    /// is installed, so the next dirty set is relative to that plan.
    pub fn clear_dirty(&mut self) {
        self.dirty_devices.clear();
        self.dirty_edges.clear();
    }

    /// Ready edge hosts (what the learning controller may place on).
    pub fn ready_edges(&self) -> Vec<&NodeInfo> {
        self.edges.values().filter(|n| n.state == NodeState::Ready).collect()
    }

    pub fn ready_devices(&self) -> Vec<&NodeInfo> {
        self.devices.values().filter(|n| n.state == NodeState::Ready).collect()
    }

    pub fn edge(&self, id: usize) -> Option<&NodeInfo> {
        self.edges.get(&id)
    }

    /// Realize a deployment plan (records it; in a real system this would
    /// drive the container orchestrator).
    pub fn apply_deployments(&mut self, deps: Vec<Deployment>) {
        self.events.push(format!("applied {} deployments", deps.len()));
        self.deployments = deps;
    }

    pub fn deployments(&self) -> &[Deployment] {
        &self.deployments
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> GeoPoint {
        GeoPoint { lat: 34.1, lon: -118.3 }
    }

    #[test]
    fn register_and_query() {
        let mut g = Gpo::new();
        g.register_device(0, p());
        g.register_edge(0, p(), 10.0);
        g.register_edge(1, p(), 20.0);
        assert_eq!(g.ready_devices().len(), 1);
        assert_eq!(g.ready_edges().len(), 2);
    }

    #[test]
    fn failure_removes_from_ready_set() {
        let mut g = Gpo::new();
        g.register_edge(0, p(), 10.0);
        g.register_edge(1, p(), 10.0);
        g.fail_edge(0);
        let ready: Vec<usize> = g.ready_edges().iter().map(|n| n.id).collect();
        assert_eq!(ready, vec![1]);
        g.recover_edge(0);
        assert_eq!(g.ready_edges().len(), 2);
        assert_eq!(g.events.len(), 2);
    }

    #[test]
    fn capacity_update_logged() {
        let mut g = Gpo::new();
        g.register_edge(3, p(), 10.0);
        g.set_edge_capacity(3, 4.0);
        assert_eq!(g.edge(3).unwrap().capacity, 4.0);
        assert!(g.events[0].contains("capacity"));
    }

    #[test]
    fn epoch_and_dirty_track_effective_changes_only() {
        let mut g = Gpo::new();
        g.register_device(7, p());
        g.register_edge(0, p(), 10.0);
        let e0 = g.epoch();
        assert!(e0 >= 2);
        assert!(g.dirty_devices().contains(&7));
        assert!(g.dirty_edges().contains(&0));

        g.clear_dirty();
        assert!(g.dirty_devices().is_empty() && g.dirty_edges().is_empty());
        assert_eq!(g.epoch(), e0, "clear_dirty must not advance the epoch");

        g.fail_edge(0);
        assert_eq!(g.epoch(), e0 + 1);
        assert!(g.dirty_edges().contains(&0));
        // Redundant fail: still logged, but no epoch bump / re-dirty.
        g.clear_dirty();
        g.fail_edge(0);
        assert_eq!(g.epoch(), e0 + 1);
        assert!(g.dirty_edges().is_empty());
        assert_eq!(g.events.len(), 2, "every fault call is logged regardless");

        g.recover_edge(0);
        assert_eq!(g.epoch(), e0 + 2);

        // Same-value capacity report: logged, not a change.
        g.clear_dirty();
        g.set_edge_capacity(0, 10.0);
        assert_eq!(g.epoch(), e0 + 2);
        assert!(g.dirty_edges().is_empty());
        g.set_edge_capacity(0, 4.0);
        assert_eq!(g.epoch(), e0 + 3);
        assert!(g.dirty_edges().contains(&0));
    }

    #[test]
    fn deployments_recorded() {
        let mut g = Gpo::new();
        g.apply_deployments(vec![
            Deployment::Aggregator { edge_id: 1 },
            Deployment::FlClient { device_id: 0, aggregator_edge: Some(1) },
        ]);
        assert_eq!(g.deployments().len(), 2);
    }
}
