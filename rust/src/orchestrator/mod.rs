//! HFL orchestration layer — the paper's §III architecture.
//!
//! * [`gpo`] — the general-purpose-orchestrator mock (Kubernetes stand-in):
//!   node inventory, resource states, deployment plans, fault injection.
//! * [`learning`] — the learning controller: pulls inventory + workload
//!   info from the GPO, builds the HFLOP instance, invokes the clustering
//!   mechanism (the solver), emits a deployment plan, and re-clusters on
//!   environmental events (node failure, capacity change).
//! * [`inference_ctl`] — the inference controller: deploys serving agents
//!   per node, monitors accuracy, and triggers a new HFL task when
//!   inference accuracy degrades below threshold (continual learning).
//! * [`budget`] — the communication-cost control plane (DESIGN.md §11):
//!   an action cost model pricing reconfigurations in bytes, and the
//!   budget policy (hard cap + epoch-refill token bucket) the learning
//!   controller consults before installing a plan.

pub mod budget;
pub mod gpo;
pub mod inference_ctl;
pub mod learning;

pub use budget::{ActionCostModel, BudgetGovernor, BudgetPolicy, PlanDelta, TokenBucket};
pub use gpo::{Gpo, NodeKind, NodeState};
pub use inference_ctl::{InferenceController, InferenceCtlConfig};
pub use learning::{DeploymentPlan, LearningController, LearningCtlConfig, ResolveStrategy};
