//! Budget-governed reactive re-orchestration — the communication-cost
//! control plane (DESIGN.md §11).
//!
//! The paper's orchestrator re-solves on staleness and drift with no
//! notion of what a reconfiguration costs on the wire, yet its headline
//! result is that HFL wins precisely because communication is scarce.
//! Following the group's follow-up work on cost-aware reactive
//! orchestration, this module prices every control action in bytes and
//! gates plan installs behind an explicit budget:
//!
//! * [`ActionCostModel`] — the price list. A plan install costs one full
//!   model push plus a signalling message per *reassigned* device and a
//!   churn message per aggregator opened or closed; a warm partial
//!   repair is estimated from the [`DirtySet`] it touches; doing nothing
//!   costs telemetry only.
//! * [`BudgetPolicy`] — a hard cumulative cap and/or an epoch-refill
//!   [`TokenBucket`]. Both default to absent (= unlimited), which keeps
//!   every pre-budget golden path byte-identical: an unlimited governor
//!   meters traffic but never changes a decision.
//! * [`BudgetGovernor`] — what the [`LearningController`] carries and
//!   the co-sim control plane consults before acting. Denied installs
//!   are *deferred*: the stale plan stays live, the trigger stays
//!   pending, and the next monitor tick re-prices the latest desired
//!   plan against the refilled budget.
//!
//! Everything here is integer byte arithmetic driven by simulated time,
//! so the module lives in the detlint deterministic zone
//! (`rust/lint.toml`): bucket refills are idempotent per epoch and
//! independent of event tie-ordering at equal timestamps.
//!
//! [`LearningController`]: super::learning::LearningController

use crate::metrics::cost::CommLedger;
use crate::solver::DirtySet;

/// Prices of control-plane actions in bytes (the DESIGN.md §11 table).
#[derive(Debug, Clone, PartialEq)]
pub struct ActionCostModel {
    /// Full-model transfer size: what one reassigned device downloads.
    pub model_bytes: usize,
    /// Reassignment signalling message per displaced device.
    pub signal_bytes: u64,
    /// Churn message per aggregator opened or closed by a swap.
    pub churn_bytes: u64,
    /// Monitoring telemetry per control decision — charged even when
    /// the decision is "do nothing".
    pub telemetry_bytes: u64,
}

impl Default for ActionCostModel {
    fn default() -> Self {
        ActionCostModel {
            model_bytes: 262_144,
            signal_bytes: 512,
            churn_bytes: 4_096,
            telemetry_bytes: 256,
        }
    }
}

impl ActionCostModel {
    /// Default message sizes around an explicit model size (the co-sim
    /// wires its `model_bytes` here so redistribution pricing matches
    /// the training plane's transfer accounting).
    pub fn for_model(model_bytes: usize) -> ActionCostModel {
        ActionCostModel { model_bytes, ..Default::default() }
    }

    /// Price of actually installing a plan, from the realized
    /// [`PlanDelta`] — NOT from the instance size. A no-op delta prices
    /// to zero (the governor then charges telemetry only).
    pub fn install_bytes(&self, delta: &PlanDelta) -> u64 {
        (delta.reassigned as u64)
            .saturating_mul(self.model_bytes as u64 + self.signal_bytes)
            .saturating_add((delta.churned_edges as u64).saturating_mul(self.churn_bytes))
    }

    /// Worst-case estimate for a full re-solve: every device
    /// redistributed, every aggregator churned.
    pub fn full_estimate(&self, n_devices: usize, n_edges: usize) -> u64 {
        (n_devices as u64)
            .saturating_mul(self.model_bytes as u64 + self.signal_bytes)
            .saturating_add((n_edges as u64).saturating_mul(self.churn_bytes))
    }

    /// Estimate for a warm partial repair, priced from the [`DirtySet`]
    /// it would touch: transfers only for the displaced rows, churn only
    /// for the dirty columns.
    pub fn repair_estimate(&self, dirty: &DirtySet) -> u64 {
        (dirty.rows.len() as u64)
            .saturating_mul(self.model_bytes as u64 + self.signal_bytes)
            .saturating_add((dirty.cols.len() as u64).saturating_mul(self.churn_bytes))
    }
}

/// The realized difference between the live assignment and a candidate
/// plan — what an install actually moves on the wire.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlanDelta {
    /// Devices whose serving edge changes (including to/from `None`).
    pub reassigned: usize,
    /// Edges entering or leaving the set of used aggregators.
    pub churned_edges: usize,
}

impl PlanDelta {
    /// An identical plan: nothing moves, the decision is telemetry only.
    pub fn is_noop(&self) -> bool {
        self.reassigned == 0 && self.churned_edges == 0
    }
}

/// Diff two dense per-device assignments (old = live, new = candidate).
/// An edge counts as churned when it gains its first device or loses
/// its last one — aggregator spin-up/teardown traffic.
pub fn plan_delta(old: &[Option<usize>], new: &[Option<usize>]) -> PlanDelta {
    let n = old.len().max(new.len());
    let mut reassigned = 0usize;
    let mut old_used = std::collections::BTreeSet::new();
    let mut new_used = std::collections::BTreeSet::new();
    for d in 0..n {
        let a = old.get(d).copied().flatten();
        let b = new.get(d).copied().flatten();
        if a != b {
            reassigned += 1;
        }
        if let Some(j) = a {
            old_used.insert(j);
        }
        if let Some(j) = b {
            new_used.insert(j);
        }
    }
    let churned_edges = old_used.symmetric_difference(&new_used).count();
    PlanDelta { reassigned, churned_edges }
}

/// Epoch-refill token bucket over simulated time. `refill_to` is
/// idempotent within an epoch: any number of calls at the same (or an
/// earlier) timestamp is a no-op, so spend/refill outcomes cannot
/// depend on how same-time events happen to be ordered.
#[derive(Debug, Clone, PartialEq)]
pub struct TokenBucket {
    /// Bytes added once per elapsed epoch.
    pub refill_bytes: u64,
    /// Epoch length in simulated seconds.
    pub epoch_s: f64,
    /// Level ceiling (unclaimed refills saturate here).
    pub burst_bytes: u64,
    level: u64,
    last_epoch: u64,
}

impl TokenBucket {
    /// A bucket that starts full (level = `burst_bytes`).
    pub fn new(refill_bytes: u64, epoch_s: f64, burst_bytes: u64) -> TokenBucket {
        TokenBucket { refill_bytes, epoch_s, burst_bytes, level: burst_bytes, last_epoch: 0 }
    }

    /// A bucket that starts empty: budget accrues one refill per epoch,
    /// so early triggers defer until spend capacity has accumulated.
    pub fn starting_empty(refill_bytes: u64, epoch_s: f64, burst_bytes: u64) -> TokenBucket {
        TokenBucket { refill_bytes, epoch_s, burst_bytes, level: 0, last_epoch: 0 }
    }

    pub fn level(&self) -> u64 {
        self.level
    }

    /// Advance the bucket to simulated time `now_s`, crediting one
    /// refill per fully elapsed epoch since the last credit.
    pub fn refill_to(&mut self, now_s: f64) {
        if !self.epoch_s.is_finite() || self.epoch_s <= 0.0 || !now_s.is_finite() || now_s <= 0.0 {
            return;
        }
        // Guarded float→int: now_s is finite and positive here, and the
        // epoch index is clamped below u64 range before the cast.
        let epoch = (now_s / self.epoch_s).min(u32::MAX as f64).max(0.0) as u64;
        if epoch > self.last_epoch {
            let credit = (epoch - self.last_epoch).saturating_mul(self.refill_bytes);
            self.level = self.level.saturating_add(credit).min(self.burst_bytes);
            self.last_epoch = epoch;
        }
    }

    fn affords(&self, cost: u64) -> bool {
        cost <= self.level
    }

    fn drain(&mut self, cost: u64) {
        self.level = self.level.saturating_sub(cost);
    }
}

/// The budget itself: an optional hard cumulative cap plus an optional
/// refilling bucket. `None`/`None` (the default) is unlimited — every
/// spend is approved, which is what keeps pre-budget behavior intact.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BudgetPolicy {
    /// Hard ceiling on cumulative approved spend, in bytes.
    pub cap_bytes: Option<u64>,
    /// Rate limit on spend over time.
    pub bucket: Option<TokenBucket>,
    /// Cumulative approved reconfiguration spend (metered even when
    /// unlimited, so the oracle run reports its spend too).
    pub spent_bytes: u64,
}

impl BudgetPolicy {
    pub fn unlimited() -> BudgetPolicy {
        BudgetPolicy::default()
    }

    pub fn capped(cap_bytes: u64) -> BudgetPolicy {
        BudgetPolicy { cap_bytes: Some(cap_bytes), ..Default::default() }
    }

    pub fn with_bucket(mut self, bucket: TokenBucket) -> BudgetPolicy {
        self.bucket = Some(bucket);
        self
    }

    pub fn is_unlimited(&self) -> bool {
        self.cap_bytes.is_none() && self.bucket.is_none()
    }

    pub fn refill_to(&mut self, now_s: f64) {
        if let Some(b) = &mut self.bucket {
            b.refill_to(now_s);
        }
    }

    /// Would `cost` fit right now (cap headroom AND bucket level)?
    pub fn affords(&self, cost: u64) -> bool {
        let cap_ok =
            self.cap_bytes.map_or(true, |cap| self.spent_bytes.saturating_add(cost) <= cap);
        let bucket_ok = self.bucket.as_ref().map_or(true, |b| b.affords(cost));
        cap_ok && bucket_ok
    }

    /// Refill to `now_s`, then spend `cost` if it fits. Returns whether
    /// the spend was approved; cumulative spend can therefore never
    /// exceed `cap_bytes`.
    pub fn try_spend(&mut self, now_s: f64, cost: u64) -> bool {
        self.refill_to(now_s);
        if !self.affords(cost) {
            return false;
        }
        self.spent_bytes = self.spent_bytes.saturating_add(cost);
        if let Some(b) = &mut self.bucket {
            b.drain(cost);
        }
        true
    }
}

/// What the learning controller carries: the price list, the budget,
/// and the per-category [`CommLedger`] the spend is metered into.
#[derive(Debug, Clone, PartialEq)]
pub struct BudgetGovernor {
    pub costs: ActionCostModel,
    pub policy: BudgetPolicy,
    /// Control-plane traffic by category (`redistribution_bytes`,
    /// `signalling_bytes`, `telemetry_bytes`; the training-plane fields
    /// stay zero here).
    pub ledger: CommLedger,
    /// Plan installs denied (and queued) by the policy.
    pub deferrals: usize,
    /// A denied install awaits re-evaluation at the next monitor tick.
    pending: bool,
}

impl Default for BudgetGovernor {
    fn default() -> Self {
        BudgetGovernor::unlimited(ActionCostModel::default())
    }
}

impl BudgetGovernor {
    pub fn new(costs: ActionCostModel, policy: BudgetPolicy) -> BudgetGovernor {
        BudgetGovernor { costs, policy, ledger: CommLedger::new(), deferrals: 0, pending: false }
    }

    /// A governor that meters but never denies.
    pub fn unlimited(costs: ActionCostModel) -> BudgetGovernor {
        BudgetGovernor::new(costs, BudgetPolicy::unlimited())
    }

    /// One monitoring heartbeat: refill the bucket and meter telemetry.
    pub fn note_telemetry(&mut self, now_s: f64) {
        self.policy.refill_to(now_s);
        self.ledger.telemetry(self.costs.telemetry_bytes);
    }

    /// Gate one plan install, priced from the *actual* delta between
    /// the live assignment and the candidate plan. A no-op delta is
    /// charged telemetry only and always approved; a real delta spends
    /// `install_bytes(delta)` or is deferred.
    pub fn approve_install(&mut self, now_s: f64, delta: &PlanDelta) -> bool {
        if delta.is_noop() {
            self.ledger.telemetry(self.costs.telemetry_bytes);
            self.pending = false;
            return true;
        }
        let cost = self.costs.install_bytes(delta);
        if self.policy.try_spend(now_s, cost) {
            self.ledger.model_redistribution(delta.reassigned, self.costs.model_bytes);
            self.ledger.reconfiguration_signal(
                (delta.reassigned as u64)
                    .saturating_mul(self.costs.signal_bytes)
                    .saturating_add(
                        (delta.churned_edges as u64).saturating_mul(self.costs.churn_bytes),
                    ),
            );
            self.pending = false;
            true
        } else {
            self.deferrals += 1;
            self.pending = true;
            false
        }
    }

    /// Is a deferred install queued for re-evaluation on refill?
    pub fn has_pending(&self) -> bool {
        self.pending
    }

    /// Strategy hint for `ResolveStrategy::Auto` under budget pressure:
    /// prefer a warm partial repair when the worst-case full re-solve
    /// does not fit the current budget but the DirtySet-priced repair
    /// does. Always `false` when unlimited, so the pre-budget Auto
    /// heuristic is unchanged by default.
    pub fn budget_prefers_partial(&self, n: usize, m: usize, dirty: &DirtySet) -> bool {
        if self.policy.is_unlimited() {
            return false;
        }
        !self.policy.affords(self.costs.full_estimate(n, m))
            && self.policy.affords(self.costs.repair_estimate(dirty))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta(reassigned: usize, churned_edges: usize) -> PlanDelta {
        PlanDelta { reassigned, churned_edges }
    }

    #[test]
    fn plan_delta_prices_the_actual_diff_not_instance_size() {
        let old = vec![Some(0), Some(0), Some(1), None];
        let new = vec![Some(0), Some(2), Some(1), None];
        // One device moved (1), edge 0 keeps a device, edge 2 gains its
        // first device (churn 1); edge 1 is untouched.
        assert_eq!(plan_delta(&old, &new), delta(1, 1));
        // Identical plans: a no-op regardless of how many devices exist.
        assert!(plan_delta(&old, &old).is_noop());
        // Length mismatch treats missing tail entries as unassigned.
        assert_eq!(plan_delta(&[Some(0)], &[Some(0), Some(1)]), delta(1, 1));
    }

    #[test]
    fn noop_install_is_telemetry_only() {
        // Satellite regression: a re-solve that lands on the identical
        // plan must charge telemetry, not redistribution or signalling.
        let mut gov = BudgetGovernor::new(ActionCostModel::default(), BudgetPolicy::capped(1));
        assert!(gov.approve_install(10.0, &delta(0, 0)));
        assert_eq!(gov.policy.spent_bytes, 0, "no-op must not touch the budget");
        assert_eq!(gov.ledger.redistribution_bytes, 0);
        assert_eq!(gov.ledger.signalling_bytes, 0);
        assert_eq!(gov.ledger.telemetry_bytes, ActionCostModel::default().telemetry_bytes);
        assert_eq!(gov.deferrals, 0);
    }

    #[test]
    fn install_cost_scales_with_delta_and_meters_categories() {
        let costs = ActionCostModel {
            model_bytes: 1_000,
            signal_bytes: 10,
            churn_bytes: 100,
            telemetry_bytes: 1,
        };
        let mut gov = BudgetGovernor::unlimited(costs);
        assert!(gov.approve_install(0.0, &delta(3, 2)));
        assert_eq!(gov.policy.spent_bytes, 3 * 1_010 + 2 * 100);
        assert_eq!(gov.ledger.redistribution_bytes, 3_000);
        assert_eq!(gov.ledger.signalling_bytes, 3 * 10 + 2 * 100);
        assert_eq!(gov.ledger.telemetry_bytes, 0);
        assert_eq!(gov.ledger.total_bytes(), 0, "control spend must not pollute training totals");
    }

    #[test]
    fn hard_cap_is_never_exceeded_and_denials_defer() {
        let costs = ActionCostModel {
            model_bytes: 1_000,
            signal_bytes: 0,
            churn_bytes: 0,
            telemetry_bytes: 1,
        };
        let mut gov = BudgetGovernor::new(costs, BudgetPolicy::capped(2_500));
        assert!(gov.approve_install(1.0, &delta(2, 0))); // 2000 ≤ 2500
        assert!(!gov.approve_install(2.0, &delta(1, 0)), "1000 more would breach the cap");
        assert!(gov.has_pending());
        assert_eq!(gov.deferrals, 1);
        assert_eq!(gov.policy.spent_bytes, 2_000);
        // The queue drains once an affordable delta shows up.
        assert!(!gov.approve_install(3.0, &delta(1, 0)));
        assert_eq!(gov.deferrals, 2);
        assert!(gov.approve_install(4.0, &delta(0, 0)), "no-op still approved");
        assert!(!gov.has_pending(), "an approved decision clears the queue");
        assert!(gov.policy.spent_bytes <= 2_500);
    }

    #[test]
    fn token_bucket_refills_per_epoch_and_saturates_at_burst() {
        let mut b = TokenBucket::new(100, 10.0, 250);
        assert_eq!(b.level(), 250, "bucket starts full");
        b.drain(250);
        b.refill_to(9.9);
        assert_eq!(b.level(), 0, "no epoch elapsed yet");
        b.refill_to(10.0);
        assert_eq!(b.level(), 100);
        b.refill_to(45.0); // epochs 1→4: 3 more refills, clipped at burst
        assert_eq!(b.level(), 250);
        // Time never flows backwards in the kernel, but a stale call
        // must still be harmless.
        b.refill_to(10.0);
        assert_eq!(b.level(), 250);
    }

    #[test]
    fn refill_is_independent_of_event_tie_ordering() {
        // Two same-timestamp schedules of the same work, interleaved
        // differently: spend-then-extra-refills vs refills-then-spend.
        // The refill is idempotent per epoch, so both orders land on the
        // identical (level, spent) state.
        let policy = || {
            BudgetPolicy::capped(10_000).with_bucket(TokenBucket::new(500, 10.0, 1_000))
        };
        let t = 30.0;

        let mut a = policy();
        assert!(a.try_spend(t, 700));
        a.refill_to(t);
        a.refill_to(t);
        assert!(!a.try_spend(t, 700), "level 300 cannot fund another 700 at the same tick");

        let mut b = policy();
        b.refill_to(t);
        b.refill_to(t);
        assert!(b.try_spend(t, 700));
        assert!(!b.try_spend(t, 700));

        assert_eq!(a, b, "tie-order must not affect bucket state");
        assert_eq!(a.bucket.as_ref().unwrap().level(), 300);
        assert_eq!(a.spent_bytes, 700);
    }

    #[test]
    fn bucket_rate_limits_but_cap_bounds_cumulative_spend() {
        let mut p = BudgetPolicy::capped(1_500).with_bucket(TokenBucket::new(1_000, 10.0, 1_000));
        assert!(p.try_spend(0.0, 1_000));
        assert!(!p.try_spend(5.0, 1_000), "bucket empty mid-epoch");
        // The bucket refills at t=10 but the hard cap only has 500 left.
        assert!(!p.try_spend(10.0, 1_000));
        assert!(p.try_spend(10.0, 500));
        assert_eq!(p.spent_bytes, 1_500);
        assert!(!p.try_spend(100.0, 1), "cap exhausted forever");
    }

    #[test]
    fn unlimited_policy_always_approves_but_still_meters() {
        let mut p = BudgetPolicy::unlimited();
        assert!(p.is_unlimited());
        for k in 0..100 {
            assert!(p.try_spend(k as f64, 1_000_000));
        }
        assert_eq!(p.spent_bytes, 100_000_000);
    }

    #[test]
    fn budget_pressure_prefers_partial_repair() {
        let costs = ActionCostModel {
            model_bytes: 1_000,
            signal_bytes: 0,
            churn_bytes: 0,
            telemetry_bytes: 0,
        };
        let dirty = DirtySet { rows: vec![0, 1], cols: vec![0] };
        // Unlimited: never overrides the Auto heuristic.
        let gov = BudgetGovernor::unlimited(costs.clone());
        assert!(!gov.budget_prefers_partial(100, 4, &dirty));
        // Tight budget: a 100-device full redistribution (100k) does not
        // fit, the 2-row repair (2k) does.
        let gov = BudgetGovernor::new(costs.clone(), BudgetPolicy::capped(5_000));
        assert!(gov.budget_prefers_partial(100, 4, &dirty));
        // Starved budget: neither fits — no preference, the install gate
        // will defer whatever the solver produces.
        let gov = BudgetGovernor::new(costs, BudgetPolicy::capped(1_000));
        assert!(!gov.budget_prefers_partial(100, 4, &dirty));
    }
}
