//! Inference controller (§III): deploys/monitors inference services and
//! triggers new HFL tasks when served-model accuracy degrades — the
//! continual-learning control loop ("a task of the inference controller
//! is to monitor inference services and trigger a new HFL task if
//! inference accuracy is below a specific threshold").

/// Controller configuration.
#[derive(Debug, Clone)]
pub struct InferenceCtlConfig {
    /// Trigger retraining when the exponentially-weighted MSE exceeds
    /// this threshold.
    pub mse_threshold: f32,
    /// EWMA smoothing factor in (0, 1]; higher = more reactive.
    pub alpha: f32,
    /// Minimum observations before triggering (debounce).
    pub min_observations: usize,
    /// Cooldown (observations) after a trigger before the next one.
    pub cooldown: usize,
}

impl Default for InferenceCtlConfig {
    fn default() -> Self {
        InferenceCtlConfig {
            mse_threshold: 0.1,
            alpha: 0.2,
            min_observations: 10,
            cooldown: 20,
        }
    }
}

/// Accuracy-triggered retraining monitor.
#[derive(Debug, Clone)]
pub struct InferenceController {
    pub config: InferenceCtlConfig,
    ewma_mse: Option<f32>,
    observations: usize,
    since_trigger: usize,
    pub triggers: usize,
}

impl InferenceController {
    pub fn new(config: InferenceCtlConfig) -> InferenceController {
        InferenceController {
            config,
            ewma_mse: None,
            observations: 0,
            since_trigger: usize::MAX / 2,
            triggers: 0,
        }
    }

    pub fn ewma(&self) -> Option<f32> {
        self.ewma_mse
    }

    /// Feed one observed serving-accuracy sample (per-request or batched
    /// MSE). Returns true when a new HFL task should be triggered.
    pub fn observe_mse(&mut self, mse: f32) -> bool {
        let a = self.config.alpha;
        self.ewma_mse = Some(match self.ewma_mse {
            None => mse,
            Some(prev) => a * mse + (1.0 - a) * prev,
        });
        self.observations += 1;
        self.since_trigger = self.since_trigger.saturating_add(1);

        let degraded = self.ewma_mse.unwrap() > self.config.mse_threshold;
        if degraded
            && self.observations >= self.config.min_observations
            && self.since_trigger >= self.config.cooldown
        {
            self.triggers += 1;
            self.since_trigger = 0;
            return true;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl(threshold: f32) -> InferenceController {
        InferenceController::new(InferenceCtlConfig {
            mse_threshold: threshold,
            alpha: 0.5,
            min_observations: 3,
            cooldown: 5,
        })
    }

    #[test]
    fn healthy_model_never_triggers() {
        let mut c = ctl(0.1);
        for _ in 0..100 {
            assert!(!c.observe_mse(0.01));
        }
        assert_eq!(c.triggers, 0);
    }

    #[test]
    fn degradation_triggers_after_min_observations() {
        let mut c = ctl(0.1);
        let mut fired_at = None;
        for i in 0..10 {
            if c.observe_mse(0.5) {
                fired_at = Some(i);
                break;
            }
        }
        assert_eq!(fired_at, Some(2)); // 3rd observation (min_observations)
    }

    #[test]
    fn cooldown_debounces_repeated_triggers() {
        let mut c = ctl(0.1);
        let mut fires = 0;
        for _ in 0..20 {
            if c.observe_mse(1.0) {
                fires += 1;
            }
        }
        // First at obs 3, then every 5 observations (cooldown).
        assert!(fires >= 3 && fires <= 5, "{fires}");
        assert_eq!(c.triggers, fires);
    }

    #[test]
    fn ewma_smooths_spikes() {
        let mut c = ctl(0.5);
        for _ in 0..10 {
            c.observe_mse(0.1);
        }
        // One spike must not immediately trigger with alpha=0.5 and
        // threshold 0.5: ewma = 0.5*0.8 + 0.5*0.1 = 0.45.
        assert!(!c.observe_mse(0.8));
        assert!(c.ewma().unwrap() < 0.5);
    }

    #[test]
    fn recovery_resets_behaviour() {
        let mut c = ctl(0.1);
        for _ in 0..10 {
            c.observe_mse(1.0);
        }
        // During EWMA decay a trailing trigger may still fire; once the
        // smoothed MSE is back under threshold, no more triggers ever.
        let mut decay_fires = 0;
        for _ in 0..10 {
            if c.observe_mse(0.001) {
                decay_fires += 1;
            }
        }
        assert!(decay_fires <= 2, "{decay_fires}");
        assert!(c.ewma().unwrap() < 0.1);
        for _ in 0..50 {
            assert!(!c.observe_mse(0.001));
        }
    }
}
