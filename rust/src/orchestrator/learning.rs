//! Learning controller: the HFL-specific orchestrator component that owns
//! the clustering mechanism (§III).
//!
//! Responsibilities implemented here:
//! * pull node inventory + inference workload info from the [`Gpo`];
//! * build the HFLOP instance and solve it (the clustering mechanism);
//! * translate the solution into a deployment plan (aggregator
//!   placements, client associations, inference agents per node);
//! * re-cluster on environmental events: edge failure or capacity change
//!   invalidates the current plan (§VI "dealing with environment
//!   dynamics").

use super::gpo::{Deployment, Gpo, NodeKind};
use crate::core::DenseMatrix;
use crate::hflop::Instance;
use crate::solver::{self, Assignment, SolveOptions};
use crate::topology::haversine_km;

/// Controller configuration.
#[derive(Debug, Clone)]
pub struct LearningCtlConfig {
    /// Local rounds per global round (HFLOP's `l`).
    pub l: f64,
    /// Minimum participating devices (HFLOP's T).
    pub t_min: usize,
    /// Device→edge cost: km beyond which distance is metered.
    pub free_radius_km: f64,
    /// Edge↔cloud cost per exchange.
    pub cloud_cost: f64,
    pub solve: SolveOptions,
}

impl Default for LearningCtlConfig {
    fn default() -> Self {
        LearningCtlConfig {
            l: 2.0,
            t_min: 0,
            free_radius_km: 3.0,
            cloud_cost: 25.0,
            solve: SolveOptions::auto(),
        }
    }
}

/// The realized HFL configuration.
#[derive(Debug, Clone)]
pub struct DeploymentPlan {
    /// Device id (GPO numbering) → edge id, in instance-local indices
    /// mapped back to GPO ids.
    pub assignment: Assignment,
    /// GPO edge ids corresponding to instance columns.
    pub edge_ids: Vec<usize>,
    /// GPO device ids corresponding to instance rows.
    pub device_ids: Vec<usize>,
    pub cost: f64,
    pub proven_optimal: bool,
}

impl DeploymentPlan {
    /// Expand to GPO deployment records.
    pub fn deployments(&self) -> Vec<Deployment> {
        let mut out = Vec::new();
        for (col, &edge_id) in self.edge_ids.iter().enumerate() {
            if self.assignment.open[col] {
                out.push(Deployment::Aggregator { edge_id });
                out.push(Deployment::InferenceAgent { node_id: edge_id, kind: NodeKind::EdgeHost });
            }
        }
        for (row, &dev_id) in self.device_ids.iter().enumerate() {
            let agg = self.assignment.assign[row].map(|c| self.edge_ids[c]);
            out.push(Deployment::FlClient { device_id: dev_id, aggregator_edge: agg });
            out.push(Deployment::InferenceAgent { node_id: dev_id, kind: NodeKind::Device });
        }
        out
    }

    /// GPO edge id serving a GPO device id, if assigned.
    pub fn aggregator_of(&self, device_id: usize) -> Option<usize> {
        let row = self.device_ids.iter().position(|&d| d == device_id)?;
        self.assignment.assign[row].map(|c| self.edge_ids[c])
    }

    /// Dense device-indexed assignment (`out[device_id] = Some(edge_id)`)
    /// for worlds with dense GPO ids — the form the serving plane routes
    /// by. Devices the plan does not cover stay `None` (direct-to-cloud).
    pub fn assignment_by_device(&self, n_devices: usize) -> Vec<Option<usize>> {
        let mut out = vec![None; n_devices];
        for (row, &dev) in self.device_ids.iter().enumerate() {
            if dev < n_devices {
                out[dev] = self.assignment.assign[row].map(|c| self.edge_ids[c]);
            }
        }
        out
    }
}

/// The learning controller.
pub struct LearningController {
    pub config: LearningCtlConfig,
    /// Per-device inference rates λ_i, keyed by GPO device id.
    pub lambda: std::collections::BTreeMap<usize, f64>,
    pub current_plan: Option<DeploymentPlan>,
    /// Count of re-clustering runs (observability).
    pub reclusters: usize,
}

impl LearningController {
    pub fn new(config: LearningCtlConfig) -> LearningController {
        LearningController {
            config,
            lambda: Default::default(),
            current_plan: None,
            reclusters: 0,
        }
    }

    pub fn set_lambda(&mut self, device_id: usize, rate: f64) {
        self.lambda.insert(device_id, rate);
    }

    /// Build the HFLOP instance from current GPO state.
    pub fn build_instance(&self, gpo: &Gpo) -> anyhow::Result<(Instance, Vec<usize>, Vec<usize>)> {
        let devices = gpo.ready_devices();
        let edges = gpo.ready_edges();
        anyhow::ensure!(!devices.is_empty(), "no ready devices");
        anyhow::ensure!(!edges.is_empty(), "no ready edge hosts");

        let device_ids: Vec<usize> = devices.iter().map(|n| n.id).collect();
        let edge_ids: Vec<usize> = edges.iter().map(|n| n.id).collect();

        let c_d = DenseMatrix::from_fn(devices.len(), edges.len(), |i, j| {
            let km = haversine_km(devices[i].location, edges[j].location);
            if km <= self.config.free_radius_km {
                0.0
            } else {
                km
            }
        });

        let t_min = if self.config.t_min == 0 { devices.len() } else { self.config.t_min };
        let inst = Instance {
            c_d,
            c_e: vec![self.config.cloud_cost; edges.len()],
            lambda: device_ids
                .iter()
                .map(|id| self.lambda.get(id).copied().unwrap_or(1.0))
                .collect(),
            r: edges.iter().map(|e| e.capacity).collect(),
            l: self.config.l,
            t_min: t_min.min(devices.len()),
            meta: Default::default(),
        };
        Ok((inst, device_ids, edge_ids))
    }

    /// Run the clustering mechanism and install the plan into the GPO.
    pub fn cluster(&mut self, gpo: &mut Gpo) -> anyhow::Result<&DeploymentPlan> {
        let (inst, device_ids, edge_ids) = self.build_instance(gpo)?;
        let sol = solver::solve(&inst, &self.config.solve)
            .map_err(|e| anyhow::anyhow!("clustering failed: {e}"))?;
        let plan = DeploymentPlan {
            assignment: sol.assignment,
            edge_ids,
            device_ids,
            cost: sol.cost,
            proven_optimal: sol.proven_optimal,
        };
        gpo.apply_deployments(plan.deployments());
        self.current_plan = Some(plan);
        self.reclusters += 1;
        Ok(self.current_plan.as_ref().unwrap())
    }

    /// React to an environmental event: if the current plan references a
    /// failed edge or stale capacity, re-cluster. Returns true if a new
    /// plan was produced.
    pub fn on_environment_change(&mut self, gpo: &mut Gpo) -> anyhow::Result<bool> {
        let plan_invalid = match &self.current_plan {
            None => true,
            Some(plan) => {
                // Any open aggregator on a non-ready or capacity-reduced edge?
                plan.edge_ids.iter().enumerate().any(|(col, &eid)| {
                    plan.assignment.open[col]
                        && match gpo.edge(eid) {
                            None => true,
                            Some(n) => {
                                n.state != super::gpo::NodeState::Ready || {
                                    // Capacity below the load we routed to it.
                                    let load: f64 = plan
                                        .device_ids
                                        .iter()
                                        .enumerate()
                                        .filter(|(row, _)| plan.assignment.assign[*row] == Some(col))
                                        .map(|(row, _)| {
                                            self.lambda
                                                .get(&plan.device_ids[row])
                                                .copied()
                                                .unwrap_or(1.0)
                                        })
                                        .sum();
                                    load > n.capacity + 1e-9
                                }
                            }
                        }
                })
            }
        };
        if plan_invalid {
            self.cluster(gpo)?;
            Ok(true)
        } else {
            Ok(false)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::GeoPoint;

    fn setup(n_dev: usize, n_edge: usize) -> (Gpo, LearningController) {
        let mut gpo = Gpo::new();
        for i in 0..n_dev {
            gpo.register_device(
                i,
                GeoPoint { lat: 34.0 + 0.01 * (i % 5) as f64, lon: -118.4 + 0.02 * (i / 5) as f64 },
            );
        }
        for j in 0..n_edge {
            gpo.register_edge(
                100 + j,
                GeoPoint { lat: 34.0 + 0.02 * j as f64, lon: -118.4 + 0.03 * j as f64 },
                8.0,
            );
        }
        let mut ctl = LearningController::new(LearningCtlConfig::default());
        for i in 0..n_dev {
            ctl.set_lambda(i, 1.0);
        }
        (gpo, ctl)
    }

    #[test]
    fn clustering_produces_feasible_plan() {
        let (mut gpo, mut ctl) = setup(12, 3);
        let plan = ctl.cluster(&mut gpo).unwrap().clone();
        let (inst, _, _) = ctl.build_instance(&gpo).unwrap();
        plan.assignment.check_feasible(&inst).unwrap();
        assert!(!gpo.deployments().is_empty());
    }

    #[test]
    fn plan_maps_gpo_ids() {
        let (mut gpo, mut ctl) = setup(6, 2);
        let plan = ctl.cluster(&mut gpo).unwrap();
        for dev in 0..6 {
            let agg = plan.aggregator_of(dev);
            assert!(agg.map(|e| e >= 100).unwrap_or(false), "device {dev} -> {agg:?}");
        }
    }

    #[test]
    fn edge_failure_triggers_recluster() {
        let (mut gpo, mut ctl) = setup(10, 3);
        ctl.cluster(&mut gpo).unwrap();
        assert_eq!(ctl.reclusters, 1);
        // Fail an edge actually used by the plan.
        let used = ctl
            .current_plan
            .as_ref()
            .unwrap()
            .edge_ids
            .iter()
            .enumerate()
            .find(|(c, _)| ctl.current_plan.as_ref().unwrap().assignment.open[*c])
            .map(|(_, &e)| e)
            .unwrap();
        gpo.fail_edge(used);
        let changed = ctl.on_environment_change(&mut gpo).unwrap();
        assert!(changed);
        assert_eq!(ctl.reclusters, 2);
        // New plan uses only ready edges.
        let plan = ctl.current_plan.as_ref().unwrap();
        assert!(!plan.edge_ids.contains(&used));
    }

    #[test]
    fn no_recluster_when_plan_still_valid() {
        let (mut gpo, mut ctl) = setup(10, 3);
        ctl.cluster(&mut gpo).unwrap();
        let changed = ctl.on_environment_change(&mut gpo).unwrap();
        assert!(!changed);
        assert_eq!(ctl.reclusters, 1);
    }

    #[test]
    fn capacity_drop_below_load_triggers_recluster() {
        let (mut gpo, mut ctl) = setup(10, 2);
        ctl.cluster(&mut gpo).unwrap();
        let plan = ctl.current_plan.as_ref().unwrap();
        let (col, &eid) = plan
            .edge_ids
            .iter()
            .enumerate()
            .find(|(c, _)| plan.assignment.open[*c])
            .unwrap();
        let load = plan
            .assignment
            .devices_of(col)
            .len() as f64;
        gpo.set_edge_capacity(eid, load - 0.5);
        assert!(ctl.on_environment_change(&mut gpo).unwrap());
    }

    #[test]
    fn assignment_by_device_maps_dense_ids() {
        let (mut gpo, mut ctl) = setup(6, 2);
        let plan = ctl.cluster(&mut gpo).unwrap().clone();
        let dense = plan.assignment_by_device(6);
        assert_eq!(dense.len(), 6);
        for dev in 0..6 {
            assert_eq!(dense[dev], plan.aggregator_of(dev));
            assert!(dense[dev].is_some());
        }
        // Truncated view drops out-of-range devices without panicking.
        assert_eq!(plan.assignment_by_device(3).len(), 3);
    }

    #[test]
    fn errors_without_infrastructure() {
        let mut gpo = Gpo::new();
        let mut ctl = LearningController::new(LearningCtlConfig::default());
        assert!(ctl.cluster(&mut gpo).is_err());
    }
}
