//! Learning controller: the HFL-specific orchestrator component that owns
//! the clustering mechanism (§III).
//!
//! Responsibilities implemented here:
//! * pull node inventory + inference workload info from the [`Gpo`];
//! * build the HFLOP instance and solve it (the clustering mechanism);
//! * translate the solution into a deployment plan (aggregator
//!   placements, client associations, inference agents per node);
//! * re-cluster on environmental events: edge failure or capacity change
//!   invalidates the current plan (§VI "dealing with environment
//!   dynamics").

use std::collections::{BTreeMap, BTreeSet};

use super::gpo::{Deployment, Gpo, NodeKind};
use crate::core::DenseMatrix;
use crate::hflop::Instance;
use crate::solver::{self, Assignment, DirtySet, SolveCache, SolveOptions};
use crate::topology::haversine_km;

/// How [`LearningController::cluster`] reacts to a trigger
/// (DESIGN.md §10 "Re-orchestration fast path").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResolveStrategy {
    /// Cold solve on every trigger — the default; every golden-matrix
    /// and oracle path runs this verbatim legacy behavior.
    Full,
    /// Warm-start repair seeded from the installed plan
    /// ([`solver::resolve`]), with the content-addressed [`SolveCache`]
    /// and the GPO epoch short-circuit in front. Falls back to a cold
    /// solve only when the repair goes infeasible.
    WarmStart,
    /// `WarmStart` while the dirty fraction stays at or below
    /// [`LearningCtlConfig::warm_dirty_max_frac`], cold beyond it (a
    /// mostly-changed instance gains nothing from repair).
    Auto,
}

impl ResolveStrategy {
    pub fn name(self) -> &'static str {
        match self {
            ResolveStrategy::Full => "full",
            ResolveStrategy::WarmStart => "warm",
            ResolveStrategy::Auto => "auto",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<ResolveStrategy> {
        match s {
            "full" => Ok(ResolveStrategy::Full),
            "warm" => Ok(ResolveStrategy::WarmStart),
            "auto" => Ok(ResolveStrategy::Auto),
            other => anyhow::bail!("unknown resolve strategy '{other}' (full|warm|auto)"),
        }
    }
}

/// Controller configuration.
#[derive(Debug, Clone)]
pub struct LearningCtlConfig {
    /// Local rounds per global round (HFLOP's `l`).
    pub l: f64,
    /// Minimum participating devices (HFLOP's T).
    pub t_min: usize,
    /// Device→edge cost: km beyond which distance is metered.
    pub free_radius_km: f64,
    /// Edge↔cloud cost per exchange.
    pub cloud_cost: f64,
    pub solve: SolveOptions,
    /// Re-solve strategy; `Full` keeps every legacy path intact.
    pub strategy: ResolveStrategy,
    /// `Auto` falls back to a cold solve when the dirty fraction of the
    /// rebuilt instance exceeds this.
    pub warm_dirty_max_frac: f64,
    /// Entry bound for the content-addressed solve cache (warm paths
    /// only; `Full` never consults it).
    pub cache_entries: usize,
}

impl Default for LearningCtlConfig {
    fn default() -> Self {
        LearningCtlConfig {
            l: 2.0,
            t_min: 0,
            free_radius_km: 3.0,
            cloud_cost: 25.0,
            solve: SolveOptions::auto(),
            strategy: ResolveStrategy::Full,
            warm_dirty_max_frac: 0.35,
            cache_entries: 32,
        }
    }
}

/// The realized HFL configuration.
#[derive(Debug, Clone)]
pub struct DeploymentPlan {
    /// Device id (GPO numbering) → edge id, in instance-local indices
    /// mapped back to GPO ids.
    pub assignment: Assignment,
    /// GPO edge ids corresponding to instance columns.
    pub edge_ids: Vec<usize>,
    /// GPO device ids corresponding to instance rows.
    pub device_ids: Vec<usize>,
    pub cost: f64,
    pub proven_optimal: bool,
}

impl DeploymentPlan {
    /// Expand to GPO deployment records.
    pub fn deployments(&self) -> Vec<Deployment> {
        let mut out = Vec::new();
        for (col, &edge_id) in self.edge_ids.iter().enumerate() {
            if self.assignment.open[col] {
                out.push(Deployment::Aggregator { edge_id });
                out.push(Deployment::InferenceAgent { node_id: edge_id, kind: NodeKind::EdgeHost });
            }
        }
        for (row, &dev_id) in self.device_ids.iter().enumerate() {
            let agg = self.assignment.assign[row].map(|c| self.edge_ids[c]);
            out.push(Deployment::FlClient { device_id: dev_id, aggregator_edge: agg });
            out.push(Deployment::InferenceAgent { node_id: dev_id, kind: NodeKind::Device });
        }
        out
    }

    /// GPO edge id serving a GPO device id, if assigned.
    pub fn aggregator_of(&self, device_id: usize) -> Option<usize> {
        let row = self.device_ids.iter().position(|&d| d == device_id)?;
        self.assignment.assign[row].map(|c| self.edge_ids[c])
    }

    /// Dense device-indexed assignment (`out[device_id] = Some(edge_id)`)
    /// for worlds with dense GPO ids — the form the serving plane routes
    /// by. Devices the plan does not cover stay `None` (direct-to-cloud).
    pub fn assignment_by_device(&self, n_devices: usize) -> Vec<Option<usize>> {
        let mut out = vec![None; n_devices];
        for (row, &dev) in self.device_ids.iter().enumerate() {
            if dev < n_devices {
                out[dev] = self.assignment.assign[row].map(|c| self.edge_ids[c]);
            }
        }
        out
    }
}

/// The learning controller.
pub struct LearningController {
    pub config: LearningCtlConfig,
    /// Per-device inference rates λ_i, keyed by GPO device id. Write via
    /// [`set_lambda`](Self::set_lambda) so dirty tracking and the cached
    /// per-edge loads stay coherent.
    pub lambda: BTreeMap<usize, f64>,
    pub current_plan: Option<DeploymentPlan>,
    /// Count of re-clustering runs (observability).
    pub reclusters: usize,
    /// Plans produced by a warm-start repair (observability).
    pub warm_resolves: usize,
    /// Plans served from the content-addressed solve cache.
    pub cache_hits: usize,
    /// Triggers short-circuited because the GPO epoch and the λ view
    /// were both unchanged since the last installed plan.
    pub epoch_hits: usize,
    /// Warm repairs that went infeasible and fell back to a cold solve.
    pub warm_fallbacks: usize,
    /// Communication-budget governor (DESIGN.md §11). Defaults to
    /// unlimited, which meters control traffic but never changes a
    /// decision; the co-sim control plane consults it before installing
    /// a plan, and `ResolveStrategy::Auto` lets it bias the full-vs-
    /// partial choice under budget pressure.
    pub governor: super::budget::BudgetGovernor,
    cache: SolveCache,
    /// Device ids whose λ changed since the last installed plan.
    dirty_lambda: BTreeSet<usize>,
    /// GPO epoch at the last install (None until a plan is installed or
    /// after an external [`seed_plan`](Self::seed_plan)).
    installed_epoch: Option<u64>,
    /// Per-plan-column assigned load, rebuilt lazily — plan installs and
    /// λ changes invalidate. Turns the plan-invalidation verdict from an
    /// O(n·m) rescan per event into O(m) (O(n+m) right after a change).
    plan_loads: Vec<f64>,
    plan_loads_valid: bool,
}

impl LearningController {
    pub fn new(config: LearningCtlConfig) -> LearningController {
        let cache = SolveCache::new(config.cache_entries);
        LearningController {
            config,
            lambda: Default::default(),
            current_plan: None,
            reclusters: 0,
            warm_resolves: 0,
            cache_hits: 0,
            epoch_hits: 0,
            warm_fallbacks: 0,
            governor: super::budget::BudgetGovernor::default(),
            cache,
            dirty_lambda: BTreeSet::new(),
            installed_epoch: None,
            plan_loads: Vec::new(),
            plan_loads_valid: false,
        }
    }

    pub fn set_lambda(&mut self, device_id: usize, rate: f64) {
        let prev = self.lambda.insert(device_id, rate);
        if prev.map(f64::to_bits) != Some(rate.to_bits()) {
            self.dirty_lambda.insert(device_id);
            self.plan_loads_valid = false;
        }
    }

    /// Install an externally computed plan (e.g. a scenario's HFLOP
    /// solution) as the incumbent. Use this instead of writing
    /// `current_plan` directly so the cached per-edge loads invalidate
    /// and warm-start state resets.
    pub fn seed_plan(&mut self, plan: DeploymentPlan) {
        self.current_plan = Some(plan);
        self.plan_loads_valid = false;
        // Unknown provenance relative to the GPO: no epoch short-circuit
        // until this controller installs a plan itself.
        self.installed_epoch = None;
    }

    /// Build the HFLOP instance from current GPO state.
    pub fn build_instance(&self, gpo: &Gpo) -> anyhow::Result<(Instance, Vec<usize>, Vec<usize>)> {
        let devices = gpo.ready_devices();
        let edges = gpo.ready_edges();
        anyhow::ensure!(!devices.is_empty(), "no ready devices");
        anyhow::ensure!(!edges.is_empty(), "no ready edge hosts");

        let device_ids: Vec<usize> = devices.iter().map(|n| n.id).collect();
        let edge_ids: Vec<usize> = edges.iter().map(|n| n.id).collect();

        let c_d = DenseMatrix::from_fn(devices.len(), edges.len(), |i, j| {
            let km = haversine_km(devices[i].location, edges[j].location);
            if km <= self.config.free_radius_km {
                0.0
            } else {
                km
            }
        });

        let t_min = if self.config.t_min == 0 { devices.len() } else { self.config.t_min };
        let inst = Instance {
            c_d,
            c_e: vec![self.config.cloud_cost; edges.len()],
            lambda: device_ids
                .iter()
                .map(|id| self.lambda.get(id).copied().unwrap_or(1.0))
                .collect(),
            r: edges.iter().map(|e| e.capacity).collect(),
            l: self.config.l,
            t_min: t_min.min(devices.len()),
            meta: Default::default(),
        };
        Ok((inst, device_ids, edge_ids))
    }

    /// [`build_instance`](Self::build_instance) plus the instance-local
    /// dirty set: rows/columns whose λ, capacity, or liveness changed
    /// since the last installed plan, mapped from GPO ids into instance
    /// indices. GPO-dirty nodes that are not in the instance (failed
    /// edges, deregistered devices) are represented indirectly — they
    /// change the column/row sets, which the warm path's plan projection
    /// marks dirty on its own.
    pub fn build_instance_dirty(
        &self,
        gpo: &Gpo,
    ) -> anyhow::Result<(Instance, Vec<usize>, Vec<usize>, DirtySet)> {
        let (inst, device_ids, edge_ids) = self.build_instance(gpo)?;
        let changed_devices: BTreeSet<usize> =
            self.dirty_lambda.iter().chain(gpo.dirty_devices()).copied().collect();
        let rows: Vec<usize> = changed_devices
            .iter()
            .filter_map(|id| device_ids.binary_search(id).ok())
            .collect();
        let cols: Vec<usize> =
            gpo.dirty_edges().iter().filter_map(|id| edge_ids.binary_search(id).ok()).collect();
        Ok((inst, device_ids, edge_ids, DirtySet { rows, cols }))
    }

    /// Run the clustering mechanism and install the plan into the GPO.
    /// Dispatch on [`LearningCtlConfig::strategy`]: `Full` is the
    /// verbatim legacy cold path; the warm strategies try, in order, the
    /// GPO epoch short-circuit, the content-addressed solve cache, and a
    /// warm-start repair of the installed plan before paying for a cold
    /// solve.
    pub fn cluster(&mut self, gpo: &mut Gpo) -> anyhow::Result<&DeploymentPlan> {
        match self.config.strategy {
            ResolveStrategy::Full => self.cluster_full(gpo),
            ResolveStrategy::WarmStart | ResolveStrategy::Auto => self.cluster_warm(gpo),
        }
    }

    fn cluster_full(&mut self, gpo: &mut Gpo) -> anyhow::Result<&DeploymentPlan> {
        let (inst, device_ids, edge_ids) = self.build_instance(gpo)?;
        let sol = cold_solve(&inst, &self.config.solve)?;
        self.install(gpo, sol, device_ids, edge_ids)
    }

    fn cluster_warm(&mut self, gpo: &mut Gpo) -> anyhow::Result<&DeploymentPlan> {
        // O(1) short-circuit: nothing changed since the last install, so
        // the installed plan is still THE answer — skip even the
        // instance build.
        if self.current_plan.is_some()
            && self.installed_epoch == Some(gpo.epoch())
            && self.dirty_lambda.is_empty()
        {
            self.epoch_hits += 1;
            return Ok(self.current_plan.as_ref().unwrap());
        }
        let (inst, device_ids, edge_ids, mut dirty) = self.build_instance_dirty(gpo)?;

        // Content-addressed memoization: a byte-identical instance
        // (churn that reverted, or λ-only wobble that cancelled out)
        // returns the previously computed plan outright.
        let key = SolveCache::cacheable(&self.config.solve)
            .then(|| SolveCache::key(&inst, &self.config.solve));
        if let Some(k) = key {
            if let Some(sol) = self.cache.get(k) {
                self.cache_hits += 1;
                return self.install(gpo, sol, device_ids, edge_ids);
            }
        }

        let (n, m) = (inst.n(), inst.m());
        let warm_seed = self
            .current_plan
            .as_ref()
            .map(|plan| project_plan(plan, &device_ids, &edge_ids, &mut dirty));
        let try_warm = warm_seed.is_some()
            && (self.config.strategy == ResolveStrategy::WarmStart
                || dirty.fraction(n, m) <= self.config.warm_dirty_max_frac
                // Budget pressure (DESIGN.md §11): when a worst-case
                // full redistribution no longer fits the remaining
                // budget but the DirtySet-priced repair does, Auto
                // prefers the partial path. Inert when unlimited.
                || self.governor.budget_prefers_partial(n, m, &dirty));
        let (sol, was_cold) = match warm_seed {
            Some(prev) if try_warm => {
                match solver::resolve_assignment(&inst, &prev, &dirty, &self.config.solve) {
                    Ok(sol) => {
                        self.warm_resolves += 1;
                        (sol, false)
                    }
                    Err(_) => {
                        self.warm_fallbacks += 1;
                        (cold_solve(&inst, &self.config.solve)?, true)
                    }
                }
            }
            _ => (cold_solve(&inst, &self.config.solve)?, true),
        };
        // Only cold results enter the cache: a warm repair depends on
        // the incumbent, which is not part of the content key.
        if was_cold {
            if let Some(k) = key {
                self.cache.put(k, sol.clone());
            }
        }
        self.install(gpo, sol, device_ids, edge_ids)
    }

    fn install(
        &mut self,
        gpo: &mut Gpo,
        sol: solver::Solution,
        device_ids: Vec<usize>,
        edge_ids: Vec<usize>,
    ) -> anyhow::Result<&DeploymentPlan> {
        let plan = DeploymentPlan {
            assignment: sol.assignment,
            edge_ids,
            device_ids,
            cost: sol.cost,
            proven_optimal: sol.proven_optimal,
        };
        gpo.apply_deployments(plan.deployments());
        self.current_plan = Some(plan);
        self.reclusters += 1;
        // The installed plan is the new baseline: dirt accumulated so
        // far is accounted for, and the cached loads are stale.
        gpo.clear_dirty();
        self.dirty_lambda.clear();
        self.installed_epoch = Some(gpo.epoch());
        self.plan_loads_valid = false;
        Ok(self.current_plan.as_ref().unwrap())
    }

    /// Rebuild the cached per-column loads of the installed plan. Rows
    /// are accumulated in ascending order — the same per-column addition
    /// order as the legacy per-event rescan, so the floating-point sums
    /// (and therefore the invalidation verdicts) are bit-identical to
    /// it (pinned by `tests/resolve_warm.rs`).
    fn rebuild_plan_loads(&mut self) {
        let mut loads = std::mem::take(&mut self.plan_loads);
        loads.clear();
        if let Some(plan) = &self.current_plan {
            loads.resize(plan.edge_ids.len(), 0.0);
            for (row, &dev) in plan.device_ids.iter().enumerate() {
                if let Some(col) = plan.assignment.assign[row] {
                    loads[col] += self.lambda.get(&dev).copied().unwrap_or(1.0);
                }
            }
        }
        self.plan_loads = loads;
        self.plan_loads_valid = true;
    }

    /// React to an environmental event: if the current plan references a
    /// failed edge or stale capacity, re-cluster. Returns true if a new
    /// plan was produced.
    pub fn on_environment_change(&mut self, gpo: &mut Gpo) -> anyhow::Result<bool> {
        let plan_invalid = if self.current_plan.is_none() {
            true
        } else {
            if !self.plan_loads_valid {
                self.rebuild_plan_loads();
            }
            let plan = self.current_plan.as_ref().expect("checked above");
            let loads = &self.plan_loads;
            // Any open aggregator on a non-ready or capacity-reduced edge?
            plan.edge_ids.iter().enumerate().any(|(col, &eid)| {
                plan.assignment.open[col]
                    && match gpo.edge(eid) {
                        None => true,
                        Some(n) => {
                            n.state != super::gpo::NodeState::Ready
                                || loads[col] > n.capacity + 1e-9
                        }
                    }
            })
        };
        if plan_invalid {
            self.cluster(gpo)?;
            Ok(true)
        } else {
            Ok(false)
        }
    }
}

fn cold_solve(inst: &Instance, opts: &SolveOptions) -> anyhow::Result<solver::Solution> {
    solver::solve(inst, opts).map_err(|e| anyhow::anyhow!("clustering failed: {e}"))
}

/// Project the installed plan onto a freshly built instance: rows and
/// columns are matched by GPO id. Assignments whose edge vanished are
/// dropped (their rows join the dirty set); columns the plan has never
/// seen arrive closed and dirty; devices the plan has never seen arrive
/// unassigned and dirty.
fn project_plan(
    plan: &DeploymentPlan,
    device_ids: &[usize],
    edge_ids: &[usize],
    dirty: &mut DirtySet,
) -> Assignment {
    let prev_row: BTreeMap<usize, usize> =
        plan.device_ids.iter().enumerate().map(|(r, &id)| (id, r)).collect();
    let prev_col: BTreeMap<usize, usize> =
        plan.edge_ids.iter().enumerate().map(|(c, &id)| (id, c)).collect();

    let mut extra_rows: BTreeSet<usize> = dirty.rows.iter().copied().collect();
    let mut extra_cols: BTreeSet<usize> = dirty.cols.iter().copied().collect();

    let mut open = vec![false; edge_ids.len()];
    for (c, eid) in edge_ids.iter().enumerate() {
        match prev_col.get(eid) {
            Some(&pc) => open[c] = plan.assignment.open[pc],
            None => {
                extra_cols.insert(c);
            }
        }
    }
    let mut assign = vec![None; device_ids.len()];
    for (r, did) in device_ids.iter().enumerate() {
        let carried = prev_row
            .get(did)
            .and_then(|&pr| plan.assignment.assign[pr])
            .map(|pc| plan.edge_ids[pc])
            .and_then(|eid| edge_ids.binary_search(&eid).ok())
            .filter(|&c| open[c]);
        match carried {
            Some(c) => assign[r] = Some(c),
            None => {
                extra_rows.insert(r);
            }
        }
    }
    dirty.rows = extra_rows.into_iter().collect();
    dirty.cols = extra_cols.into_iter().collect();
    Assignment { assign, open }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::GeoPoint;

    fn setup(n_dev: usize, n_edge: usize) -> (Gpo, LearningController) {
        let mut gpo = Gpo::new();
        for i in 0..n_dev {
            gpo.register_device(
                i,
                GeoPoint { lat: 34.0 + 0.01 * (i % 5) as f64, lon: -118.4 + 0.02 * (i / 5) as f64 },
            );
        }
        for j in 0..n_edge {
            gpo.register_edge(
                100 + j,
                GeoPoint { lat: 34.0 + 0.02 * j as f64, lon: -118.4 + 0.03 * j as f64 },
                8.0,
            );
        }
        let mut ctl = LearningController::new(LearningCtlConfig::default());
        for i in 0..n_dev {
            ctl.set_lambda(i, 1.0);
        }
        (gpo, ctl)
    }

    #[test]
    fn clustering_produces_feasible_plan() {
        let (mut gpo, mut ctl) = setup(12, 3);
        let plan = ctl.cluster(&mut gpo).unwrap().clone();
        let (inst, _, _) = ctl.build_instance(&gpo).unwrap();
        plan.assignment.check_feasible(&inst).unwrap();
        assert!(!gpo.deployments().is_empty());
    }

    #[test]
    fn plan_maps_gpo_ids() {
        let (mut gpo, mut ctl) = setup(6, 2);
        let plan = ctl.cluster(&mut gpo).unwrap();
        for dev in 0..6 {
            let agg = plan.aggregator_of(dev);
            assert!(agg.map(|e| e >= 100).unwrap_or(false), "device {dev} -> {agg:?}");
        }
    }

    #[test]
    fn edge_failure_triggers_recluster() {
        let (mut gpo, mut ctl) = setup(10, 3);
        ctl.cluster(&mut gpo).unwrap();
        assert_eq!(ctl.reclusters, 1);
        // Fail an edge actually used by the plan.
        let used = ctl
            .current_plan
            .as_ref()
            .unwrap()
            .edge_ids
            .iter()
            .enumerate()
            .find(|(c, _)| ctl.current_plan.as_ref().unwrap().assignment.open[*c])
            .map(|(_, &e)| e)
            .unwrap();
        gpo.fail_edge(used);
        let changed = ctl.on_environment_change(&mut gpo).unwrap();
        assert!(changed);
        assert_eq!(ctl.reclusters, 2);
        // New plan uses only ready edges.
        let plan = ctl.current_plan.as_ref().unwrap();
        assert!(!plan.edge_ids.contains(&used));
    }

    #[test]
    fn no_recluster_when_plan_still_valid() {
        let (mut gpo, mut ctl) = setup(10, 3);
        ctl.cluster(&mut gpo).unwrap();
        let changed = ctl.on_environment_change(&mut gpo).unwrap();
        assert!(!changed);
        assert_eq!(ctl.reclusters, 1);
    }

    #[test]
    fn capacity_drop_below_load_triggers_recluster() {
        let (mut gpo, mut ctl) = setup(10, 2);
        ctl.cluster(&mut gpo).unwrap();
        let plan = ctl.current_plan.as_ref().unwrap();
        let (col, &eid) = plan
            .edge_ids
            .iter()
            .enumerate()
            .find(|(c, _)| plan.assignment.open[*c])
            .unwrap();
        let load = plan
            .assignment
            .devices_of(col)
            .len() as f64;
        gpo.set_edge_capacity(eid, load - 0.5);
        assert!(ctl.on_environment_change(&mut gpo).unwrap());
    }

    #[test]
    fn assignment_by_device_maps_dense_ids() {
        let (mut gpo, mut ctl) = setup(6, 2);
        let plan = ctl.cluster(&mut gpo).unwrap().clone();
        let dense = plan.assignment_by_device(6);
        assert_eq!(dense.len(), 6);
        for dev in 0..6 {
            assert_eq!(dense[dev], plan.aggregator_of(dev));
            assert!(dense[dev].is_some());
        }
        // Truncated view drops out-of-range devices without panicking.
        assert_eq!(plan.assignment_by_device(3).len(), 3);
    }

    #[test]
    fn errors_without_infrastructure() {
        let mut gpo = Gpo::new();
        let mut ctl = LearningController::new(LearningCtlConfig::default());
        assert!(ctl.cluster(&mut gpo).is_err());
    }

    fn setup_with(n_dev: usize, n_edge: usize, strategy: ResolveStrategy) -> (Gpo, LearningController) {
        let (gpo, mut ctl) = setup(n_dev, n_edge);
        ctl.config.strategy = strategy;
        (gpo, ctl)
    }

    #[test]
    fn warm_recluster_after_fault_is_feasible() {
        let (mut gpo, mut ctl) = setup_with(10, 3, ResolveStrategy::WarmStart);
        ctl.cluster(&mut gpo).unwrap();
        let used = ctl
            .current_plan
            .as_ref()
            .unwrap()
            .edge_ids
            .iter()
            .enumerate()
            .find(|(c, _)| ctl.current_plan.as_ref().unwrap().assignment.open[*c])
            .map(|(_, &e)| e)
            .unwrap();
        gpo.fail_edge(used);
        assert!(ctl.on_environment_change(&mut gpo).unwrap());
        assert_eq!(ctl.reclusters, 2);
        // Exactly one warm attempt happened (repair or its cold fallback).
        assert_eq!(ctl.warm_resolves + ctl.warm_fallbacks, 1);
        let plan = ctl.current_plan.as_ref().unwrap().clone();
        assert!(!plan.edge_ids.contains(&used));
        let (inst, _, _) = ctl.build_instance(&gpo).unwrap();
        plan.assignment.check_feasible(&inst).unwrap();
    }

    #[test]
    fn unchanged_epoch_short_circuits_warm_cluster() {
        let (mut gpo, mut ctl) = setup_with(10, 3, ResolveStrategy::WarmStart);
        let cost = ctl.cluster(&mut gpo).unwrap().cost;
        ctl.cluster(&mut gpo).unwrap();
        assert_eq!(ctl.epoch_hits, 1);
        assert_eq!(ctl.reclusters, 1, "short-circuit must not install a new plan");
        assert_eq!(ctl.current_plan.as_ref().unwrap().cost.to_bits(), cost.to_bits());
        // Any effective change breaks the short-circuit.
        ctl.set_lambda(0, 2.0);
        ctl.cluster(&mut gpo).unwrap();
        assert_eq!(ctl.epoch_hits, 1);
        assert_eq!(ctl.reclusters, 2);
    }

    #[test]
    fn cache_returns_identical_plan_when_environment_reverts() {
        let (mut gpo, mut ctl) = setup_with(10, 3, ResolveStrategy::WarmStart);
        let plan1 = ctl.cluster(&mut gpo).unwrap().clone();
        let used = plan1
            .edge_ids
            .iter()
            .enumerate()
            .find(|(c, _)| plan1.assignment.open[*c])
            .map(|(_, &e)| e)
            .unwrap();
        gpo.fail_edge(used);
        assert!(ctl.on_environment_change(&mut gpo).unwrap());
        gpo.recover_edge(used);
        // The rebuilt instance is byte-identical to the first one, so
        // the content-addressed cache returns the original plan — and
        // the hit is bit-identical to that recompute.
        ctl.cluster(&mut gpo).unwrap();
        assert_eq!(ctl.cache_hits, 1);
        let plan3 = ctl.current_plan.as_ref().unwrap();
        assert_eq!(plan3.assignment, plan1.assignment);
        assert_eq!(plan3.cost.to_bits(), plan1.cost.to_bits());
    }

    #[test]
    fn auto_strategy_pivots_on_dirty_fraction() {
        let (mut gpo, mut ctl) = setup_with(10, 3, ResolveStrategy::Auto);
        ctl.config.warm_dirty_max_frac = 0.0;
        ctl.cluster(&mut gpo).unwrap();
        ctl.set_lambda(0, 2.0);
        ctl.cluster(&mut gpo).unwrap();
        assert_eq!(ctl.warm_resolves, 0, "zero threshold must force the cold path");
        assert_eq!(ctl.reclusters, 2);

        let (mut gpo, mut ctl) = setup_with(10, 3, ResolveStrategy::Auto);
        ctl.config.warm_dirty_max_frac = 1.0;
        ctl.cluster(&mut gpo).unwrap();
        ctl.set_lambda(0, 2.0);
        ctl.cluster(&mut gpo).unwrap();
        assert_eq!(ctl.warm_resolves, 1, "full threshold must allow the warm path");
    }

    #[test]
    fn failed_resolve_keeps_stale_plan_installed() {
        for strategy in [ResolveStrategy::Full, ResolveStrategy::WarmStart] {
            let (mut gpo, mut ctl) = setup_with(6, 2, strategy);
            ctl.cluster(&mut gpo).unwrap();
            let stale = ctl.current_plan.as_ref().unwrap().clone();
            gpo.fail_edge(100);
            gpo.fail_edge(101);
            assert!(ctl.on_environment_change(&mut gpo).is_err(), "{strategy:?}");
            let kept = ctl.current_plan.as_ref().unwrap();
            assert_eq!(kept.assignment, stale.assignment, "{strategy:?}");
            assert_eq!(ctl.reclusters, 1, "{strategy:?}");
        }
    }

    /// The legacy O(n·m) invalidation rescan, kept verbatim as the
    /// oracle for the incremental per-edge-load verdict.
    fn legacy_verdict(ctl: &LearningController, gpo: &Gpo) -> bool {
        match &ctl.current_plan {
            None => true,
            Some(plan) => plan.edge_ids.iter().enumerate().any(|(col, &eid)| {
                plan.assignment.open[col]
                    && match gpo.edge(eid) {
                        None => true,
                        Some(n) => {
                            n.state != crate::orchestrator::gpo::NodeState::Ready || {
                                let load: f64 = plan
                                    .device_ids
                                    .iter()
                                    .enumerate()
                                    .filter(|(row, _)| plan.assignment.assign[*row] == Some(col))
                                    .map(|(row, _)| {
                                        ctl.lambda
                                            .get(&plan.device_ids[row])
                                            .copied()
                                            .unwrap_or(1.0)
                                    })
                                    .sum();
                                load > n.capacity + 1e-9
                            }
                        }
                    }
            }),
        }
    }

    #[test]
    fn invalidation_verdicts_match_legacy_scan() {
        for strategy in [ResolveStrategy::Full, ResolveStrategy::WarmStart] {
            for seed in 0..6usize {
                let (mut gpo, mut ctl) = setup_with(12, 3, strategy);
                ctl.cluster(&mut gpo).unwrap();
                for step in 0..10 {
                    let k = seed + step;
                    match k % 4 {
                        0 => gpo.set_edge_capacity(100 + k % 3, 3.0),
                        1 => ctl.set_lambda(k % 12, 1.0 + (k % 3) as f64),
                        2 => gpo.set_edge_capacity(100 + k % 3, 8.0),
                        _ => {}
                    }
                    let expect = legacy_verdict(&ctl, &gpo);
                    match ctl.on_environment_change(&mut gpo) {
                        Ok(got) => assert_eq!(
                            got, expect,
                            "{strategy:?} seed {seed} step {step}: verdict diverged"
                        ),
                        Err(_) => assert!(expect, "re-solve only runs on an invalid plan"),
                    }
                }
            }
        }
    }
}
