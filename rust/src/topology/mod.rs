//! Physical topology model: devices (FL clients / sensors), candidate edge
//! hosts, the cloud, geographic placement, communication-cost matrices,
//! and the location-based clustering baseline the paper compares against
//! (§V-B2: "we first clustered the clients ... based on their location").

pub mod geo;
pub mod kmeans;

pub use geo::{haversine_km, GeoPoint, LA_BBOX};
pub use kmeans::{kmeans, kmeans_weighted, KMeansResult};

use crate::core::DenseMatrix;
use crate::util::rng::Rng;

/// An FL device (in the use case: a traffic sensor with compute).
#[derive(Debug, Clone)]
pub struct Device {
    pub id: usize,
    pub location: GeoPoint,
    /// Inference request rate λ_i (requests/s) — §IV-A.
    pub lambda: f64,
}

/// A candidate edge host location where an aggregator may be placed.
#[derive(Debug, Clone)]
pub struct EdgeHost {
    pub id: usize,
    pub location: GeoPoint,
    /// Inference request processing capacity r_j (requests/s) — §IV-A.
    pub capacity: f64,
}

/// A topology instance: devices + edge hosts + cost structure.
///
/// Costs follow the paper's model: `c_d[i][j]` is the communication cost
/// between device i and edge host j (per model exchange), `c_e[j]` between
/// edge host j and the global server. The cloud has infinite inference
/// capacity (§IV-A).
#[derive(Debug, Clone)]
pub struct Topology {
    pub devices: Vec<Device>,
    pub edges: Vec<EdgeHost>,
    /// Device-to-edge communication cost matrix, n x m (row-major).
    pub c_d: DenseMatrix,
    /// Edge-to-cloud communication cost vector, m.
    pub c_e: Vec<f64>,
}

impl Topology {
    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    pub fn n_edges(&self) -> usize {
        self.edges.len()
    }

    /// Index of the cheapest edge host for device `i`.
    pub fn cheapest_edge(&self, i: usize) -> usize {
        let row = self.c_d.row(i);
        (0..row.len())
            .min_by(|&a, &b| row[a].total_cmp(&row[b]))
            .expect("topology has no edge hosts")
    }

    /// Sanity-check matrix dimensions and value ranges.
    pub fn validate(&self) -> anyhow::Result<()> {
        let (n, m) = (self.n_devices(), self.n_edges());
        anyhow::ensure!(self.c_d.rows() == n, "c_d rows {} != n {}", self.c_d.rows(), n);
        anyhow::ensure!(self.c_d.cols() == m, "c_d cols {} != m {}", self.c_d.cols(), m);
        for (i, row) in self.c_d.row_iter().enumerate() {
            anyhow::ensure!(
                row.iter().all(|&c| c >= 0.0 && c.is_finite()),
                "c_d[{i}] negative/NaN"
            );
        }
        anyhow::ensure!(self.c_e.len() == m, "c_e len {} != m {}", self.c_e.len(), m);
        anyhow::ensure!(self.c_e.iter().all(|&c| c >= 0.0 && c.is_finite()), "c_e negative/NaN");
        anyhow::ensure!(self.devices.iter().all(|d| d.lambda >= 0.0), "negative lambda");
        anyhow::ensure!(self.edges.iter().all(|e| e.capacity >= 0.0), "negative capacity");
        Ok(())
    }
}

/// Builder for the geographic topology used in the use-case experiments
/// (Fig. 5–8): devices at sensor locations, edge hosts at cluster
/// centroids, costs proportional to distance.
pub struct GeoTopologyBuilder {
    pub device_locations: Vec<GeoPoint>,
    pub n_edges: usize,
    pub lambda_range: (f64, f64),
    pub capacity_range: (f64, f64),
    pub seed: u64,
}

impl GeoTopologyBuilder {
    pub fn new(device_locations: Vec<GeoPoint>, n_edges: usize, seed: u64) -> Self {
        GeoTopologyBuilder {
            device_locations,
            n_edges,
            // Paper §V-C1: each FL device is assigned a rate λ_i; workloads
            // and capacities are drawn uniformly at random (§V-D).
            lambda_range: (0.5, 2.0),
            capacity_range: (5.0, 15.0),
            seed,
        }
    }

    pub fn lambda_range(mut self, lo: f64, hi: f64) -> Self {
        self.lambda_range = (lo, hi);
        self
    }

    pub fn capacity_range(mut self, lo: f64, hi: f64) -> Self {
        self.capacity_range = (lo, hi);
        self
    }

    /// Build: k-means the device locations into `n_edges` clusters, place
    /// one edge host at each centroid, and derive distance-proportional
    /// costs (unit cost per km, zero below `FREE_RADIUS_KM`).
    pub fn build(self) -> Topology {
        let mut rng = Rng::new(self.seed);
        let km = kmeans(&self.device_locations, self.n_edges, 50, &mut rng);

        let devices: Vec<Device> = self
            .device_locations
            .iter()
            .enumerate()
            .map(|(id, &location)| Device {
                id,
                location,
                lambda: rng.uniform(self.lambda_range.0, self.lambda_range.1),
            })
            .collect();

        let edges: Vec<EdgeHost> = km
            .centroids
            .iter()
            .enumerate()
            .map(|(id, &location)| EdgeHost {
                id,
                location,
                capacity: rng.uniform(self.capacity_range.0, self.capacity_range.1),
            })
            .collect();

        // Cost: proportional to distance; an edge host within a small
        // radius is effectively "same LAN" => 0 (paper: "an aggregator
        // placed inside a device's local area network").
        const FREE_RADIUS_KM: f64 = 3.0;
        let c_d = DenseMatrix::from_fn(devices.len(), edges.len(), |i, j| {
            let dist = haversine_km(devices[i].location, edges[j].location);
            if dist <= FREE_RADIUS_KM {
                0.0
            } else {
                dist
            }
        });
        // Edge-to-cloud links are metered uniformly; scaled so one global
        // exchange costs about one moderately-remote local exchange.
        let c_e = edges.iter().map(|_| 25.0).collect();

        Topology { devices, edges, c_d, c_e }
    }
}

/// The paper's §V-D synthetic cost topology: for each device exactly one
/// edge host is reachable at zero cost (same LAN), every other at unit
/// cost; all edge-cloud links at unit cost. Workloads/capacities uniform.
pub fn unit_cost_topology(
    n_devices: usize,
    n_edges: usize,
    lambda_range: (f64, f64),
    capacity_range: (f64, f64),
    seed: u64,
) -> Topology {
    let mut rng = Rng::new(seed);
    let devices: Vec<Device> = (0..n_devices)
        .map(|id| Device {
            id,
            location: GeoPoint { lat: 0.0, lon: 0.0 },
            lambda: rng.uniform(lambda_range.0, lambda_range.1),
        })
        .collect();
    let edges: Vec<EdgeHost> = (0..n_edges)
        .map(|id| EdgeHost {
            id,
            location: GeoPoint { lat: 0.0, lon: 0.0 },
            capacity: rng.uniform(capacity_range.0, capacity_range.1),
        })
        .collect();
    let mut c_d = DenseMatrix::zeros(n_devices, n_edges);
    for i in 0..n_devices {
        let free = rng.below(n_edges);
        for (j, c) in c_d.row_mut(i).iter_mut().enumerate() {
            *c = if j == free { 0.0 } else { 1.0 };
        }
    }
    let c_e = vec![1.0; n_edges];
    Topology { devices, edges, c_d, c_e }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid_locations(n: usize) -> Vec<GeoPoint> {
        (0..n)
            .map(|i| GeoPoint {
                lat: 34.0 + 0.01 * (i % 10) as f64,
                lon: -118.4 + 0.01 * (i / 10) as f64,
            })
            .collect()
    }

    #[test]
    fn geo_builder_shapes() {
        let t = GeoTopologyBuilder::new(grid_locations(40), 4, 1).build();
        assert_eq!(t.n_devices(), 40);
        assert_eq!(t.n_edges(), 4);
        t.validate().unwrap();
    }

    #[test]
    fn geo_builder_deterministic() {
        let a = GeoTopologyBuilder::new(grid_locations(30), 3, 9).build();
        let b = GeoTopologyBuilder::new(grid_locations(30), 3, 9).build();
        assert_eq!(a.c_d, b.c_d);
        for (x, y) in a.devices.iter().zip(&b.devices) {
            assert_eq!(x.lambda, y.lambda);
        }
    }

    #[test]
    fn geo_builder_lambda_in_range() {
        let t = GeoTopologyBuilder::new(grid_locations(50), 5, 2)
            .lambda_range(1.0, 3.0)
            .capacity_range(10.0, 20.0)
            .build();
        assert!(t.devices.iter().all(|d| (1.0..3.0).contains(&d.lambda)));
        assert!(t.edges.iter().all(|e| (10.0..20.0).contains(&e.capacity)));
    }

    #[test]
    fn unit_cost_has_one_free_edge_per_device() {
        let t = unit_cost_topology(100, 8, (0.5, 2.0), (5.0, 15.0), 3);
        t.validate().unwrap();
        for row in &t.c_d {
            let zeros = row.iter().filter(|&&c| c == 0.0).count();
            let ones = row.iter().filter(|&&c| c == 1.0).count();
            assert_eq!(zeros, 1);
            assert_eq!(ones, 7);
        }
        assert!(t.c_e.iter().all(|&c| c == 1.0));
    }

    #[test]
    fn cheapest_edge_finds_zero_cost() {
        let t = unit_cost_topology(20, 5, (0.5, 2.0), (5.0, 15.0), 4);
        for i in 0..20 {
            let j = t.cheapest_edge(i);
            assert_eq!(t.c_d[i][j], 0.0);
        }
    }

    #[test]
    fn validate_catches_bad_shapes() {
        let mut t = unit_cost_topology(5, 2, (0.5, 1.0), (1.0, 2.0), 5);
        t.c_e.pop();
        assert!(t.validate().is_err());
    }

    #[test]
    fn validate_catches_negative_lambda() {
        let mut t = unit_cost_topology(5, 2, (0.5, 1.0), (1.0, 2.0), 6);
        t.devices[0].lambda = -1.0;
        assert!(t.validate().is_err());
    }
}
