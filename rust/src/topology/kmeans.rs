//! Lloyd's k-means over geographic points.
//!
//! Used for (a) the paper's location-based clustering baseline (§V-B2,
//! Fig. 5: sensors clustered by location, one edge server per cluster) and
//! (b) edge-host placement at cluster centroids in the geo topology
//! builder. k-means++ seeding for stable quality.

use super::geo::{haversine_km, GeoPoint};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct KMeansResult {
    pub centroids: Vec<GeoPoint>,
    /// assignment[i] = cluster index of point i.
    pub assignment: Vec<usize>,
    /// Sum of squared distances (km^2) to assigned centroids.
    pub inertia: f64,
    pub iterations: usize,
}

/// Run k-means++ / Lloyd. `k` is clamped to the number of points.
pub fn kmeans(points: &[GeoPoint], k: usize, max_iter: usize, rng: &mut Rng) -> KMeansResult {
    kmeans_weighted(points, None, k, max_iter, rng)
}

/// Weighted k-means++ / Lloyd: `weights[i]` scales point `i`'s pull in
/// both the seeding distribution and the centroid update, so demand-heavy
/// devices attract region centers (the sharded solver weights by λ).
/// `weights: None` is the unit-weight case and is bit-identical to
/// [`kmeans`] — multiplying by exactly 1.0 and summing exact integer
/// counts changes no float.
pub fn kmeans_weighted(
    points: &[GeoPoint],
    weights: Option<&[f64]>,
    k: usize,
    max_iter: usize,
    rng: &mut Rng,
) -> KMeansResult {
    assert!(!points.is_empty(), "kmeans over empty points");
    if let Some(ws) = weights {
        assert_eq!(ws.len(), points.len(), "weights len mismatch");
        assert!(ws.iter().all(|&w| w.is_finite() && w >= 0.0), "bad weight");
    }
    let w = |i: usize| weights.map_or(1.0, |ws| ws[i]);
    let k = k.clamp(1, points.len());

    // --- k-means++ seeding (weight-scaled d^2 sampling) --------------------
    let mut centroids: Vec<GeoPoint> = Vec::with_capacity(k);
    centroids.push(points[rng.below(points.len())]);
    let mut d2: Vec<f64> = points
        .iter()
        .map(|&p| haversine_km(p, centroids[0]).powi(2))
        .collect();
    while centroids.len() < k {
        let total: f64 = d2.iter().enumerate().map(|(i, &d)| w(i) * d).sum();
        let next = if total <= 1e-12 {
            // All points coincide with existing centroids; pick any.
            points[rng.below(points.len())]
        } else {
            let mut target = rng.f64() * total;
            let mut idx = 0;
            for (i, &d) in d2.iter().enumerate() {
                target -= w(i) * d;
                if target <= 0.0 {
                    idx = i;
                    break;
                }
                idx = i;
            }
            points[idx]
        };
        centroids.push(next);
        for (i, &p) in points.iter().enumerate() {
            d2[i] = d2[i].min(haversine_km(p, next).powi(2));
        }
    }

    // --- Lloyd iterations --------------------------------------------------
    let mut assignment = vec![0usize; points.len()];
    let mut iterations = 0;
    for it in 0..max_iter {
        iterations = it + 1;
        // Assign.
        let mut changed = false;
        for (i, &p) in points.iter().enumerate() {
            let best = (0..centroids.len())
                .min_by(|&a, &b| {
                    haversine_km(p, centroids[a]).total_cmp(&haversine_km(p, centroids[b]))
                })
                .unwrap();
            if assignment[i] != best {
                assignment[i] = best;
                changed = true;
            }
        }
        if !changed && it > 0 {
            break;
        }
        // Update: weighted mean in lat/lon space (fine at city scale).
        let mut sums = vec![(0.0f64, 0.0f64, 0.0f64); centroids.len()];
        for (i, &p) in points.iter().enumerate() {
            let wi = w(i);
            let s = &mut sums[assignment[i]];
            s.0 += wi * p.lat;
            s.1 += wi * p.lon;
            s.2 += wi;
        }
        for (c, s) in centroids.iter_mut().zip(&sums) {
            if s.2 > 0.0 {
                *c = GeoPoint { lat: s.0 / s.2, lon: s.1 / s.2 };
            } else {
                // Re-seed an empty (or zero-weight) cluster at the
                // farthest point.
                let far = points
                    .iter()
                    .max_by(|&&a, &&b| {
                        haversine_km(a, *c).total_cmp(&haversine_km(b, *c))
                    })
                    .unwrap();
                *c = *far;
            }
        }
    }

    let inertia = points
        .iter()
        .zip(&assignment)
        .map(|(&p, &a)| haversine_km(p, centroids[a]).powi(2))
        .sum();

    KMeansResult { centroids, assignment, inertia, iterations }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two tight blobs 20km apart must be split into their natural clusters.
    fn blobs(rng: &mut Rng) -> (Vec<GeoPoint>, usize) {
        let mut pts = Vec::new();
        for _ in 0..30 {
            pts.push(GeoPoint {
                lat: 34.00 + rng.normal() * 0.002,
                lon: -118.40 + rng.normal() * 0.002,
            });
        }
        for _ in 0..30 {
            pts.push(GeoPoint {
                lat: 34.18 + rng.normal() * 0.002,
                lon: -118.22 + rng.normal() * 0.002,
            });
        }
        (pts, 30)
    }

    #[test]
    fn separates_two_blobs() {
        let mut rng = Rng::new(1);
        let (pts, split) = blobs(&mut rng);
        let r = kmeans(&pts, 2, 100, &mut rng);
        // All of blob A in one cluster, all of blob B in the other.
        let a0 = r.assignment[0];
        assert!(r.assignment[..split].iter().all(|&a| a == a0));
        assert!(r.assignment[split..].iter().all(|&a| a != a0));
    }

    #[test]
    fn inertia_decreases_with_more_clusters() {
        let mut rng = Rng::new(2);
        let pts: Vec<GeoPoint> = (0..100)
            .map(|_| GeoPoint {
                lat: rng.uniform(34.0, 34.2),
                lon: rng.uniform(-118.5, -118.2),
            })
            .collect();
        let i2 = kmeans(&pts, 2, 100, &mut Rng::new(3)).inertia;
        let i8 = kmeans(&pts, 8, 100, &mut Rng::new(3)).inertia;
        assert!(i8 < i2);
    }

    #[test]
    fn k_clamped_to_point_count() {
        let pts = vec![GeoPoint { lat: 34.0, lon: -118.3 }; 3];
        let mut rng = Rng::new(4);
        let r = kmeans(&pts, 10, 50, &mut rng);
        assert_eq!(r.centroids.len(), 3);
        assert!(r.assignment.iter().all(|&a| a < 3));
    }

    #[test]
    fn k_one_centroid_is_mean() {
        let pts = vec![
            GeoPoint { lat: 34.0, lon: -118.4 },
            GeoPoint { lat: 34.2, lon: -118.2 },
        ];
        let mut rng = Rng::new(5);
        let r = kmeans(&pts, 1, 50, &mut rng);
        assert!((r.centroids[0].lat - 34.1).abs() < 1e-9);
        assert!((r.centroids[0].lon + 118.3).abs() < 1e-9);
    }

    #[test]
    fn assignment_is_nearest_centroid() {
        let mut rng = Rng::new(6);
        let pts: Vec<GeoPoint> = (0..60)
            .map(|_| GeoPoint {
                lat: rng.uniform(34.0, 34.2),
                lon: rng.uniform(-118.5, -118.2),
            })
            .collect();
        let r = kmeans(&pts, 4, 100, &mut rng);
        for (i, &p) in pts.iter().enumerate() {
            let d_assigned = haversine_km(p, r.centroids[r.assignment[i]]);
            for &c in &r.centroids {
                assert!(d_assigned <= haversine_km(p, c) + 1e-9);
            }
        }
    }

    #[test]
    fn unit_weights_bit_identical_to_unweighted() {
        let mut rng = Rng::new(21);
        let pts: Vec<GeoPoint> = (0..80)
            .map(|_| GeoPoint {
                lat: rng.uniform(34.0, 34.2),
                lon: rng.uniform(-118.5, -118.2),
            })
            .collect();
        let ones = vec![1.0; pts.len()];
        let a = kmeans(&pts, 5, 100, &mut Rng::new(9));
        let b = kmeans_weighted(&pts, Some(&ones), 5, 100, &mut Rng::new(9));
        assert_eq!(a.assignment, b.assignment);
        assert_eq!(a.iterations, b.iterations);
        for (ca, cb) in a.centroids.iter().zip(&b.centroids) {
            assert_eq!(ca.lat.to_bits(), cb.lat.to_bits());
            assert_eq!(ca.lon.to_bits(), cb.lon.to_bits());
        }
        assert_eq!(a.inertia.to_bits(), b.inertia.to_bits());
    }

    #[test]
    fn heavy_weight_pulls_centroid() {
        // One cluster: the weighted mean must sit on the heavy point side.
        let pts = vec![
            GeoPoint { lat: 34.0, lon: -118.4 },
            GeoPoint { lat: 34.2, lon: -118.2 },
        ];
        let ws = vec![9.0, 1.0];
        let r = kmeans_weighted(&pts, Some(&ws), 1, 50, &mut Rng::new(5));
        assert!((r.centroids[0].lat - 34.02).abs() < 1e-9, "{}", r.centroids[0].lat);
        assert!((r.centroids[0].lon + 118.38).abs() < 1e-9);
    }

    #[test]
    fn identical_points_do_not_crash() {
        let pts = vec![GeoPoint { lat: 34.1, lon: -118.3 }; 20];
        let mut rng = Rng::new(7);
        let r = kmeans(&pts, 4, 50, &mut rng);
        assert!(r.inertia < 1e-9);
    }
}
