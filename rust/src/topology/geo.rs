//! Geographic primitives: points, great-circle distance, bounding boxes.
//!
//! The synthetic METR-LA substitute places sensors inside the Los Angeles
//! County bounding box the real dataset covers (Fig. 4 in the paper).

/// A WGS-84 latitude/longitude point (degrees).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GeoPoint {
    pub lat: f64,
    pub lon: f64,
}

/// Bounding box: (lat_min, lat_max, lon_min, lon_max).
pub type BBox = (f64, f64, f64, f64);

/// The METR-LA sensor region (LA County highways, cf. paper Fig. 4).
pub const LA_BBOX: BBox = (34.0, 34.2, -118.5, -118.2);

/// Great-circle distance between two points in km (haversine formula).
pub fn haversine_km(a: GeoPoint, b: GeoPoint) -> f64 {
    const R_EARTH_KM: f64 = 6371.0;
    let (lat1, lon1) = (a.lat.to_radians(), a.lon.to_radians());
    let (lat2, lon2) = (b.lat.to_radians(), b.lon.to_radians());
    let dlat = lat2 - lat1;
    let dlon = lon2 - lon1;
    let h = (dlat / 2.0).sin().powi(2) + lat1.cos() * lat2.cos() * (dlon / 2.0).sin().powi(2);
    2.0 * R_EARTH_KM * h.sqrt().asin()
}

impl GeoPoint {
    /// Linear interpolation between two points (for corridor layouts).
    pub fn lerp(self, other: GeoPoint, t: f64) -> GeoPoint {
        GeoPoint {
            lat: self.lat + (other.lat - self.lat) * t,
            lon: self.lon + (other.lon - self.lon) * t,
        }
    }

    pub fn in_bbox(self, bbox: BBox) -> bool {
        (bbox.0..=bbox.1).contains(&self.lat) && (bbox.2..=bbox.3).contains(&self.lon)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_distance() {
        let p = GeoPoint { lat: 34.05, lon: -118.25 };
        assert!(haversine_km(p, p) < 1e-9);
    }

    #[test]
    fn known_distance_la_to_sf() {
        // LA (34.05, -118.24) to SF (37.77, -122.42) ≈ 559 km.
        let la = GeoPoint { lat: 34.05, lon: -118.24 };
        let sf = GeoPoint { lat: 37.77, lon: -122.42 };
        let d = haversine_km(la, sf);
        assert!((d - 559.0).abs() < 5.0, "{d}");
    }

    #[test]
    fn symmetry() {
        let a = GeoPoint { lat: 34.0, lon: -118.3 };
        let b = GeoPoint { lat: 34.1, lon: -118.5 };
        assert!((haversine_km(a, b) - haversine_km(b, a)).abs() < 1e-12);
    }

    #[test]
    fn triangle_inequality() {
        let a = GeoPoint { lat: 34.00, lon: -118.40 };
        let b = GeoPoint { lat: 34.10, lon: -118.30 };
        let c = GeoPoint { lat: 34.05, lon: -118.20 };
        assert!(haversine_km(a, c) <= haversine_km(a, b) + haversine_km(b, c) + 1e-9);
    }

    #[test]
    fn lerp_endpoints_and_midpoint() {
        let a = GeoPoint { lat: 34.0, lon: -118.4 };
        let b = GeoPoint { lat: 34.2, lon: -118.2 };
        assert_eq!(a.lerp(b, 0.0), a);
        assert_eq!(a.lerp(b, 1.0), b);
        let m = a.lerp(b, 0.5);
        assert!((m.lat - 34.1).abs() < 1e-12);
        assert!((m.lon + 118.3).abs() < 1e-12);
    }

    #[test]
    fn bbox_containment() {
        assert!(GeoPoint { lat: 34.1, lon: -118.3 }.in_bbox(LA_BBOX));
        assert!(!GeoPoint { lat: 35.0, lon: -118.3 }.in_bbox(LA_BBOX));
        assert!(!GeoPoint { lat: 34.1, lon: -117.0 }.in_bbox(LA_BBOX));
    }
}
