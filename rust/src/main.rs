//! `hflop` — CLI launcher for the HFLOP orchestration framework.
//!
//! Subcommands:
//!   solve       solve one HFLOP instance (synthetic generators or sweep)
//!   train       run continual hierarchical FL on the PJRT runtime
//!   serve       run the real batched-serving hot path (PJRT predict)
//!   experiment  regenerate a paper artifact: fig2|fig6|fig7|fig8|fig9|cl
//!   sweep       run a deterministic parallel scenario-sweep grid
//!   info        print artifact manifest / environment info
//!
//! Flags go last (schema-light parser): `hflop solve --n 100 --m 8 --exact`.

use hflop::cli::Args;
use hflop::config::Setup;
use hflop::data::window::ContinualWindow;
use hflop::experiments::{self, Scenario, ScenarioConfig};
use hflop::fl::{FlConfig, ModelRuntime};
use hflop::hflop::InstanceBuilder;
use hflop::inference::serving::{BatchingServer, InferenceRequest};
use hflop::metrics::export::{ascii_table, ResultsWriter};
use hflop::runtime::{Engine, Manifest, Preload};
use hflop::solver::{self, SolveOptions};
use hflop::util::json::Json;
use hflop::util::rng::Rng;

const USAGE: &str = "\
hflop — inference load-aware orchestration for hierarchical FL

USAGE: hflop <subcommand> [options] [--flags]

  solve       --n <devices> --m <edges> [--seed S] [--exact|--heuristic] [--uncap]
  train       --setup flat|hier|hflop --rounds R [--variant small|paper]
              [--clients N] [--edges M] [--epochs E] [--batches B] [--lr LR]
  serve       --requests N [--variant small|paper]
  experiment  fig2|fig6|fig7|fig8|fig9|cl [--out results/]
  sweep       [--grid interference|fig7|fig8] [--workers W] [--root-seed S]
              [--out results/] [--smoke] [--compare]
  info
";

fn main() {
    hflop::init_logging();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand.as_deref() {
        Some("solve") => run_solve(&args),
        Some("train") => run_train(&args),
        Some("serve") => run_serve(&args),
        Some("experiment") => run_experiment(&args),
        Some("sweep") => run_sweep(&args),
        Some("info") => run_info(),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e:#}");
        1
    });
    std::process::exit(code);
}

fn run_solve(args: &Args) -> anyhow::Result<()> {
    let n = args.usize_or("n", 100)?;
    let m = args.usize_or("m", 8)?;
    let seed = args.u64_or("seed", 42)?;
    let builder = InstanceBuilder::unit_cost(n, m, seed);
    let inst =
        if args.has_flag("uncap") { builder.uncapacitated().build() } else { builder.build() };
    let opts = if args.has_flag("exact") {
        SolveOptions::exact()
    } else if args.has_flag("heuristic") {
        SolveOptions::heuristic()
    } else {
        SolveOptions::auto()
    };
    let sol = solver::solve(&inst, &opts)?;
    println!(
        "instance n={n} m={m} seed={seed}: cost={:.3} open_edges={} assigned={} optimal={} nodes={} wall={:.3}s",
        sol.cost,
        sol.assignment.n_open(),
        sol.assignment.n_assigned(),
        sol.proven_optimal,
        sol.nodes,
        sol.wall_s
    );
    Ok(())
}

fn run_train(args: &Args) -> anyhow::Result<()> {
    let setup = Setup::parse(&args.str_or("setup", "hflop"))?;
    let variant = args.str_or("variant", "small");
    let rounds = args.usize_or("rounds", 20)?;
    let sc = Scenario::build(ScenarioConfig {
        n_clients: args.usize_or("clients", 20)?,
        n_edges: args.usize_or("edges", 4)?,
        weeks: args.usize_or("weeks", 6)?,
        seed: args.u64_or("seed", 42)?,
        ..Default::default()
    })?;
    let manifest = Manifest::load_default()?;
    let engine = Engine::new(&manifest, &variant, Preload::Training)?;
    let init = manifest.load_init_params(engine.variant())?;
    let fl = FlConfig {
        epochs: args.usize_or("epochs", 1)?,
        batches_per_epoch: args.usize_or("batches", 4)?,
        l: args.usize_or("l", 2)?,
        lr: args.f64_or("lr", 1e-3)? as f32,
        rounds,
        eval_every: 1,
    };
    let window = ContinualWindow::paper(sc.dataset.n_steps, args.usize_or("shift", 288)?);
    let run = experiments::fig6::run_setup(&sc, &engine, setup, fl, window, init, 7)?;
    println!(
        "setup={} rounds={} final_mse={:.5} comm={:.3} GB converged_at={:?}",
        setup.name(),
        rounds,
        run.mean_final_mse,
        run.ledger.total_gb(),
        run.rounds_to_converge
    );
    Ok(())
}

fn run_serve(args: &Args) -> anyhow::Result<()> {
    let variant = args.str_or("variant", "paper");
    let n_requests = args.usize_or("requests", 1000)?;
    let manifest = Manifest::load_default()?;
    let engine = Engine::new(&manifest, &variant, Preload::Serving)?;
    let params = manifest.load_init_params(engine.variant())?;
    let seq = engine.variant().seq_len;
    let mut server = BatchingServer::new(&engine, params);
    let mut rng = Rng::new(args.u64_or("seed", 1)?);
    let mut served = 0usize;
    for id in 0..n_requests as u64 {
        let window: Vec<f32> = (0..seq).map(|_| rng.normal() as f32).collect();
        served += server.submit(InferenceRequest { id, window })?.len();
    }
    served += server.flush()?.len();
    let s = &server.stats;
    println!(
        "served {served} requests in {} batches: mean_batch_exec={:.3} ms exec_throughput={:.0} req/s mean_request_latency={:.3} ms",
        s.batches,
        s.batch_exec_ms.mean(),
        s.exec_throughput_rps(),
        s.request_ms.mean()
    );
    Ok(())
}

fn run_sweep(args: &Args) -> anyhow::Result<()> {
    use hflop::experiments::sweep::{run_grid, SweepGrid};
    use hflop::util::{pool, time_it};

    let root = args.u64_or("root-seed", 2026)?;
    let grid = if args.has_flag("smoke") {
        // `--smoke` is its own (reduced) grid; an explicit `--grid`
        // would be silently ignored, so reject the combination.
        anyhow::ensure!(
            !args.options.contains_key("grid"),
            "--smoke selects the smoke grid; drop --grid or drop --smoke"
        );
        SweepGrid::smoke(root)
    } else {
        match args.str_or("grid", "interference").as_str() {
            "interference" => SweepGrid::interference(root),
            "fig7" => SweepGrid::fig7(root),
            "fig8" => SweepGrid::fig8(root),
            other => anyhow::bail!("unknown sweep grid '{other}' (interference|fig7|fig8)"),
        }
    };
    let workers = args.usize_or("workers", pool::default_workers())?;
    println!(
        "sweep '{}': {} cells ({} rows x {} seeds x {} modes x {} envs), {} workers",
        grid.name,
        grid.n_cells(),
        grid.rows.len(),
        grid.n_seeds,
        grid.modes.len(),
        grid.envs.len(),
        workers
    );

    let (matrix, wall_s) = time_it(|| run_grid(&grid, workers));
    let matrix = matrix?;
    let mut timing = vec![
        ("workers", Json::Num(workers as f64)),
        ("parallel_wall_s", Json::Num(wall_s)),
        ("total_cell_wall_s", Json::Num(matrix.total_cell_wall_s())),
    ];
    println!("{workers}-worker run: {wall_s:.2}s wall over {} cells", matrix.cells.len());

    // `--compare` (implied by `--smoke`) re-runs the grid serially: the
    // acceptance check that the pool beats the serial loop while the
    // matrix stays byte-identical.
    if args.has_flag("compare") || args.has_flag("smoke") {
        let (serial, serial_s) = time_it(|| run_grid(&grid, 1));
        let serial = serial?;
        let identical = serial.to_json().to_pretty() == matrix.to_json().to_pretty();
        println!(
            "serial re-run: {serial_s:.2}s wall | speedup {:.2}x | bit-identical: {identical}",
            serial_s / wall_s.max(1e-9)
        );
        anyhow::ensure!(identical, "worker count changed the matrix — determinism bug");
        timing.push(("serial_wall_s", Json::Num(serial_s)));
        timing.push(("speedup", Json::Num(serial_s / wall_s.max(1e-9))));
    }

    println!(
        "{}",
        ascii_table(
            &["row", "cells", "requests", "mean ms", "p99 ms", "rounds", "swaps"],
            &matrix.summary_rows()
        )
    );

    let out = ResultsWriter::new(args.str_or("out", "results"))?;
    let path = out.write_json(
        "BENCH_sweep.json",
        &Json::obj(vec![("matrix", matrix.to_json()), ("timing", Json::obj(timing))]),
    )?;
    println!("wrote {}", path.display());
    Ok(())
}

fn run_experiment(args: &Args) -> anyhow::Result<()> {
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow::anyhow!("experiment name required: fig2|fig6|fig7|fig8|fig9|cl"))?;
    let out = ResultsWriter::new(args.str_or("out", "results"))?;
    match which {
        "fig2" => experiment_fig2(args, &out),
        "fig6" => experiment_fig6(args, &out),
        "fig7" => experiment_fig7(args, &out),
        "fig8" => experiment_fig8(args, &out),
        "fig9" => experiment_fig9(args, &out),
        "cl" => experiment_cl(args, &out),
        other => anyhow::bail!("unknown experiment '{other}'"),
    }
}

fn experiment_fig2(args: &Args, out: &ResultsWriter) -> anyhow::Result<()> {
    let reps = args.usize_or("reps", 5)?;
    let rows = experiments::fig2::run(&experiments::fig2::default_sweep(), reps, 60.0);
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{}", r.n),
                format!("{}", r.m),
                format!("{:.4}", r.mean_s),
                format!("{:.4}", r.ci95_s),
                format!("{:.0}", r.mean_nodes),
                format!("{}", r.all_optimal),
            ]
        })
        .collect();
    println!("{}", ascii_table(&["n", "m", "mean_s", "ci95", "nodes", "optimal"], &table));
    out.write_csv(
        "fig2.csv",
        &["n", "m", "mean_s", "ci95_s", "mean_nodes"],
        &rows
            .iter()
            .map(|r| vec![r.n as f64, r.m as f64, r.mean_s, r.ci95_s, r.mean_nodes])
            .collect::<Vec<_>>(),
    )?;
    Ok(())
}

fn experiment_fig6(args: &Args, out: &ResultsWriter) -> anyhow::Result<()> {
    // The end-to-end PJRT driver lives in examples/continual_traffic.rs;
    // this regenerates the figure quickly with the mock runtime.
    let sc = Scenario::build(ScenarioConfig {
        weeks: args.usize_or("weeks", 6)?,
        ..Default::default()
    })?;
    let rt = hflop::fl::MockRuntime::new(12, 16);
    let fl = FlConfig {
        epochs: 2,
        batches_per_epoch: 4,
        l: 2,
        lr: 0.05,
        rounds: args.usize_or("rounds", 40)?,
        eval_every: 1,
    };
    let window = ContinualWindow::paper(sc.dataset.n_steps, 288);
    let runs = experiments::fig6::run_all(&sc, &rt, fl, window, vec![0.0; rt.n_params()], 3)?;
    let mut rows = Vec::new();
    for r in &runs {
        println!(
            "{:<10} final_mse={:.5} converged_at={:?} comm={:.4} GB",
            r.setup.name(),
            r.mean_final_mse,
            r.rounds_to_converge,
            r.ledger.total_gb()
        );
        for round in 0..r.curves.n_rounds() {
            rows.push(vec![
                match r.setup {
                    Setup::Flat => 0.0,
                    Setup::LocationClustered => 1.0,
                    _ => 2.0,
                },
                round as f64,
                r.curves.mean_at(round) as f64,
            ]);
        }
    }
    out.write_csv("fig6_mock.csv", &["setup", "round", "mean_mse"], &rows)?;
    Ok(())
}

fn experiment_fig7(args: &Args, out: &ResultsWriter) -> anyhow::Result<()> {
    // The paper reports one testbed run; we aggregate over several random
    // scenario draws (client placement + workloads + capacities) — the
    // location-blind baseline's heavy tail comes from the draws whose
    // geographic clusters overload a weak edge.
    use hflop::util::stats::OnlineStats;
    let base_seed = args.u64_or("seed", 40)?;
    let reps = args.u64_or("reps", 6)?;
    let mut agg = [OnlineStats::new(), OnlineStats::new(), OnlineStats::new()];
    let mut spills = [0.0f64; 3];
    let mut requests = [0u64; 3];
    for s in 0..reps {
        let sc = Scenario::build(ScenarioConfig {
            weeks: 5,
            balanced_clients: false,
            seed: base_seed + s,
            ..Default::default()
        })?;
        let r = experiments::fig7::run(&sc, &experiments::fig7::Fig7Config::default());
        for (k, o) in [&r.flat, &r.location, &r.hflop].iter().enumerate() {
            agg[k].merge(&o.latency);
            spills[k] += o.spill_fraction();
            requests[k] += o.total();
        }
    }
    let names = ["flat", "hier", "hflop"];
    let table: Vec<Vec<String>> = (0..3)
        .map(|k| {
            vec![
                names[k].to_string(),
                format!("{:.2}", agg[k].mean()),
                format!("{:.2}", agg[k].std()),
                format!("{}", requests[k]),
                format!("{:.3}", spills[k] / reps as f64),
            ]
        })
        .collect();
    println!("paper:  flat 79.07±15.94   hier 17.72±24.26   hflop 9.89±4.63 (ms)");
    println!("{}", ascii_table(&["setup", "mean_ms", "std_ms", "requests", "spill"], &table));
    out.write_json(
        "fig7.json",
        &Json::obj(vec![
            ("flat_mean_ms", Json::Num(agg[0].mean())),
            ("flat_std_ms", Json::Num(agg[0].std())),
            ("hier_mean_ms", Json::Num(agg[1].mean())),
            ("hier_std_ms", Json::Num(agg[1].std())),
            ("hflop_mean_ms", Json::Num(agg[2].mean())),
            ("hflop_std_ms", Json::Num(agg[2].std())),
        ]),
    )?;
    Ok(())
}

fn experiment_fig8(args: &Args, out: &ResultsWriter) -> anyhow::Result<()> {
    let sc = Scenario::build(ScenarioConfig {
        weeks: 5,
        balanced_clients: false,
        seed: args.u64_or("seed", 42)?,
        ..Default::default()
    })?;
    for (name, scale) in [("a", 1.0), ("b", 10.0)] {
        let cfg = experiments::fig8::Fig8Config { lambda_scale: scale, ..Default::default() };
        let rows = experiments::fig8::run(&sc, &cfg);
        let cx = experiments::fig8::crossover(&rows);
        println!("fig8{name} (lambda x{scale}): crossover={cx:?} (paper 8b: 0.1425)");
        out.write_csv(
            &format!("fig8{name}.csv"),
            &["speedup", "flat_ms", "location_ms", "hflop_ms"],
            &rows
                .iter()
                .map(|r| vec![r.speedup, r.flat_ms, r.location_ms, r.hflop_ms])
                .collect::<Vec<_>>(),
        )?;
    }
    Ok(())
}

fn experiment_fig9(args: &Args, out: &ResultsWriter) -> anyhow::Result<()> {
    let cfg = experiments::fig9::Fig9Config {
        n_devices: args.usize_or("n", 200)?,
        reps: args.usize_or("reps", 10)?,
        ..Default::default()
    };
    let rows = experiments::fig9::run(&cfg)?;
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{}", r.m),
                format!("{:.2}", r.hflop_savings_pct),
                format!("{:.2}", r.hflop_ci95),
                format!("{:.2}", r.uncap_savings_pct),
                format!("{:.2}", r.uncap_ci95),
            ]
        })
        .collect();
    println!("{}", ascii_table(&["edges", "hflop_sav_%", "±", "uncap_sav_%", "±"], &table));
    let (flat, hflop, uncap) = experiments::fig9::absolute_reference(5)?;
    println!("absolute (20 dev, 4 edges, 100 rounds): flat={flat:.2} GB hflop={hflop:.2} GB uncap={uncap:.2} GB");
    println!("paper:                                  flat=2.37 GB hflop=0.53 GB uncap=0.24 GB");
    out.write_csv(
        "fig9.csv",
        &["m", "hflop_savings_pct", "hflop_ci95", "uncap_savings_pct", "uncap_ci95"],
        &rows
            .iter()
            .map(|r| {
                vec![r.m as f64, r.hflop_savings_pct, r.hflop_ci95, r.uncap_savings_pct, r.uncap_ci95]
            })
            .collect::<Vec<_>>(),
    )?;
    Ok(())
}

fn experiment_cl(args: &Args, out: &ResultsWriter) -> anyhow::Result<()> {
    use hflop::data::synth::{generate, SynthConfig};
    use hflop::data::STEPS_PER_WEEK;
    let synth = SynthConfig {
        n_steps: args.usize_or("weeks", 10)? * STEPS_PER_WEEK,
        drift_scale: 2.5,
        ..Default::default()
    };
    let ds = generate(&synth);
    // The real GRU through PJRT (the paper's §V-B1 is a centralized GRU
    // run); a linear mock cannot see the drift — next-step traffic
    // prediction is nearly level-invariant for a linear AR model.
    let manifest = Manifest::load_default()?;
    let variant = args.str_or("variant", "small");
    let engine = Engine::new(&manifest, &variant, Preload::Training)?;
    let init = manifest.load_init_params(engine.variant())?;
    let window =
        ContinualWindow::new(3 * STEPS_PER_WEEK, STEPS_PER_WEEK, STEPS_PER_WEEK / 2, ds.n_steps);
    let r = experiments::cl_table::run(
        &engine,
        &ds.series[0],
        init,
        window,
        args.usize_or("initial_steps", 1500)?,
        args.usize_or("steps_per_shift", 300)?,
        args.f64_or("lr", 0.01)? as f32,
        7,
    )?;
    println!(
        "static MSE = {:.5}   retrained MSE = {:.5}   improvement = {:.2}% (paper: 0.04470 -> 0.04284, 4.2%)",
        r.static_mse,
        r.retrained_mse,
        r.improvement_pct()
    );
    out.write_json(
        "cl_table.json",
        &Json::obj(vec![
            ("static_mse", Json::Num(r.static_mse as f64)),
            ("retrained_mse", Json::Num(r.retrained_mse as f64)),
        ]),
    )?;
    Ok(())
}

fn run_info() -> anyhow::Result<()> {
    match Manifest::load_default() {
        Ok(m) => {
            println!("artifacts: {}", m.dir.display());
            for (name, v) in &m.variants {
                println!(
                    "  {name}: GRU hidden={} layers={} seq={} params={} ({} bytes) artifacts={:?}",
                    v.hidden,
                    v.layers,
                    v.seq_len,
                    v.param_count,
                    v.model_bytes,
                    v.artifacts.keys().collect::<Vec<_>>()
                );
            }
        }
        Err(e) => println!("artifacts not built: {e}"),
    }
    Ok(())
}
