//! `hflop` — CLI launcher for the HFLOP orchestration framework.
//!
//! Subcommands:
//!   solve       solve one HFLOP instance (synthetic generators or sweep)
//!   train       run continual hierarchical FL on the PJRT runtime
//!   serve       run the real batched-serving hot path (PJRT predict)
//!   experiment  run a registered experiment (see `experiment --list`)
//!   sweep       run a deterministic parallel scenario-sweep grid
//!   info        print artifact manifest / environment info
//!
//! `experiment` dispatches purely through the registry
//! (`experiments::registry::REGISTRY`): `--list` enumerates it,
//! `experiment <name> --help` renders the generated parameter schema,
//! and parameters resolve as defaults ← `--config file.toml` ←
//! `--set key=value` (unknown keys fail fast).
//!
//! Flags go last (schema-light parser): `hflop solve --n 100 --m 8 --exact`.

use hflop::cli::Args;
use hflop::config::params::Params;
use hflop::config::Setup;
use hflop::data::window::ContinualWindow;
use hflop::experiments::registry::{self, ExperimentCtx};
use hflop::experiments::sweep::{AxisPoint, run_grid, SweepGrid};
use hflop::experiments::{self, Scenario, ScenarioConfig};
use hflop::fl::FlConfig;
use hflop::hflop::InstanceBuilder;
use hflop::inference::serving::{BatchingServer, InferenceRequest};
use hflop::metrics::export::{ascii_table, ResultsWriter, SCHEMA_VERSION};
use hflop::runtime::{Engine, Manifest, Preload};
use hflop::solver::{self, SolveOptions};
use hflop::util::json::Json;
use hflop::util::rng::Rng;
use hflop::util::tomlmini::{self, Config};

const USAGE: &str = "\
hflop — inference load-aware orchestration for hierarchical FL

USAGE: hflop <subcommand> [options] [--flags]

  solve       --n <devices> --m <edges> [--seed S] [--exact|--heuristic] [--uncap]
  train       --setup flat|hier|hflop --rounds R [--variant small|paper]
              [--clients N] [--edges M] [--epochs E] [--batches B] [--lr LR]
  serve       --requests N [--variant small|paper]
  experiment  --list | --names
  experiment  <name> [--help] [--config F.toml] [--set k=v]... [--<param> v]...
              [--out results/] [--smoke]
  sweep       [--grid interference|smoke|fig7|fig8|budget] [--workers W] [--root-seed S]
              [--out results/] [--smoke] [--compare]
  sweep       --experiment <name> [--rows k=v1,v2] [--modes k=v1,v2]
              [--envs k=v1,v2] [--seeds N] [--set k=v]... (custom registry grid)
  lint        [--manifest lint.toml] (determinism static analysis; exits
              nonzero on deny findings — see DESIGN.md §9)
  info
";

fn main() {
    hflop::init_logging();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand.as_deref() {
        Some("solve") => run_solve(&args),
        Some("train") => run_train(&args),
        Some("serve") => run_serve(&args),
        Some("experiment") => run_experiment(&args),
        Some("sweep") => run_sweep(&args),
        Some("lint") => run_lint(&args),
        Some("info") => run_info(),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e:#}");
        1
    });
    std::process::exit(code);
}

fn run_solve(args: &Args) -> anyhow::Result<()> {
    let n = args.usize_or("n", 100)?;
    let m = args.usize_or("m", 8)?;
    let seed = args.u64_or("seed", 42)?;
    let builder = InstanceBuilder::unit_cost(n, m, seed);
    let inst =
        if args.has_flag("uncap") { builder.uncapacitated().build() } else { builder.build() };
    let opts = if args.has_flag("exact") {
        SolveOptions::exact()
    } else if args.has_flag("heuristic") {
        SolveOptions::heuristic()
    } else {
        SolveOptions::auto()
    };
    let sol = solver::solve(&inst, &opts)?;
    println!(
        "instance n={n} m={m} seed={seed}: cost={:.3} open_edges={} assigned={} optimal={} nodes={} wall={:.3}s",
        sol.cost,
        sol.assignment.n_open(),
        sol.assignment.n_assigned(),
        sol.proven_optimal,
        sol.nodes,
        sol.wall_s
    );
    Ok(())
}

fn run_train(args: &Args) -> anyhow::Result<()> {
    let setup = Setup::parse(&args.str_or("setup", "hflop"))?;
    let variant = args.str_or("variant", "small");
    let rounds = args.usize_or("rounds", 20)?;
    let sc = Scenario::build(ScenarioConfig {
        n_clients: args.usize_or("clients", 20)?,
        n_edges: args.usize_or("edges", 4)?,
        weeks: args.usize_or("weeks", 6)?,
        seed: args.u64_or("seed", 42)?,
        ..Default::default()
    })?;
    let manifest = Manifest::load_default()?;
    let engine = Engine::new(&manifest, &variant, Preload::Training)?;
    let init = manifest.load_init_params(engine.variant())?;
    let fl = FlConfig {
        epochs: args.usize_or("epochs", 1)?,
        batches_per_epoch: args.usize_or("batches", 4)?,
        l: args.usize_or("l", 2)?,
        lr: args.f64_or("lr", 1e-3)? as f32,
        rounds,
        eval_every: 1,
    };
    let window = ContinualWindow::paper(sc.dataset.n_steps, args.usize_or("shift", 288)?);
    let run = experiments::fig6::run_setup(&sc, &engine, setup, fl, window, init, 7)?;
    println!(
        "setup={} rounds={} final_mse={:.5} comm={:.3} GB converged_at={:?}",
        setup.name(),
        rounds,
        run.mean_final_mse,
        run.ledger.total_gb(),
        run.rounds_to_converge
    );
    Ok(())
}

fn run_serve(args: &Args) -> anyhow::Result<()> {
    let variant = args.str_or("variant", "paper");
    let n_requests = args.usize_or("requests", 1000)?;
    let manifest = Manifest::load_default()?;
    let engine = Engine::new(&manifest, &variant, Preload::Serving)?;
    let params = manifest.load_init_params(engine.variant())?;
    let seq = engine.variant().seq_len;
    let mut server = BatchingServer::new(&engine, params);
    let mut rng = Rng::new(args.u64_or("seed", 1)?);
    let mut served = 0usize;
    // Caller-supplied clock: the serve harness measures real latencies.
    let clock = hflop::util::WallClock::start();
    for id in 0..n_requests as u64 {
        let window: Vec<f32> = (0..seq).map(|_| rng.normal() as f32).collect();
        served += server.submit(InferenceRequest { id, window }, clock.elapsed_s())?.len();
    }
    served += server.flush(clock.elapsed_s())?.len();
    let s = &server.stats;
    println!(
        "served {served} requests in {} batches: mean_batch_exec={:.3} ms exec_throughput={:.0} req/s mean_request_latency={:.3} ms",
        s.batches,
        s.batch_exec_ms.mean(),
        s.exec_throughput_rps(),
        s.request_ms.mean()
    );
    Ok(())
}

/// Option keys / flags the experiment subcommand itself consumes; every
/// other `--key value` is resolved against the experiment's schema.
const RESERVED_OPTIONS: [&str; 3] = ["config", "out", "set"];
const RESERVED_FLAGS: [&str; 4] = ["list", "names", "help", "smoke"];

fn run_experiment(args: &Args) -> anyhow::Result<()> {
    // --list / --names: enumerate the registry (names = machine-readable,
    // one per line — the CI smoke loop iterates over it).
    if args.has_flag("names") {
        for e in registry::REGISTRY {
            println!("{}", e.name());
        }
        return Ok(());
    }
    if args.has_flag("list") {
        println!("registered experiments (hflop experiment <name> --help for parameters):");
        let width = registry::names().iter().map(|n| n.len()).max().unwrap_or(0);
        for e in registry::REGISTRY {
            println!("  {:<width$}  {}", e.name(), e.describe());
        }
        return Ok(());
    }

    let name = args.positional.first().map(String::as_str).ok_or_else(|| {
        anyhow::anyhow!("experiment name required (one of: {})", registry::names().join(", "))
    })?;
    let exp = registry::lookup(name)?;
    if args.has_flag("help") {
        println!("{}", registry::render_help(exp));
        return Ok(());
    }

    // Parameter resolution: defaults ← --config file ← --<param> value /
    // --set k=v overrides (in command-line order; unknown keys fail fast).
    let file: Option<Config> = match args.options.get("config") {
        Some(path) => Some(Config::load(path)?),
        None => None,
    };
    let schema = exp.param_schema();
    let mut sets = Vec::new();
    for (key, value) in &args.all_options {
        if key == "set" {
            sets.push(parse_set_spec(value)?);
            continue;
        }
        if RESERVED_OPTIONS.contains(&key.as_str()) {
            continue;
        }
        anyhow::ensure!(
            schema.iter().any(|s| s.key == *key),
            "unknown option --{} for experiment '{}' (parameters: {}; or use --set k=v)",
            key,
            name,
            schema.iter().map(|s| s.key).collect::<Vec<_>>().join(", ")
        );
        sets.push((key.clone(), tomlmini::parse_scalar(value)));
    }
    for flag in &args.flags {
        if RESERVED_FLAGS.contains(&flag.as_str()) {
            continue;
        }
        anyhow::ensure!(
            schema.iter().any(|s| s.key == *flag),
            "unknown flag --{} for experiment '{}'",
            flag,
            name
        );
        sets.push((flag.clone(), hflop::util::tomlmini::Value::Bool(true)));
    }
    let params = Params::resolve(schema, file.as_ref(), &sets)?;

    let out = ResultsWriter::new(args.str_or("out", "results"))?;
    let mut ctx = ExperimentCtx::new(params).with_out(out);
    if args.has_flag("smoke") {
        ctx = ctx.with_smoke(true);
    }
    let report = exp.run(&mut ctx)?;
    let sink = ctx.out.as_ref().expect("launcher always provides a sink");
    for path in report.write(sink)? {
        println!("wrote {}", path.display());
    }
    Ok(())
}

/// Parse one `--set key=value` spec (shared by `experiment` and the
/// custom-grid `sweep` path).
fn parse_set_spec(spec: &str) -> anyhow::Result<(String, hflop::util::tomlmini::Value)> {
    let (key, value) = spec
        .split_once('=')
        .ok_or_else(|| anyhow::anyhow!("--set expects key=value (got '{spec}')"))?;
    Ok((key.trim().to_string(), tomlmini::parse_scalar(value)))
}

/// Parse one `--rows/--modes/--envs key=v1,v2,...` axis spec into hashed
/// axis points (one per value).
fn parse_axis(experiment: &str, spec: &str) -> anyhow::Result<Vec<AxisPoint>> {
    let (key, values) = spec
        .split_once('=')
        .ok_or_else(|| anyhow::anyhow!("axis expects key=v1,v2,... (got '{spec}')"))?;
    let points: Vec<AxisPoint> = values
        .split(',')
        .map(|v| {
            let value = tomlmini::parse_scalar(v);
            AxisPoint::hashed(
                experiment,
                v.trim(),
                vec![(key.trim().to_string(), value)],
            )
        })
        .collect();
    anyhow::ensure!(!points.is_empty(), "axis '{spec}' has no values");
    Ok(points)
}

fn run_sweep(args: &Args) -> anyhow::Result<()> {
    use hflop::util::{pool, time_it};

    let root = args.u64_or("root-seed", 2026)?;
    let grid = if let Some(exp) = args.options.get("experiment") {
        // Custom declarative grid: any registered experiment × override
        // axes × seed range, no code changes required.
        anyhow::ensure!(
            !args.options.contains_key("grid") && !args.has_flag("smoke"),
            "--experiment builds a custom grid; drop --grid/--smoke"
        );
        // Same fail-fast contract as `experiment`: anything that is not
        // a sweep option must be a --set override, never silently
        // dropped (a typo'd `--duration_s 10` would otherwise run the
        // full default grid while looking parameterized).
        const SWEEP_OPTIONS: [&str; 9] =
            ["experiment", "rows", "modes", "envs", "seeds", "set", "workers", "root-seed", "out"];
        let mut base = Vec::new();
        for (key, value) in &args.all_options {
            if key == "set" {
                base.push(parse_set_spec(value)?);
                continue;
            }
            anyhow::ensure!(
                SWEEP_OPTIONS.contains(&key.as_str()),
                "unknown option --{key} for a custom sweep (sweep options: {}; experiment \
                 parameters go through --set k=v)",
                SWEEP_OPTIONS.join(", ")
            );
        }
        let axis_or_neutral = |opt: &str, neutral: &str| -> anyhow::Result<Vec<AxisPoint>> {
            match args.options.get(opt) {
                Some(spec) => parse_axis(exp, spec),
                None => Ok(vec![AxisPoint::neutral(neutral)]),
            }
        };
        SweepGrid::custom(
            exp,
            base,
            axis_or_neutral("rows", "all")?,
            axis_or_neutral("modes", "base")?,
            axis_or_neutral("envs", "base")?,
            args.usize_or("seeds", 2)?,
            root,
        )?
    } else if args.has_flag("smoke") {
        // `--smoke` is its own (reduced) grid; an explicit `--grid`
        // would be silently ignored, so reject the combination.
        anyhow::ensure!(
            !args.options.contains_key("grid"),
            "--smoke selects the smoke grid; drop --grid or drop --smoke"
        );
        SweepGrid::smoke(root)
    } else {
        let name = args.str_or("grid", "interference");
        SweepGrid::by_name(&name, root).ok_or_else(|| {
            anyhow::anyhow!("unknown sweep grid '{name}' ({})", SweepGrid::BUILTIN.join("|"))
        })?
    };
    let workers = args.usize_or("workers", pool::default_workers())?;
    println!(
        "sweep '{}' over experiment '{}': {} cells ({} rows x {} seeds x {} modes x {} envs), {} workers",
        grid.name,
        grid.experiment,
        grid.n_cells(),
        grid.rows.len(),
        grid.n_seeds,
        grid.modes.len(),
        grid.envs.len(),
        workers
    );

    let (matrix, wall_s) = time_it(|| run_grid(&grid, workers));
    let matrix = matrix?;
    let mut timing = vec![
        ("workers", Json::Num(workers as f64)),
        ("parallel_wall_s", Json::Num(wall_s)),
        ("total_cell_wall_s", Json::Num(matrix.total_cell_wall_s())),
    ];
    println!("{workers}-worker run: {wall_s:.2}s wall over {} cells", matrix.cells.len());

    // `--compare` (implied by `--smoke`) re-runs the grid serially: the
    // acceptance check that the pool beats the serial loop while the
    // matrix stays byte-identical.
    if args.has_flag("compare") || args.has_flag("smoke") {
        let (serial, serial_s) = time_it(|| run_grid(&grid, 1));
        let serial = serial?;
        let identical = serial.to_json().to_pretty() == matrix.to_json().to_pretty();
        println!(
            "serial re-run: {serial_s:.2}s wall | speedup {:.2}x | bit-identical: {identical}",
            serial_s / wall_s.max(1e-9)
        );
        anyhow::ensure!(identical, "worker count changed the matrix — determinism bug");
        timing.push(("serial_wall_s", Json::Num(serial_s)));
        timing.push(("speedup", Json::Num(serial_s / wall_s.max(1e-9))));
    }

    println!(
        "{}",
        ascii_table(
            &["row", "cells", "requests", "mean ms", "p99 ms", "rounds", "swaps"],
            &matrix.summary_rows()
        )
    );

    let out = ResultsWriter::new(args.str_or("out", "results"))?;
    let path = out.write_json(
        "BENCH_sweep.json",
        &Json::obj(vec![
            ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
            ("matrix", matrix.to_json()),
            ("timing", Json::obj(timing)),
        ]),
    )?;
    println!("wrote {}", path.display());
    Ok(())
}

fn run_lint(args: &Args) -> anyhow::Result<()> {
    use hflop::analysis::{lint_tree, LintManifest};
    use std::path::{Path, PathBuf};

    // Manifest resolution: --manifest wins; otherwise probe the two
    // layouts (`rust/lint.toml` from the repo root, `lint.toml` from
    // inside rust/).
    let manifest_path = match args.options.get("manifest") {
        Some(p) => PathBuf::from(p),
        None => ["rust/lint.toml", "lint.toml"]
            .iter()
            .map(PathBuf::from)
            .find(|p| p.is_file())
            .ok_or_else(|| {
                anyhow::anyhow!("no lint.toml found in ./rust or .; pass --manifest <path>")
            })?,
    };
    let manifest = LintManifest::load(&manifest_path)?;
    let base = match manifest_path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => Path::new(".").to_path_buf(),
    };
    let report = lint_tree(&manifest, &base)?;
    print!("{}", report.render());
    anyhow::ensure!(
        report.deny_count() == 0,
        "{} deny finding(s) in deterministic zones",
        report.deny_count()
    );
    Ok(())
}

fn run_info() -> anyhow::Result<()> {
    match Manifest::load_default() {
        Ok(m) => {
            println!("artifacts: {}", m.dir.display());
            for (name, v) in &m.variants {
                println!(
                    "  {name}: GRU hidden={} layers={} seq={} params={} ({} bytes) artifacts={:?}",
                    v.hidden,
                    v.layers,
                    v.seq_len,
                    v.param_count,
                    v.model_bytes,
                    v.artifacts.keys().collect::<Vec<_>>()
                );
            }
        }
        Err(e) => println!("artifacts not built: {e}"),
    }
    Ok(())
}
