//! FL device client: owns one sensor's data shard and runs local SGD
//! epochs through a [`ModelRuntime`] (the AOT train-step artifact in
//! production).

use super::ModelRuntime;
use crate::data::window::ClientData;
use crate::util::rng::Rng;

/// Result of one local training phase.
#[derive(Debug, Clone)]
pub struct LocalTrainReport {
    pub params: Vec<f32>,
    pub mean_loss: f32,
    /// Samples used (FedAvg weight).
    pub n_samples: usize,
}

/// An FL client (the paper's "FL device"/sensor).
pub struct Client {
    pub id: usize,
    pub data: ClientData,
    rng: Rng,
}

impl Client {
    pub fn new(id: usize, data: ClientData, seed: u64) -> Client {
        Client { id, data, rng: Rng::new(seed ^ (id as u64).wrapping_mul(0x9E37_79B9)) }
    }

    /// Train `epochs` local epochs of `batches_per_epoch` stochastic
    /// batches sampled from `range` of this client's series.
    pub fn local_train(
        &mut self,
        rt: &dyn ModelRuntime,
        mut params: Vec<f32>,
        range: (usize, usize),
        epochs: usize,
        batches_per_epoch: usize,
        lr: f32,
    ) -> anyhow::Result<LocalTrainReport> {
        let b = rt.train_batch_size();
        let mut loss_acc = 0.0f64;
        let mut steps = 0usize;
        for _ in 0..epochs {
            for _ in 0..batches_per_epoch {
                let (x, y) = self.data.sample_batch(range, b, &mut self.rng);
                let (p, loss) = rt.train_batch(&params, &x, &y, lr)?;
                params = p;
                loss_acc += loss as f64;
                steps += 1;
            }
        }
        Ok(LocalTrainReport {
            params,
            mean_loss: if steps > 0 { (loss_acc / steps as f64) as f32 } else { f32::NAN },
            n_samples: steps * b,
        })
    }

    /// Evaluate MSE over the windows of `range`, chunked into eval
    /// batches (tail padded by wrapping so every window counts once in
    /// expectation; the remainder bias is negligible at our sizes).
    pub fn evaluate(
        &self,
        rt: &dyn ModelRuntime,
        params: &[f32],
        range: (usize, usize),
    ) -> anyhow::Result<f32> {
        let (xs, ys) = self.data.windows(range);
        let t = rt.seq_len();
        let be = rt.eval_batch_size();
        anyhow::ensure!(!ys.is_empty(), "evaluation span has no windows");
        let n = ys.len();
        let mut total = 0.0f64;
        let mut batches = 0usize;
        let mut start = 0usize;
        while start < n {
            // Build one eval batch, wrapping at the end.
            let mut bx = Vec::with_capacity(be * t);
            let mut by = Vec::with_capacity(be);
            for k in 0..be {
                let idx = (start + k) % n;
                bx.extend_from_slice(&xs[idx * t..(idx + 1) * t]);
                by.push(ys[idx]);
            }
            total += rt.eval(params, &bx, &by)? as f64;
            batches += 1;
            start += be;
        }
        Ok((total / batches as f64) as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::window::{ClientData, WindowSpec};
    use crate::fl::MockRuntime;

    fn make_client(id: usize) -> Client {
        let raw: Vec<f32> = (0..600)
            .map(|i| (i as f32 * 0.05).sin() * 10.0 + 30.0)
            .collect();
        let data = ClientData::new(&raw, WindowSpec { seq_len: 4, horizon: 1 }, (0, 400));
        Client::new(id, data, 42)
    }

    #[test]
    fn local_train_reduces_loss() {
        let rt = MockRuntime::new(4, 8);
        let mut c = make_client(0);
        let params = vec![0.0f32; 5];
        let r1 = c.local_train(&rt, params.clone(), (0, 400), 1, 10, 0.05).unwrap();
        let r2 = c.local_train(&rt, r1.params.clone(), (0, 400), 5, 10, 0.05).unwrap();
        assert!(r2.mean_loss < r1.mean_loss, "{} -> {}", r1.mean_loss, r2.mean_loss);
    }

    #[test]
    fn report_counts_samples() {
        let rt = MockRuntime::new(4, 8);
        let mut c = make_client(1);
        let r = c.local_train(&rt, vec![0.0; 5], (0, 400), 3, 7, 0.01).unwrap();
        assert_eq!(r.n_samples, 3 * 7 * 8);
        assert_eq!(r.params.len(), 5);
    }

    #[test]
    fn evaluate_smaller_after_training() {
        let rt = MockRuntime::new(4, 8);
        let mut c = make_client(2);
        let before = c.evaluate(&rt, &vec![0.0; 5], (400, 600)).unwrap();
        let trained = c
            .local_train(&rt, vec![0.0; 5], (0, 400), 20, 10, 0.05)
            .unwrap()
            .params;
        let after = c.evaluate(&rt, &trained, (400, 600)).unwrap();
        assert!(after < before, "{before} -> {after}");
    }

    #[test]
    fn evaluate_errors_on_empty_span() {
        let rt = MockRuntime::new(4, 8);
        let c = make_client(3);
        assert!(c.evaluate(&rt, &vec![0.0; 5], (0, 3)).is_err());
    }

    #[test]
    fn deterministic_given_same_seed() {
        let rt = MockRuntime::new(4, 8);
        let mut a = make_client(7);
        let mut b = make_client(7);
        let ra = a.local_train(&rt, vec![0.0; 5], (0, 400), 2, 5, 0.05).unwrap();
        let rb = b.local_train(&rt, vec![0.0; 5], (0, 400), 2, 5, 0.05).unwrap();
        assert_eq!(ra.params, rb.params);
    }
}
