//! Hierarchical federated learning runtime (L3).
//!
//! The module tree mirrors the paper's §III architecture:
//! * [`client`] — FL device: local SGD epochs through the AOT train-step
//!   artifact, local evaluation.
//! * [`fedavg`] — weighted federated averaging of flat parameter blocks.
//! * [`hierarchy`] — cluster structure (device ↔ edge aggregator ↔ cloud)
//!   built from an HFLOP solution, a location clustering, or flat FL.
//! * [`continual`] — the continual-learning round engine: local rounds,
//!   global rounds every `l` locals, sliding data window per round
//!   (§V-B2), per-client MSE tracking (Fig. 6) and communication-cost
//!   accounting (Fig. 9).
//!
//! Model execution is abstracted behind [`ModelRuntime`] so the FL logic
//! is testable without artifacts ([`MockRuntime`]) and runs the real
//! PJRT engine in production ([`crate::runtime::Engine`] implements the
//! trait).

pub mod client;
pub mod continual;
pub mod fedavg;
pub mod hierarchy;
pub mod timing;

pub use client::{Client, LocalTrainReport};
pub use continual::{ContinualHfl, FlConfig, RoundRecord};
pub use fedavg::fedavg;
pub use hierarchy::{Cluster, Hierarchy};
pub use timing::RoundTimeModel;

use crate::runtime::Engine;

/// Minimal interface the FL round engine needs from a model runtime.
pub trait ModelRuntime {
    /// One SGD step. `x: [B*T*in]`, `y: [B*out]` -> (new params, loss).
    fn train_batch(&self, params: &[f32], x: &[f32], y: &[f32], lr: f32)
        -> anyhow::Result<(Vec<f32>, f32)>;
    /// Mean squared error over one eval batch.
    fn eval(&self, params: &[f32], x: &[f32], y: &[f32]) -> anyhow::Result<f32>;

    fn train_batch_size(&self) -> usize;
    fn eval_batch_size(&self) -> usize;
    fn seq_len(&self) -> usize;
    fn n_params(&self) -> usize;
    /// Serialized model size (bytes) for communication accounting.
    fn model_bytes(&self) -> usize;
}

impl ModelRuntime for Engine {
    fn train_batch(&self, params: &[f32], x: &[f32], y: &[f32], lr: f32)
        -> anyhow::Result<(Vec<f32>, f32)> {
        self.train_step(params, x, y, lr)
    }

    fn eval(&self, params: &[f32], x: &[f32], y: &[f32]) -> anyhow::Result<f32> {
        self.eval_mse(params, x, y)
    }

    fn train_batch_size(&self) -> usize {
        self.variant().train_batch
    }
    fn eval_batch_size(&self) -> usize {
        self.variant().eval_batch
    }
    fn seq_len(&self) -> usize {
        self.variant().seq_len
    }
    fn n_params(&self) -> usize {
        self.variant().param_count
    }
    fn model_bytes(&self) -> usize {
        self.variant().model_bytes
    }
}

/// An artifact-free runtime for tests: a linear model
/// `y = w · x_window + b` trained by exact gradient descent. Keeps the FL
/// logic fully testable (loss must decrease, FedAvg must mix parameters)
/// without the PJRT engine.
#[derive(Debug, Clone)]
pub struct MockRuntime {
    pub seq_len: usize,
    pub batch: usize,
    pub eval_batch: usize,
}

impl MockRuntime {
    pub fn new(seq_len: usize, batch: usize) -> MockRuntime {
        MockRuntime { seq_len, batch, eval_batch: batch }
    }

    fn forward(&self, params: &[f32], window: &[f32]) -> f32 {
        let w = &params[..self.seq_len];
        let b = params[self.seq_len];
        w.iter().zip(window).map(|(a, b)| a * b).sum::<f32>() + b
    }
}

impl ModelRuntime for MockRuntime {
    fn train_batch(&self, params: &[f32], x: &[f32], y: &[f32], lr: f32)
        -> anyhow::Result<(Vec<f32>, f32)> {
        anyhow::ensure!(params.len() == self.seq_len + 1, "mock param len");
        let b = self.batch;
        let t = self.seq_len;
        anyhow::ensure!(x.len() == b * t && y.len() == b, "mock batch shapes");
        let mut grad = vec![0.0f32; t + 1];
        let mut loss = 0.0f32;
        for i in 0..b {
            let win = &x[i * t..(i + 1) * t];
            let pred = self.forward(params, win);
            let err = pred - y[i];
            loss += err * err;
            for (g, &xv) in grad.iter_mut().zip(win) {
                *g += 2.0 * err * xv / b as f32;
            }
            grad[t] += 2.0 * err / b as f32;
        }
        loss /= b as f32;
        let new: Vec<f32> = params.iter().zip(&grad).map(|(p, g)| p - lr * g).collect();
        Ok((new, loss))
    }

    fn eval(&self, params: &[f32], x: &[f32], y: &[f32]) -> anyhow::Result<f32> {
        let t = self.seq_len;
        let n = y.len();
        anyhow::ensure!(x.len() == n * t, "mock eval shapes");
        let mut loss = 0.0f32;
        for i in 0..n {
            let pred = self.forward(params, &x[i * t..(i + 1) * t]);
            loss += (pred - y[i]).powi(2);
        }
        Ok(loss / n as f32)
    }

    fn train_batch_size(&self) -> usize {
        self.batch
    }
    fn eval_batch_size(&self) -> usize {
        self.eval_batch
    }
    fn seq_len(&self) -> usize {
        self.seq_len
    }
    fn n_params(&self) -> usize {
        self.seq_len + 1
    }
    fn model_bytes(&self) -> usize {
        4 * (self.seq_len + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_runtime_learns_linear_target() {
        let rt = MockRuntime::new(4, 8);
        let mut params = vec![0.0f32; 5];
        let mut rng = crate::util::rng::Rng::new(3);
        let true_w = [0.5f32, -0.25, 0.1, 0.7];
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..200 {
            let x: Vec<f32> = (0..32).map(|_| rng.normal() as f32).collect();
            let y: Vec<f32> = (0..8)
                .map(|i| {
                    x[i * 4..(i + 1) * 4]
                        .iter()
                        .zip(&true_w)
                        .map(|(a, b)| a * b)
                        .sum::<f32>()
                        + 0.3
                })
                .collect();
            let (p, loss) = rt.train_batch(&params, &x, &y, 0.1).unwrap();
            params = p;
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(last < first.unwrap() * 0.01, "{first:?} -> {last}");
        for (w, t) in params[..4].iter().zip(&true_w) {
            assert!((w - t).abs() < 0.05);
        }
    }

    #[test]
    fn mock_eval_zero_for_perfect_model() {
        let rt = MockRuntime::new(3, 2);
        let params = vec![1.0, 0.0, 0.0, 0.0]; // y = first element
        let x = vec![5.0, 1.0, 2.0, 7.0, 3.0, 4.0];
        let y = vec![5.0, 7.0];
        assert!(rt.eval(&params, &x, &y).unwrap() < 1e-12);
    }

    #[test]
    fn mock_rejects_bad_shapes() {
        let rt = MockRuntime::new(3, 2);
        assert!(rt.train_batch(&[0.0; 4], &[0.0; 5], &[0.0; 2], 0.1).is_err());
        assert!(rt.train_batch(&[0.0; 3], &[0.0; 6], &[0.0; 2], 0.1).is_err());
    }
}
