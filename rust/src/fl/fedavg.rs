//! Federated averaging over flat f32 parameter blocks.
//!
//! Both aggregation levels of the paper's HFL use the same operator: edge
//! aggregators average their cluster's client models, the global server
//! averages the cluster models. Weighting is by sample count (standard
//! FedAvg); uniform weighting is available as an ablation.

/// Weighted average of parameter blocks: `Σ w_k p_k / Σ w_k`.
///
/// Panics on empty input or mismatched lengths (programming errors in the
/// round engine, not runtime conditions).
pub fn fedavg(blocks: &[(&[f32], f64)]) -> Vec<f32> {
    assert!(!blocks.is_empty(), "fedavg over no models");
    let len = blocks[0].0.len();
    let total_w: f64 = blocks.iter().map(|(_, w)| *w).sum();
    assert!(total_w > 0.0, "fedavg with zero total weight");
    let mut acc = vec![0.0f64; len];
    for (params, w) in blocks {
        assert_eq!(params.len(), len, "fedavg: parameter length mismatch");
        let wn = *w / total_w;
        for (a, &p) in acc.iter_mut().zip(*params) {
            *a += wn * p as f64;
        }
    }
    acc.into_iter().map(|v| v as f32).collect()
}

/// Uniform-weight variant (ablation).
pub fn fedavg_uniform(blocks: &[&[f32]]) -> Vec<f32> {
    let weighted: Vec<(&[f32], f64)> = blocks.iter().map(|&b| (b, 1.0)).collect();
    fedavg(&weighted)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_block_identity() {
        let p = vec![1.0f32, -2.0, 3.5];
        let out = fedavg(&[(&p, 7.0)]);
        assert_eq!(out, p);
    }

    #[test]
    fn equal_weights_mean() {
        let a = vec![0.0f32, 2.0];
        let b = vec![4.0f32, 6.0];
        let out = fedavg(&[(&a, 1.0), (&b, 1.0)]);
        assert_eq!(out, vec![2.0, 4.0]);
    }

    #[test]
    fn weights_proportional() {
        let a = vec![0.0f32];
        let b = vec![10.0f32];
        let out = fedavg(&[(&a, 3.0), (&b, 1.0)]);
        assert!((out[0] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn weight_scale_invariance() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 4.0];
        let o1 = fedavg(&[(&a, 1.0), (&b, 2.0)]);
        let o2 = fedavg(&[(&a, 10.0), (&b, 20.0)]);
        for (x, y) in o1.iter().zip(&o2) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn uniform_matches_equal_weights() {
        let a = vec![1.0f32, 5.0];
        let b = vec![3.0f32, 7.0];
        let c = vec![5.0f32, 0.0];
        let u = fedavg_uniform(&[&a, &b, &c]);
        let w = fedavg(&[(&a, 2.0), (&b, 2.0), (&c, 2.0)]);
        assert_eq!(u, w);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn mismatched_lengths_panic() {
        let a = vec![1.0f32];
        let b = vec![1.0f32, 2.0];
        fedavg(&[(&a, 1.0), (&b, 1.0)]);
    }

    #[test]
    #[should_panic(expected = "no models")]
    fn empty_panics() {
        fedavg(&[]);
    }

    #[test]
    fn idempotent_on_identical_blocks() {
        let p = vec![0.25f32; 64];
        let out = fedavg(&[(&p, 1.0), (&p, 5.0), (&p, 0.5)]);
        for (a, b) in out.iter().zip(&p) {
            assert!((a - b).abs() < 1e-7);
        }
    }
}
