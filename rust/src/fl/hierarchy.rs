//! FL hierarchy: which devices report to which edge aggregator.
//!
//! Three construction paths matching the paper's three evaluated setups:
//! * [`Hierarchy::flat`] — vanilla centralized FL (every device talks to
//!   the cloud; modeled as a single virtual aggregator co-located with
//!   the global server).
//! * [`Hierarchy::from_location_clusters`] — the location-based baseline
//!   (§V-B2 / Fig. 5): k-means clusters, one edge server per cluster.
//! * [`Hierarchy::from_assignment`] — the HFLOP solution (§IV): clusters
//!   follow the cost-optimal, capacity-feasible assignment.

use crate::solver::Assignment;
use crate::topology::{kmeans, GeoPoint};
use crate::util::rng::Rng;

/// One cluster: an edge aggregator and its member devices.
#[derive(Debug, Clone, PartialEq)]
pub struct Cluster {
    /// Edge host id (usize::MAX for the virtual cloud aggregator in flat FL).
    pub edge_id: usize,
    pub members: Vec<usize>,
}

/// The full hierarchy.
#[derive(Debug, Clone)]
pub struct Hierarchy {
    pub clusters: Vec<Cluster>,
    /// True when the "aggregator" actually is the cloud (flat FL): every
    /// local round is a global round and device↔aggregator traffic is
    /// metered at cloud rates.
    pub flat: bool,
}

pub const CLOUD_EDGE_ID: usize = usize::MAX;

impl Hierarchy {
    /// Vanilla FL: one virtual cluster at the cloud.
    pub fn flat(n_devices: usize) -> Hierarchy {
        Hierarchy {
            clusters: vec![Cluster { edge_id: CLOUD_EDGE_ID, members: (0..n_devices).collect() }],
            flat: true,
        }
    }

    /// Location-based clustering baseline: k-means over device locations.
    pub fn from_location_clusters(
        locations: &[GeoPoint],
        n_clusters: usize,
        seed: u64,
    ) -> Hierarchy {
        let mut rng = Rng::new(seed);
        let km = kmeans(locations, n_clusters, 100, &mut rng);
        let k = km.centroids.len();
        let mut clusters: Vec<Cluster> =
            (0..k).map(|j| Cluster { edge_id: j, members: Vec::new() }).collect();
        for (i, &c) in km.assignment.iter().enumerate() {
            clusters[c].members.push(i);
        }
        clusters.retain(|c| !c.members.is_empty());
        Hierarchy { clusters, flat: false }
    }

    /// From an HFLOP solution. Unassigned devices (allowed when T < n) are
    /// left out of the hierarchy — they do not participate this task.
    pub fn from_assignment(sol: &Assignment) -> Hierarchy {
        let m = sol.open.len();
        let mut clusters: Vec<Cluster> =
            (0..m).map(|j| Cluster { edge_id: j, members: Vec::new() }).collect();
        for (i, &a) in sol.assign.iter().enumerate() {
            if let Some(j) = a {
                clusters[j].members.push(i);
            }
        }
        clusters.retain(|c| !c.members.is_empty());
        Hierarchy { clusters, flat: false }
    }

    pub fn n_participants(&self) -> usize {
        self.clusters.iter().map(|c| c.members.len()).sum()
    }

    pub fn n_clusters(&self) -> usize {
        self.clusters.len()
    }

    /// Cluster index serving device `i`, if any.
    pub fn cluster_of(&self, device: usize) -> Option<usize> {
        self.clusters.iter().position(|c| c.members.contains(&device))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hflop::InstanceBuilder;
    use crate::solver::{solve, SolveOptions};

    #[test]
    fn flat_single_cluster() {
        let h = Hierarchy::flat(10);
        assert!(h.flat);
        assert_eq!(h.n_clusters(), 1);
        assert_eq!(h.n_participants(), 10);
        assert_eq!(h.clusters[0].edge_id, CLOUD_EDGE_ID);
    }

    #[test]
    fn from_assignment_groups_members() {
        let inst = InstanceBuilder::unit_cost(20, 4, 3).build();
        let sol = solve(&inst, &SolveOptions::exact()).unwrap();
        let h = Hierarchy::from_assignment(&sol.assignment);
        assert!(!h.flat);
        assert_eq!(h.n_participants(), 20);
        // Each member's assignment matches its cluster's edge.
        for c in &h.clusters {
            for &i in &c.members {
                assert_eq!(sol.assignment.assign[i], Some(c.edge_id));
            }
        }
    }

    #[test]
    fn from_assignment_skips_unassigned() {
        use crate::solver::Assignment;
        let sol = Assignment {
            assign: vec![Some(0), None, Some(0)],
            open: vec![true, false],
        };
        let h = Hierarchy::from_assignment(&sol);
        assert_eq!(h.n_participants(), 2);
        assert_eq!(h.cluster_of(1), None);
        assert_eq!(h.cluster_of(0), Some(0));
    }

    #[test]
    fn location_clusters_cover_all_devices() {
        let locs: Vec<GeoPoint> = (0..40)
            .map(|i| GeoPoint {
                lat: 34.0 + 0.17 * ((i % 4) as f64 / 4.0),
                lon: -118.45 + 0.2 * ((i / 4) as f64 / 10.0),
            })
            .collect();
        let h = Hierarchy::from_location_clusters(&locs, 4, 1);
        assert_eq!(h.n_participants(), 40);
        assert!(h.n_clusters() <= 4 && h.n_clusters() >= 1);
        for i in 0..40 {
            assert!(h.cluster_of(i).is_some());
        }
    }
}
