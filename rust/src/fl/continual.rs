//! The continual hierarchical FL round engine (§III + §V-B2).
//!
//! One *aggregation round* (the unit on Fig. 6's x-axis):
//! 1. every participating client trains `epochs` local epochs on its
//!    current training window and uploads to its edge aggregator;
//! 2. the edge aggregator FedAvg-combines its cluster and pushes the
//!    cluster model back to members (a *local round*);
//! 3. every `l`-th local round the aggregators upload cluster models to
//!    the global server, which FedAvg-combines them into the global model
//!    and broadcasts it back down (a *global round*);
//! 4. each client evaluates the model it now holds on its validation
//!    window (Fig. 6 plots this per client);
//! 5. the data window shifts ("the global time shifts") — continual
//!    learning.
//!
//! Flat FL degenerates to: every round is a global round and the
//! "aggregator" is the cloud.
//!
//! Communication is accounted in a [`CommLedger`] exactly as the paper
//! meters it (§V-D): device↔edge exchanges are metered iff that link has
//! positive cost; edge↔cloud always.

use super::client::Client;
use super::fedavg::fedavg;
use super::hierarchy::Hierarchy;
use super::timing::RoundTimeModel;
use super::ModelRuntime;
use crate::data::window::ContinualWindow;
use crate::hflop::Instance;
use crate::metrics::cost::CommLedger;
use crate::metrics::MseCurves;

/// Round-engine configuration.
#[derive(Debug, Clone)]
pub struct FlConfig {
    /// Local epochs per aggregation round (paper: 5).
    pub epochs: usize,
    /// Stochastic batches per epoch (scales compute; paper trains full
    /// epochs — we subsample to fit the testbed, see EXPERIMENTS.md).
    pub batches_per_epoch: usize,
    /// Local rounds per global round (paper: l = 2).
    pub l: usize,
    pub lr: f32,
    /// Total aggregation rounds (paper: 100).
    pub rounds: usize,
    /// Evaluate every k-th round (1 = every round, Fig. 6 granularity).
    pub eval_every: usize,
}

impl Default for FlConfig {
    fn default() -> Self {
        FlConfig { epochs: 5, batches_per_epoch: 8, l: 2, lr: 1e-3, rounds: 100, eval_every: 1 }
    }
}

/// Per-round record for logs/plots.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: usize,
    pub global_round: bool,
    pub mean_train_loss: f32,
    pub mean_val_mse: f32,
    /// Timeline span the round occupied (both 0 when no time model is
    /// attached; see [`ContinualHfl::with_timing`]).
    pub start_s: f64,
    pub end_s: f64,
}

impl RoundRecord {
    pub fn duration_s(&self) -> f64 {
        self.end_s - self.start_s
    }
}

/// The assembled training system for one experiment setup.
pub struct ContinualHfl<'a> {
    pub runtime: &'a dyn ModelRuntime,
    pub hierarchy: Hierarchy,
    pub clients: Vec<Client>,
    pub window: ContinualWindow,
    pub config: FlConfig,
    /// Cost context: the HFLOP instance supplies per-link metering. For
    /// flat FL it is ignored (all exchanges are cloud exchanges).
    pub instance: Option<&'a Instance>,
    /// Optional wall-clock time model: when set, every round occupies a
    /// timeline interval (straggler compute + model transfers) recorded
    /// in its [`RoundRecord`].
    pub timing: Option<RoundTimeModel>,

    // --- state -----------------------------------------------------------
    /// Simulated wall clock (s); advances by each round's duration when a
    /// time model is attached.
    pub clock_s: f64,
    pub global_params: Vec<f32>,
    cluster_params: Vec<Vec<f32>>,
    pub ledger: CommLedger,
    pub curves: MseCurves,
    pub records: Vec<RoundRecord>,
}

impl<'a> ContinualHfl<'a> {
    pub fn new(
        runtime: &'a dyn ModelRuntime,
        hierarchy: Hierarchy,
        clients: Vec<Client>,
        window: ContinualWindow,
        config: FlConfig,
        init_params: Vec<f32>,
        instance: Option<&'a Instance>,
    ) -> ContinualHfl<'a> {
        assert_eq!(init_params.len(), runtime.n_params(), "init params shape");
        let n_clusters = hierarchy.n_clusters();
        let n_clients = clients.len();
        ContinualHfl {
            runtime,
            hierarchy,
            clients,
            window,
            config,
            instance,
            timing: None,
            clock_s: 0.0,
            cluster_params: vec![init_params.clone(); n_clusters],
            global_params: init_params,
            ledger: CommLedger::new(),
            curves: MseCurves::new(n_clients),
            records: Vec::new(),
        }
    }

    /// Attach a wall-clock time model: rounds then occupy timeline
    /// intervals instead of executing atemporally.
    pub fn with_timing(mut self, timing: RoundTimeModel) -> Self {
        self.timing = Some(timing);
        self
    }

    /// Is the device↔edge link metered? (flat FL: always a cloud link.)
    fn device_link_metered(&self, device: usize, edge_id: usize) -> bool {
        match self.instance {
            Some(inst) if edge_id < inst.m() => inst.c_d[device][edge_id] > 0.0,
            _ => true,
        }
    }

    /// Run one aggregation round. Returns the record.
    pub fn step_round(&mut self, round: usize) -> anyhow::Result<RoundRecord> {
        let cfg = self.config.clone();
        let model_bytes = self.runtime.model_bytes();
        let train_range = self.window.train_range();
        let val_range = self.window.val_range();
        let is_global = self.hierarchy.flat || (round + 1) % cfg.l == 0;

        let mut loss_acc = 0.0f64;
        let mut loss_cnt = 0usize;

        // ---- local training + edge aggregation ---------------------------
        for (ci, cluster) in self.hierarchy.clusters.clone().iter().enumerate() {
            let mut uploads: Vec<(Vec<f32>, f64)> = Vec::with_capacity(cluster.members.len());
            for &dev in &cluster.members {
                let report = self.clients[dev].local_train(
                    self.runtime,
                    self.cluster_params[ci].clone(),
                    train_range,
                    cfg.epochs,
                    cfg.batches_per_epoch,
                    cfg.lr,
                )?;
                loss_acc += report.mean_loss as f64;
                loss_cnt += 1;
                // Device -> aggregator upload + later download of the
                // aggregated model: one exchange.
                if self.hierarchy.flat {
                    self.ledger.cloud_exchange(model_bytes);
                } else {
                    let metered = self.device_link_metered(dev, cluster.edge_id);
                    self.ledger.device_edge_exchange(metered, model_bytes);
                }
                uploads.push((report.params, report.n_samples as f64));
            }
            let refs: Vec<(&[f32], f64)> =
                uploads.iter().map(|(p, w)| (p.as_slice(), *w)).collect();
            self.cluster_params[ci] = fedavg(&refs);
        }

        // ---- global aggregation ------------------------------------------
        if is_global {
            let weights: Vec<f64> = self
                .hierarchy
                .clusters
                .iter()
                .map(|c| c.members.len() as f64)
                .collect();
            let refs: Vec<(&[f32], f64)> = self
                .cluster_params
                .iter()
                .zip(&weights)
                .map(|(p, &w)| (p.as_slice(), w))
                .collect();
            self.global_params = fedavg(&refs);
            for params in self.cluster_params.iter_mut() {
                *params = self.global_params.clone();
            }
            if !self.hierarchy.flat {
                // Each open aggregator exchanges with the cloud.
                for _ in 0..self.hierarchy.n_clusters() {
                    self.ledger.cloud_exchange(model_bytes);
                }
            }
        }

        // ---- evaluation (Fig. 6: after receiving the updated model) ------
        let mut val_acc = 0.0f64;
        let mut val_cnt = 0usize;
        if round % cfg.eval_every == 0 {
            for (ci, cluster) in self.hierarchy.clusters.iter().enumerate() {
                for &dev in &cluster.members {
                    let mse = self.clients[dev].evaluate(
                        self.runtime,
                        &self.cluster_params[ci],
                        val_range,
                    )?;
                    self.curves.push(dev, mse);
                    val_acc += mse as f64;
                    val_cnt += 1;
                }
            }
        }

        // ---- continual shift ---------------------------------------------
        self.window.advance();

        // ---- timeline accounting -----------------------------------------
        // Clusters train in parallel; the round lasts as long as the
        // slowest cluster, plus the edge↔cloud sync when the round is
        // global (flat FL syncs with the cloud every round).
        let start_s = self.clock_s;
        if let Some(tm) = &self.timing {
            let slowest_cluster = self
                .hierarchy
                .clusters
                .iter()
                .map(|c| tm.cluster_round_s(&c.members, cfg.epochs, model_bytes))
                .fold(0.0, f64::max);
            let sync = if is_global { tm.global_sync_s(model_bytes) } else { 0.0 };
            self.clock_s += slowest_cluster + sync;
        }

        let rec = RoundRecord {
            round,
            global_round: is_global,
            mean_train_loss: if loss_cnt > 0 { (loss_acc / loss_cnt as f64) as f32 } else { f32::NAN },
            mean_val_mse: if val_cnt > 0 { (val_acc / val_cnt as f64) as f32 } else { f32::NAN },
            start_s,
            end_s: self.clock_s,
        };
        self.records.push(rec.clone());
        Ok(rec)
    }

    /// Run the configured number of rounds.
    pub fn run(&mut self) -> anyhow::Result<()> {
        for round in 0..self.config.rounds {
            let rec = self.step_round(round)?;
            crate::log_info!(
                "round {:>3}{} train_loss={:.5} val_mse={:.5} comm={:.3} GB",
                rec.round,
                if rec.global_round { " [global]" } else { "        " },
                rec.mean_train_loss,
                rec.mean_val_mse,
                self.ledger.total_gb(),
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::window::{ClientData, WindowSpec};
    use crate::fl::MockRuntime;
    use crate::util::rng::Rng;

    const T: usize = 4;

    /// Clients observing noisy versions of the same AR-ish process (so a
    /// shared model helps) split across two clusters.
    fn make_clients(n: usize) -> Vec<Client> {
        let mut rng = Rng::new(1);
        (0..n)
            .map(|id| {
                let raw: Vec<f32> = (0..800)
                    .map(|i| {
                        ((i as f32 * 0.05).sin() * 8.0 + 20.0) + rng.normal() as f32 * 0.5
                    })
                    .collect();
                let data = ClientData::new(&raw, WindowSpec { seq_len: T, horizon: 1 }, (0, 500));
                Client::new(id, data, 77)
            })
            .collect()
    }

    fn base_config() -> FlConfig {
        FlConfig { epochs: 1, batches_per_epoch: 4, l: 2, lr: 0.05, rounds: 12, eval_every: 1 }
    }

    fn hierarchical(n: usize) -> Hierarchy {
        Hierarchy {
            clusters: vec![
                super::super::hierarchy::Cluster { edge_id: 0, members: (0..n / 2).collect() },
                super::super::hierarchy::Cluster { edge_id: 1, members: (n / 2..n).collect() },
            ],
            flat: false,
        }
    }

    #[test]
    fn training_reduces_val_mse() {
        let rt = MockRuntime::new(T, 8);
        let clients = make_clients(6);
        let window = ContinualWindow::new(500, 100, 10, 800);
        let mut sys = ContinualHfl::new(
            &rt,
            hierarchical(6),
            clients,
            window,
            base_config(),
            vec![0.0; T + 1],
            None,
        );
        sys.run().unwrap();
        let first = sys.curves.mean_at(0);
        let last = sys.curves.converged_mean(3);
        assert!(last < first * 0.8, "{first} -> {last}");
    }

    #[test]
    fn global_round_syncs_clusters() {
        let rt = MockRuntime::new(T, 8);
        let clients = make_clients(4);
        let window = ContinualWindow::new(500, 100, 0, 800);
        let mut cfg = base_config();
        cfg.rounds = 2; // round 1 (index 1) is a global round with l=2
        let mut sys = ContinualHfl::new(
            &rt,
            hierarchical(4),
            clients,
            window,
            cfg,
            vec![0.0; T + 1],
            None,
        );
        sys.step_round(0).unwrap();
        assert_ne!(sys.cluster_params[0], sys.cluster_params[1]);
        sys.step_round(1).unwrap();
        assert_eq!(sys.cluster_params[0], sys.cluster_params[1]);
        assert_eq!(sys.cluster_params[0], sys.global_params);
    }

    #[test]
    fn flat_fl_comm_matches_closed_form() {
        let rt = MockRuntime::new(T, 8);
        let n = 5;
        let clients = make_clients(n);
        let window = ContinualWindow::new(500, 100, 0, 800);
        let mut cfg = base_config();
        cfg.rounds = 10;
        let mut sys = ContinualHfl::new(
            &rt,
            Hierarchy::flat(n),
            clients,
            window,
            cfg,
            vec![0.0; T + 1],
            None,
        );
        sys.run().unwrap();
        let expect = crate::metrics::cost::flat_fl_bytes(n, 10, rt.model_bytes());
        assert_eq!(sys.ledger.total_bytes(), expect);
    }

    #[test]
    fn hierarchical_comm_cheaper_than_flat_with_free_links() {
        let rt = MockRuntime::new(T, 8);
        let n = 6;
        // Instance where every device's assigned edge is free.
        let inst = crate::hflop::Instance {
            c_d: vec![vec![0.0, 0.0]; n].into(),
            c_e: vec![1.0, 1.0],
            lambda: vec![1.0; n].into(),
            r: vec![100.0, 100.0].into(),
            l: 2.0,
            t_min: n,
            meta: Default::default(),
        };
        let window = ContinualWindow::new(500, 100, 0, 800);
        let mut cfg = base_config();
        cfg.rounds = 8;
        let mut hier_sys = ContinualHfl::new(
            &rt,
            hierarchical(n),
            make_clients(n),
            window.clone(),
            cfg.clone(),
            vec![0.0; T + 1],
            Some(&inst),
        );
        hier_sys.run().unwrap();
        let mut flat_sys = ContinualHfl::new(
            &rt,
            Hierarchy::flat(n),
            make_clients(n),
            window,
            cfg,
            vec![0.0; T + 1],
            None,
        );
        flat_sys.run().unwrap();
        assert!(hier_sys.ledger.total_bytes() < flat_sys.ledger.total_bytes());
        // Hier: only cluster<->cloud exchanges are metered: 2 clusters * 4
        // global rounds * 2 * bytes.
        assert_eq!(
            hier_sys.ledger.total_bytes(),
            2 * 2 * 4 * rt.model_bytes() as u64
        );
    }

    #[test]
    fn window_advances_each_round() {
        let rt = MockRuntime::new(T, 8);
        let clients = make_clients(2);
        let window = ContinualWindow::new(500, 100, 20, 800);
        let mut cfg = base_config();
        cfg.rounds = 5;
        let mut sys = ContinualHfl::new(
            &rt,
            Hierarchy::flat(2),
            clients,
            window,
            cfg,
            vec![0.0; T + 1],
            None,
        );
        sys.run().unwrap();
        assert_eq!(sys.window.offset, 100); // 5 rounds * shift 20
    }

    #[test]
    fn records_and_curves_populated() {
        let rt = MockRuntime::new(T, 8);
        let clients = make_clients(3);
        let window = ContinualWindow::new(500, 100, 0, 800);
        let mut cfg = base_config();
        cfg.rounds = 4;
        let mut sys = ContinualHfl::new(
            &rt,
            Hierarchy::flat(3),
            clients,
            window,
            cfg,
            vec![0.0; T + 1],
            None,
        );
        sys.run().unwrap();
        assert_eq!(sys.records.len(), 4);
        assert_eq!(sys.curves.n_rounds(), 4);
        assert!(sys.records.iter().all(|r| r.mean_val_mse.is_finite()));
    }

    #[test]
    fn rounds_atemporal_without_time_model() {
        let rt = MockRuntime::new(T, 8);
        let mut cfg = base_config();
        cfg.rounds = 3;
        let mut sys = ContinualHfl::new(
            &rt,
            Hierarchy::flat(2),
            make_clients(2),
            ContinualWindow::new(500, 100, 0, 800),
            cfg,
            vec![0.0; T + 1],
            None,
        );
        sys.run().unwrap();
        assert_eq!(sys.clock_s, 0.0);
        assert!(sys.records.iter().all(|r| r.start_s == 0.0 && r.end_s == 0.0));
    }

    #[test]
    fn rounds_occupy_contiguous_timeline_intervals() {
        use crate::fl::timing::RoundTimeModel;
        let rt = MockRuntime::new(T, 8);
        let mut cfg = base_config();
        cfg.rounds = 6;
        let tm = RoundTimeModel { epoch_compute_s: 3.0, ..Default::default() };
        let mut sys = ContinualHfl::new(
            &rt,
            hierarchical(6),
            make_clients(6),
            ContinualWindow::new(500, 100, 0, 800),
            cfg.clone(),
            vec![0.0; T + 1],
            None,
        )
        .with_timing(tm.clone());
        sys.run().unwrap();
        assert_eq!(sys.records.len(), 6);
        // Spans are contiguous, ordered, and strictly positive.
        let mut prev_end = 0.0;
        for r in &sys.records {
            assert_eq!(r.start_s, prev_end);
            assert!(r.duration_s() > 0.0, "round {} has no duration", r.round);
            prev_end = r.end_s;
        }
        assert_eq!(sys.clock_s, prev_end);
        // A global round costs extra (edge↔cloud sync) relative to a
        // local round with the same cluster structure.
        let local = sys.records.iter().find(|r| !r.global_round).unwrap();
        let global = sys.records.iter().find(|r| r.global_round).unwrap();
        assert!(
            global.duration_s() > local.duration_s(),
            "global {} vs local {}",
            global.duration_s(),
            local.duration_s()
        );
    }

    #[test]
    fn straggler_device_stretches_rounds() {
        use crate::fl::timing::RoundTimeModel;
        let rt = MockRuntime::new(T, 8);
        let mut cfg = base_config();
        cfg.rounds = 2;
        let fast = RoundTimeModel::default();
        let slow = RoundTimeModel { device_speed: vec![1.0, 0.1], ..Default::default() };
        let run_with = |tm: RoundTimeModel| {
            let mut sys = ContinualHfl::new(
                &rt,
                hierarchical(4),
                make_clients(4),
                ContinualWindow::new(500, 100, 0, 800),
                cfg.clone(),
                vec![0.0; T + 1],
                None,
            )
            .with_timing(tm);
            sys.run().unwrap();
            sys.clock_s
        };
        assert!(run_with(slow) > run_with(fast) * 2.0);
    }
}
