//! Wall-clock time model for HFL rounds.
//!
//! The paper couples training and serving on shared infrastructure, so
//! rounds must *occupy intervals on a timeline* instead of executing
//! atemporally: a round's duration is the straggler's local compute time
//! (device capacity) plus model-exchange time (`model_bytes` over the
//! device↔edge link), plus the edge↔cloud sync on global rounds. The
//! continual round engine ([`super::continual::ContinualHfl`]) uses this
//! to stamp `RoundRecord`s with timeline spans, and the co-simulation
//! kernel (`inference::cosim`) uses the same model to decide how long an
//! edge's serving capacity is degraded by an in-flight round.

/// Time model mapping one aggregation round to a wall-clock duration.
#[derive(Debug, Clone)]
pub struct RoundTimeModel {
    /// Local compute seconds for one epoch at unit device speed.
    pub epoch_compute_s: f64,
    /// Per-device relative compute speed (1.0 = reference). Devices not
    /// listed default to 1.0; slower devices (< 1.0) become stragglers.
    pub device_speed: Vec<f64>,
    /// Device ↔ edge link throughput for model exchanges (bytes/s).
    pub device_link_bytes_per_s: f64,
    /// Edge ↔ cloud backhaul throughput (bytes/s).
    pub backhaul_bytes_per_s: f64,
    /// One-way device → edge network latency (s).
    pub device_latency_s: f64,
    /// One-way edge → cloud network latency (s).
    pub cloud_latency_s: f64,
}

impl Default for RoundTimeModel {
    fn default() -> Self {
        RoundTimeModel {
            epoch_compute_s: 2.0,
            device_speed: Vec::new(),
            device_link_bytes_per_s: 2.0e6, // ~16 Mbit/s uplink
            backhaul_bytes_per_s: 20.0e6,
            device_latency_s: 0.009, // paper §V-C1: edge RTT 8–10 ms
            cloud_latency_s: 0.075,  // paper §V-C1: cloud RTT 50–100 ms
        }
    }
}

impl RoundTimeModel {
    /// Relative compute speed of `device` (defaults to 1.0).
    pub fn speed(&self, device: usize) -> f64 {
        self.device_speed.get(device).copied().unwrap_or(1.0).max(1e-9)
    }

    /// One model transfer over the device ↔ edge link (s).
    pub fn device_transfer_s(&self, model_bytes: usize) -> f64 {
        model_bytes as f64 / self.device_link_bytes_per_s.max(1e-9) + self.device_latency_s
    }

    /// Local compute + model upload for one client in one round (s).
    pub fn client_round_s(&self, device: usize, epochs: usize, model_bytes: usize) -> f64 {
        epochs as f64 * self.epoch_compute_s / self.speed(device)
            + self.device_transfer_s(model_bytes)
    }

    /// One cluster's local round (s): synchronous FedAvg waits for the
    /// straggler, then broadcasts the aggregate back to members.
    pub fn cluster_round_s(&self, members: &[usize], epochs: usize, model_bytes: usize) -> f64 {
        if members.is_empty() {
            return 0.0;
        }
        let slowest = members
            .iter()
            .map(|&d| self.client_round_s(d, epochs, model_bytes))
            .fold(0.0, f64::max);
        slowest + self.device_transfer_s(model_bytes)
    }

    /// Edge ↔ cloud sync on a global round: cluster-model upload plus
    /// global-model broadcast (s).
    pub fn global_sync_s(&self, model_bytes: usize) -> f64 {
        2.0 * (model_bytes as f64 / self.backhaul_bytes_per_s.max(1e-9) + self.cloud_latency_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn straggler_dominates_cluster_round() {
        let tm = RoundTimeModel {
            device_speed: vec![1.0, 0.25, 1.0],
            ..Default::default()
        };
        let fast = tm.client_round_s(0, 5, 40_000);
        let slow = tm.client_round_s(1, 5, 40_000);
        assert!(slow > fast * 3.0, "{slow} vs {fast}");
        let cluster = tm.cluster_round_s(&[0, 1, 2], 5, 40_000);
        assert!((cluster - (slow + tm.device_transfer_s(40_000))).abs() < 1e-12);
    }

    #[test]
    fn empty_cluster_takes_no_time() {
        assert_eq!(RoundTimeModel::default().cluster_round_s(&[], 5, 40_000), 0.0);
    }

    #[test]
    fn more_epochs_take_longer() {
        let tm = RoundTimeModel::default();
        assert!(tm.client_round_s(0, 10, 1000) > tm.client_round_s(0, 5, 1000));
    }

    #[test]
    fn bigger_model_costs_more_transfer() {
        let tm = RoundTimeModel::default();
        assert!(tm.global_sync_s(4_000_000) > tm.global_sync_s(4_000));
        assert!(tm.cluster_round_s(&[0], 1, 4_000_000) > tm.cluster_round_s(&[0], 1, 4_000));
    }

    #[test]
    fn unknown_devices_default_to_unit_speed() {
        let tm = RoundTimeModel::default();
        assert_eq!(tm.speed(99), 1.0);
    }
}
