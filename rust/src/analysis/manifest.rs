//! `rust/lint.toml` — the committed detlint manifest.
//!
//! Declares which paths under the crate's `src/` are deterministic
//! zones, which files inside them are excluded verbatim (the frozen
//! `sim/oracle.rs` differential baseline), and each rule's severity.
//! Parsed with [`crate::util::tomlmini`] (arrays single-line, per that
//! parser's subset). Unknown rule names in `[severity]` are hard errors
//! so a typo cannot silently disable a rule.

use std::collections::BTreeMap;
use std::path::Path;

use super::rules;
use crate::util::tomlmini::{Config, Value};

/// What a rule hit does to the exit code.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// Reported; makes `hflop lint` exit nonzero.
    Deny,
    /// Reported; exit code unaffected.
    Warn,
    /// Rule disabled.
    Allow,
}

impl Severity {
    fn parse(s: &str) -> Option<Severity> {
        match s {
            "deny" => Some(Severity::Deny),
            "warn" => Some(Severity::Warn),
            "allow" => Some(Severity::Allow),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Severity::Deny => "deny",
            Severity::Warn => "warn",
            Severity::Allow => "allow",
        }
    }
}

/// Parsed manifest: zone map plus per-rule severities.
#[derive(Debug, Clone)]
pub struct LintManifest {
    /// Source root the zone paths are relative to (default `src`).
    pub root: String,
    /// Deterministic-zone path prefixes, relative to `root`. An entry
    /// matches a directory subtree (`solver`) or a single file with or
    /// without its `.rs` extension (`experiments/sweep`).
    pub zones: Vec<String>,
    /// Files inside zones scanned never (frozen oracles).
    pub exclude: Vec<String>,
    /// Per-rule severity; rules absent here default to deny.
    pub severity: BTreeMap<String, Severity>,
}

impl LintManifest {
    pub fn parse(text: &str) -> anyhow::Result<LintManifest> {
        let cfg = Config::parse(text)?;
        let root = cfg.str_or("detlint.root", "src").to_string();
        let zones = str_array(&cfg, "zones.deterministic")?;
        anyhow::ensure!(!zones.is_empty(), "lint.toml declares no deterministic zones");
        let exclude = match cfg.get("zones.exclude") {
            Some(_) => str_array(&cfg, "zones.exclude")?,
            None => Vec::new(),
        };
        let mut severity = BTreeMap::new();
        for (key, value) in cfg.section("severity") {
            let rule = key.strip_prefix("severity.").unwrap_or(key.as_str());
            anyhow::ensure!(
                rules::names().contains(&rule),
                "lint.toml [severity] names unknown rule '{rule}' (rules: {})",
                rules::names().join(", ")
            );
            let sev = value
                .as_str()
                .and_then(Severity::parse)
                .ok_or_else(|| anyhow::anyhow!("rule '{rule}': severity must be deny|warn|allow"))?;
            severity.insert(rule.to_string(), sev);
        }
        Ok(LintManifest { root, zones, exclude, severity })
    }

    pub fn load(path: &Path) -> anyhow::Result<LintManifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        LintManifest::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
    }

    /// Severity of `rule` (deny when the manifest is silent).
    pub fn severity_of(&self, rule: &str) -> Severity {
        self.severity.get(rule).copied().unwrap_or(Severity::Deny)
    }

    /// The zone entry covering `rel` (a `/`-separated path relative to
    /// `root`), if any.
    pub fn zone_of(&self, rel: &str) -> Option<&str> {
        self.zones.iter().map(String::as_str).find(|z| path_matches(z, rel))
    }

    pub fn excluded(&self, rel: &str) -> bool {
        self.exclude.iter().any(|e| path_matches(e, rel))
    }
}

/// `entry` matches `rel` as the whole path, a directory prefix, or a
/// file named with or without the `.rs` extension.
pub(crate) fn path_matches(entry: &str, rel: &str) -> bool {
    match rel.strip_prefix(entry) {
        Some(rest) => rest.is_empty() || rest == ".rs" || rest.starts_with('/'),
        None => false,
    }
}

fn str_array(cfg: &Config, key: &str) -> anyhow::Result<Vec<String>> {
    let arr = cfg
        .get(key)
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow::anyhow!("lint.toml: '{key}' must be an array of strings"))?;
    arr.iter()
        .map(|v| {
            v.as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("lint.toml: '{key}' entries must be strings"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
[detlint]
version = 1
root = "src"

[zones]
deterministic = ["solver", "experiments/sweep"]
exclude = ["solver/frozen.rs"]

[severity]
wall-clock = "deny"
float-cast = "warn"
"#;

    #[test]
    fn parses_zones_and_severities() {
        let m = LintManifest::parse(SAMPLE).unwrap();
        assert_eq!(m.root, "src");
        assert_eq!(m.zones, vec!["solver", "experiments/sweep"]);
        assert_eq!(m.severity_of("wall-clock"), Severity::Deny);
        assert_eq!(m.severity_of("float-cast"), Severity::Warn);
        // Unlisted rules default to deny.
        assert_eq!(m.severity_of("hash-iteration"), Severity::Deny);
    }

    #[test]
    fn zone_matching_covers_dirs_and_extensionless_files() {
        let m = LintManifest::parse(SAMPLE).unwrap();
        assert_eq!(m.zone_of("solver/bb.rs"), Some("solver"));
        assert_eq!(m.zone_of("solver/deep/nested.rs"), Some("solver"));
        assert_eq!(m.zone_of("experiments/sweep.rs"), Some("experiments/sweep"));
        assert_eq!(m.zone_of("experiments/fig2.rs"), None);
        // Prefixes only match at path-component boundaries.
        assert_eq!(m.zone_of("solverx/other.rs"), None);
        assert!(m.excluded("solver/frozen.rs"));
        assert!(!m.excluded("solver/bb.rs"));
    }

    #[test]
    fn unknown_rule_and_bad_severity_rejected() {
        let bad_rule = "[zones]\ndeterministic = [\"solver\"]\n[severity]\nno-such-rule = \"deny\"\n";
        assert!(LintManifest::parse(bad_rule).is_err());
        let bad_sev = "[zones]\ndeterministic = [\"solver\"]\n[severity]\nwall-clock = \"fatal\"\n";
        assert!(LintManifest::parse(bad_sev).is_err());
        let no_zones = "[severity]\nwall-clock = \"deny\"\n";
        assert!(LintManifest::parse(no_zones).is_err());
    }
}
