//! detlint — determinism/correctness static analysis for the
//! deterministic zones (DESIGN.md §9).
//!
//! The repo's core contract — byte-identical `SweepMatrix`/solver output
//! at any worker count — is enforced at runtime by differential tests;
//! this module enforces it at the *source* level: a hand-rolled lexer
//! ([`lexer`]), token-stream rules ([`rules`]), and a committed manifest
//! (`rust/lint.toml`, [`manifest`]) declaring which paths must stay
//! deterministic and how severe each rule is. `hflop lint` walks the
//! tree and exits nonzero on any deny-severity finding.
//!
//! Escape hatch: `// detlint: allow(<rule>) -- <reason>` on the
//! offending line (or the line above) suppresses one rule there; the
//! justification string is mandatory, and a directive that does not
//! parse is itself a finding (`malformed-allow`).

pub mod lexer;
pub mod manifest;
pub mod rules;

use std::path::{Path, PathBuf};

pub use manifest::{LintManifest, Severity};
pub use rules::Finding;

/// One reportable lint hit: a [`Finding`] located in a file, with the
/// manifest severity attached.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub severity: Severity,
    pub rule: &'static str,
    /// Display path (as walked, e.g. `src/solver/bb.rs`).
    pub file: String,
    pub line: u32,
    pub col: u32,
    pub token: String,
    pub note: String,
}

impl Diagnostic {
    /// rustc-style one-line rendering:
    /// `src/solver/bb.rs:148:14: deny[wall-clock] `Instant` — note`.
    pub fn render(&self) -> String {
        format!(
            "{}:{}:{}: {}[{}] `{}` — {}",
            self.file,
            self.line,
            self.col,
            self.severity.label(),
            self.rule,
            self.token,
            self.note
        )
    }
}

/// Result of linting a tree.
#[derive(Debug, Default)]
pub struct LintReport {
    pub diagnostics: Vec<Diagnostic>,
    /// `.rs` files seen under the root.
    pub files_scanned: usize,
    /// Files that fell inside a deterministic zone (and were analyzed).
    pub files_in_zones: usize,
}

impl LintReport {
    pub fn deny_count(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Deny).count()
    }

    pub fn warn_count(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Warn).count()
    }

    /// Full human-readable report (diagnostics + summary line).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&d.render());
            out.push('\n');
        }
        out.push_str(&format!(
            "detlint: {} file(s) scanned, {} in deterministic zones: {} deny, {} warn\n",
            self.files_scanned,
            self.files_in_zones,
            self.deny_count(),
            self.warn_count()
        ));
        out
    }
}

/// Lint the tree under `base` (the directory containing the manifest's
/// `root`, i.e. the crate directory for `root = "src"`).
///
/// Every zone and exclusion entry must match at least one file — a
/// module rename cannot silently drop a zone from coverage.
pub fn lint_tree(m: &LintManifest, base: &Path) -> anyhow::Result<LintReport> {
    let src_root = base.join(&m.root);
    anyhow::ensure!(src_root.is_dir(), "source root {} not found", src_root.display());
    let mut files = Vec::new();
    collect_rs_files(&src_root, &mut files)?;
    files.sort();

    let mut report = LintReport::default();
    let mut zone_used = vec![false; m.zones.len()];
    let mut exclude_used = vec![false; m.exclude.len()];
    for path in &files {
        report.files_scanned += 1;
        let rel = rel_slash_path(path, &src_root)?;
        let Some(zone) = m.zone_of(&rel) else { continue };
        if let Some(zi) = m.zones.iter().position(|z| z == zone) {
            zone_used[zi] = true;
        }
        if m.excluded(&rel) {
            if let Some(ei) = m.exclude.iter().position(|e| manifest::path_matches(e, &rel)) {
                exclude_used[ei] = true;
            }
            continue;
        }
        report.files_in_zones += 1;
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let display = format!("{}/{}", m.root, rel);
        for f in rules::scan(&src) {
            let severity = m.severity_of(f.rule);
            if severity == Severity::Allow {
                continue;
            }
            report.diagnostics.push(Diagnostic {
                severity,
                rule: f.rule,
                file: display.clone(),
                line: f.line,
                col: f.col,
                token: f.token,
                note: f.note,
            });
        }
    }
    for (zi, used) in zone_used.iter().enumerate() {
        anyhow::ensure!(
            used,
            "lint.toml zone '{}' matches no files under {} (renamed module?)",
            m.zones[zi],
            src_root.display()
        );
    }
    for (ei, used) in exclude_used.iter().enumerate() {
        anyhow::ensure!(
            used,
            "lint.toml exclusion '{}' matches no files (renamed module?)",
            m.exclude[ei]
        );
    }
    Ok(report)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    for entry in std::fs::read_dir(dir).map_err(|e| anyhow::anyhow!("{}: {e}", dir.display()))? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn rel_slash_path(path: &Path, root: &Path) -> anyhow::Result<String> {
    let rel = path
        .strip_prefix(root)
        .map_err(|_| anyhow::anyhow!("{} outside source root", path.display()))?;
    let parts: Vec<String> =
        rel.components().map(|c| c.as_os_str().to_string_lossy().into_owned()).collect();
    Ok(parts.join("/"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> LintManifest {
        LintManifest::parse(
            "[zones]\ndeterministic = [\"solver\"]\n[severity]\nfloat-cast = \"warn\"\n",
        )
        .unwrap()
    }

    #[test]
    fn diagnostic_renders_rustc_style() {
        let d = Diagnostic {
            severity: Severity::Deny,
            rule: "wall-clock",
            file: "src/solver/bb.rs".into(),
            line: 148,
            col: 14,
            token: "Instant".into(),
            note: "wall-clock time source".into(),
        };
        assert_eq!(
            d.render(),
            "src/solver/bb.rs:148:14: deny[wall-clock] `Instant` — wall-clock time source"
        );
    }

    #[test]
    fn report_counts_by_severity() {
        let m = manifest();
        let mut r = LintReport::default();
        for (rule, src) in [
            ("wall-clock", "let t = Instant::now();"),
            ("float-cast", "let x = y.floor() as usize;"),
        ] {
            for f in rules::scan(src) {
                r.diagnostics.push(Diagnostic {
                    severity: m.severity_of(f.rule),
                    rule: f.rule,
                    file: "src/solver/x.rs".into(),
                    line: f.line,
                    col: f.col,
                    token: f.token,
                    note: f.note,
                });
                assert_eq!(f.rule, rule);
            }
        }
        assert_eq!(r.deny_count(), 1);
        assert_eq!(r.warn_count(), 1);
        assert!(r.render().contains("1 deny, 1 warn"));
    }
}
