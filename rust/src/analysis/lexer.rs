//! Hand-rolled Rust lexer for `detlint` (in the spirit of
//! `util::tomlmini`: a small, dependency-free parser for exactly the
//! subset the tool needs — no regex, no syn).
//!
//! The token stream carries 1-based line/column positions so rule hits
//! render as rustc-style `file:line:col` diagnostics. Comments and
//! string/char literals are consumed (never tokenized as code), which is
//! what makes the rules immune to `// HashMap` prose; line comments are
//! additionally scanned for `// detlint: allow(<rule>) -- <reason>`
//! escape-hatch directives.

/// Token class. Keywords are ordinary [`TokKind::Ident`]s — the rules
/// match on text (`fn`, `as`, ...) where grammar matters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Int,
    Float,
    Str,
    Char,
    Lifetime,
    Punct,
}

/// One lexed token with its source position (1-based).
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
    pub col: u32,
}

/// A well-formed `// detlint: allow(<rule>) -- <reason>` directive. It
/// suppresses findings for `rule` on its own line and on the next line.
#[derive(Debug, Clone)]
pub struct Allow {
    pub rule: String,
    pub line: u32,
}

/// A comment that mentions `detlint:` but does not parse as a complete
/// allow directive (missing rule or missing justification).
#[derive(Debug, Clone)]
pub struct Malformed {
    pub line: u32,
    pub col: u32,
    pub msg: String,
}

/// Full lexer output for one source file.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub allows: Vec<Allow>,
    pub malformed: Vec<Malformed>,
}

struct Cursor {
    chars: Vec<char>,
    i: usize,
    line: u32,
    col: u32,
}

impl Cursor {
    fn peek(&self, k: usize) -> Option<char> {
        self.chars.get(self.i + k).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.i).copied()?;
        self.i += 1;
        if c == '\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(c)
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Lex one Rust source file.
pub fn lex(src: &str) -> Lexed {
    let mut cur = Cursor { chars: src.chars().collect(), i: 0, line: 1, col: 1 };
    let mut out = Lexed::default();
    while let Some(c) = cur.peek(0) {
        let (line, col) = (cur.line, cur.col);
        if c.is_whitespace() {
            cur.bump();
            continue;
        }
        // Line comment (also covers /// and //! docs): consume to EOL and
        // check for a detlint directive.
        if c == '/' && cur.peek(1) == Some('/') {
            let mut text = String::new();
            while let Some(ch) = cur.peek(0) {
                if ch == '\n' {
                    break;
                }
                text.push(ch);
                cur.bump();
            }
            scan_directive(&text, line, col, &mut out);
            continue;
        }
        // Block comment, nested per Rust rules. Directives are not
        // recognized here — the escape hatch is line-comment only.
        if c == '/' && cur.peek(1) == Some('*') {
            cur.bump();
            cur.bump();
            let mut depth = 1usize;
            while depth > 0 {
                match cur.bump() {
                    None => break,
                    Some('/') if cur.peek(0) == Some('*') => {
                        cur.bump();
                        depth += 1;
                    }
                    Some('*') if cur.peek(0) == Some('/') => {
                        cur.bump();
                        depth -= 1;
                    }
                    _ => {}
                }
            }
            continue;
        }
        // r"..." / r#"..."# raw strings and r#ident raw identifiers.
        if c == 'r' && matches!(cur.peek(1), Some('"') | Some('#')) {
            if cur.peek(1) == Some('#') && cur.peek(2).is_some_and(is_ident_start) {
                cur.bump(); // r
                cur.bump(); // #
                let text = lex_ident_text(&mut cur);
                out.toks.push(Tok { kind: TokKind::Ident, text, line, col });
            } else if raw_string_follows(&cur, 1) {
                cur.bump(); // r
                consume_raw_string(&mut cur);
                out.toks.push(Tok { kind: TokKind::Str, text: String::new(), line, col });
            } else {
                // `r#` not followed by a raw string or ident: lone ident r.
                cur.bump();
                out.toks.push(Tok { kind: TokKind::Ident, text: "r".into(), line, col });
            }
            continue;
        }
        // b"..." byte strings, br"..." raw byte strings, b'.' byte chars.
        if c == 'b' && matches!(cur.peek(1), Some('"') | Some('\'') | Some('r')) {
            if cur.peek(1) == Some('"') {
                cur.bump();
                cur.bump();
                consume_plain_string(&mut cur);
                out.toks.push(Tok { kind: TokKind::Str, text: String::new(), line, col });
                continue;
            }
            if cur.peek(1) == Some('\'') {
                cur.bump();
                cur.bump();
                consume_char_body(&mut cur);
                out.toks.push(Tok { kind: TokKind::Char, text: String::new(), line, col });
                continue;
            }
            if raw_string_follows(&cur, 2) {
                cur.bump(); // b
                cur.bump(); // r
                consume_raw_string(&mut cur);
                out.toks.push(Tok { kind: TokKind::Str, text: String::new(), line, col });
                continue;
            }
            // plain identifier starting with b (e.g. `branch`): fall through.
        }
        if c == '"' {
            cur.bump();
            consume_plain_string(&mut cur);
            out.toks.push(Tok { kind: TokKind::Str, text: String::new(), line, col });
            continue;
        }
        // 'x' char literal vs 'label lifetime.
        if c == '\'' {
            let lifetime = cur.peek(1).is_some_and(is_ident_start) && cur.peek(2) != Some('\'');
            cur.bump();
            if lifetime {
                let text = lex_ident_text(&mut cur);
                out.toks.push(Tok { kind: TokKind::Lifetime, text, line, col });
            } else {
                consume_char_body(&mut cur);
                out.toks.push(Tok { kind: TokKind::Char, text: String::new(), line, col });
            }
            continue;
        }
        if c.is_ascii_digit() {
            let (text, float) = lex_number(&mut cur);
            let kind = if float { TokKind::Float } else { TokKind::Int };
            out.toks.push(Tok { kind, text, line, col });
            continue;
        }
        if is_ident_start(c) {
            let text = lex_ident_text(&mut cur);
            out.toks.push(Tok { kind: TokKind::Ident, text, line, col });
            continue;
        }
        // Everything else: one punctuation char per token (`::` is two
        // `:` tokens — the rules match sequences where that matters).
        cur.bump();
        out.toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line, col });
    }
    out
}

fn lex_ident_text(cur: &mut Cursor) -> String {
    let mut text = String::new();
    while let Some(ch) = cur.peek(0) {
        if !is_ident_continue(ch) {
            break;
        }
        text.push(ch);
        cur.bump();
    }
    text
}

/// After an `r` (offset 1) or `br` (offset 2) prefix: do `#`s followed by
/// `"` — or a bare `"` — come next?
fn raw_string_follows(cur: &Cursor, from: usize) -> bool {
    let mut k = from;
    while cur.peek(k) == Some('#') {
        k += 1;
    }
    cur.peek(k) == Some('"')
}

/// Cursor sits on the `#`s/`"` of a raw string; consume through the
/// matching `"###...` terminator.
fn consume_raw_string(cur: &mut Cursor) {
    let mut hashes = 0usize;
    while cur.peek(0) == Some('#') {
        hashes += 1;
        cur.bump();
    }
    cur.bump(); // opening quote
    loop {
        match cur.bump() {
            None => return,
            Some('"') => {
                let mut k = 0usize;
                while k < hashes && cur.peek(k) == Some('#') {
                    k += 1;
                }
                if k == hashes {
                    for _ in 0..hashes {
                        cur.bump();
                    }
                    return;
                }
            }
            _ => {}
        }
    }
}

/// Cursor sits just past the opening `"`; consume through the closing one.
fn consume_plain_string(cur: &mut Cursor) {
    loop {
        match cur.bump() {
            None | Some('"') => return,
            Some('\\') => {
                cur.bump();
            }
            _ => {}
        }
    }
}

/// Cursor sits just past the opening `'`; consume through the closing one
/// (handles `'\''`, `'\u{1F600}'`, multi-char escapes).
fn consume_char_body(cur: &mut Cursor) {
    loop {
        match cur.bump() {
            None | Some('\'') => return,
            Some('\\') => {
                cur.bump();
            }
            _ => {}
        }
    }
}

/// Cursor sits on a leading digit. Returns (text, is_float). Handles
/// `0x/0o/0b` prefixes, `_` separators, `1.5`, `1.`, `1e-4`, and type
/// suffixes (`1.0f32`, `10usize`); `0..n` ranges and `x.0` tuple fields
/// stay integers.
fn lex_number(cur: &mut Cursor) -> (String, bool) {
    let mut text = String::new();
    let mut float = false;
    if cur.peek(0) == Some('0') && matches!(cur.peek(1), Some('x') | Some('X') | Some('o') | Some('b')) {
        text.push(cur.bump().unwrap());
        text.push(cur.bump().unwrap());
        while cur.peek(0).is_some_and(|ch| ch.is_ascii_alphanumeric() || ch == '_') {
            text.push(cur.bump().unwrap());
        }
        return (text, false);
    }
    while cur.peek(0).is_some_and(|ch| ch.is_ascii_digit() || ch == '_') {
        text.push(cur.bump().unwrap());
    }
    if cur.peek(0) == Some('.') {
        let next = cur.peek(1);
        let range_or_field = next == Some('.') || next.is_some_and(is_ident_start);
        if !range_or_field {
            float = true;
            text.push(cur.bump().unwrap());
            while cur.peek(0).is_some_and(|ch| ch.is_ascii_digit() || ch == '_') {
                text.push(cur.bump().unwrap());
            }
        }
    }
    if matches!(cur.peek(0), Some('e') | Some('E')) {
        let (sign, digit) = (cur.peek(1), cur.peek(2));
        let exp = match sign {
            Some('+') | Some('-') => digit.is_some_and(|ch| ch.is_ascii_digit()),
            other => other.is_some_and(|ch| ch.is_ascii_digit()),
        };
        if exp {
            float = true;
            text.push(cur.bump().unwrap()); // e
            if matches!(cur.peek(0), Some('+') | Some('-')) {
                text.push(cur.bump().unwrap());
            }
            while cur.peek(0).is_some_and(|ch| ch.is_ascii_digit() || ch == '_') {
                text.push(cur.bump().unwrap());
            }
        }
    }
    // Type suffix (f64 marks the literal float even without a dot).
    let mut suffix = String::new();
    while cur.peek(0).is_some_and(is_ident_continue) {
        suffix.push(cur.bump().unwrap());
    }
    if suffix.starts_with('f') {
        float = true;
    }
    text.push_str(&suffix);
    (text, float)
}

/// Recognize `detlint:` directives inside one line comment's text.
fn scan_directive(comment: &str, line: u32, col: u32, out: &mut Lexed) {
    let Some(pos) = comment.find("detlint:") else {
        return;
    };
    let body = comment[pos + "detlint:".len()..].trim();
    let parsed = (|| {
        let inner = body.strip_prefix("allow(")?;
        let close = inner.find(')')?;
        let rule = inner[..close].trim();
        if rule.is_empty() {
            return None;
        }
        let rest = inner[close + 1..].trim();
        let reason = rest.strip_prefix("--")?.trim();
        if reason.is_empty() {
            return None;
        }
        Some(rule.to_string())
    })();
    match parsed {
        Some(rule) => out.allows.push(Allow { rule, line }),
        None => out.malformed.push(Malformed {
            line,
            col,
            msg: "detlint directive must read `// detlint: allow(<rule>) -- <reason>` \
                  (rule and justification both required)"
                .into(),
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).toks.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn idents_keywords_and_puncts() {
        let got = texts("fn f(x: u32) -> u32 { x }");
        assert_eq!(got, vec!["fn", "f", "(", "x", ":", "u32", ")", "-", ">", "u32", "{", "x", "}"]);
    }

    #[test]
    fn positions_are_one_based() {
        let l = lex("ab\n  cd");
        assert_eq!((l.toks[0].line, l.toks[0].col), (1, 1));
        assert_eq!((l.toks[1].line, l.toks[1].col), (2, 3));
    }

    #[test]
    fn comments_do_not_tokenize() {
        let l = lex("a // HashMap here\n/* Instant::now /* nested */ */ b");
        let t: Vec<_> = l.toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(t, vec!["a", "b"]);
    }

    #[test]
    fn strings_and_chars_are_opaque() {
        let l = lex(r#"x("HashMap", 'H', "esc\"aped", b"Instant")"#);
        assert!(l.toks.iter().all(|t| t.text != "HashMap" && t.text != "Instant"));
        assert_eq!(l.toks.iter().filter(|t| t.kind == TokKind::Str).count(), 3);
        assert_eq!(l.toks.iter().filter(|t| t.kind == TokKind::Char).count(), 1);
    }

    #[test]
    fn raw_strings_with_hashes() {
        let l = lex(r##"let s = r#"a "quoted" HashMap"# ; tail"##);
        let t: Vec<_> = l.toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(t, vec!["let", "s", "=", "", ";", "tail"]);
        assert_eq!(l.toks[3].kind, TokKind::Str);
    }

    #[test]
    fn raw_ident_and_lifetime() {
        let l = lex("r#type 'a 'x' <'static>");
        assert_eq!(l.toks[0].kind, TokKind::Ident);
        assert_eq!(l.toks[0].text, "type");
        assert_eq!(l.toks[1].kind, TokKind::Lifetime);
        assert_eq!(l.toks[1].text, "a");
        assert_eq!(l.toks[2].kind, TokKind::Char);
        assert_eq!(l.toks[4].kind, TokKind::Lifetime);
        assert_eq!(l.toks[4].text, "static");
    }

    #[test]
    fn numbers_int_vs_float() {
        let l = lex("1 1.5 1e-4 0x1F 2.0f32 10usize 0..n x.0 3.");
        let kinds: Vec<_> = l
            .toks
            .iter()
            .filter(|t| matches!(t.kind, TokKind::Int | TokKind::Float))
            .map(|t| (t.text.clone(), t.kind))
            .collect();
        assert_eq!(kinds[0], ("1".into(), TokKind::Int));
        assert_eq!(kinds[1], ("1.5".into(), TokKind::Float));
        assert_eq!(kinds[2], ("1e-4".into(), TokKind::Float));
        assert_eq!(kinds[3], ("0x1F".into(), TokKind::Int));
        assert_eq!(kinds[4], ("2.0f32".into(), TokKind::Float));
        assert_eq!(kinds[5], ("10usize".into(), TokKind::Int));
        assert_eq!(kinds[6], ("0".into(), TokKind::Int)); // 0..n stays int
        assert_eq!(kinds[7], ("0".into(), TokKind::Int)); // x.0 tuple field
        assert_eq!(kinds[8], ("3.".into(), TokKind::Float));
    }

    #[test]
    fn allow_directive_parses() {
        let l = lex("let x = 1; // detlint: allow(wall-clock) -- bench-only timer\n");
        assert_eq!(l.allows.len(), 1);
        assert_eq!(l.allows[0].rule, "wall-clock");
        assert_eq!(l.allows[0].line, 1);
        assert!(l.malformed.is_empty());
    }

    #[test]
    fn directive_without_reason_is_malformed() {
        for bad in [
            "// detlint: allow(wall-clock)",
            "// detlint: allow(wall-clock) --",
            "// detlint: allow() -- reason",
            "// detlint: suppress(wall-clock) -- reason",
        ] {
            let l = lex(bad);
            assert!(l.allows.is_empty(), "{bad}");
            assert_eq!(l.malformed.len(), 1, "{bad}");
        }
    }

    #[test]
    fn ordinary_comments_are_not_directives() {
        let l = lex("// detlint is the linter's name\n// nothing to see\n");
        assert!(l.allows.is_empty());
        assert!(l.malformed.is_empty());
    }
}
