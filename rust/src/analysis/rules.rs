//! Token-stream lint rules for the determinism zones.
//!
//! Each rule pattern-matches the [`lexer`] token stream — grammar-aware
//! enough to tell `fn partial_cmp` (a `PartialOrd` impl) from a
//! `partial_cmp` call, and to walk a postfix chain backwards from an
//! `as usize` cast — without being a full parser. `#[cfg(test)]` items
//! are skipped (tests may use hash containers and ad-hoc clocks), and
//! `// detlint: allow(rule) -- reason` directives suppress findings on
//! their own line and the next.

use super::lexer::{self, Lexed, Tok, TokKind};

/// Rule identifiers, in the order findings are reported. The last entry
/// is the meta-rule for unparseable escape-hatch directives. DESIGN.md §9
/// lists exactly this table (drift-guarded by `detlint_contract.rs`).
pub const NAMES: [&str; 6] = [
    "wall-clock",
    "hash-iteration",
    "float-partial-cmp",
    "unseeded-rng",
    "float-cast",
    "malformed-allow",
];

/// All rule names, including the `malformed-allow` meta-rule.
pub fn names() -> &'static [&'static str] {
    &NAMES
}

/// One raw rule hit (severity is attached later from the manifest).
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub line: u32,
    pub col: u32,
    /// The offending token span, e.g. `Instant` or `as usize`.
    pub token: String,
    pub note: String,
}

/// Scan one source file, returning suppression-filtered findings sorted
/// by position.
pub fn scan(src: &str) -> Vec<Finding> {
    let lexed = lexer::lex(src);
    let toks = &lexed.toks;
    let skip = test_mask(toks);
    let mut found = Vec::new();

    for i in 0..toks.len() {
        if skip[i] || toks[i].kind != TokKind::Ident {
            continue;
        }
        let text = toks[i].text.as_str();
        match text {
            "Instant" | "SystemTime" => found.push(at(
                &toks[i],
                "wall-clock",
                text,
                "wall-clock time source in a deterministic zone; route measurement \
                 through util::clock and keep it out of control flow",
            )),
            "HashMap" | "HashSet" => found.push(at(
                &toks[i],
                "hash-iteration",
                text,
                "hash-ordered container in a deterministic zone; iteration order is \
                 unstable — use BTreeMap/BTreeSet or index order",
            )),
            "partial_cmp" => {
                // `fn partial_cmp` is a PartialOrd impl definition, not a
                // float comparison at a call site.
                let is_def = i > 0 && toks[i - 1].text == "fn";
                if !is_def {
                    found.push(at(
                        &toks[i],
                        "float-partial-cmp",
                        text,
                        "partial_cmp returns None on NaN and poisons orderings; \
                         use total_cmp for float comparators",
                    ));
                }
            }
            "thread_rng" | "from_entropy" | "OsRng" => found.push(at(
                &toks[i],
                "unseeded-rng",
                text,
                "entropy-seeded RNG in a deterministic zone; construct \
                 util::rng::Rng with an explicit seed",
            )),
            "random" if path_prefix_is(toks, i, "rand") => found.push(at(
                &toks[i],
                "unseeded-rng",
                "rand::random",
                "rand::random draws from thread-local entropy; construct \
                 util::rng::Rng with an explicit seed",
            )),
            "default" if path_prefix_rng(toks, i) => found.push(at(
                &toks[i],
                "unseeded-rng",
                "Rng::default",
                "Default-constructed RNG hides its seed; construct \
                 util::rng::Rng with an explicit seed",
            )),
            "as" => {
                if let Some(f) = float_cast_finding(toks, i) {
                    found.push(f);
                }
            }
            _ => {}
        }
    }

    for m in &lexed.malformed {
        found.push(Finding {
            rule: "malformed-allow",
            line: m.line,
            col: m.col,
            token: "detlint:".into(),
            note: m.msg.clone(),
        });
    }

    found.retain(|f| {
        !lexed
            .allows
            .iter()
            .any(|a| a.rule == f.rule && (a.line == f.line || a.line + 1 == f.line))
    });
    found.sort_by_key(|f| (f.line, f.col));
    found
}

fn at(tok: &Tok, rule: &'static str, token: &str, note: &str) -> Finding {
    Finding { rule, line: tok.line, col: tok.col, token: token.into(), note: note.into() }
}

/// Is token `i` preceded by `<prefix> ::`?
fn path_prefix_is(toks: &[Tok], i: usize, prefix: &str) -> bool {
    i >= 3 && toks[i - 1].text == ":" && toks[i - 2].text == ":" && toks[i - 3].text == prefix
}

/// Is token `i` preceded by `<SomethingRng> ::`?
fn path_prefix_rng(toks: &[Tok], i: usize) -> bool {
    i >= 3
        && toks[i - 1].text == ":"
        && toks[i - 2].text == ":"
        && toks[i - 3].kind == TokKind::Ident
        && toks[i - 3].text.ends_with("Rng")
}

/// Methods whose receiver is definitely floating point.
const FLOAT_METHODS: [&str; 10] =
    ["floor", "ceil", "round", "trunc", "sqrt", "powf", "powi", "exp", "ln", "fract"];

/// Methods that bound or test the value before the cast, defusing NaN /
/// negative-overflow hazards (`(x).max(0.0) as usize` saturates cleanly).
const GUARD_METHODS: [&str; 5] = ["max", "min", "clamp", "is_nan", "is_finite"];

/// `as usize` on evidently-float expressions without a NaN/range guard.
///
/// Walks the postfix chain backwards from the cast: balanced `(...)` /
/// `[...]` groups plus idents, literals and `.` continue the chain; any
/// other token at depth 0 ends it. Float evidence = a float literal, an
/// `f64`/`f32` ident, or a [`FLOAT_METHODS`] call; a [`GUARD_METHODS`]
/// call anywhere in the chain defuses the finding.
fn float_cast_finding(toks: &[Tok], i: usize) -> Option<Finding> {
    if toks.get(i + 1).map(|t| t.text.as_str()) != Some("usize") {
        return None;
    }
    let mut depth = 0usize;
    let mut evidence = false;
    let mut guarded = false;
    let mut j = i;
    while j > 0 {
        j -= 1;
        let t = &toks[j];
        let txt = t.text.as_str();
        if depth == 0 {
            let continues = matches!(
                t.kind,
                TokKind::Ident | TokKind::Int | TokKind::Float | TokKind::Str
            ) || txt == "."
                || txt == ")"
                || txt == "]";
            if !continues {
                break;
            }
        }
        match txt {
            ")" | "]" => depth += 1,
            "(" | "[" => depth -= 1, // depth > 0 here: balanced group interior
            _ => {}
        }
        if t.kind == TokKind::Float {
            evidence = true;
        }
        if t.kind == TokKind::Ident {
            let is_method = j > 0 && toks[j - 1].text == ".";
            if txt == "f64" || txt == "f32" {
                evidence = true;
            }
            if is_method && FLOAT_METHODS.contains(&txt) {
                evidence = true;
            }
            if is_method && GUARD_METHODS.contains(&txt) {
                guarded = true;
            }
        }
    }
    if evidence && !guarded {
        Some(at(
            &toks[i],
            "float-cast",
            "as usize",
            "float-to-usize cast without a NaN/range guard; NaN casts to 0 and \
             negatives saturate — bound the value (e.g. `.max(0.0)`) first",
        ))
    } else {
        None
    }
}

/// Mark every token inside a `#[cfg(test)]`-gated item (tests may use
/// hash containers, ad-hoc clocks, and partial_cmp freely).
fn test_mask(toks: &[Tok]) -> Vec<bool> {
    let mut skip = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        if !(toks[i].text == "#"
            && tok_text(toks, i + 1) == "["
            && tok_text(toks, i + 2) == "cfg"
            && tok_text(toks, i + 3) == "(")
        {
            i += 1;
            continue;
        }
        // Find the attribute's closing `]`, checking for a `test` ident
        // anywhere inside cfg(...) (covers cfg(test) and cfg(all(test, ..))).
        let mut j = i + 4;
        let mut depth = 1usize; // inside cfg(
        let mut has_test = false;
        let mut has_not = false;
        while j < toks.len() && depth > 0 {
            match toks[j].text.as_str() {
                "(" => depth += 1,
                ")" => depth -= 1,
                "test" => has_test = true,
                "not" => has_not = true,
                _ => {}
            }
            j += 1;
        }
        // `cfg(not(test))` gates production code; never skip it.
        let has_test = has_test && !has_not;
        // j is now just past `)`; expect `]`.
        if tok_text(toks, j) != "]" || !has_test {
            i += 1;
            continue;
        }
        j += 1;
        // Skip any stacked attributes between the cfg and the item.
        while tok_text(toks, j) == "#" && tok_text(toks, j + 1) == "[" {
            let mut d = 0usize;
            while j < toks.len() {
                match toks[j].text.as_str() {
                    "[" => d += 1,
                    "]" => {
                        d -= 1;
                        if d == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
            j += 1;
        }
        // Find the item's opening brace; a `;` first means a braceless
        // item (nothing iterable to skip).
        let mut k = j;
        while k < toks.len() && toks[k].text != "{" && toks[k].text != ";" {
            k += 1;
        }
        if k >= toks.len() || toks[k].text == ";" {
            i = k.min(toks.len());
            continue;
        }
        let mut d = 0usize;
        let mut end = k;
        while end < toks.len() {
            match toks[end].text.as_str() {
                "{" => d += 1,
                "}" => {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                }
                _ => {}
            }
            end += 1;
        }
        let end = (end + 1).min(toks.len());
        for s in skip.iter_mut().take(end).skip(i) {
            *s = true;
        }
        i = end;
    }
    skip
}

fn tok_text(toks: &[Tok], i: usize) -> &str {
    toks.get(i).map(|t| t.text.as_str()).unwrap_or("")
}

/// Re-export for fixture tests: scan plus the raw lexer output.
pub fn lex_for_tests(src: &str) -> Lexed {
    lexer::lex(src)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_hit(src: &str) -> Vec<&'static str> {
        scan(src).into_iter().map(|f| f.rule).collect()
    }

    #[test]
    fn wall_clock_hits_with_position() {
        let f = scan("fn f() { let t0 = std::time::Instant::now(); }");
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "wall-clock");
        assert_eq!(f[0].line, 1);
        assert_eq!(f[0].col, 30);
        assert_eq!(f[0].token, "Instant");
    }

    #[test]
    fn fn_partial_cmp_definition_exempt() {
        assert!(rules_hit("impl PartialOrd for X { fn partial_cmp(&self, o: &X) -> Option<Ordering> { None } }")
            .is_empty());
        assert_eq!(rules_hit("v.sort_by(|a, b| a.partial_cmp(b).unwrap());"), ["float-partial-cmp"]);
    }

    #[test]
    fn cfg_test_items_are_skipped() {
        let src = "
            fn live() {}
            #[cfg(test)]
            mod tests {
                use std::collections::HashMap;
                fn clock() { let _ = std::time::Instant::now(); }
            }
        ";
        assert!(rules_hit(src).is_empty());
    }

    #[test]
    fn allow_directive_suppresses_same_and_next_line() {
        let same = "let t = Instant::now(); // detlint: allow(wall-clock) -- bench timer\n";
        assert!(rules_hit(same).is_empty());
        let next = "// detlint: allow(wall-clock) -- bench timer\nlet t = Instant::now();\n";
        assert!(rules_hit(next).is_empty());
        let wrong_rule = "// detlint: allow(hash-iteration) -- mismatched\nlet t = Instant::now();\n";
        assert_eq!(rules_hit(wrong_rule), ["wall-clock"]);
        let too_far = "// detlint: allow(wall-clock) -- too far away\n\nlet t = Instant::now();\n";
        assert_eq!(rules_hit(too_far), ["wall-clock"]);
    }

    #[test]
    fn float_cast_guard_analysis() {
        assert_eq!(rules_hit("let b = quota.floor() as usize;"), ["float-cast"]);
        assert!(rules_hit("let b = quota.floor().max(0.0) as usize;").is_empty());
        assert_eq!(rules_hit("let h = ((n as f64 * frac).ceil() as usize).min(n);"), ["float-cast"]);
        assert!(rules_hit("let h = ((n as f64 * frac).ceil().max(0.0) as usize).min(n);").is_empty());
        // Integer chains carry no float evidence.
        assert!(rules_hit("let x = (hi - lo + 1) as usize; let y = idx as usize;").is_empty());
    }

    #[test]
    fn unseeded_rng_patterns() {
        assert_eq!(rules_hit("let r = rand::thread_rng();"), ["unseeded-rng"]);
        assert_eq!(rules_hit("let v: f64 = rand::random();"), ["unseeded-rng"]);
        assert_eq!(rules_hit("let r = SmallRng::from_entropy();"), ["unseeded-rng"]);
        assert_eq!(rules_hit("let r = Rng::default();"), ["unseeded-rng"]);
        // An unrelated `random` ident or Default impl is not a hit.
        assert!(rules_hit("let random = 3; let d = Config::default();").is_empty());
    }

    #[test]
    fn hash_iteration_flags_types_not_prose() {
        assert_eq!(
            rules_hit("use std::collections::HashMap; fn f(m: &HashMap<u32, u32>) {}"),
            ["hash-iteration", "hash-iteration"]
        );
        assert!(rules_hit("// HashMap in prose\nlet s = \"HashMap\";").is_empty());
    }
}
