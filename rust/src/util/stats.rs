//! Statistics substrate: summary statistics, confidence intervals,
//! percentiles, histograms, and online (streaming) accumulators.
//!
//! Every figure in the paper reports either a mean with a 95% confidence
//! interval (Fig. 2, Fig. 9) or a distribution summary (Fig. 7, Fig. 8);
//! this module is the single implementation both the experiment harnesses
//! and the bench runner use.

/// Summary of a sample: n, mean, std (sample), min/max, 95% CI half-width.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    /// Half-width of the 95% confidence interval on the mean.
    pub ci95: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of(empty)");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let std = var.sqrt();
        let ci95 = if n < 2 {
            f64::INFINITY // t(0) * 0/1 would be NaN; a single sample pins nothing
        } else {
            t_critical_975(n - 1) * std / (n as f64).sqrt()
        };
        Summary {
            n,
            mean,
            std,
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            ci95,
        }
    }
}

/// Two-sided 97.5% Student-t critical value (for 95% CIs), by degrees of
/// freedom. Table for small df, normal limit beyond.
pub fn t_critical_975(df: usize) -> f64 {
    const TABLE: [f64; 31] = [
        f64::INFINITY, // df = 0 (degenerate)
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262,
        2.228, 2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101,
        2.093, 2.086, 2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052,
        2.048, 2.045, 2.042,
    ];
    if df == 0 {
        return f64::INFINITY;
    }
    if df < TABLE.len() {
        TABLE[df]
    } else if df < 60 {
        2.02
    } else if df < 120 {
        2.00
    } else {
        1.96
    }
}

/// Percentile with linear interpolation (p in [0, 100]). Sorts a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut s = xs.to_vec();
    s.sort_by(f64::total_cmp);
    percentile_sorted(&s, p)
}

/// Percentile on pre-sorted data.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Geometric mean of strictly positive values, computed in log space so
/// wide dynamic ranges (e.g. per-workload benchmark speedups spanning
/// orders of magnitude) don't overflow. Returns NaN on empty input or
/// any non-positive value — callers must not silently average those.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() || xs.iter().any(|&x| !(x > 0.0)) {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Streaming accumulator (Welford). Constant memory; used by the DES to
/// track per-class latency without storing every sample.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let d = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += d * n2 / n;
        self.m2 += other.m2 + d * d * n1 * n2 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n > 1 {
            self.m2 / (self.n - 1) as f64
        } else {
            0.0
        }
    }
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
    pub fn ci95(&self) -> f64 {
        if self.n < 2 {
            return f64::INFINITY;
        }
        t_critical_975((self.n - 1) as usize) * self.std() / (self.n as f64).sqrt()
    }
}

/// Fixed-bin histogram over [lo, hi); out-of-range samples clamp to the
/// edge bins. Used for latency distribution reporting (Fig. 7).
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], total: 0 }
    }

    pub fn push(&mut self, x: f64) {
        let nb = self.bins.len();
        let idx = if x <= self.lo {
            0
        } else if x >= self.hi {
            nb - 1
        } else {
            (((x - self.lo) / (self.hi - self.lo)) * nb as f64) as usize
        };
        self.bins[idx.min(nb - 1)] += 1;
        self.total += 1;
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Bin center for index i.
    pub fn center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        self.lo + w * (i as f64 + 0.5)
    }

    /// ASCII rendering for terminal reports.
    pub fn render(&self, width: usize) -> String {
        let maxc = self.bins.iter().copied().max().unwrap_or(1).max(1);
        let mut out = String::new();
        for (i, &c) in self.bins.iter().enumerate() {
            let bar = "#".repeat((c as usize * width / maxc as usize).max(usize::from(c > 0)));
            out.push_str(&format!("{:>10.2} | {:<width$} {}\n", self.center(i), bar, c));
        }
        out
    }
}

/// Seeded reservoir sampler (Vitter's Algorithm R): a uniform random
/// sample of a stream in O(cap) memory. Replaces unbounded
/// `Vec<f64>` sample retention in the serving simulation so
/// million-request runs keep distribution plots (Fig. 7) without holding
/// every latency in RAM. Deterministic: the kept sample depends only on
/// the seed and the push order. Equality compares the kept sample and
/// stream length (not the generator state).
#[derive(Debug, Clone)]
pub struct Reservoir {
    cap: usize,
    seen: u64,
    items: Vec<f64>,
    rng: crate::util::rng::Rng,
}

impl PartialEq for Reservoir {
    fn eq(&self, other: &Self) -> bool {
        self.cap == other.cap && self.seen == other.seen && self.items == other.items
    }
}

impl Reservoir {
    pub fn new(cap: usize, seed: u64) -> Reservoir {
        assert!(cap > 0, "reservoir capacity must be positive");
        Reservoir {
            cap,
            seen: 0,
            items: Vec::with_capacity(cap.min(4096)),
            rng: crate::util::rng::Rng::new(seed),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.seen += 1;
        if self.items.len() < self.cap {
            self.items.push(x);
            return;
        }
        let j = self.rng.below(self.seen as usize);
        if j < self.cap {
            self.items[j] = x;
        }
    }

    /// Total stream length observed (>= kept length).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.items
    }
}

impl std::ops::Deref for Reservoir {
    type Target = [f64];
    fn deref(&self) -> &[f64] {
        &self.items
    }
}

/// Streaming quantile estimator — the P² algorithm (Jain & Chlamtac,
/// 1985): tracks one quantile with five markers in O(1) memory and O(1)
/// per observation, no samples stored. Exact for the first five
/// observations, then a piecewise-parabolic approximation.
#[derive(Debug, Clone)]
pub struct P2Quantile {
    p: f64,
    /// Marker heights.
    q: [f64; 5],
    /// Marker positions (1-based observation ranks).
    n: [f64; 5],
    /// Desired marker positions.
    np: [f64; 5],
    /// Desired-position increments per observation.
    dn: [f64; 5],
    count: u64,
    /// First five observations (exact phase).
    init: Vec<f64>,
}

impl P2Quantile {
    pub fn new(p: f64) -> P2Quantile {
        assert!((0.0..=1.0).contains(&p), "quantile out of [0,1]");
        P2Quantile {
            p,
            q: [0.0; 5],
            n: [0.0; 5],
            np: [0.0; 5],
            dn: [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0],
            count: 0,
            init: Vec::with_capacity(5),
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn push(&mut self, x: f64) {
        if x.is_nan() {
            return; // a NaN would poison every marker; drop it
        }
        self.count += 1;
        if self.init.len() < 5 {
            self.init.push(x);
            if self.init.len() == 5 {
                let mut s = self.init.clone();
                s.sort_by(f64::total_cmp);
                for i in 0..5 {
                    self.q[i] = s[i];
                    self.n[i] = (i + 1) as f64;
                }
                let p = self.p;
                self.np = [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0];
            }
            return;
        }

        // Cell k (0-based): x lands in [q[k], q[k+1]).
        let k = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x >= self.q[4] {
            if x > self.q[4] {
                self.q[4] = x;
            }
            3
        } else {
            let mut k = 3;
            for i in 1..5 {
                if x < self.q[i] {
                    k = i - 1;
                    break;
                }
            }
            k
        };

        for i in (k + 1)..5 {
            self.n[i] += 1.0;
        }
        for i in 0..5 {
            self.np[i] += self.dn[i];
        }

        // Adjust interior markers toward their desired positions.
        for i in 1..4 {
            let d = self.np[i] - self.n[i];
            if (d >= 1.0 && self.n[i + 1] - self.n[i] > 1.0)
                || (d <= -1.0 && self.n[i - 1] - self.n[i] < -1.0)
            {
                let ds = d.signum();
                let qp = self.parabolic(i, ds);
                self.q[i] = if self.q[i - 1] < qp && qp < self.q[i + 1] {
                    qp
                } else {
                    self.linear(i, ds)
                };
                self.n[i] += ds;
            }
        }
    }

    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let (q, n) = (&self.q, &self.n);
        q[i] + d / (n[i + 1] - n[i - 1])
            * ((n[i] - n[i - 1] + d) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
                + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))
    }

    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = if d > 0.0 { i + 1 } else { i - 1 };
        self.q[i] + d * (self.q[j] - self.q[i]) / (self.n[j] - self.n[i])
    }

    /// Current quantile estimate (NaN before any observation).
    pub fn value(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        if self.init.len() < 5 {
            let mut s = self.init.clone();
            s.sort_by(f64::total_cmp);
            return percentile_sorted(&s, self.p * 100.0);
        }
        self.q[2]
    }
}

/// The latency percentiles the serving reports quote (p50/p90/p99),
/// estimated streaming so outcomes stay O(1) in request count.
#[derive(Debug, Clone)]
pub struct StreamingPercentiles {
    p50: P2Quantile,
    p90: P2Quantile,
    p99: P2Quantile,
}

impl Default for StreamingPercentiles {
    fn default() -> Self {
        Self::new()
    }
}

impl StreamingPercentiles {
    pub fn new() -> StreamingPercentiles {
        StreamingPercentiles {
            p50: P2Quantile::new(0.50),
            p90: P2Quantile::new(0.90),
            p99: P2Quantile::new(0.99),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.p50.push(x);
        self.p90.push(x);
        self.p99.push(x);
    }

    pub fn p50(&self) -> f64 {
        self.p50.value()
    }
    pub fn p90(&self) -> f64 {
        self.p90.value()
    }
    pub fn p99(&self) -> f64 {
        self.p99.value()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        // t(4) = 2.776, sem = sqrt(2.5)/sqrt(5)
        let expect = 2.776 * (2.5f64).sqrt() / (5f64).sqrt();
        assert!((s.ci95 - expect).abs() < 1e-9);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert!(s.ci95.is_infinite());
    }

    #[test]
    fn t_table_monotone_decreasing() {
        let mut prev = f64::INFINITY;
        for df in 1..200 {
            let t = t_critical_975(df);
            assert!(t <= prev + 1e-9, "df={df}");
            prev = t;
        }
        assert!((t_critical_975(1000) - 1.96).abs() < 1e-9);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[5.0]) - 5.0).abs() < 1e-12);
        // Log-space path survives huge dynamic range without overflow.
        let g = geomean(&[1e-300, 1e300]);
        assert!((g - 1.0).abs() < 1e-9, "{g}");
        assert!(geomean(&[]).is_nan());
        assert!(geomean(&[1.0, 0.0]).is_nan());
        assert!(geomean(&[1.0, -2.0]).is_nan());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn online_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| ((i * 37) % 101) as f64).collect();
        let mut o = OnlineStats::new();
        for &x in &xs {
            o.push(x);
        }
        let s = Summary::of(&xs);
        assert!((o.mean() - s.mean).abs() < 1e-9);
        assert!((o.std() - s.std).abs() < 1e-9);
        assert_eq!(o.min(), s.min);
        assert_eq!(o.max(), s.max);
        assert!((o.ci95() - s.ci95).abs() < 1e-9);
    }

    #[test]
    fn online_merge_equals_concat() {
        let a: Vec<f64> = (0..500).map(|i| (i as f64).sin() * 10.0).collect();
        let b: Vec<f64> = (0..300).map(|i| (i as f64).cos() * 5.0 + 2.0).collect();
        let mut oa = OnlineStats::new();
        let mut ob = OnlineStats::new();
        a.iter().for_each(|&x| oa.push(x));
        b.iter().for_each(|&x| ob.push(x));
        oa.merge(&ob);
        let all: Vec<f64> = a.iter().chain(b.iter()).cloned().collect();
        let s = Summary::of(&all);
        assert!((oa.mean() - s.mean).abs() < 1e-9);
        assert!((oa.std() - s.std).abs() < 1e-9);
    }

    #[test]
    fn merge_with_empty() {
        let mut a = OnlineStats::new();
        a.push(1.0);
        a.push(2.0);
        let before = a.clone();
        a.merge(&OnlineStats::new());
        assert_eq!(a.count(), before.count());
        let mut e = OnlineStats::new();
        e.merge(&a);
        assert_eq!(e.count(), 2);
        assert!((e.mean() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts_and_clamping() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.push(-5.0); // clamps to bin 0
        h.push(0.5);
        h.push(9.99);
        h.push(50.0); // clamps to last bin
        assert_eq!(h.total(), 4);
        assert_eq!(h.bins()[0], 2);
        assert_eq!(h.bins()[9], 2);
        assert!((h.center(0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_render_nonempty() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.push(0.1);
        h.push(0.9);
        let r = h.render(20);
        assert_eq!(r.lines().count(), 4);
        assert!(r.contains('#'));
    }

    #[test]
    fn reservoir_keeps_everything_under_cap() {
        let mut r = Reservoir::new(10, 1);
        for i in 0..7 {
            r.push(i as f64);
        }
        assert_eq!(r.as_slice(), &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(r.seen(), 7);
    }

    #[test]
    fn reservoir_bounded_and_deterministic() {
        let stream: Vec<f64> = (0..10_000).map(|i| ((i * 31) % 997) as f64).collect();
        let mut a = Reservoir::new(64, 42);
        let mut b = Reservoir::new(64, 42);
        for &x in &stream {
            a.push(x);
            b.push(x);
        }
        assert_eq!(a.len(), 64);
        assert_eq!(a, b);
        let mut c = Reservoir::new(64, 43);
        for &x in &stream {
            c.push(x);
        }
        assert_ne!(a, c, "different seeds keep different samples");
    }

    #[test]
    fn reservoir_sample_is_representative() {
        // Uniform stream: the kept sample's mean must be near the
        // stream's mean (loose bound; the sampler is unbiased).
        let mut rng = crate::util::rng::Rng::new(5);
        let mut r = Reservoir::new(2000, 9);
        let mut stream_mean = 0.0;
        let n = 100_000;
        for i in 0..n {
            let x = rng.uniform(0.0, 100.0);
            stream_mean += (x - stream_mean) / (i + 1) as f64;
            r.push(x);
        }
        let kept_mean: f64 = r.iter().sum::<f64>() / r.len() as f64;
        assert!((kept_mean - stream_mean).abs() < 3.0, "{kept_mean} vs {stream_mean}");
    }

    #[test]
    fn p2_exact_during_init_phase() {
        let mut q = P2Quantile::new(0.5);
        assert!(q.value().is_nan());
        q.push(10.0);
        assert_eq!(q.value(), 10.0);
        q.push(20.0);
        q.push(30.0);
        assert_eq!(q.value(), 20.0);
    }

    #[test]
    fn p2_tracks_known_quantiles() {
        let mut rng = crate::util::rng::Rng::new(11);
        let mut p50 = P2Quantile::new(0.5);
        let mut p90 = P2Quantile::new(0.9);
        let mut xs = Vec::new();
        for _ in 0..20_000 {
            // Skewed positive stream (latency-like).
            let x = rng.exponential(0.1) + rng.uniform(0.0, 5.0);
            xs.push(x);
            p50.push(x);
            p90.push(x);
        }
        let exact50 = percentile(&xs, 50.0);
        let exact90 = percentile(&xs, 90.0);
        assert!((p50.value() - exact50).abs() / exact50 < 0.05, "{} vs {exact50}", p50.value());
        assert!((p90.value() - exact90).abs() / exact90 < 0.05, "{} vs {exact90}", p90.value());
    }

    #[test]
    fn p2_ignores_nan() {
        let mut q = P2Quantile::new(0.5);
        for x in [1.0, f64::NAN, 2.0, 3.0, f64::NAN, 4.0, 5.0, 6.0, 7.0] {
            q.push(x);
        }
        assert!(q.value().is_finite());
        assert_eq!(q.count(), 7);
    }

    #[test]
    fn streaming_percentiles_ordered() {
        let mut sp = StreamingPercentiles::new();
        let mut rng = crate::util::rng::Rng::new(13);
        for _ in 0..5000 {
            sp.push(rng.uniform(0.0, 1000.0));
        }
        assert!(sp.p50() < sp.p90());
        assert!(sp.p90() < sp.p99());
        assert!((sp.p50() - 500.0).abs() < 50.0, "{}", sp.p50());
    }
}
