//! Deterministic PRNG + sampling distributions.
//!
//! The offline environment ships no `rand` crate, so this module provides
//! the randomness substrate for the whole system: a SplitMix64-seeded
//! xoshiro256++ generator plus the distributions the experiments need
//! (uniform, normal, exponential, Poisson). Everything is reproducible
//! from a single `u64` seed; experiment configs carry seeds explicitly so
//! that every figure regenerates bit-identically.

/// xoshiro256++ PRNG (Blackman & Vigna), seeded via SplitMix64.
///
/// Fast, high-quality, 2^256-1 period; more than enough for simulation.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from the Box-Muller pair.
    cached_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Hash a root seed with coordinate words into a well-mixed child seed
/// (chained SplitMix64). The sweep engine derives every cell's RNG seed
/// from its grid coordinates this way, so a cell's stream depends only on
/// *where it sits in the grid* — never on worker count, scheduling, or
/// completion order. Changing any single coordinate (or the root) yields
/// an unrelated stream.
pub fn mix_seed(root: u64, coords: &[u64]) -> u64 {
    let mut state = root ^ 0xA0761D6478BD642F;
    let mut out = splitmix64(&mut state);
    for &c in coords {
        state = out ^ c.wrapping_mul(0x9E3779B97F4A7C15);
        out = splitmix64(&mut state);
    }
    out
}

impl Rng {
    /// Create a generator from a seed. Different seeds give independent
    /// streams (SplitMix64 scrambles the state initialization).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Derive an independent child stream (for per-entity RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let base = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        Rng::new(base)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses Lemire's rejection-free-ish method.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (with deviate caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.cached_normal.take() {
            return v;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda` (mean 1/lambda). Inter-arrival times
    /// of the Poisson inference request processes.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Poisson-distributed count with mean `lambda`.
    ///
    /// Knuth's method for small lambda, normal approximation above 64
    /// (experiments only need counts; the approximation error there is
    /// far below sampling noise).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 64.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let v = self.normal_ms(lambda, lambda.sqrt()).round();
            if v < 0.0 {
                0
            } else {
                v as u64
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // Partial Fisher-Yates: only the first k positions matter.
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform(8.0, 10.0)).sum::<f64>() / n as f64;
        assert!((mean - 9.0).abs() < 0.02, "{mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "{mean}");
        assert!((var - 1.0).abs() < 0.02, "{var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "{mean}");
    }

    #[test]
    fn poisson_small_lambda_mean_var() {
        let mut r = Rng::new(8);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.poisson(3.5) as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 3.5).abs() < 0.06, "{mean}");
        assert!((var - 3.5).abs() < 0.15, "{var}");
    }

    #[test]
    fn poisson_large_lambda_uses_normal_approx() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let mean = (0..n).map(|_| r.poisson(200.0) as f64).sum::<f64>() / n as f64;
        assert!((mean - 200.0).abs() < 1.0, "{mean}");
    }

    #[test]
    fn poisson_zero() {
        let mut r = Rng::new(10);
        assert_eq!(r.poisson(0.0), 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(12);
        for _ in 0..100 {
            let s = r.sample_indices(20, 5);
            assert_eq!(s.len(), 5);
            let mut t = s.clone();
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), 5);
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(13);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn mix_seed_sensitive_to_every_coordinate() {
        let base = mix_seed(7, &[1, 2, 3]);
        assert_eq!(base, mix_seed(7, &[1, 2, 3]), "deterministic");
        assert_ne!(base, mix_seed(8, &[1, 2, 3]), "root matters");
        assert_ne!(base, mix_seed(7, &[0, 2, 3]));
        assert_ne!(base, mix_seed(7, &[1, 0, 3]));
        assert_ne!(base, mix_seed(7, &[1, 2, 0]));
        assert_ne!(base, mix_seed(7, &[1, 2]), "length matters");
        // Coordinate order matters (a swap is a different cell).
        assert_ne!(mix_seed(7, &[1, 2, 3]), mix_seed(7, &[2, 1, 3]));
    }

    #[test]
    fn mix_seed_low_collision_over_small_grid() {
        // Every cell of an 8x8x8x8 grid gets a distinct seed.
        let mut seen = std::collections::HashSet::new();
        for a in 0..8u64 {
            for b in 0..8u64 {
                for c in 0..8u64 {
                    for d in 0..8u64 {
                        assert!(seen.insert(mix_seed(42, &[a, b, c, d])));
                    }
                }
            }
        }
        assert_eq!(seen.len(), 4096);
    }

    #[test]
    fn int_range_inclusive() {
        let mut r = Rng::new(14);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let v = r.int_range(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }
}
