//! TOML-subset parser for experiment configuration files.
//!
//! Supports the subset the launcher needs: `[section]` and
//! `[section.subsection]` headers, `key = value` with string / integer /
//! float / bool / homogeneous-array values, `#` comments, and blank lines.
//! No multi-line strings, datetimes, or table arrays — configs stay simple
//! by design (see `configs/*.toml`).

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
}

/// A parsed config: flat map from "section.key" (or "key" at top level)
/// to value.
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub entries: BTreeMap<String, Value>,
}

#[derive(Debug, thiserror::Error)]
#[error("config parse error on line {line}: {msg}")]
pub struct ConfigError {
    pub line: usize,
    pub msg: String,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config, ConfigError> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or(ConfigError {
                    line: ln + 1,
                    msg: "unterminated section header".into(),
                })?;
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(ConfigError { line: ln + 1, msg: "empty section".into() });
                }
                continue;
            }
            let eq = line.find('=').ok_or(ConfigError {
                line: ln + 1,
                msg: "expected key = value".into(),
            })?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(ConfigError { line: ln + 1, msg: "empty key".into() });
            }
            let value = parse_value(line[eq + 1..].trim()).map_err(|msg| ConfigError {
                line: ln + 1,
                msg,
            })?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            entries.insert(full, value);
        }
        Ok(Config { entries })
    }

    pub fn load(path: &str) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        Ok(Config::parse(&text)?)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_i64).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.i64_or(key, default as i64).max(0) as usize
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// All keys under a section prefix ("fl." -> "fl.epochs", ...).
    pub fn section(&self, prefix: &str) -> impl Iterator<Item = (&String, &Value)> {
        let want = format!("{prefix}.");
        self.entries.iter().filter(move |(k, _)| k.starts_with(&want))
    }
}

/// Parse one TOML-subset value from a bare string (the `--set key=value`
/// CLI path). Unlike [`Config::parse`] this accepts an *unquoted* word as
/// a string fallback, so `--set preset=steady` works without shell
/// quoting gymnastics; quoted strings, ints, floats, bools and arrays
/// parse exactly as they do in a config file.
pub fn parse_scalar(s: &str) -> Value {
    match parse_value(s.trim()) {
        Ok(v) => v,
        Err(_) => Value::Str(s.trim().to_string()),
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("missing value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("embedded quote in string".into());
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let vals: Result<Vec<Value>, String> =
            inner.split(',').map(|part| parse_value(part.trim())).collect();
        return Ok(Value::Arr(vals?));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    s.parse::<f64>().map(Value::Float).map_err(|_| format!("bad value: {s}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
name = "fig6"          # inline comment
seed = 42

[fl]
clients = 20
epochs = 5
lr = 0.0001
hierarchical = true

[fl.window]
train_weeks = 3.0

[edges]
capacities = [10, 20, 30]
labels = ["a", "b"]
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("name", ""), "fig6");
        assert_eq!(c.i64_or("seed", 0), 42);
        assert_eq!(c.i64_or("fl.clients", 0), 20);
        assert!((c.f64_or("fl.lr", 0.0) - 1e-4).abs() < 1e-12);
        assert!(c.bool_or("fl.hierarchical", false));
        assert!((c.f64_or("fl.window.train_weeks", 0.0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn arrays() {
        let c = Config::parse(SAMPLE).unwrap();
        let caps = c.get("edges.capacities").unwrap().as_arr().unwrap();
        assert_eq!(caps.len(), 3);
        assert_eq!(caps[1].as_i64(), Some(20));
        let labels = c.get("edges.labels").unwrap().as_arr().unwrap();
        assert_eq!(labels[0].as_str(), Some("a"));
    }

    #[test]
    fn defaults_for_missing() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.i64_or("nope", 7), 7);
        assert_eq!(c.str_or("fl.nothing", "d"), "d");
    }

    #[test]
    fn int_vs_float() {
        let c = Config::parse("a = 3\nb = 3.0\n").unwrap();
        assert_eq!(c.get("a").unwrap().as_i64(), Some(3));
        assert_eq!(c.get("b").unwrap().as_i64(), None);
        assert_eq!(c.f64_or("a", 0.0), 3.0);
        assert_eq!(c.f64_or("b", 0.0), 3.0);
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let c = Config::parse("tag = \"a#b\"\n").unwrap();
        assert_eq!(c.str_or("tag", ""), "a#b");
    }

    #[test]
    fn section_iteration() {
        let c = Config::parse(SAMPLE).unwrap();
        let keys: Vec<_> = c.section("fl").map(|(k, _)| k.clone()).collect();
        assert!(keys.contains(&"fl.clients".to_string()));
        assert!(keys.contains(&"fl.window.train_weeks".to_string()));
        assert!(!keys.contains(&"name".to_string()));
    }

    #[test]
    fn errors() {
        assert!(Config::parse("[unterminated\n").is_err());
        assert!(Config::parse("novalue\n").is_err());
        assert!(Config::parse("x = \n").is_err());
        assert!(Config::parse("x = [1, 2\n").is_err());
        assert!(Config::parse("x = \"open\n").is_err());
        let e = Config::parse("ok = 1\nbad\n").unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn parse_scalar_types_and_bare_string_fallback() {
        assert_eq!(parse_scalar("42"), Value::Int(42));
        assert_eq!(parse_scalar("0.25"), Value::Float(0.25));
        assert_eq!(parse_scalar("true"), Value::Bool(true));
        assert_eq!(parse_scalar("\"quoted\""), Value::Str("quoted".into()));
        // Bare words fall back to strings (CLI ergonomics).
        assert_eq!(parse_scalar("steady"), Value::Str("steady".into()));
        assert_eq!(parse_scalar(" hflop-uncap "), Value::Str("hflop-uncap".into()));
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let c = Config::parse("a = -5\nb = 1e-4\nc = -2.5\n").unwrap();
        assert_eq!(c.i64_or("a", 0), -5);
        assert!((c.f64_or("b", 0.0) - 1e-4).abs() < 1e-18);
        assert!((c.f64_or("c", 0.0) + 2.5).abs() < 1e-12);
    }
}
