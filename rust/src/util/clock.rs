//! The crate's single wall-clock site.
//!
//! Every deterministic zone (see `rust/lint.toml` and DESIGN.md §9) is
//! forbidden from touching `std::time` directly: wall time must never
//! influence control flow there, only measurement. Code that needs a
//! duration *reading* goes through [`time_it`] or [`WallClock`], which
//! keeps the `Instant::now` calls in one allowlisted module that both
//! `hflop lint` and clippy's `disallowed-methods` list can pin down.

// Sole sanctioned `Instant::now` call sites (clippy.toml disallows the
// method everywhere else).
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

/// A started stopwatch. Read-only: the elapsed seconds feed `wall_s`
/// style diagnostics and must not steer algorithmic decisions.
#[derive(Debug, Clone, Copy)]
pub struct WallClock {
    t0: Instant,
}

impl WallClock {
    /// Start a stopwatch now.
    pub fn start() -> WallClock {
        WallClock { t0: Instant::now() }
    }

    /// Seconds elapsed since [`WallClock::start`].
    pub fn elapsed_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }
}

/// Measure wall time of `f` in seconds.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let c = WallClock::start();
    let v = f();
    let s = c.elapsed_s();
    (v, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_returns_value_and_positive_time() {
        let (v, t) = time_it(|| (0..1000).sum::<u64>());
        assert_eq!(v, 499500);
        assert!(t >= 0.0);
    }

    #[test]
    fn wall_clock_is_monotonic_nonnegative() {
        let c = WallClock::start();
        let a = c.elapsed_s();
        let b = c.elapsed_s();
        assert!(a >= 0.0);
        assert!(b >= a);
    }
}
