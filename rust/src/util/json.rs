//! Minimal JSON parser + writer.
//!
//! The offline environment has no `serde`/`serde_json`, and the rust
//! coordinator must read the AOT `artifacts/manifest.json` (model shapes,
//! artifact index, oracle vectors) and export experiment results. This is
//! a small recursive-descent parser for the full JSON grammar (objects,
//! arrays, strings with escapes, numbers, bool, null) plus a pretty
//! writer. Not performance-critical: it runs at startup and at report
//! time, never on the request path.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) for stable output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ----- accessors ------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null-ish None when missing.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<f64> (for oracle vectors).
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()?.iter().map(|v| v.as_f64().map(|x| x as f32)).collect()
    }

    // ----- constructors ---------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        // Surrogate pairs.
                        if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone surrogate"));
                            }
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                                low = low * 16
                                    + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                            }
                            code = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                        }
                        s.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-wise.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        self.pos = start + len;
                        if self.pos > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

// ----- writer ---------------------------------------------------------------

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_num(n: f64, out: &mut String) {
    if n.is_finite() {
        if n == n.trunc() && n.abs() < 1e15 {
            out.push_str(&format!("{}", n as i64));
        } else {
            out.push_str(&format!("{n}"));
        }
    } else {
        out.push_str("null"); // JSON has no Inf/NaN
    }
}

impl Json {
    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => escape_into(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(k, out);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        let pad1 = "  ".repeat(indent + 1);
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    out.push_str(&pad1);
                    v.write_pretty(out, indent + 1);
                    if i + 1 < a.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    out.push_str(&pad1);
                    escape_into(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push('}');
            }
            other => other.write_compact(out),
        }
    }

    pub fn to_compact(&self) -> String {
        let mut s = String::new();
        self.write_compact(&mut s);
        s
    }

    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_compact())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        assert_eq!(
            v.path(&["a"]).unwrap().as_arr().unwrap()[2].get("b").unwrap(),
            &Json::Null
        );
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" \\ A é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" \\ A é");
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Json::parse("\"héllo wörld 漢字\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld 漢字");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"arr":[1,2.5,-3],"b":true,"n":null,"s":"x\ny"}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_compact();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj(vec![
            ("x", Json::arr_f64(&[1.0, 2.0])),
            ("y", Json::obj(vec![("z", Json::Str("deep".into()))])),
            ("empty", Json::Arr(vec![])),
        ]);
        let pretty = v.to_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn f64_vec_helpers() {
        let v = Json::parse("[1, 2, 3.5]").unwrap();
        assert_eq!(v.as_f64_vec().unwrap(), vec![1.0, 2.0, 3.5]);
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0f32, 2.0, 3.5]);
        assert!(Json::parse("[1, \"x\"]").unwrap().as_f64_vec().is_none());
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(3.0).to_compact(), "3");
        assert_eq!(Json::Num(3.25).to_compact(), "3.25");
        assert_eq!(Json::Num(f64::NAN).to_compact(), "null");
    }

    #[test]
    fn whitespace_tolerance() {
        let v = Json::parse(" \n\t{ \"a\" :\r[ 1 , 2 ] } ").unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_f64_vec().unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = Json::parse(&text).unwrap();
            assert!(v.path(&["models", "small", "param_count"]).is_some());
        }
    }
}
