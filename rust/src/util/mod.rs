//! Utility substrates built in-tree (the offline environment provides no
//! serde / rand / clap / criterion): JSON, PRNG + distributions,
//! statistics, a scoped worker pool, TOML-subset configs, logging, and a
//! tiny bench timer.

pub mod json;
pub mod logging;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod tomlmini;

use std::time::Instant;

/// Measure wall time of `f` in seconds.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed().as_secs_f64())
}

/// CI smoke mode: `HFLOP_BENCH_SMOKE=1` asks every harness — benches
/// *and* registry experiments — to shrink its workload so workflows can
/// verify the code paths cheaply. `0`, empty, `false`, or unset mean
/// full runs. The bench harness (`benches/bench_common`) and the
/// experiment registry (`experiments::registry::ExperimentCtx::smoke`)
/// share this one predicate.
pub fn smoke_mode() -> bool {
    std::env::var("HFLOP_BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0" && v.to_ascii_lowercase() != "false")
        .unwrap_or(false)
}

#[cfg(test)]
mod tests {
    #[test]
    fn time_it_returns_value_and_positive_time() {
        let (v, t) = super::time_it(|| (0..1000).sum::<u64>());
        assert_eq!(v, 499500);
        assert!(t >= 0.0);
    }
}
