//! Utility substrates built in-tree (the offline environment provides no
//! serde / rand / clap / criterion): JSON, PRNG + distributions,
//! statistics, a scoped worker pool, TOML-subset configs, logging, and a
//! tiny bench timer.

pub mod clock;
pub mod json;
pub mod logging;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod tomlmini;

pub use clock::{time_it, WallClock};

/// CI smoke mode: `HFLOP_BENCH_SMOKE=1` asks every harness — benches
/// *and* registry experiments — to shrink its workload so workflows can
/// verify the code paths cheaply. `0`, empty, `false`, or unset mean
/// full runs. The bench harness (`benches/bench_common`) and the
/// experiment registry (`experiments::registry::ExperimentCtx::smoke`)
/// share this one predicate.
pub fn smoke_mode() -> bool {
    std::env::var("HFLOP_BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0" && v.to_ascii_lowercase() != "false")
        .unwrap_or(false)
}

#[cfg(test)]
mod tests {
    #[test]
    fn smoke_mode_reads_env_shape() {
        // Only shape-check the predicate (env mutation in tests races);
        // the CI workflows exercise the =1 path for real.
        let _ = super::smoke_mode();
    }
}
