//! Scoped worker pool for deterministic fan-out.
//!
//! The sweep engine (`experiments::sweep`) runs many independent
//! simulation cells; this pool fans an indexed job set over
//! `std::thread::scope` workers (no external deps) while keeping the
//! *results* in job order, so callers observe output that is independent
//! of worker count and completion order. Determinism of the work itself
//! is the caller's job (each sweep cell derives its RNG from its grid
//! coordinates, never from execution order).
//!
//! Invariants:
//! * jobs are claimed from a single atomic counter — every index in
//!   `0..n_jobs` runs exactly once;
//! * results land in slot `i` for job `i` regardless of which worker
//!   finished first;
//! * `workers <= 1` (or a single job) runs inline on the caller thread —
//!   the serial loop and the pooled run are the same code path feeding
//!   the same slots;
//! * a panicking job propagates: the scope re-raises the worker panic
//!   after the surviving workers drain.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Reasonable worker-count default: the machine's available parallelism
/// (1 when it cannot be queried).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Map `f` over `0..n_jobs` on up to `workers` scoped threads, returning
/// the results in job order. `f` must be pure with respect to execution
/// order (same index ⇒ same result) for the output to be reproducible
/// across worker counts — which is exactly the contract the sweep
/// determinism tests enforce end to end.
pub fn scoped_map<T, F>(workers: usize, n_jobs: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = workers.clamp(1, n_jobs.max(1));
    if workers == 1 {
        return (0..n_jobs).map(f).collect();
    }

    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    let mut slots: Vec<Option<T>> = (0..n_jobs).map(|_| None).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_jobs {
                    break;
                }
                // The receiver outlives the scope; a send only fails if
                // the collector stopped early (another job panicked) —
                // stop claiming work in that case.
                if tx.send((i, f(i))).is_err() {
                    break;
                }
            });
        }
        // The collector runs on the caller thread inside the scope; the
        // channel closes when the last worker drops its sender.
        drop(tx);
        for (i, r) in rx {
            debug_assert!(slots[i].is_none(), "job {i} delivered twice");
            slots[i] = Some(r);
        }
    });
    slots
        .into_iter()
        .enumerate()
        .map(|(i, s)| s.unwrap_or_else(|| panic!("job {i} produced no result")))
        .collect()
}

/// Map `f` over contiguous index chunks of `0..n_items` (the last chunk
/// may be short) and concatenate the per-chunk outputs in index order.
/// The element-granularity cousin of [`scoped_map`] for jobs that are too
/// cheap to dispatch one at a time (e.g. per-device candidate
/// construction); the same determinism contract applies — chunk
/// boundaries are a pure function of `(n_items, chunk)`, so output is
/// independent of worker count.
pub fn scoped_chunk_map<T, F>(workers: usize, n_items: usize, chunk: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(std::ops::Range<usize>) -> Vec<T> + Sync,
{
    assert!(chunk > 0, "chunk must be positive");
    let n_jobs = n_items.div_ceil(chunk);
    let parts = scoped_map(workers, n_jobs, |job| {
        let lo = job * chunk;
        f(lo..(lo + chunk).min(n_items))
    });
    let mut out = Vec::with_capacity(n_items);
    for part in parts {
        out.extend(part);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn results_are_in_job_order() {
        let out = scoped_map(4, 64, |i| i * i);
        assert_eq!(out, (0..64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let job = |i: usize| (i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let serial = scoped_map(1, 33, job);
        for workers in [2, 3, 8] {
            assert_eq!(scoped_map(workers, 33, job), serial);
        }
    }

    #[test]
    fn slow_first_job_does_not_scramble_output() {
        // Job 0 finishes last; its result must still land in slot 0.
        let out = scoped_map(8, 16, |i| {
            if i == 0 {
                std::thread::sleep(std::time::Duration::from_millis(30));
            }
            i + 100
        });
        assert_eq!(out, (100..116).collect::<Vec<_>>());
    }

    #[test]
    fn every_job_runs_exactly_once() {
        let runs = AtomicUsize::new(0);
        let out = scoped_map(8, 200, |i| {
            runs.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(out.len(), 200);
        assert_eq!(runs.load(Ordering::Relaxed), 200);
    }

    #[test]
    fn zero_jobs_is_empty() {
        let out: Vec<usize> = scoped_map(4, 0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_jobs_is_fine() {
        assert_eq!(scoped_map(32, 3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn chunk_map_matches_element_map() {
        let f = |i: usize| i * 3 + 1;
        let expect: Vec<usize> = (0..103).map(f).collect();
        for (workers, chunk) in [(1, 7), (4, 7), (8, 16), (3, 200)] {
            let got = scoped_chunk_map(workers, 103, chunk, |range| {
                range.map(f).collect::<Vec<_>>()
            });
            assert_eq!(got, expect, "workers={workers} chunk={chunk}");
        }
    }

    #[test]
    fn chunk_map_zero_items_is_empty() {
        let out: Vec<usize> = scoped_chunk_map(4, 0, 8, |r| r.collect());
        assert!(out.is_empty());
    }

    #[test]
    fn chunk_map_ranges_partition_exactly_once() {
        let seen = AtomicUsize::new(0);
        let out = scoped_chunk_map(6, 50, 9, |range| {
            seen.fetch_add(range.len(), Ordering::Relaxed);
            range.collect::<Vec<_>>()
        });
        assert_eq!(out, (0..50).collect::<Vec<_>>());
        assert_eq!(seen.load(Ordering::Relaxed), 50);
    }

    #[test]
    fn default_workers_positive() {
        assert!(default_workers() >= 1);
    }

    #[test]
    #[should_panic]
    fn worker_panic_propagates() {
        scoped_map(4, 8, |i| {
            if i == 5 {
                panic!("boom");
            }
            i
        });
    }
}
