//! Leveled stderr logger implementing the `log` facade.
//!
//! `HFLOP_LOG=debug|info|warn|error` controls verbosity (default info).
//! Timestamps are seconds since logger init — wall-clock formatting is
//! irrelevant for experiment logs, monotonic offsets are what you diff.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};
use once_cell::sync::OnceCell;

static START: OnceCell<Instant> = OnceCell::new();
static INSTALLED: AtomicBool = AtomicBool::new(false);

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.get().map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:10.3}] {lvl} {}: {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Install the logger (idempotent). Level from `HFLOP_LOG` env var.
pub fn init() {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    START.get_or_init(Instant::now);
    let level = match std::env::var("HFLOP_LOG").as_deref() {
        Ok("trace") => LevelFilter::Trace,
        Ok("debug") => LevelFilter::Debug,
        Ok("warn") => LevelFilter::Warn,
        Ok("error") => LevelFilter::Error,
        Ok("off") => LevelFilter::Off,
        _ => LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        log::info!("logging smoke test");
        assert!(INSTALLED.load(Ordering::SeqCst));
    }
}
