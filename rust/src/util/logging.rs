//! Leveled stderr logger (no external `log` facade — the offline image
//! carries no crates beyond the Cargo.toml baseline).
//!
//! `HFLOP_LOG=trace|debug|info|warn|error|off` controls verbosity
//! (default info). Timestamps are seconds since logger init —
//! wall-clock formatting is irrelevant for experiment logs, monotonic
//! offsets are what you diff. Emit lines with [`log_at`] or the
//! [`crate::log_info!`] / [`crate::log_warn!`] macros.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use crate::util::clock::WallClock;

/// Message severity, ordered from most to least verbose.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Trace = 0,
    Debug = 1,
    Info = 2,
    Warn = 3,
    Error = 4,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Trace => "TRACE",
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
        }
    }
}

/// Numeric filter: messages with `level as u8 >= FILTER` are emitted;
/// `OFF` silences everything.
const OFF: u8 = 5;
static FILTER: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: OnceLock<WallClock> = OnceLock::new();

/// Install the logger (idempotent). Level from `HFLOP_LOG` env var.
pub fn init() {
    START.get_or_init(WallClock::start);
    let filter = match std::env::var("HFLOP_LOG").as_deref() {
        Ok("trace") => Level::Trace as u8,
        Ok("debug") => Level::Debug as u8,
        Ok("warn") => Level::Warn as u8,
        Ok("error") => Level::Error as u8,
        Ok("off") => OFF,
        _ => Level::Info as u8,
    };
    FILTER.store(filter, Ordering::SeqCst);
}

/// True when `level` passes the current filter.
pub fn enabled(level: Level) -> bool {
    level as u8 >= FILTER.load(Ordering::SeqCst)
}

/// Emit one line at `level`; called by the `log_*` macros. `init()` need
/// not have run — messages then carry a 0.000 offset and default filter.
pub fn log_at(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.get().map(|c| c.elapsed_s()).unwrap_or(0.0);
    eprintln!("[{t:10.3}] {} {target}: {args}", level.tag());
}

/// Emit an info-level log line, `format!`-style.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log_at(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Emit a warn-level log line, `format!`-style.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log_at(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent_and_filters() {
        init();
        init();
        // Default filter is info: warn passes, trace does not (unless the
        // environment overrides HFLOP_LOG, in which case skip the check).
        if std::env::var("HFLOP_LOG").is_err() {
            assert!(enabled(Level::Warn));
            assert!(!enabled(Level::Trace));
        }
        crate::log_info!("logging smoke test {}", 42);
    }

    #[test]
    fn level_order_matches_severity() {
        assert!(Level::Trace < Level::Debug);
        assert!(Level::Debug < Level::Info);
        assert!(Level::Info < Level::Warn);
        assert!(Level::Warn < Level::Error);
    }
}
