//! # hflop — Inference Load-Aware Orchestration for Hierarchical FL
//!
//! Rust implementation of the system described in *"Inference Load-Aware
//! Orchestration for Hierarchical Federated Learning"* (Lackinger et al.,
//! 2024): the HFLOP optimization problem and solvers, a hierarchical
//! federated-learning runtime whose model compute executes AOT-compiled
//! JAX/Pallas artifacts through PJRT, an inference-serving path with the
//! paper's R1–R3 routing rules, a discrete-event simulator for the
//! latency/cost experiments, and the orchestration layer tying them
//! together.
//!
//! Layer map (see DESIGN.md):
//! * L3 (this crate): coordination — solving HFLOP, running HFL rounds,
//!   routing inference requests, accounting communication costs. Its
//!   numeric substrate is [`core`]: flat dense matrices and
//!   workload/capacity vectors shared by topology, hflop and the solvers.
//! * L2/L1 (python, build time only): the GRU model and its fused Pallas
//!   cell, lowered to `artifacts/*.hlo.txt` which [`runtime`] executes.
//!
//! ## Quickstart
//!
//! ```no_run
//! use hflop::hflop::InstanceBuilder;
//! use hflop::solver::{self, SolveOptions};
//!
//! // 20 devices, 4 candidate edge hosts, the paper's unit-cost topology.
//! let inst = InstanceBuilder::unit_cost(20, 4, 42).build();
//! let sol = solver::solve(&inst, &SolveOptions::exact()).unwrap();
//! println!("optimal HFL communication cost: {}", sol.cost);
//! ```

pub mod analysis;
pub mod cli;
pub mod config;
pub mod core;
pub mod data;
pub mod experiments;
pub mod fl;
pub mod hflop;
pub mod inference;
pub mod metrics;
pub mod orchestrator;
pub mod runtime;
pub mod sim;
pub mod solver;
pub mod topology;
pub mod util;

pub use util::logging::init as init_logging;
