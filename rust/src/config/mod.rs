//! Typed experiment configuration, loadable from TOML-subset files
//! (`configs/*.toml`) with CLI overrides.
//!
//! One [`ExperimentConfig`] drives the launcher: which setup (flat /
//! location-clustered / HFLOP), the FL schedule, the data generator, the
//! serving parameters, and the seeds. Defaults reproduce the paper's
//! §V settings scaled to this testbed (see EXPERIMENTS.md for the
//! scaling notes).

pub mod params;

use crate::fl::FlConfig;
use crate::inference::LatencyModel;
use crate::util::tomlmini::Config;

/// Which clustering policy an experiment runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Setup {
    Flat,
    LocationClustered,
    Hflop,
    HflopUncapacitated,
}

/// Accepted spellings per variant; the first is the canonical `name()`.
const SETUP_SPELLINGS: [(&[&str], Setup); 4] = [
    (&["flat", "vanilla", "centralized"], Setup::Flat),
    (&["location", "hierarchical", "hier"], Setup::LocationClustered),
    (&["hflop"], Setup::Hflop),
    (&["hflop-uncap", "uncapacitated"], Setup::HflopUncapacitated),
];

impl Setup {
    pub const ALL: [Setup; 4] =
        [Setup::Flat, Setup::LocationClustered, Setup::Hflop, Setup::HflopUncapacitated];

    pub fn parse(s: &str) -> anyhow::Result<Setup> {
        for (spellings, setup) in SETUP_SPELLINGS {
            if spellings.contains(&s) {
                return Ok(setup);
            }
        }
        let valid: Vec<String> = SETUP_SPELLINGS.iter().map(|(sp, _)| sp.join("|")).collect();
        anyhow::bail!("unknown setup '{s}' (valid: {})", valid.join(", "))
    }

    pub fn name(&self) -> &'static str {
        match self {
            Setup::Flat => "flat",
            Setup::LocationClustered => "location",
            Setup::Hflop => "hflop",
            Setup::HflopUncapacitated => "hflop-uncap",
        }
    }
}

/// Full experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub setup: Setup,
    /// Model variant from the artifact manifest ("paper" or "small").
    pub variant: String,
    /// FL clients participating (paper: 20, 5 per cluster).
    pub n_clients: usize,
    /// Candidate edge hosts / clusters (paper: 4).
    pub n_edges: usize,
    pub fl: FlConfig,
    pub latency: LatencyModel,
    /// Synthetic-data seed (dataset identity).
    pub data_seed: u64,
    /// Experiment-level seed (sampling, workloads).
    pub seed: u64,
    /// Continual window shift per aggregation round, timesteps.
    pub window_shift: usize,
    /// λ_i sampling range (req/s).
    pub lambda_range: (f64, f64),
    /// r_j sampling range (req/s).
    pub capacity_range: (f64, f64),
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            setup: Setup::Hflop,
            variant: "paper".into(),
            n_clients: 20,
            n_edges: 4,
            fl: FlConfig::default(),
            latency: LatencyModel::default(),
            data_seed: 1234,
            seed: 42,
            window_shift: 288, // one day per aggregation round
            lambda_range: (20.0, 60.0),
            capacity_range: (250.0, 450.0),
        }
    }
}

impl ExperimentConfig {
    /// Load from a TOML-subset file, falling back to defaults per key.
    pub fn from_file(path: &str) -> anyhow::Result<ExperimentConfig> {
        let c = Config::load(path)?;
        Self::from_config(&c)
    }

    pub fn from_config(c: &Config) -> anyhow::Result<ExperimentConfig> {
        let d = ExperimentConfig::default();
        let mut cfg = ExperimentConfig {
            setup: Setup::parse(c.str_or("setup", d.setup.name()))?,
            variant: c.str_or("variant", &d.variant).to_string(),
            n_clients: c.usize_or("clients", d.n_clients),
            n_edges: c.usize_or("edges", d.n_edges),
            data_seed: c.i64_or("data_seed", d.data_seed as i64) as u64,
            seed: c.i64_or("seed", d.seed as i64) as u64,
            window_shift: c.usize_or("window_shift", d.window_shift),
            lambda_range: (
                c.f64_or("lambda.min", d.lambda_range.0),
                c.f64_or("lambda.max", d.lambda_range.1),
            ),
            capacity_range: (
                c.f64_or("capacity.min", d.capacity_range.0),
                c.f64_or("capacity.max", d.capacity_range.1),
            ),
            fl: FlConfig {
                epochs: c.usize_or("fl.epochs", d.fl.epochs),
                batches_per_epoch: c.usize_or("fl.batches_per_epoch", d.fl.batches_per_epoch),
                l: c.usize_or("fl.l", d.fl.l),
                lr: c.f64_or("fl.lr", d.fl.lr as f64) as f32,
                rounds: c.usize_or("fl.rounds", d.fl.rounds),
                eval_every: c.usize_or("fl.eval_every", d.fl.eval_every),
            },
            latency: LatencyModel {
                edge_rtt_ms: (
                    c.f64_or("latency.edge_rtt_min", d.latency.edge_rtt_ms.0),
                    c.f64_or("latency.edge_rtt_max", d.latency.edge_rtt_ms.1),
                ),
                cloud_rtt_ms: (
                    c.f64_or("latency.cloud_rtt_min", d.latency.cloud_rtt_ms.0),
                    c.f64_or("latency.cloud_rtt_max", d.latency.cloud_rtt_ms.1),
                ),
                edge_service_ms: c.f64_or("latency.edge_service_ms", d.latency.edge_service_ms),
                speedup: c.f64_or("latency.speedup", d.latency.speedup),
                stochastic_service: c.bool_or("latency.stochastic", d.latency.stochastic_service),
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&mut self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n_clients > 0, "clients must be positive");
        anyhow::ensure!(self.n_edges > 0, "edges must be positive");
        anyhow::ensure!(self.fl.rounds > 0, "rounds must be positive");
        anyhow::ensure!(self.fl.l > 0, "l must be positive");
        anyhow::ensure!(self.fl.lr > 0.0, "lr must be positive");
        anyhow::ensure!(
            self.lambda_range.0 <= self.lambda_range.1,
            "lambda range inverted"
        );
        anyhow::ensure!(
            self.capacity_range.0 <= self.capacity_range.1,
            "capacity range inverted"
        );
        anyhow::ensure!(
            (0.0..=0.95).contains(&self.latency.speedup),
            "speedup out of [0, 0.95]"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_mirror_paper() {
        let c = ExperimentConfig::default();
        assert_eq!(c.n_clients, 20);
        assert_eq!(c.n_edges, 4);
        assert_eq!(c.fl.l, 2);
        assert_eq!(c.fl.epochs, 5);
        assert_eq!(c.latency.cloud_rtt_ms, (50.0, 100.0));
        assert_eq!(c.latency.edge_rtt_ms, (8.0, 10.0));
    }

    #[test]
    fn parse_setup_aliases() {
        assert_eq!(Setup::parse("flat").unwrap(), Setup::Flat);
        assert_eq!(Setup::parse("hier").unwrap(), Setup::LocationClustered);
        assert_eq!(Setup::parse("hflop").unwrap(), Setup::Hflop);
        assert_eq!(Setup::parse("uncapacitated").unwrap(), Setup::HflopUncapacitated);
        assert!(Setup::parse("wat").is_err());
    }

    #[test]
    fn setup_name_parse_round_trip_all_variants() {
        // Every canonical name must re-parse to the same variant — the
        // CLI, config files and the sweep engine all pass setups by name.
        for setup in Setup::ALL {
            assert_eq!(Setup::parse(setup.name()).unwrap(), setup, "{}", setup.name());
        }
        // Every documented alias parses, and lands on a variant whose
        // canonical name round-trips back to it.
        for (spellings, expected) in SETUP_SPELLINGS {
            for s in spellings {
                let parsed = Setup::parse(s).unwrap();
                assert_eq!(parsed, expected, "alias '{s}'");
                assert_eq!(Setup::parse(parsed.name()).unwrap(), parsed);
            }
        }
    }

    #[test]
    fn setup_parse_error_lists_valid_spellings() {
        let err = Setup::parse("hflopp").unwrap_err().to_string();
        for canonical in ["flat", "location", "hflop", "hflop-uncap", "uncapacitated", "hier"] {
            assert!(err.contains(canonical), "error should list '{canonical}': {err}");
        }
    }

    #[test]
    fn from_config_overrides() {
        let toml = r#"
setup = "flat"
clients = 8
[fl]
rounds = 30
lr = 0.01
[latency]
speedup = 0.5
"#;
        let c = Config::parse(toml).unwrap();
        let e = ExperimentConfig::from_config(&c).unwrap();
        assert_eq!(e.setup, Setup::Flat);
        assert_eq!(e.n_clients, 8);
        assert_eq!(e.fl.rounds, 30);
        assert!((e.fl.lr - 0.01).abs() < 1e-9);
        assert!((e.latency.speedup - 0.5).abs() < 1e-12);
        // Untouched keys keep defaults.
        assert_eq!(e.n_edges, 4);
    }

    #[test]
    fn validation_rejects_bad_values() {
        let c = Config::parse("clients = 0\n").unwrap();
        assert!(ExperimentConfig::from_config(&c).is_err());
        let c = Config::parse("[latency]\nspeedup = 0.99\n").unwrap();
        assert!(ExperimentConfig::from_config(&c).is_err());
    }
}
