//! Schema-checked experiment parameters (DESIGN.md §5).
//!
//! Every registry experiment declares a static `&[ParamSpec]` schema:
//! the full set of keys it understands, each with a typed default and a
//! help line (the generated `hflop experiment <name> --help` renders it
//! verbatim). [`Params::resolve`] merges three layers in precedence
//! order
//!
//! 1. schema defaults (lowest),
//! 2. a TOML-subset config file (`--config run.toml`, parsed by
//!    [`crate::util::tomlmini`]; section headers flatten to dotted keys),
//! 3. `--set key=value` CLI overrides (highest; later wins),
//!
//! and **hard-errors on any key the schema does not declare** — a typo'd
//! parameter fails fast with the list of valid spellings instead of
//! silently running on defaults. Typed getters ([`Params::usize`],
//! [`Params::f64`], …) never miss: resolution already proved every
//! stored value matches its spec's kind.

use std::collections::BTreeMap;

use crate::util::tomlmini::Config;
pub use crate::util::tomlmini::Value;

/// The kind of value a parameter accepts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamKind {
    Int,
    Float,
    Bool,
    Str,
}

impl ParamKind {
    pub fn name(&self) -> &'static str {
        match self {
            ParamKind::Int => "int",
            ParamKind::Float => "float",
            ParamKind::Bool => "bool",
            ParamKind::Str => "string",
        }
    }
}

/// A parameter's typed default (const-constructible for static schemas).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ParamDefault {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(&'static str),
}

impl ParamDefault {
    pub fn kind(&self) -> ParamKind {
        match self {
            ParamDefault::Int(_) => ParamKind::Int,
            ParamDefault::Float(_) => ParamKind::Float,
            ParamDefault::Bool(_) => ParamKind::Bool,
            ParamDefault::Str(_) => ParamKind::Str,
        }
    }

    /// Rendering for `--help` output.
    pub fn render(&self) -> String {
        match self {
            ParamDefault::Int(i) => format!("{i}"),
            ParamDefault::Float(f) => format!("{f}"),
            ParamDefault::Bool(b) => format!("{b}"),
            ParamDefault::Str(s) => format!("\"{s}\""),
        }
    }
}

/// One declared experiment parameter.
#[derive(Debug, Clone, Copy)]
pub struct ParamSpec {
    /// Flat dotted key, e.g. `"seed"` or `"fl.rounds"`.
    pub key: &'static str,
    pub default: ParamDefault,
    pub help: &'static str,
}

/// Resolved parameters: explicitly-set values over schema defaults.
#[derive(Debug, Clone)]
pub struct Params {
    schema: &'static [ParamSpec],
    values: BTreeMap<String, Value>,
}

fn valid_keys(schema: &[ParamSpec]) -> String {
    schema.iter().map(|s| s.key).collect::<Vec<_>>().join(", ")
}

/// Type-check (and lightly coerce) one provided value against its spec.
/// Ints are accepted where floats are expected; a string spec accepts
/// any scalar (stringified) so `--set preset=steady` and `--set m=4`
/// both do the obvious thing.
fn check(spec: &ParamSpec, value: Value) -> anyhow::Result<Value> {
    let ok = match (spec.default.kind(), &value) {
        (ParamKind::Int, Value::Int(_)) => true,
        (ParamKind::Float, Value::Int(i)) => return Ok(Value::Float(*i as f64)),
        (ParamKind::Float, Value::Float(_)) => true,
        (ParamKind::Bool, Value::Bool(_)) => true,
        (ParamKind::Str, Value::Str(_)) => true,
        (ParamKind::Str, Value::Int(i)) => return Ok(Value::Str(format!("{i}"))),
        (ParamKind::Str, Value::Float(f)) => return Ok(Value::Str(format!("{f}"))),
        (ParamKind::Str, Value::Bool(b)) => return Ok(Value::Str(format!("{b}"))),
        _ => false,
    };
    anyhow::ensure!(
        ok,
        "parameter '{}' expects {} (got {:?})",
        spec.key,
        spec.default.kind().name(),
        value
    );
    Ok(value)
}

impl Params {
    /// Schema defaults only.
    pub fn defaults(schema: &'static [ParamSpec]) -> Params {
        Params { schema, values: BTreeMap::new() }
    }

    /// Merge defaults ← config file ← `--set` overrides. Unknown keys in
    /// either layer are a hard error listing the valid spellings.
    pub fn resolve(
        schema: &'static [ParamSpec],
        file: Option<&Config>,
        sets: &[(String, Value)],
    ) -> anyhow::Result<Params> {
        let mut p = Params::defaults(schema);
        if let Some(cfg) = file {
            for (key, value) in &cfg.entries {
                p.set(key, value.clone())?;
            }
        }
        for (key, value) in sets {
            p.set(key, value.clone())?;
        }
        Ok(p)
    }

    /// Set one value, schema-checked. Later calls override earlier ones.
    pub fn set(&mut self, key: &str, value: Value) -> anyhow::Result<()> {
        let spec = self.schema.iter().find(|s| s.key == key).ok_or_else(|| {
            anyhow::anyhow!("unknown parameter '{}' (valid: {})", key, valid_keys(self.schema))
        })?;
        let value = check(spec, value)?;
        self.values.insert(key.to_string(), value);
        Ok(())
    }

    pub fn schema(&self) -> &'static [ParamSpec] {
        self.schema
    }

    /// Was this key explicitly set (file or CLI), or is it on default?
    pub fn is_set(&self, key: &str) -> bool {
        self.values.contains_key(key)
    }

    fn spec(&self, key: &str) -> anyhow::Result<&ParamSpec> {
        self.schema.iter().find(|s| s.key == key).ok_or_else(|| {
            anyhow::anyhow!(
                "experiment read undeclared parameter '{}' (schema bug; valid: {})",
                key,
                valid_keys(self.schema)
            )
        })
    }

    pub fn i64(&self, key: &str) -> anyhow::Result<i64> {
        let spec = self.spec(key)?;
        match (self.values.get(key), spec.default) {
            (Some(Value::Int(i)), _) => Ok(*i),
            (None, ParamDefault::Int(i)) => Ok(i),
            (v, d) => anyhow::bail!("parameter '{key}' is not an int (value {v:?}, default {d:?})"),
        }
    }

    pub fn usize(&self, key: &str) -> anyhow::Result<usize> {
        let i = self.i64(key)?;
        anyhow::ensure!(i >= 0, "parameter '{key}' must be non-negative (got {i})");
        Ok(i as usize)
    }

    /// Seeds are 64-bit hashes; they round-trip through the i64 storage
    /// bit-exactly (the sweep engine stores `cell_seed as i64`).
    pub fn u64(&self, key: &str) -> anyhow::Result<u64> {
        Ok(self.i64(key)? as u64)
    }

    pub fn f64(&self, key: &str) -> anyhow::Result<f64> {
        let spec = self.spec(key)?;
        match (self.values.get(key), spec.default) {
            (Some(Value::Float(f)), _) => Ok(*f),
            (Some(Value::Int(i)), _) => Ok(*i as f64),
            (None, ParamDefault::Float(f)) => Ok(f),
            (None, ParamDefault::Int(i)) => Ok(i as f64),
            (v, d) => anyhow::bail!("parameter '{key}' is not a float (value {v:?}, default {d:?})"),
        }
    }

    pub fn bool(&self, key: &str) -> anyhow::Result<bool> {
        let spec = self.spec(key)?;
        match (self.values.get(key), spec.default) {
            (Some(Value::Bool(b)), _) => Ok(*b),
            (None, ParamDefault::Bool(b)) => Ok(b),
            (v, d) => anyhow::bail!("parameter '{key}' is not a bool (value {v:?}, default {d:?})"),
        }
    }

    pub fn str(&self, key: &str) -> anyhow::Result<String> {
        let spec = self.spec(key)?;
        match (self.values.get(key), spec.default) {
            (Some(Value::Str(s)), _) => Ok(s.clone()),
            (None, ParamDefault::Str(s)) => Ok(s.to_string()),
            (v, d) => {
                anyhow::bail!("parameter '{key}' is not a string (value {v:?}, default {d:?})")
            }
        }
    }

    /// The seed the [`crate::experiments::registry::ExperimentCtx`] RNG
    /// starts from: the `seed` parameter if the schema declares one.
    pub fn seed_or(&self, default: u64) -> u64 {
        if self.schema.iter().any(|s| s.key == "seed") {
            self.u64("seed").unwrap_or(default)
        } else {
            default
        }
    }
}

/// Canonical text form of a value — the sweep engine hashes override
/// sets through this (`experiments::sweep::override_coord`), so it must
/// stay stable.
pub fn value_repr(v: &Value) -> String {
    match v {
        Value::Str(s) => s.clone(),
        Value::Int(i) => format!("{i}"),
        Value::Float(f) => format!("{f}"),
        Value::Bool(b) => format!("{b}"),
        Value::Arr(a) => {
            let parts: Vec<String> = a.iter().map(value_repr).collect();
            format!("[{}]", parts.join(","))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SCHEMA: &[ParamSpec] = &[
        ParamSpec { key: "seed", default: ParamDefault::Int(7), help: "rng seed" },
        ParamSpec { key: "duration_s", default: ParamDefault::Float(120.0), help: "sim horizon" },
        ParamSpec { key: "preset", default: ParamDefault::Str("steady"), help: "scenario preset" },
        ParamSpec { key: "balanced", default: ParamDefault::Bool(true), help: "balanced clients" },
        ParamSpec { key: "fl.rounds", default: ParamDefault::Int(40), help: "fl rounds" },
    ];

    #[test]
    fn defaults_apply_when_unset() {
        let p = Params::defaults(SCHEMA);
        assert_eq!(p.i64("seed").unwrap(), 7);
        assert!((p.f64("duration_s").unwrap() - 120.0).abs() < 1e-12);
        assert_eq!(p.str("preset").unwrap(), "steady");
        assert!(p.bool("balanced").unwrap());
        assert!(!p.is_set("seed"));
    }

    #[test]
    fn file_overrides_defaults_and_sets_override_file() {
        let cfg = Config::parse("seed = 1\npreset = \"edge-failure\"\n[fl]\nrounds = 9\n").unwrap();
        let sets = vec![("seed".to_string(), Value::Int(2))];
        let p = Params::resolve(SCHEMA, Some(&cfg), &sets).unwrap();
        // --set beats the file; the file beats the default.
        assert_eq!(p.i64("seed").unwrap(), 2);
        assert_eq!(p.str("preset").unwrap(), "edge-failure");
        assert_eq!(p.usize("fl.rounds").unwrap(), 9);
        // Untouched keys keep defaults.
        assert!((p.f64("duration_s").unwrap() - 120.0).abs() < 1e-12);
        assert!(p.is_set("seed") && !p.is_set("duration_s"));
    }

    #[test]
    fn later_set_wins() {
        let sets = vec![
            ("seed".to_string(), Value::Int(1)),
            ("seed".to_string(), Value::Int(5)),
        ];
        let p = Params::resolve(SCHEMA, None, &sets).unwrap();
        assert_eq!(p.i64("seed").unwrap(), 5);
    }

    #[test]
    fn unknown_key_is_a_hard_error_in_both_layers() {
        // A typo in the file must not silently run on defaults.
        let cfg = Config::parse("durration_s = 10.0\n").unwrap();
        let err = Params::resolve(SCHEMA, Some(&cfg), &[]).unwrap_err();
        assert!(err.to_string().contains("unknown parameter 'durration_s'"), "{err}");
        assert!(err.to_string().contains("duration_s"), "error must list valid keys: {err}");
        // Same for --set.
        let sets = vec![("sed".to_string(), Value::Int(1))];
        let err = Params::resolve(SCHEMA, None, &sets).unwrap_err();
        assert!(err.to_string().contains("unknown parameter 'sed'"), "{err}");
    }

    #[test]
    fn type_mismatch_rejected_and_int_widens_to_float() {
        let bad = vec![("balanced".to_string(), Value::Int(1))];
        assert!(Params::resolve(SCHEMA, None, &bad).is_err());
        let bad = vec![("seed".to_string(), Value::Float(1.5))];
        assert!(Params::resolve(SCHEMA, None, &bad).is_err());
        // Int where a float is expected widens.
        let ok = vec![("duration_s".to_string(), Value::Int(60))];
        let p = Params::resolve(SCHEMA, None, &ok).unwrap();
        assert!((p.f64("duration_s").unwrap() - 60.0).abs() < 1e-12);
        // Scalars coerce into string params (CLI ergonomics).
        let ok = vec![("preset".to_string(), Value::Int(3))];
        let p = Params::resolve(SCHEMA, None, &ok).unwrap();
        assert_eq!(p.str("preset").unwrap(), "3");
    }

    #[test]
    fn u64_seed_round_trips_through_i64_storage() {
        let big: u64 = 0xDEAD_BEEF_CAFE_F00D;
        let sets = vec![("seed".to_string(), Value::Int(big as i64))];
        let p = Params::resolve(SCHEMA, None, &sets).unwrap();
        assert_eq!(p.u64("seed").unwrap(), big);
    }

    #[test]
    fn undeclared_read_errors() {
        let p = Params::defaults(SCHEMA);
        assert!(p.i64("nope").is_err());
        assert!(p.usize("preset").is_err(), "kind mismatch on read must error");
    }

    #[test]
    fn value_repr_stable() {
        assert_eq!(value_repr(&Value::Int(-3)), "-3");
        assert_eq!(value_repr(&Value::Float(0.25)), "0.25");
        assert_eq!(value_repr(&Value::Bool(true)), "true");
        assert_eq!(value_repr(&Value::Str("x".into())), "x");
        assert_eq!(
            value_repr(&Value::Arr(vec![Value::Int(1), Value::Str("a".into())])),
            "[1,a]"
        );
    }

    #[test]
    fn negative_usize_rejected() {
        let sets = vec![("fl.rounds".to_string(), Value::Int(-1))];
        let p = Params::resolve(SCHEMA, None, &sets).unwrap();
        assert!(p.usize("fl.rounds").is_err());
    }
}
