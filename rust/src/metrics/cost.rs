//! Communication-cost accounting (the paper's §V-D metric): traffic volume
//! over *metered* links only. A device↔edge link is metered iff its
//! communication cost is positive; edge↔cloud links are always metered.
//! Every model exchange counts twice the model size (upload + download),
//! exactly as the paper's absolute numbers do (e.g. flat FL: 20 devices ×
//! 100 rounds × 2 × 594 KB ≈ 2.37 GB).

use crate::hflop::Instance;
use crate::solver::Assignment;

/// Running ledger, fed by the FL round engine.
#[derive(Debug, Clone, Default)]
pub struct CommLedger {
    /// Bytes over metered device↔aggregator links.
    pub local_bytes: u64,
    /// Bytes over aggregator↔cloud (or device↔cloud in flat FL) links.
    pub global_bytes: u64,
    /// Exchange counts for sanity checks.
    pub local_exchanges: u64,
    pub global_exchanges: u64,
}

impl CommLedger {
    pub fn new() -> CommLedger {
        CommLedger::default()
    }

    /// One device↔aggregator model exchange (up + down).
    pub fn device_edge_exchange(&mut self, metered: bool, model_bytes: usize) {
        self.local_exchanges += 1;
        if metered {
            self.local_bytes += 2 * model_bytes as u64;
        }
    }

    /// One aggregator↔cloud (or device↔cloud) model exchange (up + down).
    pub fn cloud_exchange(&mut self, model_bytes: usize) {
        self.global_exchanges += 1;
        self.global_bytes += 2 * model_bytes as u64;
    }

    pub fn total_bytes(&self) -> u64 {
        self.local_bytes + self.global_bytes
    }

    pub fn total_gb(&self) -> f64 {
        self.total_bytes() as f64 / 1e9
    }
}

/// Closed-form predicted traffic for flat (vanilla) FL:
/// every aggregation round, every device exchanges with the cloud.
pub fn flat_fl_bytes(n_devices: usize, rounds: usize, model_bytes: usize) -> u64 {
    2 * (n_devices * rounds * model_bytes) as u64
}

/// Closed-form predicted traffic for an HFL configuration:
/// * every local round: each assigned device exchanges with its edge
///   (metered iff `c_d > 0`);
/// * every `l`-th local round is a global round: each open edge exchanges
///   with the cloud.
///
/// `local_rounds` counts local aggregation rounds total (the paper's
/// "100 aggregation rounds" with `l = 2` → 50 global rounds).
pub fn hfl_bytes(
    inst: &Instance,
    sol: &Assignment,
    local_rounds: usize,
    model_bytes: usize,
) -> u64 {
    let metered_devices = sol
        .assign
        .iter()
        .enumerate()
        .filter(|(i, a)| matches!(a, Some(j) if inst.c_d[*i][*j] > 0.0))
        .count();
    let open_edges = sol.n_open();
    let global_rounds = local_rounds / inst.l.max(1.0) as usize;
    let local = 2 * metered_devices as u64 * local_rounds as u64 * model_bytes as u64;
    let global = 2 * open_edges as u64 * global_rounds as u64 * model_bytes as u64;
    local + global
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hflop::InstanceBuilder;
    use crate::solver::{solve, SolveOptions};

    const KB594: usize = 598_020; // our paper-model serialized size

    #[test]
    fn paper_flat_fl_absolute_number() {
        // §V-D: ~2.37 GB for 20 devices, 100 rounds, 594 KB model.
        let bytes = flat_fl_bytes(20, 100, KB594);
        let gb = bytes as f64 / 1e9;
        assert!((gb - 2.37).abs() < 0.05, "{gb}");
    }

    #[test]
    fn ledger_counts_match_closed_form_flat() {
        let mut ledger = CommLedger::new();
        for _round in 0..100 {
            for _dev in 0..20 {
                ledger.cloud_exchange(KB594);
            }
        }
        assert_eq!(ledger.total_bytes(), flat_fl_bytes(20, 100, KB594));
        assert_eq!(ledger.global_exchanges, 2000);
    }

    #[test]
    fn hfl_bytes_all_free_edges_is_global_only() {
        // If every device sits at a zero-cost edge, local traffic is free;
        // only global rounds are metered — the paper's uncapacitated
        // lower bound (~0.24 GB for 4 edges, 50 global rounds).
        let inst = InstanceBuilder::unit_cost(20, 4, 1).uncapacitated().build();
        let sol = solve(&inst, &SolveOptions::exact()).unwrap().assignment;
        // In the uncapacitated optimum every device uses its free edge.
        let bytes = hfl_bytes(&inst, &sol, 100, KB594);
        let open = sol.n_open() as u64;
        assert_eq!(bytes, 2 * open * 50 * KB594 as u64);
        let gb = bytes as f64 / 1e9;
        assert!(gb < 0.3, "{gb}");
    }

    #[test]
    fn hfl_bytes_counts_metered_devices() {
        let inst = InstanceBuilder::unit_cost(10, 2, 2).build();
        let mut sol = solve(&inst, &SolveOptions::exact()).unwrap().assignment;
        // Force device 0 onto a metered edge (cost 1).
        let j_metered = (0..2).find(|&j| inst.c_d[0][j] > 0.0).unwrap();
        // ensure the target edge is open in the solution for the formula
        sol.open[j_metered] = true;
        let before = hfl_bytes(&inst, &sol, 10, 1000);
        sol.assign[0] = Some(j_metered);
        let after = hfl_bytes(&inst, &sol, 10, 1000);
        assert!(after >= before, "moving to metered link cannot reduce traffic");
    }

    #[test]
    fn ledger_metered_flag_respected() {
        let mut ledger = CommLedger::new();
        ledger.device_edge_exchange(false, 1000);
        assert_eq!(ledger.local_bytes, 0);
        assert_eq!(ledger.local_exchanges, 1);
        ledger.device_edge_exchange(true, 1000);
        assert_eq!(ledger.local_bytes, 2000);
    }

    #[test]
    fn savings_ordering_flat_vs_hflop_vs_uncap() {
        // Reproduce the Fig. 9 ordering on a small instance:
        // flat >= HFLOP >= uncapacitated.
        let n = 20;
        let inst_c = InstanceBuilder::unit_cost(n, 4, 5).build();
        let inst_u = InstanceBuilder::unit_cost(n, 4, 5).uncapacitated().build();
        let sol_c = solve(&inst_c, &SolveOptions::exact()).unwrap().assignment;
        let sol_u = solve(&inst_u, &SolveOptions::exact()).unwrap().assignment;
        let flat = flat_fl_bytes(n, 100, KB594);
        let hflop = hfl_bytes(&inst_c, &sol_c, 100, KB594);
        let uncap = hfl_bytes(&inst_u, &sol_u, 100, KB594);
        assert!(flat > hflop, "flat {flat} hflop {hflop}");
        assert!(hflop >= uncap, "hflop {hflop} uncap {uncap}");
    }
}
