//! Communication-cost accounting (the paper's §V-D metric): traffic volume
//! over *metered* links only. A device↔edge link is metered iff its
//! communication cost is positive; edge↔cloud links are always metered.
//! Every model exchange counts twice the model size (upload + download),
//! exactly as the paper's absolute numbers do (e.g. flat FL: 20 devices ×
//! 100 rounds × 2 × 594 KB ≈ 2.37 GB).

use crate::hflop::Instance;
use crate::solver::Assignment;

/// Running ledger, fed by the FL round engine — and, since the budget
/// control plane (DESIGN.md §11), by the orchestrator's reconfiguration
/// actions. Training traffic and control traffic are separate accounts:
/// [`total_bytes`](CommLedger::total_bytes) stays the paper's §V-D
/// training-plane metric (local + global only), while the three
/// control-plane categories sum into
/// [`control_bytes`](CommLedger::control_bytes).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CommLedger {
    /// Bytes over metered device↔aggregator links.
    pub local_bytes: u64,
    /// Bytes over aggregator↔cloud (or device↔cloud in flat FL) links.
    pub global_bytes: u64,
    /// Exchange counts for sanity checks.
    pub local_exchanges: u64,
    pub global_exchanges: u64,
    /// Control plane: model pushes to devices reassigned by a plan swap.
    pub redistribution_bytes: u64,
    /// Control plane: reconfiguration signalling (reassignment messages,
    /// aggregator open/close churn).
    pub signalling_bytes: u64,
    /// Control plane: monitoring traffic — charged even when the
    /// decision is "do nothing".
    pub telemetry_bytes: u64,
}

impl CommLedger {
    pub fn new() -> CommLedger {
        CommLedger::default()
    }

    /// One device↔aggregator model exchange (up + down).
    pub fn device_edge_exchange(&mut self, metered: bool, model_bytes: usize) {
        self.local_exchanges += 1;
        if metered {
            self.local_bytes += 2 * model_bytes as u64;
        }
    }

    /// One aggregator↔cloud (or device↔cloud) model exchange (up + down).
    pub fn cloud_exchange(&mut self, model_bytes: usize) {
        self.global_exchanges += 1;
        self.global_bytes += 2 * model_bytes as u64;
    }

    /// Full-model pushes to `devices` reassigned devices (download only —
    /// the new plan ships one model copy per displaced device).
    pub fn model_redistribution(&mut self, devices: usize, model_bytes: usize) {
        self.redistribution_bytes =
            self.redistribution_bytes.saturating_add((devices as u64).saturating_mul(model_bytes as u64));
    }

    /// Reconfiguration signalling bytes (reassignment + churn messages).
    pub fn reconfiguration_signal(&mut self, bytes: u64) {
        self.signalling_bytes = self.signalling_bytes.saturating_add(bytes);
    }

    /// Monitoring / decision telemetry bytes.
    pub fn telemetry(&mut self, bytes: u64) {
        self.telemetry_bytes = self.telemetry_bytes.saturating_add(bytes);
    }

    /// Training-plane traffic only (the paper's §V-D metric) — control
    /// categories are deliberately excluded so pre-budget callers see
    /// unchanged numbers.
    pub fn total_bytes(&self) -> u64 {
        self.local_bytes + self.global_bytes
    }

    pub fn total_gb(&self) -> f64 {
        self.total_bytes() as f64 / 1e9
    }

    /// Control-plane traffic: redistribution + signalling + telemetry.
    pub fn control_bytes(&self) -> u64 {
        self.redistribution_bytes
            .saturating_add(self.signalling_bytes)
            .saturating_add(self.telemetry_bytes)
    }

    pub fn control_gb(&self) -> f64 {
        self.control_bytes() as f64 / 1e9
    }
}

/// Closed-form predicted traffic for flat (vanilla) FL:
/// every aggregation round, every device exchanges with the cloud.
pub fn flat_fl_bytes(n_devices: usize, rounds: usize, model_bytes: usize) -> u64 {
    2 * (n_devices * rounds * model_bytes) as u64
}

/// Closed-form predicted traffic for an HFL configuration:
/// * every local round: each assigned device exchanges with its edge
///   (metered iff `c_d > 0`);
/// * every `l`-th local round is a global round: each open edge exchanges
///   with the cloud.
///
/// `local_rounds` counts local aggregation rounds total (the paper's
/// "100 aggregation rounds" with `l = 2` → 50 global rounds).
pub fn hfl_bytes(
    inst: &Instance,
    sol: &Assignment,
    local_rounds: usize,
    model_bytes: usize,
) -> u64 {
    let metered_devices = sol
        .assign
        .iter()
        .enumerate()
        .filter(|(i, a)| matches!(a, Some(j) if inst.c_d[*i][*j] > 0.0))
        .count();
    let open_edges = sol.n_open();
    let global_rounds = local_rounds / inst.l.max(1.0) as usize;
    let local = 2 * metered_devices as u64 * local_rounds as u64 * model_bytes as u64;
    let global = 2 * open_edges as u64 * global_rounds as u64 * model_bytes as u64;
    local + global
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hflop::InstanceBuilder;
    use crate::solver::{solve, SolveOptions};

    const KB594: usize = 598_020; // our paper-model serialized size

    #[test]
    fn paper_flat_fl_absolute_number() {
        // §V-D: ~2.37 GB for 20 devices, 100 rounds, 594 KB model.
        let bytes = flat_fl_bytes(20, 100, KB594);
        let gb = bytes as f64 / 1e9;
        assert!((gb - 2.37).abs() < 0.05, "{gb}");
    }

    #[test]
    fn ledger_counts_match_closed_form_flat() {
        let mut ledger = CommLedger::new();
        for _round in 0..100 {
            for _dev in 0..20 {
                ledger.cloud_exchange(KB594);
            }
        }
        assert_eq!(ledger.total_bytes(), flat_fl_bytes(20, 100, KB594));
        assert_eq!(ledger.global_exchanges, 2000);
    }

    #[test]
    fn hfl_bytes_all_free_edges_is_global_only() {
        // If every device sits at a zero-cost edge, local traffic is free;
        // only global rounds are metered — the paper's uncapacitated
        // lower bound (~0.24 GB for 4 edges, 50 global rounds).
        let inst = InstanceBuilder::unit_cost(20, 4, 1).uncapacitated().build();
        let sol = solve(&inst, &SolveOptions::exact()).unwrap().assignment;
        // In the uncapacitated optimum every device uses its free edge.
        let bytes = hfl_bytes(&inst, &sol, 100, KB594);
        let open = sol.n_open() as u64;
        assert_eq!(bytes, 2 * open * 50 * KB594 as u64);
        let gb = bytes as f64 / 1e9;
        assert!(gb < 0.3, "{gb}");
    }

    #[test]
    fn hfl_bytes_counts_metered_devices() {
        let inst = InstanceBuilder::unit_cost(10, 2, 2).build();
        let mut sol = solve(&inst, &SolveOptions::exact()).unwrap().assignment;
        // Force device 0 onto a metered edge (cost 1).
        let j_metered = (0..2).find(|&j| inst.c_d[0][j] > 0.0).unwrap();
        // ensure the target edge is open in the solution for the formula
        sol.open[j_metered] = true;
        let before = hfl_bytes(&inst, &sol, 10, 1000);
        sol.assign[0] = Some(j_metered);
        let after = hfl_bytes(&inst, &sol, 10, 1000);
        assert!(after >= before, "moving to metered link cannot reduce traffic");
    }

    #[test]
    fn ledger_metered_flag_respected() {
        let mut ledger = CommLedger::new();
        ledger.device_edge_exchange(false, 1000);
        assert_eq!(ledger.local_bytes, 0);
        assert_eq!(ledger.local_exchanges, 1);
        ledger.device_edge_exchange(true, 1000);
        assert_eq!(ledger.local_bytes, 2000);
    }

    #[test]
    fn control_categories_do_not_leak_into_training_totals() {
        // Backward compatibility: `total_bytes()`/`total_gb()` are the
        // paper's training-plane metric and must ignore the budget
        // control plane's categories entirely.
        let mut ledger = CommLedger::new();
        ledger.device_edge_exchange(true, 1000);
        ledger.cloud_exchange(1000);
        let training = ledger.total_bytes();
        ledger.model_redistribution(5, 2000);
        ledger.reconfiguration_signal(512);
        ledger.telemetry(64);
        assert_eq!(ledger.total_bytes(), training, "control traffic leaked into total_bytes");
        assert_eq!(ledger.redistribution_bytes, 10_000);
        assert_eq!(ledger.signalling_bytes, 512);
        assert_eq!(ledger.telemetry_bytes, 64);
        assert_eq!(ledger.control_bytes(), 10_000 + 512 + 64);
        assert!((ledger.control_gb() - (10_576.0 / 1e9)).abs() < 1e-12);
    }

    #[test]
    fn control_categories_accumulate_independently() {
        let mut ledger = CommLedger::new();
        ledger.telemetry(10);
        ledger.telemetry(10);
        assert_eq!(ledger.telemetry_bytes, 20);
        assert_eq!(ledger.redistribution_bytes, 0);
        assert_eq!(ledger.signalling_bytes, 0);
        // A do-nothing decision is telemetry only: the other categories
        // stay untouched until an actual reconfiguration is charged.
        ledger.model_redistribution(0, 1_000_000);
        assert_eq!(ledger.redistribution_bytes, 0);
        assert_eq!(ledger.control_bytes(), 20);
    }

    #[test]
    fn flat_vs_hfl_crossover_in_metered_device_count() {
        // HFL beats flat FL only while enough device↔edge links are
        // free. With n=20 devices, m=2 open edges, l=2 and k metered
        // devices: hfl(k) = 2·k·R·B + 2·2·(R/2)·B, flat = 2·20·R·B —
        // so the crossover sits exactly at k = 19.
        let inst = InstanceBuilder::unit_cost(20, 2, 3).uncapacitated().build();
        assert_eq!(inst.l, 2.0, "builder default l drifted; crossover arithmetic assumes l=2");
        let rounds = 100;
        let mb = 1000;
        let free_edge = |i: usize| (0..2).find(|&j| inst.c_d[i][j] == 0.0).unwrap();
        let metered_edge = |i: usize| (0..2).find(|&j| inst.c_d[i][j] > 0.0).unwrap();
        let hfl_with_k_metered = |k: usize| {
            let mut sol = Assignment::empty(20, 2);
            sol.open = vec![true, true];
            for i in 0..20 {
                sol.assign[i] = Some(if i < k { metered_edge(i) } else { free_edge(i) });
            }
            hfl_bytes(&inst, &sol, rounds, mb)
        };
        let flat = flat_fl_bytes(20, rounds, mb);
        for k in 1..=20 {
            assert!(
                hfl_with_k_metered(k) > hfl_with_k_metered(k - 1),
                "hfl traffic must grow with metered device count (k={k})"
            );
        }
        assert!(hfl_with_k_metered(18) < flat, "below the crossover HFL must win");
        assert_eq!(hfl_with_k_metered(19), flat, "k=19 is the exact crossover point");
        assert!(hfl_with_k_metered(20) > flat, "past the crossover flat FL wins");
    }

    #[test]
    fn savings_ordering_flat_vs_hflop_vs_uncap() {
        // Reproduce the Fig. 9 ordering on a small instance:
        // flat >= HFLOP >= uncapacitated.
        let n = 20;
        let inst_c = InstanceBuilder::unit_cost(n, 4, 5).build();
        let inst_u = InstanceBuilder::unit_cost(n, 4, 5).uncapacitated().build();
        let sol_c = solve(&inst_c, &SolveOptions::exact()).unwrap().assignment;
        let sol_u = solve(&inst_u, &SolveOptions::exact()).unwrap().assignment;
        let flat = flat_fl_bytes(n, 100, KB594);
        let hflop = hfl_bytes(&inst_c, &sol_c, 100, KB594);
        let uncap = hfl_bytes(&inst_u, &sol_u, 100, KB594);
        assert!(flat > hflop, "flat {flat} hflop {hflop}");
        assert!(hflop >= uncap, "hflop {hflop} uncap {uncap}");
    }
}
