//! Result export: CSV and JSON writers for experiment outputs.
//!
//! Every experiment harness writes machine-readable results under
//! `results/` so EXPERIMENTS.md numbers are regenerable and diffable.

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Version stamp for every machine-readable artifact this module writes:
/// registry `Report` JSON summaries, the `SweepMatrix` JSON and
/// `BENCH_sweep.json`. Bump on any breaking change to those layouts and
/// record the migration in DESIGN.md §8. History: v1 = the unstamped
/// PR 3 formats; v2 = the registry-era formats (stamp added, report
/// summaries wrapped in `{experiment, schema_version, summary}`).
pub const SCHEMA_VERSION: u32 = 2;

/// A named CSV table inside an experiment's artifact bundle; `name` is
/// the output file stem (`<name>.csv`).
#[derive(Debug, Clone)]
pub struct Table {
    pub name: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<f64>>,
}

impl Table {
    pub fn new(name: &str, header: &[&str], rows: Vec<Vec<f64>>) -> Table {
        Table {
            name: name.to_string(),
            header: header.iter().map(|h| h.to_string()).collect(),
            rows,
        }
    }
}

/// Writes experiment results into a directory (creating it).
pub struct ResultsWriter {
    dir: PathBuf,
}

impl ResultsWriter {
    pub fn new(dir: impl AsRef<Path>) -> anyhow::Result<ResultsWriter> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir)?;
        Ok(ResultsWriter { dir })
    }

    pub fn default_dir() -> anyhow::Result<ResultsWriter> {
        let dir = std::env::var("HFLOP_RESULTS").unwrap_or_else(|_| "results".into());
        Self::new(dir)
    }

    pub fn path(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }

    /// Write a CSV file: header row + rows of f64 cells.
    pub fn write_csv(
        &self,
        name: &str,
        header: &[&str],
        rows: &[Vec<f64>],
    ) -> anyhow::Result<PathBuf> {
        let path = self.path(name);
        let mut f = fs::File::create(&path)?;
        writeln!(f, "{}", header.join(","))?;
        for row in rows {
            let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
            writeln!(f, "{}", cells.join(","))?;
        }
        Ok(path)
    }

    /// Write pretty JSON.
    pub fn write_json(&self, name: &str, value: &Json) -> anyhow::Result<PathBuf> {
        let path = self.path(name);
        fs::write(&path, value.to_pretty())?;
        Ok(path)
    }

    /// Write one named table as `<table.name>.csv`.
    pub fn write_table(&self, table: &Table) -> anyhow::Result<PathBuf> {
        let header: Vec<&str> = table.header.iter().map(String::as_str).collect();
        self.write_csv(&format!("{}.csv", table.name), &header, &table.rows)
    }
}

/// Render an ASCII table (for terminal experiment reports).
pub fn ascii_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (c, cell) in row.iter().enumerate().take(ncol) {
            widths[c] = widths[c].max(cell.len());
        }
    }
    let sep: String = widths.iter().map(|w| format!("+{}", "-".repeat(w + 2))).collect::<String>() + "+";
    let fmt_row = |cells: &[String]| -> String {
        let mut s = String::new();
        for (c, w) in widths.iter().enumerate() {
            let empty = String::new();
            let cell = cells.get(c).unwrap_or(&empty);
            s.push_str(&format!("| {cell:>w$} "));
        }
        s + "|"
    };
    let mut out = String::new();
    out.push_str(&sep);
    out.push('\n');
    out.push_str(&fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    out.push('\n');
    out.push_str(&sep);
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row));
        out.push('\n');
    }
    out.push_str(&sep);
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let tmp = std::env::temp_dir().join("hflop_test_results");
        let w = ResultsWriter::new(&tmp).unwrap();
        let p = w
            .write_csv("t.csv", &["a", "b"], &[vec![1.0, 2.5], vec![3.0, 4.0]])
            .unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert_eq!(text, "a,b\n1,2.5\n3,4\n");
    }

    #[test]
    fn json_write() {
        let tmp = std::env::temp_dir().join("hflop_test_results");
        let w = ResultsWriter::new(&tmp).unwrap();
        let p = w
            .write_json("t.json", &Json::obj(vec![("x", Json::Num(1.0))]))
            .unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn ascii_table_renders() {
        let t = ascii_table(
            &["setup", "ms"],
            &[
                vec!["flat".into(), "79.07".into()],
                vec!["hflop".into(), "9.89".into()],
            ],
        );
        assert!(t.contains("flat"));
        assert!(t.contains("9.89"));
        // sep, header, sep, 2 data rows, sep
        assert_eq!(t.lines().count(), 6);
    }
}
