//! Metrics: communication-cost accounting (Fig. 9), MSE curves (Fig. 6),
//! latency recording (Fig. 7/8), and result export.

pub mod cost;
pub mod export;

pub use cost::CommLedger;
pub use export::ResultsWriter;

/// Per-(round, client) MSE curve storage for Fig. 6-style plots.
#[derive(Debug, Clone, Default)]
pub struct MseCurves {
    /// `curves[client]` = per-round MSE of that client.
    pub curves: Vec<Vec<f32>>,
}

impl MseCurves {
    pub fn new(n_clients: usize) -> MseCurves {
        MseCurves { curves: vec![Vec::new(); n_clients] }
    }

    pub fn push(&mut self, client: usize, mse: f32) {
        self.curves[client].push(mse);
    }

    pub fn n_rounds(&self) -> usize {
        self.curves.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Mean MSE across clients at a round.
    pub fn mean_at(&self, round: usize) -> f32 {
        let vals: Vec<f32> = self
            .curves
            .iter()
            .filter_map(|c| c.get(round).copied())
            .collect();
        if vals.is_empty() {
            return f32::NAN;
        }
        vals.iter().sum::<f32>() / vals.len() as f32
    }

    /// Mean MSE over the final `k` rounds (convergence-level metric).
    pub fn converged_mean(&self, k: usize) -> f32 {
        let n = self.n_rounds();
        if n == 0 {
            return f32::NAN;
        }
        let lo = n.saturating_sub(k);
        let vals: Vec<f32> = (lo..n).map(|r| self.mean_at(r)).collect();
        vals.iter().sum::<f32>() / vals.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mse_curves_mean() {
        let mut c = MseCurves::new(2);
        c.push(0, 1.0);
        c.push(1, 3.0);
        c.push(0, 0.5);
        c.push(1, 1.5);
        assert_eq!(c.n_rounds(), 2);
        assert!((c.mean_at(0) - 2.0).abs() < 1e-6);
        assert!((c.mean_at(1) - 1.0).abs() < 1e-6);
        assert!((c.converged_mean(1) - 1.0).abs() < 1e-6);
        assert!((c.converged_mean(2) - 1.5).abs() < 1e-6);
    }

    #[test]
    fn empty_curves_nan() {
        let c = MseCurves::new(3);
        assert!(c.mean_at(0).is_nan());
        assert!(c.converged_mean(5).is_nan());
    }
}
