//! Sliding-window sample extraction + the continual-learning schedule.
//!
//! The paper's continual setup (§V-B2): "we use 3 weeks of training and
//! 1 week of validation. After each aggregation round, the global time
//! shifts for some timestamps so that the number of training and test
//! samples stays the same, but it is shifted to simulate time passing."

use super::{Normalizer, STEPS_PER_WEEK};
use crate::util::rng::Rng;

/// Shape of supervised samples: `seq_len` past readings -> next reading.
#[derive(Debug, Clone, Copy)]
pub struct WindowSpec {
    pub seq_len: usize,
    pub horizon: usize, // steps ahead of the window end to predict (>= 1)
}

impl Default for WindowSpec {
    fn default() -> Self {
        WindowSpec { seq_len: 12, horizon: 1 }
    }
}

/// Extract (x, y) windows from a normalized series segment.
/// Returns (xs, ys) where xs is `[n_samples * seq_len]` row-major and ys is
/// `[n_samples]`.
pub fn make_windows(series: &[f32], spec: WindowSpec) -> (Vec<f32>, Vec<f32>) {
    let need = spec.seq_len + spec.horizon;
    if series.len() < need {
        return (Vec::new(), Vec::new());
    }
    let n = series.len() - need + 1;
    let mut xs = Vec::with_capacity(n * spec.seq_len);
    let mut ys = Vec::with_capacity(n);
    for start in 0..n {
        xs.extend_from_slice(&series[start..start + spec.seq_len]);
        ys.push(series[start + spec.seq_len + spec.horizon - 1]);
    }
    (xs, ys)
}

/// The continual-learning window: a training span and a validation span
/// that both shift forward by `shift` timesteps every aggregation round.
#[derive(Debug, Clone)]
pub struct ContinualWindow {
    pub train_len: usize,
    pub val_len: usize,
    pub shift: usize,
    pub offset: usize,
    pub total_len: usize,
}

impl ContinualWindow {
    /// Paper defaults: 3 weeks train, 1 week validation.
    pub fn paper(total_len: usize, shift: usize) -> ContinualWindow {
        ContinualWindow {
            train_len: 3 * STEPS_PER_WEEK,
            val_len: STEPS_PER_WEEK,
            shift,
            offset: 0,
            total_len,
        }
    }

    pub fn new(train_len: usize, val_len: usize, shift: usize, total_len: usize) -> Self {
        assert!(train_len + val_len <= total_len, "window longer than series");
        ContinualWindow { train_len, val_len, shift, offset: 0, total_len }
    }

    /// Current train span `[lo, hi)`.
    pub fn train_range(&self) -> (usize, usize) {
        (self.offset, self.offset + self.train_len)
    }

    /// Current validation span `[lo, hi)` (immediately after training span).
    pub fn val_range(&self) -> (usize, usize) {
        (self.offset + self.train_len, self.offset + self.train_len + self.val_len)
    }

    /// Whether another shift still fits inside the series.
    pub fn can_advance(&self) -> bool {
        self.offset + self.shift + self.train_len + self.val_len <= self.total_len
    }

    /// Advance one aggregation round ("the global time shifts").
    /// Returns false (and stays put) when the series is exhausted.
    pub fn advance(&mut self) -> bool {
        if !self.can_advance() {
            return false;
        }
        self.offset += self.shift;
        true
    }

    /// How many rounds of `advance()` remain.
    pub fn rounds_remaining(&self) -> usize {
        if self.shift == 0 {
            return usize::MAX;
        }
        (self.total_len - (self.train_len + self.val_len) - self.offset) / self.shift
    }
}

/// A client-side dataset: normalized windows for the current continual
/// span, batched for the AOT train-step artifact.
#[derive(Debug, Clone)]
pub struct ClientData {
    pub spec: WindowSpec,
    pub normalizer: Normalizer,
    /// Full normalized series for this client's sensor.
    pub series: Vec<f32>,
}

impl ClientData {
    /// Normalize with stats fit on the *initial* training span only
    /// (no leakage from future data).
    pub fn new(raw: &[f32], spec: WindowSpec, fit_range: (usize, usize)) -> ClientData {
        let normalizer = Normalizer::fit(&raw[fit_range.0..fit_range.1]);
        ClientData {
            spec,
            normalizer,
            series: raw.iter().map(|&x| normalizer.transform(x)).collect(),
        }
    }

    /// Windows over a span; returns (xs row-major, ys).
    pub fn windows(&self, range: (usize, usize)) -> (Vec<f32>, Vec<f32>) {
        make_windows(&self.series[range.0..range.1.min(self.series.len())], self.spec)
    }

    /// Sample `batch` random windows from a span (for stochastic local
    /// epochs). Returns row-major xs `[batch * seq_len]` and ys `[batch]`.
    pub fn sample_batch(
        &self,
        range: (usize, usize),
        batch: usize,
        rng: &mut Rng,
    ) -> (Vec<f32>, Vec<f32>) {
        let span = &self.series[range.0..range.1.min(self.series.len())];
        let need = self.spec.seq_len + self.spec.horizon;
        assert!(span.len() >= need, "span too short for one window");
        let n = span.len() - need + 1;
        let mut xs = Vec::with_capacity(batch * self.spec.seq_len);
        let mut ys = Vec::with_capacity(batch);
        for _ in 0..batch {
            let s = rng.below(n);
            xs.extend_from_slice(&span[s..s + self.spec.seq_len]);
            ys.push(span[s + self.spec.seq_len + self.spec.horizon - 1]);
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_count_and_alignment() {
        let series: Vec<f32> = (0..20).map(|i| i as f32).collect();
        let (xs, ys) = make_windows(&series, WindowSpec { seq_len: 4, horizon: 1 });
        // 20 - 5 + 1 = 16 samples
        assert_eq!(ys.len(), 16);
        assert_eq!(xs.len(), 16 * 4);
        // First window [0,1,2,3] -> 4
        assert_eq!(&xs[..4], &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(ys[0], 4.0);
        // Last window [15,16,17,18] -> 19
        assert_eq!(ys[15], 19.0);
    }

    #[test]
    fn windows_multi_horizon() {
        let series: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let (_, ys) = make_windows(&series, WindowSpec { seq_len: 3, horizon: 3 });
        assert_eq!(ys[0], 5.0); // [0,1,2] -> idx 2+3 = 5
        assert_eq!(ys.len(), 10 - 6 + 1);
    }

    #[test]
    fn windows_short_series_empty() {
        let (xs, ys) = make_windows(&[1.0, 2.0], WindowSpec { seq_len: 4, horizon: 1 });
        assert!(xs.is_empty() && ys.is_empty());
    }

    #[test]
    fn continual_paper_defaults() {
        let w = ContinualWindow::paper(17 * STEPS_PER_WEEK, 288);
        assert_eq!(w.train_len, 3 * STEPS_PER_WEEK);
        assert_eq!(w.val_len, STEPS_PER_WEEK);
        let (lo, hi) = w.train_range();
        assert_eq!((lo, hi), (0, 3 * STEPS_PER_WEEK));
        let (vlo, vhi) = w.val_range();
        assert_eq!(vlo, hi);
        assert_eq!(vhi - vlo, STEPS_PER_WEEK);
    }

    #[test]
    fn continual_advance_shifts_and_stops() {
        let mut w = ContinualWindow::new(100, 20, 10, 200);
        let mut rounds = 0;
        while w.advance() {
            rounds += 1;
        }
        // offset can go up to 200-120 = 80 => 8 shifts of 10.
        assert_eq!(rounds, 8);
        assert_eq!(w.offset, 80);
        assert!(!w.can_advance());
        // advance() past the end must not move the window
        assert!(!w.advance());
        assert_eq!(w.offset, 80);
    }

    #[test]
    fn rounds_remaining_counts_down() {
        let mut w = ContinualWindow::new(100, 20, 10, 200);
        assert_eq!(w.rounds_remaining(), 8);
        w.advance();
        assert_eq!(w.rounds_remaining(), 7);
    }

    #[test]
    fn sample_sizes_stay_constant_under_shift() {
        // The paper: "the number of training and test samples stays the
        // same, but it is shifted".
        let raw: Vec<f32> = (0..500).map(|i| (i as f32 * 0.1).sin()).collect();
        let cd = ClientData::new(&raw, WindowSpec { seq_len: 6, horizon: 1 }, (0, 300));
        let mut w = ContinualWindow::new(300, 100, 25, 500);
        let (x0, y0) = cd.windows(w.train_range());
        w.advance();
        let (x1, y1) = cd.windows(w.train_range());
        assert_eq!(x0.len(), x1.len());
        assert_eq!(y0.len(), y1.len());
        assert_ne!(x0, x1); // but the content shifted
    }

    #[test]
    fn client_data_normalized_on_fit_range() {
        let mut raw: Vec<f32> = vec![10.0; 100];
        raw.extend(vec![50.0; 100]); // later regime differs
        let cd = ClientData::new(&raw, WindowSpec::default(), (0, 100));
        // Fit range mean is 10 -> those normalize to ~0.
        assert!(cd.series[..100].iter().all(|&z| z.abs() < 1e-2));
        assert!(cd.series[150] > 1.0); // later data clearly above
    }

    #[test]
    fn sample_batch_shapes_and_determinism() {
        let raw: Vec<f32> = (0..300).map(|i| (i as f32 * 0.05).cos()).collect();
        let cd = ClientData::new(&raw, WindowSpec { seq_len: 8, horizon: 1 }, (0, 200));
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let (x1, y1) = cd.sample_batch((0, 200), 16, &mut r1);
        let (x2, y2) = cd.sample_batch((0, 200), 16, &mut r2);
        assert_eq!(x1.len(), 16 * 8);
        assert_eq!(y1.len(), 16);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn sample_batch_targets_consistent_with_windows() {
        let raw: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let cd = ClientData::new(&raw, WindowSpec { seq_len: 4, horizon: 1 }, (0, 100));
        let mut rng = Rng::new(1);
        let (xs, ys) = cd.sample_batch((0, 100), 8, &mut rng);
        for b in 0..8 {
            let window = &xs[b * 4..(b + 1) * 4];
            // y must be the normalized value right after the window.
            let last = window[3];
            let y = ys[b];
            // raw series is linear => normalized series is linear with the
            // same slope everywhere.
            let step = cd.series[1] - cd.series[0];
            assert!((y - (last + step)).abs() < 1e-4);
        }
    }

    #[test]
    #[should_panic(expected = "span too short")]
    fn sample_batch_panics_on_short_span() {
        let raw: Vec<f32> = (0..50).map(|i| i as f32).collect();
        let cd = ClientData::new(&raw, WindowSpec { seq_len: 12, horizon: 1 }, (0, 50));
        let mut rng = Rng::new(2);
        cd.sample_batch((0, 10), 4, &mut rng);
    }
}
