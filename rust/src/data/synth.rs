//! Synthetic METR-LA: traffic-speed time series with the structure the
//! paper's experiments rely on.
//!
//! What must be preserved (DESIGN.md §3):
//! * **Spatial cluster structure** — sensors along highway "corridors" in
//!   the LA bounding box, so location-based clustering (Fig. 5) finds
//!   meaningful groups.
//! * **Non-IID per-sensor series** — each sensor has its own free-flow
//!   speed, rush-hour depth, and noise level.
//! * **Temporal periodicity** — daily and weekly seasonality with weekday
//!   rush hours (the structure a GRU can learn).
//! * **Drift** — slowly evolving congestion patterns over the 4-month
//!   horizon, which is what makes *continual* retraining beneficial
//!   (§V-B1) and what the paper attributes Fig. 6's late-round MSE
//!   oscillation to ("one reason for this increase may be the changing
//!   data").
//! * **Correlated congestion waves** — corridor-level shocks shared by
//!   neighbouring sensors (accidents/closures), giving realistic
//!   heteroscedastic noise.

use super::{STEPS_PER_DAY, STEPS_PER_WEEK};
use crate::topology::geo::{GeoPoint, BBox, LA_BBOX};
use crate::util::rng::Rng;

/// Generator configuration. Defaults mirror METR-LA's published shape:
/// 207 sensors, 5-minute cadence, 34,272 timestamps (= 17 weeks).
#[derive(Debug, Clone)]
pub struct SynthConfig {
    pub n_sensors: usize,
    pub n_steps: usize,
    pub n_corridors: usize,
    pub bbox: BBox,
    pub seed: u64,
    /// Strength of the slow drift component (0 disables; 1 = default).
    pub drift_scale: f64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            n_sensors: 207,
            n_steps: 34_272,
            n_corridors: 6,
            bbox: LA_BBOX,
            seed: 1234,
            drift_scale: 1.0,
        }
    }
}

impl SynthConfig {
    /// A small config for unit tests (seconds, not minutes, to generate).
    pub fn tiny(seed: u64) -> SynthConfig {
        SynthConfig {
            n_sensors: 12,
            n_steps: 2 * STEPS_PER_WEEK,
            n_corridors: 3,
            seed,
            ..Default::default()
        }
    }
}

/// The generated dataset: sensor locations + speed series (mph),
/// row-major `[sensor][timestep]`.
#[derive(Debug, Clone)]
pub struct TrafficDataset {
    pub locations: Vec<GeoPoint>,
    pub series: Vec<Vec<f32>>,
    pub corridor_of: Vec<usize>,
    pub n_steps: usize,
}

/// Per-sensor latent parameters.
struct SensorProfile {
    free_flow: f64,     // free-flow speed, mph
    rush_depth_am: f64, // fractional speed drop in the AM peak
    rush_depth_pm: f64,
    weekend_lift: f64,  // weekend speeds are closer to free flow
    noise_std: f64,
    phase_jitter: f64,  // shifts the peak time slightly per sensor
}

/// Smooth bump centered at `center` hours with width `width` hours.
fn rush_bump(hour: f64, center: f64, width: f64) -> f64 {
    let d = (hour - center) / width;
    (-0.5 * d * d).exp()
}

pub fn generate(cfg: &SynthConfig) -> TrafficDataset {
    assert!(cfg.n_sensors > 0 && cfg.n_steps > 0 && cfg.n_corridors > 0);
    let mut rng = Rng::new(cfg.seed);

    // --- corridor geometry: straight highway segments across the bbox ----
    let (lat0, lat1, lon0, lon1) = cfg.bbox;
    let corridors: Vec<(GeoPoint, GeoPoint)> = (0..cfg.n_corridors)
        .map(|_| {
            let a = GeoPoint {
                lat: rng.uniform(lat0, lat1),
                lon: rng.uniform(lon0, lon1),
            };
            let b = GeoPoint {
                lat: rng.uniform(lat0, lat1),
                lon: rng.uniform(lon0, lon1),
            };
            (a, b)
        })
        .collect();

    // --- sensor placement along corridors, with jitter ------------------
    let mut locations = Vec::with_capacity(cfg.n_sensors);
    let mut corridor_of = Vec::with_capacity(cfg.n_sensors);
    for i in 0..cfg.n_sensors {
        let c = i % cfg.n_corridors;
        let (a, b) = corridors[c];
        let t = rng.f64();
        let mut p = a.lerp(b, t);
        p.lat += rng.normal() * 0.004;
        p.lon += rng.normal() * 0.004;
        locations.push(p);
        corridor_of.push(c);
    }

    // --- per-sensor profiles ---------------------------------------------
    let profiles: Vec<SensorProfile> = (0..cfg.n_sensors)
        .map(|_| SensorProfile {
            free_flow: rng.uniform(55.0, 70.0),
            rush_depth_am: rng.uniform(0.25, 0.55),
            rush_depth_pm: rng.uniform(0.30, 0.60),
            weekend_lift: rng.uniform(0.5, 0.9),
            noise_std: rng.uniform(1.5, 4.0),
            phase_jitter: rng.normal() * 0.4,
        })
        .collect();

    // --- corridor-level congestion shocks ---------------------------------
    // Each corridor gets an AR(1)-smoothed shock process; shared by all its
    // sensors (correlated congestion waves).
    let mut shocks = vec![vec![0.0f64; cfg.n_steps]; cfg.n_corridors];
    for shock in shocks.iter_mut() {
        let mut s = 0.0f64;
        let mut shock_rng = rng.fork(0xC0FFEE);
        for v in shock.iter_mut() {
            // Occasionally a shock event begins; it decays geometrically.
            if shock_rng.chance(0.001) {
                s -= shock_rng.uniform(5.0, 20.0); // mph drop
            }
            s *= 0.97;
            *v = s;
        }
    }

    // --- drift: slowly evolving rush-hour intensity ------------------------
    // A low-frequency sinusoid + linear trend per corridor; makes stale
    // models go stale (the continual-learning signal).
    let drift_period = (8 * STEPS_PER_WEEK) as f64;

    let mut series = Vec::with_capacity(cfg.n_sensors);
    for (i, prof) in profiles.iter().enumerate() {
        let mut sensor_rng = rng.fork(i as u64 + 1);
        let corridor = corridor_of[i];
        let corridor_phase = corridor as f64 * 0.9;
        let mut xs = Vec::with_capacity(cfg.n_steps);
        for t in 0..cfg.n_steps {
            let step_of_day = t % STEPS_PER_DAY;
            let hour = step_of_day as f64 / 12.0;
            let day = (t / STEPS_PER_DAY) % 7;
            let weekend = day >= 5;

            // Drift multiplies rush depth: congestion worsens/lightens over
            // months.
            let drift = 1.0
                + cfg.drift_scale
                    * (0.35 * ((t as f64 / drift_period) * std::f64::consts::TAU
                        + corridor_phase)
                        .sin()
                        + 0.10 * (t as f64 / cfg.n_steps as f64));

            let am = prof.rush_depth_am
                * drift
                * rush_bump(hour, 8.0 + prof.phase_jitter, 1.4);
            let pm = prof.rush_depth_pm
                * drift
                * rush_bump(hour, 17.5 + prof.phase_jitter, 1.8);
            let mut depth = am + pm;
            if weekend {
                depth *= 1.0 - prof.weekend_lift;
            }
            depth = depth.clamp(0.0, 0.9);

            let mean = prof.free_flow * (1.0 - depth);
            let v = mean + shocks[corridor][t] + sensor_rng.normal() * prof.noise_std;
            xs.push(v.clamp(0.0, 80.0) as f32);
        }
        series.push(xs);
    }

    TrafficDataset { locations, series, corridor_of, n_steps: cfg.n_steps }
}

impl TrafficDataset {
    pub fn n_sensors(&self) -> usize {
        self.series.len()
    }

    /// Mean speed of sensor `i` over timestep range `[lo, hi)`.
    pub fn mean_speed(&self, i: usize, lo: usize, hi: usize) -> f64 {
        let s = &self.series[i][lo..hi];
        s.iter().map(|&x| x as f64).sum::<f64>() / s.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> TrafficDataset {
        generate(&SynthConfig::tiny(7))
    }

    #[test]
    fn shapes() {
        let d = tiny();
        assert_eq!(d.n_sensors(), 12);
        assert_eq!(d.locations.len(), 12);
        assert!(d.series.iter().all(|s| s.len() == d.n_steps));
    }

    #[test]
    fn deterministic() {
        let a = generate(&SynthConfig::tiny(7));
        let b = generate(&SynthConfig::tiny(7));
        assert_eq!(a.series, b.series);
        let c = generate(&SynthConfig::tiny(8));
        assert_ne!(a.series, c.series);
    }

    #[test]
    fn speeds_physical() {
        let d = tiny();
        for s in &d.series {
            assert!(s.iter().all(|&x| (0.0..=80.0).contains(&x)));
        }
    }

    #[test]
    fn locations_near_bbox() {
        let d = tiny();
        let (lat0, lat1, lon0, lon1) = LA_BBOX;
        for p in &d.locations {
            // jitter may step slightly outside; allow a small margin
            assert!(p.lat > lat0 - 0.05 && p.lat < lat1 + 0.05);
            assert!(p.lon > lon0 - 0.05 && p.lon < lon1 + 0.05);
        }
    }

    #[test]
    fn weekday_rush_slower_than_night() {
        let d = tiny();
        // Hour 8 (AM peak) vs hour 3 (night), averaged over weekdays of
        // week 1 and all sensors.
        let mut rush = 0.0;
        let mut night = 0.0;
        let mut cnt = 0.0;
        for s in &d.series {
            for day in 0..5 {
                let base = day * STEPS_PER_DAY;
                rush += s[base + 8 * 12] as f64;
                night += s[base + 3 * 12] as f64;
                cnt += 1.0;
            }
        }
        assert!(rush / cnt < night / cnt - 5.0, "rush {} night {}", rush / cnt, night / cnt);
    }

    #[test]
    fn weekend_faster_than_weekday_rush() {
        let d = tiny();
        let mut wd = 0.0;
        let mut we = 0.0;
        for s in &d.series {
            // Monday 8am vs Saturday 8am (day 5).
            wd += s[8 * 12] as f64;
            we += s[5 * STEPS_PER_DAY + 8 * 12] as f64;
        }
        assert!(we > wd, "weekend {} weekday {}", we, wd);
    }

    #[test]
    fn drift_changes_distribution_over_time() {
        // With drift on, early vs late rush-hour means must differ
        // noticeably more than with drift off.
        let mut cfg = SynthConfig::tiny(3);
        cfg.n_steps = 8 * STEPS_PER_WEEK;
        let with_drift = generate(&cfg);
        cfg.drift_scale = 0.0;
        let without = generate(&cfg);

        let delta = |d: &TrafficDataset| -> f64 {
            let early = d.mean_speed(0, 0, STEPS_PER_WEEK);
            let late = d.mean_speed(0, 7 * STEPS_PER_WEEK, 8 * STEPS_PER_WEEK);
            (early - late).abs()
        };
        assert!(delta(&with_drift) > delta(&without));
    }

    #[test]
    fn corridor_assignment_round_robin() {
        let d = tiny();
        assert_eq!(d.corridor_of[0], 0);
        assert_eq!(d.corridor_of[1], 1);
        assert_eq!(d.corridor_of[3], 0);
        assert!(d.corridor_of.iter().all(|&c| c < 3));
    }

    #[test]
    fn default_config_is_metr_la_shaped() {
        let cfg = SynthConfig::default();
        assert_eq!(cfg.n_sensors, 207);
        assert_eq!(cfg.n_steps, 34_272);
    }
}
