//! Traffic data substrate: the synthetic METR-LA substitute, per-sensor
//! normalization, sliding-window sample extraction, and the continual-
//! learning window scheduler.
//!
//! The real METR-LA dataset (207 loop detectors, 4 months of 5-minute
//! readings, 34,272 timestamps — §V-A) is not available offline; `synth`
//! generates a statistically analogous dataset preserving the properties
//! the paper's experiments exercise. See DESIGN.md §3 for the
//! substitution rationale.

pub mod synth;
pub mod window;

pub use synth::{SynthConfig, TrafficDataset};
pub use window::{make_windows, ContinualWindow, WindowSpec};

/// Timestamps per hour at the METR-LA 5-minute cadence.
pub const STEPS_PER_HOUR: usize = 12;
/// Timestamps per day.
pub const STEPS_PER_DAY: usize = 24 * STEPS_PER_HOUR;
/// Timestamps per week.
pub const STEPS_PER_WEEK: usize = 7 * STEPS_PER_DAY;

/// Per-sensor z-score normalization statistics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Normalizer {
    pub mean: f32,
    pub std: f32,
}

impl Normalizer {
    pub fn fit(xs: &[f32]) -> Normalizer {
        assert!(!xs.is_empty());
        let n = xs.len() as f64;
        let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n;
        let var = xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
        Normalizer { mean: mean as f32, std: (var.sqrt().max(1e-6)) as f32 }
    }

    #[inline]
    pub fn transform(&self, x: f32) -> f32 {
        (x - self.mean) / self.std
    }

    #[inline]
    pub fn inverse(&self, z: f32) -> f32 {
        z * self.std + self.mean
    }

    pub fn transform_vec(&self, xs: &[f32]) -> Vec<f32> {
        xs.iter().map(|&x| self.transform(x)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalizer_roundtrip() {
        let xs = [10.0f32, 20.0, 30.0, 40.0];
        let nz = Normalizer::fit(&xs);
        for &x in &xs {
            let z = nz.transform(x);
            assert!((nz.inverse(z) - x).abs() < 1e-4);
        }
    }

    #[test]
    fn normalizer_zero_mean_unit_std() {
        let xs: Vec<f32> = (0..1000).map(|i| (i % 37) as f32).collect();
        let nz = Normalizer::fit(&xs);
        let zs = nz.transform_vec(&xs);
        let mean: f64 = zs.iter().map(|&z| z as f64).sum::<f64>() / zs.len() as f64;
        let var: f64 = zs.iter().map(|&z| (z as f64 - mean).powi(2)).sum::<f64>() / zs.len() as f64;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn normalizer_constant_series_no_nan() {
        let nz = Normalizer::fit(&[5.0f32; 10]);
        let z = nz.transform(5.0);
        assert!(z.is_finite());
        assert!(z.abs() < 1e-3);
    }

    #[test]
    fn cadence_constants() {
        assert_eq!(STEPS_PER_DAY, 288);
        assert_eq!(STEPS_PER_WEEK, 2016);
        // Paper: 4 months ≈ 34,272 timestamps.
        assert!((17 * STEPS_PER_WEEK) as i64 - 34_272i64 == 0);
    }
}
