//! §V-B1 — the continual-learning benefit table: a model trained once on
//! an initial window vs. a model continuously retrained as the window
//! slides, both evaluated on later (drifted) data. Paper numbers
//! (centralized GRU on METR-LA): static MSE 0.04470 vs retrained
//! 0.04284 — continual retraining wins.

use crate::config::params::ParamSpec;
use crate::data::synth::{generate, SynthConfig};
use crate::data::window::{ClientData, ContinualWindow, WindowSpec};
use crate::data::STEPS_PER_WEEK;
use crate::fl::{MockRuntime, ModelRuntime};
use crate::util::rng::Rng;

use super::registry::{runtime_gate, Experiment, ExperimentCtx, ParamDefault, Report};

#[derive(Debug, Clone)]
pub struct ClTableResult {
    pub static_mse: f32,
    pub retrained_mse: f32,
}

impl ClTableResult {
    pub fn improvement_pct(&self) -> f32 {
        100.0 * (1.0 - self.retrained_mse / self.static_mse)
    }
}

/// Train once on the initial window ("static") and continuously on the
/// sliding window ("retrained"); evaluate both on each shifted validation
/// span and average. The drift in the synthetic data is what separates
/// the two (DESIGN.md §3).
#[allow(clippy::too_many_arguments)]
pub fn run(
    rt: &dyn ModelRuntime,
    series: &[f32],
    init_params: Vec<f32>,
    mut window: ContinualWindow,
    initial_steps: usize,
    steps_per_shift: usize,
    lr: f32,
    seed: u64,
) -> anyhow::Result<ClTableResult> {
    let data = ClientData::new(
        series,
        WindowSpec { seq_len: rt.seq_len(), horizon: 1 },
        window.train_range(),
    );
    let mut rng = Rng::new(seed);
    let b = rt.train_batch_size();

    // --- phase 1: shared initial training on the first window ----------
    let mut static_params = init_params;
    for _ in 0..initial_steps {
        let (x, y) = data.sample_batch(window.train_range(), b, &mut rng);
        let (p, _) = rt.train_batch(&static_params, &x, &y, lr)?;
        static_params = p;
    }
    let mut retrained_params = static_params.clone();

    // --- phase 2: slide; only "retrained" keeps learning ---------------
    let mut static_mses = Vec::new();
    let mut retrained_mses = Vec::new();
    while window.advance() {
        for _ in 0..steps_per_shift {
            let (x, y) = data.sample_batch(window.train_range(), b, &mut rng);
            let (p, _) = rt.train_batch(&retrained_params, &x, &y, lr)?;
            retrained_params = p;
        }
        let val = window.val_range();
        static_mses.push(eval_span(rt, &static_params, &data, val)?);
        retrained_mses.push(eval_span(rt, &retrained_params, &data, val)?);
    }
    anyhow::ensure!(!static_mses.is_empty(), "window never advanced");

    let avg = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
    Ok(ClTableResult { static_mse: avg(&static_mses), retrained_mse: avg(&retrained_mses) })
}

fn eval_span(
    rt: &dyn ModelRuntime,
    params: &[f32],
    data: &ClientData,
    range: (usize, usize),
) -> anyhow::Result<f32> {
    let (xs, ys) = data.windows(range);
    anyhow::ensure!(!ys.is_empty(), "empty eval span");
    let t = rt.seq_len();
    let be = rt.eval_batch_size();
    let n = ys.len();
    let mut total = 0.0f64;
    let mut batches = 0usize;
    let mut start = 0;
    while start < n {
        let mut bx = Vec::with_capacity(be * t);
        let mut by = Vec::with_capacity(be);
        for k in 0..be {
            let idx = (start + k) % n;
            bx.extend_from_slice(&xs[idx * t..(idx + 1) * t]);
            by.push(ys[idx]);
        }
        total += rt.eval(params, &bx, &by)? as f64;
        batches += 1;
        start += be;
    }
    Ok((total / batches as f64) as f32)
}

/// Registry port (DESIGN.md §5). Like `fig6`, the `runtime` parameter
/// gates real-GRU vs mock execution — and the mock path is loudly
/// marked (`cl_table_mock.json`, `mock = true`): the paper's §V-B1
/// numbers come from a GRU that *can* see the drift, while the linear
/// mock mostly cannot, so its improvement percentage is meaningless as
/// a paper artifact and only proves the harness runs.
pub struct ClTableExperiment;

const SCHEMA: &[ParamSpec] = &[
    ParamSpec {
        key: "runtime",
        default: ParamDefault::Str("auto"),
        help: "auto|real|mock — real PJRT GRU, or the clearly-marked linear mock",
    },
    ParamSpec {
        key: "variant",
        default: ParamDefault::Str("small"),
        help: "model variant from the artifact manifest (real runtime)",
    },
    ParamSpec {
        key: "weeks",
        default: ParamDefault::Int(10),
        help: "synthetic dataset length (floored at 6 so the window can slide)",
    },
    ParamSpec {
        key: "drift_scale",
        default: ParamDefault::Float(2.5),
        help: "drift strength of the synthetic series",
    },
    ParamSpec { key: "data_seed", default: ParamDefault::Int(1234), help: "dataset seed" },
    ParamSpec {
        key: "initial_steps",
        default: ParamDefault::Int(1500),
        help: "shared initial-training SGD steps",
    },
    ParamSpec {
        key: "steps_per_shift",
        default: ParamDefault::Int(300),
        help: "retraining SGD steps per window shift",
    },
    ParamSpec { key: "lr", default: ParamDefault::Float(0.01), help: "learning rate" },
    ParamSpec { key: "seed", default: ParamDefault::Int(7), help: "batch-sampling seed" },
];

const MOCK_WARNING: &str = "cl: MOCK runtime — a linear model barely sees the drift, so the \
                            improvement number is NOT the paper's §V-B1 artifact (marked \
                            cl_table_mock.json, mock=true). Build the PJRT artifacts and pass \
                            --set runtime=real for the real table.";

impl Experiment for ClTableExperiment {
    fn name(&self) -> &'static str {
        "cl"
    }

    fn describe(&self) -> &'static str {
        "§V-B1 table: static vs continually-retrained MSE under drift"
    }

    fn param_schema(&self) -> &'static [ParamSpec] {
        SCHEMA
    }

    fn run(&self, ctx: &mut ExperimentCtx) -> anyhow::Result<Report> {
        let synth = SynthConfig {
            n_steps: ctx.usize_capped("weeks", 8)?.max(6) * STEPS_PER_WEEK,
            drift_scale: ctx.params.f64("drift_scale")?,
            seed: ctx.params.u64("data_seed")?,
            ..Default::default()
        };
        let ds = generate(&synth);

        let real = runtime_gate(ctx, "cl")?;

        let window = ContinualWindow::new(
            3 * STEPS_PER_WEEK,
            STEPS_PER_WEEK,
            STEPS_PER_WEEK / 2,
            ds.n_steps,
        );
        let initial_steps = ctx.usize_capped("initial_steps", 200)?;
        let steps_per_shift = ctx.usize_capped("steps_per_shift", 50)?;
        let lr = ctx.params.f64("lr")? as f32;
        let seed = ctx.params.u64("seed")?;

        let mock = MockRuntime::new(12, 8);
        let (r, runtime_name) = match &real {
            Some((manifest, engine)) => {
                let init = manifest.load_init_params(engine.variant())?;
                let rt: &dyn ModelRuntime = engine;
                (run(rt, &ds.series[0], init, window, initial_steps, steps_per_shift, lr, seed)?,
                 "real")
            }
            None => {
                eprintln!("{MOCK_WARNING}");
                let init = vec![0.0f32; mock.n_params()];
                let rt: &dyn ModelRuntime = &mock;
                (run(rt, &ds.series[0], init, window, initial_steps, steps_per_shift, lr, seed)?,
                 "mock")
            }
        };

        ctx.say(|| {
            format!(
                "static MSE = {:.5}   retrained MSE = {:.5}   improvement = {:.2}% \
                 (paper: 0.04470 -> 0.04284, 4.2%)",
                r.static_mse,
                r.retrained_mse,
                r.improvement_pct()
            )
        });

        let mut report = Report::new("cl");
        report.set_stem(if runtime_name == "mock" { "cl_table_mock" } else { "cl_table" });
        report.text("runtime", runtime_name);
        report.flag("mock", runtime_name == "mock");
        report.num("static_mse", r.static_mse as f64);
        report.num("retrained_mse", r.retrained_mse as f64);
        report.num("improvement_pct", r.improvement_pct() as f64);
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retraining_beats_static_under_drift() {
        // Strong drift -> the static model must fall behind.
        let mut cfg = SynthConfig::tiny(3);
        cfg.n_steps = 10 * STEPS_PER_WEEK;
        cfg.drift_scale = 2.0;
        let ds = generate(&cfg);
        let rt = MockRuntime::new(12, 8);
        let window = ContinualWindow::new(
            2 * STEPS_PER_WEEK,
            STEPS_PER_WEEK / 2,
            STEPS_PER_WEEK / 2,
            ds.n_steps,
        );
        let r = run(
            &rt,
            &ds.series[0],
            vec![0.0; rt.n_params()],
            window,
            400,
            100,
            0.05,
            7,
        )
        .unwrap();
        assert!(
            r.retrained_mse < r.static_mse,
            "static {} retrained {}",
            r.static_mse,
            r.retrained_mse
        );
        assert!(r.improvement_pct() > 0.0);
    }

    #[test]
    fn experiment_trait_mock_run_is_marked() {
        use crate::config::params::{Params, Value};
        use crate::experiments::registry::ExperimentCtx;
        let mut p = Params::defaults(ClTableExperiment.param_schema());
        p.set("runtime", Value::Str("mock".into())).unwrap();
        p.set("weeks", Value::Int(6)).unwrap();
        p.set("initial_steps", Value::Int(150)).unwrap();
        p.set("steps_per_shift", Value::Int(40)).unwrap();
        let mut ctx = ExperimentCtx::cell(p);
        let report = ClTableExperiment.run(&mut ctx).unwrap();
        assert_eq!(report.stem, "cl_table_mock");
        assert_eq!(report.summary.get("mock").unwrap().as_bool(), Some(true));
        assert!(report.get_f64("static_mse").unwrap() > 0.0);
        assert!(report.get_f64("retrained_mse").unwrap() > 0.0);
    }

    #[test]
    fn no_drift_keeps_them_close() {
        let mut cfg = SynthConfig::tiny(4);
        cfg.n_steps = 8 * STEPS_PER_WEEK;
        cfg.drift_scale = 0.0;
        let ds = generate(&cfg);
        let rt = MockRuntime::new(12, 8);
        let window = ContinualWindow::new(
            2 * STEPS_PER_WEEK,
            STEPS_PER_WEEK / 2,
            STEPS_PER_WEEK,
            ds.n_steps,
        );
        let r = run(
            &rt,
            &ds.series[0],
            vec![0.0; rt.n_params()],
            window,
            400,
            50,
            0.05,
            7,
        )
        .unwrap();
        // Without drift the gap must be small (retraining still helps a
        // little through more optimization steps).
        let rel = (r.static_mse - r.retrained_mse).abs() / r.static_mse;
        assert!(rel < 0.5, "static {} retrained {}", r.static_mse, r.retrained_mse);
    }
}
