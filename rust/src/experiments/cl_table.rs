//! §V-B1 — the continual-learning benefit table: a model trained once on
//! an initial window vs. a model continuously retrained as the window
//! slides, both evaluated on later (drifted) data. Paper numbers
//! (centralized GRU on METR-LA): static MSE 0.04470 vs retrained
//! 0.04284 — continual retraining wins.

use crate::data::window::{ClientData, ContinualWindow, WindowSpec};
use crate::fl::ModelRuntime;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct ClTableResult {
    pub static_mse: f32,
    pub retrained_mse: f32,
}

impl ClTableResult {
    pub fn improvement_pct(&self) -> f32 {
        100.0 * (1.0 - self.retrained_mse / self.static_mse)
    }
}

/// Train once on the initial window ("static") and continuously on the
/// sliding window ("retrained"); evaluate both on each shifted validation
/// span and average. The drift in the synthetic data is what separates
/// the two (DESIGN.md §3).
#[allow(clippy::too_many_arguments)]
pub fn run(
    rt: &dyn ModelRuntime,
    series: &[f32],
    init_params: Vec<f32>,
    mut window: ContinualWindow,
    initial_steps: usize,
    steps_per_shift: usize,
    lr: f32,
    seed: u64,
) -> anyhow::Result<ClTableResult> {
    let data = ClientData::new(
        series,
        WindowSpec { seq_len: rt.seq_len(), horizon: 1 },
        window.train_range(),
    );
    let mut rng = Rng::new(seed);
    let b = rt.train_batch_size();

    // --- phase 1: shared initial training on the first window ----------
    let mut static_params = init_params;
    for _ in 0..initial_steps {
        let (x, y) = data.sample_batch(window.train_range(), b, &mut rng);
        let (p, _) = rt.train_batch(&static_params, &x, &y, lr)?;
        static_params = p;
    }
    let mut retrained_params = static_params.clone();

    // --- phase 2: slide; only "retrained" keeps learning ---------------
    let mut static_mses = Vec::new();
    let mut retrained_mses = Vec::new();
    while window.advance() {
        for _ in 0..steps_per_shift {
            let (x, y) = data.sample_batch(window.train_range(), b, &mut rng);
            let (p, _) = rt.train_batch(&retrained_params, &x, &y, lr)?;
            retrained_params = p;
        }
        let val = window.val_range();
        static_mses.push(eval_span(rt, &static_params, &data, val)?);
        retrained_mses.push(eval_span(rt, &retrained_params, &data, val)?);
    }
    anyhow::ensure!(!static_mses.is_empty(), "window never advanced");

    let avg = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
    Ok(ClTableResult { static_mse: avg(&static_mses), retrained_mse: avg(&retrained_mses) })
}

fn eval_span(
    rt: &dyn ModelRuntime,
    params: &[f32],
    data: &ClientData,
    range: (usize, usize),
) -> anyhow::Result<f32> {
    let (xs, ys) = data.windows(range);
    anyhow::ensure!(!ys.is_empty(), "empty eval span");
    let t = rt.seq_len();
    let be = rt.eval_batch_size();
    let n = ys.len();
    let mut total = 0.0f64;
    let mut batches = 0usize;
    let mut start = 0;
    while start < n {
        let mut bx = Vec::with_capacity(be * t);
        let mut by = Vec::with_capacity(be);
        for k in 0..be {
            let idx = (start + k) % n;
            bx.extend_from_slice(&xs[idx * t..(idx + 1) * t]);
            by.push(ys[idx]);
        }
        total += rt.eval(params, &bx, &by)? as f64;
        batches += 1;
        start += be;
    }
    Ok((total / batches as f64) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::data::STEPS_PER_WEEK;
    use crate::fl::MockRuntime;

    #[test]
    fn retraining_beats_static_under_drift() {
        // Strong drift -> the static model must fall behind.
        let mut cfg = SynthConfig::tiny(3);
        cfg.n_steps = 10 * STEPS_PER_WEEK;
        cfg.drift_scale = 2.0;
        let ds = generate(&cfg);
        let rt = MockRuntime::new(12, 8);
        let window = ContinualWindow::new(
            2 * STEPS_PER_WEEK,
            STEPS_PER_WEEK / 2,
            STEPS_PER_WEEK / 2,
            ds.n_steps,
        );
        let r = run(
            &rt,
            &ds.series[0],
            vec![0.0; rt.n_params()],
            window,
            400,
            100,
            0.05,
            7,
        )
        .unwrap();
        assert!(
            r.retrained_mse < r.static_mse,
            "static {} retrained {}",
            r.static_mse,
            r.retrained_mse
        );
        assert!(r.improvement_pct() > 0.0);
    }

    #[test]
    fn no_drift_keeps_them_close() {
        let mut cfg = SynthConfig::tiny(4);
        cfg.n_steps = 8 * STEPS_PER_WEEK;
        cfg.drift_scale = 0.0;
        let ds = generate(&cfg);
        let rt = MockRuntime::new(12, 8);
        let window = ContinualWindow::new(
            2 * STEPS_PER_WEEK,
            STEPS_PER_WEEK / 2,
            STEPS_PER_WEEK,
            ds.n_steps,
        );
        let r = run(
            &rt,
            &ds.series[0],
            vec![0.0; rt.n_params()],
            window,
            400,
            50,
            0.05,
            7,
        )
        .unwrap();
        // Without drift the gap must be small (retraining still helps a
        // little through more optimization steps).
        let rel = (r.static_mse - r.retrained_mse).abs() / r.static_mse;
        assert!(rel < 0.5, "static {} retrained {}", r.static_mse, r.retrained_mse);
    }
}
