//! Shared experiment scenario: synthetic METR-LA sensors, client
//! selection, edge placement, the HFLOP instance, and the three
//! device→edge assignments the paper compares (flat / location-clustered
//! / HFLOP).

use crate::data::synth::{generate, SynthConfig, TrafficDataset};
use crate::hflop::{Instance, InstanceBuilder};
use crate::solver::{self, Assignment, SolveOptions};
use crate::topology::{kmeans, GeoTopologyBuilder, Topology};
use crate::util::rng::Rng;

/// Scenario parameters (paper defaults: 20 clients, 4 edge servers).
#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    pub n_clients: usize,
    pub n_edges: usize,
    pub data_seed: u64,
    pub seed: u64,
    pub lambda_range: (f64, f64),
    pub capacity_range: (f64, f64),
    /// Pick clients evenly per geographic cluster (the paper's Fig. 5:
    /// "5 random sensors were chosen from each cluster") vs uniformly.
    pub balanced_clients: bool,
    /// Smaller synthetic dataset (weeks instead of 4 months) for fast
    /// runs; the paper-scale default is 17 weeks.
    pub weeks: usize,
    pub l: f64,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            n_clients: 20,
            n_edges: 4,
            data_seed: 1234,
            seed: 42,
            lambda_range: (20.0, 60.0),
            capacity_range: (250.0, 450.0),
            balanced_clients: true,
            weeks: 17,
            l: 2.0,
        }
    }
}

/// A fully-built scenario.
pub struct Scenario {
    pub cfg: ScenarioConfig,
    pub dataset: TrafficDataset,
    /// Sensor index of each client.
    pub client_sensors: Vec<usize>,
    pub topo: Topology,
    pub inst: Instance,
    /// Location-based (capacity-blind) assignment: nearest cluster edge.
    pub assign_location: Assignment,
    /// HFLOP (capacity-aware, cost-optimal) assignment.
    pub assign_hflop: Assignment,
    pub hflop_cost: f64,
    pub hflop_optimal: bool,
}

impl Scenario {
    pub fn build(cfg: ScenarioConfig) -> anyhow::Result<Scenario> {
        let mut rng = Rng::new(cfg.seed);

        // --- dataset -------------------------------------------------------
        let synth = SynthConfig {
            n_steps: cfg.weeks * crate::data::STEPS_PER_WEEK,
            seed: cfg.data_seed,
            ..SynthConfig::default()
        };
        let dataset = generate(&synth);

        // --- client selection (paper: 5 random sensors per geo cluster) ---
        let km = kmeans(&dataset.locations, cfg.n_edges, 100, &mut rng);
        let client_sensors: Vec<usize> = if cfg.balanced_clients {
            let per = cfg.n_clients / cfg.n_edges.max(1);
            let mut chosen = Vec::new();
            for c in 0..km.centroids.len() {
                let members: Vec<usize> = (0..dataset.n_sensors())
                    .filter(|&i| km.assignment[i] == c)
                    .collect();
                let take = per.min(members.len());
                let idx = rng.sample_indices(members.len(), take);
                chosen.extend(idx.into_iter().map(|k| members[k]));
            }
            // Top up if rounding or empty clusters left us short.
            while chosen.len() < cfg.n_clients {
                let cand = rng.below(dataset.n_sensors());
                if !chosen.contains(&cand) {
                    chosen.push(cand);
                }
            }
            chosen.truncate(cfg.n_clients);
            chosen
        } else {
            rng.sample_indices(dataset.n_sensors(), cfg.n_clients)
        };

        // --- topology: edges at client-cluster centroids -------------------
        let client_locs: Vec<_> = client_sensors.iter().map(|&i| dataset.locations[i]).collect();
        let topo = GeoTopologyBuilder::new(client_locs.clone(), cfg.n_edges, cfg.seed ^ 0xBEEF)
            .lambda_range(cfg.lambda_range.0, cfg.lambda_range.1)
            .capacity_range(cfg.capacity_range.0, cfg.capacity_range.1)
            .build();

        let inst = InstanceBuilder::from_topology(&topo, cfg.l, cfg.n_clients).build();

        // --- location-based assignment (capacity-blind nearest edge) -------
        let mut open = vec![false; topo.n_edges()];
        let assign: Vec<Option<usize>> = (0..topo.n_devices())
            .map(|i| {
                let j = topo.cheapest_edge(i);
                open[j] = true;
                Some(j)
            })
            .collect();
        let assign_location = Assignment { assign, open };

        // --- HFLOP assignment ----------------------------------------------
        let sol = solver::solve(&inst, &SolveOptions::auto())
            .map_err(|e| anyhow::anyhow!("HFLOP solve failed: {e}"))?;

        Ok(Scenario {
            cfg,
            dataset,
            client_sensors,
            topo,
            inst,
            assign_location,
            assign_hflop: sol.assignment,
            hflop_cost: sol.cost,
            hflop_optimal: sol.proven_optimal,
        })
    }

    /// λ per client (from the topology).
    pub fn lambdas(&self) -> Vec<f64> {
        self.topo.devices.iter().map(|d| d.lambda).collect()
    }

    /// r per edge (from the topology).
    pub fn capacities(&self) -> Vec<f64> {
        self.topo.edges.iter().map(|e| e.capacity).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ScenarioConfig {
        ScenarioConfig { n_clients: 12, n_edges: 3, weeks: 5, ..Default::default() }
    }

    #[test]
    fn builds_consistent_scenario() {
        let s = Scenario::build(tiny_cfg()).unwrap();
        assert_eq!(s.client_sensors.len(), 12);
        assert_eq!(s.topo.n_devices(), 12);
        assert_eq!(s.topo.n_edges(), 3);
        s.inst.validate().unwrap();
        s.assign_hflop.check_feasible(&s.inst).unwrap();
        // Location assignment covers everyone.
        assert_eq!(s.assign_location.n_assigned(), 12);
    }

    #[test]
    fn client_sensors_distinct() {
        let s = Scenario::build(tiny_cfg()).unwrap();
        let mut c = s.client_sensors.clone();
        c.sort_unstable();
        c.dedup();
        assert_eq!(c.len(), 12);
    }

    #[test]
    fn hflop_cost_not_above_location_cost() {
        let s = Scenario::build(tiny_cfg()).unwrap();
        // The location assignment may violate capacity; but measured in
        // pure communication cost HFLOP (optimal) is never worse than any
        // feasible assignment; compare only if location is feasible.
        if s.assign_location.check_feasible(&s.inst).is_ok() {
            assert!(s.hflop_cost <= s.assign_location.cost(&s.inst) + 1e-9);
        }
    }

    #[test]
    fn deterministic_by_seeds() {
        let a = Scenario::build(tiny_cfg()).unwrap();
        let b = Scenario::build(tiny_cfg()).unwrap();
        assert_eq!(a.client_sensors, b.client_sensors);
        assert_eq!(a.assign_hflop.assign, b.assign_hflop.assign);
    }
}
