//! Shared experiment scenario: synthetic METR-LA sensors, client
//! selection, edge placement, the HFLOP instance, and the three
//! device→edge assignments the paper compares (flat / location-clustered
//! / HFLOP).

use crate::config::params::ParamSpec;
use crate::data::synth::{generate, SynthConfig, TrafficDataset};
use crate::hflop::{Instance, InstanceBuilder};
use crate::metrics::export::ascii_table;
use crate::solver::{self, Assignment, SolveOptions};
use crate::topology::{kmeans, GeoTopologyBuilder, Topology};
use crate::util::rng::Rng;

use super::registry::{Experiment, ExperimentCtx, ParamDefault, Report};

/// Scenario parameters (paper defaults: 20 clients, 4 edge servers).
#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    pub n_clients: usize,
    pub n_edges: usize,
    pub data_seed: u64,
    pub seed: u64,
    pub lambda_range: (f64, f64),
    pub capacity_range: (f64, f64),
    /// Pick clients evenly per geographic cluster (the paper's Fig. 5:
    /// "5 random sensors were chosen from each cluster") vs uniformly.
    pub balanced_clients: bool,
    /// Smaller synthetic dataset (weeks instead of 4 months) for fast
    /// runs; the paper-scale default is 17 weeks.
    pub weeks: usize,
    pub l: f64,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            n_clients: 20,
            n_edges: 4,
            data_seed: 1234,
            seed: 42,
            lambda_range: (20.0, 60.0),
            capacity_range: (250.0, 450.0),
            balanced_clients: true,
            weeks: 17,
            l: 2.0,
        }
    }
}

/// A fully-built scenario.
pub struct Scenario {
    pub cfg: ScenarioConfig,
    pub dataset: TrafficDataset,
    /// Sensor index of each client.
    pub client_sensors: Vec<usize>,
    pub topo: Topology,
    pub inst: Instance,
    /// Location-based (capacity-blind) assignment: nearest cluster edge.
    pub assign_location: Assignment,
    /// HFLOP (capacity-aware, cost-optimal) assignment.
    pub assign_hflop: Assignment,
    pub hflop_cost: f64,
    pub hflop_optimal: bool,
}

impl Scenario {
    pub fn build(cfg: ScenarioConfig) -> anyhow::Result<Scenario> {
        let mut rng = Rng::new(cfg.seed);

        // --- dataset -------------------------------------------------------
        let synth = SynthConfig {
            n_steps: cfg.weeks * crate::data::STEPS_PER_WEEK,
            seed: cfg.data_seed,
            ..SynthConfig::default()
        };
        let dataset = generate(&synth);

        // --- client selection (paper: 5 random sensors per geo cluster) ---
        let km = kmeans(&dataset.locations, cfg.n_edges, 100, &mut rng);
        let client_sensors: Vec<usize> = if cfg.balanced_clients {
            let per = cfg.n_clients / cfg.n_edges.max(1);
            let mut chosen = Vec::new();
            for c in 0..km.centroids.len() {
                let members: Vec<usize> = (0..dataset.n_sensors())
                    .filter(|&i| km.assignment[i] == c)
                    .collect();
                let take = per.min(members.len());
                let idx = rng.sample_indices(members.len(), take);
                chosen.extend(idx.into_iter().map(|k| members[k]));
            }
            // Top up if rounding or empty clusters left us short.
            while chosen.len() < cfg.n_clients {
                let cand = rng.below(dataset.n_sensors());
                if !chosen.contains(&cand) {
                    chosen.push(cand);
                }
            }
            chosen.truncate(cfg.n_clients);
            chosen
        } else {
            rng.sample_indices(dataset.n_sensors(), cfg.n_clients)
        };

        // --- topology: edges at client-cluster centroids -------------------
        let client_locs: Vec<_> = client_sensors.iter().map(|&i| dataset.locations[i]).collect();
        let topo = GeoTopologyBuilder::new(client_locs.clone(), cfg.n_edges, cfg.seed ^ 0xBEEF)
            .lambda_range(cfg.lambda_range.0, cfg.lambda_range.1)
            .capacity_range(cfg.capacity_range.0, cfg.capacity_range.1)
            .build();

        let inst = InstanceBuilder::from_topology(&topo, cfg.l, cfg.n_clients).build();

        // --- location-based assignment (capacity-blind nearest edge) -------
        let mut open = vec![false; topo.n_edges()];
        let assign: Vec<Option<usize>> = (0..topo.n_devices())
            .map(|i| {
                let j = topo.cheapest_edge(i);
                open[j] = true;
                Some(j)
            })
            .collect();
        let assign_location = Assignment { assign, open };

        // --- HFLOP assignment ----------------------------------------------
        let sol = solver::solve(&inst, &SolveOptions::auto())
            .map_err(|e| anyhow::anyhow!("HFLOP solve failed: {e}"))?;

        Ok(Scenario {
            cfg,
            dataset,
            client_sensors,
            topo,
            inst,
            assign_location,
            assign_hflop: sol.assignment,
            hflop_cost: sol.cost,
            hflop_optimal: sol.proven_optimal,
        })
    }

    /// λ per client (from the topology).
    pub fn lambdas(&self) -> Vec<f64> {
        self.topo.devices.iter().map(|d| d.lambda).collect()
    }

    /// r per edge (from the topology).
    pub fn capacities(&self) -> Vec<f64> {
        self.topo.edges.iter().map(|e| e.capacity).collect()
    }
}

/// Registry port (DESIGN.md §5): the static `Scenario` builder as a
/// first-class experiment — build the shared world and report the
/// topology, the three assignments and their Eq. 1 costs. Useful on its
/// own (inspect what every figure runs on) and as the template future
/// world-building scenarios (budget triggers, MaaS pricing) extend.
pub struct ScenarioExperiment;

const SCHEMA: &[ParamSpec] = &[
    ParamSpec { key: "clients", default: ParamDefault::Int(20), help: "FL clients / devices" },
    ParamSpec { key: "edges", default: ParamDefault::Int(4), help: "candidate edge hosts" },
    ParamSpec {
        key: "weeks",
        default: ParamDefault::Int(17),
        help: "synthetic dataset length (paper scale: 17)",
    },
    ParamSpec {
        key: "balanced",
        default: ParamDefault::Bool(true),
        help: "balanced client placement (5 per cluster)",
    },
    ParamSpec { key: "scenario_seed", default: ParamDefault::Int(42), help: "scenario seed" },
    ParamSpec { key: "data_seed", default: ParamDefault::Int(1234), help: "dataset seed" },
    ParamSpec {
        key: "lambda_min",
        default: ParamDefault::Float(20.0),
        help: "lambda_i sampling range lower bound (req/s)",
    },
    ParamSpec {
        key: "lambda_max",
        default: ParamDefault::Float(60.0),
        help: "lambda_i sampling range upper bound (req/s)",
    },
    ParamSpec {
        key: "capacity_min",
        default: ParamDefault::Float(250.0),
        help: "r_j sampling range lower bound (req/s)",
    },
    ParamSpec {
        key: "capacity_max",
        default: ParamDefault::Float(450.0),
        help: "r_j sampling range upper bound (req/s)",
    },
];

impl Experiment for ScenarioExperiment {
    fn name(&self) -> &'static str {
        "scenario"
    }

    fn describe(&self) -> &'static str {
        "build the shared world: topology, three assignments, Eq. 1 costs"
    }

    fn param_schema(&self) -> &'static [ParamSpec] {
        SCHEMA
    }

    fn run(&self, ctx: &mut ExperimentCtx) -> anyhow::Result<Report> {
        let sc = Scenario::build(ScenarioConfig {
            n_clients: ctx.params.usize("clients")?,
            n_edges: ctx.params.usize("edges")?,
            weeks: ctx.usize_capped("weeks", 5)?,
            balanced_clients: ctx.params.bool("balanced")?,
            seed: ctx.params.u64("scenario_seed")?,
            data_seed: ctx.params.u64("data_seed")?,
            lambda_range: (ctx.params.f64("lambda_min")?, ctx.params.f64("lambda_max")?),
            capacity_range: (ctx.params.f64("capacity_min")?, ctx.params.f64("capacity_max")?),
            ..Default::default()
        })?;

        let location_cost = sc.assign_location.cost(&sc.inst);
        let location_feasible = sc.assign_location.check_feasible(&sc.inst).is_ok();
        ctx.say(|| {
            ascii_table(
                &["assignment", "eq1_cost", "feasible"],
                &[
                    vec![
                        "location".into(),
                        format!("{location_cost:.2}"),
                        format!("{location_feasible}"),
                    ],
                    vec!["hflop".into(), format!("{:.2}", sc.hflop_cost), "true".into()],
                ],
            )
        });

        let mut report = Report::new("scenario");
        report.num("n_devices", sc.topo.n_devices() as f64);
        report.num("n_edges", sc.topo.n_edges() as f64);
        report.num("dataset_steps", sc.dataset.n_steps as f64);
        report.num("hflop_cost", sc.hflop_cost);
        report.flag("hflop_optimal", sc.hflop_optimal);
        report.num("location_cost", location_cost);
        report.flag("location_feasible", location_feasible);
        report.num("total_lambda", sc.lambdas().iter().sum());
        report.num("total_capacity", sc.capacities().iter().sum());
        report.table(
            "scenario_devices",
            &["device", "lambda", "location_edge", "hflop_edge"],
            (0..sc.topo.n_devices())
                .map(|i| {
                    let enc = |a: &Option<usize>| a.map(|j| j as f64).unwrap_or(-1.0);
                    vec![
                        i as f64,
                        sc.topo.devices[i].lambda,
                        enc(&sc.assign_location.assign[i]),
                        enc(&sc.assign_hflop.assign[i]),
                    ]
                })
                .collect(),
        );
        report.table(
            "scenario_edges",
            &["edge", "capacity", "open_location", "open_hflop"],
            (0..sc.topo.n_edges())
                .map(|j| {
                    vec![
                        j as f64,
                        sc.topo.edges[j].capacity,
                        sc.assign_location.open[j] as u8 as f64,
                        sc.assign_hflop.open[j] as u8 as f64,
                    ]
                })
                .collect(),
        );
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ScenarioConfig {
        ScenarioConfig { n_clients: 12, n_edges: 3, weeks: 5, ..Default::default() }
    }

    #[test]
    fn builds_consistent_scenario() {
        let s = Scenario::build(tiny_cfg()).unwrap();
        assert_eq!(s.client_sensors.len(), 12);
        assert_eq!(s.topo.n_devices(), 12);
        assert_eq!(s.topo.n_edges(), 3);
        s.inst.validate().unwrap();
        s.assign_hflop.check_feasible(&s.inst).unwrap();
        // Location assignment covers everyone.
        assert_eq!(s.assign_location.n_assigned(), 12);
    }

    #[test]
    fn client_sensors_distinct() {
        let s = Scenario::build(tiny_cfg()).unwrap();
        let mut c = s.client_sensors.clone();
        c.sort_unstable();
        c.dedup();
        assert_eq!(c.len(), 12);
    }

    #[test]
    fn hflop_cost_not_above_location_cost() {
        let s = Scenario::build(tiny_cfg()).unwrap();
        // The location assignment may violate capacity; but measured in
        // pure communication cost HFLOP (optimal) is never worse than any
        // feasible assignment; compare only if location is feasible.
        if s.assign_location.check_feasible(&s.inst).is_ok() {
            assert!(s.hflop_cost <= s.assign_location.cost(&s.inst) + 1e-9);
        }
    }

    #[test]
    fn deterministic_by_seeds() {
        let a = Scenario::build(tiny_cfg()).unwrap();
        let b = Scenario::build(tiny_cfg()).unwrap();
        assert_eq!(a.client_sensors, b.client_sensors);
        assert_eq!(a.assign_hflop.assign, b.assign_hflop.assign);
    }

    #[test]
    fn experiment_trait_reports_world() {
        use crate::config::params::{Params, Value};
        use crate::experiments::registry::ExperimentCtx;
        let mut p = Params::defaults(ScenarioExperiment.param_schema());
        p.set("clients", Value::Int(12)).unwrap();
        p.set("edges", Value::Int(3)).unwrap();
        p.set("weeks", Value::Int(5)).unwrap();
        let mut ctx = ExperimentCtx::cell(p);
        let report = ScenarioExperiment.run(&mut ctx).unwrap();
        assert_eq!(report.get_f64("n_devices").unwrap(), 12.0);
        assert_eq!(report.get_f64("n_edges").unwrap(), 3.0);
        assert!(report.get_f64("hflop_cost").unwrap() > 0.0);
        assert_eq!(report.tables[0].rows.len(), 12);
        assert_eq!(report.tables[1].rows.len(), 3);
    }
}
