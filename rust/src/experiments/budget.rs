//! The `budget` experiment — budget-governed reactive re-orchestration
//! (DESIGN.md §11).
//!
//! Every cell runs the same fault/surge scenario **twice** on one
//! kernel: once as an *unbudgeted oracle* (unlimited governor — the
//! orchestrator reconfigures whenever it wants) and once under the
//! configured [`BudgetPolicy`] (hard cumulative cap and/or epoch-refill
//! token bucket). The report carries the standard co-sim serving keys
//! for the budgeted run plus the control-plane economics:
//!
//! * `ctl_spend_gb` / `budget_deferrals` — approved reconfiguration
//!   spend and denied installs (also surfaced per sweep cell);
//! * `regret_ms` — p99 latency lost to budgeting: budgeted p99 minus
//!   oracle p99 (can be ≤ 0 when deferring happened to be harmless);
//! * `bytes_saved_gb` — oracle spend minus budgeted spend: what the
//!   budget kept off the wire;
//! * `within_cap` — the acceptance invariant: cumulative budgeted spend
//!   never exceeds the configured cap.
//!
//! The sweep axes are the budget level (`budget_mb` rows), the fault
//! rate (`fault_rate` modes: edge fail/recover cycles over the horizon)
//! and the surge factor (`surge_factor` envs) — `SweepGrid::budget`
//! declares exactly that grid.

use crate::config::params::ParamSpec;
use crate::experiments::interference::{cosim_summary, solve_from_ls_mode};
use crate::experiments::registry::{Experiment, ExperimentCtx, ParamDefault, Report};
use crate::experiments::scenario::{Scenario, ScenarioConfig};
use crate::fl::timing::RoundTimeModel;
use crate::inference::cosim::{
    run_cell_reusing, CoEvent, ControlConfig, ControlPlane, CoSimConfig, CoSimOutcome,
    DriftModel, FaultEvent, TrainingConfig, TrainingSchedule,
};
use crate::inference::simulation::ServingConfig;
use crate::inference::trace::ArrivalModel;
use crate::inference::LatencyModel;
use crate::orchestrator::budget::{ActionCostModel, BudgetGovernor, BudgetPolicy, TokenBucket};
use crate::orchestrator::{
    DeploymentPlan, Gpo, InferenceController, InferenceCtlConfig, LearningController,
    LearningCtlConfig, ResolveStrategy,
};
use crate::sim::Kernel;
use crate::solver::SolveOptions;

/// One budget cell: the shared fault/surge world both the oracle and
/// the budgeted run execute.
#[derive(Debug, Clone)]
pub struct BudgetCellConfig {
    pub duration_s: f64,
    pub interference_factor: f64,
    pub lambda_scale: f64,
    pub model_bytes: usize,
    pub solve: SolveOptions,
    pub resolve: ResolveStrategy,
    /// Edge fail/recover cycles over the horizon (the fault-rate axis).
    pub fault_rate: usize,
    /// Mid-run λ surge multiplier; ≤ 1 disables the surge window.
    pub surge_factor: f64,
    pub seed: u64,
}

impl Default for BudgetCellConfig {
    fn default() -> Self {
        BudgetCellConfig {
            duration_s: 240.0,
            interference_factor: 0.25,
            lambda_scale: 1.0,
            model_bytes: 262_144,
            solve: SolveOptions::auto(),
            resolve: ResolveStrategy::Auto,
            fault_rate: 2,
            surge_factor: 1.0,
            seed: 7,
        }
    }
}

/// Deterministic fault/surge schedule: `fault_rate` fail/recover cycles
/// rotating over the edges in descending-load order (heaviest first),
/// plus one surge window when `surge_factor > 1`.
fn fault_schedule(cfg: &BudgetCellConfig, sc: &Scenario, lambdas: &[f64]) -> Vec<(f64, FaultEvent)> {
    let d = cfg.duration_s;
    let m = sc.topo.n_edges();
    let mut faults = Vec::new();
    if cfg.fault_rate > 0 && m > 0 {
        let mut load = vec![0.0f64; m];
        for (dev, a) in sc.assign_hflop.assign.iter().enumerate() {
            if let Some(j) = *a {
                load[j] += lambdas[dev];
            }
        }
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&a, &b| load[b].total_cmp(&load[a]).then(a.cmp(&b)));
        let cycles = cfg.fault_rate as f64;
        for c in 0..cfg.fault_rate {
            let victim = order[c % m];
            faults.push(((c as f64 + 0.25) / cycles * d, FaultEvent::EdgeFail(victim)));
            faults.push(((c as f64 + 0.70) / cycles * d, FaultEvent::EdgeRecover(victim)));
        }
    }
    if cfg.surge_factor > 1.0 {
        faults.push((0.30 * d, FaultEvent::SurgeStart { factor: cfg.surge_factor }));
        faults.push((0.85 * d, FaultEvent::SurgeEnd));
    }
    faults.sort_by(|a, b| a.0.total_cmp(&b.0));
    faults
}

/// Run one governed co-sim cell on a caller-supplied kernel: wire the
/// GPO/controllers from the scenario (seeded with its HFLOP plan, like
/// `interference::run`), install `policy` behind the learning
/// controller's governor, and run to the horizon.
pub fn run_cell(
    sc: &Scenario,
    cfg: &BudgetCellConfig,
    policy: BudgetPolicy,
    kernel: Kernel<CoEvent>,
) -> anyhow::Result<(CoSimOutcome, Kernel<CoEvent>)> {
    let n = sc.topo.n_devices();
    let m = sc.topo.n_edges();
    let lambdas: Vec<f64> = sc.lambdas().iter().map(|l| l * cfg.lambda_scale).collect();
    let caps = sc.capacities();

    let mut gpo = Gpo::new();
    for dev in &sc.topo.devices {
        gpo.register_device(dev.id, dev.location);
    }
    for edge in &sc.topo.edges {
        gpo.register_edge(edge.id, edge.location, edge.capacity);
    }

    let mut learning = LearningController::new(LearningCtlConfig {
        l: sc.cfg.l,
        solve: cfg.solve.clone(),
        strategy: cfg.resolve,
        ..Default::default()
    });
    learning.governor = BudgetGovernor::new(ActionCostModel::for_model(cfg.model_bytes), policy);
    for (dev, &l) in lambdas.iter().enumerate() {
        learning.set_lambda(dev, l);
    }
    learning.seed_plan(DeploymentPlan {
        assignment: sc.assign_hflop.clone(),
        edge_ids: (0..m).collect(),
        device_ids: (0..n).collect(),
        cost: sc.hflop_cost,
        proven_optimal: sc.hflop_optimal,
    });

    let faults = fault_schedule(cfg, sc, &lambdas);
    let control = ControlPlane::new(
        gpo,
        learning,
        InferenceController::new(InferenceCtlConfig::default()),
        ControlConfig {
            monitor_period_s: 2.0,
            report_delay_s: 3.0,
            drift: DriftModel { fresh_mse: 0.02, drift_per_s: 0.0 },
            resolve_on_recover: true,
        },
    );

    Ok(run_cell_reusing(
        CoSimConfig {
            serving: ServingConfig {
                assign: sc.assign_hflop.assign.clone(),
                lambda: lambdas,
                capacity: caps,
                latency: LatencyModel::default(),
                duration_s: cfg.duration_s,
                queue_window_s: 0.05,
                seed: cfg.seed,
            },
            interference_factor: cfg.interference_factor,
            training: TrainingConfig {
                schedule: TrainingSchedule::Periodic {
                    start_s: 0.1 * cfg.duration_s,
                    gap_s: (0.05 * cfg.duration_s).max(1.0),
                },
                time_model: RoundTimeModel::default(),
                epochs: 5,
                model_bytes: cfg.model_bytes,
            },
            faults,
            bucket_s: 10.0,
            record_trace: false,
            arrivals: ArrivalModel::PerDevicePoisson,
        },
        Some(control),
        kernel,
    ))
}

/// Registry port. Each run reports the budgeted co-sim (standard
/// serving + orchestration keys) and the regret/bytes-saved comparison
/// against the unbudgeted oracle — the sweep-cell path the
/// `SweepGrid::budget` grid drives with per-cell seeds.
pub struct BudgetExperiment;

const SCHEMA: &[ParamSpec] = &[
    ParamSpec { key: "clients", default: ParamDefault::Int(20), help: "FL clients / devices" },
    ParamSpec { key: "edges", default: ParamDefault::Int(4), help: "candidate edge hosts" },
    ParamSpec { key: "weeks", default: ParamDefault::Int(5), help: "synthetic dataset length" },
    ParamSpec {
        key: "balanced",
        default: ParamDefault::Bool(false),
        help: "balanced client placement",
    },
    ParamSpec { key: "scenario_seed", default: ParamDefault::Int(42), help: "scenario seed" },
    ParamSpec { key: "data_seed", default: ParamDefault::Int(1234), help: "dataset seed" },
    ParamSpec {
        key: "duration_s",
        default: ParamDefault::Float(240.0),
        help: "simulated co-sim horizon (s)",
    },
    ParamSpec {
        key: "interference_factor",
        default: ParamDefault::Float(0.25),
        help: "serving-capacity multiplier while an edge trains",
    },
    ParamSpec {
        key: "lambda_scale",
        default: ParamDefault::Float(1.0),
        help: "scale factor on every lambda_i",
    },
    ParamSpec {
        key: "model_bytes",
        default: ParamDefault::Int(262_144),
        help: "model transfer size (redistribution pricing + round timing)",
    },
    ParamSpec {
        key: "ls_mode",
        default: ParamDefault::Str("auto"),
        help: "control-plane re-solve engine: auto|completion|incremental",
    },
    ParamSpec {
        key: "resolve_strategy",
        default: ParamDefault::Str("auto"),
        help: "control-plane re-solve strategy: full|warm|auto",
    },
    ParamSpec {
        key: "fault_rate",
        default: ParamDefault::Int(2),
        help: "edge fail/recover cycles over the horizon (the fault-rate axis)",
    },
    ParamSpec {
        key: "surge_factor",
        default: ParamDefault::Float(1.0),
        help: "mid-run lambda surge multiplier; 1 = no surge (the surge axis)",
    },
    ParamSpec {
        key: "budget_mb",
        default: ParamDefault::Float(8.0),
        help: "hard cumulative reconfiguration cap in MB; 0 = uncapped (the budget axis)",
    },
    ParamSpec {
        key: "refill_mb",
        default: ParamDefault::Float(0.0),
        help: "token-bucket refill per epoch in MB; 0 = no bucket",
    },
    ParamSpec {
        key: "refill_epoch_s",
        default: ParamDefault::Float(30.0),
        help: "token-bucket epoch length (s)",
    },
    ParamSpec {
        key: "burst_mb",
        default: ParamDefault::Float(0.0),
        help: "token-bucket burst ceiling in MB; 0 = one refill",
    },
    ParamSpec {
        key: "seed",
        default: ParamDefault::Int(7),
        help: "co-simulation seed (the sweep writes the cell seed here)",
    },
];

/// Guarded MB→bytes conversion (params are floats; negative, NaN and
/// absurd values clamp to a sane byte count).
fn mb_to_bytes(mb: f64) -> u64 {
    (mb * 1e6).clamp(0.0, 1e18) as u64
}

/// Build the budgeted policy from params; all-zero knobs = unlimited.
fn policy_from(budget_mb: f64, refill_mb: f64, refill_epoch_s: f64, burst_mb: f64) -> BudgetPolicy {
    let mut policy = BudgetPolicy::unlimited();
    if budget_mb > 0.0 {
        policy.cap_bytes = Some(mb_to_bytes(budget_mb));
    }
    if refill_mb > 0.0 {
        let refill = mb_to_bytes(refill_mb);
        let burst = if burst_mb > 0.0 { mb_to_bytes(burst_mb) } else { refill };
        policy = policy.with_bucket(TokenBucket::new(refill, refill_epoch_s, burst));
    }
    policy
}

fn scenario_from(ctx: &ExperimentCtx) -> anyhow::Result<Scenario> {
    Scenario::build(ScenarioConfig {
        n_clients: ctx.params.usize("clients")?,
        n_edges: ctx.params.usize("edges")?,
        weeks: ctx.params.usize("weeks")?,
        balanced_clients: ctx.params.bool("balanced")?,
        seed: ctx.params.u64("scenario_seed")?,
        data_seed: ctx.params.u64("data_seed")?,
        ..Default::default()
    })
}

impl Experiment for BudgetExperiment {
    fn name(&self) -> &'static str {
        "budget"
    }

    fn describe(&self) -> &'static str {
        "budget-governed re-orchestration: comm spend, deferrals, p99 regret vs unbudgeted oracle"
    }

    fn param_schema(&self) -> &'static [ParamSpec] {
        SCHEMA
    }

    fn run(&self, ctx: &mut ExperimentCtx) -> anyhow::Result<Report> {
        let sc = scenario_from(ctx)?;
        let duration_s = ctx.f64_capped("duration_s", 60.0)?;
        let cfg = BudgetCellConfig {
            duration_s,
            interference_factor: ctx.params.f64("interference_factor")?,
            lambda_scale: ctx.params.f64("lambda_scale")?,
            model_bytes: ctx.params.usize("model_bytes")?,
            solve: solve_from_ls_mode(&ctx.params.str("ls_mode")?)?,
            resolve: ResolveStrategy::parse(&ctx.params.str("resolve_strategy")?)?,
            fault_rate: ctx.params.usize("fault_rate")?,
            surge_factor: ctx.params.f64("surge_factor")?,
            seed: ctx.params.u64("seed")?,
        };
        let policy = policy_from(
            ctx.params.f64("budget_mb")?,
            ctx.params.f64("refill_mb")?,
            ctx.params.f64("refill_epoch_s")?,
            ctx.params.f64("burst_mb")?,
        );
        let cap_bytes = policy.cap_bytes;

        // Same scenario, same seed, one kernel threaded through both
        // runs: the only difference is the governor's policy.
        let (oracle, kernel) = run_cell(&sc, &cfg, BudgetPolicy::unlimited(), Kernel::new())?;
        let (out, _) = run_cell(&sc, &cfg, policy, kernel)?;

        let mut report = Report::new("budget");
        cosim_summary(&mut report, &sc, &out, cfg.model_bytes);
        let regret_ms = out.serving.percentiles.p99() - oracle.serving.percentiles.p99();
        report.num("regret_ms", regret_ms);
        report.num("oracle_p99_ms", oracle.serving.percentiles.p99());
        report.num("oracle_spend_gb", oracle.ctl_spend_bytes as f64 / 1e9);
        report.num("oracle_plan_swaps", oracle.plan_swaps as f64);
        report.num(
            "bytes_saved_gb",
            oracle.ctl_spend_bytes.saturating_sub(out.ctl_spend_bytes) as f64 / 1e9,
        );
        report.num("ctl_telemetry_gb", out.ctl_telemetry_bytes as f64 / 1e9);
        report.num("budget_cap_gb", cap_bytes.map_or(0.0, |c| c as f64 / 1e9));
        let within = cap_bytes.map_or(true, |cap| out.ctl_spend_bytes <= cap);
        report.flag("within_cap", within);
        anyhow::ensure!(
            within,
            "budget invariant violated: spent {} bytes over a {:?}-byte cap",
            out.ctl_spend_bytes,
            cap_bytes
        );
        report.table(
            "budget_vs_oracle",
            &["budgeted", "spend_gb", "p99_ms", "plan_swaps", "deferrals"],
            vec![
                vec![
                    1.0,
                    out.ctl_spend_bytes as f64 / 1e9,
                    out.serving.percentiles.p99(),
                    out.plan_swaps as f64,
                    out.budget_deferrals as f64,
                ],
                vec![
                    0.0,
                    oracle.ctl_spend_bytes as f64 / 1e9,
                    oracle.serving.percentiles.p99(),
                    oracle.plan_swaps as f64,
                    oracle.budget_deferrals as f64,
                ],
            ],
        );
        ctx.say(|| {
            format!(
                "budget: spend {:.4} GB (oracle {:.4} GB), {} deferrals, p99 regret {:+.2} ms",
                out.ctl_spend_bytes as f64 / 1e9,
                oracle.ctl_spend_bytes as f64 / 1e9,
                out.budget_deferrals,
                regret_ms
            )
        });
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::{Params, Value};

    fn small_params() -> Params {
        let mut p = Params::defaults(BudgetExperiment.param_schema());
        p.set("clients", Value::Int(12)).unwrap();
        p.set("edges", Value::Int(3)).unwrap();
        p.set("duration_s", Value::Float(60.0)).unwrap();
        p.set("lambda_scale", Value::Float(0.5)).unwrap();
        p
    }

    #[test]
    fn end_to_end_spend_never_exceeds_cap_and_regret_is_reported() {
        // The acceptance invariant: under a finite budget the cumulative
        // comm spend stays under the cap while the p99 regret vs the
        // unbudgeted oracle is bounded and present in the JSON summary.
        let mut p = small_params();
        p.set("budget_mb", Value::Float(2.0)).unwrap();
        p.set("fault_rate", Value::Int(2)).unwrap();
        p.set("surge_factor", Value::Float(3.0)).unwrap();
        let mut ctx = ExperimentCtx::cell(p);
        let report = BudgetExperiment.run(&mut ctx).unwrap();
        let spend = report.get_f64("ctl_spend_gb").unwrap();
        let cap = report.get_f64("budget_cap_gb").unwrap();
        assert!(cap > 0.0);
        assert!(spend <= cap, "spend {spend} exceeds cap {cap}");
        let regret = report.get_f64("regret_ms").unwrap();
        assert!(regret.is_finite(), "regret must be a finite latency delta");
        assert!(regret.abs() < 10_000.0, "regret implausibly large: {regret}");
        assert!(report.get_f64("requests").unwrap() > 100.0, "sweep honesty keys present");
        assert!(report.get_f64("oracle_spend_gb").unwrap() >= spend);
        assert!(report.get_f64("bytes_saved_gb").unwrap() >= 0.0);
    }

    #[test]
    fn starved_budget_defers_and_saves_bytes() {
        let mut p = small_params();
        // 1 KB cap: no reconfiguration can ever be afforded.
        p.set("budget_mb", Value::Float(0.001)).unwrap();
        p.set("fault_rate", Value::Int(3)).unwrap();
        let report = BudgetExperiment.run(&mut ExperimentCtx::cell(p)).unwrap();
        assert_eq!(report.get_f64("ctl_spend_gb").unwrap(), 0.0);
        assert!(report.get_f64("budget_deferrals").unwrap() >= 1.0);
        assert!(
            report.get_f64("oracle_plan_swaps").unwrap() >= 1.0,
            "the oracle must actually reconfigure for the comparison to mean anything"
        );
        assert_eq!(
            report.get_f64("bytes_saved_gb").unwrap(),
            report.get_f64("oracle_spend_gb").unwrap(),
        );
    }

    #[test]
    fn unlimited_budget_has_zero_regret_by_construction() {
        // budget_mb = 0 disables the cap: the budgeted run IS the oracle
        // (same seed, same kernel reset), so regret must be exactly 0.
        let mut p = small_params();
        p.set("budget_mb", Value::Float(0.0)).unwrap();
        let report = BudgetExperiment.run(&mut ExperimentCtx::cell(p)).unwrap();
        assert_eq!(report.get_f64("regret_ms").unwrap(), 0.0);
        assert_eq!(report.get_f64("bytes_saved_gb").unwrap(), 0.0);
        assert_eq!(report.get_f64("budget_deferrals").unwrap(), 0.0);
    }

    #[test]
    fn report_is_deterministic_across_runs() {
        let run = || {
            let mut p = small_params();
            p.set("budget_mb", Value::Float(1.0)).unwrap();
            p.set("refill_mb", Value::Float(0.5)).unwrap();
            p.set("surge_factor", Value::Float(2.0)).unwrap();
            BudgetExperiment.run(&mut ExperimentCtx::cell(p)).unwrap().to_json().to_pretty()
        };
        assert_eq!(run(), run(), "budget cells must be bit-reproducible");
    }

    #[test]
    fn fault_schedule_is_sorted_and_scales_with_rate() {
        let sc = Scenario::build(ScenarioConfig {
            n_clients: 10,
            n_edges: 3,
            weeks: 5,
            balanced_clients: false,
            seed: 42,
            data_seed: 1234,
            ..Default::default()
        })
        .unwrap();
        let lambdas = sc.lambdas();
        let mut cfg = BudgetCellConfig { fault_rate: 3, surge_factor: 2.0, ..Default::default() };
        let faults = fault_schedule(&cfg, &sc, &lambdas);
        assert_eq!(faults.len(), 3 * 2 + 2, "3 cycles + surge window");
        assert!(faults.windows(2).all(|w| w[0].0 <= w[1].0), "schedule must be time-sorted");
        cfg.fault_rate = 0;
        cfg.surge_factor = 1.0;
        assert!(fault_schedule(&cfg, &sc, &lambdas).is_empty());
    }
}
