//! Fig. 8 — end-to-end latency vs edge→cloud compute speedup.
//!
//! §V-C3: "we compare the methods, considering a theoretical speedup of
//! up to 95%" for cloud hardware relative to edge hardware.
//! (a) baseline rates λ_i: latency is network-dominated, the speedup
//!     barely moves any curve, hierarchical methods stay far ahead;
//! (b) rates λ_i × 10: edges saturate; the flat (all-cloud) method
//!     benefits from the full speedup while the hierarchical ones only
//!     benefit on their spilled fraction — above a crossover speedup the
//!     non-hierarchical method wins (paper: 14.25%).

use crate::config::params::ParamSpec;
use crate::inference::trace::ArrivalModel;
use crate::inference::LatencyModel;

use super::fig7::{arrivals_from, run as run_fig7, Fig7Config};
use super::registry::{Experiment, ExperimentCtx, ParamDefault, Report};
use super::scenario::{Scenario, ScenarioConfig};

#[derive(Debug, Clone)]
pub struct Fig8Row {
    pub speedup: f64,
    pub flat_ms: f64,
    pub location_ms: f64,
    pub hflop_ms: f64,
}

#[derive(Debug, Clone)]
pub struct Fig8Config {
    /// Base latency model; `edge_service_ms` here is the *cloud-class
    /// service time at speedup 0* (§V-C3 makes compute non-negligible).
    pub latency: LatencyModel,
    pub duration_s: f64,
    pub queue_window_s: f64,
    pub seed: u64,
    pub lambda_scale: f64,
    pub speedups: Vec<f64>,
    /// Arrival generation, threaded through every speedup point.
    pub arrivals: ArrivalModel,
}

impl Default for Fig8Config {
    fn default() -> Self {
        Fig8Config {
            latency: LatencyModel {
                // Compute-heavy serving regime of the speedup study.
                edge_service_ms: 25.0,
                ..LatencyModel::default()
            },
            duration_s: 60.0,
            queue_window_s: 0.05,
            seed: 11,
            lambda_scale: 1.0,
            speedups: (0..=19).map(|i| i as f64 * 0.05).collect(),
            arrivals: ArrivalModel::PerDevicePoisson,
        }
    }
}

/// Sweep the speedup axis.
pub fn run(sc: &Scenario, cfg: &Fig8Config) -> Vec<Fig8Row> {
    cfg.speedups
        .iter()
        .map(|&sp| {
            let f7 = Fig7Config {
                latency: cfg.latency.clone().with_speedup(sp.min(0.95)),
                duration_s: cfg.duration_s,
                queue_window_s: cfg.queue_window_s,
                seed: cfg.seed,
                lambda_scale: cfg.lambda_scale,
                arrivals: cfg.arrivals.clone(),
            };
            let r = run_fig7(sc, &f7);
            Fig8Row {
                speedup: sp,
                flat_ms: r.flat.latency.mean(),
                location_ms: r.location.latency.mean(),
                hflop_ms: r.hflop.latency.mean(),
            }
        })
        .collect()
}

/// First speedup at which the flat method beats both hierarchical ones
/// (the paper's 14.25% crossover in Fig. 8b); None if it never does.
pub fn crossover(rows: &[Fig8Row]) -> Option<f64> {
    rows.iter()
        .find(|r| r.flat_ms < r.location_ms && r.flat_ms < r.hflop_ms)
        .map(|r| r.speedup)
}

/// Registry port (DESIGN.md §5): both Fig. 8 panels — (a) base rates,
/// (b) rates × `lambda_scale_b` with the paper's crossover — on one
/// scenario. The `fig8` *sweep grid* does not use this experiment: it
/// re-expresses the speedup axis as `fig7` single-setup cells (see
/// `SweepGrid::fig8`), which is exactly what the pre-registry grid ran.
pub struct Fig8Experiment;

const SCHEMA: &[ParamSpec] = &[
    ParamSpec { key: "clients", default: ParamDefault::Int(20), help: "FL clients / devices" },
    ParamSpec { key: "edges", default: ParamDefault::Int(4), help: "candidate edge hosts" },
    ParamSpec { key: "weeks", default: ParamDefault::Int(5), help: "synthetic dataset length" },
    ParamSpec {
        key: "balanced",
        default: ParamDefault::Bool(false),
        help: "balanced client placement",
    },
    ParamSpec { key: "scenario_seed", default: ParamDefault::Int(42), help: "scenario seed" },
    ParamSpec { key: "data_seed", default: ParamDefault::Int(1234), help: "dataset seed" },
    ParamSpec {
        key: "duration_s",
        default: ParamDefault::Float(60.0),
        help: "simulated serving horizon per speedup point (s)",
    },
    ParamSpec { key: "seed", default: ParamDefault::Int(11), help: "serving-simulation seed" },
    ParamSpec {
        key: "edge_service_ms",
        default: ParamDefault::Float(25.0),
        help: "compute-heavy service time of the speedup study (ms)",
    },
    ParamSpec {
        key: "lambda_scale_b",
        default: ParamDefault::Float(10.0),
        help: "rate multiplier of panel (b), the saturated regime",
    },
    ParamSpec {
        key: "speedup_points",
        default: ParamDefault::Int(20),
        help: "points on the 0..0.95 speedup axis",
    },
    ParamSpec {
        key: "trace",
        default: ParamDefault::Str("none"),
        help: "open-loop arrival trace: none|constant|diurnal|flash-crowd|hotspot",
    },
    ParamSpec {
        key: "trace_peak",
        default: ParamDefault::Float(3.0),
        help: "trace peak rate multiplier (diurnal/flash-crowd/hotspot)",
    },
    ParamSpec {
        key: "trace_period_s",
        default: ParamDefault::Float(0.0),
        help: "diurnal period (s); 0 = one cycle over the horizon",
    },
    ParamSpec {
        key: "trace_chunk_s",
        default: ParamDefault::Float(10.0),
        help: "open-loop generation chunk (s)",
    },
];

impl Experiment for Fig8Experiment {
    fn name(&self) -> &'static str {
        "fig8"
    }

    fn describe(&self) -> &'static str {
        "end-to-end latency vs edge->cloud speedup, panels (a) and (b) with crossover"
    }

    fn param_schema(&self) -> &'static [ParamSpec] {
        SCHEMA
    }

    fn run(&self, ctx: &mut ExperimentCtx) -> anyhow::Result<Report> {
        let sc = Scenario::build(ScenarioConfig {
            n_clients: ctx.params.usize("clients")?,
            n_edges: ctx.params.usize("edges")?,
            weeks: ctx.params.usize("weeks")?,
            balanced_clients: ctx.params.bool("balanced")?,
            seed: ctx.params.u64("scenario_seed")?,
            data_seed: ctx.params.u64("data_seed")?,
            ..Default::default()
        })?;
        let n_points = ctx.usize_capped("speedup_points", 5)?.max(2);
        let duration_s = ctx.f64_capped("duration_s", 15.0)?;
        let speedups: Vec<f64> =
            (0..n_points).map(|i| 0.95 * i as f64 / (n_points - 1) as f64).collect();
        let base = Fig8Config {
            latency: LatencyModel {
                edge_service_ms: ctx.params.f64("edge_service_ms")?,
                ..LatencyModel::default()
            },
            duration_s,
            seed: ctx.params.u64("seed")?,
            speedups,
            arrivals: arrivals_from(ctx, duration_s)?,
            ..Fig8Config::default()
        };

        let mut report = Report::new("fig8");
        let lambda_b = ctx.params.f64("lambda_scale_b")?;
        for (panel, scale) in [("a", 1.0), ("b", lambda_b)] {
            let cfg = Fig8Config { lambda_scale: scale, ..base.clone() };
            let rows = run(&sc, &cfg);
            let cx = crossover(&rows);
            ctx.say(|| {
                format!("fig8{panel} (lambda x{scale}): crossover={cx:?} (paper 8b: 0.1425)")
            });
            match cx {
                Some(v) => report.num(&format!("crossover_{panel}"), v),
                None => report.put(&format!("crossover_{panel}"), crate::util::json::Json::Null),
            }
            report.table(
                &format!("fig8{panel}"),
                &["speedup", "flat_ms", "location_ms", "hflop_ms"],
                rows.iter()
                    .map(|r| vec![r.speedup, r.flat_ms, r.location_ms, r.hflop_ms])
                    .collect(),
            );
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::{Params, Value};
    use crate::experiments::scenario::ScenarioConfig;

    fn scenario() -> Scenario {
        Scenario::build(ScenarioConfig {
            n_clients: 20,
            n_edges: 4,
            weeks: 5,
            balanced_clients: false,
            ..Default::default()
        })
        .unwrap()
    }

    fn short(cfg: Fig8Config) -> Fig8Config {
        Fig8Config {
            duration_s: 20.0,
            speedups: vec![0.0, 0.25, 0.5, 0.75, 0.95],
            ..cfg
        }
    }

    #[test]
    fn fig8a_no_crossover_at_base_rates() {
        // Network-dominated: hierarchical stays ahead at every speedup.
        let sc = scenario();
        let mut cfg = short(Fig8Config::default());
        cfg.latency.edge_service_ms = 2.0; // light compute, like Fig. 7
        let rows = run(&sc, &cfg);
        assert_eq!(crossover(&rows), None);
        // Speedup barely moves the hierarchical curves.
        let h0 = rows.first().unwrap().hflop_ms;
        let h1 = rows.last().unwrap().hflop_ms;
        assert!((h0 - h1).abs() < 5.0, "{h0} vs {h1}");
    }

    #[test]
    fn fig8b_crossover_under_heavy_load() {
        // λ×10 + compute-heavy: flat must win above some speedup.
        let sc = scenario();
        let cfg = Fig8Config {
            lambda_scale: 10.0,
            ..short(Fig8Config::default())
        };
        let rows = run(&sc, &cfg);
        let cx = crossover(&rows);
        assert!(cx.is_some(), "no crossover found: {rows:?}");
        // Paper: 14.25% — ours must land in a low-to-mid band, not at 0
        // and not at the very end.
        let cx = cx.unwrap();
        assert!((0.0..=0.8).contains(&cx), "{cx}");
    }

    #[test]
    fn flat_curve_monotone_decreasing_in_speedup() {
        let sc = scenario();
        let rows = run(&sc, &short(Fig8Config::default()));
        for w in rows.windows(2) {
            assert!(w[1].flat_ms <= w[0].flat_ms + 2.0, "{w:?}");
        }
    }

    #[test]
    fn experiment_trait_emits_both_panels() {
        let mut p = Params::defaults(Fig8Experiment.param_schema());
        p.set("clients", Value::Int(12)).unwrap();
        p.set("edges", Value::Int(3)).unwrap();
        p.set("duration_s", Value::Float(10.0)).unwrap();
        p.set("speedup_points", Value::Int(3)).unwrap();
        let mut ctx = ExperimentCtx::cell(p);
        let report = Fig8Experiment.run(&mut ctx).unwrap();
        assert_eq!(report.tables.len(), 2);
        assert_eq!(report.tables[0].name, "fig8a");
        assert_eq!(report.tables[1].name, "fig8b");
        assert_eq!(report.tables[0].rows.len(), 3);
        // Both panels report a crossover entry (possibly null).
        assert!(report.summary.get("crossover_a").is_some());
        assert!(report.summary.get("crossover_b").is_some());
    }
}
