//! Fig. 8 — end-to-end latency vs edge→cloud compute speedup.
//!
//! §V-C3: "we compare the methods, considering a theoretical speedup of
//! up to 95%" for cloud hardware relative to edge hardware.
//! (a) baseline rates λ_i: latency is network-dominated, the speedup
//!     barely moves any curve, hierarchical methods stay far ahead;
//! (b) rates λ_i × 10: edges saturate; the flat (all-cloud) method
//!     benefits from the full speedup while the hierarchical ones only
//!     benefit on their spilled fraction — above a crossover speedup the
//!     non-hierarchical method wins (paper: 14.25%).

use super::fig7::{run as run_fig7, Fig7Config};
use super::scenario::Scenario;
use crate::inference::LatencyModel;

#[derive(Debug, Clone)]
pub struct Fig8Row {
    pub speedup: f64,
    pub flat_ms: f64,
    pub location_ms: f64,
    pub hflop_ms: f64,
}

#[derive(Debug, Clone)]
pub struct Fig8Config {
    /// Base latency model; `edge_service_ms` here is the *cloud-class
    /// service time at speedup 0* (§V-C3 makes compute non-negligible).
    pub latency: LatencyModel,
    pub duration_s: f64,
    pub queue_window_s: f64,
    pub seed: u64,
    pub lambda_scale: f64,
    pub speedups: Vec<f64>,
}

impl Default for Fig8Config {
    fn default() -> Self {
        Fig8Config {
            latency: LatencyModel {
                // Compute-heavy serving regime of the speedup study.
                edge_service_ms: 25.0,
                ..LatencyModel::default()
            },
            duration_s: 60.0,
            queue_window_s: 0.05,
            seed: 11,
            lambda_scale: 1.0,
            speedups: (0..=19).map(|i| i as f64 * 0.05).collect(),
        }
    }
}

/// Sweep the speedup axis.
pub fn run(sc: &Scenario, cfg: &Fig8Config) -> Vec<Fig8Row> {
    cfg.speedups
        .iter()
        .map(|&sp| {
            let f7 = Fig7Config {
                latency: cfg.latency.clone().with_speedup(sp.min(0.95)),
                duration_s: cfg.duration_s,
                queue_window_s: cfg.queue_window_s,
                seed: cfg.seed,
                lambda_scale: cfg.lambda_scale,
            };
            let r = run_fig7(sc, &f7);
            Fig8Row {
                speedup: sp,
                flat_ms: r.flat.latency.mean(),
                location_ms: r.location.latency.mean(),
                hflop_ms: r.hflop.latency.mean(),
            }
        })
        .collect()
}

/// First speedup at which the flat method beats both hierarchical ones
/// (the paper's 14.25% crossover in Fig. 8b); None if it never does.
pub fn crossover(rows: &[Fig8Row]) -> Option<f64> {
    rows.iter()
        .find(|r| r.flat_ms < r.location_ms && r.flat_ms < r.hflop_ms)
        .map(|r| r.speedup)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::scenario::ScenarioConfig;

    fn scenario() -> Scenario {
        Scenario::build(ScenarioConfig {
            n_clients: 20,
            n_edges: 4,
            weeks: 5,
            balanced_clients: false,
            ..Default::default()
        })
        .unwrap()
    }

    fn short(cfg: Fig8Config) -> Fig8Config {
        Fig8Config {
            duration_s: 20.0,
            speedups: vec![0.0, 0.25, 0.5, 0.75, 0.95],
            ..cfg
        }
    }

    #[test]
    fn fig8a_no_crossover_at_base_rates() {
        // Network-dominated: hierarchical stays ahead at every speedup.
        let sc = scenario();
        let mut cfg = short(Fig8Config::default());
        cfg.latency.edge_service_ms = 2.0; // light compute, like Fig. 7
        let rows = run(&sc, &cfg);
        assert_eq!(crossover(&rows), None);
        // Speedup barely moves the hierarchical curves.
        let h0 = rows.first().unwrap().hflop_ms;
        let h1 = rows.last().unwrap().hflop_ms;
        assert!((h0 - h1).abs() < 5.0, "{h0} vs {h1}");
    }

    #[test]
    fn fig8b_crossover_under_heavy_load() {
        // λ×10 + compute-heavy: flat must win above some speedup.
        let sc = scenario();
        let cfg = Fig8Config {
            lambda_scale: 10.0,
            ..short(Fig8Config::default())
        };
        let rows = run(&sc, &cfg);
        let cx = crossover(&rows);
        assert!(cx.is_some(), "no crossover found: {rows:?}");
        // Paper: 14.25% — ours must land in a low-to-mid band, not at 0
        // and not at the very end.
        let cx = cx.unwrap();
        assert!((0.0..=0.8).contains(&cx), "{cx}");
    }

    #[test]
    fn flat_curve_monotone_decreasing_in_speedup() {
        let sc = scenario();
        let rows = run(&sc, &short(Fig8Config::default()));
        for w in rows.windows(2) {
            assert!(w[1].flat_ms <= w[0].flat_ms + 2.0, "{w:?}");
        }
    }
}
