//! Fig. 6 — per-client MSE over aggregation rounds for (a) flat FL,
//! (b) location-clustered HFL, (c) HFLOP HFL. 20 clients (5 per
//! cluster), 5 local epochs, 4 edge servers, l = 2, sliding window per
//! round. The paper's observations to reproduce: all three setups
//! converge after ~20 rounds to comparable MSE (hierarchy does not hurt
//! accuracy), with mild oscillation later as the data drifts.

use super::scenario::Scenario;
use crate::config::Setup;
use crate::data::window::{ClientData, ContinualWindow, WindowSpec};
use crate::fl::{Client, ContinualHfl, FlConfig, Hierarchy, ModelRuntime};
use crate::metrics::cost::CommLedger;
use crate::metrics::MseCurves;

/// Outcome of one setup's training run.
pub struct Fig6Run {
    pub setup: Setup,
    pub curves: MseCurves,
    pub ledger: CommLedger,
    pub mean_final_mse: f32,
    pub rounds_to_converge: Option<usize>,
}

/// Build the per-setup hierarchy from a scenario.
pub fn hierarchy_for(sc: &Scenario, setup: Setup) -> Hierarchy {
    match setup {
        Setup::Flat => Hierarchy::flat(sc.topo.n_devices()),
        Setup::LocationClustered => Hierarchy::from_assignment(&sc.assign_location),
        Setup::Hflop | Setup::HflopUncapacitated => Hierarchy::from_assignment(&sc.assign_hflop),
    }
}

/// Build FL clients holding each scenario client's sensor data.
pub fn build_clients(
    sc: &Scenario,
    rt: &dyn ModelRuntime,
    train_span: (usize, usize),
    seed: u64,
) -> Vec<Client> {
    sc.client_sensors
        .iter()
        .enumerate()
        .map(|(id, &sensor)| {
            let raw = &sc.dataset.series[sensor];
            let data = ClientData::new(
                raw,
                WindowSpec { seq_len: rt.seq_len(), horizon: 1 },
                train_span,
            );
            Client::new(id, data, seed)
        })
        .collect()
}

/// Rounds until the mean curve first comes within 10% of its final
/// converged level (the paper's "converges after about 20 rounds").
pub fn rounds_to_converge(curves: &MseCurves) -> Option<usize> {
    let n = curves.n_rounds();
    if n < 4 {
        return None;
    }
    let final_level = curves.converged_mean(n / 4);
    (0..n).find(|&r| curves.mean_at(r) <= final_level * 1.1)
}

/// Run one setup.
pub fn run_setup(
    sc: &Scenario,
    rt: &dyn ModelRuntime,
    setup: Setup,
    fl: FlConfig,
    window: ContinualWindow,
    init_params: Vec<f32>,
    seed: u64,
) -> anyhow::Result<Fig6Run> {
    let hierarchy = hierarchy_for(sc, setup);
    let clients = build_clients(sc, rt, window.train_range(), seed);
    let mut sys = ContinualHfl::new(
        rt,
        hierarchy,
        clients,
        window,
        fl,
        init_params,
        Some(&sc.inst),
    );
    sys.run()?;
    let mean_final = sys.curves.converged_mean(5);
    let conv = rounds_to_converge(&sys.curves);
    Ok(Fig6Run {
        setup,
        curves: sys.curves,
        ledger: sys.ledger,
        mean_final_mse: mean_final,
        rounds_to_converge: conv,
    })
}

/// Run all three setups with a shared runtime & schedule.
pub fn run_all(
    sc: &Scenario,
    rt: &dyn ModelRuntime,
    fl: FlConfig,
    window: ContinualWindow,
    init_params: Vec<f32>,
    seed: u64,
) -> anyhow::Result<Vec<Fig6Run>> {
    [Setup::Flat, Setup::LocationClustered, Setup::Hflop]
        .into_iter()
        .map(|s| run_setup(sc, rt, s, fl.clone(), window.clone(), init_params.clone(), seed))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::scenario::ScenarioConfig;
    use crate::fl::MockRuntime;

    fn scenario() -> Scenario {
        Scenario::build(ScenarioConfig {
            n_clients: 8,
            n_edges: 2,
            weeks: 5,
            ..Default::default()
        })
        .unwrap()
    }

    fn fl_cfg() -> FlConfig {
        FlConfig { epochs: 2, batches_per_epoch: 4, l: 2, lr: 0.05, rounds: 15, eval_every: 1 }
    }

    #[test]
    fn all_setups_converge_to_similar_mse() {
        // The paper's core Fig. 6 claim: hierarchy (b/c) does not hurt
        // accuracy relative to flat FL (a).
        let sc = scenario();
        let rt = MockRuntime::new(12, 8);
        let window = ContinualWindow::new(2000, 800, 50, sc.dataset.n_steps);
        let runs =
            run_all(&sc, &rt, fl_cfg(), window, vec![0.0; rt.n_params()], 3).unwrap();
        assert_eq!(runs.len(), 3);
        let finals: Vec<f32> = runs.iter().map(|r| r.mean_final_mse).collect();
        for r in &runs {
            // Training helped substantially in every setup.
            let first = r.curves.mean_at(0);
            assert!(
                r.mean_final_mse < first * 0.9,
                "{:?}: {first} -> {}",
                r.setup,
                r.mean_final_mse
            );
        }
        // Final MSEs within 2x of each other.
        let max = finals.iter().cloned().fold(f32::MIN, f32::max);
        let min = finals.iter().cloned().fold(f32::MAX, f32::min);
        assert!(max / min < 2.0, "{finals:?}");
    }

    #[test]
    fn hierarchical_cheaper_comm_than_flat() {
        let sc = scenario();
        let rt = MockRuntime::new(12, 8);
        let window = ContinualWindow::new(2000, 800, 50, sc.dataset.n_steps);
        let runs =
            run_all(&sc, &rt, fl_cfg(), window, vec![0.0; rt.n_params()], 3).unwrap();
        let flat = &runs[0];
        let hflop = &runs[2];
        assert!(
            hflop.ledger.total_bytes() < flat.ledger.total_bytes(),
            "hflop {} flat {}",
            hflop.ledger.total_bytes(),
            flat.ledger.total_bytes()
        );
    }

    #[test]
    fn convergence_detection_reasonable() {
        let sc = scenario();
        let rt = MockRuntime::new(12, 8);
        let window = ContinualWindow::new(2000, 800, 50, sc.dataset.n_steps);
        let run = run_setup(
            &sc,
            &rt,
            Setup::Hflop,
            fl_cfg(),
            window,
            vec![0.0; rt.n_params()],
            3,
        )
        .unwrap();
        let conv = run.rounds_to_converge.unwrap();
        assert!(conv < 15, "{conv}");
    }
}
