//! Fig. 6 — per-client MSE over aggregation rounds for (a) flat FL,
//! (b) location-clustered HFL, (c) HFLOP HFL. 20 clients (5 per
//! cluster), 5 local epochs, 4 edge servers, l = 2, sliding window per
//! round. The paper's observations to reproduce: all three setups
//! converge after ~20 rounds to comparable MSE (hierarchy does not hurt
//! accuracy), with mild oscillation later as the data drifts.

use crate::config::params::ParamSpec;
use crate::config::Setup;
use crate::data::window::{ClientData, ContinualWindow, WindowSpec};
use crate::fl::{Client, ContinualHfl, FlConfig, Hierarchy, MockRuntime, ModelRuntime};
use crate::metrics::cost::CommLedger;
use crate::metrics::MseCurves;

use super::registry::{runtime_gate, Experiment, ExperimentCtx, ParamDefault, Report};
use super::scenario::{Scenario, ScenarioConfig};

/// Outcome of one setup's training run.
pub struct Fig6Run {
    pub setup: Setup,
    pub curves: MseCurves,
    pub ledger: CommLedger,
    pub mean_final_mse: f32,
    pub rounds_to_converge: Option<usize>,
}

/// Build the per-setup hierarchy from a scenario.
pub fn hierarchy_for(sc: &Scenario, setup: Setup) -> Hierarchy {
    match setup {
        Setup::Flat => Hierarchy::flat(sc.topo.n_devices()),
        Setup::LocationClustered => Hierarchy::from_assignment(&sc.assign_location),
        Setup::Hflop | Setup::HflopUncapacitated => Hierarchy::from_assignment(&sc.assign_hflop),
    }
}

/// Build FL clients holding each scenario client's sensor data.
pub fn build_clients(
    sc: &Scenario,
    rt: &dyn ModelRuntime,
    train_span: (usize, usize),
    seed: u64,
) -> Vec<Client> {
    sc.client_sensors
        .iter()
        .enumerate()
        .map(|(id, &sensor)| {
            let raw = &sc.dataset.series[sensor];
            let data = ClientData::new(
                raw,
                WindowSpec { seq_len: rt.seq_len(), horizon: 1 },
                train_span,
            );
            Client::new(id, data, seed)
        })
        .collect()
}

/// Rounds until the mean curve first comes within 10% of its final
/// converged level (the paper's "converges after about 20 rounds").
pub fn rounds_to_converge(curves: &MseCurves) -> Option<usize> {
    let n = curves.n_rounds();
    if n < 4 {
        return None;
    }
    let final_level = curves.converged_mean(n / 4);
    (0..n).find(|&r| curves.mean_at(r) <= final_level * 1.1)
}

/// Run one setup.
pub fn run_setup(
    sc: &Scenario,
    rt: &dyn ModelRuntime,
    setup: Setup,
    fl: FlConfig,
    window: ContinualWindow,
    init_params: Vec<f32>,
    seed: u64,
) -> anyhow::Result<Fig6Run> {
    let hierarchy = hierarchy_for(sc, setup);
    let clients = build_clients(sc, rt, window.train_range(), seed);
    let mut sys = ContinualHfl::new(
        rt,
        hierarchy,
        clients,
        window,
        fl,
        init_params,
        Some(&sc.inst),
    );
    sys.run()?;
    let mean_final = sys.curves.converged_mean(5);
    let conv = rounds_to_converge(&sys.curves);
    Ok(Fig6Run {
        setup,
        curves: sys.curves,
        ledger: sys.ledger,
        mean_final_mse: mean_final,
        rounds_to_converge: conv,
    })
}

/// Run all three setups with a shared runtime & schedule.
pub fn run_all(
    sc: &Scenario,
    rt: &dyn ModelRuntime,
    fl: FlConfig,
    window: ContinualWindow,
    init_params: Vec<f32>,
    seed: u64,
) -> anyhow::Result<Vec<Fig6Run>> {
    [Setup::Flat, Setup::LocationClustered, Setup::Hflop]
        .into_iter()
        .map(|s| run_setup(sc, rt, s, fl.clone(), window.clone(), init_params.clone(), seed))
        .collect()
}

/// Registry port (DESIGN.md §5). The `runtime` parameter gates what
/// backs the MSE curves:
///
/// * `"real"` — the PJRT engine over the AOT GRU artifacts (errors when
///   the artifacts / `pjrt` feature are absent); artifact `fig6.csv`.
/// * `"mock"` — the linear [`MockRuntime`]. The MSE values are synthetic
///   (a harness check, **not** a paper artifact), so the run is loudly
///   marked: artifact `fig6_mock.csv`, summary `runtime = "mock"` /
///   `mock = true`, and a stderr warning.
/// * `"auto"` (default) — try real, fall back to mock with the warning.
pub struct Fig6Experiment;

const SCHEMA: &[ParamSpec] = &[
    ParamSpec {
        key: "runtime",
        default: ParamDefault::Str("auto"),
        help: "auto|real|mock — real PJRT GRU, or the clearly-marked linear mock",
    },
    ParamSpec {
        key: "variant",
        default: ParamDefault::Str("small"),
        help: "model variant from the artifact manifest (real runtime)",
    },
    ParamSpec { key: "clients", default: ParamDefault::Int(20), help: "FL clients" },
    ParamSpec { key: "edges", default: ParamDefault::Int(4), help: "edge servers / clusters" },
    ParamSpec { key: "weeks", default: ParamDefault::Int(6), help: "synthetic dataset length" },
    ParamSpec {
        key: "balanced",
        default: ParamDefault::Bool(true),
        help: "balanced client placement (paper: 5 per cluster)",
    },
    ParamSpec { key: "scenario_seed", default: ParamDefault::Int(42), help: "scenario seed" },
    ParamSpec { key: "data_seed", default: ParamDefault::Int(1234), help: "dataset seed" },
    ParamSpec { key: "rounds", default: ParamDefault::Int(40), help: "aggregation rounds" },
    ParamSpec { key: "epochs", default: ParamDefault::Int(2), help: "local epochs per round" },
    ParamSpec {
        key: "batches",
        default: ParamDefault::Int(4),
        help: "batches per local epoch",
    },
    ParamSpec { key: "l", default: ParamDefault::Int(2), help: "local rounds per global round" },
    ParamSpec { key: "lr", default: ParamDefault::Float(0.05), help: "learning rate" },
    ParamSpec {
        key: "shift",
        default: ParamDefault::Int(288),
        help: "window shift per round (timesteps; 288 = one day)",
    },
    ParamSpec { key: "seed", default: ParamDefault::Int(3), help: "client-sampling seed" },
];

const MOCK_WARNING: &str = "fig6: MOCK runtime — synthetic linear-model MSE, clearly marked \
                            (fig6_mock.csv, summary mock=true); NOT a paper artifact. Build the \
                            PJRT artifacts and pass --set runtime=real for the real curves.";

impl Experiment for Fig6Experiment {
    fn name(&self) -> &'static str {
        "fig6"
    }

    fn describe(&self) -> &'static str {
        "per-client MSE curves over rounds, 3 setups, continual HFL"
    }

    fn param_schema(&self) -> &'static [ParamSpec] {
        SCHEMA
    }

    fn run(&self, ctx: &mut ExperimentCtx) -> anyhow::Result<Report> {
        let sc = Scenario::build(ScenarioConfig {
            n_clients: ctx.params.usize("clients")?,
            n_edges: ctx.params.usize("edges")?,
            weeks: ctx.params.usize("weeks")?,
            balanced_clients: ctx.params.bool("balanced")?,
            seed: ctx.params.u64("scenario_seed")?,
            data_seed: ctx.params.u64("data_seed")?,
            ..Default::default()
        })?;
        let fl = FlConfig {
            epochs: ctx.params.usize("epochs")?,
            batches_per_epoch: ctx.params.usize("batches")?,
            l: ctx.params.usize("l")?,
            lr: ctx.params.f64("lr")? as f32,
            rounds: ctx.usize_capped("rounds", 8)?,
            eval_every: 1,
        };
        let window = ContinualWindow::paper(sc.dataset.n_steps, ctx.params.usize("shift")?);
        let seed = ctx.params.u64("seed")?;

        // --- runtime gate (mock results must be unmistakable) -----------
        let real = runtime_gate(ctx, "fig6")?;
        let mock = MockRuntime::new(12, 16);
        let (runs, runtime_name) = match &real {
            Some((manifest, engine)) => {
                let init = manifest.load_init_params(engine.variant())?;
                (run_all(&sc, engine, fl, window, init, seed)?, "real")
            }
            None => {
                eprintln!("{MOCK_WARNING}");
                let init = vec![0.0f32; mock.n_params()];
                (run_all(&sc, &mock, fl, window, init, seed)?, "mock")
            }
        };

        let mut report = Report::new("fig6");
        if runtime_name == "mock" {
            report.set_stem("fig6_mock");
        }
        report.text("runtime", runtime_name);
        report.flag("mock", runtime_name == "mock");
        let mut rows = Vec::new();
        for r in &runs {
            ctx.say(|| {
                format!(
                    "{:<10} final_mse={:.5} converged_at={:?} comm={:.4} GB",
                    r.setup.name(),
                    r.mean_final_mse,
                    r.rounds_to_converge,
                    r.ledger.total_gb()
                )
            });
            let key = r.setup.name().replace('-', "_");
            report.num(&format!("{key}_final_mse"), r.mean_final_mse as f64);
            report.num(&format!("{key}_comm_gb"), r.ledger.total_gb());
            let setup_id = match r.setup {
                Setup::Flat => 0.0,
                Setup::LocationClustered => 1.0,
                _ => 2.0,
            };
            for round in 0..r.curves.n_rounds() {
                rows.push(vec![setup_id, round as f64, r.curves.mean_at(round) as f64]);
            }
        }
        let stem = report.stem.clone();
        report.table(&stem, &["setup", "round", "mean_mse"], rows);
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::scenario::ScenarioConfig;
    use crate::fl::MockRuntime;

    fn scenario() -> Scenario {
        Scenario::build(ScenarioConfig {
            n_clients: 8,
            n_edges: 2,
            weeks: 5,
            ..Default::default()
        })
        .unwrap()
    }

    fn fl_cfg() -> FlConfig {
        FlConfig { epochs: 2, batches_per_epoch: 4, l: 2, lr: 0.05, rounds: 15, eval_every: 1 }
    }

    #[test]
    fn all_setups_converge_to_similar_mse() {
        // The paper's core Fig. 6 claim: hierarchy (b/c) does not hurt
        // accuracy relative to flat FL (a).
        let sc = scenario();
        let rt = MockRuntime::new(12, 8);
        let window = ContinualWindow::new(2000, 800, 50, sc.dataset.n_steps);
        let runs =
            run_all(&sc, &rt, fl_cfg(), window, vec![0.0; rt.n_params()], 3).unwrap();
        assert_eq!(runs.len(), 3);
        let finals: Vec<f32> = runs.iter().map(|r| r.mean_final_mse).collect();
        for r in &runs {
            // Training helped substantially in every setup.
            let first = r.curves.mean_at(0);
            assert!(
                r.mean_final_mse < first * 0.9,
                "{:?}: {first} -> {}",
                r.setup,
                r.mean_final_mse
            );
        }
        // Final MSEs within 2x of each other.
        let max = finals.iter().cloned().fold(f32::MIN, f32::max);
        let min = finals.iter().cloned().fold(f32::MAX, f32::min);
        assert!(max / min < 2.0, "{finals:?}");
    }

    #[test]
    fn hierarchical_cheaper_comm_than_flat() {
        let sc = scenario();
        let rt = MockRuntime::new(12, 8);
        let window = ContinualWindow::new(2000, 800, 50, sc.dataset.n_steps);
        let runs =
            run_all(&sc, &rt, fl_cfg(), window, vec![0.0; rt.n_params()], 3).unwrap();
        let flat = &runs[0];
        let hflop = &runs[2];
        assert!(
            hflop.ledger.total_bytes() < flat.ledger.total_bytes(),
            "hflop {} flat {}",
            hflop.ledger.total_bytes(),
            flat.ledger.total_bytes()
        );
    }

    #[test]
    fn experiment_trait_mock_run_is_clearly_marked() {
        use crate::config::params::{Params, Value};
        let mut p = Params::defaults(Fig6Experiment.param_schema());
        p.set("runtime", Value::Str("mock".into())).unwrap();
        p.set("clients", Value::Int(8)).unwrap();
        p.set("edges", Value::Int(2)).unwrap();
        p.set("weeks", Value::Int(5)).unwrap();
        p.set("rounds", Value::Int(6)).unwrap();
        let mut ctx = ExperimentCtx::cell(p);
        let report = Fig6Experiment.run(&mut ctx).unwrap();
        // The mock gate: artifact stem, summary flag and table name all
        // scream "mock" so the CSV can't pass for a paper artifact.
        assert_eq!(report.stem, "fig6_mock");
        assert_eq!(report.summary.get("mock").unwrap().as_bool(), Some(true));
        assert_eq!(report.summary.get("runtime").unwrap().as_str(), Some("mock"));
        assert_eq!(report.tables[0].name, "fig6_mock");
        assert!(report.get_f64("hflop_final_mse").unwrap() > 0.0);
    }

    #[test]
    fn experiment_trait_real_runtime_hard_errors_without_artifacts() {
        use crate::config::params::{Params, Value};
        // Without the pjrt feature/artifacts, runtime=real must fail
        // loudly rather than silently substitute the mock.
        if cfg!(feature = "pjrt") {
            return;
        }
        let mut p = Params::defaults(Fig6Experiment.param_schema());
        p.set("runtime", Value::Str("real".into())).unwrap();
        p.set("clients", Value::Int(8)).unwrap();
        p.set("edges", Value::Int(2)).unwrap();
        p.set("weeks", Value::Int(5)).unwrap();
        assert!(Fig6Experiment.run(&mut ExperimentCtx::cell(p)).is_err());
    }

    #[test]
    fn convergence_detection_reasonable() {
        let sc = scenario();
        let rt = MockRuntime::new(12, 8);
        let window = ContinualWindow::new(2000, 800, 50, sc.dataset.n_steps);
        let run = run_setup(
            &sc,
            &rt,
            Setup::Hflop,
            fl_cfg(),
            window,
            vec![0.0; rt.n_params()],
            3,
        )
        .unwrap();
        let conv = run.rounds_to_converge.unwrap();
        assert!(conv < 15, "{conv}");
    }
}
