//! Deterministic parallel scenario-sweep engine.
//!
//! Every headline artifact of the paper (Figs. 6–9, the §V-B1 table) is
//! a *sweep*: many (scenario × seed × solver mode × environment) cells.
//! This module turns that shape into a first-class engine:
//!
//! * [`SweepGrid`] declares the grid — rows (static Fig. 7/8 setups or
//!   `interference` co-sim presets) × a seed range × solver [`LsMode`] ×
//!   environment configs (interference factor / speedup / λ-scale);
//! * [`run_grid`] fans the cells over a scoped worker pool
//!   (`util::pool`), reusing the PR 2 co-sim kernel and the PR 1
//!   incremental solver inside each cell;
//! * every cell's RNG seed is **hashed from its grid coordinates**
//!   (`util::rng::mix_seed`) and each cell owns all of its state
//!   (`inference::cosim::run_cell`), so the assembled [`SweepMatrix`] —
//!   and its JSON — is **bit-identical regardless of worker count or
//!   completion order** (`rust/tests/sweep_determinism.rs` holds this at
//!   1, 2 and 8 workers, including under an injected slow cell);
//! * [`SweepMatrix::to_json`] serializes via `util::json` into the
//!   deterministic half of `BENCH_sweep.json` (cell wall-clock lives
//!   outside it, in the driver's timing object).
//!
//! Drivers: `hflop sweep` (CLI), `examples/sweep.rs`, and
//! `benches/bench_sweep.rs` (which records the serial-vs-parallel
//! wall-clock the ROADMAP's perf trajectory tracks).

use crate::experiments::interference::{self, InterferenceConfig, Preset};
use crate::experiments::scenario::{Scenario, ScenarioConfig};
use crate::inference::simulation::{simulate, ServingConfig};
use crate::inference::LatencyModel;
use crate::metrics::cost::{flat_fl_bytes, hfl_bytes};
use crate::solver::{LocalSearchOptions, LsMode, Mode, SolveOptions};
use crate::util::json::Json;
use crate::util::pool;
use crate::util::rng::mix_seed;

/// Which fixed assignment a static (serving-only) row simulates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StaticSetup {
    /// Flat FL: no aggregators, every request direct to cloud.
    Flat,
    /// Location-clustered (capacity-blind) assignment.
    Location,
    /// The scenario's HFLOP (capacity-aware) assignment.
    Hflop,
}

/// What one grid row runs per cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    /// The Fig. 7/8 static serving fast path.
    Static(StaticSetup),
    /// A joint-timeline co-simulation preset (orchestrator in the loop).
    Cosim(Preset),
}

/// One named grid row.
#[derive(Debug, Clone)]
pub struct RowSpec {
    pub name: &'static str,
    pub workload: Workload,
}

/// One environment configuration (the grid's fourth axis).
#[derive(Debug, Clone)]
pub struct EnvSpec {
    pub name: String,
    /// Serving-capacity multiplier while an edge trains (co-sim rows).
    pub interference_factor: f64,
    /// Edge→cloud compute speedup in [0, 0.95] (static rows, Fig. 8).
    pub speedup: f64,
    /// Scale factor on every λ_i.
    pub lambda_scale: f64,
}

impl Default for EnvSpec {
    fn default() -> Self {
        EnvSpec { name: "base".into(), interference_factor: 0.25, speedup: 0.0, lambda_scale: 1.0 }
    }
}

/// Stable short name for an [`LsMode`] axis entry.
pub fn mode_name(mode: LsMode) -> &'static str {
    match mode {
        LsMode::Auto => "auto",
        LsMode::Completion => "completion",
        LsMode::Incremental => "incremental",
    }
}

/// Solve options that pin the control plane's re-solves to one
/// local-search engine (the sweep's solver axis).
pub fn solve_options(mode: LsMode) -> SolveOptions {
    SolveOptions {
        mode: Mode::Heuristic,
        ls: LocalSearchOptions { mode, ..Default::default() },
        ..SolveOptions::exact()
    }
}

/// The declarative sweep: rows × seeds × solver modes × environments.
#[derive(Debug, Clone)]
pub struct SweepGrid {
    pub name: &'static str,
    /// Shared world built once per grid (all cells read it immutably).
    pub scenario: ScenarioConfig,
    pub rows: Vec<RowSpec>,
    /// Seed axis: scenario-replication seeds `seed_base..seed_base+n`.
    pub seed_base: u64,
    pub n_seeds: usize,
    pub modes: Vec<LsMode>,
    pub envs: Vec<EnvSpec>,
    /// Simulated wall time per cell (s).
    pub duration_s: f64,
    /// Serialized model size for comm-volume accounting.
    pub model_bytes: usize,
    /// Root of the per-cell seed derivation.
    pub root_seed: u64,
}

impl SweepGrid {
    /// The default grid: all four interference presets × 2 replication
    /// seeds × both local-search engines × two interference factors —
    /// 32 cells over the full co-sim (the acceptance grid).
    pub fn interference(root_seed: u64) -> SweepGrid {
        SweepGrid {
            name: "interference",
            scenario: ScenarioConfig {
                n_clients: 20,
                n_edges: 4,
                weeks: 5,
                balanced_clients: false,
                ..Default::default()
            },
            rows: Preset::ALL
                .iter()
                .map(|&p| RowSpec { name: p.name(), workload: Workload::Cosim(p) })
                .collect(),
            seed_base: 0,
            n_seeds: 2,
            modes: vec![LsMode::Completion, LsMode::Incremental],
            envs: vec![
                EnvSpec { name: "if0.25".into(), interference_factor: 0.25, ..Default::default() },
                EnvSpec { name: "if1.0".into(), interference_factor: 1.0, ..Default::default() },
            ],
            duration_s: 240.0,
            model_bytes: 4 * 65_536,
            root_seed,
        }
    }

    /// CI smoke grid: still ≥ 24 cells but a small world and a short
    /// horizon, so `sweep --smoke` finishes in seconds.
    pub fn smoke(root_seed: u64) -> SweepGrid {
        SweepGrid {
            name: "smoke",
            scenario: ScenarioConfig {
                n_clients: 12,
                n_edges: 3,
                weeks: 5,
                balanced_clients: false,
                ..Default::default()
            },
            n_seeds: 3,
            envs: vec![EnvSpec {
                name: "if0.25".into(),
                interference_factor: 0.25,
                lambda_scale: 0.5,
                ..Default::default()
            }],
            duration_s: 60.0,
            ..Self::interference(root_seed)
        }
    }

    /// Fig. 7 as grid rows: the three static setups × replication seeds.
    pub fn fig7(root_seed: u64) -> SweepGrid {
        SweepGrid {
            name: "fig7",
            scenario: ScenarioConfig {
                n_clients: 20,
                n_edges: 4,
                weeks: 5,
                balanced_clients: false,
                ..Default::default()
            },
            rows: vec![
                RowSpec { name: "flat", workload: Workload::Static(StaticSetup::Flat) },
                RowSpec { name: "location", workload: Workload::Static(StaticSetup::Location) },
                RowSpec { name: "hflop", workload: Workload::Static(StaticSetup::Hflop) },
            ],
            seed_base: 0,
            n_seeds: 6,
            modes: vec![LsMode::Auto],
            envs: vec![EnvSpec { interference_factor: 1.0, ..Default::default() }],
            duration_s: 120.0,
            model_bytes: 4 * 65_536,
            root_seed,
        }
    }

    /// Fig. 8b as grid rows: the three static setups × a speedup axis at
    /// λ×10 (the saturated regime with the paper's crossover).
    pub fn fig8(root_seed: u64) -> SweepGrid {
        SweepGrid {
            name: "fig8",
            n_seeds: 2,
            envs: (0..=5)
                .map(|i| {
                    let sp = i as f64 * 0.19;
                    EnvSpec {
                        name: format!("sp{sp:.2}"),
                        interference_factor: 1.0,
                        speedup: sp,
                        lambda_scale: 10.0,
                    }
                })
                .collect(),
            duration_s: 60.0,
            ..Self::fig7(root_seed)
        }
    }

    pub fn n_cells(&self) -> usize {
        self.rows.len() * self.n_seeds * self.modes.len() * self.envs.len()
    }

    /// Decode a flat cell index into `(row, seed, mode, env)` indices
    /// (row-major, the order cells appear in the matrix).
    pub fn coords(&self, idx: usize) -> (usize, usize, usize, usize) {
        assert!(idx < self.n_cells(), "cell index out of range");
        let e = idx % self.envs.len();
        let rest = idx / self.envs.len();
        let m = rest % self.modes.len();
        let rest = rest / self.modes.len();
        let s = rest % self.n_seeds;
        let r = rest / self.n_seeds;
        (r, s, m, e)
    }

    /// The cell's RNG seed, hashed from the root seed and the cell's
    /// grid coordinates — never from execution order.
    pub fn cell_seed(&self, r: usize, s: usize, m: usize, e: usize) -> u64 {
        mix_seed(self.root_seed, &[r as u64, self.seed_base + s as u64, m as u64, e as u64])
    }
}

/// Compact, fully deterministic outcome of one sweep cell.
#[derive(Debug, Clone)]
pub struct CellOutcome {
    pub row: usize,
    pub seed_idx: usize,
    pub mode_idx: usize,
    pub env_idx: usize,
    /// `row/s<seed>/<mode>/<env>`.
    pub label: String,
    pub cell_seed: u64,
    // --- serving (streaming moments + P² percentiles) -------------------
    pub requests: u64,
    pub served_at_edge: u64,
    pub spilled_to_cloud: u64,
    pub direct_to_cloud: u64,
    pub spill_fraction: f64,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
    // --- training / orchestration ---------------------------------------
    pub rounds_completed: usize,
    pub plan_swaps: usize,
    pub reclusters: usize,
    pub retrain_triggers: usize,
    pub events_processed: u64,
    pub events_cancelled: u64,
    // --- cost accounting -------------------------------------------------
    /// Eq. 1 communication cost of the cell's deployment plan.
    pub eq1_cost: f64,
    /// Predicted metered traffic (GB) for the cell's training activity.
    pub comm_gb: f64,
    /// Wall-clock seconds this cell took. Recorded for the bench report,
    /// EXCLUDED from [`CellOutcome::to_json`] — wall time varies run to
    /// run and must not break matrix bit-identity.
    pub wall_s: f64,
}

impl CellOutcome {
    /// Deterministic JSON view (everything except `wall_s`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("cell_seed", Json::Str(format!("{:016x}", self.cell_seed))),
            ("requests", Json::Num(self.requests as f64)),
            ("served_at_edge", Json::Num(self.served_at_edge as f64)),
            ("spilled_to_cloud", Json::Num(self.spilled_to_cloud as f64)),
            ("direct_to_cloud", Json::Num(self.direct_to_cloud as f64)),
            ("spill_fraction", Json::Num(self.spill_fraction)),
            ("mean_ms", Json::Num(self.mean_ms)),
            ("std_ms", Json::Num(self.std_ms)),
            ("min_ms", Json::Num(self.min_ms)),
            ("max_ms", Json::Num(self.max_ms)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p90_ms", Json::Num(self.p90_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
            ("rounds_completed", Json::Num(self.rounds_completed as f64)),
            ("plan_swaps", Json::Num(self.plan_swaps as f64)),
            ("reclusters", Json::Num(self.reclusters as f64)),
            ("retrain_triggers", Json::Num(self.retrain_triggers as f64)),
            ("events_processed", Json::Num(self.events_processed as f64)),
            ("events_cancelled", Json::Num(self.events_cancelled as f64)),
            ("eq1_cost", Json::Num(self.eq1_cost)),
            ("comm_gb", Json::Num(self.comm_gb)),
        ])
    }
}

/// The merged sweep result: one [`CellOutcome`] per grid cell, in grid
/// order (independent of which worker finished first).
#[derive(Debug, Clone)]
pub struct SweepMatrix {
    pub grid_name: String,
    pub root_seed: u64,
    pub row_names: Vec<String>,
    pub seeds: Vec<u64>,
    pub mode_names: Vec<String>,
    pub env_names: Vec<String>,
    pub duration_s: f64,
    pub cells: Vec<CellOutcome>,
}

impl SweepMatrix {
    /// The deterministic sweep artifact (the `matrix` half of
    /// `BENCH_sweep.json`): bit-identical for a given grid + root seed
    /// at any worker count.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "grid",
                Json::obj(vec![
                    ("name", Json::Str(self.grid_name.clone())),
                    ("root_seed", Json::Num(self.root_seed as f64)),
                    ("rows", str_arr(&self.row_names)),
                    (
                        "seeds",
                        Json::Arr(self.seeds.iter().map(|&s| Json::Num(s as f64)).collect()),
                    ),
                    ("modes", str_arr(&self.mode_names)),
                    ("envs", str_arr(&self.env_names)),
                    ("duration_s", Json::Num(self.duration_s)),
                    ("n_cells", Json::Num(self.cells.len() as f64)),
                ]),
            ),
            ("cells", Json::Arr(self.cells.iter().map(CellOutcome::to_json).collect())),
        ])
    }

    /// Sum of per-cell wall-clock (the work the pool parallelizes).
    pub fn total_cell_wall_s(&self) -> f64 {
        self.cells.iter().map(|c| c.wall_s).sum()
    }

    /// Per-row mean-latency summary for terminal reports.
    pub fn summary_rows(&self) -> Vec<Vec<String>> {
        let mut out = Vec::new();
        for (r, name) in self.row_names.iter().enumerate() {
            let cells: Vec<&CellOutcome> = self.cells.iter().filter(|c| c.row == r).collect();
            if cells.is_empty() {
                continue;
            }
            let n = cells.len() as f64;
            let mean = cells.iter().map(|c| c.mean_ms).sum::<f64>() / n;
            let p99 = cells.iter().map(|c| c.p99_ms).sum::<f64>() / n;
            let req: u64 = cells.iter().map(|c| c.requests).sum();
            let swaps: usize = cells.iter().map(|c| c.plan_swaps).sum();
            let rounds: usize = cells.iter().map(|c| c.rounds_completed).sum();
            out.push(vec![
                name.clone(),
                format!("{}", cells.len()),
                format!("{req}"),
                format!("{mean:.2}"),
                format!("{p99:.1}"),
                format!("{rounds}"),
                format!("{swaps}"),
            ]);
        }
        out
    }
}

fn str_arr(xs: &[String]) -> Json {
    Json::Arr(xs.iter().map(|s| Json::Str(s.clone())).collect())
}

/// Run one cell by flat index against the shared scenario. Pure in the
/// functional sense: output depends only on `(sc, grid, idx)`.
fn run_cell_at(sc: &Scenario, grid: &SweepGrid, idx: usize) -> anyhow::Result<CellOutcome> {
    let (r, s, m, e) = grid.coords(idx);
    let row = &grid.rows[r];
    let env = &grid.envs[e];
    let mode = grid.modes[m];
    let seed = grid.cell_seed(r, s, m, e);
    let label =
        format!("{}/s{}/{}/{}", row.name, grid.seed_base + s as u64, mode_name(mode), env.name);
    let t0 = std::time::Instant::now();

    let mut rounds_completed = 0usize;
    let mut plan_swaps = 0usize;
    let mut reclusters = 0usize;
    let mut retrain_triggers = 0usize;
    let mut events_processed = 0u64;
    let mut events_cancelled = 0u64;
    let serving = match row.workload {
        Workload::Static(setup) => {
            let assign = match setup {
                StaticSetup::Flat => vec![None; sc.topo.n_devices()],
                StaticSetup::Location => sc.assign_location.assign.clone(),
                StaticSetup::Hflop => sc.assign_hflop.assign.clone(),
            };
            let cfg = ServingConfig {
                assign,
                lambda: sc.lambdas().iter().map(|l| l * env.lambda_scale).collect(),
                capacity: sc.capacities(),
                latency: LatencyModel::default().with_speedup(env.speedup.min(0.95)),
                duration_s: grid.duration_s,
                queue_window_s: 0.05,
                seed,
            };
            simulate(&cfg)
        }
        Workload::Cosim(preset) => {
            let cfg = InterferenceConfig {
                preset,
                duration_s: grid.duration_s,
                interference_factor: env.interference_factor,
                lambda_scale: env.lambda_scale,
                model_bytes: grid.model_bytes,
                solve: solve_options(mode),
                seed,
                ..Default::default()
            };
            let out = interference::run(sc, &cfg)?;
            rounds_completed = out.rounds_completed;
            plan_swaps = out.plan_swaps;
            reclusters = out.reclusters;
            retrain_triggers = out.retrain_triggers;
            events_processed = out.events_processed;
            events_cancelled = out.events_cancelled;
            out.serving
        }
    };

    // Eq. 1 cost of the cell's (initial) deployment plan and the metered
    // traffic its training activity predicts (static rows use the
    // paper's nominal 100 aggregation rounds).
    let (eq1_cost, comm_rounds) = match row.workload {
        Workload::Static(StaticSetup::Flat) => (0.0, 100),
        Workload::Static(StaticSetup::Location) => (sc.assign_location.cost(&sc.inst), 100),
        Workload::Static(StaticSetup::Hflop) => (sc.hflop_cost, 100),
        Workload::Cosim(_) => (sc.hflop_cost, rounds_completed),
    };
    let comm_bytes = match row.workload {
        Workload::Static(StaticSetup::Flat) => {
            flat_fl_bytes(sc.topo.n_devices(), comm_rounds, grid.model_bytes)
        }
        Workload::Static(StaticSetup::Location) => {
            hfl_bytes(&sc.inst, &sc.assign_location, comm_rounds, grid.model_bytes)
        }
        _ => hfl_bytes(&sc.inst, &sc.assign_hflop, comm_rounds, grid.model_bytes),
    };

    Ok(CellOutcome {
        row: r,
        seed_idx: s,
        mode_idx: m,
        env_idx: e,
        label,
        cell_seed: seed,
        requests: serving.total(),
        served_at_edge: serving.served_at_edge,
        spilled_to_cloud: serving.spilled_to_cloud,
        direct_to_cloud: serving.direct_to_cloud,
        spill_fraction: serving.spill_fraction(),
        mean_ms: serving.latency.mean(),
        std_ms: serving.latency.std(),
        min_ms: serving.latency.min(),
        max_ms: serving.latency.max(),
        p50_ms: serving.percentiles.p50(),
        p90_ms: serving.percentiles.p90(),
        p99_ms: serving.percentiles.p99(),
        rounds_completed,
        plan_swaps,
        reclusters,
        retrain_triggers,
        events_processed,
        events_cancelled,
        eq1_cost,
        comm_gb: comm_bytes as f64 / 1e9,
        wall_s: t0.elapsed().as_secs_f64(),
    })
}

/// Fan the grid over `workers` pool threads and merge the outcomes into
/// a [`SweepMatrix`] in grid order.
pub fn run_grid(grid: &SweepGrid, workers: usize) -> anyhow::Result<SweepMatrix> {
    run_grid_with_hook(grid, workers, |_| {})
}

/// [`run_grid`] with a per-cell entry hook, called with the cell index
/// on the worker thread *before* the cell runs. The determinism tests
/// use it to inject a slow cell and scramble completion order; it must
/// not touch cell state.
pub fn run_grid_with_hook(
    grid: &SweepGrid,
    workers: usize,
    pre_cell: impl Fn(usize) + Sync,
) -> anyhow::Result<SweepMatrix> {
    anyhow::ensure!(grid.n_cells() > 0, "empty sweep grid");
    let sc = Scenario::build(grid.scenario.clone())?;
    let results = pool::scoped_map(workers, grid.n_cells(), |i| {
        pre_cell(i);
        run_cell_at(&sc, grid, i)
    });
    let cells = results.into_iter().collect::<anyhow::Result<Vec<_>>>()?;
    Ok(SweepMatrix {
        grid_name: grid.name.to_string(),
        root_seed: grid.root_seed,
        row_names: grid.rows.iter().map(|r| r.name.to_string()).collect(),
        seeds: (0..grid.n_seeds).map(|s| grid.seed_base + s as u64).collect(),
        mode_names: grid.modes.iter().map(|&m| mode_name(m).to_string()).collect(),
        env_names: grid.envs.iter().map(|e| e.name.clone()).collect(),
        duration_s: grid.duration_s,
        cells,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SweepGrid {
        SweepGrid {
            scenario: ScenarioConfig {
                n_clients: 12,
                n_edges: 3,
                weeks: 5,
                balanced_clients: false,
                ..Default::default()
            },
            rows: vec![
                RowSpec { name: "flat", workload: Workload::Static(StaticSetup::Flat) },
                RowSpec { name: "steady", workload: Workload::Cosim(Preset::Steady) },
            ],
            n_seeds: 2,
            modes: vec![LsMode::Incremental],
            envs: vec![EnvSpec { lambda_scale: 0.5, ..Default::default() }],
            duration_s: 20.0,
            ..SweepGrid::interference(7)
        }
    }

    #[test]
    fn coords_roundtrip_covers_grid() {
        let g = SweepGrid::interference(1);
        let mut seen = std::collections::HashSet::new();
        for i in 0..g.n_cells() {
            let (r, s, m, e) = g.coords(i);
            assert!(r < g.rows.len() && s < g.n_seeds);
            assert!(m < g.modes.len() && e < g.envs.len());
            assert!(seen.insert((r, s, m, e)), "coords repeat at {i}");
        }
        assert_eq!(seen.len(), g.n_cells());
    }

    #[test]
    fn acceptance_grid_is_at_least_24_cells() {
        assert!(SweepGrid::interference(0).n_cells() >= 24);
        assert!(SweepGrid::smoke(0).n_cells() >= 24);
    }

    #[test]
    fn cell_seeds_are_distinct_and_root_dependent() {
        let g = SweepGrid::interference(3);
        let mut seeds = std::collections::HashSet::new();
        for i in 0..g.n_cells() {
            let (r, s, m, e) = g.coords(i);
            assert!(seeds.insert(g.cell_seed(r, s, m, e)));
        }
        let g2 = SweepGrid::interference(4);
        assert_ne!(g.cell_seed(0, 0, 0, 0), g2.cell_seed(0, 0, 0, 0));
    }

    #[test]
    fn tiny_grid_runs_and_merges_in_order() {
        let m = run_grid(&tiny(), 2).unwrap();
        assert_eq!(m.cells.len(), 4);
        for (i, c) in m.cells.iter().enumerate() {
            let (r, s, mo, e) = tiny().coords(i);
            assert_eq!((c.row, c.seed_idx, c.mode_idx, c.env_idx), (r, s, mo, e));
            assert!(c.requests > 0, "cell {} served nothing", c.label);
        }
        // Static flat rows serve everything at the cloud; the co-sim row
        // trains on the timeline.
        assert!(m.cells[0].direct_to_cloud > 0);
        assert_eq!(m.cells[0].rounds_completed, 0);
        assert!(m.cells[2].rounds_completed >= 1);
    }

    #[test]
    fn matrix_json_excludes_wall_clock() {
        let m = run_grid(&tiny(), 1).unwrap();
        let text = m.to_json().to_pretty();
        assert!(!text.contains("wall"), "wall-clock leaked into the deterministic matrix");
        assert!(text.contains("\"cells\""));
        assert!(Json::parse(&text).is_ok());
        assert!(m.total_cell_wall_s() > 0.0);
    }
}
