//! Deterministic parallel scenario-sweep engine, rebuilt on the
//! experiment registry (DESIGN.md §5/§8).
//!
//! A [`SweepGrid`] is now fully declarative: **one registered experiment
//! × three param-override axes (rows / modes / envs) × a seed range**.
//! Each cell resolves the experiment's schema with the grid's base
//! overrides, its axis-point overrides and a coordinate-hashed seed,
//! runs the experiment through the [`registry::Experiment`] trait (quiet, no
//! filesystem), and compacts the returned [`Report`] summary into a
//! [`CellOutcome`]. Any experiment in the registry — including future
//! budget-trigger / MaaS scenarios — becomes sweepable by declaring a
//! grid; `sweep.rs` itself never changes. Compaction refuses reports
//! flagged `mock = true` or lacking the standard serving keys, so a
//! matrix can never silently fill with fabricated or zeroed numbers.
//!
//! **Cell seeding.** A cell's RNG seed is
//! `mix_seed(root, [row.coord, seed_base + s, mode.coord, env.coord])`.
//! Axis points made with [`AxisPoint::hashed`] derive their coordinate
//! word by hashing *the experiment name + their override set*
//! ([`override_coord`]), so a point's stream is tied to what it runs,
//! not to where it happens to sit in a `Vec`. The built-in
//! `interference`/`fig7`/`fig8`/`smoke` grids instead pin the
//! pre-registry integer coordinates ([`AxisPoint::pinned`]), which keeps
//! their matrices **byte-identical to the pre-registry engine** — held
//! by the golden-matrix regression test
//! (`rust/tests/sweep_golden_matrix.rs`, 1 and 8 workers).
//!
//! Execution and merge semantics are unchanged from PR 3: cells fan out
//! over `util::pool::scoped_map`, results land in grid (row-major)
//! order, per-cell wall time is excluded from [`SweepMatrix::to_json`]
//! (the determinism contract, now stamped with
//! [`metrics::export::SCHEMA_VERSION`]), and
//! `rust/tests/sweep_determinism.rs` holds byte-identity at 1/2/8
//! workers including under an injected slow cell.
//!
//! Known tradeoff: cells are fully self-contained, so each one rebuilds
//! its `Scenario` from params (the pre-registry engine shared one per
//! grid). The build is deterministic — results are unaffected — but
//! per-cell wall time now includes it; treat `BENCH_sweep.json` timing
//! across the PR 3 → PR 4 boundary accordingly.

use crate::config::params::{value_repr, Params, Value};
use crate::experiments::registry::{self, ExperimentCtx, Report};
use crate::metrics::export::SCHEMA_VERSION;
use crate::util::json::Json;
use crate::util::pool;
use crate::util::rng::mix_seed;

/// One point on a grid axis: a label segment for the cell's
/// `row/s<seed>/mode/env` label, the param overrides the point applies,
/// and the `mix_seed` coordinate word identifying it.
#[derive(Debug, Clone)]
pub struct AxisPoint {
    pub name: String,
    pub overrides: Vec<(String, Value)>,
    pub coord: u64,
}

impl AxisPoint {
    /// A point with an explicitly pinned coordinate word. The built-in
    /// grids pin the pre-registry integer coordinates so their cell
    /// seeds (and matrices) stay byte-identical across the redesign.
    pub fn pinned(coord: u64, name: &str, overrides: Vec<(String, Value)>) -> AxisPoint {
        AxisPoint { name: name.to_string(), overrides, coord }
    }

    /// A point whose coordinate word hashes the experiment name and the
    /// override set — the default for newly declared grids: reordering
    /// or extending an axis never changes an existing point's seeds.
    pub fn hashed(experiment: &str, name: &str, overrides: Vec<(String, Value)>) -> AxisPoint {
        let coord = override_coord(experiment, &overrides);
        AxisPoint { name: name.to_string(), overrides, coord }
    }

    /// A neutral singleton (no overrides, coordinate 0) for unused axes.
    pub fn neutral(name: &str) -> AxisPoint {
        AxisPoint::pinned(0, name, Vec::new())
    }
}

/// FNV-1a over bytes, the stable word hash under [`override_coord`].
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Hash an experiment name + override set into a `mix_seed` coordinate
/// word. Overrides are canonicalized (sorted by key, values through
/// `config::params::value_repr`) so declaration order cannot leak into
/// cell seeds.
pub fn override_coord(experiment: &str, overrides: &[(String, Value)]) -> u64 {
    let mut words = vec![fnv1a(experiment.as_bytes())];
    let mut sorted: Vec<&(String, Value)> = overrides.iter().collect();
    sorted.sort_by(|a, b| a.0.cmp(&b.0));
    for (key, value) in sorted {
        words.push(fnv1a(key.as_bytes()));
        words.push(fnv1a(value_repr(value).as_bytes()));
    }
    mix_seed(0x9E37_79B9_7F4A_7C15, &words)
}

/// The declarative sweep: one registered experiment × override axes ×
/// a seed range.
#[derive(Debug, Clone)]
pub struct SweepGrid {
    pub name: String,
    /// Registry name of the experiment every cell runs.
    pub experiment: String,
    /// Overrides applied to every cell (the grid's fixed world).
    pub base: Vec<(String, Value)>,
    /// Primary axis (scenario presets / setups).
    pub rows: Vec<AxisPoint>,
    /// Secondary axis (solver engines in the built-in grids).
    pub modes: Vec<AxisPoint>,
    /// Environment axis (interference factor / speedup / λ-scale).
    pub envs: Vec<AxisPoint>,
    /// Seed axis: replication seeds `seed_base..seed_base + n_seeds`.
    pub seed_base: u64,
    pub n_seeds: usize,
    /// Which experiment parameter receives the per-cell seed.
    pub seed_key: String,
    /// Simulated horizon recorded in the matrix header (kept in sync
    /// with the grid's `duration_s` override by the constructors).
    pub duration_s: f64,
    /// Root of the per-cell seed derivation.
    pub root_seed: u64,
}

fn ov(key: &str, value: Value) -> (String, Value) {
    (key.to_string(), value)
}

impl SweepGrid {
    /// The default grid: all four interference presets × 2 replication
    /// seeds × both local-search engines × two interference factors —
    /// 32 cells over the full co-sim (the acceptance grid).
    pub fn interference(root_seed: u64) -> SweepGrid {
        SweepGrid {
            name: "interference".into(),
            experiment: "interference".into(),
            base: vec![
                ov("clients", Value::Int(20)),
                ov("edges", Value::Int(4)),
                ov("weeks", Value::Int(5)),
                ov("balanced", Value::Bool(false)),
                ov("duration_s", Value::Float(240.0)),
                ov("model_bytes", Value::Int(4 * 65_536)),
            ],
            rows: crate::experiments::interference::Preset::ALL
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    AxisPoint::pinned(
                        i as u64,
                        p.name(),
                        vec![ov("preset", Value::Str(p.name().into()))],
                    )
                })
                .collect(),
            modes: ["completion", "incremental"]
                .iter()
                .enumerate()
                .map(|(i, m)| {
                    AxisPoint::pinned(i as u64, m, vec![ov("ls_mode", Value::Str((*m).into()))])
                })
                .collect(),
            envs: vec![
                AxisPoint::pinned(0, "if0.25", vec![ov("interference_factor", Value::Float(0.25))]),
                AxisPoint::pinned(1, "if1.0", vec![ov("interference_factor", Value::Float(1.0))]),
            ],
            seed_base: 0,
            n_seeds: 2,
            seed_key: "seed".into(),
            duration_s: 240.0,
            root_seed,
        }
    }

    /// CI smoke grid: still ≥ 24 cells but a small world and a short
    /// horizon, so `sweep --smoke` finishes in seconds.
    pub fn smoke(root_seed: u64) -> SweepGrid {
        let mut g = SweepGrid::interference(root_seed);
        g.name = "smoke".into();
        g.set_base("clients", Value::Int(12));
        g.set_base("edges", Value::Int(3));
        g.set_base("duration_s", Value::Float(60.0));
        g.duration_s = 60.0;
        g.n_seeds = 3;
        g.envs = vec![AxisPoint::pinned(
            0,
            "if0.25",
            vec![
                ov("interference_factor", Value::Float(0.25)),
                ov("lambda_scale", Value::Float(0.5)),
            ],
        )];
        g
    }

    /// Fig. 7 as grid rows: the three static setups × replication seeds,
    /// each cell a single-setup `fig7` serving simulation.
    pub fn fig7(root_seed: u64) -> SweepGrid {
        SweepGrid {
            name: "fig7".into(),
            experiment: "fig7".into(),
            base: vec![
                ov("clients", Value::Int(20)),
                ov("edges", Value::Int(4)),
                ov("weeks", Value::Int(5)),
                ov("balanced", Value::Bool(false)),
                ov("duration_s", Value::Float(120.0)),
                ov("model_bytes", Value::Int(4 * 65_536)),
            ],
            rows: ["flat", "location", "hflop"]
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    AxisPoint::pinned(i as u64, s, vec![ov("setup", Value::Str((*s).into()))])
                })
                .collect(),
            modes: vec![AxisPoint::neutral("auto")],
            envs: vec![AxisPoint::neutral("base")],
            seed_base: 0,
            n_seeds: 6,
            seed_key: "seed".into(),
            duration_s: 120.0,
            root_seed,
        }
    }

    /// Fig. 8b as grid rows: the three static setups × a speedup axis at
    /// λ×10 (the saturated regime with the paper's crossover). Runs the
    /// `fig7` experiment — the speedup study *is* the Fig. 7 serving
    /// fast path under different environments.
    pub fn fig8(root_seed: u64) -> SweepGrid {
        let mut g = SweepGrid::fig7(root_seed);
        g.name = "fig8".into();
        g.set_base("duration_s", Value::Float(60.0));
        g.set_base("lambda_scale", Value::Float(10.0));
        g.duration_s = 60.0;
        g.n_seeds = 2;
        g.envs = (0..=5)
            .map(|i| {
                let sp = i as f64 * 0.19;
                AxisPoint::pinned(
                    i as u64,
                    &format!("sp{sp:.2}"),
                    vec![ov("speedup", Value::Float(sp))],
                )
            })
            .collect();
        g
    }

    /// Budget control-plane grid (DESIGN.md §11): budget level rows ×
    /// fault-rate modes × surge-factor envs × replication seeds, each
    /// cell a `budget` oracle-vs-governed co-sim pair reporting spend,
    /// deferrals and p99 regret.
    pub fn budget(root_seed: u64) -> SweepGrid {
        SweepGrid {
            name: "budget".into(),
            experiment: "budget".into(),
            base: vec![
                ov("clients", Value::Int(12)),
                ov("edges", Value::Int(3)),
                ov("weeks", Value::Int(5)),
                ov("balanced", Value::Bool(false)),
                ov("duration_s", Value::Float(60.0)),
                ov("model_bytes", Value::Int(4 * 65_536)),
            ],
            rows: [("unlimited", 0.0), ("cap8", 8.0), ("cap2", 2.0)]
                .iter()
                .map(|(name, mb)| {
                    AxisPoint::hashed(
                        "budget",
                        name,
                        vec![ov("budget_mb", Value::Float(*mb))],
                    )
                })
                .collect(),
            modes: [("f1", 1), ("f3", 3)]
                .iter()
                .map(|(name, rate)| {
                    AxisPoint::hashed(
                        "budget",
                        name,
                        vec![ov("fault_rate", Value::Int(*rate))],
                    )
                })
                .collect(),
            envs: [("s1", 1.0), ("s3", 3.0)]
                .iter()
                .map(|(name, f)| {
                    AxisPoint::hashed(
                        "budget",
                        name,
                        vec![ov("surge_factor", Value::Float(*f))],
                    )
                })
                .collect(),
            seed_base: 0,
            n_seeds: 2,
            seed_key: "seed".into(),
            duration_s: 60.0,
            root_seed,
        }
    }

    /// Built-in grid lookup for the CLI.
    pub fn by_name(name: &str, root_seed: u64) -> Option<SweepGrid> {
        match name {
            "interference" => Some(SweepGrid::interference(root_seed)),
            "smoke" => Some(SweepGrid::smoke(root_seed)),
            "fig7" => Some(SweepGrid::fig7(root_seed)),
            "fig8" => Some(SweepGrid::fig8(root_seed)),
            "budget" => Some(SweepGrid::budget(root_seed)),
            _ => None,
        }
    }

    pub const BUILTIN: [&'static str; 5] = ["interference", "smoke", "fig7", "fig8", "budget"];

    /// A custom grid over any registered experiment (the
    /// `hflop sweep --experiment ...` path). Axis points get hashed
    /// coordinates; the matrix-header duration comes from the
    /// experiment's `duration_s` schema default unless the base
    /// overrides it.
    pub fn custom(
        experiment: &str,
        base: Vec<(String, Value)>,
        rows: Vec<AxisPoint>,
        modes: Vec<AxisPoint>,
        envs: Vec<AxisPoint>,
        n_seeds: usize,
        root_seed: u64,
    ) -> anyhow::Result<SweepGrid> {
        let exp = registry::lookup(experiment)?;
        anyhow::ensure!(
            exp.param_schema().iter().any(|s| s.key == "seed"),
            "experiment '{experiment}' declares no 'seed' parameter and cannot be swept"
        );
        let mut duration_s = exp
            .param_schema()
            .iter()
            .find(|s| s.key == "duration_s")
            .and_then(|s| match s.default {
                crate::config::params::ParamDefault::Float(f) => Some(f),
                crate::config::params::ParamDefault::Int(i) => Some(i as f64),
                _ => None,
            })
            .unwrap_or(0.0);
        if let Some((_, v)) = base.iter().rev().find(|(k, _)| k == "duration_s") {
            if let Some(f) = v.as_f64() {
                duration_s = f;
            }
        }
        Ok(SweepGrid {
            name: format!("custom-{experiment}"),
            experiment: experiment.to_string(),
            base,
            rows,
            modes,
            envs,
            seed_base: 0,
            n_seeds,
            seed_key: "seed".into(),
            duration_s,
            root_seed,
        })
    }

    /// Replace (or append) one base override.
    pub fn set_base(&mut self, key: &str, value: Value) {
        match self.base.iter_mut().find(|(k, _)| k == key) {
            Some(slot) => slot.1 = value,
            None => self.base.push(ov(key, value)),
        }
    }

    pub fn n_cells(&self) -> usize {
        self.rows.len() * self.n_seeds * self.modes.len() * self.envs.len()
    }

    /// Decode a flat cell index into `(row, seed, mode, env)` indices
    /// (row-major, the order cells appear in the matrix).
    pub fn coords(&self, idx: usize) -> (usize, usize, usize, usize) {
        assert!(idx < self.n_cells(), "cell index out of range");
        let e = idx % self.envs.len();
        let rest = idx / self.envs.len();
        let m = rest % self.modes.len();
        let rest = rest / self.modes.len();
        let s = rest % self.n_seeds;
        let r = rest / self.n_seeds;
        (r, s, m, e)
    }

    /// The cell's RNG seed, hashed from the root seed and the cell's
    /// axis coordinate words — never from execution order.
    pub fn cell_seed(&self, r: usize, s: usize, m: usize, e: usize) -> u64 {
        mix_seed(
            self.root_seed,
            &[
                self.rows[r].coord,
                self.seed_base + s as u64,
                self.modes[m].coord,
                self.envs[e].coord,
            ],
        )
    }

    /// `row/s<seed>/<mode>/<env>`.
    pub fn cell_label(&self, r: usize, s: usize, m: usize, e: usize) -> String {
        format!(
            "{}/s{}/{}/{}",
            self.rows[r].name,
            self.seed_base + s as u64,
            self.modes[m].name,
            self.envs[e].name
        )
    }

    /// Resolve the full parameter set of one cell: base ← row ← mode ←
    /// env ← per-cell seed, all schema-checked against the experiment.
    pub fn cell_params(&self, r: usize, s: usize, m: usize, e: usize) -> anyhow::Result<Params> {
        let exp = registry::lookup(&self.experiment)?;
        let mut sets = self.base.clone();
        sets.extend(self.rows[r].overrides.iter().cloned());
        sets.extend(self.modes[m].overrides.iter().cloned());
        sets.extend(self.envs[e].overrides.iter().cloned());
        let seed = self.cell_seed(r, s, m, e);
        sets.push((self.seed_key.clone(), Value::Int(seed as i64)));
        Params::resolve(exp.param_schema(), None, &sets)
    }
}

/// Compact, fully deterministic outcome of one sweep cell, extracted
/// from the experiment's [`Report`] summary (missing keys read as 0 —
/// e.g. static serving cells have no training counters).
#[derive(Debug, Clone)]
pub struct CellOutcome {
    pub row: usize,
    pub seed_idx: usize,
    pub mode_idx: usize,
    pub env_idx: usize,
    /// `row/s<seed>/<mode>/<env>`.
    pub label: String,
    pub cell_seed: u64,
    // --- serving (streaming moments + P² percentiles) -------------------
    pub requests: u64,
    pub served_at_edge: u64,
    pub spilled_to_cloud: u64,
    pub direct_to_cloud: u64,
    pub spill_fraction: f64,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
    // --- training / orchestration ---------------------------------------
    pub rounds_completed: usize,
    pub plan_swaps: usize,
    pub reclusters: usize,
    pub retrain_triggers: usize,
    pub events_processed: u64,
    pub events_cancelled: u64,
    // --- cost accounting -------------------------------------------------
    /// Eq. 1 communication cost of the cell's deployment plan.
    pub eq1_cost: f64,
    /// Predicted metered traffic (GB) for the cell's training activity.
    pub comm_gb: f64,
    // --- budget control plane (DESIGN.md §11) ----------------------------
    /// Reconfiguration bytes the budget governor approved (GB).
    pub ctl_spend_gb: f64,
    /// Plan installs the budget governor denied.
    pub budget_deferrals: usize,
    /// p99 latency lost vs the unbudgeted oracle (budget experiment; 0
    /// for experiments that do not run the oracle comparison).
    pub regret_ms: f64,
    /// Wall-clock seconds this cell took. Recorded for the bench report,
    /// EXCLUDED from [`CellOutcome::to_json`] — wall time varies run to
    /// run and must not break matrix bit-identity.
    pub wall_s: f64,
}

impl CellOutcome {
    /// Compact an experiment report into a cell (standard summary keys;
    /// values pass through as the `f64`s the experiment wrote, which is
    /// what keeps the registry path bit-identical to the old direct
    /// cell runner).
    #[allow(clippy::too_many_arguments)]
    pub fn from_report(
        (r, s, m, e): (usize, usize, usize, usize),
        label: String,
        cell_seed: u64,
        report: &Report,
        wall_s: f64,
    ) -> CellOutcome {
        let g = |k: &str| report.get_f64(k).unwrap_or(0.0);
        CellOutcome {
            row: r,
            seed_idx: s,
            mode_idx: m,
            env_idx: e,
            label,
            cell_seed,
            requests: g("requests") as u64,
            served_at_edge: g("served_at_edge") as u64,
            spilled_to_cloud: g("spilled_to_cloud") as u64,
            direct_to_cloud: g("direct_to_cloud") as u64,
            spill_fraction: g("spill_fraction"),
            mean_ms: g("mean_ms"),
            std_ms: g("std_ms"),
            min_ms: g("min_ms"),
            max_ms: g("max_ms"),
            p50_ms: g("p50_ms"),
            p90_ms: g("p90_ms"),
            p99_ms: g("p99_ms"),
            rounds_completed: g("rounds_completed") as usize,
            plan_swaps: g("plan_swaps") as usize,
            reclusters: g("reclusters") as usize,
            retrain_triggers: g("retrain_triggers") as usize,
            events_processed: g("events_processed") as u64,
            events_cancelled: g("events_cancelled") as u64,
            eq1_cost: g("eq1_cost"),
            comm_gb: g("comm_gb"),
            ctl_spend_gb: g("ctl_spend_gb"),
            budget_deferrals: g("budget_deferrals") as usize,
            regret_ms: g("regret_ms"),
            wall_s,
        }
    }

    /// Deterministic JSON view (everything except `wall_s`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("cell_seed", Json::Str(format!("{:016x}", self.cell_seed))),
            ("requests", Json::Num(self.requests as f64)),
            ("served_at_edge", Json::Num(self.served_at_edge as f64)),
            ("spilled_to_cloud", Json::Num(self.spilled_to_cloud as f64)),
            ("direct_to_cloud", Json::Num(self.direct_to_cloud as f64)),
            ("spill_fraction", Json::Num(self.spill_fraction)),
            ("mean_ms", Json::Num(self.mean_ms)),
            ("std_ms", Json::Num(self.std_ms)),
            ("min_ms", Json::Num(self.min_ms)),
            ("max_ms", Json::Num(self.max_ms)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p90_ms", Json::Num(self.p90_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
            ("rounds_completed", Json::Num(self.rounds_completed as f64)),
            ("plan_swaps", Json::Num(self.plan_swaps as f64)),
            ("reclusters", Json::Num(self.reclusters as f64)),
            ("retrain_triggers", Json::Num(self.retrain_triggers as f64)),
            ("events_processed", Json::Num(self.events_processed as f64)),
            ("events_cancelled", Json::Num(self.events_cancelled as f64)),
            ("eq1_cost", Json::Num(self.eq1_cost)),
            ("comm_gb", Json::Num(self.comm_gb)),
            ("ctl_spend_gb", Json::Num(self.ctl_spend_gb)),
            ("budget_deferrals", Json::Num(self.budget_deferrals as f64)),
            ("regret_ms", Json::Num(self.regret_ms)),
        ])
    }
}

/// The merged sweep result: one [`CellOutcome`] per grid cell, in grid
/// order (independent of which worker finished first).
#[derive(Debug, Clone)]
pub struct SweepMatrix {
    pub grid_name: String,
    pub root_seed: u64,
    pub experiment: String,
    pub row_names: Vec<String>,
    pub seeds: Vec<u64>,
    pub mode_names: Vec<String>,
    pub env_names: Vec<String>,
    pub duration_s: f64,
    pub cells: Vec<CellOutcome>,
}

impl SweepMatrix {
    /// The deterministic sweep artifact (the `matrix` half of
    /// `BENCH_sweep.json`): bit-identical for a given grid + root seed
    /// at any worker count. Carries `schema_version` since v2
    /// (DESIGN.md §8 compatibility note).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
            (
                "grid",
                Json::obj(vec![
                    ("name", Json::Str(self.grid_name.clone())),
                    ("root_seed", Json::Num(self.root_seed as f64)),
                    ("experiment", Json::Str(self.experiment.clone())),
                    ("rows", str_arr(&self.row_names)),
                    (
                        "seeds",
                        Json::Arr(self.seeds.iter().map(|&s| Json::Num(s as f64)).collect()),
                    ),
                    ("modes", str_arr(&self.mode_names)),
                    ("envs", str_arr(&self.env_names)),
                    ("duration_s", Json::Num(self.duration_s)),
                    ("n_cells", Json::Num(self.cells.len() as f64)),
                ]),
            ),
            ("cells", Json::Arr(self.cells.iter().map(CellOutcome::to_json).collect())),
        ])
    }

    /// Sum of per-cell wall-clock (the work the pool parallelizes).
    pub fn total_cell_wall_s(&self) -> f64 {
        self.cells.iter().map(|c| c.wall_s).sum()
    }

    /// Per-row mean-latency summary for terminal reports.
    pub fn summary_rows(&self) -> Vec<Vec<String>> {
        let mut out = Vec::new();
        for (r, name) in self.row_names.iter().enumerate() {
            let cells: Vec<&CellOutcome> = self.cells.iter().filter(|c| c.row == r).collect();
            if cells.is_empty() {
                continue;
            }
            let n = cells.len() as f64;
            let mean = cells.iter().map(|c| c.mean_ms).sum::<f64>() / n;
            let p99 = cells.iter().map(|c| c.p99_ms).sum::<f64>() / n;
            let req: u64 = cells.iter().map(|c| c.requests).sum();
            let swaps: usize = cells.iter().map(|c| c.plan_swaps).sum();
            let rounds: usize = cells.iter().map(|c| c.rounds_completed).sum();
            out.push(vec![
                name.clone(),
                format!("{}", cells.len()),
                format!("{req}"),
                format!("{mean:.2}"),
                format!("{p99:.1}"),
                format!("{rounds}"),
                format!("{swaps}"),
            ]);
        }
        out
    }
}

fn str_arr(xs: &[String]) -> Json {
    Json::Arr(xs.iter().map(|s| Json::Str(s.clone())).collect())
}

/// Run one cell by flat index: resolve its params, run the registered
/// experiment through the trait (quiet, no sink), compact the report.
/// Pure in the functional sense: output depends only on `(grid, idx)`.
fn run_cell_at(grid: &SweepGrid, idx: usize) -> anyhow::Result<CellOutcome> {
    let (r, s, m, e) = grid.coords(idx);
    let seed = grid.cell_seed(r, s, m, e);
    let label = grid.cell_label(r, s, m, e);
    let clock = crate::util::WallClock::start();
    let exp = registry::lookup(&grid.experiment)?;
    let params = grid.cell_params(r, s, m, e)?;
    let report = exp
        .run(&mut ExperimentCtx::cell(params))
        .map_err(|err| err.context(format!("sweep cell {label}")))?;
    // Two honesty guards before compaction. Mock-gated experiments
    // (fig6/cl) mark fabricated results with `mock = true`: those must
    // never be laundered into a matrix of real-looking numbers. And a
    // report without the standard serving keys would zero-fill every
    // cell field — a silent all-zero BENCH_sweep.json — so reject it
    // with a pointer to a serving-shaped mode instead.
    anyhow::ensure!(
        report.summary.get("mock").and_then(Json::as_bool) != Some(true),
        "sweep cell {label}: experiment '{}' produced MOCK-runtime results, which must not \
         enter a sweep matrix as real numbers (build the PJRT artifacts, or sweep a \
         serving-shaped experiment)",
        grid.experiment
    );
    anyhow::ensure!(
        report.get_f64("requests").is_some() || report.get_f64("eq1_cost").is_some(),
        "sweep cell {label}: experiment '{}' reported no serving metrics ('requests' and \
         'eq1_cost' both missing), so every cell field would read 0 — select a serving-shaped \
         mode on the row axis (e.g. fig7 --rows setup=flat,location,hflop or an interference \
         preset; setup=all, fig6 and cl reports are not sweep-compatible)",
        grid.experiment
    );
    Ok(CellOutcome::from_report(
        (r, s, m, e),
        label,
        seed,
        &report,
        clock.elapsed_s(),
    ))
}

/// Fan the grid over `workers` pool threads and merge the outcomes into
/// a [`SweepMatrix`] in grid order.
pub fn run_grid(grid: &SweepGrid, workers: usize) -> anyhow::Result<SweepMatrix> {
    run_grid_with_hook(grid, workers, |_| {})
}

/// [`run_grid`] with a per-cell entry hook, called with the cell index
/// on the worker thread *before* the cell runs. The determinism tests
/// use it to inject a slow cell and scramble completion order; it must
/// not touch cell state.
pub fn run_grid_with_hook(
    grid: &SweepGrid,
    workers: usize,
    pre_cell: impl Fn(usize) + Sync,
) -> anyhow::Result<SweepMatrix> {
    anyhow::ensure!(grid.n_cells() > 0, "empty sweep grid");
    registry::lookup(&grid.experiment)?;
    let results = pool::scoped_map(workers, grid.n_cells(), |i| {
        pre_cell(i);
        run_cell_at(grid, i)
    });
    let cells = results.into_iter().collect::<anyhow::Result<Vec<_>>>()?;
    Ok(SweepMatrix {
        grid_name: grid.name.clone(),
        root_seed: grid.root_seed,
        experiment: grid.experiment.clone(),
        row_names: grid.rows.iter().map(|r| r.name.clone()).collect(),
        seeds: (0..grid.n_seeds).map(|s| grid.seed_base + s as u64).collect(),
        mode_names: grid.modes.iter().map(|m| m.name.clone()).collect(),
        env_names: grid.envs.iter().map(|e| e.name.clone()).collect(),
        duration_s: grid.duration_s,
        cells,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A fast 4-cell grid: one static fig7 row is impossible in a
    /// single-experiment grid, so the tiny grid runs the co-sim
    /// experiment with a short horizon and a small world.
    fn tiny() -> SweepGrid {
        let mut g = SweepGrid::interference(7);
        g.set_base("clients", Value::Int(12));
        g.set_base("edges", Value::Int(3));
        g.set_base("duration_s", Value::Float(20.0));
        g.set_base("lambda_scale", Value::Float(0.5));
        g.duration_s = 20.0;
        g.rows.truncate(2); // steady, diurnal-surge
        g.modes.truncate(1); // completion
        g.envs.truncate(1); // if0.25
        g
    }

    #[test]
    fn coords_roundtrip_covers_grid() {
        let g = SweepGrid::interference(1);
        let mut seen = std::collections::HashSet::new();
        for i in 0..g.n_cells() {
            let (r, s, m, e) = g.coords(i);
            assert!(r < g.rows.len() && s < g.n_seeds);
            assert!(m < g.modes.len() && e < g.envs.len());
            assert!(seen.insert((r, s, m, e)), "coords repeat at {i}");
        }
        assert_eq!(seen.len(), g.n_cells());
    }

    #[test]
    fn acceptance_grid_is_at_least_24_cells() {
        assert!(SweepGrid::interference(0).n_cells() >= 24);
        assert!(SweepGrid::smoke(0).n_cells() >= 24);
    }

    #[test]
    fn cell_seeds_are_distinct_and_root_dependent() {
        let g = SweepGrid::interference(3);
        let mut seeds = std::collections::HashSet::new();
        for i in 0..g.n_cells() {
            let (r, s, m, e) = g.coords(i);
            assert!(seeds.insert(g.cell_seed(r, s, m, e)));
        }
        let g2 = SweepGrid::interference(4);
        assert_ne!(g.cell_seed(0, 0, 0, 0), g2.cell_seed(0, 0, 0, 0));
    }

    #[test]
    fn builtin_grid_cells_resolve_against_their_schemas() {
        // Every base/axis override of every built-in grid must name a
        // declared parameter of the grid's experiment — a drifting key
        // would otherwise only explode at run time.
        for name in SweepGrid::BUILTIN {
            let g = SweepGrid::by_name(name, 1).unwrap();
            for idx in 0..g.n_cells() {
                let (r, s, m, e) = g.coords(idx);
                g.cell_params(r, s, m, e)
                    .unwrap_or_else(|err| panic!("grid {name} cell {idx}: {err}"));
            }
        }
    }

    #[test]
    fn hashed_coords_depend_on_experiment_and_overrides_not_order() {
        let a = override_coord("fig7", &[ov("setup", Value::Str("flat".into()))]);
        let b = override_coord("fig7", &[ov("setup", Value::Str("hflop".into()))]);
        let c = override_coord("interference", &[ov("setup", Value::Str("flat".into()))]);
        assert_ne!(a, b, "override value must reach the coord");
        assert_ne!(a, c, "experiment name must reach the coord");
        // Canonicalization: declaration order does not matter.
        let x = override_coord(
            "fig7",
            &[ov("a", Value::Int(1)), ov("b", Value::Int(2))],
        );
        let y = override_coord(
            "fig7",
            &[ov("b", Value::Int(2)), ov("a", Value::Int(1))],
        );
        assert_eq!(x, y);
        // And the empty set is stable.
        assert_eq!(override_coord("fig7", &[]), override_coord("fig7", &[]));
    }

    #[test]
    fn tiny_grid_runs_and_merges_in_order() {
        let g = tiny();
        let m = run_grid(&g, 2).unwrap();
        assert_eq!(m.cells.len(), 4);
        for (i, c) in m.cells.iter().enumerate() {
            let (r, s, mo, e) = g.coords(i);
            assert_eq!((c.row, c.seed_idx, c.mode_idx, c.env_idx), (r, s, mo, e));
            assert!(c.requests > 0, "cell {} served nothing", c.label);
        }
        // Co-sim rows train on the timeline.
        assert!(m.cells.iter().all(|c| c.rounds_completed >= 1));
        assert_eq!(m.experiment, "interference");
    }

    #[test]
    fn custom_grid_over_fig7_runs_static_cells() {
        let g = SweepGrid::custom(
            "fig7",
            vec![
                ov("clients", Value::Int(12)),
                ov("edges", Value::Int(3)),
                ov("duration_s", Value::Float(15.0)),
            ],
            vec![
                AxisPoint::hashed("fig7", "flat", vec![ov("setup", Value::Str("flat".into()))]),
                AxisPoint::hashed("fig7", "hflop", vec![ov("setup", Value::Str("hflop".into()))]),
            ],
            vec![AxisPoint::neutral("auto")],
            vec![AxisPoint::neutral("base")],
            2,
            9,
        )
        .unwrap();
        assert_eq!(g.n_cells(), 4);
        // Header duration falls back to the base override.
        assert!((g.duration_s - 15.0).abs() < 1e-12);
        let m = run_grid(&g, 2).unwrap();
        // Static flat rows serve everything at the cloud and never train.
        assert!(m.cells[0].direct_to_cloud > 0);
        assert_eq!(m.cells[0].rounds_completed, 0);
        assert!(m.cells.iter().all(|c| c.requests > 100));
        // Distinct hashed row coords -> distinct seeds at equal indices.
        assert_ne!(m.cells[0].cell_seed, m.cells[2].cell_seed);
    }

    #[test]
    fn custom_grid_rejects_unknown_experiment_and_unsweepable_schema() {
        assert!(SweepGrid::custom("fig11", vec![], vec![], vec![], vec![], 1, 0).is_err());
    }

    #[test]
    fn custom_grid_over_fig2_sharded_reports_cost_cells() {
        // Solver-shaped experiments carry no serving counters; the cost
        // key alone must satisfy the compaction guard.
        let g = SweepGrid::custom(
            "fig2",
            vec![
                ov("solver", Value::Str("sharded".into())),
                ov("sharded_n", Value::Int(250)),
                ov("sharded_m", Value::Int(8)),
                ov("reps", Value::Int(1)),
                ov("max_points", Value::Int(1)),
            ],
            vec![AxisPoint::hashed("fig2", "k4", vec![ov("cand_k", Value::Int(4))])],
            vec![AxisPoint::neutral("base")],
            vec![AxisPoint::neutral("base")],
            1,
            7,
        )
        .unwrap();
        let m = run_grid(&g, 1).unwrap();
        assert_eq!(m.cells.len(), 1);
        assert!(m.cells[0].eq1_cost > 0.0, "sharded cell must report Eq.1 cost");
        assert_eq!(m.cells[0].requests, 0);
    }

    #[test]
    fn mock_backed_cells_are_rejected() {
        // Sweeping a mock-gated experiment must not launder fabricated
        // numbers into a matrix: the cell fails with a MOCK error.
        let g = SweepGrid::custom(
            "cl",
            vec![
                ov("runtime", Value::Str("mock".into())),
                ov("weeks", Value::Int(6)),
                ov("initial_steps", Value::Int(60)),
                ov("steps_per_shift", Value::Int(20)),
            ],
            vec![AxisPoint::hashed("cl", "drift", vec![ov("drift_scale", Value::Float(2.0))])],
            vec![AxisPoint::neutral("base")],
            vec![AxisPoint::neutral("base")],
            1,
            5,
        )
        .unwrap();
        let err = run_grid(&g, 1).unwrap_err().to_string();
        assert!(err.contains("MOCK"), "{err}");
    }

    #[test]
    fn non_serving_reports_are_rejected_not_zero_filled() {
        // `--experiment fig7` without a row axis leaves setup=all, whose
        // report has none of the standard serving keys; the old behavior
        // silently compacted it to an all-zero matrix.
        let g = SweepGrid::custom(
            "fig7",
            vec![
                ov("clients", Value::Int(12)),
                ov("edges", Value::Int(3)),
                ov("duration_s", Value::Float(8.0)),
                ov("reps", Value::Int(1)),
            ],
            vec![AxisPoint::neutral("all")],
            vec![AxisPoint::neutral("base")],
            vec![AxisPoint::neutral("base")],
            1,
            5,
        )
        .unwrap();
        let err = run_grid(&g, 1).unwrap_err().to_string();
        assert!(err.contains("no serving metrics"), "{err}");
    }

    #[test]
    fn matrix_json_excludes_wall_clock_and_carries_schema_version() {
        let m = run_grid(&tiny(), 1).unwrap();
        let text = m.to_json().to_pretty();
        assert!(!text.contains("wall"), "wall-clock leaked into the deterministic matrix");
        assert!(text.contains("\"cells\""));
        assert!(text.contains("\"schema_version\""));
        assert!(Json::parse(&text).is_ok());
        assert!(m.total_cell_wall_s() > 0.0);
    }
}
