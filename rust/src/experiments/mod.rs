//! Experiment harnesses — one module per paper artifact, all unified
//! behind the [`registry::Experiment`] trait (DESIGN.md §5):
//!
//! | experiment | paper artifact |
//! |------------|----------------|
//! | [`fig2`]   | HFLOP optimal solve times vs instance size |
//! | [`fig6`]   | per-client MSE curves, 3 setups, continual HFL |
//! | [`fig7`]   | inference response-time distributions |
//! | [`fig8`]   | end-to-end latency vs edge→cloud speedup |
//! | [`fig9`]   | communication-cost savings vs edge density |
//! | [`cl_table`] | §V-B1 static vs continually-retrained MSE |
//! | [`interference`] | joint training/serving timeline (co-sim presets) |
//! | [`budget`] | budget-governed re-orchestration: spend, deferrals, regret |
//! | [`scenario`] | the shared world itself (topology + assignments) |
//!
//! [`registry::REGISTRY`] is the single typed entry point: `main.rs`
//! dispatches `hflop experiment <name>` through it, `--list`/`--help`
//! are generated from it, and [`sweep`] fans *registered experiment ×
//! param-override axes × seed range* grids over the worker pool with
//! per-cell coordinate-hashed seeds. The `examples/` binaries and
//! `rust/benches/` harnesses stay thin drivers over these modules.

pub mod budget;
pub mod cl_table;
pub mod fig2;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod interference;
pub mod registry;
pub mod scenario;
pub mod sweep;

pub use registry::{Experiment, ExperimentCtx, Report, REGISTRY};
pub use scenario::{Scenario, ScenarioConfig};
pub use sweep::{SweepGrid, SweepMatrix};
