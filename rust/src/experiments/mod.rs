//! Experiment harnesses — one module per paper artifact (DESIGN.md §5):
//!
//! | module     | paper artifact |
//! |------------|----------------|
//! | [`fig2`]   | HFLOP optimal solve times vs instance size |
//! | [`fig6`]   | per-client MSE curves, 3 setups, continual HFL |
//! | [`fig7`]   | inference response-time distributions |
//! | [`fig8`]   | end-to-end latency vs edge→cloud speedup |
//! | [`fig9`]   | communication-cost savings vs edge density |
//! | [`cl_table`] | §V-B1 static vs continually-retrained MSE |
//! | [`interference`] | joint training/serving timeline (co-sim presets) |
//! | [`sweep`]  | deterministic parallel scenario-sweep engine (grids over the above) |
//!
//! [`scenario`] builds the shared world (synthetic METR-LA, topology,
//! assignments). The `examples/` binaries and `rust/benches/` harnesses
//! are thin drivers over these functions; [`sweep`] fans grids of them
//! over a worker pool with per-cell coordinate-hashed seeds.

pub mod cl_table;
pub mod fig2;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod interference;
pub mod scenario;
pub mod sweep;

pub use scenario::{Scenario, ScenarioConfig};
pub use sweep::{SweepGrid, SweepMatrix};
