//! `experiments::interference` — the joint-timeline artifact.
//!
//! Training, serving and the orchestrator run on *one* event-driven
//! kernel (`inference::cosim`), so the paper's coupling claim — training
//! and inference workloads interfere on shared infrastructure — becomes
//! a reproducible experiment alongside Figs. 2/6–9. Four scenario
//! presets:
//!
//! * [`Preset::Steady`] — steady request load under the continual
//!   training cadence: periodic rounds degrade edge serving capacity by
//!   the interference factor; the latency timeline shows the dips.
//! * [`Preset::DiurnalSurge`] — a mid-run arrival surge; the learning
//!   controller's λ view tracks it and may re-place clusters
//!   (load-aware re-orchestration).
//! * [`Preset::EdgeFailure`] — the busiest edge fails mid-run: stale
//!   service timers are cancelled via kernel generation tags, the
//!   backlog spills to the cloud, the GPO marks the node failed, and the
//!   learning controller re-solves and installs a new plan.
//! * [`Preset::RetrainBurst`] — served-model drift trips the inference
//!   controller's EWMA trigger; the resulting retrain burst occupies
//!   timeline intervals and degrades serving while it runs — the full
//!   continual-learning control loop, closed on one clock.
//!
//! Driver: `cargo run --release --example interference`.

use crate::config::params::ParamSpec;
use crate::experiments::fig7::serving_summary;
use crate::experiments::registry::{Experiment, ExperimentCtx, ParamDefault, Report};
use crate::experiments::scenario::{Scenario, ScenarioConfig};
use crate::fl::timing::RoundTimeModel;
use crate::inference::cosim::{
    run_cell_reusing, CoEvent, ControlConfig, ControlPlane, CoSimConfig, CoSimOutcome,
    DriftModel, FaultEvent, TrainingConfig, TrainingSchedule,
};
use crate::inference::simulation::ServingConfig;
use crate::inference::trace::{ArrivalModel, RateTrace};
use crate::inference::LatencyModel;
use crate::metrics::cost::hfl_bytes;
use crate::metrics::export::ascii_table;
use crate::orchestrator::{
    DeploymentPlan, Gpo, InferenceController, InferenceCtlConfig, LearningController,
    LearningCtlConfig, ResolveStrategy,
};
use crate::sim::Kernel;
use crate::solver::{LocalSearchOptions, LsMode, Mode, SolveOptions};

/// The four joint-timeline scenarios.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Preset {
    Steady,
    DiurnalSurge,
    EdgeFailure,
    RetrainBurst,
}

impl Preset {
    pub const ALL: [Preset; 4] =
        [Preset::Steady, Preset::DiurnalSurge, Preset::EdgeFailure, Preset::RetrainBurst];

    pub fn name(&self) -> &'static str {
        match self {
            Preset::Steady => "steady",
            Preset::DiurnalSurge => "diurnal-surge",
            Preset::EdgeFailure => "edge-failure",
            Preset::RetrainBurst => "retrain-burst",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Preset> {
        Preset::ALL.into_iter().find(|p| p.name() == s).ok_or_else(|| {
            let valid: Vec<&str> = Preset::ALL.iter().map(Preset::name).collect();
            anyhow::anyhow!("unknown preset '{s}' (valid: all, {})", valid.join(", "))
        })
    }
}

#[derive(Debug, Clone)]
pub struct InterferenceConfig {
    pub preset: Preset,
    /// Simulated wall time (s).
    pub duration_s: f64,
    /// Serving-capacity multiplier while an edge trains (paper coupling).
    pub interference_factor: f64,
    /// Scale factor on every λ_i.
    pub lambda_scale: f64,
    pub latency: LatencyModel,
    pub queue_window_s: f64,
    /// Accuracy-monitor cadence (control plane).
    pub monitor_period_s: f64,
    /// Telemetry lag before the GPO sees a capacity change.
    pub report_delay_s: f64,
    /// Latency-timeline bucket width (s).
    pub bucket_s: f64,
    /// HFL round time model (straggler compute + transfers).
    pub time_model: RoundTimeModel,
    pub epochs: usize,
    pub model_bytes: usize,
    /// Solver options for the control plane's re-solves (the sweep
    /// engine's `LsMode` axis plugs in here).
    pub solve: SolveOptions,
    /// Re-solve strategy for the control plane (the sweep engine's
    /// `resolve_strategy` axis); `Full` is the legacy cold-solve path.
    pub resolve: ResolveStrategy,
    /// Arrival generation. With an open-loop [`ArrivalModel::Trace`],
    /// preset surge faults are folded into the trace as overlays (the
    /// trace owns the λ timeline) instead of multiplier pokes.
    pub arrivals: ArrivalModel,
    pub seed: u64,
    pub record_trace: bool,
}

impl Default for InterferenceConfig {
    fn default() -> Self {
        InterferenceConfig {
            preset: Preset::Steady,
            duration_s: 240.0,
            interference_factor: 0.25,
            lambda_scale: 1.0,
            latency: LatencyModel::default(),
            queue_window_s: 0.05,
            monitor_period_s: 2.0,
            report_delay_s: 3.0,
            bucket_s: 10.0,
            time_model: RoundTimeModel::default(),
            epochs: 5,
            model_bytes: 4 * 65_536,
            solve: SolveOptions::auto(),
            resolve: ResolveStrategy::Full,
            arrivals: ArrivalModel::PerDevicePoisson,
            seed: 7,
            record_trace: false,
        }
    }
}

/// When the [`Preset::EdgeFailure`] victim fails / recovers, as
/// fractions of the run horizon. Public so drivers annotating the
/// latency timeline (e.g. `examples/interference.rs`) stay in sync with
/// the schedule instead of duplicating the constants.
pub const EDGE_FAILURE_AT_FRAC: f64 = 0.4;
pub const EDGE_RECOVER_AT_FRAC: f64 = 0.75;

/// Training cadence + fault schedule for one preset.
fn preset_plan(
    cfg: &InterferenceConfig,
    sc: &Scenario,
    lambdas: &[f64],
) -> (TrainingSchedule, Vec<(f64, FaultEvent)>, DriftModel) {
    let d = cfg.duration_s;
    let periodic = TrainingSchedule::Periodic { start_s: 0.1 * d, gap_s: (0.05 * d).max(1.0) };
    let no_drift = DriftModel { fresh_mse: 0.02, drift_per_s: 0.0 };
    match cfg.preset {
        Preset::Steady => (periodic, Vec::new(), no_drift),
        Preset::DiurnalSurge => (
            periodic,
            vec![
                (0.3 * d, FaultEvent::SurgeStart { factor: 3.0 }),
                (0.6 * d, FaultEvent::SurgeEnd),
            ],
            no_drift,
        ),
        Preset::EdgeFailure => {
            // Fail the edge carrying the most load under the HFLOP plan.
            let m = sc.topo.n_edges();
            let mut load = vec![0.0f64; m];
            for (dev, a) in sc.assign_hflop.assign.iter().enumerate() {
                if let Some(j) = *a {
                    load[j] += lambdas[dev];
                }
            }
            let victim = (0..m)
                .max_by(|&a, &b| load[a].total_cmp(&load[b]))
                .unwrap_or(0);
            (
                periodic,
                vec![
                    (EDGE_FAILURE_AT_FRAC * d, FaultEvent::EdgeFail(victim)),
                    (EDGE_RECOVER_AT_FRAC * d, FaultEvent::EdgeRecover(victim)),
                ],
                no_drift,
            )
        }
        Preset::RetrainBurst => (
            TrainingSchedule::OnTrigger { rounds_per_task: 3 },
            Vec::new(),
            DriftModel { fresh_mse: 0.02, drift_per_s: 0.002 },
        ),
    }
}

/// Run one preset on a built scenario: wires the GPO inventory and the
/// two controllers from the scenario topology, seeds the controller with
/// the scenario's HFLOP plan (so the first re-solve is a *swap*, not a
/// cold start), and runs the co-simulation to the horizon.
pub fn run(sc: &Scenario, cfg: &InterferenceConfig) -> anyhow::Result<CoSimOutcome> {
    Ok(run_with_kernel(sc, cfg, Kernel::new())?.0)
}

/// [`run`] on a caller-supplied kernel, returning it for the next cell:
/// the all-presets driver threads one kernel through its four runs so
/// the slab and bucket arrays are allocated once (outcomes stay
/// bit-identical — the kernel is fully reset between cells).
pub fn run_with_kernel(
    sc: &Scenario,
    cfg: &InterferenceConfig,
    kernel: Kernel<CoEvent>,
) -> anyhow::Result<(CoSimOutcome, Kernel<CoEvent>)> {
    let n = sc.topo.n_devices();
    let m = sc.topo.n_edges();
    let lambdas: Vec<f64> = sc.lambdas().iter().map(|l| l * cfg.lambda_scale).collect();
    let caps = sc.capacities();

    // GPO inventory mirrors the scenario topology (dense ids 0..n, 0..m).
    let mut gpo = Gpo::new();
    for dev in &sc.topo.devices {
        gpo.register_device(dev.id, dev.location);
    }
    for edge in &sc.topo.edges {
        gpo.register_edge(edge.id, edge.location, edge.capacity);
    }

    let mut learning = LearningController::new(LearningCtlConfig {
        l: sc.cfg.l,
        solve: cfg.solve.clone(),
        strategy: cfg.resolve,
        ..Default::default()
    });
    for (dev, &l) in lambdas.iter().enumerate() {
        learning.set_lambda(dev, l);
    }
    learning.seed_plan(DeploymentPlan {
        assignment: sc.assign_hflop.clone(),
        edge_ids: (0..m).collect(),
        device_ids: (0..n).collect(),
        cost: sc.hflop_cost,
        proven_optimal: sc.hflop_optimal,
    });

    let (schedule, mut faults, drift) = preset_plan(cfg, sc, &lambdas);
    // In open-loop trace mode the trace owns the λ timeline: preset
    // surge fault pairs are folded in as overlays (the announcements at
    // the overlay's boundaries keep the controller's λ view in sync),
    // and the now-inert multiplier pokes are dropped from the schedule.
    let arrivals = match &cfg.arrivals {
        ArrivalModel::PerDevicePoisson => ArrivalModel::PerDevicePoisson,
        ArrivalModel::Trace { trace, chunk_s } => {
            let mut combined = trace.clone();
            let mut pending: Option<(f64, f64)> = None;
            for (t, f) in &faults {
                match f {
                    FaultEvent::SurgeStart { factor } => pending = Some((*t, *factor)),
                    FaultEvent::SurgeEnd => {
                        if let Some((t0, factor)) = pending.take() {
                            if *t > t0 {
                                combined = combined.overlay(&RateTrace::surge(factor, t0, *t));
                            }
                        }
                    }
                    _ => {}
                }
            }
            faults.retain(|(_, f)| {
                !matches!(f, FaultEvent::SurgeStart { .. } | FaultEvent::SurgeEnd)
            });
            ArrivalModel::Trace { trace: combined, chunk_s: *chunk_s }
        }
    };
    let control = ControlPlane::new(
        gpo,
        learning,
        InferenceController::new(InferenceCtlConfig::default()),
        ControlConfig {
            monitor_period_s: cfg.monitor_period_s,
            report_delay_s: cfg.report_delay_s,
            drift,
            resolve_on_recover: true,
        },
    );

    Ok(run_cell_reusing(
        CoSimConfig {
            serving: ServingConfig {
                assign: sc.assign_hflop.assign.clone(),
                lambda: lambdas,
                capacity: caps,
                latency: cfg.latency.clone(),
                duration_s: cfg.duration_s,
                queue_window_s: cfg.queue_window_s,
                seed: cfg.seed,
            },
            interference_factor: cfg.interference_factor,
            training: TrainingConfig {
                schedule,
                time_model: cfg.time_model.clone(),
                epochs: cfg.epochs,
                model_bytes: cfg.model_bytes,
            },
            faults,
            bucket_s: cfg.bucket_s,
            record_trace: cfg.record_trace,
            arrivals,
        },
        Some(control),
        kernel,
    ))
}

/// Solve options that pin the control plane's re-solves to one
/// local-search engine (the sweep's `ls_mode` axis plugs in here;
/// formerly `sweep::solve_options`).
pub fn solve_options_for(mode: LsMode) -> SolveOptions {
    SolveOptions {
        mode: Mode::Heuristic,
        ls: LocalSearchOptions { mode, ..Default::default() },
        ..SolveOptions::exact()
    }
}

/// Map the `ls_mode` parameter onto solver options: `"auto"` keeps the
/// full auto policy (exact when small — the standalone default), the
/// other two pin the heuristic engine the way the sweep's axis does.
pub(crate) fn solve_from_ls_mode(s: &str) -> anyhow::Result<SolveOptions> {
    Ok(match s {
        "auto" => SolveOptions::auto(),
        "completion" => solve_options_for(LsMode::Completion),
        "incremental" => solve_options_for(LsMode::Incremental),
        other => anyhow::bail!("unknown ls_mode '{other}' (valid: auto, completion, incremental)"),
    })
}

/// Registry port (DESIGN.md §5). `preset = "all"` (default) reproduces
/// the joint-timeline artifact over all four presets; a single preset
/// name runs one co-simulation and reports the standard serving +
/// orchestration metrics — the sweep-cell path, kept bit-identical to
/// the pre-registry cell runner by `rust/tests/sweep_golden_matrix.rs`.
pub struct InterferenceExperiment;

const SCHEMA: &[ParamSpec] = &[
    ParamSpec {
        key: "preset",
        default: ParamDefault::Str("all"),
        help: "all, or one of steady|diurnal-surge|edge-failure|retrain-burst",
    },
    ParamSpec { key: "clients", default: ParamDefault::Int(20), help: "FL clients / devices" },
    ParamSpec { key: "edges", default: ParamDefault::Int(4), help: "candidate edge hosts" },
    ParamSpec { key: "weeks", default: ParamDefault::Int(5), help: "synthetic dataset length" },
    ParamSpec {
        key: "balanced",
        default: ParamDefault::Bool(false),
        help: "balanced client placement",
    },
    ParamSpec { key: "scenario_seed", default: ParamDefault::Int(42), help: "scenario seed" },
    ParamSpec { key: "data_seed", default: ParamDefault::Int(1234), help: "dataset seed" },
    ParamSpec {
        key: "duration_s",
        default: ParamDefault::Float(240.0),
        help: "simulated co-sim horizon (s)",
    },
    ParamSpec {
        key: "interference_factor",
        default: ParamDefault::Float(0.25),
        help: "serving-capacity multiplier while an edge trains",
    },
    ParamSpec {
        key: "lambda_scale",
        default: ParamDefault::Float(1.0),
        help: "scale factor on every lambda_i",
    },
    ParamSpec {
        key: "model_bytes",
        default: ParamDefault::Int(262_144),
        help: "model transfer size (round timing + comm accounting)",
    },
    ParamSpec {
        key: "ls_mode",
        default: ParamDefault::Str("auto"),
        help: "control-plane re-solve engine: auto|completion|incremental",
    },
    ParamSpec {
        key: "resolve_strategy",
        default: ParamDefault::Str("full"),
        help: "control-plane re-solve strategy: full|warm|auto",
    },
    ParamSpec {
        key: "trace",
        default: ParamDefault::Str("none"),
        help: "open-loop arrival trace: none|constant|diurnal|flash-crowd|hotspot",
    },
    ParamSpec {
        key: "trace_peak",
        default: ParamDefault::Float(3.0),
        help: "trace peak rate multiplier (diurnal/flash-crowd/hotspot)",
    },
    ParamSpec {
        key: "trace_period_s",
        default: ParamDefault::Float(0.0),
        help: "diurnal period (s); 0 = one cycle over the horizon",
    },
    ParamSpec {
        key: "trace_chunk_s",
        default: ParamDefault::Float(10.0),
        help: "open-loop generation chunk (s)",
    },
    ParamSpec {
        key: "seed",
        default: ParamDefault::Int(7),
        help: "co-simulation seed (the sweep writes the cell seed here)",
    },
];

fn scenario_from(ctx: &ExperimentCtx) -> anyhow::Result<Scenario> {
    Scenario::build(ScenarioConfig {
        n_clients: ctx.params.usize("clients")?,
        n_edges: ctx.params.usize("edges")?,
        weeks: ctx.params.usize("weeks")?,
        balanced_clients: ctx.params.bool("balanced")?,
        seed: ctx.params.u64("scenario_seed")?,
        data_seed: ctx.params.u64("data_seed")?,
        ..Default::default()
    })
}

fn config_from(
    ctx: &ExperimentCtx,
    preset: Preset,
    duration_s: f64,
) -> anyhow::Result<InterferenceConfig> {
    Ok(InterferenceConfig {
        preset,
        duration_s,
        interference_factor: ctx.params.f64("interference_factor")?,
        lambda_scale: ctx.params.f64("lambda_scale")?,
        model_bytes: ctx.params.usize("model_bytes")?,
        solve: solve_from_ls_mode(&ctx.params.str("ls_mode")?)?,
        resolve: ResolveStrategy::parse(&ctx.params.str("resolve_strategy")?)?,
        arrivals: ArrivalModel::from_named(
            &ctx.params.str("trace")?,
            ctx.params.f64("trace_peak")?,
            ctx.params.f64("trace_period_s")?,
            ctx.params.f64("trace_chunk_s")?,
            duration_s,
        )?,
        seed: ctx.params.u64("seed")?,
        ..Default::default()
    })
}

/// Fill a report with one co-sim outcome: the standard serving keys
/// (shared with `fig7`) plus training/orchestration counters, the cost
/// accounting the pre-registry sweep cell carried, and the budget
/// control plane's spend/deferral counters (DESIGN.md §11; shared with
/// the `budget` experiment, which adds the regret keys on top).
pub(crate) fn cosim_summary(
    report: &mut Report,
    sc: &Scenario,
    out: &CoSimOutcome,
    model_bytes: usize,
) {
    serving_summary(report, &out.serving);
    report.num("rounds_completed", out.rounds_completed as f64);
    report.num("plan_swaps", out.plan_swaps as f64);
    report.num("reclusters", out.reclusters as f64);
    report.num("retrain_triggers", out.retrain_triggers as f64);
    report.num("events_processed", out.events_processed as f64);
    report.num("events_cancelled", out.events_cancelled as f64);
    report.num("eq1_cost", sc.hflop_cost);
    let comm = hfl_bytes(&sc.inst, &sc.assign_hflop, out.rounds_completed, model_bytes);
    report.num("comm_gb", comm as f64 / 1e9);
    report.num("ctl_spend_gb", out.ctl_spend_bytes as f64 / 1e9);
    report.num("budget_deferrals", out.budget_deferrals as f64);
}

impl Experiment for InterferenceExperiment {
    fn name(&self) -> &'static str {
        "interference"
    }

    fn describe(&self) -> &'static str {
        "joint training/serving co-sim timeline, orchestrator in the loop (4 presets)"
    }

    fn param_schema(&self) -> &'static [ParamSpec] {
        SCHEMA
    }

    fn run(&self, ctx: &mut ExperimentCtx) -> anyhow::Result<Report> {
        let sc = scenario_from(ctx)?;
        let which = ctx.params.str("preset")?;
        let model_bytes = ctx.params.usize("model_bytes")?;
        let mut report = Report::new("interference");

        if which == "all" {
            let duration_s = ctx.f64_capped("duration_s", 60.0)?;
            let mut rows = Vec::new();
            let mut pretty: Vec<Vec<String>> = Vec::new();
            // One kernel threads through all four presets: its slab and
            // bucket arrays are allocated once and reset between cells.
            let mut kernel = Kernel::new();
            for (i, preset) in Preset::ALL.into_iter().enumerate() {
                let (out, k) =
                    run_with_kernel(&sc, &config_from(ctx, preset, duration_s)?, kernel)?;
                kernel = k;
                let key = preset.name().replace('-', "_");
                report.num(&format!("{key}_mean_ms"), out.serving.latency.mean());
                report.num(&format!("{key}_rounds"), out.rounds_completed as f64);
                report.num(&format!("{key}_plan_swaps"), out.plan_swaps as f64);
                rows.push(vec![
                    i as f64,
                    out.serving.total() as f64,
                    out.serving.latency.mean(),
                    out.serving.percentiles.p99(),
                    out.serving.spill_fraction(),
                    out.rounds_completed as f64,
                    out.plan_swaps as f64,
                    out.retrain_triggers as f64,
                    out.events_cancelled as f64,
                ]);
                pretty.push(vec![
                    preset.name().to_string(),
                    format!("{}", out.serving.total()),
                    format!("{:.2}", out.serving.latency.mean()),
                    format!("{:.1}", out.serving.percentiles.p99()),
                    format!("{:.1}%", 100.0 * out.serving.spill_fraction()),
                    format!("{}", out.rounds_completed),
                    format!("{}", out.plan_swaps),
                    format!("{}", out.retrain_triggers),
                ]);
            }
            ctx.say(|| {
                ascii_table(
                    &[
                        "preset", "requests", "mean ms", "p99 ms", "spill", "rounds", "swaps",
                        "retrains",
                    ],
                    &pretty,
                )
            });
            report.text("preset", "all");
            report.table(
                "interference",
                &[
                    "preset", "requests", "mean_ms", "p99_ms", "spill", "rounds", "swaps",
                    "retrains", "cancelled",
                ],
                rows,
            );
        } else {
            let preset = Preset::parse(&which)?;
            let duration_s = ctx.params.f64("duration_s")?;
            let out = run(&sc, &config_from(ctx, preset, duration_s)?)?;
            report.text("preset", preset.name());
            cosim_summary(&mut report, &sc, &out, model_bytes);
            ctx.say(|| {
                format!(
                    "interference preset={}: {} requests, mean {:.2} ms, {} rounds, {} swaps",
                    preset.name(),
                    out.serving.total(),
                    out.serving.latency.mean(),
                    out.rounds_completed,
                    out.plan_swaps
                )
            });
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::scenario::{Scenario, ScenarioConfig};

    fn scenario() -> Scenario {
        Scenario::build(ScenarioConfig {
            n_clients: 12,
            n_edges: 3,
            weeks: 5,
            balanced_clients: false,
            ..Default::default()
        })
        .unwrap()
    }

    fn quick(preset: Preset) -> InterferenceConfig {
        InterferenceConfig {
            preset,
            duration_s: 120.0,
            lambda_scale: 0.5,
            ..Default::default()
        }
    }

    #[test]
    fn steady_preset_serves_and_trains_on_one_timeline() {
        let sc = scenario();
        let out = run(&sc, &quick(Preset::Steady)).unwrap();
        assert!(out.serving.total() > 1000, "{}", out.serving.total());
        assert!(out.rounds_completed >= 1, "{}", out.rounds_completed);
        assert!(out.retrain_triggers == 0);
    }

    #[test]
    fn edge_failure_preset_swaps_plan_mid_run() {
        let sc = scenario();
        // Isolate the failure reaction: no training interference, so the
        // re-solve after the failure is always feasible.
        let cfg = InterferenceConfig {
            interference_factor: 1.0,
            ..quick(Preset::EdgeFailure)
        };
        let out = run(&sc, &cfg).unwrap();
        assert!(out.plan_swaps >= 1, "no swap installed");
        assert!(out.reclusters >= 1, "{}", out.reclusters);
    }

    #[test]
    fn retrain_burst_preset_closes_the_control_loop() {
        let sc = scenario();
        let cfg = InterferenceConfig {
            duration_s: 150.0,
            ..quick(Preset::RetrainBurst)
        };
        let out = run(&sc, &cfg).unwrap();
        assert!(out.retrain_triggers >= 1, "{}", out.retrain_triggers);
        assert!(out.rounds_completed >= 3, "{}", out.rounds_completed);
    }

    #[test]
    fn surge_preset_increases_request_volume() {
        let sc = scenario();
        let steady = run(&sc, &quick(Preset::Steady)).unwrap();
        let surged = run(&sc, &quick(Preset::DiurnalSurge)).unwrap();
        assert!(
            surged.serving.total() > steady.serving.total(),
            "{} vs {}",
            surged.serving.total(),
            steady.serving.total()
        );
    }

    #[test]
    fn preset_names_round_trip_through_parse() {
        for p in Preset::ALL {
            assert_eq!(Preset::parse(p.name()).unwrap(), p);
        }
        let err = Preset::parse("steadyy").unwrap_err().to_string();
        assert!(err.contains("steady") && err.contains("retrain-burst"), "{err}");
    }

    #[test]
    fn experiment_trait_single_preset_reports_cosim_metrics() {
        use crate::config::params::{Params, Value};
        let mut p = Params::defaults(InterferenceExperiment.param_schema());
        p.set("preset", Value::Str("steady".into())).unwrap();
        p.set("clients", Value::Int(12)).unwrap();
        p.set("edges", Value::Int(3)).unwrap();
        p.set("duration_s", Value::Float(60.0)).unwrap();
        p.set("lambda_scale", Value::Float(0.5)).unwrap();
        p.set("ls_mode", Value::Str("incremental".into())).unwrap();
        let mut ctx = ExperimentCtx::cell(p);
        let report = InterferenceExperiment.run(&mut ctx).unwrap();
        assert!(report.get_f64("requests").unwrap() > 100.0);
        assert!(report.get_f64("rounds_completed").unwrap() >= 1.0);
        assert!(report.get_f64("eq1_cost").unwrap() > 0.0);
        assert!(report.get_f64("comm_gb").unwrap() > 0.0);
    }

    #[test]
    fn bad_ls_mode_errors() {
        use crate::config::params::{Params, Value};
        let mut p = Params::defaults(InterferenceExperiment.param_schema());
        p.set("preset", Value::Str("steady".into())).unwrap();
        p.set("ls_mode", Value::Str("fastest".into())).unwrap();
        assert!(InterferenceExperiment.run(&mut ExperimentCtx::cell(p)).is_err());
    }

    #[test]
    fn presets_are_deterministic() {
        let sc = scenario();
        let cfg = InterferenceConfig { record_trace: true, ..quick(Preset::EdgeFailure) };
        let a = run(&sc, &cfg).unwrap();
        let b = run(&sc, &cfg).unwrap();
        assert!(!a.trace.is_empty());
        assert_eq!(a.trace, b.trace);
        assert_eq!(a.serving.latency.mean().to_bits(), b.serving.latency.mean().to_bits());
        assert_eq!(a.plan_swaps, b.plan_swaps);
    }

    #[test]
    fn kernel_reuse_across_presets_is_bit_identical() {
        // The all-presets driver threads one kernel through its runs;
        // each cell must match a fresh-kernel run exactly.
        let sc = scenario();
        let mut kernel = Kernel::new();
        for preset in Preset::ALL {
            let cfg = InterferenceConfig { record_trace: true, ..quick(preset) };
            let fresh = run(&sc, &cfg).unwrap();
            let (reused, k) = run_with_kernel(&sc, &cfg, kernel).unwrap();
            kernel = k;
            assert_eq!(fresh.trace, reused.trace, "preset {}", preset.name());
            assert_eq!(fresh.events_processed, reused.events_processed);
            assert_eq!(fresh.events_cancelled, reused.events_cancelled);
        }
    }

    #[test]
    fn diurnal_trace_mode_runs_and_adds_volume() {
        let sc = scenario();
        let base = quick(Preset::Steady);
        let traced = InterferenceConfig {
            arrivals: ArrivalModel::from_named("diurnal", 3.0, 0.0, 10.0, base.duration_s)
                .unwrap(),
            ..base.clone()
        };
        let flat = run(&sc, &base).unwrap();
        let out = run(&sc, &traced).unwrap();
        // Diurnal trough 1.0 / peak 3.0 averages above the flat rate.
        assert!(
            out.serving.total() as f64 > flat.serving.total() as f64 * 1.2,
            "{} vs {}",
            out.serving.total(),
            flat.serving.total()
        );
        assert!(out.rounds_completed >= 1);
    }

    #[test]
    fn trace_mode_folds_preset_surge_into_overlay() {
        // DiurnalSurge under a constant open-loop trace: the preset's
        // SurgeStart/SurgeEnd pair must act through the trace overlay
        // (more volume than steady), not through the inert multiplier.
        let sc = scenario();
        let mk = |preset| InterferenceConfig {
            arrivals: ArrivalModel::Trace {
                trace: RateTrace::constant(1.0),
                chunk_s: 10.0,
            },
            ..quick(preset)
        };
        let steady = run(&sc, &mk(Preset::Steady)).unwrap();
        let surged = run(&sc, &mk(Preset::DiurnalSurge)).unwrap();
        assert!(
            surged.serving.total() as f64 > steady.serving.total() as f64 * 1.2,
            "{} vs {}",
            surged.serving.total(),
            steady.serving.total()
        );
    }

    #[test]
    fn experiment_trait_accepts_trace_param() {
        use crate::config::params::{Params, Value};
        let mut p = Params::defaults(InterferenceExperiment.param_schema());
        p.set("preset", Value::Str("steady".into())).unwrap();
        p.set("clients", Value::Int(12)).unwrap();
        p.set("edges", Value::Int(3)).unwrap();
        p.set("duration_s", Value::Float(60.0)).unwrap();
        p.set("lambda_scale", Value::Float(0.5)).unwrap();
        p.set("trace", Value::Str("flash-crowd".into())).unwrap();
        let mut ctx = ExperimentCtx::cell(p);
        let report = InterferenceExperiment.run(&mut ctx).unwrap();
        assert!(report.get_f64("requests").unwrap() > 100.0);

        let mut bad = Params::defaults(InterferenceExperiment.param_schema());
        bad.set("preset", Value::Str("steady".into())).unwrap();
        bad.set("trace", Value::Str("sinusoid".into())).unwrap();
        assert!(InterferenceExperiment.run(&mut ExperimentCtx::cell(bad)).is_err());
    }
}
