//! `experiments::interference` — the joint-timeline artifact.
//!
//! Training, serving and the orchestrator run on *one* event-driven
//! kernel (`inference::cosim`), so the paper's coupling claim — training
//! and inference workloads interfere on shared infrastructure — becomes
//! a reproducible experiment alongside Figs. 2/6–9. Four scenario
//! presets:
//!
//! * [`Preset::Steady`] — steady request load under the continual
//!   training cadence: periodic rounds degrade edge serving capacity by
//!   the interference factor; the latency timeline shows the dips.
//! * [`Preset::DiurnalSurge`] — a mid-run arrival surge; the learning
//!   controller's λ view tracks it and may re-place clusters
//!   (load-aware re-orchestration).
//! * [`Preset::EdgeFailure`] — the busiest edge fails mid-run: stale
//!   service timers are cancelled via kernel generation tags, the
//!   backlog spills to the cloud, the GPO marks the node failed, and the
//!   learning controller re-solves and installs a new plan.
//! * [`Preset::RetrainBurst`] — served-model drift trips the inference
//!   controller's EWMA trigger; the resulting retrain burst occupies
//!   timeline intervals and degrades serving while it runs — the full
//!   continual-learning control loop, closed on one clock.
//!
//! Driver: `cargo run --release --example interference`.

use crate::experiments::scenario::Scenario;
use crate::fl::timing::RoundTimeModel;
use crate::inference::cosim::{
    run_cell, ControlConfig, ControlPlane, CoSimConfig, CoSimOutcome, DriftModel, FaultEvent,
    TrainingConfig, TrainingSchedule,
};
use crate::inference::simulation::ServingConfig;
use crate::inference::LatencyModel;
use crate::orchestrator::{
    DeploymentPlan, Gpo, InferenceController, InferenceCtlConfig, LearningController,
    LearningCtlConfig,
};
use crate::solver::SolveOptions;

/// The four joint-timeline scenarios.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Preset {
    Steady,
    DiurnalSurge,
    EdgeFailure,
    RetrainBurst,
}

impl Preset {
    pub const ALL: [Preset; 4] =
        [Preset::Steady, Preset::DiurnalSurge, Preset::EdgeFailure, Preset::RetrainBurst];

    pub fn name(&self) -> &'static str {
        match self {
            Preset::Steady => "steady",
            Preset::DiurnalSurge => "diurnal-surge",
            Preset::EdgeFailure => "edge-failure",
            Preset::RetrainBurst => "retrain-burst",
        }
    }
}

#[derive(Debug, Clone)]
pub struct InterferenceConfig {
    pub preset: Preset,
    /// Simulated wall time (s).
    pub duration_s: f64,
    /// Serving-capacity multiplier while an edge trains (paper coupling).
    pub interference_factor: f64,
    /// Scale factor on every λ_i.
    pub lambda_scale: f64,
    pub latency: LatencyModel,
    pub queue_window_s: f64,
    /// Accuracy-monitor cadence (control plane).
    pub monitor_period_s: f64,
    /// Telemetry lag before the GPO sees a capacity change.
    pub report_delay_s: f64,
    /// Latency-timeline bucket width (s).
    pub bucket_s: f64,
    /// HFL round time model (straggler compute + transfers).
    pub time_model: RoundTimeModel,
    pub epochs: usize,
    pub model_bytes: usize,
    /// Solver options for the control plane's re-solves (the sweep
    /// engine's `LsMode` axis plugs in here).
    pub solve: SolveOptions,
    pub seed: u64,
    pub record_trace: bool,
}

impl Default for InterferenceConfig {
    fn default() -> Self {
        InterferenceConfig {
            preset: Preset::Steady,
            duration_s: 240.0,
            interference_factor: 0.25,
            lambda_scale: 1.0,
            latency: LatencyModel::default(),
            queue_window_s: 0.05,
            monitor_period_s: 2.0,
            report_delay_s: 3.0,
            bucket_s: 10.0,
            time_model: RoundTimeModel::default(),
            epochs: 5,
            model_bytes: 4 * 65_536,
            solve: SolveOptions::auto(),
            seed: 7,
            record_trace: false,
        }
    }
}

/// When the [`Preset::EdgeFailure`] victim fails / recovers, as
/// fractions of the run horizon. Public so drivers annotating the
/// latency timeline (e.g. `examples/interference.rs`) stay in sync with
/// the schedule instead of duplicating the constants.
pub const EDGE_FAILURE_AT_FRAC: f64 = 0.4;
pub const EDGE_RECOVER_AT_FRAC: f64 = 0.75;

/// Training cadence + fault schedule for one preset.
fn preset_plan(
    cfg: &InterferenceConfig,
    sc: &Scenario,
    lambdas: &[f64],
) -> (TrainingSchedule, Vec<(f64, FaultEvent)>, DriftModel) {
    let d = cfg.duration_s;
    let periodic = TrainingSchedule::Periodic { start_s: 0.1 * d, gap_s: (0.05 * d).max(1.0) };
    let no_drift = DriftModel { fresh_mse: 0.02, drift_per_s: 0.0 };
    match cfg.preset {
        Preset::Steady => (periodic, Vec::new(), no_drift),
        Preset::DiurnalSurge => (
            periodic,
            vec![
                (0.3 * d, FaultEvent::SurgeStart { factor: 3.0 }),
                (0.6 * d, FaultEvent::SurgeEnd),
            ],
            no_drift,
        ),
        Preset::EdgeFailure => {
            // Fail the edge carrying the most load under the HFLOP plan.
            let m = sc.topo.n_edges();
            let mut load = vec![0.0f64; m];
            for (dev, a) in sc.assign_hflop.assign.iter().enumerate() {
                if let Some(j) = *a {
                    load[j] += lambdas[dev];
                }
            }
            let victim = (0..m)
                .max_by(|&a, &b| load[a].total_cmp(&load[b]))
                .unwrap_or(0);
            (
                periodic,
                vec![
                    (EDGE_FAILURE_AT_FRAC * d, FaultEvent::EdgeFail(victim)),
                    (EDGE_RECOVER_AT_FRAC * d, FaultEvent::EdgeRecover(victim)),
                ],
                no_drift,
            )
        }
        Preset::RetrainBurst => (
            TrainingSchedule::OnTrigger { rounds_per_task: 3 },
            Vec::new(),
            DriftModel { fresh_mse: 0.02, drift_per_s: 0.002 },
        ),
    }
}

/// Run one preset on a built scenario: wires the GPO inventory and the
/// two controllers from the scenario topology, seeds the controller with
/// the scenario's HFLOP plan (so the first re-solve is a *swap*, not a
/// cold start), and runs the co-simulation to the horizon.
pub fn run(sc: &Scenario, cfg: &InterferenceConfig) -> anyhow::Result<CoSimOutcome> {
    let n = sc.topo.n_devices();
    let m = sc.topo.n_edges();
    let lambdas: Vec<f64> = sc.lambdas().iter().map(|l| l * cfg.lambda_scale).collect();
    let caps = sc.capacities();

    // GPO inventory mirrors the scenario topology (dense ids 0..n, 0..m).
    let mut gpo = Gpo::new();
    for dev in &sc.topo.devices {
        gpo.register_device(dev.id, dev.location);
    }
    for edge in &sc.topo.edges {
        gpo.register_edge(edge.id, edge.location, edge.capacity);
    }

    let mut learning = LearningController::new(LearningCtlConfig {
        l: sc.cfg.l,
        solve: cfg.solve.clone(),
        ..Default::default()
    });
    for (dev, &l) in lambdas.iter().enumerate() {
        learning.set_lambda(dev, l);
    }
    learning.current_plan = Some(DeploymentPlan {
        assignment: sc.assign_hflop.clone(),
        edge_ids: (0..m).collect(),
        device_ids: (0..n).collect(),
        cost: sc.hflop_cost,
        proven_optimal: sc.hflop_optimal,
    });

    let (schedule, faults, drift) = preset_plan(cfg, sc, &lambdas);
    let control = ControlPlane::new(
        gpo,
        learning,
        InferenceController::new(InferenceCtlConfig::default()),
        ControlConfig {
            monitor_period_s: cfg.monitor_period_s,
            report_delay_s: cfg.report_delay_s,
            drift,
            resolve_on_recover: true,
        },
    );

    Ok(run_cell(
        CoSimConfig {
            serving: ServingConfig {
                assign: sc.assign_hflop.assign.clone(),
                lambda: lambdas,
                capacity: caps,
                latency: cfg.latency.clone(),
                duration_s: cfg.duration_s,
                queue_window_s: cfg.queue_window_s,
                seed: cfg.seed,
            },
            interference_factor: cfg.interference_factor,
            training: TrainingConfig {
                schedule,
                time_model: cfg.time_model.clone(),
                epochs: cfg.epochs,
                model_bytes: cfg.model_bytes,
            },
            faults,
            bucket_s: cfg.bucket_s,
            record_trace: cfg.record_trace,
        },
        Some(control),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::scenario::{Scenario, ScenarioConfig};

    fn scenario() -> Scenario {
        Scenario::build(ScenarioConfig {
            n_clients: 12,
            n_edges: 3,
            weeks: 5,
            balanced_clients: false,
            ..Default::default()
        })
        .unwrap()
    }

    fn quick(preset: Preset) -> InterferenceConfig {
        InterferenceConfig {
            preset,
            duration_s: 120.0,
            lambda_scale: 0.5,
            ..Default::default()
        }
    }

    #[test]
    fn steady_preset_serves_and_trains_on_one_timeline() {
        let sc = scenario();
        let out = run(&sc, &quick(Preset::Steady)).unwrap();
        assert!(out.serving.total() > 1000, "{}", out.serving.total());
        assert!(out.rounds_completed >= 1, "{}", out.rounds_completed);
        assert!(out.retrain_triggers == 0);
    }

    #[test]
    fn edge_failure_preset_swaps_plan_mid_run() {
        let sc = scenario();
        // Isolate the failure reaction: no training interference, so the
        // re-solve after the failure is always feasible.
        let cfg = InterferenceConfig {
            interference_factor: 1.0,
            ..quick(Preset::EdgeFailure)
        };
        let out = run(&sc, &cfg).unwrap();
        assert!(out.plan_swaps >= 1, "no swap installed");
        assert!(out.reclusters >= 1, "{}", out.reclusters);
    }

    #[test]
    fn retrain_burst_preset_closes_the_control_loop() {
        let sc = scenario();
        let cfg = InterferenceConfig {
            duration_s: 150.0,
            ..quick(Preset::RetrainBurst)
        };
        let out = run(&sc, &cfg).unwrap();
        assert!(out.retrain_triggers >= 1, "{}", out.retrain_triggers);
        assert!(out.rounds_completed >= 3, "{}", out.rounds_completed);
    }

    #[test]
    fn surge_preset_increases_request_volume() {
        let sc = scenario();
        let steady = run(&sc, &quick(Preset::Steady)).unwrap();
        let surged = run(&sc, &quick(Preset::DiurnalSurge)).unwrap();
        assert!(
            surged.serving.total() > steady.serving.total(),
            "{} vs {}",
            surged.serving.total(),
            steady.serving.total()
        );
    }

    #[test]
    fn presets_are_deterministic() {
        let sc = scenario();
        let cfg = InterferenceConfig { record_trace: true, ..quick(Preset::EdgeFailure) };
        let a = run(&sc, &cfg).unwrap();
        let b = run(&sc, &cfg).unwrap();
        assert!(!a.trace.is_empty());
        assert_eq!(a.trace, b.trace);
        assert_eq!(a.serving.latency.mean().to_bits(), b.serving.latency.mean().to_bits());
        assert_eq!(a.plan_swaps, b.plan_swaps);
    }
}
