//! Fig. 2 — execution time of solving HFLOP optimally, for growing
//! instance sizes, mean with 95% confidence intervals.
//!
//! The paper uses CPLEX branch & cut on an 8-core Ryzen (up to 10,000
//! devices × 100 edges, hundreds of seconds). Our exact solver is the
//! in-tree B&B + simplex on one core, so the sweep sizes are scaled down;
//! the reproduced claim is the *shape*: super-linear growth in n·m and
//! feasibility for practically-sized instances (§IV-C).

use crate::config::params::ParamSpec;
use crate::hflop::{InstanceBuilder, SparseInstance};
use crate::metrics::export::ascii_table;
use crate::solver::{aggregated_lp_bound, branch_and_bound, solve_sparse, BbOptions, SolveOptions};
use crate::util::stats::Summary;

use super::registry::{Experiment, ExperimentCtx, ParamDefault, Report};

/// One sweep point result.
#[derive(Debug, Clone)]
pub struct Fig2Row {
    pub n: usize,
    pub m: usize,
    pub mean_s: f64,
    pub ci95_s: f64,
    pub mean_nodes: f64,
    pub mean_cost: f64,
    pub all_optimal: bool,
}

/// One sharded sweep point (`solver=sharded`): wall time, Eq. 1 cost and
/// the relative gap to the aggregated-LP lower bound.
#[derive(Debug, Clone)]
pub struct Fig2ShardedRow {
    pub n: usize,
    pub m: usize,
    pub mean_s: f64,
    pub ci95_s: f64,
    pub mean_cost: f64,
    pub mean_gap: f64,
}

/// Default sweep: the paper's 2-D grid shape (devices × edge hosts),
/// scaled to this solver/core.
pub fn default_sweep() -> Vec<(usize, usize)> {
    vec![
        (25, 4),
        (50, 4),
        (100, 6),
        (200, 8),
        (400, 10),
        (800, 12),
    ]
}

/// Default sharded sweep: metro-scale clustered instances the dense
/// solvers cannot touch without materializing n·m costs. The benchmark
/// (`bench_solver`) extends the same family to n = 1M.
pub fn default_sharded_sweep() -> Vec<(usize, usize)> {
    vec![(2_000, 16), (10_000, 64), (50_000, 128)]
}

/// Run the sweep: `reps` random instances per size, seeded `seed + rep`.
///
/// `time_limit_s` is the opt-in wall-clock cutoff (this experiment
/// measures solve *time*, so machine-dependence is inherent); pass 0 or
/// a negative value to run on the deterministic node budget alone.
pub fn run(sweep: &[(usize, usize)], reps: usize, time_limit_s: f64, seed: u64) -> Vec<Fig2Row> {
    let time_limit = if time_limit_s > 0.0 { Some(time_limit_s) } else { None };
    let mut rows = Vec::with_capacity(sweep.len());
    for &(n, m) in sweep {
        let mut times = Vec::with_capacity(reps);
        let mut nodes = Vec::with_capacity(reps);
        let mut costs = Vec::with_capacity(reps);
        let mut all_optimal = true;
        for rep in 0..reps {
            let inst = InstanceBuilder::unit_cost(n, m, seed.wrapping_add(rep as u64)).build();
            let opts = BbOptions { time_limit_s: time_limit, ..Default::default() };
            let out = branch_and_bound(&inst, &opts);
            all_optimal &= out.proven_optimal;
            times.push(out.wall_s);
            nodes.push(out.nodes as f64);
            costs.push(out.cost);
        }
        let ts = Summary::of(&times);
        let ns = Summary::of(&nodes);
        rows.push(Fig2Row {
            n,
            m,
            mean_s: ts.mean,
            ci95_s: if ts.ci95.is_finite() { ts.ci95 } else { 0.0 },
            mean_nodes: ns.mean,
            mean_cost: Summary::of(&costs).mean,
            all_optimal,
        });
    }
    rows
}

/// Run the sharded sweep: clustered sparse instances solved through the
/// region-parallel path, with the aggregated-LP bound as the gap
/// reference.
pub fn run_sharded(
    sweep: &[(usize, usize)],
    reps: usize,
    seed: u64,
    cand_k: usize,
    regions: usize,
) -> anyhow::Result<Vec<Fig2ShardedRow>> {
    let mut rows = Vec::with_capacity(sweep.len());
    for &(n, m) in sweep {
        let mut times = Vec::with_capacity(reps);
        let mut costs = Vec::with_capacity(reps);
        let mut gaps = Vec::with_capacity(reps);
        for rep in 0..reps {
            let rep_seed = seed.wrapping_add(rep as u64);
            let sp = SparseInstance::clustered(n, m, rep_seed, cand_k);
            let mut opts = SolveOptions::sharded();
            opts.shard.root_seed = rep_seed;
            opts.shard.regions = regions;
            let out = solve_sparse(&sp, &opts).map_err(anyhow::Error::new)?;
            let bound = aggregated_lp_bound(&sp);
            let cost = out.solution.cost;
            times.push(out.solution.wall_s);
            costs.push(cost);
            gaps.push(if bound > 0.0 { (cost - bound) / bound } else { 0.0 });
        }
        let ts = Summary::of(&times);
        rows.push(Fig2ShardedRow {
            n,
            m,
            mean_s: ts.mean,
            ci95_s: if ts.ci95.is_finite() { ts.ci95 } else { 0.0 },
            mean_cost: Summary::of(&costs).mean,
            mean_gap: Summary::of(&gaps).mean,
        });
    }
    Ok(rows)
}

/// Registry port (DESIGN.md §5): the Fig. 2 solve-time sweep as a typed
/// experiment.
pub struct Fig2Experiment;

const SCHEMA: &[ParamSpec] = &[
    ParamSpec {
        key: "reps",
        default: ParamDefault::Int(5),
        help: "random instances per sweep point",
    },
    ParamSpec {
        key: "time_limit_s",
        default: ParamDefault::Float(60.0),
        help: "opt-in B&B wall-clock limit per solve (0 = node budget only)",
    },
    ParamSpec {
        key: "max_points",
        default: ParamDefault::Int(6),
        help: "how many of the default sweep sizes to run",
    },
    ParamSpec {
        key: "seed",
        default: ParamDefault::Int(1000),
        help: "base instance seed (rep r uses seed + r)",
    },
    ParamSpec {
        key: "solver",
        default: ParamDefault::Str("exact"),
        help: "'exact' (dense B&B sweep) or 'sharded' (sparse region-parallel sweep)",
    },
    ParamSpec {
        key: "cand_k",
        default: ParamDefault::Int(8),
        help: "candidate edges per device (sharded solver only)",
    },
    ParamSpec {
        key: "regions",
        default: ParamDefault::Int(0),
        help: "shard region count, 0 = auto (sharded solver only)",
    },
    ParamSpec {
        key: "sharded_n",
        default: ParamDefault::Int(0),
        help: "override: single sharded sweep point, devices (0 = default sweep)",
    },
    ParamSpec {
        key: "sharded_m",
        default: ParamDefault::Int(0),
        help: "override: single sharded sweep point, edge hosts (0 = default sweep)",
    },
];

impl Experiment for Fig2Experiment {
    fn name(&self) -> &'static str {
        "fig2"
    }

    fn describe(&self) -> &'static str {
        "HFLOP optimal solve times vs instance size (mean + 95% CI)"
    }

    fn param_schema(&self) -> &'static [ParamSpec] {
        SCHEMA
    }

    fn run(&self, ctx: &mut ExperimentCtx) -> anyhow::Result<Report> {
        let reps = ctx.usize_capped("reps", 2)?;
        let time_limit_s = ctx.params.f64("time_limit_s")?;
        // Smoke runs keep only the two smallest points.
        let max_points = ctx.usize_capped("max_points", 2)?.max(1);
        let seed = ctx.params.i64("seed")? as u64;
        let solver = ctx.params.str("solver")?;

        if solver == "sharded" {
            return self.run_sharded_sweep(ctx, reps, max_points, seed);
        }
        anyhow::ensure!(solver == "exact", "unknown fig2 solver '{solver}'");

        let mut sweep = default_sweep();
        sweep.truncate(max_points);

        let rows = run(&sweep, reps, time_limit_s, seed);
        let table: Vec<Vec<String>> = rows
            .iter()
            .map(|r| {
                vec![
                    format!("{}", r.n),
                    format!("{}", r.m),
                    format!("{:.4}", r.mean_s),
                    format!("{:.4}", r.ci95_s),
                    format!("{:.0}", r.mean_nodes),
                    format!("{}", r.all_optimal),
                ]
            })
            .collect();
        ctx.say(|| ascii_table(&["n", "m", "mean_s", "ci95", "nodes", "optimal"], &table));

        let mut report = Report::new("fig2");
        report.num("n_points", rows.len() as f64);
        report.num("reps", reps as f64);
        report.flag("all_optimal", rows.iter().all(|r| r.all_optimal));
        report.num(
            "max_mean_s",
            rows.iter().map(|r| r.mean_s).fold(0.0f64, f64::max),
        );
        report.num(
            "eq1_cost",
            rows.iter().map(|r| r.mean_cost).sum::<f64>() / rows.len() as f64,
        );
        report.table(
            "fig2",
            &["n", "m", "mean_s", "ci95_s", "mean_nodes", "mean_cost"],
            rows.iter()
                .map(|r| {
                    vec![r.n as f64, r.m as f64, r.mean_s, r.ci95_s, r.mean_nodes, r.mean_cost]
                })
                .collect(),
        );
        Ok(report)
    }
}

impl Fig2Experiment {
    fn run_sharded_sweep(
        &self,
        ctx: &mut ExperimentCtx,
        reps: usize,
        max_points: usize,
        seed: u64,
    ) -> anyhow::Result<Report> {
        let cand_k = ctx.params.usize("cand_k")?.max(1);
        let regions = ctx.params.usize("regions")?;
        let n_override = ctx.params.usize("sharded_n")?;
        let m_override = ctx.params.usize("sharded_m")?;
        let mut sweep = if n_override > 0 && m_override > 0 {
            vec![(n_override, m_override)]
        } else {
            default_sharded_sweep()
        };
        sweep.truncate(max_points);

        let rows = run_sharded(&sweep, reps, seed, cand_k, regions)?;
        let table: Vec<Vec<String>> = rows
            .iter()
            .map(|r| {
                vec![
                    format!("{}", r.n),
                    format!("{}", r.m),
                    format!("{:.4}", r.mean_s),
                    format!("{:.4}", r.ci95_s),
                    format!("{:.2}", r.mean_cost),
                    format!("{:.4}", r.mean_gap),
                ]
            })
            .collect();
        ctx.say(|| ascii_table(&["n", "m", "mean_s", "ci95", "cost", "gap"], &table));

        let mut report = Report::new("fig2");
        report.num("n_points", rows.len() as f64);
        report.num("reps", reps as f64);
        report.num(
            "eq1_cost",
            rows.iter().map(|r| r.mean_cost).sum::<f64>() / rows.len() as f64,
        );
        report.num(
            "max_gap",
            rows.iter().map(|r| r.mean_gap).fold(0.0f64, f64::max),
        );
        report.table(
            "fig2_sharded",
            &["n", "m", "mean_s", "ci95_s", "mean_cost", "mean_gap"],
            rows.iter()
                .map(|r| vec![r.n as f64, r.m as f64, r.mean_s, r.ci95_s, r.mean_cost, r.mean_gap])
                .collect(),
        );
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::{Params, Value};

    #[test]
    fn small_sweep_runs_and_grows() {
        let rows = run(&[(10, 3), (40, 5)], 3, 60.0, 1000);
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.all_optimal));
        assert!(rows.iter().all(|r| r.mean_s >= 0.0));
        assert!(rows.iter().all(|r| r.mean_cost > 0.0));
        // Bigger instances must not be (meaningfully) faster.
        assert!(rows[1].mean_s >= rows[0].mean_s * 0.5);
    }

    #[test]
    fn rows_expose_ci() {
        let rows = run(&[(10, 3)], 4, 60.0, 1000);
        assert!(rows[0].ci95_s >= 0.0);
        assert!(rows[0].mean_nodes >= 1.0);
    }

    #[test]
    fn sharded_sweep_reports_cost_and_gap() {
        let rows = run_sharded(&[(300, 8)], 2, 5, 4, 0).unwrap();
        assert_eq!(rows.len(), 1);
        assert!(rows[0].mean_cost > 0.0);
        assert!(rows[0].mean_gap >= 0.0);
    }

    #[test]
    fn experiment_trait_runs_sharded_solver() {
        let mut params = Params::defaults(Fig2Experiment.param_schema());
        params.set("solver", Value::Str("sharded".into())).unwrap();
        params.set("sharded_n", Value::Int(250)).unwrap();
        params.set("sharded_m", Value::Int(8)).unwrap();
        params.set("reps", Value::Int(1)).unwrap();
        params.set("max_points", Value::Int(1)).unwrap();
        let mut ctx = ExperimentCtx::cell(params);
        let report = Fig2Experiment.run(&mut ctx).unwrap();
        assert!(report.get_f64("eq1_cost").unwrap() > 0.0);
        assert!(report.get_f64("max_gap").unwrap() >= 0.0);
        assert_eq!(report.tables[0].name, "fig2_sharded");
    }

    #[test]
    fn experiment_trait_runs_in_smoke_mode() {
        let params = Params::defaults(Fig2Experiment.param_schema());
        let mut ctx = ExperimentCtx::cell(params).with_smoke(true);
        let report = Fig2Experiment.run(&mut ctx).unwrap();
        assert_eq!(report.experiment, "fig2");
        // Smoke caps: 2 points, 2 reps.
        assert_eq!(report.get_f64("n_points").unwrap(), 2.0);
        assert_eq!(report.tables.len(), 1);
        assert_eq!(report.tables[0].rows.len(), 2);
    }
}
