//! Fig. 2 — execution time of solving HFLOP optimally, for growing
//! instance sizes, mean with 95% confidence intervals.
//!
//! The paper uses CPLEX branch & cut on an 8-core Ryzen (up to 10,000
//! devices × 100 edges, hundreds of seconds). Our exact solver is the
//! in-tree B&B + simplex on one core, so the sweep sizes are scaled down;
//! the reproduced claim is the *shape*: super-linear growth in n·m and
//! feasibility for practically-sized instances (§IV-C).

use crate::hflop::InstanceBuilder;
use crate::solver::{branch_and_bound, BbOptions};
use crate::util::stats::Summary;

/// One sweep point result.
#[derive(Debug, Clone)]
pub struct Fig2Row {
    pub n: usize,
    pub m: usize,
    pub mean_s: f64,
    pub ci95_s: f64,
    pub mean_nodes: f64,
    pub all_optimal: bool,
}

/// Default sweep: the paper's 2-D grid shape (devices × edge hosts),
/// scaled to this solver/core.
pub fn default_sweep() -> Vec<(usize, usize)> {
    vec![
        (25, 4),
        (50, 4),
        (100, 6),
        (200, 8),
        (400, 10),
        (800, 12),
    ]
}

/// Run the sweep: `reps` random instances per size.
pub fn run(sweep: &[(usize, usize)], reps: usize, time_limit_s: f64) -> Vec<Fig2Row> {
    let mut rows = Vec::with_capacity(sweep.len());
    for &(n, m) in sweep {
        let mut times = Vec::with_capacity(reps);
        let mut nodes = Vec::with_capacity(reps);
        let mut all_optimal = true;
        for rep in 0..reps {
            let inst = InstanceBuilder::unit_cost(n, m, 1000 + rep as u64).build();
            let opts = BbOptions { time_limit_s, ..Default::default() };
            let out = branch_and_bound(&inst, &opts);
            all_optimal &= out.proven_optimal;
            times.push(out.wall_s);
            nodes.push(out.nodes as f64);
        }
        let ts = Summary::of(&times);
        let ns = Summary::of(&nodes);
        rows.push(Fig2Row {
            n,
            m,
            mean_s: ts.mean,
            ci95_s: if ts.ci95.is_finite() { ts.ci95 } else { 0.0 },
            mean_nodes: ns.mean,
            all_optimal,
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_sweep_runs_and_grows() {
        let rows = run(&[(10, 3), (40, 5)], 3, 60.0);
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.all_optimal));
        assert!(rows.iter().all(|r| r.mean_s >= 0.0));
        // Bigger instances must not be (meaningfully) faster.
        assert!(rows[1].mean_s >= rows[0].mean_s * 0.5);
    }

    #[test]
    fn rows_expose_ci() {
        let rows = run(&[(10, 3)], 4, 60.0);
        assert!(rows[0].ci95_s >= 0.0);
        assert!(rows[0].mean_nodes >= 1.0);
    }
}
