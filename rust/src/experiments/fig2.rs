//! Fig. 2 — execution time of solving HFLOP optimally, for growing
//! instance sizes, mean with 95% confidence intervals.
//!
//! The paper uses CPLEX branch & cut on an 8-core Ryzen (up to 10,000
//! devices × 100 edges, hundreds of seconds). Our exact solver is the
//! in-tree B&B + simplex on one core, so the sweep sizes are scaled down;
//! the reproduced claim is the *shape*: super-linear growth in n·m and
//! feasibility for practically-sized instances (§IV-C).

use crate::config::params::ParamSpec;
use crate::hflop::InstanceBuilder;
use crate::metrics::export::ascii_table;
use crate::solver::{branch_and_bound, BbOptions};
use crate::util::stats::Summary;

use super::registry::{Experiment, ExperimentCtx, ParamDefault, Report};

/// One sweep point result.
#[derive(Debug, Clone)]
pub struct Fig2Row {
    pub n: usize,
    pub m: usize,
    pub mean_s: f64,
    pub ci95_s: f64,
    pub mean_nodes: f64,
    pub all_optimal: bool,
}

/// Default sweep: the paper's 2-D grid shape (devices × edge hosts),
/// scaled to this solver/core.
pub fn default_sweep() -> Vec<(usize, usize)> {
    vec![
        (25, 4),
        (50, 4),
        (100, 6),
        (200, 8),
        (400, 10),
        (800, 12),
    ]
}

/// Run the sweep: `reps` random instances per size.
pub fn run(sweep: &[(usize, usize)], reps: usize, time_limit_s: f64) -> Vec<Fig2Row> {
    let mut rows = Vec::with_capacity(sweep.len());
    for &(n, m) in sweep {
        let mut times = Vec::with_capacity(reps);
        let mut nodes = Vec::with_capacity(reps);
        let mut all_optimal = true;
        for rep in 0..reps {
            let inst = InstanceBuilder::unit_cost(n, m, 1000 + rep as u64).build();
            let opts = BbOptions { time_limit_s, ..Default::default() };
            let out = branch_and_bound(&inst, &opts);
            all_optimal &= out.proven_optimal;
            times.push(out.wall_s);
            nodes.push(out.nodes as f64);
        }
        let ts = Summary::of(&times);
        let ns = Summary::of(&nodes);
        rows.push(Fig2Row {
            n,
            m,
            mean_s: ts.mean,
            ci95_s: if ts.ci95.is_finite() { ts.ci95 } else { 0.0 },
            mean_nodes: ns.mean,
            all_optimal,
        });
    }
    rows
}

/// Registry port (DESIGN.md §5): the Fig. 2 solve-time sweep as a typed
/// experiment.
pub struct Fig2Experiment;

const SCHEMA: &[ParamSpec] = &[
    ParamSpec {
        key: "reps",
        default: ParamDefault::Int(5),
        help: "random instances per sweep point",
    },
    ParamSpec {
        key: "time_limit_s",
        default: ParamDefault::Float(60.0),
        help: "B&B time limit per solve",
    },
    ParamSpec {
        key: "max_points",
        default: ParamDefault::Int(6),
        help: "how many of the default sweep sizes to run",
    },
];

impl Experiment for Fig2Experiment {
    fn name(&self) -> &'static str {
        "fig2"
    }

    fn describe(&self) -> &'static str {
        "HFLOP optimal solve times vs instance size (mean + 95% CI)"
    }

    fn param_schema(&self) -> &'static [ParamSpec] {
        SCHEMA
    }

    fn run(&self, ctx: &mut ExperimentCtx) -> anyhow::Result<Report> {
        let reps = ctx.usize_capped("reps", 2)?;
        let time_limit_s = ctx.params.f64("time_limit_s")?;
        // Smoke runs keep only the two smallest points.
        let max_points = ctx.usize_capped("max_points", 2)?.max(1);
        let mut sweep = default_sweep();
        sweep.truncate(max_points);

        let rows = run(&sweep, reps, time_limit_s);
        let table: Vec<Vec<String>> = rows
            .iter()
            .map(|r| {
                vec![
                    format!("{}", r.n),
                    format!("{}", r.m),
                    format!("{:.4}", r.mean_s),
                    format!("{:.4}", r.ci95_s),
                    format!("{:.0}", r.mean_nodes),
                    format!("{}", r.all_optimal),
                ]
            })
            .collect();
        ctx.say(|| ascii_table(&["n", "m", "mean_s", "ci95", "nodes", "optimal"], &table));

        let mut report = Report::new("fig2");
        report.num("n_points", rows.len() as f64);
        report.num("reps", reps as f64);
        report.flag("all_optimal", rows.iter().all(|r| r.all_optimal));
        report.num(
            "max_mean_s",
            rows.iter().map(|r| r.mean_s).fold(0.0f64, f64::max),
        );
        report.table(
            "fig2",
            &["n", "m", "mean_s", "ci95_s", "mean_nodes"],
            rows.iter()
                .map(|r| vec![r.n as f64, r.m as f64, r.mean_s, r.ci95_s, r.mean_nodes])
                .collect(),
        );
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::Params;

    #[test]
    fn small_sweep_runs_and_grows() {
        let rows = run(&[(10, 3), (40, 5)], 3, 60.0);
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.all_optimal));
        assert!(rows.iter().all(|r| r.mean_s >= 0.0));
        // Bigger instances must not be (meaningfully) faster.
        assert!(rows[1].mean_s >= rows[0].mean_s * 0.5);
    }

    #[test]
    fn rows_expose_ci() {
        let rows = run(&[(10, 3)], 4, 60.0);
        assert!(rows[0].ci95_s >= 0.0);
        assert!(rows[0].mean_nodes >= 1.0);
    }

    #[test]
    fn experiment_trait_runs_in_smoke_mode() {
        let params = Params::defaults(Fig2Experiment.param_schema());
        let mut ctx = ExperimentCtx::cell(params).with_smoke(true);
        let report = Fig2Experiment.run(&mut ctx).unwrap();
        assert_eq!(report.experiment, "fig2");
        // Smoke caps: 2 points, 2 reps.
        assert_eq!(report.get_f64("n_points").unwrap(), 2.0);
        assert_eq!(report.tables.len(), 1);
        assert_eq!(report.tables[0].rows.len(), 2);
    }
}
