//! The experiment registry — one typed entry point for every paper
//! artifact (DESIGN.md §5).
//!
//! Every scenario the repo can reproduce implements [`Experiment`]:
//!
//! * `name()` / `describe()` — identity and the one-liner
//!   `hflop experiment --list` prints;
//! * `param_schema()` — the full set of parameters the experiment
//!   understands ([`ParamSpec`]), from which the per-experiment `--help`
//!   is generated and against which every config file / `--set` override
//!   is validated (unknown keys fail fast, `config::params`);
//! * `run(&mut ExperimentCtx)` — the work, returning a uniform
//!   [`Report`] artifact bundle (JSON summary + named CSV tables through
//!   `metrics::export`, stamped with
//!   [`crate::metrics::export::SCHEMA_VERSION`]).
//!
//! The static [`REGISTRY`] lists every implementation. `main.rs`
//! dispatches `hflop experiment <name>` purely through [`find`]; the
//! sweep engine (`experiments::sweep`) builds its grids as *registered
//! experiment × param-override axes × seed range*, so anything added
//! here is immediately runnable, documentable (`--list`/`--help`),
//! sweepable, and smoke-tested by the CI loop over `--names` — without
//! touching the launcher or `sweep.rs`.

use crate::config::params::{ParamSpec, Params};
use crate::metrics::export::{ResultsWriter, Table, SCHEMA_VERSION};
use crate::runtime::{Engine, Manifest, Preload};
use crate::util::json::Json;
use crate::util::rng::Rng;

pub use crate::config::params::{ParamDefault, ParamKind};

/// Everything an experiment run needs, bundled: the resolved parameters,
/// a seeded RNG (from the `seed` parameter when the schema declares
/// one), the optional output sink for extra artifacts, and the two
/// execution-mode knobs (CI smoke budget, sweep-cell quiet mode).
pub struct ExperimentCtx {
    pub params: Params,
    pub rng: Rng,
    /// Extra-artifact sink. The launcher passes one; sweep cells pass
    /// `None` (cells must not touch the filesystem — their entire output
    /// is the returned [`Report`]).
    pub out: Option<ResultsWriter>,
    /// `HFLOP_BENCH_SMOKE=1`: shrink the workload (experiments only
    /// shrink parameters the user did not explicitly set).
    pub smoke: bool,
    /// Suppress console tables (sweep cells run quiet on worker threads).
    pub quiet: bool,
}

impl ExperimentCtx {
    /// Launcher-side context: smoke from the environment, console on.
    pub fn new(params: Params) -> ExperimentCtx {
        let rng = Rng::new(params.seed_or(0));
        ExperimentCtx { params, rng, out: None, smoke: crate::util::smoke_mode(), quiet: false }
    }

    /// Sweep-cell context: quiet, and immune to the smoke knob so a
    /// grid's declared parameters fully determine its matrix.
    pub fn cell(params: Params) -> ExperimentCtx {
        let rng = Rng::new(params.seed_or(0));
        ExperimentCtx { params, rng, out: None, smoke: false, quiet: true }
    }

    pub fn with_out(mut self, out: ResultsWriter) -> ExperimentCtx {
        self.out = Some(out);
        self
    }

    pub fn with_smoke(mut self, smoke: bool) -> ExperimentCtx {
        self.smoke = smoke;
        self
    }

    /// `usize` parameter with a smoke-mode cap: explicit settings always
    /// win; otherwise smoke runs use `min(default, cap)`.
    pub fn usize_capped(&self, key: &str, cap: usize) -> anyhow::Result<usize> {
        let v = self.params.usize(key)?;
        Ok(if self.smoke && !self.params.is_set(key) { v.min(cap) } else { v })
    }

    /// `f64` parameter with a smoke-mode cap (same rules).
    pub fn f64_capped(&self, key: &str, cap: f64) -> anyhow::Result<f64> {
        let v = self.params.f64(key)?;
        Ok(if self.smoke && !self.params.is_set(key) { v.min(cap) } else { v })
    }

    /// Console print gate: `ctx.say(|| format!(...))`.
    pub fn say(&self, line: impl FnOnce() -> String) {
        if !self.quiet {
            println!("{}", line());
        }
    }
}

/// A uniform experiment artifact bundle: one JSON summary object plus
/// any number of named CSV tables. [`Report::write`] lands it under the
/// results directory as `<stem>.json` + `<table>.csv` files, all
/// carrying [`SCHEMA_VERSION`].
#[derive(Debug, Clone)]
pub struct Report {
    pub experiment: String,
    /// Output file stem for the JSON summary (defaults to the experiment
    /// name; the mock-gated experiments switch to `<name>_mock` so a
    /// fabricated artifact can never be mistaken for a paper one).
    pub stem: String,
    pub schema_version: u32,
    /// Always a `Json::Obj`.
    pub summary: Json,
    pub tables: Vec<Table>,
}

impl Report {
    pub fn new(experiment: &str) -> Report {
        Report {
            experiment: experiment.to_string(),
            stem: experiment.to_string(),
            schema_version: SCHEMA_VERSION,
            summary: Json::obj(vec![]),
            tables: Vec::new(),
        }
    }

    pub fn set_stem(&mut self, stem: &str) {
        self.stem = stem.to_string();
    }

    /// Insert one summary entry.
    pub fn put(&mut self, key: &str, value: Json) {
        if let Json::Obj(m) = &mut self.summary {
            m.insert(key.to_string(), value);
        }
    }

    pub fn num(&mut self, key: &str, value: f64) {
        self.put(key, Json::Num(value));
    }

    pub fn text(&mut self, key: &str, value: &str) {
        self.put(key, Json::Str(value.to_string()));
    }

    pub fn flag(&mut self, key: &str, value: bool) {
        self.put(key, Json::Bool(value));
    }

    pub fn table(&mut self, name: &str, header: &[&str], rows: Vec<Vec<f64>>) {
        self.tables.push(Table::new(name, header, rows));
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.summary.get(key).and_then(Json::as_f64)
    }

    /// The JSON summary artifact.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("experiment", Json::Str(self.experiment.clone())),
            ("schema_version", Json::Num(self.schema_version as f64)),
            ("summary", self.summary.clone()),
        ])
    }

    /// Write `<stem>.json` + one CSV per table; returns the paths.
    pub fn write(&self, out: &ResultsWriter) -> anyhow::Result<Vec<std::path::PathBuf>> {
        let mut paths = vec![out.write_json(&format!("{}.json", self.stem), &self.to_json())?];
        for t in &self.tables {
            paths.push(out.write_table(t)?);
        }
        Ok(paths)
    }
}

/// One reproducible artifact of the paper (or a derived scenario).
///
/// `Sync` is a supertrait so implementations can live in the static
/// [`REGISTRY`] and run on sweep worker threads.
pub trait Experiment: Sync {
    /// Registry key: what `hflop experiment <name>` dispatches on.
    fn name(&self) -> &'static str;
    /// One-line description for `--list` and DESIGN.md §5.
    fn describe(&self) -> &'static str;
    /// Every parameter the experiment understands.
    fn param_schema(&self) -> &'static [ParamSpec];
    /// Run with resolved parameters; all output goes through the report.
    fn run(&self, ctx: &mut ExperimentCtx) -> anyhow::Result<Report>;
}

/// Every registered experiment, in `--list` order. DESIGN.md §5 must
/// mirror this table row-for-row (`rust/tests/registry_contract.rs`).
pub static REGISTRY: &[&dyn Experiment] = &[
    &super::fig2::Fig2Experiment,
    &super::fig6::Fig6Experiment,
    &super::fig7::Fig7Experiment,
    &super::fig8::Fig8Experiment,
    &super::fig9::Fig9Experiment,
    &super::cl_table::ClTableExperiment,
    &super::interference::InterferenceExperiment,
    &super::budget::BudgetExperiment,
    &super::scenario::ScenarioExperiment,
];

/// Look an experiment up by registry name.
pub fn find(name: &str) -> Option<&'static dyn Experiment> {
    REGISTRY.iter().copied().find(|e| e.name() == name)
}

/// All registered names, in `--list` order.
pub fn names() -> Vec<&'static str> {
    REGISTRY.iter().map(|e| e.name()).collect()
}

/// Like [`find`] but with an error listing the valid names.
pub fn lookup(name: &str) -> anyhow::Result<&'static dyn Experiment> {
    find(name).ok_or_else(|| {
        anyhow::anyhow!("unknown experiment '{}' (valid: {})", name, names().join(", "))
    })
}

/// Shared `runtime = auto|real|mock` gate for the PJRT-backed
/// experiments (`fig6`, `cl`; their schemas declare `runtime` and
/// `variant`). `Some((manifest, engine))` means run the real engine;
/// `None` means take the clearly-marked mock path. `auto` tries real
/// and falls back with a stderr note; `real` hard-errors when the
/// artifacts / `pjrt` feature are absent rather than silently
/// substituting fabricated numbers.
pub fn runtime_gate(
    ctx: &ExperimentCtx,
    experiment: &str,
) -> anyhow::Result<Option<(Manifest, Engine)>> {
    let requested = ctx.params.str("runtime")?;
    match requested.as_str() {
        "mock" => Ok(None),
        "real" | "auto" => {
            let attempt = Manifest::load_default().and_then(|manifest| {
                let engine =
                    Engine::new(&manifest, &ctx.params.str("variant")?, Preload::Training)?;
                Ok((manifest, engine))
            });
            match attempt {
                Ok(pair) => Ok(Some(pair)),
                Err(e) if requested == "auto" => {
                    eprintln!(
                        "{experiment}: real runtime unavailable ({e}); falling back to mock"
                    );
                    Ok(None)
                }
                Err(e) => Err(e.context(format!("{experiment} --set runtime=real"))),
            }
        }
        other => anyhow::bail!("unknown runtime '{other}' (valid: auto, real, mock)"),
    }
}

/// Generated per-experiment help, straight from the schema.
pub fn render_help(e: &dyn Experiment) -> String {
    let mut out = String::new();
    out.push_str(&format!("hflop experiment {} — {}\n\n", e.name(), e.describe()));
    out.push_str("parameters (set via --<key> <value>, --set <key>=<value>, or --config <file>):\n");
    let width = e.param_schema().iter().map(|s| s.key.len()).max().unwrap_or(0);
    for spec in e.param_schema() {
        out.push_str(&format!(
            "  --set {:<width$}={:<10} {} [{}]\n",
            spec.key,
            spec.default.render(),
            spec.help,
            spec.default.kind().name(),
        ));
    }
    out.push_str("\ncommon options: --config <file.toml>  --out <dir>  --help\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_unique_and_well_formed() {
        let names = names();
        assert_eq!(names.len(), REGISTRY.len());
        let mut uniq = names.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), names.len(), "duplicate registry names: {names:?}");
        for e in REGISTRY {
            assert!(!e.name().is_empty());
            assert!(!e.describe().is_empty(), "{} has no description", e.name());
            assert!(!e.param_schema().is_empty(), "{} declares no parameters", e.name());
        }
    }

    #[test]
    fn registry_holds_all_nine_experiments() {
        for expect in
            ["fig2", "fig6", "fig7", "fig8", "fig9", "cl", "interference", "budget", "scenario"]
        {
            assert!(find(expect).is_some(), "experiment '{expect}' not registered");
        }
        assert_eq!(REGISTRY.len(), 9);
    }

    #[test]
    fn schema_keys_unique_per_experiment() {
        for e in REGISTRY {
            let mut keys: Vec<&str> = e.param_schema().iter().map(|s| s.key).collect();
            let n = keys.len();
            keys.sort_unstable();
            keys.dedup();
            assert_eq!(keys.len(), n, "{} has duplicate schema keys", e.name());
        }
    }

    #[test]
    fn lookup_error_lists_valid_names() {
        let err = lookup("fig11").unwrap_err().to_string();
        assert!(err.contains("fig2") && err.contains("interference"), "{err}");
    }

    #[test]
    fn help_renders_every_parameter() {
        for e in REGISTRY {
            let help = render_help(*e);
            for spec in e.param_schema() {
                assert!(help.contains(spec.key), "{}: help misses '{}'", e.name(), spec.key);
            }
        }
    }

    #[test]
    fn report_bundle_roundtrips_to_disk() {
        let mut r = Report::new("demo");
        r.num("x", 1.5);
        r.text("mode", "test");
        r.table("demo_rows", &["a", "b"], vec![vec![1.0, 2.0]]);
        let json = r.to_json();
        assert_eq!(json.get("experiment").unwrap().as_str().unwrap(), "demo");
        assert_eq!(
            json.get("schema_version").unwrap().as_f64().unwrap() as u32,
            SCHEMA_VERSION
        );
        assert_eq!(json.path(&["summary", "x"]).unwrap().as_f64().unwrap(), 1.5);

        let dir = std::env::temp_dir().join("hflop_registry_report_test");
        let out = ResultsWriter::new(&dir).unwrap();
        let paths = r.write(&out).unwrap();
        assert_eq!(paths.len(), 2);
        assert!(paths[0].ends_with("demo.json"));
        assert!(paths[1].ends_with("demo_rows.csv"));
        let text = std::fs::read_to_string(&paths[0]).unwrap();
        assert!(Json::parse(&text).unwrap().get("schema_version").is_some());
    }
}
