//! Fig. 9 — communication-cost savings of HFLOP and uncapacitated HFLOP
//! relative to standard (flat) FL, for increasing edge-node density.
//!
//! Paper setup (§V-D): n devices; for each device exactly one edge host
//! at zero cost, the rest at unit cost; unit edge↔cloud cost; uniform
//! random workloads/capacities; T = n; l = 2 (one global round per two
//! local); convergence ≈ 100 aggregation rounds → 50 global rounds;
//! model payload 594 KB. Savings are reported as mean % with 95% CI.
//! Absolute reference (4 edges / 20 devices): FL 2.37 GB, HFLOP 0.53 GB,
//! uncapacitated 0.24 GB.

use crate::config::params::ParamSpec;
use crate::hflop::InstanceBuilder;
use crate::metrics::cost::{flat_fl_bytes, hfl_bytes};
use crate::metrics::export::ascii_table;
use crate::solver::{self, SolveOptions};
use crate::util::stats::Summary;

use super::registry::{Experiment, ExperimentCtx, ParamDefault, Report};

#[derive(Debug, Clone)]
pub struct Fig9Row {
    pub m: usize,
    pub hflop_savings_pct: f64,
    pub hflop_ci95: f64,
    pub uncap_savings_pct: f64,
    pub uncap_ci95: f64,
}

#[derive(Debug, Clone)]
pub struct Fig9Config {
    pub n_devices: usize,
    /// Edge-node densities to sweep (the figure's x axis).
    pub densities: Vec<usize>,
    pub reps: usize,
    /// Total local aggregation rounds until convergence (paper: 100).
    pub rounds: usize,
    pub model_bytes: usize,
    pub seed: u64,
}

impl Default for Fig9Config {
    fn default() -> Self {
        Fig9Config {
            // Fig. 9 caption: n = 200 devices (the text's larger 500-device
            // variant is available via the CLI).
            n_devices: 200,
            densities: vec![2, 4, 8, 16, 32],
            reps: 10,
            rounds: 100,
            model_bytes: 598_020,
            seed: 9,
        }
    }
}

/// Capacity headroom for the capacitated variant. Near-1 headroom makes
/// constraint (4) genuinely binding — this is what separates HFLOP's
/// 0.53 GB from the uncapacitated 0.24 GB in the paper's absolute
/// numbers (devices forced onto metered links).
const CAPACITY_HEADROOM: f64 = 1.1;

/// One (variant, density, rep) evaluation -> metered bytes.
fn bytes_for(
    n: usize,
    m: usize,
    seed: u64,
    rounds: usize,
    model_bytes: usize,
    uncapacitated: bool,
) -> anyhow::Result<u64> {
    let builder = InstanceBuilder::unit_cost_with_headroom(n, m, seed, CAPACITY_HEADROOM);
    let inst = if uncapacitated { builder.uncapacitated().build() } else { builder.build() };
    // Capacitated instances with binding capacity have a large
    // integrality gap (unsplittable loads), which blows up exact B&B even
    // at modest sizes — exactly the regime §IV-C prescribes heuristics
    // for. The uncapacitated bound stays exact (its LP is near-integral).
    let opts = if uncapacitated { SolveOptions::auto() } else { SolveOptions::heuristic() };
    let sol = solver::solve(&inst, &opts).map_err(|e| anyhow::anyhow!("fig9 solve: {e}"))?;
    Ok(hfl_bytes(&inst, &sol.assignment, rounds, model_bytes))
}

/// Run the density sweep.
pub fn run(cfg: &Fig9Config) -> anyhow::Result<Vec<Fig9Row>> {
    let flat = flat_fl_bytes(cfg.n_devices, cfg.rounds, cfg.model_bytes) as f64;
    let mut rows = Vec::with_capacity(cfg.densities.len());
    for &m in &cfg.densities {
        let mut sav_c = Vec::with_capacity(cfg.reps);
        let mut sav_u = Vec::with_capacity(cfg.reps);
        for rep in 0..cfg.reps {
            let seed = cfg.seed + 1000 * rep as u64;
            let c = bytes_for(cfg.n_devices, m, seed, cfg.rounds, cfg.model_bytes, false)?;
            let u = bytes_for(cfg.n_devices, m, seed, cfg.rounds, cfg.model_bytes, true)?;
            sav_c.push(100.0 * (1.0 - c as f64 / flat));
            sav_u.push(100.0 * (1.0 - u as f64 / flat));
        }
        let sc = Summary::of(&sav_c);
        let su = Summary::of(&sav_u);
        rows.push(Fig9Row {
            m,
            hflop_savings_pct: sc.mean,
            hflop_ci95: if sc.ci95.is_finite() { sc.ci95 } else { 0.0 },
            uncap_savings_pct: su.mean,
            uncap_ci95: if su.ci95.is_finite() { su.ci95 } else { 0.0 },
        });
    }
    Ok(rows)
}

/// The paper's absolute-volume reference case: 4 edges, 20 devices,
/// 100 rounds, 594 KB model → (flat, hflop, uncap) in GB.
pub fn absolute_reference(seed: u64) -> anyhow::Result<(f64, f64, f64)> {
    let model_bytes = 598_020;
    let flat = flat_fl_bytes(20, 100, model_bytes) as f64 / 1e9;
    let c = bytes_for(20, 4, seed, 100, model_bytes, false)? as f64 / 1e9;
    let u = bytes_for(20, 4, seed, 100, model_bytes, true)? as f64 / 1e9;
    Ok((flat, c, u))
}

/// Registry port (DESIGN.md §5): the density sweep plus the paper's
/// absolute-volume reference case.
pub struct Fig9Experiment;

const SCHEMA: &[ParamSpec] = &[
    ParamSpec { key: "n", default: ParamDefault::Int(200), help: "devices (paper caption: 200)" },
    ParamSpec {
        key: "densities",
        default: ParamDefault::Str("2,4,8,16,32"),
        help: "comma-separated edge-node densities (the x axis)",
    },
    ParamSpec { key: "reps", default: ParamDefault::Int(10), help: "random instances per density" },
    ParamSpec {
        key: "rounds",
        default: ParamDefault::Int(100),
        help: "local aggregation rounds until convergence",
    },
    ParamSpec {
        key: "model_bytes",
        default: ParamDefault::Int(598_020),
        help: "model payload (paper: 594 KB)",
    },
    ParamSpec { key: "seed", default: ParamDefault::Int(9), help: "instance-generator seed base" },
];

fn parse_densities(s: &str) -> anyhow::Result<Vec<usize>> {
    let out: Result<Vec<usize>, _> = s.split(',').map(|p| p.trim().parse::<usize>()).collect();
    let out = out.map_err(|_| anyhow::anyhow!("bad densities '{s}' (want e.g. \"2,4,8\")"))?;
    anyhow::ensure!(!out.is_empty() && out.iter().all(|&m| m > 0), "densities must be positive");
    Ok(out)
}

impl Experiment for Fig9Experiment {
    fn name(&self) -> &'static str {
        "fig9"
    }

    fn describe(&self) -> &'static str {
        "communication-cost savings vs edge density (HFLOP + uncapacitated vs flat FL)"
    }

    fn param_schema(&self) -> &'static [ParamSpec] {
        SCHEMA
    }

    fn run(&self, ctx: &mut ExperimentCtx) -> anyhow::Result<Report> {
        let mut densities = parse_densities(&ctx.params.str("densities")?)?;
        if ctx.smoke && !ctx.params.is_set("densities") {
            densities.truncate(2);
        }
        let cfg = Fig9Config {
            n_devices: ctx.usize_capped("n", 40)?,
            densities,
            reps: ctx.usize_capped("reps", 2)?,
            rounds: ctx.params.usize("rounds")?,
            model_bytes: ctx.params.usize("model_bytes")?,
            seed: ctx.params.u64("seed")?,
        };
        let rows = run(&cfg)?;
        let table: Vec<Vec<String>> = rows
            .iter()
            .map(|r| {
                vec![
                    format!("{}", r.m),
                    format!("{:.2}", r.hflop_savings_pct),
                    format!("{:.2}", r.hflop_ci95),
                    format!("{:.2}", r.uncap_savings_pct),
                    format!("{:.2}", r.uncap_ci95),
                ]
            })
            .collect();
        ctx.say(|| ascii_table(&["edges", "hflop_sav_%", "±", "uncap_sav_%", "±"], &table));
        let (flat, hflop, uncap) = absolute_reference(5)?;
        ctx.say(|| {
            format!(
                "absolute (20 dev, 4 edges, 100 rounds): flat={flat:.2} GB hflop={hflop:.2} GB uncap={uncap:.2} GB\n\
                 paper:                                  flat=2.37 GB hflop=0.53 GB uncap=0.24 GB"
            )
        });

        let mut report = Report::new("fig9");
        report.num("n_devices", cfg.n_devices as f64);
        report.num("flat_gb", flat);
        report.num("hflop_gb", hflop);
        report.num("uncap_gb", uncap);
        report.table(
            "fig9",
            &["m", "hflop_savings_pct", "hflop_ci95", "uncap_savings_pct", "uncap_ci95"],
            rows.iter()
                .map(|r| {
                    vec![
                        r.m as f64,
                        r.hflop_savings_pct,
                        r.hflop_ci95,
                        r.uncap_savings_pct,
                        r.uncap_ci95,
                    ]
                })
                .collect(),
        );
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::Params;

    #[test]
    fn savings_positive_and_ordered() {
        let cfg = Fig9Config {
            n_devices: 40,
            densities: vec![2, 4, 8],
            reps: 3,
            ..Default::default()
        };
        let rows = run(&cfg).unwrap();
        for r in &rows {
            // Both HFL variants must save vs flat FL.
            assert!(r.hflop_savings_pct > 0.0, "{r:?}");
            // Uncapacitated is the lower bound on cost -> >= savings.
            assert!(r.uncap_savings_pct >= r.hflop_savings_pct - 1e-9, "{r:?}");
            assert!(r.uncap_savings_pct <= 100.0);
        }
    }

    #[test]
    fn savings_shrink_with_density_for_uncap() {
        // Paper: "savings are more drastic when edge host density is low"
        // — with few edges, a zero-cost edge serves many devices and few
        // costly cloud links exist.
        let cfg = Fig9Config {
            n_devices: 40,
            densities: vec![2, 16],
            reps: 4,
            ..Default::default()
        };
        let rows = run(&cfg).unwrap();
        assert!(
            rows[0].uncap_savings_pct >= rows[1].uncap_savings_pct - 1.0,
            "{rows:?}"
        );
    }

    #[test]
    fn experiment_trait_smoke_run_shrinks_and_reports() {
        let params = Params::defaults(Fig9Experiment.param_schema());
        let mut ctx = ExperimentCtx::cell(params).with_smoke(true);
        let report = Fig9Experiment.run(&mut ctx).unwrap();
        // Smoke caps: 40 devices, 2 densities, 2 reps.
        assert_eq!(report.get_f64("n_devices").unwrap(), 40.0);
        assert_eq!(report.tables[0].rows.len(), 2);
        assert!(report.get_f64("hflop_gb").unwrap() < report.get_f64("flat_gb").unwrap());
    }

    #[test]
    fn densities_parse_rejects_garbage() {
        assert!(parse_densities("2,4,8").is_ok());
        assert!(parse_densities("").is_err());
        assert!(parse_densities("2,x").is_err());
        assert!(parse_densities("0").is_err());
    }

    #[test]
    fn absolute_reference_matches_paper_scale() {
        let (flat, hflop, uncap) = absolute_reference(5).unwrap();
        // Paper: 2.37 / 0.53 / 0.24 GB. Ours must reproduce the flat
        // number nearly exactly and the ordering + rough magnitudes.
        assert!((flat - 2.37).abs() < 0.05, "flat {flat}");
        assert!(uncap < hflop && hflop < flat, "{flat} {hflop} {uncap}");
        assert!((0.1..=0.4).contains(&uncap), "uncap {uncap}");
        assert!((0.2..=1.2).contains(&hflop), "hflop {hflop}");
    }
}
