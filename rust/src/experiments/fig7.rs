//! Fig. 7 — inference response-time distributions while clients train,
//! for the three setups. Paper reference means (ms):
//! flat 79.07 ± 15.94, hierarchical 17.72 ± 24.26, HFLOP 9.89 ± 4.63.
//!
//! The mechanism (per §V-C1): all clients are busy training, so every
//! request is offloaded (R1). Flat FL pays the cloud RTT; the
//! hierarchical baselines pay the edge RTT unless the edge is over
//! capacity and proxies the request to the cloud (R3). HFLOP's
//! capacity-aware assignment keeps edges under their limits, so its
//! latency concentrates at the edge RTT.

use crate::config::params::ParamSpec;
use crate::config::Setup;
use crate::inference::simulation::{
    simulate_with_arrivals, ServingConfig, ServingOutcome,
};
use crate::inference::trace::ArrivalModel;
use crate::inference::LatencyModel;
use crate::metrics::cost::{flat_fl_bytes, hfl_bytes};
use crate::metrics::export::ascii_table;
use crate::util::json::Json;
use crate::util::stats::OnlineStats;

use super::registry::{Experiment, ExperimentCtx, ParamDefault, Report};
use super::scenario::{Scenario, ScenarioConfig};

/// Results for the three setups.
#[derive(Debug)]
pub struct Fig7Result {
    pub flat: ServingOutcome,
    pub location: ServingOutcome,
    pub hflop: ServingOutcome,
}

#[derive(Debug, Clone)]
pub struct Fig7Config {
    pub latency: LatencyModel,
    pub duration_s: f64,
    pub queue_window_s: f64,
    pub seed: u64,
    /// Scale factor on every λ_i (Fig. 8b uses 10×).
    pub lambda_scale: f64,
    /// Arrival generation (default: per-device Poisson, the paper
    /// regime; an open-loop trace evaluates the setups under diurnal /
    /// flash-crowd / hotspot load shapes).
    pub arrivals: ArrivalModel,
}

impl Default for Fig7Config {
    fn default() -> Self {
        Fig7Config {
            latency: LatencyModel::default(),
            duration_s: 120.0,
            queue_window_s: 0.05,
            seed: 7,
            lambda_scale: 1.0,
            arrivals: ArrivalModel::PerDevicePoisson,
        }
    }
}

/// Run the three-setup comparison on a built scenario.
pub fn run(sc: &Scenario, cfg: &Fig7Config) -> Fig7Result {
    let lambdas: Vec<f64> = sc.lambdas().iter().map(|l| l * cfg.lambda_scale).collect();
    let caps = sc.capacities();

    let base = |assign: Vec<Option<usize>>, seed_off: u64| ServingConfig {
        assign,
        lambda: lambdas.clone(),
        capacity: caps.clone(),
        latency: cfg.latency.clone(),
        duration_s: cfg.duration_s,
        queue_window_s: cfg.queue_window_s,
        seed: cfg.seed + seed_off,
    };

    let flat =
        simulate_with_arrivals(&base(vec![None; sc.topo.n_devices()], 0), &cfg.arrivals);
    let location =
        simulate_with_arrivals(&base(sc.assign_location.assign.clone(), 1), &cfg.arrivals);
    let hflop =
        simulate_with_arrivals(&base(sc.assign_hflop.assign.clone(), 2), &cfg.arrivals);

    Fig7Result { flat, location, hflop }
}

/// Standard serving-metric summary keys shared by every experiment the
/// sweep engine can turn into a [`super::sweep::CellOutcome`]. The key
/// names mirror the cell fields exactly; values pass through `f64`
/// untouched, which is what keeps the registry-driven sweep bit-exact
/// with the pre-registry cell runner.
pub fn serving_summary(report: &mut Report, o: &ServingOutcome) {
    report.num("requests", o.total() as f64);
    report.num("served_at_edge", o.served_at_edge as f64);
    report.num("spilled_to_cloud", o.spilled_to_cloud as f64);
    report.num("direct_to_cloud", o.direct_to_cloud as f64);
    report.num("spill_fraction", o.spill_fraction());
    report.num("mean_ms", o.latency.mean());
    report.num("std_ms", o.latency.std());
    report.num("min_ms", o.latency.min());
    report.num("max_ms", o.latency.max());
    report.num("p50_ms", o.percentiles.p50());
    report.num("p90_ms", o.percentiles.p90());
    report.num("p99_ms", o.percentiles.p99());
}

/// Registry port (DESIGN.md §5). Two modes:
///
/// * `setup = "all"` (default) — the paper figure: aggregate the three
///   setups over `reps` random scenario draws;
/// * `setup = flat|location|hflop` — one setup on one fixed scenario,
///   the sweep-cell fast path (`hflop sweep --grid fig7|fig8` drives
///   this with per-cell seeds; kept bit-identical to the pre-registry
///   cell runner by `rust/tests/sweep_golden_matrix.rs`).
pub struct Fig7Experiment;

const SCHEMA: &[ParamSpec] = &[
    ParamSpec {
        key: "setup",
        default: ParamDefault::Str("all"),
        help: "all, or one of flat|location|hflop (single-setup sweep cell)",
    },
    ParamSpec { key: "reps", default: ParamDefault::Int(6), help: "scenario draws (setup=all)" },
    ParamSpec { key: "clients", default: ParamDefault::Int(20), help: "FL clients / devices" },
    ParamSpec { key: "edges", default: ParamDefault::Int(4), help: "candidate edge hosts" },
    ParamSpec { key: "weeks", default: ParamDefault::Int(5), help: "synthetic dataset length" },
    ParamSpec {
        key: "balanced",
        default: ParamDefault::Bool(false),
        help: "balanced client placement (false = uneven clusters, the Fig. 7 regime)",
    },
    ParamSpec {
        key: "scenario_seed",
        default: ParamDefault::Int(42),
        help: "scenario seed (base seed of the draws when setup=all)",
    },
    ParamSpec { key: "data_seed", default: ParamDefault::Int(1234), help: "dataset seed" },
    ParamSpec {
        key: "duration_s",
        default: ParamDefault::Float(120.0),
        help: "simulated serving horizon (s)",
    },
    ParamSpec {
        key: "queue_window_s",
        default: ParamDefault::Float(0.05),
        help: "R3 admission window (s)",
    },
    ParamSpec {
        key: "lambda_scale",
        default: ParamDefault::Float(1.0),
        help: "scale factor on every lambda_i (Fig. 8b uses 10)",
    },
    ParamSpec {
        key: "speedup",
        default: ParamDefault::Float(0.0),
        help: "edge->cloud compute speedup in [0, 0.95]",
    },
    ParamSpec {
        key: "seed",
        default: ParamDefault::Int(7),
        help: "serving-simulation seed (the sweep writes the cell seed here)",
    },
    ParamSpec {
        key: "rounds",
        default: ParamDefault::Int(100),
        help: "nominal aggregation rounds for comm-volume accounting",
    },
    ParamSpec {
        key: "trace",
        default: ParamDefault::Str("none"),
        help: "open-loop arrival trace: none|constant|diurnal|flash-crowd|hotspot",
    },
    ParamSpec {
        key: "trace_peak",
        default: ParamDefault::Float(3.0),
        help: "trace peak rate multiplier (diurnal/flash-crowd/hotspot)",
    },
    ParamSpec {
        key: "trace_period_s",
        default: ParamDefault::Float(0.0),
        help: "diurnal period (s); 0 = one cycle over the horizon",
    },
    ParamSpec {
        key: "trace_chunk_s",
        default: ParamDefault::Float(10.0),
        help: "open-loop generation chunk (s)",
    },
    ParamSpec {
        key: "model_bytes",
        default: ParamDefault::Int(262_144),
        help: "serialized model size for comm-volume accounting",
    },
];

/// Build the arrival model from the shared `trace*` params (fig7, fig8
/// and interference expose the same four keys).
pub(super) fn arrivals_from(
    ctx: &ExperimentCtx,
    duration_s: f64,
) -> anyhow::Result<ArrivalModel> {
    ArrivalModel::from_named(
        &ctx.params.str("trace")?,
        ctx.params.f64("trace_peak")?,
        ctx.params.f64("trace_period_s")?,
        ctx.params.f64("trace_chunk_s")?,
        duration_s,
    )
}

fn scenario_from(ctx: &ExperimentCtx, seed: u64) -> anyhow::Result<Scenario> {
    Scenario::build(ScenarioConfig {
        n_clients: ctx.params.usize("clients")?,
        n_edges: ctx.params.usize("edges")?,
        weeks: ctx.params.usize("weeks")?,
        balanced_clients: ctx.params.bool("balanced")?,
        seed,
        data_seed: ctx.params.u64("data_seed")?,
        ..Default::default()
    })
}

/// The single-setup sweep-cell path. Mirrors the pre-registry
/// `sweep::run_cell_at` static branch statement-for-statement: default
/// latency model + `with_speedup`, fixed scenario, the cell seed driving
/// only the serving simulation, Eq. 1 cost and predicted comm volume per
/// setup.
fn run_single(ctx: &mut ExperimentCtx, setup: Setup) -> anyhow::Result<Report> {
    // No uncapacitated serving variant exists: silently reusing the
    // capacitated assignment would mislabel the artifact.
    anyhow::ensure!(
        setup != Setup::HflopUncapacitated,
        "fig7 has no uncapacitated serving setup (valid: all, flat, location, hflop)"
    );
    let sc = scenario_from(ctx, ctx.params.u64("scenario_seed")?)?;
    let env_lambda = ctx.params.f64("lambda_scale")?;
    let speedup = ctx.params.f64("speedup")?;
    let assign = match setup {
        Setup::Flat => vec![None; sc.topo.n_devices()],
        Setup::LocationClustered => sc.assign_location.assign.clone(),
        Setup::Hflop | Setup::HflopUncapacitated => sc.assign_hflop.assign.clone(),
    };
    let cfg = ServingConfig {
        assign,
        lambda: sc.lambdas().iter().map(|l| l * env_lambda).collect(),
        capacity: sc.capacities(),
        latency: LatencyModel::default().with_speedup(speedup.min(0.95)),
        duration_s: ctx.params.f64("duration_s")?,
        queue_window_s: ctx.params.f64("queue_window_s")?,
        seed: ctx.params.u64("seed")?,
    };
    let arrivals = arrivals_from(ctx, cfg.duration_s)?;
    let out = simulate_with_arrivals(&cfg, &arrivals);

    let rounds = ctx.params.usize("rounds")?;
    let model_bytes = ctx.params.usize("model_bytes")?;
    let (eq1_cost, comm_bytes) = match setup {
        Setup::Flat => (0.0, flat_fl_bytes(sc.topo.n_devices(), rounds, model_bytes)),
        Setup::LocationClustered => (
            sc.assign_location.cost(&sc.inst),
            hfl_bytes(&sc.inst, &sc.assign_location, rounds, model_bytes),
        ),
        Setup::Hflop | Setup::HflopUncapacitated => {
            (sc.hflop_cost, hfl_bytes(&sc.inst, &sc.assign_hflop, rounds, model_bytes))
        }
    };

    let mut report = Report::new("fig7");
    report.text("setup", setup.name());
    serving_summary(&mut report, &out);
    report.num("eq1_cost", eq1_cost);
    report.num("comm_gb", comm_bytes as f64 / 1e9);
    ctx.say(|| {
        format!(
            "fig7 setup={}: {} requests, mean {:.2} ms, p99 {:.1} ms, spill {:.3}",
            setup.name(),
            out.total(),
            out.latency.mean(),
            out.percentiles.p99(),
            out.spill_fraction()
        )
    });
    Ok(report)
}

/// The paper figure: three setups aggregated over several scenario draws.
fn run_all_setups(ctx: &mut ExperimentCtx) -> anyhow::Result<Report> {
    let base_seed = ctx.params.u64("scenario_seed")?;
    let reps = ctx.usize_capped("reps", 2)? as u64;
    let duration_s = ctx.f64_capped("duration_s", 30.0)?;
    let cfg7 = Fig7Config {
        duration_s,
        queue_window_s: ctx.params.f64("queue_window_s")?,
        seed: ctx.params.u64("seed")?,
        lambda_scale: ctx.params.f64("lambda_scale")?,
        latency: LatencyModel::default()
            .with_speedup(ctx.params.f64("speedup")?.min(0.95)),
        arrivals: arrivals_from(ctx, duration_s)?,
    };
    let mut agg = [OnlineStats::new(), OnlineStats::new(), OnlineStats::new()];
    let mut spills = [0.0f64; 3];
    let mut requests = [0u64; 3];
    for s in 0..reps {
        let sc = scenario_from(ctx, base_seed + s)?;
        let r = run(&sc, &cfg7);
        for (k, o) in [&r.flat, &r.location, &r.hflop].iter().enumerate() {
            agg[k].merge(&o.latency);
            spills[k] += o.spill_fraction();
            requests[k] += o.total();
        }
    }
    let names = ["flat", "hier", "hflop"];
    let table: Vec<Vec<String>> = (0..3)
        .map(|k| {
            vec![
                names[k].to_string(),
                format!("{:.2}", agg[k].mean()),
                format!("{:.2}", agg[k].std()),
                format!("{}", requests[k]),
                format!("{:.3}", spills[k] / reps as f64),
            ]
        })
        .collect();
    ctx.say(|| "paper:  flat 79.07±15.94   hier 17.72±24.26   hflop 9.89±4.63 (ms)".to_string());
    ctx.say(|| ascii_table(&["setup", "mean_ms", "std_ms", "requests", "spill"], &table));

    let mut report = Report::new("fig7");
    report.text("setup", "all");
    report.num("reps", reps as f64);
    for (k, prefix) in ["flat", "hier", "hflop"].iter().enumerate() {
        report.num(&format!("{prefix}_mean_ms"), agg[k].mean());
        report.num(&format!("{prefix}_std_ms"), agg[k].std());
        report.put(&format!("{prefix}_requests"), Json::Num(requests[k] as f64));
        report.num(&format!("{prefix}_spill"), spills[k] / reps as f64);
    }
    report.table(
        "fig7",
        &["setup", "mean_ms", "std_ms", "requests", "spill"],
        (0..3)
            .map(|k| {
                vec![
                    k as f64,
                    agg[k].mean(),
                    agg[k].std(),
                    requests[k] as f64,
                    spills[k] / reps as f64,
                ]
            })
            .collect(),
    );
    Ok(report)
}

impl Experiment for Fig7Experiment {
    fn name(&self) -> &'static str {
        "fig7"
    }

    fn describe(&self) -> &'static str {
        "inference response-time distributions, 3 setups (or one setup as a sweep cell)"
    }

    fn param_schema(&self) -> &'static [ParamSpec] {
        SCHEMA
    }

    fn run(&self, ctx: &mut ExperimentCtx) -> anyhow::Result<Report> {
        let setup = ctx.params.str("setup")?;
        if setup == "all" {
            run_all_setups(ctx)
        } else {
            let setup = Setup::parse(&setup)?;
            run_single(ctx, setup)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::{Params, Value};
    use crate::experiments::scenario::ScenarioConfig;

    fn scenario() -> Scenario {
        Scenario::build(ScenarioConfig {
            n_clients: 20,
            n_edges: 4,
            weeks: 5,
            balanced_clients: false, // uneven clusters -> location overload
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn reproduces_fig7_ordering_and_scale() {
        let sc = scenario();
        let r = run(&sc, &Fig7Config::default());
        let (f, l, h) = (r.flat.latency.mean(), r.location.latency.mean(), r.hflop.latency.mean());
        // Ordering: flat >> location-based >= HFLOP (paper: 79 / 18 / 10).
        assert!(f > l, "flat {f} vs location {l}");
        assert!(l >= h - 0.5, "location {l} vs hflop {h}");
        // Scale: flat in the cloud-RTT band, HFLOP near the edge RTT.
        assert!((70.0..90.0).contains(&f), "{f}");
        assert!(h < 20.0, "{h}");
        // HFLOP respects capacities -> essentially no spill.
        assert!(r.hflop.spill_fraction() < 0.05, "{}", r.hflop.spill_fraction());
    }

    #[test]
    fn hflop_latency_std_smallest() {
        // Paper: HFLOP ±4.63 vs hierarchical ±24.26 — capacity awareness
        // kills the bimodality.
        let sc = scenario();
        let r = run(&sc, &Fig7Config::default());
        assert!(r.hflop.latency.std() <= r.location.latency.std() + 1.0);
    }

    #[test]
    fn lambda_scale_increases_spill() {
        let sc = scenario();
        let base = run(&sc, &Fig7Config::default());
        let heavy = run(&sc, &Fig7Config { lambda_scale: 10.0, ..Default::default() });
        assert!(heavy.hflop.spill_fraction() >= base.hflop.spill_fraction());
        assert!(heavy.location.latency.mean() > base.location.latency.mean());
    }

    fn quick_params(setup: &str) -> Params {
        let mut p = Params::defaults(Fig7Experiment.param_schema());
        p.set("setup", Value::Str(setup.into())).unwrap();
        p.set("clients", Value::Int(12)).unwrap();
        p.set("edges", Value::Int(3)).unwrap();
        p.set("duration_s", Value::Float(15.0)).unwrap();
        p
    }

    #[test]
    fn single_setup_cell_reports_standard_metrics() {
        let mut ctx = ExperimentCtx::cell(quick_params("hflop"));
        let report = Fig7Experiment.run(&mut ctx).unwrap();
        assert!(report.get_f64("requests").unwrap() > 100.0);
        assert!(report.get_f64("mean_ms").unwrap() > 0.0);
        assert!(report.get_f64("comm_gb").unwrap() > 0.0);
        assert!(report.get_f64("eq1_cost").unwrap() > 0.0);
        // Static cells never train.
        assert!(report.get_f64("rounds_completed").is_none());
    }

    #[test]
    fn single_setup_flat_serves_all_at_cloud() {
        let mut ctx = ExperimentCtx::cell(quick_params("flat"));
        let report = Fig7Experiment.run(&mut ctx).unwrap();
        assert_eq!(report.get_f64("served_at_edge").unwrap(), 0.0);
        assert!(report.get_f64("direct_to_cloud").unwrap() > 0.0);
        assert_eq!(report.get_f64("eq1_cost").unwrap(), 0.0);
    }

    #[test]
    fn setup_all_aggregates_three_setups() {
        let mut p = quick_params("all");
        p.set("reps", Value::Int(2)).unwrap();
        p.set("duration_s", Value::Float(10.0)).unwrap();
        let mut ctx = ExperimentCtx::cell(p);
        let report = Fig7Experiment.run(&mut ctx).unwrap();
        for key in ["flat_mean_ms", "hier_mean_ms", "hflop_mean_ms"] {
            assert!(report.get_f64(key).unwrap() > 0.0, "{key}");
        }
        assert!(
            report.get_f64("flat_mean_ms").unwrap() > report.get_f64("hflop_mean_ms").unwrap()
        );
    }

    #[test]
    fn flash_crowd_trace_preserves_setup_ordering() {
        // The Fig. 7 ordering (flat >> hflop) must survive an open-loop
        // flash-crowd load shape — the trace changes volume, not the
        // routing economics.
        let sc = scenario();
        let cfg = Fig7Config {
            arrivals: ArrivalModel::from_named("flash-crowd", 4.0, 0.0, 10.0, 120.0).unwrap(),
            ..Fig7Config::default()
        };
        let flat = run(&sc, &Fig7Config::default());
        let r = run(&sc, &cfg);
        assert!(r.flat.latency.mean() > r.hflop.latency.mean());
        // Flash crowd adds volume over the Poisson baseline.
        assert!(r.flat.total() > flat.flat.total());
    }

    #[test]
    fn single_setup_cell_accepts_trace_param() {
        let mut p = quick_params("hflop");
        p.set("trace", Value::Str("diurnal".into())).unwrap();
        let report = Fig7Experiment.run(&mut ExperimentCtx::cell(p)).unwrap();
        assert!(report.get_f64("requests").unwrap() > 50.0);
    }

    #[test]
    fn bad_setup_name_errors_with_spellings() {
        let mut p = Params::defaults(Fig7Experiment.param_schema());
        p.set("setup", Value::Str("hflopp".into())).unwrap();
        let err = Fig7Experiment.run(&mut ExperimentCtx::cell(p)).unwrap_err().to_string();
        assert!(err.contains("valid:"), "{err}");
    }
}
