//! Fig. 7 — inference response-time distributions while clients train,
//! for the three setups. Paper reference means (ms):
//! flat 79.07 ± 15.94, hierarchical 17.72 ± 24.26, HFLOP 9.89 ± 4.63.
//!
//! The mechanism (per §V-C1): all clients are busy training, so every
//! request is offloaded (R1). Flat FL pays the cloud RTT; the
//! hierarchical baselines pay the edge RTT unless the edge is over
//! capacity and proxies the request to the cloud (R3). HFLOP's
//! capacity-aware assignment keeps edges under their limits, so its
//! latency concentrates at the edge RTT.

use super::scenario::Scenario;
use crate::inference::simulation::{simulate, ServingConfig, ServingOutcome};
use crate::inference::LatencyModel;

/// Results for the three setups.
#[derive(Debug)]
pub struct Fig7Result {
    pub flat: ServingOutcome,
    pub location: ServingOutcome,
    pub hflop: ServingOutcome,
}

#[derive(Debug, Clone)]
pub struct Fig7Config {
    pub latency: LatencyModel,
    pub duration_s: f64,
    pub queue_window_s: f64,
    pub seed: u64,
    /// Scale factor on every λ_i (Fig. 8b uses 10×).
    pub lambda_scale: f64,
}

impl Default for Fig7Config {
    fn default() -> Self {
        Fig7Config {
            latency: LatencyModel::default(),
            duration_s: 120.0,
            queue_window_s: 0.05,
            seed: 7,
            lambda_scale: 1.0,
        }
    }
}

/// Run the three-setup comparison on a built scenario.
pub fn run(sc: &Scenario, cfg: &Fig7Config) -> Fig7Result {
    let lambdas: Vec<f64> = sc.lambdas().iter().map(|l| l * cfg.lambda_scale).collect();
    let caps = sc.capacities();

    let base = |assign: Vec<Option<usize>>, seed_off: u64| ServingConfig {
        assign,
        lambda: lambdas.clone(),
        capacity: caps.clone(),
        latency: cfg.latency.clone(),
        duration_s: cfg.duration_s,
        queue_window_s: cfg.queue_window_s,
        seed: cfg.seed + seed_off,
    };

    let flat = simulate(&base(vec![None; sc.topo.n_devices()], 0));
    let location = simulate(&base(sc.assign_location.assign.clone(), 1));
    let hflop = simulate(&base(sc.assign_hflop.assign.clone(), 2));

    Fig7Result { flat, location, hflop }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::scenario::ScenarioConfig;

    fn scenario() -> Scenario {
        Scenario::build(ScenarioConfig {
            n_clients: 20,
            n_edges: 4,
            weeks: 5,
            balanced_clients: false, // uneven clusters -> location overload
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn reproduces_fig7_ordering_and_scale() {
        let sc = scenario();
        let r = run(&sc, &Fig7Config::default());
        let (f, l, h) = (r.flat.latency.mean(), r.location.latency.mean(), r.hflop.latency.mean());
        // Ordering: flat >> location-based >= HFLOP (paper: 79 / 18 / 10).
        assert!(f > l, "flat {f} vs location {l}");
        assert!(l >= h - 0.5, "location {l} vs hflop {h}");
        // Scale: flat in the cloud-RTT band, HFLOP near the edge RTT.
        assert!((70.0..90.0).contains(&f), "{f}");
        assert!(h < 20.0, "{h}");
        // HFLOP respects capacities -> essentially no spill.
        assert!(r.hflop.spill_fraction() < 0.05, "{}", r.hflop.spill_fraction());
    }

    #[test]
    fn hflop_latency_std_smallest() {
        // Paper: HFLOP ±4.63 vs hierarchical ±24.26 — capacity awareness
        // kills the bimodality.
        let sc = scenario();
        let r = run(&sc, &Fig7Config::default());
        assert!(r.hflop.latency.std() <= r.location.latency.std() + 1.0);
    }

    #[test]
    fn lambda_scale_increases_spill() {
        let sc = scenario();
        let base = run(&sc, &Fig7Config::default());
        let heavy = run(&sc, &Fig7Config { lambda_scale: 10.0, ..Default::default() });
        assert!(heavy.hflop.spill_fraction() >= base.hflop.spill_fraction());
        assert!(heavy.location.latency.mean() > base.location.latency.mean());
    }
}
