//! Time-varying arrival-rate traces for the open-loop serving plane.
//!
//! The paper's serving load is a scalar λ per device; reactive-
//! orchestration scenarios need λ(t) — diurnal cycles, flash crowds,
//! regional hotspots. [`RateTrace`] is the first-class representation: a
//! **piecewise-constant** multiplier curve over the base per-device
//! rates, optionally carrying a *regional hotspot* (an extra boost on a
//! prefix fraction of the device population). Piecewise-constant is a
//! deliberate restriction: within a segment the aggregate rate is flat,
//! so Lewis–Shedler thinning against the per-chunk maximum is **exact**
//! (no rate is ever above the majorant) and arrival generation stays a
//! tight rejection loop (see `cosim::TraceSource`).
//!
//! Surge faults compose as overlays rather than multiplier pokes:
//! [`RateTrace::overlay`] is the pointwise product of two traces, so a
//! preset's "3× between 0.3·d and 0.6·d" surge becomes
//! `base.overlay(&RateTrace::surge(3.0, 0.3 * d, 0.6 * d))`.

/// One constant-rate span: the trace multiplies every device's base λ by
/// `mult` for `t < t_end` (until the previous segment's end), with an
/// optional hotspot boosting the first `hot_frac` of devices by
/// `hot_boost` on top.
#[derive(Debug, Clone, PartialEq)]
pub struct RateSegment {
    /// Exclusive end time of this segment; the final segment's is
    /// `f64::INFINITY`.
    pub t_end: f64,
    /// Global arrival-rate multiplier over the base per-device rates.
    pub mult: f64,
    /// Fraction of the device population (by index prefix — devices are
    /// registered in region order) inside the hotspot; 0.0 = no hotspot.
    pub hot_frac: f64,
    /// Extra rate multiplier for hotspot devices (1.0 = no boost).
    pub hot_boost: f64,
}

impl RateSegment {
    fn flat(t_end: f64, mult: f64) -> RateSegment {
        RateSegment { t_end, mult, hot_frac: 0.0, hot_boost: 1.0 }
    }

    /// Whether this segment carries a real hotspot.
    pub fn has_hotspot(&self) -> bool {
        self.hot_frac > 0.0 && self.hot_boost != 1.0
    }
}

/// Piecewise-constant λ(t) multiplier curve (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct RateTrace {
    segments: Vec<RateSegment>,
}

impl RateTrace {
    /// Build from raw segments. Ends must be strictly increasing; the
    /// trace is extended to `t = ∞` by its last multiplier if needed.
    pub fn from_segments(mut segments: Vec<RateSegment>) -> RateTrace {
        assert!(!segments.is_empty(), "a rate trace needs at least one segment");
        for s in &segments {
            assert!(s.mult.is_finite() && s.mult >= 0.0, "segment mult must be finite and >= 0");
            assert!((0.0..=1.0).contains(&s.hot_frac), "hot_frac must be in [0, 1]");
            assert!(s.hot_boost.is_finite() && s.hot_boost > 0.0, "hot_boost must be positive");
        }
        for w in segments.windows(2) {
            assert!(w[0].t_end < w[1].t_end, "segment ends must be strictly increasing");
        }
        let last = segments.last().unwrap();
        if last.t_end.is_finite() {
            let tail = RateSegment { t_end: f64::INFINITY, ..last.clone() };
            segments.push(tail);
        }
        RateTrace { segments }
    }

    /// Constant multiplier for all time.
    pub fn constant(mult: f64) -> RateTrace {
        RateTrace::from_segments(vec![RateSegment::flat(f64::INFINITY, mult)])
    }

    /// A surge window: 1.0 outside `[t0, t1)`, `factor` inside — the
    /// overlay form of a `SurgeStart`/`SurgeEnd` fault pair.
    pub fn surge(factor: f64, t0: f64, t1: f64) -> RateTrace {
        assert!(t0 < t1, "surge window must be non-empty");
        let mut segs = Vec::new();
        if t0 > 0.0 {
            segs.push(RateSegment::flat(t0, 1.0));
        }
        segs.push(RateSegment::flat(t1, factor));
        segs.push(RateSegment::flat(f64::INFINITY, 1.0));
        RateTrace::from_segments(segs)
    }

    /// Diurnal curve: a raised-cosine oscillation between `trough` and
    /// `peak` with the given period, discretized into `steps` constant
    /// segments per period (each takes the curve's midpoint value), laid
    /// out to cover `horizon_s` and settling at `trough` afterwards.
    /// `t = 0` is the trough (night); the peak lands at `period_s / 2`.
    pub fn diurnal(
        trough: f64,
        peak: f64,
        period_s: f64,
        steps: usize,
        horizon_s: f64,
    ) -> RateTrace {
        assert!(period_s > 0.0 && steps > 0, "diurnal needs a positive period and step count");
        assert!(trough >= 0.0 && peak >= trough, "diurnal needs 0 <= trough <= peak");
        let n_periods = (horizon_s / period_s).ceil().max(1.0) as usize;
        let dt = period_s / steps as f64;
        let mut segs = Vec::with_capacity(n_periods * steps + 1);
        for p in 0..n_periods {
            for s in 0..steps {
                let t_mid = (p * steps + s) as f64 * dt + 0.5 * dt;
                let phase = std::f64::consts::TAU * (t_mid / period_s);
                let mult = trough + (peak - trough) * 0.5 * (1.0 - phase.cos());
                segs.push(RateSegment::flat((p * steps + s + 1) as f64 * dt, mult));
            }
        }
        segs.push(RateSegment::flat(f64::INFINITY, trough));
        RateTrace::from_segments(segs)
    }

    /// Flash crowd: `base` until `at_s`, a linear ramp (8 constant steps)
    /// up to `peak` over `ramp_s`, a `hold_s` plateau, a symmetric ramp
    /// down, then `base` forever.
    pub fn flash_crowd(base: f64, peak: f64, at_s: f64, ramp_s: f64, hold_s: f64) -> RateTrace {
        assert!(at_s >= 0.0 && ramp_s >= 0.0 && hold_s > 0.0, "flash crowd needs a hold window");
        assert!(base >= 0.0 && peak >= base, "flash crowd needs 0 <= base <= peak");
        const RAMP_STEPS: usize = 8;
        let mut segs = Vec::new();
        if at_s > 0.0 {
            segs.push(RateSegment::flat(at_s, base));
        }
        let step = ramp_s / RAMP_STEPS as f64;
        if ramp_s > 0.0 {
            for i in 0..RAMP_STEPS {
                let frac = (i as f64 + 0.5) / RAMP_STEPS as f64;
                segs.push(RateSegment::flat(
                    at_s + (i + 1) as f64 * step,
                    base + (peak - base) * frac,
                ));
            }
        }
        let plateau_end = at_s + ramp_s + hold_s;
        segs.push(RateSegment::flat(plateau_end, peak));
        if ramp_s > 0.0 {
            for i in 0..RAMP_STEPS {
                let frac = 1.0 - (i as f64 + 0.5) / RAMP_STEPS as f64;
                segs.push(RateSegment::flat(
                    plateau_end + (i + 1) as f64 * step,
                    base + (peak - base) * frac,
                ));
            }
        }
        segs.push(RateSegment::flat(f64::INFINITY, base));
        RateTrace::from_segments(segs)
    }

    /// Regional hotspot: global rate stays at `base`, but during
    /// `[at_s, at_s + hold_s)` the first `frac` of the device population
    /// runs at `boost ×` its share (localized demand spike; the
    /// orchestrator should re-place only the hot region's clusters).
    pub fn regional_hotspot(base: f64, boost: f64, frac: f64, at_s: f64, hold_s: f64) -> RateTrace {
        assert!(hold_s > 0.0, "hotspot needs a hold window");
        let mut segs = Vec::new();
        if at_s > 0.0 {
            segs.push(RateSegment::flat(at_s, base));
        }
        segs.push(RateSegment {
            t_end: at_s + hold_s,
            mult: base,
            hot_frac: frac,
            hot_boost: boost,
        });
        segs.push(RateSegment::flat(f64::INFINITY, base));
        RateTrace::from_segments(segs)
    }

    /// Pointwise product of two traces over the merged boundary set —
    /// how surge faults compose onto a base trace. If both sides carry a
    /// hotspot in an overlapping span, the one with the larger boost
    /// wins (hotspots do not stack).
    pub fn overlay(&self, other: &RateTrace) -> RateTrace {
        let mut segs = Vec::new();
        let (mut i, mut j) = (0usize, 0usize);
        loop {
            let (a, b) = (&self.segments[i], &other.segments[j]);
            let t_end = a.t_end.min(b.t_end);
            let (hot_frac, hot_boost) = if a.has_hotspot() && b.has_hotspot() {
                if a.hot_boost >= b.hot_boost {
                    (a.hot_frac, a.hot_boost)
                } else {
                    (b.hot_frac, b.hot_boost)
                }
            } else if a.has_hotspot() {
                (a.hot_frac, a.hot_boost)
            } else {
                (b.hot_frac, b.hot_boost)
            };
            segs.push(RateSegment { t_end, mult: a.mult * b.mult, hot_frac, hot_boost });
            if t_end == f64::INFINITY {
                break;
            }
            if a.t_end == t_end {
                i += 1;
            }
            if b.t_end == t_end {
                j += 1;
            }
        }
        RateTrace::from_segments(segs)
    }

    /// Scale every segment's multiplier by `factor`.
    pub fn scaled(&self, factor: f64) -> RateTrace {
        let segs = self
            .segments
            .iter()
            .map(|s| RateSegment { mult: s.mult * factor, ..s.clone() })
            .collect();
        RateTrace::from_segments(segs)
    }

    pub fn segments(&self) -> &[RateSegment] {
        &self.segments
    }

    /// Index of the segment containing `t` (the first with `t < t_end`).
    pub fn index_at(&self, t: f64) -> usize {
        self.segments.partition_point(|s| s.t_end <= t).min(self.segments.len() - 1)
    }

    /// Global multiplier at `t` (hotspot boost not included).
    pub fn mult_at(&self, t: f64) -> f64 {
        self.segments[self.index_at(t)].mult
    }
}

/// How the serving plane's arrivals are generated.
#[derive(Debug, Clone, Default)]
pub enum ArrivalModel {
    /// One Poisson inter-arrival timer per device — the historical
    /// closed-loop default, bit-identical to the pre-trace simulator.
    #[default]
    PerDevicePoisson,
    /// Open-loop arrivals from a [`RateTrace`], generated a `chunk_s`
    /// window at a time by thinning: one pending kernel timer total
    /// instead of one per device.
    Trace { trace: RateTrace, chunk_s: f64 },
}

impl ArrivalModel {
    /// Build from registry parameters (`trace` ∈ `none | constant |
    /// diurnal | flash-crowd | hotspot`). The preset shapes are scaled to
    /// the run horizon: diurnal runs `trace_period_s` cycles (0 = one
    /// cycle per horizon), flash crowd spikes to `trace_peak` around
    /// 0.4·duration, hotspot boosts a quarter of the population by
    /// `trace_peak` for the middle third.
    pub fn from_named(
        name: &str,
        peak: f64,
        period_s: f64,
        chunk_s: f64,
        duration_s: f64,
    ) -> anyhow::Result<ArrivalModel> {
        anyhow::ensure!(chunk_s > 0.0, "trace_chunk_s must be positive");
        let trace = match name {
            "none" => return Ok(ArrivalModel::PerDevicePoisson),
            "constant" => RateTrace::constant(1.0),
            "diurnal" => {
                let period = if period_s > 0.0 { period_s } else { duration_s };
                RateTrace::diurnal(1.0, peak, period, 16, duration_s)
            }
            "flash-crowd" => RateTrace::flash_crowd(
                1.0,
                peak,
                0.4 * duration_s,
                0.05 * duration_s,
                0.2 * duration_s,
            ),
            "hotspot" => {
                RateTrace::regional_hotspot(1.0, peak, 0.25, 0.4 * duration_s, 0.3 * duration_s)
            }
            other => anyhow::bail!(
                "unknown trace '{other}' (valid: none, constant, diurnal, flash-crowd, hotspot)"
            ),
        };
        Ok(ArrivalModel::Trace { trace, chunk_s })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_trace_is_flat() {
        let tr = RateTrace::constant(2.5);
        assert_eq!(tr.mult_at(0.0), 2.5);
        assert_eq!(tr.mult_at(1e12), 2.5);
        assert_eq!(tr.segments().len(), 1);
    }

    #[test]
    fn finite_traces_are_extended_to_infinity() {
        let tr = RateTrace::from_segments(vec![RateSegment::flat(10.0, 3.0)]);
        assert_eq!(tr.segments().last().unwrap().t_end, f64::INFINITY);
        assert_eq!(tr.mult_at(1e9), 3.0);
    }

    #[test]
    fn surge_overlay_multiplies_inside_the_window_only() {
        let base = RateTrace::constant(2.0);
        let combined = base.overlay(&RateTrace::surge(3.0, 10.0, 20.0));
        assert_eq!(combined.mult_at(5.0), 2.0);
        assert_eq!(combined.mult_at(15.0), 6.0);
        assert_eq!(combined.mult_at(25.0), 2.0);
    }

    #[test]
    fn diurnal_stays_within_bounds_and_peaks_mid_period() {
        let tr = RateTrace::diurnal(1.0, 4.0, 100.0, 20, 100.0);
        for s in tr.segments() {
            assert!(s.mult >= 1.0 - 1e-12 && s.mult <= 4.0 + 1e-12, "mult {}", s.mult);
        }
        assert!(tr.mult_at(50.0) > 3.8, "peak at half period: {}", tr.mult_at(50.0));
        assert!(tr.mult_at(2.0) < 1.2, "trough near zero: {}", tr.mult_at(2.0));
    }

    #[test]
    fn flash_crowd_ramps_and_recovers() {
        let tr = RateTrace::flash_crowd(1.0, 5.0, 40.0, 10.0, 20.0);
        assert_eq!(tr.mult_at(10.0), 1.0);
        assert!(tr.mult_at(45.0) > 1.0 && tr.mult_at(45.0) < 5.0, "mid-ramp");
        assert_eq!(tr.mult_at(60.0), 5.0);
        assert_eq!(tr.mult_at(200.0), 1.0);
    }

    #[test]
    fn hotspot_keeps_global_mult_flat() {
        let tr = RateTrace::regional_hotspot(1.0, 4.0, 0.25, 30.0, 30.0);
        assert_eq!(tr.mult_at(40.0), 1.0);
        let seg = &tr.segments()[tr.index_at(40.0)];
        assert!(seg.has_hotspot());
        assert_eq!(seg.hot_frac, 0.25);
        assert_eq!(seg.hot_boost, 4.0);
        assert!(!tr.segments()[tr.index_at(10.0)].has_hotspot());
    }

    #[test]
    fn index_at_picks_the_containing_segment() {
        let tr = RateTrace::from_segments(vec![
            RateSegment::flat(1.0, 1.0),
            RateSegment::flat(2.0, 2.0),
            RateSegment::flat(f64::INFINITY, 3.0),
        ]);
        assert_eq!(tr.index_at(0.0), 0);
        assert_eq!(tr.index_at(1.0), 1); // t_end is exclusive
        assert_eq!(tr.index_at(1.999), 1);
        assert_eq!(tr.index_at(2.0), 2);
    }

    #[test]
    fn from_named_parses_the_registry_surface() {
        assert!(matches!(
            ArrivalModel::from_named("none", 3.0, 0.0, 10.0, 240.0).unwrap(),
            ArrivalModel::PerDevicePoisson
        ));
        for name in ["constant", "diurnal", "flash-crowd", "hotspot"] {
            assert!(matches!(
                ArrivalModel::from_named(name, 3.0, 0.0, 10.0, 240.0).unwrap(),
                ArrivalModel::Trace { .. }
            ));
        }
        assert!(ArrivalModel::from_named("tsunami", 3.0, 0.0, 10.0, 240.0).is_err());
        assert!(ArrivalModel::from_named("constant", 3.0, 0.0, 0.0, 240.0).is_err());
    }
}
