//! Event-driven co-simulation of the three HFL planes on one clock.
//!
//! The paper's core claim is that training and serving *couple* on
//! shared infrastructure ("training and inference workloads can
//! interfere with detrimental effects on performance"). This module
//! makes that coupling executable: the serving plane, the training
//! plane, and the orchestrator's control loop are [`Component`]s of one
//! [`Kernel`] timeline.
//!
//! * [`ServingPlane`] — the Fig. 7/8 request simulation (R1/R3 routing),
//!   except each edge's *effective* service rate is shared state: while
//!   the edge runs a training round it serves at
//!   `capacity × interference_factor`.
//! * [`TrainingPlane`] — HFL rounds occupy timeline intervals computed
//!   by [`RoundTimeModel`] (straggler compute + model transfers); rounds
//!   run on a periodic cadence (the continual regime) or on retrain
//!   triggers from the control plane.
//! * [`ControlPlane`] — the orchestrator in the loop: a [`Gpo`] mirrors
//!   edge state from kernel events (training load, failures, surges),
//!   the [`LearningController`] re-solves HFLOP when the live plan goes
//!   stale, and the [`InferenceController`] fires retrain bursts when
//!   the served model drifts. Plan swaps install mid-run; a failed
//!   edge's stale service timers are cancelled via the kernel's
//!   generation tags.
//!
//! With training idle and no control plane attached, the serving plane's
//! event and RNG streams are *identical* to the pre-kernel simulator —
//! `inference::simulation::simulate` is that static fast path, and a
//! regression test holds it bit-for-bit.

use super::latency::LatencyModel;
use super::simulation::{admission_bound, ServingConfig, ServingOutcome};
use super::trace::{ArrivalModel, RateTrace};
use crate::fl::timing::RoundTimeModel;
use crate::orchestrator::budget::plan_delta;
use crate::orchestrator::{Gpo, InferenceController, LearningController};
use crate::sim::{Component, Kernel};
use crate::util::rng::Rng;
use crate::util::stats::OnlineStats;

/// How a completed request was served.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Class {
    /// Admitted and served at the assigned edge aggregator.
    Edge,
    /// Proxied to the cloud by an over-capacity or failing edge (R3).
    Spill,
    /// Sent straight to the cloud (no aggregator / edge down).
    Direct,
}

/// Environmental fault injections (scheduled via [`CoSimConfig::faults`]).
#[derive(Debug, Clone, Copy)]
pub enum FaultEvent {
    EdgeFail(usize),
    EdgeRecover(usize),
    /// Scale every device's arrival rate by `factor` until `SurgeEnd`.
    SurgeStart { factor: f64 },
    SurgeEnd,
}

/// Every event on the co-simulation timeline.
#[derive(Debug, Clone)]
pub enum CoEvent {
    // --- serving plane ---------------------------------------------------
    Arrival { device: usize },
    /// Next open-loop arrival from the rate-trace source; the handler
    /// routes it and schedules the following one from the generated
    /// buffer (one pending timer total, not one per device).
    TraceArrival { device: usize },
    EdgeDone { edge: usize },
    Complete { t_start: f64, class: Class },
    /// Drain a failed edge's queue, proxying the backlog to the cloud.
    FlushEdge { edge: usize },

    // --- training plane --------------------------------------------------
    RoundBegin { round: usize },
    EdgeTrainEnd { edge: usize, round: usize },
    RoundEnd { round: usize },
    /// The control plane asked for a retrain burst.
    TrainTask,

    // --- control plane ---------------------------------------------------
    MonitorTick,
    /// Training state on `edge` changed; refresh the GPO's capacity view.
    CapacityReport { edge: usize },
    Fault(FaultEvent),
    /// A triggered retrain burst finished; the served model is fresh.
    TrainDone,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Plane {
    Serving,
    Training,
    Control,
}

impl CoEvent {
    fn plane(&self) -> Plane {
        match self {
            CoEvent::Arrival { .. }
            | CoEvent::TraceArrival { .. }
            | CoEvent::EdgeDone { .. }
            | CoEvent::Complete { .. }
            | CoEvent::FlushEdge { .. } => Plane::Serving,
            CoEvent::RoundBegin { .. }
            | CoEvent::EdgeTrainEnd { .. }
            | CoEvent::RoundEnd { .. }
            | CoEvent::TrainTask => Plane::Training,
            CoEvent::MonitorTick
            | CoEvent::CapacityReport { .. }
            | CoEvent::Fault(_)
            | CoEvent::TrainDone => Plane::Control,
        }
    }
}

/// Kernel tag for one edge's service timers: invalidating it on failure
/// cancels the edge's stale `EdgeDone` events without touching the rest
/// of the queue.
fn edge_tag(edge: usize) -> u64 {
    edge as u64
}

/// Per-edge state every plane can see.
#[derive(Debug, Clone)]
pub struct EdgeShared {
    pub up: bool,
    /// True while the edge runs a training round (degraded serving).
    pub training: bool,
}

/// State shared by the planes on the same timeline.
#[derive(Debug)]
pub struct SharedWorld {
    /// Live device → edge plan (None = direct to cloud). Swapped in
    /// place by the control plane on re-solves.
    pub assign: Vec<Option<usize>>,
    pub edges: Vec<EdgeShared>,
    /// Base per-edge serving capacity r_j (req/s).
    pub capacity: Vec<f64>,
    /// Serving-capacity multiplier while an edge trains.
    pub interference_factor: f64,
    /// Current arrival-rate multiplier (load surges).
    pub surge: f64,
    /// Installed plan swaps so far.
    pub plan_swaps: usize,
}

impl SharedWorld {
    /// Effective service rate of edge `j`: degraded while the edge is
    /// mid-training-round — the paper's coupling, made executable. The
    /// single source of truth for both the serving plane's queueing and
    /// the control plane's GPO capacity reports.
    pub fn effective_rate(&self, j: usize) -> f64 {
        let base = self.capacity[j];
        if self.edges[j].training {
            base * self.interference_factor
        } else {
            base
        }
    }
}

/// Mean-latency time series bucketed by completion time — how the
/// interference experiments show degradation and recovery windows.
#[derive(Debug, Clone)]
pub struct TimeBuckets {
    width_s: f64,
    buckets: Vec<OnlineStats>,
}

impl TimeBuckets {
    pub fn new(width_s: f64) -> TimeBuckets {
        assert!(width_s > 0.0, "bucket width must be positive");
        TimeBuckets { width_s, buckets: Vec::new() }
    }

    pub fn push(&mut self, t: f64, x: f64) {
        let idx = (t / self.width_s).floor().max(0.0) as usize;
        while self.buckets.len() <= idx {
            self.buckets.push(OnlineStats::new());
        }
        self.buckets[idx].push(x);
    }

    pub fn width_s(&self) -> f64 {
        self.width_s
    }

    pub fn buckets(&self) -> &[OnlineStats] {
        &self.buckets
    }

    /// Mean over all samples completing in buckets overlapping
    /// `[t0, t1)` (0.0 when empty).
    pub fn mean_between(&self, t0: f64, t1: f64) -> f64 {
        let lo = (t0 / self.width_s).floor().max(0.0) as usize;
        let hi = (((t1 / self.width_s).ceil().max(0.0)) as usize).min(self.buckets.len());
        let mut acc = OnlineStats::new();
        for b in self.buckets.iter().take(hi).skip(lo) {
            acc.merge(b);
        }
        acc.mean()
    }
}

// ---------------------------------------------------------------------------
// Open-loop arrival generation (rate traces)
// ---------------------------------------------------------------------------

/// Seed salt for the trace-arrival RNG stream — a separate stream from
/// the serving plane's routing/service draws (same pattern as the
/// reservoir's `RESERVOIR_SEED_SALT`), so attaching a trace never
/// perturbs service-time sequences.
const TRACE_SEED_SALT: u64 = 0x7261_7465_7472_6163; // "ratetrac"

/// Batched open-loop arrival generator: Lewis–Shedler thinning of a
/// [`RateTrace`] against each chunk's maximum aggregate rate. Because
/// trace segments are piecewise-constant, the chunk maximum is a true
/// majorant and thinning is *exact*, not approximate. Arrivals are
/// buffered one `chunk_s` window at a time, so the kernel carries a
/// single pending arrival timer instead of one per device.
struct TraceSource {
    trace: RateTrace,
    chunk_s: f64,
    horizon: f64,
    rng: Rng,
    lambda: Vec<f64>,
    /// Prefix sums of the base per-device rates (device attribution).
    cum_base: Vec<f64>,
    total_base: f64,
    /// Aggregate multiplier per trace segment: `mult` times the hotspot
    /// share uplift, precomputed so the thinning loop is arithmetic only.
    agg: Vec<f64>,
    /// Boosted prefix sums cached for the current hotspot parameters.
    cum_hot: Vec<f64>,
    hot_key: (f64, f64),
    buf: std::collections::VecDeque<(f64, usize)>,
    /// Generation frontier: arrivals in `[0, gen_t)` are already drawn.
    gen_t: f64,
}

/// Hotspot population size for `frac` of `n` devices (index prefix).
fn hot_count(n: usize, frac: f64) -> usize {
    ((n as f64 * frac).ceil().max(0.0) as usize).min(n)
}

impl TraceSource {
    fn new(
        trace: RateTrace,
        chunk_s: f64,
        lambda: Vec<f64>,
        seed: u64,
        horizon: f64,
    ) -> TraceSource {
        assert!(chunk_s > 0.0, "trace chunk must be positive");
        let mut cum_base = Vec::with_capacity(lambda.len());
        let mut acc = 0.0;
        for &l in &lambda {
            acc += l.max(0.0);
            cum_base.push(acc);
        }
        let total_base = acc;
        let agg = trace
            .segments()
            .iter()
            .map(|s| {
                let (share, boost) = if s.has_hotspot() && total_base > 0.0 {
                    let n_hot = hot_count(lambda.len(), s.hot_frac);
                    let share =
                        if n_hot == 0 { 0.0 } else { cum_base[n_hot - 1] / total_base };
                    (share, s.hot_boost)
                } else {
                    (0.0, 1.0)
                };
                s.mult * (1.0 + share * (boost - 1.0))
            })
            .collect();
        TraceSource {
            trace,
            chunk_s,
            horizon,
            rng: Rng::new(seed ^ TRACE_SEED_SALT),
            lambda,
            cum_base,
            total_base,
            agg,
            cum_hot: Vec::new(),
            hot_key: (0.0, 1.0),
            buf: std::collections::VecDeque::new(),
            gen_t: 0.0,
        }
    }

    /// Generate chunks until the buffer is non-empty or the horizon is
    /// reached.
    fn refill(&mut self) {
        while self.buf.is_empty() && self.gen_t < self.horizon {
            let end = (self.gen_t + self.chunk_s).min(self.horizon);
            let first = self.trace.index_at(self.gen_t);
            let last = self.trace.index_at(end);
            let peak = self.agg[first..=last].iter().fold(0.0f64, |a, &b| a.max(b));
            if peak > 0.0 && self.total_base > 0.0 {
                let lam_max = self.total_base * peak;
                let mut t = self.gen_t;
                loop {
                    t += self.rng.exponential(lam_max);
                    if t >= end {
                        break;
                    }
                    let idx = self.trace.index_at(t);
                    let a = self.agg[idx];
                    if a > 0.0 && self.rng.f64() * peak < a {
                        let d = self.pick_device(idx);
                        self.buf.push_back((t, d));
                    }
                }
            }
            self.gen_t = end;
        }
    }

    fn next_arrival(&mut self) -> Option<(f64, usize)> {
        self.refill();
        self.buf.pop_front()
    }

    /// Attribute an accepted arrival to a device: λ-proportional in the
    /// base regime, with hotspot devices up-weighted by the boost.
    fn pick_device(&mut self, seg_idx: usize) -> usize {
        let seg = &self.trace.segments()[seg_idx];
        let (hot_frac, hot_boost, hotspot) = (seg.hot_frac, seg.hot_boost, seg.has_hotspot());
        let u01 = self.rng.f64();
        if hotspot {
            self.ensure_hot_cache(hot_frac, hot_boost);
            let total = *self.cum_hot.last().expect("non-empty device set");
            let u = u01 * total;
            self.cum_hot.partition_point(|&c| c <= u).min(self.lambda.len() - 1)
        } else {
            let u = u01 * self.total_base;
            self.cum_base.partition_point(|&c| c <= u).min(self.lambda.len() - 1)
        }
    }

    fn ensure_hot_cache(&mut self, frac: f64, boost: f64) {
        if self.hot_key == (frac, boost) && !self.cum_hot.is_empty() {
            return;
        }
        let n_hot = hot_count(self.lambda.len(), frac);
        self.cum_hot.clear();
        let mut acc = 0.0;
        for (i, &l) in self.lambda.iter().enumerate() {
            acc += if i < n_hot { l.max(0.0) * boost } else { l.max(0.0) };
            self.cum_hot.push(acc);
        }
        self.hot_key = (frac, boost);
    }

    /// λ-change announcements for the control plane: `(t, aggregate
    /// factor)` at every point before the horizon where the trace's
    /// aggregate multiplier changes (including `t = 0` when it starts
    /// away from 1.0). Scheduled as `SurgeStart` faults so the learning
    /// controller's λ view tracks the trace — load-aware
    /// re-orchestration without a second notification channel.
    fn announcements(&self) -> Vec<(f64, f64)> {
        let mut out = Vec::new();
        let mut prev = 1.0f64;
        let mut t = 0.0f64;
        for (i, seg) in self.trace.segments().iter().enumerate() {
            if t >= self.horizon {
                break;
            }
            let a = self.agg[i];
            if a != prev {
                out.push((t, a));
                prev = a;
            }
            t = seg.t_end;
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Serving plane
// ---------------------------------------------------------------------------

struct EdgeQueue {
    /// Start times of requests queued or in service.
    queue: std::collections::VecDeque<f64>,
    busy: bool,
}

/// The inference-serving component (R1/R3 routing on shared capacity).
pub struct ServingPlane {
    lambda: Vec<f64>,
    latency: LatencyModel,
    queue_window_s: f64,
    rng: Rng,
    edges: Vec<EdgeQueue>,
    out: ServingOutcome,
    timeline: TimeBuckets,
    /// Open-loop trace generator; `None` in the default closed-loop
    /// per-device Poisson mode.
    source: Option<TraceSource>,
}

impl ServingPlane {
    fn edge_service_ms(&mut self, j: usize, shared: &SharedWorld) -> f64 {
        let mean = 1000.0 / shared.effective_rate(j).max(1e-9);
        if self.latency.stochastic_service {
            self.rng.exponential(1.0 / mean)
        } else {
            mean
        }
    }

    fn record(&mut self, now: f64, latency_ms: f64, class: Class) {
        self.out.latency.push(latency_ms);
        self.out.samples.push(latency_ms);
        self.out.percentiles.push(latency_ms);
        self.timeline.push(now, latency_ms);
        match class {
            Class::Edge => self.out.served_at_edge += 1,
            Class::Spill => self.out.spilled_to_cloud += 1,
            Class::Direct => self.out.direct_to_cloud += 1,
        }
    }

    /// Route one request from `device` (R1/R3 on the current assignment),
    /// regardless of whether the arrival came from the closed-loop
    /// per-device Poisson stream or an open-loop trace.
    fn route_request(
        &mut self,
        now: f64,
        device: usize,
        kernel: &mut Kernel<CoEvent>,
        shared: &mut SharedWorld,
    ) {
        match shared.assign[device] {
            Some(j) if j < self.edges.len() && shared.edges[j].up => {
                // R3 admission against the *effective* rate.
                let bound = admission_bound(self.queue_window_s, shared.effective_rate(j));
                if self.edges[j].queue.len() < bound {
                    self.edges[j].queue.push_back(now);
                    if !self.edges[j].busy {
                        self.edges[j].busy = true;
                        let svc = self.edge_service_ms(j, shared);
                        kernel.schedule_tagged_in(
                            svc / 1000.0,
                            edge_tag(j),
                            CoEvent::EdgeDone { edge: j },
                        );
                    }
                } else {
                    // Spill: proxy to cloud (edge hop + cloud path).
                    let lat = self.latency.edge_rtt(&mut self.rng)
                        + self.latency.cloud_rtt(&mut self.rng)
                        + self.latency.cloud_service(&mut self.rng);
                    kernel.schedule_in(
                        lat / 1000.0,
                        CoEvent::Complete { t_start: now, class: Class::Spill },
                    );
                }
            }
            _ => {
                // No aggregator (flat FL) or edge down: cloud.
                let lat = self.latency.cloud_rtt(&mut self.rng)
                    + self.latency.cloud_service(&mut self.rng);
                kernel.schedule_in(
                    lat / 1000.0,
                    CoEvent::Complete { t_start: now, class: Class::Direct },
                );
            }
        }
    }
}

impl Component<CoEvent, SharedWorld> for ServingPlane {
    fn name(&self) -> &'static str {
        "serving"
    }

    fn handle(
        &mut self,
        now: f64,
        event: CoEvent,
        kernel: &mut Kernel<CoEvent>,
        shared: &mut SharedWorld,
    ) {
        match event {
            CoEvent::Arrival { device } => {
                // Next request from this device (Poisson stream; a load
                // surge scales the rate of every *future* inter-arrival).
                // The interarrival draw comes FIRST so the routing RNG
                // sequence is unchanged from earlier revisions.
                let rate = self.lambda[device] * shared.surge;
                if rate > 0.0 {
                    kernel.schedule_in(self.rng.exponential(rate), CoEvent::Arrival { device });
                }
                self.route_request(now, device, kernel, shared);
            }
            CoEvent::TraceArrival { device } => {
                self.route_request(now, device, kernel, shared);
                // Pull the next open-loop arrival; the source refills its
                // buffer one chunk at a time, so the kernel only ever
                // carries a single pending trace timer.
                if let Some(src) = self.source.as_mut() {
                    if let Some((t, d)) = src.next_arrival() {
                        kernel.schedule(t, CoEvent::TraceArrival { device: d });
                    }
                }
            }
            CoEvent::EdgeDone { edge } => {
                // (A failed edge's pending EdgeDone timers were cancelled
                // at the kernel via the generation tag, so reaching here
                // means the edge's service stream is live.)
                if let Some(t_start) = self.edges[edge].queue.pop_front() {
                    let rtt = self.latency.edge_rtt(&mut self.rng);
                    let total_ms = (now - t_start) * 1000.0 + rtt;
                    self.record(now, total_ms, Class::Edge);
                }
                if self.edges[edge].queue.is_empty() {
                    self.edges[edge].busy = false;
                } else {
                    let svc = self.edge_service_ms(edge, shared);
                    kernel.schedule_tagged_in(
                        svc / 1000.0,
                        edge_tag(edge),
                        CoEvent::EdgeDone { edge },
                    );
                }
            }
            CoEvent::Complete { t_start, class } => {
                let total_ms = (now - t_start) * 1000.0;
                self.record(now, total_ms, class);
            }
            CoEvent::FlushEdge { edge } => {
                // The edge went down: its backlog is proxied to the cloud
                // (edge hop already paid; wait time accrues until the
                // cloud response lands).
                let drained: Vec<f64> = self.edges[edge].queue.drain(..).collect();
                self.edges[edge].busy = false;
                for t_start in drained {
                    let lat = self.latency.edge_rtt(&mut self.rng)
                        + self.latency.cloud_rtt(&mut self.rng)
                        + self.latency.cloud_service(&mut self.rng);
                    kernel.schedule_in(
                        lat / 1000.0,
                        CoEvent::Complete { t_start, class: Class::Spill },
                    );
                }
            }
            _ => {}
        }
    }
}

// ---------------------------------------------------------------------------
// Training plane
// ---------------------------------------------------------------------------

/// When the training plane runs rounds.
#[derive(Debug, Clone)]
pub enum TrainingSchedule {
    /// No training activity on the timeline.
    Idle,
    /// Rounds start at `start_s`; each next round begins `gap_s` after
    /// the previous one ends (the paper's continual regime).
    Periodic { start_s: f64, gap_s: f64 },
    /// Rounds run only when the inference controller triggers a task of
    /// `rounds_per_task` back-to-back rounds.
    OnTrigger { rounds_per_task: usize },
}

#[derive(Debug, Clone)]
pub struct TrainingConfig {
    pub schedule: TrainingSchedule,
    pub time_model: RoundTimeModel,
    /// Local epochs per round (paper: 5).
    pub epochs: usize,
    /// Serialized model size for transfer-time accounting.
    pub model_bytes: usize,
}

impl Default for TrainingConfig {
    fn default() -> Self {
        TrainingConfig {
            schedule: TrainingSchedule::Idle,
            time_model: RoundTimeModel::default(),
            epochs: 5,
            model_bytes: 4 * 65_536,
        }
    }
}

/// The HFL round engine as a timeline component: rounds occupy
/// intervals, marking their edges as training-busy for the duration.
pub struct TrainingPlane {
    cfg: TrainingConfig,
    active: bool,
    burst_remaining: usize,
    next_round: usize,
    rounds_completed: usize,
    /// Telemetry lag before the control plane sees a capacity change.
    report_delay_s: f64,
    control_enabled: bool,
}

impl Component<CoEvent, SharedWorld> for TrainingPlane {
    fn name(&self) -> &'static str {
        "training"
    }

    fn handle(
        &mut self,
        _now: f64,
        event: CoEvent,
        kernel: &mut Kernel<CoEvent>,
        shared: &mut SharedWorld,
    ) {
        match event {
            CoEvent::RoundBegin { round } => {
                self.active = true;
                // Cluster membership comes from the *live* plan.
                let m = shared.edges.len();
                let mut members: Vec<Vec<usize>> = vec![Vec::new(); m];
                for (d, a) in shared.assign.iter().enumerate() {
                    if let Some(j) = *a {
                        if j < m && shared.edges[j].up {
                            members[j].push(d);
                        }
                    }
                }
                let mut max_dur = 0.0f64;
                for (j, mem) in members.iter().enumerate() {
                    if mem.is_empty() {
                        continue;
                    }
                    shared.edges[j].training = true;
                    let dur = self.cfg.time_model.cluster_round_s(
                        mem,
                        self.cfg.epochs,
                        self.cfg.model_bytes,
                    );
                    max_dur = max_dur.max(dur);
                    kernel.schedule_in(dur, CoEvent::EdgeTrainEnd { edge: j, round });
                    if self.control_enabled {
                        kernel
                            .schedule_in(self.report_delay_s, CoEvent::CapacityReport { edge: j });
                    }
                }
                kernel.schedule_in(max_dur, CoEvent::RoundEnd { round });
            }
            CoEvent::EdgeTrainEnd { edge, .. } => {
                shared.edges[edge].training = false;
                if self.control_enabled {
                    kernel.schedule_in(self.report_delay_s, CoEvent::CapacityReport { edge });
                }
            }
            CoEvent::RoundEnd { .. } => {
                self.active = false;
                self.rounds_completed += 1;
                self.next_round += 1;
                match self.cfg.schedule {
                    TrainingSchedule::Idle => {}
                    TrainingSchedule::Periodic { gap_s, .. } => {
                        // Continual regime: every completed round refreshes
                        // the served model, so the control plane's drift
                        // clock resets (otherwise staleness grows forever
                        // and the monitor fires phantom retrain triggers).
                        if self.control_enabled {
                            kernel.schedule_in(0.0, CoEvent::TrainDone);
                        }
                        kernel.schedule_in(gap_s, CoEvent::RoundBegin { round: self.next_round });
                    }
                    TrainingSchedule::OnTrigger { .. } => {
                        self.burst_remaining = self.burst_remaining.saturating_sub(1);
                        if self.burst_remaining > 0 {
                            kernel
                                .schedule_in(0.0, CoEvent::RoundBegin { round: self.next_round });
                        } else if self.control_enabled {
                            kernel.schedule_in(0.0, CoEvent::TrainDone);
                        }
                    }
                }
            }
            CoEvent::TrainTask => {
                if let TrainingSchedule::OnTrigger { rounds_per_task } = self.cfg.schedule {
                    if !self.active && self.burst_remaining == 0 {
                        self.burst_remaining = rounds_per_task.max(1);
                        kernel.schedule_in(0.0, CoEvent::RoundBegin { round: self.next_round });
                    }
                }
            }
            _ => {}
        }
    }
}

// ---------------------------------------------------------------------------
// Control plane
// ---------------------------------------------------------------------------

/// Served-model accuracy drift: MSE grows linearly with time since the
/// last retrain, so the inference controller's EWMA trigger fires when
/// the model goes stale (continual-learning loop on the timeline).
#[derive(Debug, Clone)]
pub struct DriftModel {
    /// Served-model MSE right after a retrain.
    pub fresh_mse: f32,
    /// MSE growth per simulated second since the last retrain.
    pub drift_per_s: f32,
}

#[derive(Debug, Clone)]
pub struct ControlConfig {
    /// Accuracy-monitor cadence (one `observe_mse` per tick).
    pub monitor_period_s: f64,
    /// Telemetry lag between a plane state change and the GPO seeing it.
    pub report_delay_s: f64,
    pub drift: DriftModel,
    /// Force a re-solve when a failed edge comes back.
    pub resolve_on_recover: bool,
}

impl Default for ControlConfig {
    fn default() -> Self {
        ControlConfig {
            monitor_period_s: 2.0,
            report_delay_s: 3.0,
            drift: DriftModel { fresh_mse: 0.02, drift_per_s: 0.0 },
            resolve_on_recover: true,
        }
    }
}

/// The orchestrator in the loop: GPO + learning controller + inference
/// controller, driven entirely by kernel events.
pub struct ControlPlane {
    pub cfg: ControlConfig,
    pub gpo: Gpo,
    pub learning: LearningController,
    pub inference: InferenceController,
    base_lambda: Vec<f64>,
    n_devices: usize,
    /// Whether the training plane accepts TrainTask (OnTrigger schedule).
    trainable: bool,
    last_fresh_s: f64,
    pub retrain_triggers: usize,
    /// Re-solve attempts that failed (e.g. transiently infeasible while
    /// every edge is degraded); the old plan stays installed.
    pub resolve_failures: usize,
}

impl ControlPlane {
    pub fn new(
        gpo: Gpo,
        learning: LearningController,
        inference: InferenceController,
        cfg: ControlConfig,
    ) -> ControlPlane {
        ControlPlane {
            cfg,
            gpo,
            learning,
            inference,
            base_lambda: Vec::new(),
            n_devices: 0,
            trainable: false,
            last_fresh_s: 0.0,
            retrain_triggers: 0,
            resolve_failures: 0,
        }
    }

    /// Called by [`CoSim::new`] so the controller sees the same load the
    /// serving plane simulates and knows whether retrains can be served.
    fn wire(&mut self, lambda: Vec<f64>, trainable: bool) {
        self.n_devices = lambda.len();
        self.base_lambda = lambda;
        self.trainable = trainable;
    }

    /// Ask the learning controller whether the live plan survives the
    /// current environment; install the new plan if it re-solved.
    fn react(&mut self, now: f64, shared: &mut SharedWorld) {
        match self.learning.on_environment_change(&mut self.gpo) {
            Ok(true) => self.install_plan(now, shared),
            Ok(false) => {}
            Err(_) => self.resolve_failures += 1,
        }
    }

    /// Unconditional re-solve (e.g. on edge recovery).
    fn force_resolve(&mut self, now: f64, shared: &mut SharedWorld) {
        // `cluster` returns a borrow of the installed plan; drop it
        // before touching `self` again.
        let solved = self.learning.cluster(&mut self.gpo).is_ok();
        if solved {
            self.install_plan(now, shared);
        } else {
            self.resolve_failures += 1;
        }
    }

    /// Install the controller's current plan into the live world —
    /// gated by the budget governor (DESIGN.md §11), which prices the
    /// *actual* delta between the live assignment and the candidate
    /// plan. A denied install leaves the stale plan live and queues the
    /// trigger; the next monitor tick re-prices the latest desired plan
    /// against the refilled budget. With the default unlimited governor
    /// the gate always approves, so pre-budget timelines are unchanged.
    fn install_plan(&mut self, now: f64, shared: &mut SharedWorld) {
        if let Some(plan) = &self.learning.current_plan {
            let assign = plan.assignment_by_device(self.n_devices);
            let delta = plan_delta(&shared.assign, &assign);
            if !self.learning.governor.approve_install(now, &delta) {
                return;
            }
            if assign != shared.assign {
                shared.assign = assign;
                shared.plan_swaps += 1;
            }
        }
    }
}

/// Fault mutations every run applies, orchestrator or not: edge state,
/// timer cancellation via generation tags, backlog flush, surge factor.
fn apply_fault(kernel: &mut Kernel<CoEvent>, shared: &mut SharedWorld, fault: FaultEvent) {
    match fault {
        FaultEvent::EdgeFail(j) => {
            if j < shared.edges.len() && shared.edges[j].up {
                shared.edges[j].up = false;
                kernel.invalidate_tag(edge_tag(j));
                kernel.schedule_in(0.0, CoEvent::FlushEdge { edge: j });
            }
        }
        FaultEvent::EdgeRecover(j) => {
            if j < shared.edges.len() {
                shared.edges[j].up = true;
            }
        }
        FaultEvent::SurgeStart { factor } => {
            shared.surge = factor.max(1e-9);
        }
        FaultEvent::SurgeEnd => {
            shared.surge = 1.0;
        }
    }
}

impl Component<CoEvent, SharedWorld> for ControlPlane {
    fn name(&self) -> &'static str {
        "control"
    }

    fn handle(
        &mut self,
        now: f64,
        event: CoEvent,
        kernel: &mut Kernel<CoEvent>,
        shared: &mut SharedWorld,
    ) {
        match event {
            CoEvent::MonitorTick => {
                // One monitoring heartbeat: refills the budget bucket
                // and meters telemetry (charged even when the decision
                // below is "do nothing").
                self.learning.governor.note_telemetry(now);
                let staleness = (now - self.last_fresh_s) as f32;
                let mse = self.cfg.drift.fresh_mse + self.cfg.drift.drift_per_s * staleness;
                // Only count (and dispatch) a trigger when the training
                // plane can actually serve it — otherwise Idle/Periodic
                // schedules would report phantom retrains forever.
                if self.inference.observe_mse(mse) && self.trainable {
                    self.retrain_triggers += 1;
                    kernel.schedule_in(0.0, CoEvent::TrainTask);
                }
                // A budget-deferred install is re-evaluated here, where
                // the refilled bucket may now afford the latest desired
                // plan (superseding any intermediate candidates).
                if self.learning.governor.has_pending() {
                    self.install_plan(now, shared);
                }
                kernel.schedule_in(self.cfg.monitor_period_s, CoEvent::MonitorTick);
            }
            CoEvent::CapacityReport { edge } => {
                if edge < shared.capacity.len() {
                    // Same formula the serving plane queues by.
                    self.gpo.set_edge_capacity(edge, shared.effective_rate(edge));
                    self.react(now, shared);
                }
            }
            CoEvent::Fault(fault) => {
                apply_fault(kernel, shared, fault);
                match fault {
                    FaultEvent::EdgeFail(j) => {
                        self.gpo.fail_edge(j);
                        self.react(now, shared);
                    }
                    FaultEvent::EdgeRecover(j) => {
                        self.gpo.recover_edge(j);
                        if self.cfg.resolve_on_recover {
                            self.force_resolve(now, shared);
                        }
                    }
                    FaultEvent::SurgeStart { factor } => {
                        // Load-aware re-orchestration: the controller's λ
                        // view tracks the surge and may re-place.
                        for d in 0..self.n_devices {
                            self.learning.set_lambda(d, self.base_lambda[d] * factor);
                        }
                        self.react(now, shared);
                    }
                    FaultEvent::SurgeEnd => {
                        for d in 0..self.n_devices {
                            self.learning.set_lambda(d, self.base_lambda[d]);
                        }
                        self.react(now, shared);
                    }
                }
            }
            CoEvent::TrainDone => {
                self.last_fresh_s = now;
            }
            _ => {}
        }
    }
}

// ---------------------------------------------------------------------------
// The co-simulation driver
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct CoSimConfig {
    pub serving: ServingConfig,
    /// Serving-capacity multiplier for an edge mid-training-round
    /// (1.0 = the planes do not interfere).
    pub interference_factor: f64,
    pub training: TrainingConfig,
    /// Pre-scheduled environmental events `(time_s, fault)`.
    pub faults: Vec<(f64, FaultEvent)>,
    /// Latency-timeline bucket width (s).
    pub bucket_s: f64,
    /// Record a per-event trace (determinism tests / debugging).
    pub record_trace: bool,
    /// How request arrivals are generated. The default
    /// ([`ArrivalModel::PerDevicePoisson`]) is the closed-loop one-timer-
    /// per-device stream and is bit-identical to earlier revisions;
    /// [`ArrivalModel::Trace`] switches to batched open-loop generation
    /// from a [`RateTrace`].
    pub arrivals: ArrivalModel,
}

impl CoSimConfig {
    /// The static-assignment fast path: serving only, no interference,
    /// no faults — bit-identical to the pre-kernel simulator.
    pub fn static_serving(serving: ServingConfig) -> CoSimConfig {
        CoSimConfig {
            serving,
            interference_factor: 1.0,
            training: TrainingConfig::default(),
            faults: Vec::new(),
            bucket_s: 10.0,
            record_trace: false,
            arrivals: ArrivalModel::PerDevicePoisson,
        }
    }
}

/// Outcome of one co-simulation run.
#[derive(Debug, Clone)]
pub struct CoSimOutcome {
    pub serving: ServingOutcome,
    /// Mean response latency per [`CoSimConfig::bucket_s`] window.
    pub timeline: TimeBuckets,
    pub rounds_completed: usize,
    pub plan_swaps: usize,
    pub reclusters: usize,
    /// Plans produced by a warm-start repair instead of a cold solve
    /// (0 under the default `ResolveStrategy::Full`).
    pub warm_resolves: usize,
    /// Triggers answered from the solve cache or the GPO epoch
    /// short-circuit (0 under `ResolveStrategy::Full`).
    pub cache_hits: usize,
    pub retrain_triggers: usize,
    pub resolve_failures: usize,
    /// Budget-governed reconfiguration spend approved by the control
    /// plane's [`BudgetPolicy`](crate::orchestrator::BudgetPolicy)
    /// (model redistribution + signalling bytes; metered even when the
    /// governor is unlimited, 0 without a control plane).
    pub ctl_spend_bytes: u64,
    /// Monitoring telemetry bytes metered by the governor (outside the
    /// budgeted spend — the monitoring plane is always on).
    pub ctl_telemetry_bytes: u64,
    /// Plan installs denied (deferred) by the budget policy.
    pub budget_deferrals: usize,
    pub events_processed: u64,
    pub events_cancelled: u64,
    /// The GPO's per-edge capacity view at the end of the run, indexed by
    /// dense edge id (empty without a control plane). After every
    /// training round's restoring `CapacityReport` has fired, this must
    /// equal the base capacities — the stale-capacity regression tests
    /// assert exactly that.
    pub gpo_edge_capacity: Vec<f64>,
    /// The GPO's event log (capacity reports, failures, deployments;
    /// empty without a control plane).
    pub gpo_events: Vec<String>,
    /// Per-event trace (empty unless `record_trace`).
    pub trace: Vec<String>,
}

/// The assembled co-simulation: kernel + planes + shared world.
pub struct CoSim {
    kernel: Kernel<CoEvent>,
    shared: SharedWorld,
    serving: ServingPlane,
    training: TrainingPlane,
    control: Option<ControlPlane>,
    faults: Vec<(f64, FaultEvent)>,
    horizon: f64,
    trace: Option<Vec<String>>,
}

impl CoSim {
    pub fn new(cfg: CoSimConfig, control: Option<ControlPlane>) -> CoSim {
        CoSim::with_kernel(cfg, control, Kernel::new())
    }

    /// Assemble a co-simulation on a caller-supplied kernel. The kernel
    /// is [`Kernel::reset`] before use (so only its slab/bucket capacity
    /// carries over, never state) — this is the allocation-reuse path for
    /// back-to-back cells ([`run_cell_reusing`]).
    pub fn with_kernel(
        cfg: CoSimConfig,
        control: Option<ControlPlane>,
        mut kernel: Kernel<CoEvent>,
    ) -> CoSim {
        kernel.reset();
        let n = cfg.serving.assign.len();
        assert_eq!(cfg.serving.lambda.len(), n, "lambda len");
        let m = cfg.serving.capacity.len();
        if let TrainingSchedule::Periodic { gap_s, .. } = cfg.training.schedule {
            assert!(gap_s > 0.0, "periodic training needs a positive gap");
        }

        let shared = SharedWorld {
            assign: cfg.serving.assign.clone(),
            edges: vec![EdgeShared { up: true, training: false }; m],
            capacity: cfg.serving.capacity.clone(),
            interference_factor: cfg.interference_factor,
            surge: 1.0,
            plan_swaps: 0,
        };
        let source = match &cfg.arrivals {
            ArrivalModel::PerDevicePoisson => None,
            ArrivalModel::Trace { trace, chunk_s } => Some(TraceSource::new(
                trace.clone(),
                *chunk_s,
                cfg.serving.lambda.clone(),
                cfg.serving.seed,
                cfg.serving.duration_s,
            )),
        };
        let serving = ServingPlane {
            lambda: cfg.serving.lambda.clone(),
            latency: cfg.serving.latency.clone(),
            queue_window_s: cfg.serving.queue_window_s,
            rng: Rng::new(cfg.serving.seed),
            edges: (0..m)
                .map(|_| EdgeQueue { queue: std::collections::VecDeque::new(), busy: false })
                .collect(),
            out: ServingOutcome::new(cfg.serving.seed),
            timeline: TimeBuckets::new(cfg.bucket_s),
            source,
        };
        let control_enabled = control.is_some();
        let report_delay_s = control.as_ref().map(|c| c.cfg.report_delay_s).unwrap_or(0.0);
        let mut control = control;
        if let Some(c) = control.as_mut() {
            let trainable = matches!(cfg.training.schedule, TrainingSchedule::OnTrigger { .. });
            c.wire(cfg.serving.lambda.clone(), trainable);
        }
        let training = TrainingPlane {
            cfg: cfg.training,
            active: false,
            burst_remaining: 0,
            next_round: 0,
            rounds_completed: 0,
            report_delay_s,
            control_enabled,
        };
        CoSim {
            kernel,
            shared,
            serving,
            training,
            control,
            faults: cfg.faults,
            horizon: cfg.serving.duration_s,
            trace: if cfg.record_trace { Some(Vec::new()) } else { None },
        }
    }

    /// Run to the horizon and assemble the outcome.
    pub fn run(self) -> CoSimOutcome {
        self.run_returning_kernel().0
    }

    /// Run to the horizon and hand the kernel back alongside the outcome,
    /// so the next cell can reuse its slab and bucket allocations (see
    /// [`run_cell_reusing`]).
    pub fn run_returning_kernel(mut self) -> (CoSimOutcome, Kernel<CoEvent>) {
        // Seed arrivals FIRST — bit-for-bit with the pre-kernel simulator
        // (same RNG draw order, same kernel sequence numbers).
        if self.serving.source.is_some() {
            // Open-loop trace mode: the control plane learns about λ
            // changes via SurgeStart announcements at segment boundaries
            // (the trace itself drives arrivals; `shared.surge` is then
            // inert on the arrival path).
            let announcements =
                self.serving.source.as_ref().expect("checked").announcements();
            for (t, factor) in announcements {
                self.kernel.schedule(t, CoEvent::Fault(FaultEvent::SurgeStart { factor }));
            }
            if let Some((t, d)) =
                self.serving.source.as_mut().expect("checked").next_arrival()
            {
                self.kernel.schedule(t, CoEvent::TraceArrival { device: d });
            }
        } else {
            for d in 0..self.serving.lambda.len() {
                if self.serving.lambda[d] > 0.0 {
                    let dt = self.serving.rng.exponential(self.serving.lambda[d]);
                    self.kernel.schedule(dt, CoEvent::Arrival { device: d });
                }
            }
        }
        if let TrainingSchedule::Periodic { start_s, .. } = self.training.cfg.schedule {
            self.kernel.schedule(start_s.max(0.0), CoEvent::RoundBegin { round: 0 });
        }
        if self.control.is_some() {
            self.kernel.schedule(0.0, CoEvent::MonitorTick);
        }
        for (t, f) in std::mem::take(&mut self.faults) {
            self.kernel.schedule(t.max(0.0), CoEvent::Fault(f));
        }

        while let Some((t, ev)) = self.kernel.next_before(self.horizon) {
            if let Some(trace) = self.trace.as_mut() {
                trace.push(format!("{:016x}|{ev:?}", t.to_bits()));
            }
            match ev.plane() {
                Plane::Serving => {
                    self.serving.handle(t, ev, &mut self.kernel, &mut self.shared)
                }
                Plane::Training => {
                    self.training.handle(t, ev, &mut self.kernel, &mut self.shared)
                }
                Plane::Control => match self.control.as_mut() {
                    Some(c) => c.handle(t, ev, &mut self.kernel, &mut self.shared),
                    None => {
                        // No orchestrator attached: faults still hit the
                        // infrastructure (ablation baseline), everything
                        // else control-plane is a no-op.
                        if let CoEvent::Fault(f) = ev {
                            apply_fault(&mut self.kernel, &mut self.shared, f);
                        }
                    }
                },
            }
        }

        let m = self.shared.edges.len();
        let gpo_edge_capacity: Vec<f64> = match self.control.as_ref() {
            Some(c) => {
                (0..m).map(|j| c.gpo.edge(j).map(|n| n.capacity).unwrap_or(f64::NAN)).collect()
            }
            None => Vec::new(),
        };
        let gpo_events =
            self.control.as_mut().map(|c| std::mem::take(&mut c.gpo.events)).unwrap_or_default();
        let outcome = CoSimOutcome {
            serving: self.serving.out,
            timeline: self.serving.timeline,
            rounds_completed: self.training.rounds_completed,
            plan_swaps: self.shared.plan_swaps,
            reclusters: self.control.as_ref().map(|c| c.learning.reclusters).unwrap_or(0),
            warm_resolves: self.control.as_ref().map(|c| c.learning.warm_resolves).unwrap_or(0),
            cache_hits: self
                .control
                .as_ref()
                .map(|c| c.learning.cache_hits + c.learning.epoch_hits)
                .unwrap_or(0),
            retrain_triggers: self.control.as_ref().map(|c| c.retrain_triggers).unwrap_or(0),
            resolve_failures: self.control.as_ref().map(|c| c.resolve_failures).unwrap_or(0),
            ctl_spend_bytes: self
                .control
                .as_ref()
                .map(|c| c.learning.governor.policy.spent_bytes)
                .unwrap_or(0),
            ctl_telemetry_bytes: self
                .control
                .as_ref()
                .map(|c| c.learning.governor.ledger.telemetry_bytes)
                .unwrap_or(0),
            budget_deferrals: self
                .control
                .as_ref()
                .map(|c| c.learning.governor.deferrals)
                .unwrap_or(0),
            events_processed: self.kernel.processed(),
            events_cancelled: self.kernel.cancelled_count(),
            gpo_edge_capacity,
            gpo_events,
            trace: self.trace.unwrap_or_default(),
        };
        (outcome, self.kernel)
    }
}

/// Run one fully-specified co-simulation cell and return its outcome.
///
/// The sweep engine's entry point: everything a run needs arrives in the
/// arguments (config, optional control plane, the seed inside
/// `cfg.serving.seed`) and everything it produces leaves in the returned
/// [`CoSimOutcome`] — no global or thread-local state is read or written,
/// so cells are safe to fan out across `util::pool` workers in any order.
pub fn run_cell(cfg: CoSimConfig, control: Option<ControlPlane>) -> CoSimOutcome {
    CoSim::new(cfg, control).run()
}

/// [`run_cell`] variant that reuses a kernel's slab and bucket
/// allocations from a previous cell. The kernel is fully
/// [`Kernel::reset`] before the run, so outcomes are bit-identical to
/// [`run_cell`] — only allocation work is saved. Intended for loops that
/// run many cells back to back (e.g. the interference experiment's
/// all-presets sweep and the end-to-end kernel benchmark).
pub fn run_cell_reusing(
    cfg: CoSimConfig,
    control: Option<ControlPlane>,
    kernel: Kernel<CoEvent>,
) -> (CoSimOutcome, Kernel<CoEvent>) {
    CoSim::with_kernel(cfg, control, kernel).run_returning_kernel()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::simulation::simulate;
    use crate::orchestrator::{InferenceCtlConfig, LearningCtlConfig, ResolveStrategy};
    use crate::topology::GeoPoint;

    fn serving_cfg(
        assign: Vec<Option<usize>>,
        lambda: Vec<f64>,
        capacity: Vec<f64>,
        duration_s: f64,
        seed: u64,
    ) -> ServingConfig {
        ServingConfig {
            assign,
            lambda,
            capacity,
            latency: LatencyModel::default(),
            duration_s,
            queue_window_s: 0.25,
            seed,
        }
    }

    #[test]
    fn interference_factor_one_training_is_serving_noop() {
        // Training rounds on the timeline, but zero interference: the
        // serving plane's RNG/event streams are untouched, so the
        // outcome is bit-identical to the static fast path.
        let scfg = serving_cfg(
            (0..10).map(|i| Some(i % 2)).collect(),
            vec![3.0; 10],
            vec![500.0, 500.0],
            60.0,
            9,
        );
        let baseline = simulate(&scfg);
        let cfg = CoSimConfig {
            serving: scfg,
            interference_factor: 1.0,
            training: TrainingConfig {
                schedule: TrainingSchedule::Periodic { start_s: 5.0, gap_s: 5.0 },
                ..Default::default()
            },
            faults: Vec::new(),
            bucket_s: 10.0,
            record_trace: false,
            arrivals: ArrivalModel::PerDevicePoisson,
        };
        let out = CoSim::new(cfg, None).run();
        assert!(out.rounds_completed >= 1, "{}", out.rounds_completed);
        assert_eq!(out.serving.total(), baseline.total());
        assert_eq!(out.serving.served_at_edge, baseline.served_at_edge);
        assert_eq!(out.serving.latency.mean().to_bits(), baseline.latency.mean().to_bits());
        assert_eq!(out.serving.samples, baseline.samples);
    }

    #[test]
    fn training_round_degrades_shared_edge_latency() {
        // One edge, no orchestrator: latency during the round exceeds
        // the latency before it and recovers after — the paper's
        // training/serving coupling, isolated.
        let cfg = CoSimConfig {
            serving: serving_cfg(vec![Some(0); 8], vec![5.0; 8], vec![400.0], 90.0, 3),
            interference_factor: 0.05,
            training: TrainingConfig {
                schedule: TrainingSchedule::Periodic { start_s: 30.0, gap_s: 1.0e9 },
                time_model: RoundTimeModel { epoch_compute_s: 4.0, ..Default::default() },
                epochs: 5,
                model_bytes: 400_000,
            },
            faults: Vec::new(),
            bucket_s: 5.0,
            record_trace: false,
            arrivals: ArrivalModel::PerDevicePoisson,
        };
        let out = CoSim::new(cfg, None).run();
        assert_eq!(out.rounds_completed, 1);
        let before = out.timeline.mean_between(10.0, 30.0);
        let during = out.timeline.mean_between(31.0, 49.0);
        let after = out.timeline.mean_between(60.0, 85.0);
        assert!(before < 25.0, "before {before}");
        assert!(during > 40.0, "during {during}");
        assert!(after < 25.0, "after {after}");
        assert!(out.serving.spilled_to_cloud > 0);
    }

    #[test]
    fn edge_failure_without_orchestrator_falls_back_to_cloud() {
        let base = serving_cfg(vec![Some(0); 8], vec![5.0; 8], vec![500.0], 60.0, 5);
        let healthy = simulate(&base);
        let cfg = CoSimConfig {
            serving: base,
            interference_factor: 1.0,
            training: TrainingConfig::default(),
            faults: vec![(30.0, FaultEvent::EdgeFail(0))],
            bucket_s: 10.0,
            record_trace: false,
            arrivals: ArrivalModel::PerDevicePoisson,
        };
        let out = CoSim::new(cfg, None).run();
        // Post-failure arrivals go straight to the cloud.
        assert!(out.serving.direct_to_cloud > 500, "{}", out.serving.direct_to_cloud);
        assert!(out.serving.latency.mean() > healthy.latency.mean() + 10.0);
        assert_eq!(healthy.direct_to_cloud, 0);
    }

    #[test]
    fn load_surge_fault_scales_arrivals() {
        let base = serving_cfg(vec![Some(0); 6], vec![4.0; 6], vec![2000.0], 60.0, 11);
        let steady = simulate(&base);
        let cfg = CoSimConfig {
            serving: base,
            interference_factor: 1.0,
            training: TrainingConfig::default(),
            faults: vec![
                (20.0, FaultEvent::SurgeStart { factor: 4.0 }),
                (40.0, FaultEvent::SurgeEnd),
            ],
            bucket_s: 10.0,
            record_trace: false,
            arrivals: ArrivalModel::PerDevicePoisson,
        };
        let out = CoSim::new(cfg, None).run();
        // ~20 s of 4x arrivals: clearly more requests than steady state.
        assert!(
            out.serving.total() as f64 > steady.total() as f64 * 1.5,
            "{} vs {}",
            out.serving.total(),
            steady.total()
        );
    }

    #[test]
    fn orchestrator_resolve_recovers_latency_during_training() {
        // The acceptance scenario: 10 devices on edge 0, edge 1 idle.
        // A training round degrades edge 0 at t=30; the GPO hears about
        // the capacity drop 5 s later, the learning controller re-solves
        // and installs a plan that moves everyone to edge 1 — serving
        // latency degrades during [30, 35) and recovers after the swap,
        // while the round keeps running on edge 0 until ~t=60.
        let p = GeoPoint { lat: 34.05, lon: -118.25 };
        let mut gpo = Gpo::new();
        for d in 0..10 {
            gpo.register_device(d, p);
        }
        gpo.register_edge(0, p, 200.0);
        gpo.register_edge(1, p, 200.0);
        let mut learning = LearningController::new(LearningCtlConfig::default());
        for d in 0..10 {
            learning.set_lambda(d, 5.0);
        }
        let control = ControlPlane::new(
            gpo,
            learning,
            InferenceController::new(InferenceCtlConfig::default()),
            ControlConfig {
                monitor_period_s: 10.0,
                report_delay_s: 5.0,
                drift: DriftModel { fresh_mse: 0.0, drift_per_s: 0.0 },
                resolve_on_recover: true,
            },
        );
        let cfg = CoSimConfig {
            serving: serving_cfg(
                vec![Some(0); 10],
                vec![5.0; 10],
                vec![200.0, 200.0],
                80.0,
                42,
            ),
            interference_factor: 0.05,
            training: TrainingConfig {
                schedule: TrainingSchedule::Periodic { start_s: 30.0, gap_s: 1.0e9 },
                time_model: RoundTimeModel { epoch_compute_s: 6.0, ..Default::default() },
                epochs: 5,
                model_bytes: 400_000,
            },
            faults: Vec::new(),
            bucket_s: 5.0,
            record_trace: false,
            arrivals: ArrivalModel::PerDevicePoisson,
        };
        let out = CoSim::new(cfg, Some(control)).run();
        assert!(out.plan_swaps >= 1, "no plan swap installed");
        assert!(out.reclusters >= 1);
        assert_eq!(out.rounds_completed, 1);
        let before = out.timeline.mean_between(10.0, 30.0);
        let during = out.timeline.mean_between(30.0, 35.0);
        let after = out.timeline.mean_between(45.0, 60.0);
        assert!(before < 30.0, "before {before}");
        assert!(during > 45.0, "during {during}");
        assert!(after < 30.0, "after {after}");
    }

    /// Control plane for a 10-device / 2-edge world (both edges at
    /// capacity 200), the satellite-2 stale-capacity test rig.
    fn two_edge_control(report_delay_s: f64) -> ControlPlane {
        let p = GeoPoint { lat: 34.05, lon: -118.25 };
        let mut gpo = Gpo::new();
        for d in 0..10 {
            gpo.register_device(d, p);
        }
        gpo.register_edge(0, p, 200.0);
        gpo.register_edge(1, p, 200.0);
        let mut learning = LearningController::new(LearningCtlConfig::default());
        for d in 0..10 {
            learning.set_lambda(d, 5.0);
        }
        ControlPlane::new(
            gpo,
            learning,
            InferenceController::new(InferenceCtlConfig::default()),
            ControlConfig {
                monitor_period_s: 10.0,
                report_delay_s,
                drift: DriftModel { fresh_mse: 0.0, drift_per_s: 0.0 },
                resolve_on_recover: true,
            },
        )
    }

    fn one_round_on_edge0(duration_s: f64, faults: Vec<(f64, FaultEvent)>) -> CoSimConfig {
        CoSimConfig {
            serving: serving_cfg(
                vec![Some(0); 10],
                vec![5.0; 10],
                vec![200.0, 200.0],
                duration_s,
                42,
            ),
            interference_factor: 0.05,
            training: TrainingConfig {
                schedule: TrainingSchedule::Periodic { start_s: 30.0, gap_s: 1.0e9 },
                time_model: RoundTimeModel { epoch_compute_s: 6.0, ..Default::default() },
                epochs: 5,
                model_bytes: 400_000,
            },
            faults,
            bucket_s: 5.0,
            record_trace: false,
            arrivals: ArrivalModel::PerDevicePoisson,
        }
    }

    #[test]
    fn gpo_capacity_degrades_then_restores_after_round() {
        // The control plane pushes the *degraded* effective rate into the
        // GPO when a round starts; the restoring report after the round's
        // EdgeTrainEnd must bring it back to base — otherwise every later
        // re-solve prices the edge at its training-time rate forever.
        let out = run_cell(one_round_on_edge0(80.0, Vec::new()), Some(two_edge_control(5.0)));
        assert_eq!(out.rounds_completed, 1);
        // Degraded report fired (200 × 0.05 = 10 req/s)...
        assert!(
            out.gpo_events.iter().any(|e| e == "edge 0 capacity -> 10"),
            "no degraded report: {:?}",
            out.gpo_events
        );
        // ...and the edge returned to base after the round.
        let last0 = out
            .gpo_events
            .iter()
            .rev()
            .find(|e| e.starts_with("edge 0 capacity"))
            .expect("no capacity report for edge 0");
        assert_eq!(last0, "edge 0 capacity -> 200");
        assert_eq!(out.gpo_edge_capacity, vec![200.0, 200.0]);
        // The degraded report also drove the mid-round plan swap away
        // from the training edge.
        assert!(out.plan_swaps >= 1);
    }

    #[test]
    fn gpo_capacity_restores_after_midround_failure_and_swap() {
        // Edge 0 fails *during* its round: the failure cancels the edge's
        // stale service timers via the kernel tag, the re-solve installs
        // a plan swap, and the training interval still ends with a
        // restoring report — no stale degraded capacity survives the run,
        // even across the failure/recovery cycle.
        let faults = vec![(33.0, FaultEvent::EdgeFail(0)), (66.0, FaultEvent::EdgeRecover(0))];
        let out = run_cell(one_round_on_edge0(90.0, faults), Some(two_edge_control(5.0)));
        assert_eq!(out.rounds_completed, 1);
        assert!(out.plan_swaps >= 1, "failure must install a plan swap");
        assert!(out.events_cancelled > 0, "failure must cancel the edge's pending timers");
        let last0 = out
            .gpo_events
            .iter()
            .rev()
            .find(|e| e.starts_with("edge 0 capacity"))
            .expect("no capacity report for edge 0");
        assert_eq!(last0, "edge 0 capacity -> 200");
        assert_eq!(out.gpo_edge_capacity, vec![200.0, 200.0]);
    }

    #[test]
    fn budget_starved_gate_defers_every_swap_and_spends_nothing() {
        use crate::orchestrator::budget::{ActionCostModel, BudgetGovernor, BudgetPolicy};
        // Same failure/recovery rig as the stale-capacity test above,
        // but the governor can afford nothing: every non-noop install is
        // deferred, the stale plan stays live, and cumulative spend
        // never exceeds the (1-byte) cap.
        let faults = vec![(33.0, FaultEvent::EdgeFail(0)), (66.0, FaultEvent::EdgeRecover(0))];
        let mut control = two_edge_control(5.0);
        control.learning.governor =
            BudgetGovernor::new(ActionCostModel::for_model(400_000), BudgetPolicy::capped(1));
        let out = run_cell(one_round_on_edge0(90.0, faults), Some(control));
        assert_eq!(out.plan_swaps, 0, "a starved budget must block every reconfiguration");
        assert!(out.budget_deferrals >= 1, "denied installs must count as deferrals");
        assert_eq!(out.ctl_spend_bytes, 0);
        assert!(out.ctl_telemetry_bytes > 0, "monitoring telemetry is metered regardless");
    }

    #[test]
    fn budget_bucket_refill_installs_deferred_swap_later() {
        use crate::orchestrator::budget::{
            ActionCostModel, BudgetGovernor, BudgetPolicy, TokenBucket,
        };
        // An initially-empty bucket: the failure-time re-placement (10
        // devices × ~400 KB ≈ 4 MB) is deferred, then installs at a
        // monitor tick once the first 5 MB epoch refill lands.
        let faults = vec![(33.0, FaultEvent::EdgeFail(0)), (66.0, FaultEvent::EdgeRecover(0))];
        let mut control = two_edge_control(5.0);
        control.learning.governor = BudgetGovernor::new(
            ActionCostModel::for_model(400_000),
            BudgetPolicy::unlimited()
                .with_bucket(TokenBucket::starting_empty(5_000_000, 40.0, 5_000_000)),
        );
        let out = run_cell(one_round_on_edge0(90.0, faults), Some(control));
        assert!(out.budget_deferrals >= 1, "the pre-refill trigger must defer");
        assert!(out.plan_swaps >= 1, "the refilled bucket must eventually fund the swap");
        assert!(out.ctl_spend_bytes > 0);
    }

    #[test]
    fn unlimited_governor_meters_spend_without_changing_decisions() {
        use crate::orchestrator::budget::{ActionCostModel, BudgetGovernor, BudgetPolicy};
        // The default governor and an explicit huge-cap governor must
        // produce byte-identical runs (both approve everything), and an
        // approved swap must show up as metered spend.
        let mk = |governor: Option<BudgetGovernor>| {
            let faults =
                vec![(33.0, FaultEvent::EdgeFail(0)), (66.0, FaultEvent::EdgeRecover(0))];
            let mut control = two_edge_control(5.0);
            if let Some(g) = governor {
                control.learning.governor = g;
            }
            run_cell(one_round_on_edge0(90.0, faults), Some(control))
        };
        let a = mk(None);
        let b = mk(Some(BudgetGovernor::new(
            ActionCostModel::default(),
            BudgetPolicy::capped(u64::MAX),
        )));
        assert!(a.plan_swaps >= 1);
        assert_eq!(a.plan_swaps, b.plan_swaps);
        assert_eq!(a.budget_deferrals, 0);
        assert_eq!(b.budget_deferrals, 0);
        assert_eq!(a.serving.latency.mean().to_bits(), b.serving.latency.mean().to_bits());
        assert_eq!(a.events_processed, b.events_processed);
        assert!(a.ctl_spend_bytes > 0, "approved swaps must be metered even when unlimited");
        assert!(b.ctl_spend_bytes <= u64::MAX);
    }

    #[test]
    fn trace_is_deterministic_across_runs() {
        let mk = || CoSimConfig {
            serving: serving_cfg(vec![Some(0); 5], vec![3.0; 5], vec![300.0], 40.0, 7),
            interference_factor: 0.2,
            training: TrainingConfig {
                schedule: TrainingSchedule::Periodic { start_s: 10.0, gap_s: 5.0 },
                ..Default::default()
            },
            faults: vec![(20.0, FaultEvent::EdgeFail(0)), (30.0, FaultEvent::EdgeRecover(0))],
            bucket_s: 10.0,
            record_trace: true,
            arrivals: ArrivalModel::PerDevicePoisson,
        };
        let a = CoSim::new(mk(), None).run();
        let b = CoSim::new(mk(), None).run();
        assert!(!a.trace.is_empty());
        assert_eq!(a.trace, b.trace);
        assert_eq!(a.serving.latency.mean().to_bits(), b.serving.latency.mean().to_bits());
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.events_cancelled, b.events_cancelled);
    }

    #[test]
    fn constant_trace_volume_matches_closed_loop() {
        // An open-loop constant trace at multiplier 1.0 is the same
        // aggregate Poisson process as the closed-loop per-device
        // streams (different RNG path, same law): total served volume
        // must agree within sampling noise.
        let scfg = serving_cfg(vec![Some(0); 8], vec![5.0; 8], vec![2000.0], 120.0, 13);
        let closed = run_cell(CoSimConfig::static_serving(scfg.clone()), None);
        let open = run_cell(
            CoSimConfig {
                arrivals: ArrivalModel::Trace {
                    trace: RateTrace::constant(1.0),
                    chunk_s: 10.0,
                },
                ..CoSimConfig::static_serving(scfg)
            },
            None,
        );
        let (c, o) = (closed.serving.total() as f64, open.serving.total() as f64);
        assert!((c - o).abs() / c < 0.15, "closed {c} vs open {o}");
        assert!(o > 3000.0, "open-loop volume implausibly low: {o}");
    }

    #[test]
    fn trace_arrivals_are_deterministic() {
        let mk = || CoSimConfig {
            arrivals: ArrivalModel::Trace {
                trace: RateTrace::diurnal(0.5, 2.0, 120.0, 8, 120.0),
                chunk_s: 7.5,
            },
            record_trace: true,
            ..CoSimConfig::static_serving(serving_cfg(
                vec![Some(0); 6],
                vec![4.0; 6],
                vec![800.0],
                120.0,
                21,
            ))
        };
        let a = run_cell(mk(), None);
        let b = run_cell(mk(), None);
        assert!(!a.trace.is_empty());
        assert_eq!(a.trace, b.trace);
        assert_eq!(a.serving.samples, b.serving.samples);
        assert_eq!(a.events_processed, b.events_processed);
    }

    #[test]
    fn flash_crowd_trace_adds_volume() {
        let base = serving_cfg(vec![Some(0); 8], vec![4.0; 8], vec![2000.0], 200.0, 17);
        let mk = |trace: RateTrace| {
            CoSimConfig {
                arrivals: ArrivalModel::Trace { trace, chunk_s: 10.0 },
                ..CoSimConfig::static_serving(base.clone())
            }
        };
        let flat = run_cell(mk(RateTrace::constant(1.0)), None);
        let crowd = run_cell(mk(RateTrace::flash_crowd(1.0, 5.0, 80.0, 10.0, 40.0)), None);
        assert!(
            crowd.serving.total() as f64 > flat.serving.total() as f64 * 1.3,
            "crowd {} vs flat {}",
            crowd.serving.total(),
            flat.serving.total()
        );
    }

    #[test]
    fn hotspot_trace_skews_device_attribution() {
        // 8 equal-rate devices, the first quarter boosted 8x inside the
        // hotspot window: arrivals drawn in the window must concentrate
        // on the boosted prefix (expected share 16/22 ≈ 0.73).
        let trace = RateTrace::regional_hotspot(1.0, 8.0, 0.25, 10.0, 90.0);
        let mut src = TraceSource::new(trace, 10.0, vec![1.0; 8], 99, 200.0);
        let (mut hot, mut tot) = (0usize, 0usize);
        while let Some((t, d)) = src.next_arrival() {
            if (10.0..100.0).contains(&t) {
                tot += 1;
                if d < 2 {
                    hot += 1;
                }
            }
        }
        assert!(tot > 200, "too few in-window arrivals: {tot}");
        let share = hot as f64 / tot as f64;
        assert!(share > 0.55, "hot share {share}");
    }

    #[test]
    fn trace_announcements_reach_the_control_plane() {
        // Segment-boundary λ changes are announced as SurgeStart faults
        // so the learning controller's load view tracks the trace.
        let out = run_cell(
            CoSimConfig {
                arrivals: ArrivalModel::Trace {
                    trace: RateTrace::surge(3.0, 20.0, 40.0),
                    chunk_s: 10.0,
                },
                record_trace: true,
                ..CoSimConfig::static_serving(serving_cfg(
                    vec![Some(0); 4],
                    vec![3.0; 4],
                    vec![400.0],
                    60.0,
                    31,
                ))
            },
            None,
        );
        let surges: Vec<&String> =
            out.trace.iter().filter(|l| l.contains("SurgeStart")).collect();
        // One announcement entering the surge (3.0) and one leaving (1.0).
        assert_eq!(surges.len(), 2, "{surges:?}");
        assert!(surges[0].contains("factor: 3.0"), "{}", surges[0]);
    }

    #[test]
    fn run_cell_reusing_matches_run_cell() {
        // A kernel warmed by a *different* cell and then reset must give
        // bit-identical outcomes: reset reclaims all state, reuse only
        // carries allocation capacity.
        let warm_cfg = one_round_on_edge0(80.0, vec![(33.0, FaultEvent::EdgeFail(0))]);
        let (_, kernel) = run_cell_reusing(warm_cfg, Some(two_edge_control(5.0)), Kernel::new());
        let cfg = || CoSimConfig {
            record_trace: true,
            ..one_round_on_edge0(90.0, vec![(40.0, FaultEvent::SurgeStart { factor: 2.0 })])
        };
        let fresh = run_cell(cfg(), Some(two_edge_control(5.0)));
        let (reused, _) = run_cell_reusing(cfg(), Some(two_edge_control(5.0)), kernel);
        assert_eq!(fresh.trace, reused.trace);
        assert_eq!(fresh.serving.samples, reused.serving.samples);
        assert_eq!(fresh.events_processed, reused.events_processed);
        assert_eq!(fresh.events_cancelled, reused.events_cancelled);
    }

    #[test]
    fn failed_resolve_keeps_stale_plan_and_serving_alive() {
        // Both edges die: the second failure's re-solve has no ready
        // edge host left, so it errs, `resolve_failures` ticks, and the
        // stale plan stays installed — no deployment is applied after
        // the blackout — while serving keeps absorbing arrivals
        // (degraded, via the cloud paths).
        let faults = vec![(20.0, FaultEvent::EdgeFail(0)), (25.0, FaultEvent::EdgeFail(1))];
        let out = run_cell(one_round_on_edge0(60.0, faults), Some(two_edge_control(1.0)));
        assert!(out.resolve_failures >= 1, "no failed re-solve: {:?}", out.gpo_events);
        let second_fail = out
            .gpo_events
            .iter()
            .position(|e| e == "edge 1 failed")
            .expect("second failure not logged");
        let last_applied = out
            .gpo_events
            .iter()
            .rposition(|e| e.starts_with("applied"))
            .expect("no plan was ever installed");
        assert!(
            last_applied < second_fail,
            "a plan was installed after the blackout: {:?}",
            out.gpo_events
        );
        assert!(out.serving.total() > 0, "serving died with the edges");
    }

    #[test]
    fn warm_strategy_cosim_is_deterministic_and_engages() {
        let control = || {
            let mut c = two_edge_control(1.0);
            c.learning.config.strategy = ResolveStrategy::WarmStart;
            c
        };
        let faults =
            || vec![(20.0, FaultEvent::EdgeFail(0)), (40.0, FaultEvent::EdgeRecover(0))];
        let a = run_cell(one_round_on_edge0(80.0, faults()), Some(control()));
        let b = run_cell(one_round_on_edge0(80.0, faults()), Some(control()));
        assert_eq!(a.gpo_events, b.gpo_events);
        assert_eq!(a.plan_swaps, b.plan_swaps);
        assert_eq!(a.reclusters, b.reclusters);
        assert_eq!(a.warm_resolves, b.warm_resolves);
        assert_eq!(a.cache_hits, b.cache_hits);
        assert_eq!(a.serving.samples, b.serving.samples);
        assert!(
            a.warm_resolves + a.cache_hits >= 1,
            "warm machinery never engaged: warm={} cache={}",
            a.warm_resolves,
            a.cache_hits
        );
        assert_eq!(a.resolve_failures, b.resolve_failures);
    }
}
