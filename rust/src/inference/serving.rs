//! Real-execution serving path: a dynamic batcher in front of the PJRT
//! `predict` artifacts.
//!
//! This is the L3 *hot path*: when an edge aggregator (or the cloud
//! server) serves inference requests while training runs, requests are
//! coalesced into batches of up to `serve_batch` and executed through the
//! `predict_b8` artifact; singletons fall back to the B=1 `predict`
//! artifact. Padding rows reuse the first request's window (their outputs
//! are discarded).
//!
//! The batcher is deliberately synchronous and allocation-light: on this
//! class of model (GRU-128, ~0.15 ms/inference) the scheduling overhead
//! must stay well under the model execution time — measured in
//! `benches/bench_runtime.rs` and tracked in EXPERIMENTS.md §Perf.
//!
//! Clock discipline (DESIGN.md §9): queue-latency accounting runs on a
//! *caller-supplied* clock — simulation time in co-sim, a
//! `util::clock::WallClock` reading in the CLI/bench harnesses — so
//! `request_ms` is reproducible when driven from deterministic time.
//! Only `batch_exec_ms`, which measures real model execution, reads the
//! wall clock (through `util::time_it`, the allowlisted site).

use crate::fl::ModelRuntime;
use crate::runtime::Engine;
use crate::util::stats::OnlineStats;
use crate::util::time_it;

/// One pending request: a normalized input window.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: u64,
    pub window: Vec<f32>,
}

/// Serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Model-execution wall time per *batch* (ms).
    pub batch_exec_ms: OnlineStats,
    /// End-to-end per-request latency (ms) on the caller's clock, incl.
    /// queueing inside the batcher window.
    pub request_ms: OnlineStats,
    pub requests: u64,
    pub batches: u64,
    pub padded_rows: u64,
}

impl ServeStats {
    /// Requests per second of model-execution time (upper-bound
    /// throughput of the serving hot path).
    pub fn exec_throughput_rps(&self) -> f64 {
        let total_ms = self.batch_exec_ms.mean() * self.batches as f64;
        if total_ms <= 0.0 {
            return 0.0;
        }
        self.requests as f64 / (total_ms / 1000.0)
    }
}

/// Dynamic batcher over a compiled engine.
pub struct BatchingServer<'a> {
    engine: &'a Engine,
    params: Vec<f32>,
    /// Pending requests with their caller-clock submit times (seconds).
    queue: Vec<(InferenceRequest, f64)>,
    pub max_batch: usize,
    pub stats: ServeStats,
    /// Reusable input buffer (perf: avoids per-batch allocation).
    scratch: Vec<f32>,
}

impl<'a> BatchingServer<'a> {
    pub fn new(engine: &'a Engine, params: Vec<f32>) -> BatchingServer<'a> {
        let v = engine.variant();
        let max_batch = v.serve_batch;
        let scratch = Vec::with_capacity(max_batch * v.seq_len * v.in_dim);
        BatchingServer { engine, params, queue: Vec::new(), max_batch, stats: ServeStats::default(), scratch }
    }

    /// Swap in a new model version (e.g. after a global aggregation
    /// round) without tearing down the compiled executable.
    pub fn update_params(&mut self, params: Vec<f32>) {
        assert_eq!(params.len(), self.params.len(), "param block size change");
        self.params = params;
    }

    /// Enqueue a request at caller-clock time `now_s` (simulation time,
    /// or a `WallClock` reading in the harnesses). Flushes automatically
    /// at `max_batch`.
    pub fn submit(&mut self, req: InferenceRequest, now_s: f64) -> anyhow::Result<Vec<(u64, f32)>> {
        let t = self.engine.variant().seq_len * self.engine.variant().in_dim;
        anyhow::ensure!(req.window.len() == t, "window len {} != {}", req.window.len(), t);
        self.queue.push((req, now_s));
        if self.queue.len() >= self.max_batch {
            self.flush(now_s)
        } else {
            Ok(Vec::new())
        }
    }

    /// Execute everything queued as of caller-clock time `now_s`;
    /// returns (request id, prediction).
    pub fn flush(&mut self, now_s: f64) -> anyhow::Result<Vec<(u64, f32)>> {
        if self.queue.is_empty() {
            return Ok(Vec::new());
        }
        let v = self.engine.variant().clone();
        let t = v.seq_len * v.in_dim;
        let n = self.queue.len();

        let (preds, exec_s) = time_it(|| -> anyhow::Result<Vec<f32>> {
            if n == 1 {
                self.engine.predict(&self.params, &self.queue[0].0.window)
            } else {
                // Pad to serve_batch with copies of the first row.
                self.scratch.clear();
                for (req, _) in &self.queue {
                    self.scratch.extend_from_slice(&req.window);
                }
                self.stats.padded_rows += (self.max_batch - n) as u64;
                for _ in n..self.max_batch {
                    let first: Vec<f32> = self.scratch[..t].to_vec();
                    self.scratch.extend_from_slice(&first);
                }
                self.engine.predict_batch(&self.params, &self.scratch)
            }
        });
        let preds = preds?;

        self.stats.batch_exec_ms.push(exec_s * 1000.0);
        self.stats.batches += 1;

        let mut out = Vec::with_capacity(n);
        for (i, (req, t_in_s)) in self.queue.drain(..).enumerate() {
            let pred = preds[i * v.out_dim];
            self.stats.request_ms.push((now_s - t_in_s).max(0.0) * 1000.0);
            self.stats.requests += 1;
            out.push((req.id, pred));
        }
        Ok(out)
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Predict through the runtime trait (used by tests with MockRuntime
    /// via free function below).
    pub fn engine(&self) -> &Engine {
        self.engine
    }
}

/// Trait-level single prediction helper used where an [`Engine`] is not
/// available (tests, simulations needing a real forward pass).
pub fn predict_one(rt: &dyn ModelRuntime, params: &[f32], window: &[f32]) -> anyhow::Result<f32> {
    // Evaluate via a size-1 "eval" trick is not available on the trait, so
    // we run one train step with lr = 0 and read the loss against y = 0 to
    // recover the squared prediction; instead, prefer the direct engine
    // path. Here we only validate shapes and defer to eval-based probing.
    anyhow::ensure!(window.len() == rt.seq_len(), "window length");
    anyhow::ensure!(!params.is_empty(), "params");
    // loss = mean((pred - 0)^2) = pred^2 -> |pred|; sign probe with y = 1:
    // loss1 = (pred - 1)^2. pred = (1 + pred^2 - loss1) / 2.
    let b = rt.eval_batch_size();
    let xs: Vec<f32> = window.iter().cycle().take(b * rt.seq_len()).cloned().collect();
    let y0 = vec![0.0f32; b];
    let y1 = vec![1.0f32; b];
    let l0 = rt.eval(params, &xs, &y0)?;
    let l1 = rt.eval(params, &xs, &y1)?;
    Ok((1.0 + l0 - l1) / 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::MockRuntime;

    #[test]
    fn predict_one_recovers_linear_model() {
        let rt = MockRuntime::new(3, 4);
        let params = vec![0.5f32, -1.0, 2.0, 0.25]; // w, b
        let window = vec![1.0f32, 2.0, 3.0];
        let want = 0.5 - 2.0 + 6.0 + 0.25;
        let got = predict_one(&rt, &params, &window).unwrap();
        assert!((got - want).abs() < 1e-4, "{got} vs {want}");
    }

    #[test]
    fn predict_one_validates_window() {
        let rt = MockRuntime::new(3, 4);
        assert!(predict_one(&rt, &[0.0; 4], &[0.0; 2]).is_err());
    }

    // BatchingServer end-to-end tests live in
    // rust/tests/serving_integration.rs (they need artifacts).
}
