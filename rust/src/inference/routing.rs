//! Inference request routing — the paper's rules R1–R3 (§IV-A).
//!
//! * **R1**: a device busy training always offloads to its associated
//!   aggregator.
//! * **R2**: a device not participating in the current FL round decides
//!   independently to serve locally or offload to the closest aggregator.
//! * **R3**: the aggregator serves its busy devices' requests with
//!   priority; external/idle-device requests are admitted only if busy
//!   load stays sufficiently below capacity; excess spills to the cloud
//!   (the aggregator acts as a *proxy*).
//!
//! This module holds the pure decision logic; the DES in
//! [`super::simulation`] wires it to queues and clocks. §VI's
//! "lower-complexity local model" alternative is implemented as an
//! optional extension ([`RoutingPolicy::quantized_fallback`]).

/// Where a request goes next.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Serve on the device itself (full-quality model).
    Local,
    /// Serve on the device with the degraded/quantized CPU model (§VI
    /// extension; only when `quantized_fallback` is enabled).
    LocalDegraded,
    /// Forward to edge aggregator `j`.
    Edge(usize),
    /// Forward to the cloud / global server.
    Cloud,
}

/// Static device-side routing state.
#[derive(Debug, Clone, Copy)]
pub struct DeviceCtx {
    /// Busy with local FL training right now (R1)?
    pub busy_training: bool,
    /// Participating in the current FL round at all (R2)?
    pub participant_this_round: bool,
    /// The device's associated (or closest) aggregator, if any.
    pub aggregator: Option<usize>,
    /// Probability-threshold sample for the R2 "independent decision":
    /// true = prefers local execution.
    pub prefers_local: bool,
}

/// Aggregator-side admission state (R3).
#[derive(Debug, Clone, Copy)]
pub struct EdgeCtx {
    /// Instantaneous load from busy/priority devices (req/s).
    pub busy_load: f64,
    /// Additional admitted external load (req/s).
    pub external_load: f64,
    /// Capacity r_j (req/s).
    pub capacity: f64,
    /// Headroom factor: external requests admitted only while
    /// `busy_load + external_load < headroom * capacity` (R3's
    /// "sufficiently below its capacity").
    pub headroom: f64,
}

/// Device-side routing policy.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoutingPolicy {
    /// §VI extension: a busy device may serve on-CPU with a quantized
    /// model instead of offloading.
    pub quantized_fallback: bool,
}

impl RoutingPolicy {
    /// Apply R1/R2 at the device.
    pub fn route_at_device(&self, d: &DeviceCtx) -> Route {
        if d.busy_training {
            // R1 — always offload while training (or §VI fallback).
            if self.quantized_fallback {
                return Route::LocalDegraded;
            }
            return match d.aggregator {
                Some(j) => Route::Edge(j),
                None => Route::Cloud,
            };
        }
        if !d.participant_this_round {
            // R2 — independent decision.
            if d.prefers_local {
                return Route::Local;
            }
            return match d.aggregator {
                Some(j) => Route::Edge(j),
                None => Route::Cloud,
            };
        }
        // Participating but not actively busy (e.g. between epochs):
        // serve locally — the model replica is on-device.
        Route::Local
    }

    /// Apply R3 at the aggregator for a request from a *busy* device.
    /// Priority class: admitted while there is any capacity; else cloud.
    pub fn admit_priority(&self, e: &EdgeCtx) -> Route {
        if e.busy_load < e.capacity {
            Route::Edge(usize::MAX) // marker: admitted here
        } else {
            Route::Cloud
        }
    }

    /// Apply R3 for an external / idle-device request: admitted only with
    /// headroom to spare.
    pub fn admit_external(&self, e: &EdgeCtx) -> Route {
        if e.busy_load + e.external_load < e.headroom * e.capacity {
            Route::Edge(usize::MAX)
        } else {
            Route::Cloud
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev(busy: bool, part: bool, agg: Option<usize>, local: bool) -> DeviceCtx {
        DeviceCtx {
            busy_training: busy,
            participant_this_round: part,
            aggregator: agg,
            prefers_local: local,
        }
    }

    #[test]
    fn r1_busy_device_offloads_to_aggregator() {
        let p = RoutingPolicy::default();
        assert_eq!(p.route_at_device(&dev(true, true, Some(3), true)), Route::Edge(3));
    }

    #[test]
    fn r1_busy_device_without_aggregator_goes_cloud() {
        let p = RoutingPolicy::default();
        assert_eq!(p.route_at_device(&dev(true, true, None, false)), Route::Cloud);
    }

    #[test]
    fn r2_idle_nonparticipant_choice() {
        let p = RoutingPolicy::default();
        assert_eq!(p.route_at_device(&dev(false, false, Some(1), true)), Route::Local);
        assert_eq!(p.route_at_device(&dev(false, false, Some(1), false)), Route::Edge(1));
    }

    #[test]
    fn participant_between_epochs_serves_locally() {
        let p = RoutingPolicy::default();
        assert_eq!(p.route_at_device(&dev(false, true, Some(1), false)), Route::Local);
    }

    #[test]
    fn quantized_fallback_serves_degraded() {
        let p = RoutingPolicy { quantized_fallback: true };
        assert_eq!(p.route_at_device(&dev(true, true, Some(1), false)), Route::LocalDegraded);
    }

    #[test]
    fn r3_priority_admitted_until_capacity() {
        let p = RoutingPolicy::default();
        let mut e = EdgeCtx { busy_load: 5.0, external_load: 0.0, capacity: 10.0, headroom: 0.8 };
        assert!(matches!(p.admit_priority(&e), Route::Edge(_)));
        e.busy_load = 10.0;
        assert_eq!(p.admit_priority(&e), Route::Cloud);
    }

    #[test]
    fn r3_external_needs_headroom() {
        let p = RoutingPolicy::default();
        let e = EdgeCtx { busy_load: 7.0, external_load: 0.5, capacity: 10.0, headroom: 0.8 };
        assert!(matches!(p.admit_external(&e), Route::Edge(_)));
        let full = EdgeCtx { busy_load: 7.9, external_load: 0.2, capacity: 10.0, headroom: 0.8 };
        assert_eq!(p.admit_external(&full), Route::Cloud);
    }
}
