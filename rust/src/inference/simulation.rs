//! Discrete-event simulation of the inference-serving plane (Fig. 7/8).
//!
//! Devices generate Poisson inference request streams (rate λ_i). All
//! devices are busy training (the continual-learning regime the paper
//! evaluates), so per rule **R1** every request is offloaded:
//!
//! * **flat FL** — no aggregators: requests go device → cloud
//!   (`cloud_rtt + cloud_service`; the cloud has infinite capacity).
//! * **hierarchical** — requests go device → associated edge aggregator.
//!   The edge is a FIFO queue with deterministic service and an
//!   **R3 admission bound**: a request is admitted only while the number
//!   in system is below `queue_window_s · r_j` (≈ the backlog the edge can
//!   clear within the window); excess requests are proxied to the cloud,
//!   paying the edge hop *and* the cloud path
//!   (`edge_rtt + cloud_rtt + cloud_service`).
//!
//! The difference between the paper's "hierarchical benchmark" and
//! "HFLOP" is purely *which* device→edge assignment is simulated:
//! location-based clustering ignores λ/r (some edges overload → spill),
//! HFLOP respects capacity (constraint 4) so spill is rare. Fig. 7's
//! response-time distributions and Fig. 8's speedup crossover both emerge
//! from this mechanism.

use super::latency::LatencyModel;
use crate::sim::Des;
use crate::util::rng::Rng;
use crate::util::stats::OnlineStats;

/// Serving-plane configuration for one simulated policy.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Device → edge assignment (None = no aggregator; device uses cloud).
    pub assign: Vec<Option<usize>>,
    /// Per-device request rate λ_i (req/s).
    pub lambda: Vec<f64>,
    /// Per-edge processing capacity r_j (req/s).
    pub capacity: Vec<f64>,
    pub latency: LatencyModel,
    /// Simulated wall time (s).
    pub duration_s: f64,
    /// R3 admission: max in-system backlog = `queue_window_s * r_j`.
    pub queue_window_s: f64,
    pub seed: u64,
}

/// Per-run outcome.
#[derive(Debug, Clone)]
pub struct ServingOutcome {
    /// End-to-end response-time stats (ms).
    pub latency: OnlineStats,
    /// Raw samples (ms) for distribution plots (Fig. 7).
    pub samples: Vec<f64>,
    pub served_at_edge: u64,
    pub spilled_to_cloud: u64,
    pub direct_to_cloud: u64,
}

impl ServingOutcome {
    pub fn total(&self) -> u64 {
        self.served_at_edge + self.spilled_to_cloud + self.direct_to_cloud
    }

    pub fn spill_fraction(&self) -> f64 {
        let hier = self.served_at_edge + self.spilled_to_cloud;
        if hier == 0 {
            0.0
        } else {
            self.spilled_to_cloud as f64 / hier as f64
        }
    }
}

#[derive(Debug)]
enum Ev {
    /// A device emits its next request.
    Arrival { device: usize },
    /// An edge finishes its current head-of-line request.
    EdgeDone { edge: usize },
    /// A cloud-path request completes (response received by the device).
    Complete { t_start: f64, class: Class },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Class {
    Edge,
    Spill,
    Direct,
}

struct EdgeState {
    /// Requests currently queued or in service (start times).
    queue: std::collections::VecDeque<f64>,
    busy: bool,
}

/// Run the serving simulation.
pub fn simulate(cfg: &ServingConfig) -> ServingOutcome {
    let n = cfg.assign.len();
    assert_eq!(cfg.lambda.len(), n, "lambda len");
    let m = cfg.capacity.len();
    let mut rng = Rng::new(cfg.seed);
    let mut des: Des<Ev> = Des::new();

    let mut edges: Vec<EdgeState> = (0..m)
        .map(|_| EdgeState { queue: std::collections::VecDeque::new(), busy: false })
        .collect();
    // Per-edge service: capacity r_j (req/s) IS the service rate — an
    // edge processes one inference in 1/r_j seconds (deterministic by
    // default, exponential under `stochastic_service`). This makes the
    // HFLOP capacity constraint and the queueing model one and the same
    // quantity, as in §IV-A.
    let edge_service_ms = |j: usize, rng: &mut Rng, lat: &LatencyModel| -> f64 {
        let mean = 1000.0 / cfg.capacity[j].max(1e-9);
        if lat.stochastic_service {
            rng.exponential(1.0 / mean)
        } else {
            mean
        }
    };

    let mut out = ServingOutcome {
        latency: OnlineStats::new(),
        samples: Vec::new(),
        served_at_edge: 0,
        spilled_to_cloud: 0,
        direct_to_cloud: 0,
    };

    // Seed first arrivals.
    for d in 0..n {
        if cfg.lambda[d] > 0.0 {
            let dt = rng.exponential(cfg.lambda[d]);
            des.schedule(dt, Ev::Arrival { device: d });
        }
    }

    let horizon = cfg.duration_s;
    let record = |out: &mut ServingOutcome, latency_ms: f64, class: Class| {
        out.latency.push(latency_ms);
        out.samples.push(latency_ms);
        match class {
            Class::Edge => out.served_at_edge += 1,
            Class::Spill => out.spilled_to_cloud += 1,
            Class::Direct => out.direct_to_cloud += 1,
        }
    };

    while let Some((now, ev)) = des.next_before(horizon) {
        match ev {
            Ev::Arrival { device } => {
                // Schedule this device's next request.
                des.schedule_in(rng.exponential(cfg.lambda[device]), Ev::Arrival { device });

                match cfg.assign[device] {
                    None => {
                        // Flat FL: straight to the cloud (R1, no aggregator).
                        let lat = cfg.latency.cloud_rtt(&mut rng)
                            + cfg.latency.cloud_service(&mut rng);
                        des.schedule_in(lat / 1000.0, Ev::Complete { t_start: now, class: Class::Direct });
                    }
                    Some(j) => {
                        // R3 admission at the aggregator.
                        let max_in_system =
                            (cfg.queue_window_s * cfg.capacity[j]).max(1.0) as usize;
                        let e = &mut edges[j];
                        if e.queue.len() < max_in_system {
                            // Admitted: edge hop now, service when reached.
                            e.queue.push_back(now);
                            if !e.busy {
                                e.busy = true;
                                let svc = edge_service_ms(j, &mut rng, &cfg.latency);
                                des.schedule_in(svc / 1000.0, Ev::EdgeDone { edge: j });
                            }
                        } else {
                            // Spill: proxy to cloud (edge hop + cloud path).
                            let lat = cfg.latency.edge_rtt(&mut rng)
                                + cfg.latency.cloud_rtt(&mut rng)
                                + cfg.latency.cloud_service(&mut rng);
                            des.schedule_in(
                                lat / 1000.0,
                                Ev::Complete { t_start: now, class: Class::Spill },
                            );
                        }
                    }
                }
            }
            Ev::EdgeDone { edge } => {
                let e = &mut edges[edge];
                if let Some(t_start) = e.queue.pop_front() {
                    // Response travels back over the edge link.
                    let rtt = cfg.latency.edge_rtt(&mut rng);
                    let total_ms = (now - t_start) * 1000.0 + rtt;
                    record(&mut out, total_ms, Class::Edge);
                }
                if e.queue.is_empty() {
                    e.busy = false;
                } else {
                    let svc = edge_service_ms(edge, &mut rng, &cfg.latency);
                    des.schedule_in(svc / 1000.0, Ev::EdgeDone { edge });
                }
            }
            Ev::Complete { t_start, class } => {
                let total_ms = (now - t_start) * 1000.0;
                record(&mut out, total_ms, class);
            }
        }
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base(assign: Vec<Option<usize>>, lambda: Vec<f64>, capacity: Vec<f64>) -> ServingConfig {
        ServingConfig {
            assign,
            lambda,
            capacity,
            latency: LatencyModel::default(),
            duration_s: 60.0,
            queue_window_s: 0.25,
            seed: 42,
        }
    }

    #[test]
    fn flat_fl_latency_in_cloud_range() {
        // Paper Fig. 7: non-hierarchical ~79 ms (cloud RTT 50–100 + svc).
        let cfg = base(vec![None; 10], vec![5.0; 10], vec![]);
        let out = simulate(&cfg);
        assert!(out.total() > 1000);
        assert_eq!(out.served_at_edge, 0);
        let mean = out.latency.mean();
        assert!((70.0..90.0).contains(&mean), "{mean}");
    }

    #[test]
    fn underloaded_edges_give_edge_latency() {
        // Paper Fig. 7 HFLOP: ~10 ms (edge RTT + small service).
        // capacity 1000 req/s -> 1 ms service; total load 20 req/s.
        let cfg = base(
            (0..10).map(|i| Some(i % 2)).collect(),
            vec![2.0; 10],
            vec![1000.0, 1000.0],
        );
        let out = simulate(&cfg);
        assert!(out.spill_fraction() < 0.01, "{}", out.spill_fraction());
        let mean = out.latency.mean();
        assert!((8.0..20.0).contains(&mean), "{mean}");
    }

    #[test]
    fn overloaded_edge_spills_to_cloud() {
        // One tiny edge serving heavy load: most requests must spill and
        // pay edge + cloud latency.
        let cfg = base(vec![Some(0); 10], vec![20.0; 10], vec![5.0]);
        let out = simulate(&cfg);
        assert!(out.spill_fraction() > 0.5, "{}", out.spill_fraction());
        let mean = out.latency.mean();
        assert!(mean > 60.0, "{mean}");
    }

    #[test]
    fn capacity_aware_beats_location_blind() {
        // Two edges: one strong, one weak. "Location" assignment dumps
        // everything on the weak edge; capacity-aware splits by capacity.
        let lambda = vec![4.0; 12];
        let blind = base(vec![Some(1); 12], lambda.clone(), vec![500.0, 20.0]);
        let aware_assign: Vec<Option<usize>> =
            (0..12).map(|i| Some(usize::from(i >= 11))).collect();
        let aware = base(aware_assign, lambda, vec![500.0, 20.0]);
        let out_blind = simulate(&blind);
        let out_aware = simulate(&aware);
        assert!(
            out_aware.latency.mean() < out_blind.latency.mean(),
            "aware {} blind {}",
            out_aware.latency.mean(),
            out_blind.latency.mean()
        );
    }

    #[test]
    fn deterministic_by_seed() {
        let cfg = base(vec![Some(0); 5], vec![3.0; 5], vec![500.0]);
        let a = simulate(&cfg);
        let b = simulate(&cfg);
        assert_eq!(a.samples, b.samples);
        let mut cfg2 = cfg.clone();
        cfg2.seed = 43;
        let c = simulate(&cfg2);
        assert_ne!(a.samples, c.samples);
    }

    #[test]
    fn speedup_reduces_cloud_latency() {
        let mut slow = base(vec![None; 5], vec![5.0; 5], vec![]);
        slow.latency.edge_service_ms = 40.0;
        let mut fast = slow.clone();
        fast.latency = fast.latency.with_speedup(0.9);
        let ms = simulate(&slow).latency.mean();
        let mf = simulate(&fast).latency.mean();
        assert!(mf < ms - 20.0, "{ms} -> {mf}");
    }

    #[test]
    fn throughput_conservation() {
        // All generated arrivals within the horizon either complete or
        // remain in flight; completions ≈ Σλ · T within tolerance.
        let cfg = base(vec![Some(0); 4], vec![10.0; 4], vec![1000.0]);
        let out = simulate(&cfg);
        let expected = 4.0 * 10.0 * cfg.duration_s;
        let got = out.total() as f64;
        assert!((got - expected).abs() < 0.1 * expected, "{got} vs {expected}");
    }
}
