//! The inference-serving simulation (Fig. 7/8) — static fast path.
//!
//! Devices generate Poisson inference request streams (rate λ_i). All
//! devices are busy training (the continual-learning regime the paper
//! evaluates), so per rule **R1** every request is offloaded:
//!
//! * **flat FL** — no aggregators: requests go device → cloud
//!   (`cloud_rtt + cloud_service`; the cloud has infinite capacity).
//! * **hierarchical** — requests go device → associated edge aggregator.
//!   The edge is a FIFO queue with deterministic service and an
//!   **R3 admission bound**: a request is admitted only while the number
//!   in system is below `⌊queue_window_s · r_j⌋` (≈ the backlog the edge
//!   can clear within the window); excess requests are proxied to the
//!   cloud, paying the edge hop *and* the cloud path
//!   (`edge_rtt + cloud_rtt + cloud_service`).
//!
//! The difference between the paper's "hierarchical benchmark" and
//! "HFLOP" is purely *which* device→edge assignment is simulated:
//! location-based clustering ignores λ/r (some edges overload → spill),
//! HFLOP respects capacity (constraint 4) so spill is rare. Fig. 7's
//! response-time distributions and Fig. 8's speedup crossover both emerge
//! from this mechanism.
//!
//! Since the co-simulation refactor, [`simulate`] is a *fast path* over
//! the shared kernel serving component (`inference::cosim`): a fixed
//! assignment, no training plane activity, no orchestrator. A regression
//! test in this file holds its outcome bit-identical to the pre-kernel
//! implementation (kept below as the `legacy` test oracle).

use crate::inference::cosim::{CoSim, CoSimConfig};
use crate::inference::latency::LatencyModel;
use crate::inference::trace::ArrivalModel;
use crate::util::stats::{OnlineStats, Reservoir, StreamingPercentiles};

/// Response-time samples kept for distribution plots: a seeded reservoir
/// of this many, so million-request runs stay O(1) in memory.
pub const LATENCY_RESERVOIR_CAP: usize = 4096;

/// Seed salt for the reservoir's own RNG stream (kept separate from the
/// simulation stream so sampling never perturbs the event sequence).
pub(crate) const RESERVOIR_SEED_SALT: u64 = 0x5EED_5A17_0D15_7A11;

/// Serving-plane configuration for one simulated policy.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Device → edge assignment (None = no aggregator; device uses cloud).
    pub assign: Vec<Option<usize>>,
    /// Per-device request rate λ_i (req/s).
    pub lambda: Vec<f64>,
    /// Per-edge processing capacity r_j (req/s).
    pub capacity: Vec<f64>,
    pub latency: LatencyModel,
    /// Simulated wall time (s).
    pub duration_s: f64,
    /// R3 admission: max in-system backlog = `⌊queue_window_s * r_j⌋`.
    pub queue_window_s: f64,
    pub seed: u64,
}

/// R3 admission bound: the largest in-system backlog an edge with
/// service rate `service_rate` may hold, `⌊queue_window_s · r⌋` clamped
/// to at least 1 (an admitting edge can always hold the request in
/// service). Explicit `.floor()` with a NaN guard — `0 · ∞` and friends
/// admit a single request instead of whatever a raw cast produced.
pub fn admission_bound(queue_window_s: f64, service_rate: f64) -> usize {
    let backlog = queue_window_s * service_rate;
    if backlog.is_nan() {
        return 1;
    }
    // `as usize` saturates (+∞ → usize::MAX, negatives already clamped).
    backlog.floor().max(1.0) as usize
}

/// Per-run outcome. Latency is tracked streaming (Welford + P² + seeded
/// reservoir), so the outcome is O(1) in request count.
#[derive(Debug, Clone)]
pub struct ServingOutcome {
    /// End-to-end response-time stats (ms).
    pub latency: OnlineStats,
    /// Seeded reservoir of response-time samples (ms) for distribution
    /// plots (Fig. 7); bounded at [`LATENCY_RESERVOIR_CAP`].
    pub samples: Reservoir,
    /// Streaming p50/p90/p99 response-time estimates (ms).
    pub percentiles: StreamingPercentiles,
    pub served_at_edge: u64,
    pub spilled_to_cloud: u64,
    pub direct_to_cloud: u64,
}

impl ServingOutcome {
    pub fn new(seed: u64) -> ServingOutcome {
        ServingOutcome {
            latency: OnlineStats::new(),
            samples: Reservoir::new(LATENCY_RESERVOIR_CAP, seed ^ RESERVOIR_SEED_SALT),
            percentiles: StreamingPercentiles::new(),
            served_at_edge: 0,
            spilled_to_cloud: 0,
            direct_to_cloud: 0,
        }
    }

    pub fn total(&self) -> u64 {
        self.served_at_edge + self.spilled_to_cloud + self.direct_to_cloud
    }

    pub fn spill_fraction(&self) -> f64 {
        let hier = self.served_at_edge + self.spilled_to_cloud;
        if hier == 0 {
            0.0
        } else {
            self.spilled_to_cloud as f64 / hier as f64
        }
    }
}

/// Run the serving simulation with a fixed assignment: the co-simulation
/// kernel's serving component alone, bit-identical to the pre-kernel
/// simulator for the same config and seed.
pub fn simulate(cfg: &ServingConfig) -> ServingOutcome {
    CoSim::new(CoSimConfig::static_serving(cfg.clone()), None).run().serving
}

/// [`simulate`] with an explicit arrival model. With
/// [`ArrivalModel::PerDevicePoisson`] this *is* `simulate` (same events,
/// same RNG stream, bit-identical outcome); with [`ArrivalModel::Trace`]
/// the request stream comes from the open-loop rate trace instead — the
/// Fig. 7/8 experiments use this to evaluate policies under diurnal,
/// flash-crowd, and hotspot load shapes.
pub fn simulate_with_arrivals(cfg: &ServingConfig, arrivals: &ArrivalModel) -> ServingOutcome {
    let cosim = CoSimConfig {
        arrivals: arrivals.clone(),
        ..CoSimConfig::static_serving(cfg.clone())
    };
    CoSim::new(cosim, None).run().serving
}

#[cfg(test)]
mod legacy {
    //! The pre-kernel implementation, verbatim — kept as the bit-for-bit
    //! oracle for the static fast path. Do not "fix" or modernize this
    //! code: its entire value is that it still produces exactly the
    //! Fig. 7/8 event and RNG streams the seed repo produced.

    use super::ServingConfig;
    use crate::sim::Des;
    use crate::util::rng::Rng;
    use crate::util::stats::OnlineStats;

    #[derive(Debug, Clone)]
    pub struct LegacyOutcome {
        pub latency: OnlineStats,
        pub samples: Vec<f64>,
        pub served_at_edge: u64,
        pub spilled_to_cloud: u64,
        pub direct_to_cloud: u64,
    }

    #[derive(Debug)]
    enum Ev {
        Arrival { device: usize },
        EdgeDone { edge: usize },
        Complete { t_start: f64, class: Class },
    }

    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    enum Class {
        Edge,
        Spill,
        Direct,
    }

    struct EdgeState {
        queue: std::collections::VecDeque<f64>,
        busy: bool,
    }

    pub fn simulate(cfg: &ServingConfig) -> LegacyOutcome {
        let n = cfg.assign.len();
        assert_eq!(cfg.lambda.len(), n, "lambda len");
        let m = cfg.capacity.len();
        let mut rng = Rng::new(cfg.seed);
        let mut des: Des<Ev> = Des::new();

        let mut edges: Vec<EdgeState> = (0..m)
            .map(|_| EdgeState { queue: std::collections::VecDeque::new(), busy: false })
            .collect();
        let edge_service_ms = |j: usize, rng: &mut Rng| -> f64 {
            let mean = 1000.0 / cfg.capacity[j].max(1e-9);
            if cfg.latency.stochastic_service {
                rng.exponential(1.0 / mean)
            } else {
                mean
            }
        };

        let mut out = LegacyOutcome {
            latency: OnlineStats::new(),
            samples: Vec::new(),
            served_at_edge: 0,
            spilled_to_cloud: 0,
            direct_to_cloud: 0,
        };

        for d in 0..n {
            if cfg.lambda[d] > 0.0 {
                let dt = rng.exponential(cfg.lambda[d]);
                des.schedule(dt, Ev::Arrival { device: d });
            }
        }

        let horizon = cfg.duration_s;
        let record = |out: &mut LegacyOutcome, latency_ms: f64, class: Class| {
            out.latency.push(latency_ms);
            out.samples.push(latency_ms);
            match class {
                Class::Edge => out.served_at_edge += 1,
                Class::Spill => out.spilled_to_cloud += 1,
                Class::Direct => out.direct_to_cloud += 1,
            }
        };

        while let Some((now, ev)) = des.next_before(horizon) {
            match ev {
                Ev::Arrival { device } => {
                    des.schedule_in(rng.exponential(cfg.lambda[device]), Ev::Arrival { device });
                    match cfg.assign[device] {
                        None => {
                            let lat = cfg.latency.cloud_rtt(&mut rng)
                                + cfg.latency.cloud_service(&mut rng);
                            des.schedule_in(
                                lat / 1000.0,
                                Ev::Complete { t_start: now, class: Class::Direct },
                            );
                        }
                        Some(j) => {
                            let max_in_system =
                                (cfg.queue_window_s * cfg.capacity[j]).max(1.0) as usize;
                            let e = &mut edges[j];
                            if e.queue.len() < max_in_system {
                                e.queue.push_back(now);
                                if !e.busy {
                                    e.busy = true;
                                    let svc = edge_service_ms(j, &mut rng);
                                    des.schedule_in(svc / 1000.0, Ev::EdgeDone { edge: j });
                                }
                            } else {
                                let lat = cfg.latency.edge_rtt(&mut rng)
                                    + cfg.latency.cloud_rtt(&mut rng)
                                    + cfg.latency.cloud_service(&mut rng);
                                des.schedule_in(
                                    lat / 1000.0,
                                    Ev::Complete { t_start: now, class: Class::Spill },
                                );
                            }
                        }
                    }
                }
                Ev::EdgeDone { edge } => {
                    let e = &mut edges[edge];
                    if let Some(t_start) = e.queue.pop_front() {
                        let rtt = cfg.latency.edge_rtt(&mut rng);
                        let total_ms = (now - t_start) * 1000.0 + rtt;
                        record(&mut out, total_ms, Class::Edge);
                    }
                    if e.queue.is_empty() {
                        e.busy = false;
                    } else {
                        let svc = edge_service_ms(edge, &mut rng);
                        des.schedule_in(svc / 1000.0, Ev::EdgeDone { edge });
                    }
                }
                Ev::Complete { t_start, class } => {
                    let total_ms = (now - t_start) * 1000.0;
                    record(&mut out, total_ms, class);
                }
            }
        }

        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Reservoir;

    fn base(assign: Vec<Option<usize>>, lambda: Vec<f64>, capacity: Vec<f64>) -> ServingConfig {
        ServingConfig {
            assign,
            lambda,
            capacity,
            latency: LatencyModel::default(),
            duration_s: 60.0,
            queue_window_s: 0.25,
            seed: 42,
        }
    }

    /// The PR's acceptance gate: the kernel fast path reproduces the
    /// pre-refactor outcome bit-identically — class counts, every
    /// latency moment, and the kept sample set.
    #[test]
    fn static_path_matches_legacy_bit_for_bit() {
        let mut configs = vec![
            base(vec![None; 10], vec![5.0; 10], vec![]),
            base((0..10).map(|i| Some(i % 2)).collect(), vec![2.0; 10], vec![1000.0, 1000.0]),
            base(vec![Some(0); 10], vec![20.0; 10], vec![5.0]),
            base(
                (0..12).map(|i| Some(usize::from(i >= 11))).collect(),
                vec![4.0; 12],
                vec![500.0, 20.0],
            ),
        ];
        // Stochastic service exercises every RNG call site.
        let mut stoch = base(vec![Some(0), Some(1), None], vec![8.0; 3], vec![30.0, 500.0]);
        stoch.latency.stochastic_service = true;
        configs.push(stoch);

        for (i, cfg) in configs.iter().enumerate() {
            for seed in [1u64, 42, 20_26] {
                let mut cfg = cfg.clone();
                cfg.seed = seed;
                let new = simulate(&cfg);
                let old = legacy::simulate(&cfg);
                assert_eq!(new.served_at_edge, old.served_at_edge, "cfg {i} seed {seed}");
                assert_eq!(new.spilled_to_cloud, old.spilled_to_cloud, "cfg {i} seed {seed}");
                assert_eq!(new.direct_to_cloud, old.direct_to_cloud, "cfg {i} seed {seed}");
                assert_eq!(new.latency.count(), old.latency.count());
                assert_eq!(new.latency.mean().to_bits(), old.latency.mean().to_bits());
                assert_eq!(new.latency.std().to_bits(), old.latency.std().to_bits());
                assert_eq!(new.latency.min().to_bits(), old.latency.min().to_bits());
                assert_eq!(new.latency.max().to_bits(), old.latency.max().to_bits());
                // The reservoir must equal the legacy sample stream fed
                // through an identically seeded reservoir.
                let mut expect =
                    Reservoir::new(LATENCY_RESERVOIR_CAP, seed ^ RESERVOIR_SEED_SALT);
                for &s in &old.samples {
                    expect.push(s);
                }
                assert_eq!(new.samples, expect, "cfg {i} seed {seed}");
            }
        }
    }

    #[test]
    fn poisson_arrival_model_is_the_static_fast_path() {
        // simulate_with_arrivals(PerDevicePoisson) must be simulate,
        // bit for bit — the trace plumbing is strictly opt-in.
        let cfg = base(vec![Some(0), Some(1), None], vec![6.0; 3], vec![40.0, 500.0]);
        let a = simulate(&cfg);
        let b = simulate_with_arrivals(&cfg, &ArrivalModel::PerDevicePoisson);
        assert_eq!(a.samples, b.samples);
        assert_eq!(a.latency.mean().to_bits(), b.latency.mean().to_bits());
        assert_eq!(a.served_at_edge, b.served_at_edge);
        assert_eq!(a.spilled_to_cloud, b.spilled_to_cloud);
        assert_eq!(a.direct_to_cloud, b.direct_to_cloud);
    }

    #[test]
    fn admission_bound_fractional_and_degenerate() {
        // Fractional bounds floor explicitly: 2.5 admits 2, not "2-ish".
        assert_eq!(admission_bound(0.25, 10.0), 2);
        assert_eq!(admission_bound(0.25, 8.0), 2);
        assert_eq!(admission_bound(0.05, 30.0), 1); // 1.5 -> 1
        assert_eq!(admission_bound(0.05, 1000.0), 50);
        // Below one: clamp to a single in-service request.
        assert_eq!(admission_bound(0.05, 10.0), 1);
        assert_eq!(admission_bound(0.0, 500.0), 1);
        // NaN products (0·∞) admit exactly one instead of cast garbage.
        assert_eq!(admission_bound(0.0, f64::INFINITY), 1);
        assert_eq!(admission_bound(f64::INFINITY, 0.0), 1);
        // Infinite backlog saturates instead of wrapping.
        assert_eq!(admission_bound(1.0, f64::INFINITY), usize::MAX);
        assert_eq!(admission_bound(-1.0, 5.0), 1);
    }

    #[test]
    fn fractional_bound_limits_in_system_backlog() {
        // window 0.25 s · r=10 req/s -> bound 2: with service 100 ms and
        // an overwhelming arrival rate, at most ~duration·r requests can
        // be served at the edge; everything else must spill.
        let mut cfg = base(vec![Some(0)], vec![1000.0], vec![10.0]);
        cfg.duration_s = 1.0;
        let out = simulate(&cfg);
        assert!(out.served_at_edge <= 13, "{}", out.served_at_edge);
        assert!(out.spilled_to_cloud > 500, "{}", out.spilled_to_cloud);
    }

    #[test]
    fn flat_fl_latency_in_cloud_range() {
        // Paper Fig. 7: non-hierarchical ~79 ms (cloud RTT 50–100 + svc).
        let cfg = base(vec![None; 10], vec![5.0; 10], vec![]);
        let out = simulate(&cfg);
        assert!(out.total() > 1000);
        assert_eq!(out.served_at_edge, 0);
        let mean = out.latency.mean();
        assert!((70.0..90.0).contains(&mean), "{mean}");
    }

    #[test]
    fn underloaded_edges_give_edge_latency() {
        // Paper Fig. 7 HFLOP: ~10 ms (edge RTT + small service).
        // capacity 1000 req/s -> 1 ms service; total load 20 req/s.
        let cfg = base(
            (0..10).map(|i| Some(i % 2)).collect(),
            vec![2.0; 10],
            vec![1000.0, 1000.0],
        );
        let out = simulate(&cfg);
        assert!(out.spill_fraction() < 0.01, "{}", out.spill_fraction());
        let mean = out.latency.mean();
        assert!((8.0..20.0).contains(&mean), "{mean}");
    }

    #[test]
    fn overloaded_edge_spills_to_cloud() {
        // One tiny edge serving heavy load: most requests must spill and
        // pay edge + cloud latency.
        let cfg = base(vec![Some(0); 10], vec![20.0; 10], vec![5.0]);
        let out = simulate(&cfg);
        assert!(out.spill_fraction() > 0.5, "{}", out.spill_fraction());
        let mean = out.latency.mean();
        assert!(mean > 60.0, "{mean}");
    }

    #[test]
    fn capacity_aware_beats_location_blind() {
        // Two edges: one strong, one weak. "Location" assignment dumps
        // everything on the weak edge; capacity-aware splits by capacity.
        let lambda = vec![4.0; 12];
        let blind = base(vec![Some(1); 12], lambda.clone(), vec![500.0, 20.0]);
        let aware_assign: Vec<Option<usize>> =
            (0..12).map(|i| Some(usize::from(i >= 11))).collect();
        let aware = base(aware_assign, lambda, vec![500.0, 20.0]);
        let out_blind = simulate(&blind);
        let out_aware = simulate(&aware);
        assert!(
            out_aware.latency.mean() < out_blind.latency.mean(),
            "aware {} blind {}",
            out_aware.latency.mean(),
            out_blind.latency.mean()
        );
    }

    #[test]
    fn deterministic_by_seed() {
        let cfg = base(vec![Some(0); 5], vec![3.0; 5], vec![500.0]);
        let a = simulate(&cfg);
        let b = simulate(&cfg);
        assert_eq!(a.samples, b.samples);
        let mut cfg2 = cfg.clone();
        cfg2.seed = 43;
        let c = simulate(&cfg2);
        assert_ne!(a.samples, c.samples);
    }

    #[test]
    fn speedup_reduces_cloud_latency() {
        let mut slow = base(vec![None; 5], vec![5.0; 5], vec![]);
        slow.latency.edge_service_ms = 40.0;
        let mut fast = slow.clone();
        fast.latency = fast.latency.with_speedup(0.9);
        let ms = simulate(&slow).latency.mean();
        let mf = simulate(&fast).latency.mean();
        assert!(mf < ms - 20.0, "{ms} -> {mf}");
    }

    #[test]
    fn throughput_conservation() {
        // All generated arrivals within the horizon either complete or
        // remain in flight; completions ≈ Σλ · T within tolerance.
        let cfg = base(vec![Some(0); 4], vec![10.0; 4], vec![1000.0]);
        let out = simulate(&cfg);
        let expected = 4.0 * 10.0 * cfg.duration_s;
        let got = out.total() as f64;
        assert!((got - expected).abs() < 0.1 * expected, "{got} vs {expected}");
    }

    #[test]
    fn percentiles_track_distribution() {
        let cfg = base(vec![None; 10], vec![5.0; 10], vec![]);
        let out = simulate(&cfg);
        // Cloud path: RTT U(50,100) + 4 ms service -> p50 ≈ 79, p99 < 104.
        assert!((out.percentiles.p50() - 79.0).abs() < 5.0, "{}", out.percentiles.p50());
        assert!(out.percentiles.p50() < out.percentiles.p90());
        assert!(out.percentiles.p90() < out.percentiles.p99());
        assert!(out.percentiles.p99() <= 104.1, "{}", out.percentiles.p99());
    }

    #[test]
    fn reservoir_caps_sample_memory() {
        let mut cfg = base(vec![None; 10], vec![20.0; 10], vec![]);
        cfg.duration_s = 120.0; // ~24k completions
        let out = simulate(&cfg);
        assert!(out.total() > LATENCY_RESERVOIR_CAP as u64 * 2);
        assert_eq!(out.samples.len(), LATENCY_RESERVOIR_CAP);
        assert_eq!(out.samples.seen(), out.total());
        // The kept sample still reflects the distribution for Fig. 7.
        let kept_mean: f64 = out.samples.iter().sum::<f64>() / out.samples.len() as f64;
        assert!((kept_mean - out.latency.mean()).abs() < 2.0);
    }
}
