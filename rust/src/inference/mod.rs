//! Inference-serving plane: request routing (R1–R3), the latency model
//! (§V-C1 assumptions), the serving simulation behind Fig. 7/8, the
//! event-driven co-simulation that couples serving with training and the
//! orchestrator on one kernel timeline ([`cosim`]), and a real-execution
//! serving loop that drives the PJRT `predict` artifact through a
//! dynamic batcher.

pub mod cosim;
pub mod latency;
pub mod routing;
pub mod serving;
pub mod simulation;
pub mod trace;

pub use cosim::{CoSim, CoSimConfig, CoSimOutcome, ControlPlane, FaultEvent, TrainingSchedule};
pub use trace::{ArrivalModel, RateSegment, RateTrace};
pub use latency::LatencyModel;
pub use routing::{DeviceCtx, EdgeCtx, Route, RoutingPolicy};
pub use serving::{BatchingServer, ServeStats};
pub use simulation::{
    admission_bound, simulate, simulate_with_arrivals, ServingConfig, ServingOutcome,
};
