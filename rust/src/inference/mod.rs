//! Inference-serving plane: request routing (R1–R3), the latency model
//! (§V-C1 assumptions), the serving discrete-event simulation behind
//! Fig. 7/8, and a real-execution serving loop that drives the PJRT
//! `predict` artifact through a dynamic batcher.

pub mod latency;
pub mod routing;
pub mod serving;
pub mod simulation;

pub use latency::LatencyModel;
pub use routing::{DeviceCtx, EdgeCtx, Route, RoutingPolicy};
pub use serving::{BatchingServer, ServeStats};
pub use simulation::{simulate, ServingConfig, ServingOutcome};
