//! Latency model — the paper's measured assumptions (§V-C1):
//! "the latency for sending requests to the global server/cloud is
//! between 50 and 100 ms ... the latency cost to the local/edge servers
//! is much lower and estimated between 8 and 10 ms."
//!
//! Service times derive from per-node inference capacity (`r_j` req/s →
//! mean service 1/r_j) with an edge→cloud *speedup* knob for Fig. 8
//! ("a theoretical speedup of up to 95%"): cloud hardware completes an
//! inference `(1 - speedup)`× the edge service time.

use crate::util::rng::Rng;

/// All latency parameters, in milliseconds / requests-per-second.
#[derive(Debug, Clone)]
pub struct LatencyModel {
    /// Device→edge network RTT range (ms).
    pub edge_rtt_ms: (f64, f64),
    /// Any-node→cloud network RTT range (ms).
    pub cloud_rtt_ms: (f64, f64),
    /// Mean edge service time (ms) for one inference at a reference-
    /// capacity edge; actual edges scale by their capacity.
    pub edge_service_ms: f64,
    /// Cloud speedup fraction in [0, 0.95]: cloud service time =
    /// `edge_service_ms * (1 - speedup)`.
    pub speedup: f64,
    /// If true, service times are exponential (M/M/1-style); if false,
    /// deterministic. The paper's testbed serves a fixed GRU, so
    /// deterministic is the default; exponential is an ablation.
    pub stochastic_service: bool,
}

impl Default for LatencyModel {
    fn default() -> Self {
        LatencyModel {
            edge_rtt_ms: (8.0, 10.0),
            cloud_rtt_ms: (50.0, 100.0),
            edge_service_ms: 4.0,
            speedup: 0.0,
            stochastic_service: false,
        }
    }
}

impl LatencyModel {
    pub fn with_speedup(mut self, speedup: f64) -> Self {
        assert!((0.0..=0.95).contains(&speedup), "speedup out of range");
        self.speedup = speedup;
        self
    }

    /// One sampled device↔edge network round trip (ms).
    pub fn edge_rtt(&self, rng: &mut Rng) -> f64 {
        rng.uniform(self.edge_rtt_ms.0, self.edge_rtt_ms.1)
    }

    /// One sampled ↔cloud network round trip (ms).
    pub fn cloud_rtt(&self, rng: &mut Rng) -> f64 {
        rng.uniform(self.cloud_rtt_ms.0, self.cloud_rtt_ms.1)
    }

    /// Edge service time (ms). `capacity_scale` is
    /// `reference_capacity / r_j` so low-capacity edges serve slower.
    pub fn edge_service(&self, capacity_scale: f64, rng: &mut Rng) -> f64 {
        let mean = self.edge_service_ms * capacity_scale;
        if self.stochastic_service {
            rng.exponential(1.0 / mean.max(1e-9))
        } else {
            mean
        }
    }

    /// Cloud service time (ms) after applying the speedup.
    pub fn cloud_service(&self, rng: &mut Rng) -> f64 {
        let mean = self.edge_service_ms * (1.0 - self.speedup);
        if self.stochastic_service {
            rng.exponential(1.0 / mean.max(1e-9))
        } else {
            mean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rtt_ranges_match_paper() {
        let m = LatencyModel::default();
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let e = m.edge_rtt(&mut rng);
            assert!((8.0..10.0).contains(&e));
            let c = m.cloud_rtt(&mut rng);
            assert!((50.0..100.0).contains(&c));
        }
    }

    #[test]
    fn speedup_scales_cloud_service() {
        let mut rng = Rng::new(2);
        let base = LatencyModel::default().cloud_service(&mut rng);
        let fast = LatencyModel::default().with_speedup(0.95).cloud_service(&mut rng);
        assert!((base - 4.0).abs() < 1e-12);
        assert!((fast - 0.2).abs() < 1e-12);
    }

    #[test]
    fn capacity_scale_slows_weak_edges() {
        let m = LatencyModel::default();
        let mut rng = Rng::new(3);
        assert!(m.edge_service(2.0, &mut rng) > m.edge_service(1.0, &mut rng));
    }

    #[test]
    fn stochastic_service_mean() {
        let m = LatencyModel { stochastic_service: true, ..Default::default() };
        let mut rng = Rng::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| m.edge_service(1.0, &mut rng)).sum::<f64>() / n as f64;
        assert!((mean - 4.0).abs() < 0.1, "{mean}");
    }

    #[test]
    #[should_panic(expected = "speedup out of range")]
    fn speedup_validated() {
        LatencyModel::default().with_speedup(0.99);
    }
}
