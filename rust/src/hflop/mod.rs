//! The HFL Orchestration Problem (HFLOP) — §IV of the paper.
//!
//! An instance captures the joint training/inference orchestration input:
//! n FL devices, m candidate edge-aggregator locations, communication
//! costs (`c_d[i][j]` device↔edge, `c_e[j]` edge↔cloud), the number of
//! local aggregation rounds per global round `l`, per-device inference
//! request rates `lambda[i]`, per-edge inference processing capacities
//! `r[j]`, and the minimum FL participation `t_min` (constraint 6).
//!
//! The objective (Eq. 1) minimizes
//! `Σ_ij x_ij · c_d[i][j] · l + Σ_j y_j · c_e[j]`
//! subject to linking (2,3), capacity (4), single-assignment (5),
//! participation (6) and integrality (7).
//!
//! HFLOP generalizes the capacitated facility location problem with
//! unsplittable flows (NP-hard); see [`crate::solver`] for the exact
//! branch & bound and the heuristics.

pub mod sparse;

pub use sparse::SparseInstance;

use crate::core::{Capacity, DenseMatrix, Workload};
use crate::topology::Topology;
use crate::util::rng::Rng;

/// Build-time bookkeeping carried by an [`Instance`]: the validation
/// flag set by [`InstanceBuilder::build`] (so `solve()` can skip the
/// full O(n·m) re-scan on every call) and the lazily built sorted-λ
/// prefix table behind [`Instance::capacity_feasible`]. Hand-written
/// instance literals get `Default::default()` here, which keeps the
/// hard validation error on the solve path for them.
#[derive(Debug, Clone, Default)]
pub struct InstanceMeta {
    /// True iff `validate()` passed at build time. Mutating a built
    /// instance afterwards is on the caller; `solve()` still
    /// cross-checks under `debug_assertions`.
    pub validated: bool,
    /// Ascending prefix sums of sorted λ, built on the first
    /// capacity-short feasibility query (λ is immutable by contract).
    feas_prefix: std::sync::OnceLock<Vec<f64>>,
}

impl InstanceMeta {
    /// Meta carrying a set `validated` flag, for instances assembled
    /// field-by-field from an already-validated source (the sharded
    /// solver's per-region sub-instances). The caller vouches for
    /// validity; `solve()` still cross-checks under `debug_assertions`.
    pub fn prevalidated() -> InstanceMeta {
        InstanceMeta { validated: true, feas_prefix: std::sync::OnceLock::new() }
    }
}

/// One HFLOP instance. Immutable once built; solvers borrow it.
#[derive(Debug, Clone)]
pub struct Instance {
    /// Device-to-edge communication cost, `n x m` (row-major).
    pub c_d: DenseMatrix,
    /// Edge-to-cloud communication cost, `m`.
    pub c_e: Vec<f64>,
    /// Per-device inference request rate λ_i, `n`.
    pub lambda: Workload,
    /// Per-edge inference processing capacity r_j, `m`.
    pub r: Capacity,
    /// Local aggregation rounds per global round (the `l` in Eq. 1).
    pub l: f64,
    /// Minimum number of participating devices (constraint 6).
    pub t_min: usize,
    /// Validation/caching state (see [`InstanceMeta`]).
    pub meta: InstanceMeta,
}

impl Instance {
    pub fn n(&self) -> usize {
        self.c_d.rows()
    }

    pub fn m(&self) -> usize {
        self.c_e.len()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        let (n, m) = (self.n(), self.m());
        anyhow::ensure!(n > 0 && m > 0, "empty instance");
        anyhow::ensure!(self.t_min <= n, "t_min {} > n {}", self.t_min, n);
        anyhow::ensure!(self.l.is_finite() && self.l > 0.0, "l must be positive and finite");
        anyhow::ensure!(self.lambda.len() == n, "lambda len mismatch");
        anyhow::ensure!(self.r.len() == m, "r len mismatch");
        anyhow::ensure!(self.c_d.cols() == m, "c_d cols != m");
        for row in &self.c_d {
            anyhow::ensure!(row.iter().all(|&c| c >= 0.0 && c.is_finite()), "bad c_d");
        }
        anyhow::ensure!(self.c_e.iter().all(|&c| c >= 0.0 && c.is_finite()), "bad c_e");
        anyhow::ensure!(self.lambda.iter().all(|&v| v >= 0.0 && v.is_finite()), "bad lambda");
        // NaN must be rejected explicitly: capacities may legitimately be
        // +inf (uncapacitated variant), so `is_finite` is too strict, but
        // a NaN capacity would poison every residual comparison.
        anyhow::ensure!(self.r.iter().all(|&v| !v.is_nan() && v >= 0.0), "bad r");
        Ok(())
    }

    /// Quick necessary feasibility check: can `t_min` devices fit at all?
    /// (Sufficient only when every device can reach every edge, which holds
    /// for all our generators; the solvers detect residual infeasibility.)
    ///
    /// Allocation-free on the hot path: the common all-fits case is a
    /// plain O(n) sum, and the capacity-short case binary-searches a
    /// sorted-λ prefix table built once per instance (`OnceLock`) —
    /// `solve()` used to clone and fully sort λ on every call.
    pub fn capacity_feasible(&self) -> bool {
        let total: f64 = self.r.iter().sum();
        if total.is_infinite() {
            return true;
        }
        let lambda_total: f64 = self.lambda.iter().sum();
        if lambda_total <= total + 1e-9 {
            // Every device fits; the greedy pack would count all n.
            return self.lambda.len() >= self.t_min;
        }
        // Capacity-short: pack smallest lambdas into total capacity.
        // NaN-safe total order (validate rejects NaN, but never trust a
        // sort to it). Prefix sums accumulate in the same ascending
        // order as the old per-solve greedy loop, so the verdict is
        // bit-for-bit unchanged.
        let prefix = self.meta.feas_prefix.get_or_init(|| {
            let mut lam = self.lambda.to_vec();
            lam.sort_by(f64::total_cmp);
            let mut acc = 0.0;
            for v in lam.iter_mut() {
                acc += *v;
                *v = acc;
            }
            lam
        });
        debug_assert_eq!(
            prefix.len(),
            self.lambda.len(),
            "lambda mutated after the feasibility prefix table was built"
        );
        // λ ≥ 0 ⇒ prefix is nondecreasing, so the greedy stop point is a
        // partition point.
        let fit = prefix.partition_point(|&p| p <= total + 1e-9);
        fit >= self.t_min
    }
}

/// Builders for the instance families used across the experiments.
pub struct InstanceBuilder {
    inst: Instance,
}

impl InstanceBuilder {
    /// From an explicit topology (geo or unit-cost).
    pub fn from_topology(topo: &Topology, l: f64, t_min: usize) -> InstanceBuilder {
        InstanceBuilder {
            inst: Instance {
                c_d: topo.c_d.clone(),
                c_e: topo.c_e.clone(),
                lambda: topo.devices.iter().map(|d| d.lambda).collect(),
                r: topo.edges.iter().map(|e| e.capacity).collect(),
                l,
                t_min,
                meta: InstanceMeta::default(),
            },
        }
    }

    /// The paper's §V-D cost-savings setup: one zero-cost edge per device,
    /// unit cost elsewhere, unit edge-cloud cost, uniform random workloads
    /// and capacities, all devices forced to participate (T = n).
    pub fn unit_cost(n: usize, m: usize, seed: u64) -> InstanceBuilder {
        // Default headroom 2.0: aggregate capacity comfortably above
        // aggregate load (the paper notes its configurations "favor the
        // uncapacitated version").
        Self::unit_cost_with_headroom(n, m, seed, 2.0)
    }

    /// Like [`unit_cost`](Self::unit_cost) with explicit capacity
    /// headroom: `r_j ~ U(0.5, 1.5) · headroom · Σλ / m`. Headroom near
    /// 1.0 makes capacity genuinely binding (forces devices off their
    /// zero-cost edges, separating HFLOP from its uncapacitated bound).
    pub fn unit_cost_with_headroom(
        n: usize,
        m: usize,
        seed: u64,
        headroom: f64,
    ) -> InstanceBuilder {
        let mut rng = Rng::new(seed);
        let mut c_d = DenseMatrix::zeros(n, m);
        for i in 0..n {
            let free = rng.below(m);
            for (j, c) in c_d.row_mut(i).iter_mut().enumerate() {
                *c = if j == free { 0.0 } else { 1.0 };
            }
        }
        // Uniform random workloads and capacities (§V-D). Capacity draws
        // are normalized so the aggregate is exactly `headroom · Σλ`,
        // keeping every generated instance feasible while preserving the
        // per-edge spread.
        let lambda: Workload = (0..n).map(|_| rng.uniform(0.5, 2.0)).collect();
        let total_lambda = lambda.total();
        let draws: Vec<f64> = (0..m).map(|_| rng.uniform(0.5, 1.5)).collect();
        let draw_sum: f64 = draws.iter().sum();
        let r = draws
            .iter()
            .map(|u| u * headroom * total_lambda / draw_sum)
            .collect();
        InstanceBuilder {
            inst: Instance {
                c_d,
                c_e: vec![1.0; m],
                lambda,
                r,
                l: 2.0, // paper: one global round every two local rounds
                t_min: n,
                meta: InstanceMeta::default(),
            },
        }
    }

    /// Fully random instance (Fig. 2 solver-scaling benchmarks).
    pub fn random(n: usize, m: usize, seed: u64) -> InstanceBuilder {
        let mut rng = Rng::new(seed);
        let c_d = DenseMatrix::from_fn(n, m, |_, _| rng.uniform(0.0, 10.0));
        let c_e = (0..m).map(|_| rng.uniform(5.0, 50.0)).collect();
        let lambda: Workload = (0..n).map(|_| rng.uniform(0.5, 2.0)).collect();
        let total = lambda.total();
        let r = (0..m)
            .map(|_| rng.uniform(0.8, 1.6) * 1.5 * total / m as f64)
            .collect();
        InstanceBuilder {
            inst: Instance { c_d, c_e, lambda, r, l: 2.0, t_min: n, meta: InstanceMeta::default() },
        }
    }

    pub fn l(mut self, l: f64) -> Self {
        self.inst.l = l;
        self
    }

    pub fn t_min(mut self, t: usize) -> Self {
        self.inst.t_min = t;
        self
    }

    /// Replace capacities with `+inf` — the *uncapacitated* HFLOP variant
    /// used as the communication-cost lower bound in Fig. 9.
    pub fn uncapacitated(mut self) -> Self {
        for r in self.inst.r.iter_mut() {
            *r = f64::INFINITY;
        }
        self
    }

    /// Validate once, here — `solve()` trusts the flag and only
    /// re-validates under `debug_assertions` (hand-built literals keep
    /// the hard error on the solve path; their flag stays false).
    pub fn build(self) -> Instance {
        let mut inst = self.inst;
        inst.validate().expect("invalid instance");
        inst.meta.validated = true;
        inst
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::unit_cost_topology;

    #[test]
    fn unit_cost_builder_shapes() {
        let inst = InstanceBuilder::unit_cost(30, 5, 1).build();
        assert_eq!(inst.n(), 30);
        assert_eq!(inst.m(), 5);
        assert_eq!(inst.t_min, 30);
        assert_eq!(inst.l, 2.0);
        for row in &inst.c_d {
            assert_eq!(row.iter().filter(|&&c| c == 0.0).count(), 1);
        }
    }

    #[test]
    fn unit_cost_capacity_exceeds_load() {
        let inst = InstanceBuilder::unit_cost(100, 10, 2).build();
        let load: f64 = inst.lambda.iter().sum();
        let cap: f64 = inst.r.iter().sum();
        assert!(cap > load, "cap {cap} load {load}");
        assert!(inst.capacity_feasible());
    }

    #[test]
    fn from_topology_copies_fields() {
        let topo = unit_cost_topology(10, 3, (0.5, 2.0), (5.0, 15.0), 3);
        let inst = InstanceBuilder::from_topology(&topo, 4.0, 8).build();
        assert_eq!(inst.l, 4.0);
        assert_eq!(inst.t_min, 8);
        assert_eq!(inst.c_d, topo.c_d);
    }

    #[test]
    fn uncapacitated_sets_infinite_r() {
        let inst = InstanceBuilder::unit_cost(10, 3, 4).uncapacitated().build();
        assert!(inst.r.iter().all(|r| r.is_infinite()));
        assert!(inst.capacity_feasible());
    }

    #[test]
    fn validate_rejects_bad_t_min() {
        let mut inst = InstanceBuilder::unit_cost(5, 2, 5).build();
        inst.t_min = 6;
        assert!(inst.validate().is_err());
    }

    #[test]
    fn capacity_feasible_detects_overload() {
        let mut inst = InstanceBuilder::unit_cost(10, 2, 6).build();
        for r in inst.r.iter_mut() {
            *r = 0.1;
        }
        assert!(!inst.capacity_feasible());
    }

    #[test]
    fn capacity_verdict_unchanged_by_prefix_cache() {
        // Regression for the cached-prefix rewrite: the verdict on the
        // existing feasible/overload fixtures must match the old
        // clone-and-sort greedy, including repeated queries (the cache
        // is built at most once) and post-build capacity mutation.
        let feasible = InstanceBuilder::unit_cost(100, 10, 2).build();
        assert!(feasible.capacity_feasible());
        assert!(feasible.capacity_feasible(), "second query hits the fast path");

        let mut overload = InstanceBuilder::unit_cost(10, 2, 6).build();
        for r in overload.r.iter_mut() {
            *r = 0.1;
        }
        assert!(!overload.capacity_feasible());
        assert!(!overload.capacity_feasible(), "second query hits the prefix cache");

        // Reference greedy (the pre-cache implementation), cross-checked
        // over a spread of partially-overloaded instances.
        for seed in 0..20u64 {
            let mut inst = InstanceBuilder::unit_cost(40, 4, seed).t_min(30).build();
            let squeeze = 0.05 + 0.05 * seed as f64;
            for r in inst.r.iter_mut() {
                *r *= squeeze;
            }
            let total: f64 = inst.r.iter().sum();
            let mut lam = inst.lambda.to_vec();
            lam.sort_by(f64::total_cmp);
            let mut used = 0.0;
            let mut fit = 0usize;
            for v in lam {
                if used + v <= total + 1e-9 {
                    used += v;
                    fit += 1;
                } else {
                    break;
                }
            }
            assert_eq!(inst.capacity_feasible(), fit >= inst.t_min, "seed {seed}");
        }
    }

    #[test]
    fn build_marks_validated_literals_do_not() {
        let built = InstanceBuilder::unit_cost(5, 2, 1).build();
        assert!(built.meta.validated);
        let literal = Instance {
            c_d: vec![vec![0.0, 1.0]].into(),
            c_e: vec![1.0, 1.0],
            lambda: vec![1.0].into(),
            r: vec![2.0, 2.0].into(),
            l: 1.0,
            t_min: 1,
            meta: InstanceMeta::default(),
        };
        assert!(!literal.meta.validated);
        literal.validate().unwrap();
    }

    #[test]
    fn random_builder_valid() {
        let inst = InstanceBuilder::random(25, 4, 7).t_min(20).build();
        inst.validate().unwrap();
        assert_eq!(inst.t_min, 20);
    }

    #[test]
    fn deterministic_by_seed() {
        let a = InstanceBuilder::unit_cost(20, 4, 9).build();
        let b = InstanceBuilder::unit_cost(20, 4, 9).build();
        assert_eq!(a.c_d, b.c_d);
        assert_eq!(a.lambda, b.lambda);
    }
}
