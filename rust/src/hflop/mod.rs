//! The HFL Orchestration Problem (HFLOP) — §IV of the paper.
//!
//! An instance captures the joint training/inference orchestration input:
//! n FL devices, m candidate edge-aggregator locations, communication
//! costs (`c_d[i][j]` device↔edge, `c_e[j]` edge↔cloud), the number of
//! local aggregation rounds per global round `l`, per-device inference
//! request rates `lambda[i]`, per-edge inference processing capacities
//! `r[j]`, and the minimum FL participation `t_min` (constraint 6).
//!
//! The objective (Eq. 1) minimizes
//! `Σ_ij x_ij · c_d[i][j] · l + Σ_j y_j · c_e[j]`
//! subject to linking (2,3), capacity (4), single-assignment (5),
//! participation (6) and integrality (7).
//!
//! HFLOP generalizes the capacitated facility location problem with
//! unsplittable flows (NP-hard); see [`crate::solver`] for the exact
//! branch & bound and the heuristics.

use crate::core::{Capacity, DenseMatrix, Workload};
use crate::topology::Topology;
use crate::util::rng::Rng;

/// One HFLOP instance. Immutable once built; solvers borrow it.
#[derive(Debug, Clone)]
pub struct Instance {
    /// Device-to-edge communication cost, `n x m` (row-major).
    pub c_d: DenseMatrix,
    /// Edge-to-cloud communication cost, `m`.
    pub c_e: Vec<f64>,
    /// Per-device inference request rate λ_i, `n`.
    pub lambda: Workload,
    /// Per-edge inference processing capacity r_j, `m`.
    pub r: Capacity,
    /// Local aggregation rounds per global round (the `l` in Eq. 1).
    pub l: f64,
    /// Minimum number of participating devices (constraint 6).
    pub t_min: usize,
}

impl Instance {
    pub fn n(&self) -> usize {
        self.c_d.rows()
    }

    pub fn m(&self) -> usize {
        self.c_e.len()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        let (n, m) = (self.n(), self.m());
        anyhow::ensure!(n > 0 && m > 0, "empty instance");
        anyhow::ensure!(self.t_min <= n, "t_min {} > n {}", self.t_min, n);
        anyhow::ensure!(self.l.is_finite() && self.l > 0.0, "l must be positive and finite");
        anyhow::ensure!(self.lambda.len() == n, "lambda len mismatch");
        anyhow::ensure!(self.r.len() == m, "r len mismatch");
        anyhow::ensure!(self.c_d.cols() == m, "c_d cols != m");
        for row in &self.c_d {
            anyhow::ensure!(row.iter().all(|&c| c >= 0.0 && c.is_finite()), "bad c_d");
        }
        anyhow::ensure!(self.c_e.iter().all(|&c| c >= 0.0 && c.is_finite()), "bad c_e");
        anyhow::ensure!(self.lambda.iter().all(|&v| v >= 0.0 && v.is_finite()), "bad lambda");
        // NaN must be rejected explicitly: capacities may legitimately be
        // +inf (uncapacitated variant), so `is_finite` is too strict, but
        // a NaN capacity would poison every residual comparison.
        anyhow::ensure!(self.r.iter().all(|&v| !v.is_nan() && v >= 0.0), "bad r");
        Ok(())
    }

    /// Quick necessary feasibility check: can `t_min` devices fit at all?
    /// (Sufficient only when every device can reach every edge, which holds
    /// for all our generators; the solvers detect residual infeasibility.)
    pub fn capacity_feasible(&self) -> bool {
        let total: f64 = self.r.iter().sum();
        if total.is_infinite() {
            return true;
        }
        // Greedy: smallest lambdas packed into total capacity. NaN-safe
        // total order (validate rejects NaN, but never trust a sort to it).
        let mut lam = self.lambda.to_vec();
        lam.sort_by(f64::total_cmp);
        let mut used = 0.0;
        let mut fit = 0usize;
        for v in lam {
            if used + v <= total + 1e-9 {
                used += v;
                fit += 1;
            } else {
                break;
            }
        }
        fit >= self.t_min
    }
}

/// Builders for the instance families used across the experiments.
pub struct InstanceBuilder {
    inst: Instance,
}

impl InstanceBuilder {
    /// From an explicit topology (geo or unit-cost).
    pub fn from_topology(topo: &Topology, l: f64, t_min: usize) -> InstanceBuilder {
        InstanceBuilder {
            inst: Instance {
                c_d: topo.c_d.clone(),
                c_e: topo.c_e.clone(),
                lambda: topo.devices.iter().map(|d| d.lambda).collect(),
                r: topo.edges.iter().map(|e| e.capacity).collect(),
                l,
                t_min,
            },
        }
    }

    /// The paper's §V-D cost-savings setup: one zero-cost edge per device,
    /// unit cost elsewhere, unit edge-cloud cost, uniform random workloads
    /// and capacities, all devices forced to participate (T = n).
    pub fn unit_cost(n: usize, m: usize, seed: u64) -> InstanceBuilder {
        // Default headroom 2.0: aggregate capacity comfortably above
        // aggregate load (the paper notes its configurations "favor the
        // uncapacitated version").
        Self::unit_cost_with_headroom(n, m, seed, 2.0)
    }

    /// Like [`unit_cost`](Self::unit_cost) with explicit capacity
    /// headroom: `r_j ~ U(0.5, 1.5) · headroom · Σλ / m`. Headroom near
    /// 1.0 makes capacity genuinely binding (forces devices off their
    /// zero-cost edges, separating HFLOP from its uncapacitated bound).
    pub fn unit_cost_with_headroom(
        n: usize,
        m: usize,
        seed: u64,
        headroom: f64,
    ) -> InstanceBuilder {
        let mut rng = Rng::new(seed);
        let mut c_d = DenseMatrix::zeros(n, m);
        for i in 0..n {
            let free = rng.below(m);
            for (j, c) in c_d.row_mut(i).iter_mut().enumerate() {
                *c = if j == free { 0.0 } else { 1.0 };
            }
        }
        // Uniform random workloads and capacities (§V-D). Capacity draws
        // are normalized so the aggregate is exactly `headroom · Σλ`,
        // keeping every generated instance feasible while preserving the
        // per-edge spread.
        let lambda: Workload = (0..n).map(|_| rng.uniform(0.5, 2.0)).collect();
        let total_lambda = lambda.total();
        let draws: Vec<f64> = (0..m).map(|_| rng.uniform(0.5, 1.5)).collect();
        let draw_sum: f64 = draws.iter().sum();
        let r = draws
            .iter()
            .map(|u| u * headroom * total_lambda / draw_sum)
            .collect();
        InstanceBuilder {
            inst: Instance {
                c_d,
                c_e: vec![1.0; m],
                lambda,
                r,
                l: 2.0, // paper: one global round every two local rounds
                t_min: n,
            },
        }
    }

    /// Fully random instance (Fig. 2 solver-scaling benchmarks).
    pub fn random(n: usize, m: usize, seed: u64) -> InstanceBuilder {
        let mut rng = Rng::new(seed);
        let c_d = DenseMatrix::from_fn(n, m, |_, _| rng.uniform(0.0, 10.0));
        let c_e = (0..m).map(|_| rng.uniform(5.0, 50.0)).collect();
        let lambda: Workload = (0..n).map(|_| rng.uniform(0.5, 2.0)).collect();
        let total = lambda.total();
        let r = (0..m)
            .map(|_| rng.uniform(0.8, 1.6) * 1.5 * total / m as f64)
            .collect();
        InstanceBuilder {
            inst: Instance { c_d, c_e, lambda, r, l: 2.0, t_min: n },
        }
    }

    pub fn l(mut self, l: f64) -> Self {
        self.inst.l = l;
        self
    }

    pub fn t_min(mut self, t: usize) -> Self {
        self.inst.t_min = t;
        self
    }

    /// Replace capacities with `+inf` — the *uncapacitated* HFLOP variant
    /// used as the communication-cost lower bound in Fig. 9.
    pub fn uncapacitated(mut self) -> Self {
        for r in self.inst.r.iter_mut() {
            *r = f64::INFINITY;
        }
        self
    }

    pub fn build(self) -> Instance {
        self.inst.validate().expect("invalid instance");
        self.inst
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::unit_cost_topology;

    #[test]
    fn unit_cost_builder_shapes() {
        let inst = InstanceBuilder::unit_cost(30, 5, 1).build();
        assert_eq!(inst.n(), 30);
        assert_eq!(inst.m(), 5);
        assert_eq!(inst.t_min, 30);
        assert_eq!(inst.l, 2.0);
        for row in &inst.c_d {
            assert_eq!(row.iter().filter(|&&c| c == 0.0).count(), 1);
        }
    }

    #[test]
    fn unit_cost_capacity_exceeds_load() {
        let inst = InstanceBuilder::unit_cost(100, 10, 2).build();
        let load: f64 = inst.lambda.iter().sum();
        let cap: f64 = inst.r.iter().sum();
        assert!(cap > load, "cap {cap} load {load}");
        assert!(inst.capacity_feasible());
    }

    #[test]
    fn from_topology_copies_fields() {
        let topo = unit_cost_topology(10, 3, (0.5, 2.0), (5.0, 15.0), 3);
        let inst = InstanceBuilder::from_topology(&topo, 4.0, 8).build();
        assert_eq!(inst.l, 4.0);
        assert_eq!(inst.t_min, 8);
        assert_eq!(inst.c_d, topo.c_d);
    }

    #[test]
    fn uncapacitated_sets_infinite_r() {
        let inst = InstanceBuilder::unit_cost(10, 3, 4).uncapacitated().build();
        assert!(inst.r.iter().all(|r| r.is_infinite()));
        assert!(inst.capacity_feasible());
    }

    #[test]
    fn validate_rejects_bad_t_min() {
        let mut inst = InstanceBuilder::unit_cost(5, 2, 5).build();
        inst.t_min = 6;
        assert!(inst.validate().is_err());
    }

    #[test]
    fn capacity_feasible_detects_overload() {
        let mut inst = InstanceBuilder::unit_cost(10, 2, 6).build();
        for r in inst.r.iter_mut() {
            *r = 0.1;
        }
        assert!(!inst.capacity_feasible());
    }

    #[test]
    fn random_builder_valid() {
        let inst = InstanceBuilder::random(25, 4, 7).t_min(20).build();
        inst.validate().unwrap();
        assert_eq!(inst.t_min, 20);
    }

    #[test]
    fn deterministic_by_seed() {
        let a = InstanceBuilder::unit_cost(20, 4, 9).build();
        let b = InstanceBuilder::unit_cost(20, 4, 9).build();
        assert_eq!(a.c_d, b.c_d);
        assert_eq!(a.lambda, b.lambda);
    }
}
