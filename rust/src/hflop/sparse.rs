//! Candidate-sparse HFLOP instances for million-device solves.
//!
//! A dense [`Instance`](super::Instance) materializes the full `n×m`
//! cost matrix — 4 GB of `f64` at n=1M, m=512 — when almost all of it is
//! irrelevant: a device is only ever competitively served by its few
//! nearest edge hosts. [`SparseInstance`] keeps device and edge
//! *positions* plus a top-k candidate list per device, and computes any
//! pair cost on demand from an implicit geographic cost function, so
//! memory is O(n·k + m) instead of O(n·m). The sharded solver
//! (`solver::sharded`) runs entirely on this representation; small
//! instances can still be materialized with [`SparseInstance::to_dense`]
//! for the exact/heuristic dense paths and for feasibility checks in
//! tests.
//!
//! The cost function matches the geo topology builder's convention:
//! distance in km (equirectangular projection about the edge-set mean
//! latitude — exact enough at metro scale and ~20× cheaper than a
//! haversine), zero within `free_radius_km`.

use crate::core::{Capacity, DenseMatrix, Workload};
use crate::hflop::{Instance, InstanceMeta};
use crate::topology::geo::GeoPoint;
use crate::util::pool;
use crate::util::rng::Rng;

/// km per degree of latitude (2πR/360, R = 6371 km) — keeps the implicit
/// cost function consistent with `haversine_km` at small separations.
pub const KM_PER_DEG: f64 = 6371.0 * std::f64::consts::PI / 180.0;

/// Zero-cost radius, same convention as the geo topology builder.
pub const FREE_RADIUS_KM: f64 = 3.0;

/// Refusal threshold for [`SparseInstance::to_dense`]: materializing
/// more x-variables than this is almost certainly a bug (the 1M×512
/// target would allocate 4 GB).
pub const DENSE_MATERIALIZE_MAX: usize = 64_000_000;

/// Equirectangular projection fixed at a reference latitude; converts
/// lat/lon degrees to km so pair distances are two subs, two muls and a
/// sqrt.
#[derive(Debug, Clone, Copy)]
pub struct Proj {
    cos_lat: f64,
}

impl Proj {
    /// Reference the mean edge latitude (deterministic: summed in edge
    /// order).
    pub fn for_edges(edges: &[GeoPoint]) -> Proj {
        assert!(!edges.is_empty(), "projection over empty edge set");
        let mean_lat = edges.iter().map(|p| p.lat).sum::<f64>() / edges.len() as f64;
        Proj { cos_lat: mean_lat.to_radians().cos() }
    }

    /// Project to (x, y) km.
    pub fn xy(&self, p: GeoPoint) -> (f64, f64) {
        (p.lon * self.cos_lat * KM_PER_DEG, p.lat * KM_PER_DEG)
    }

    /// Distance in km between two points.
    pub fn dist_km(&self, a: GeoPoint, b: GeoPoint) -> f64 {
        let dx = (a.lon - b.lon) * self.cos_lat * KM_PER_DEG;
        let dy = (a.lat - b.lat) * KM_PER_DEG;
        (dx * dx + dy * dy).sqrt()
    }
}

/// Candidate ordering: distance first, edge id as the tiebreak, so the
/// per-device top-k is a unique, total-order-determined set.
fn by_dist_then_id(a: &(f64, u32), b: &(f64, u32)) -> std::cmp::Ordering {
    a.0.total_cmp(&b.0).then(a.1.cmp(&b.1))
}

/// A candidate-sparse HFLOP instance: Eq. 1–7 over an implicit geo cost
/// function, with per-device top-k candidate edge lists instead of a
/// dense `c_d`.
#[derive(Debug, Clone)]
pub struct SparseInstance {
    pub device_pos: Vec<GeoPoint>,
    pub edge_pos: Vec<GeoPoint>,
    /// Edge-to-cloud communication cost, `m`.
    pub c_e: Vec<f64>,
    /// Per-device inference request rate λ_i, `n`.
    pub lambda: Workload,
    /// Per-edge inference processing capacity r_j, `m`.
    pub r: Capacity,
    /// Local aggregation rounds per global round (the `l` in Eq. 1).
    pub l: f64,
    /// Minimum number of participating devices (constraint 6).
    pub t_min: usize,
    /// Zero-cost radius of the implicit cost function, km.
    pub free_radius_km: f64,
    /// Candidate edges per device (clamped to m at build).
    pub cand_k: usize,
    /// Flattened candidate lists, `n·cand_k`, cost-ascending per device
    /// (ties broken by edge id, so the layout is a pure function of the
    /// geometry).
    pub cand_edges: Vec<u32>,
    /// Costs aligned with `cand_edges`.
    pub cand_costs: Vec<f64>,
}

impl SparseInstance {
    pub fn n(&self) -> usize {
        self.device_pos.len()
    }

    pub fn m(&self) -> usize {
        self.edge_pos.len()
    }

    /// The projection the candidate lists were built under. O(m); hoist
    /// out of hot loops.
    pub fn proj(&self) -> Proj {
        Proj::for_edges(&self.edge_pos)
    }

    /// Implicit `c_d[i][j]`, defined for *every* pair — the candidate
    /// list only bounds what is materialized, not what is reachable.
    pub fn pair_cost(&self, pr: &Proj, i: usize, j: usize) -> f64 {
        let d = pr.dist_km(self.device_pos[i], self.edge_pos[j]);
        if d <= self.free_radius_km { 0.0 } else { d }
    }

    /// Device `i`'s candidate (edge, cost) pairs, cost-ascending.
    pub fn candidates(&self, i: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let lo = i * self.cand_k;
        self.cand_edges[lo..lo + self.cand_k]
            .iter()
            .zip(&self.cand_costs[lo..lo + self.cand_k])
            .map(|(&j, &c)| (j as usize, c))
    }

    /// Bytes held by the candidate structure (the part that replaces the
    /// dense matrix).
    pub fn candidate_bytes(&self) -> usize {
        self.cand_edges.len() * std::mem::size_of::<u32>()
            + self.cand_costs.len() * std::mem::size_of::<f64>()
    }

    /// Bytes a dense `c_d` for the same shape would take.
    pub fn dense_equiv_bytes(&self) -> usize {
        self.n() * self.m() * std::mem::size_of::<f64>()
    }

    /// Shape/value sanity (O(n + m); no n·m scan exists to run).
    pub fn validate(&self) -> anyhow::Result<()> {
        let (n, m) = (self.n(), self.m());
        anyhow::ensure!(n > 0 && m > 0, "empty instance");
        anyhow::ensure!(self.t_min <= n, "t_min {} > n {}", self.t_min, n);
        anyhow::ensure!(self.l.is_finite() && self.l > 0.0, "l must be positive and finite");
        anyhow::ensure!(self.lambda.len() == n, "lambda len mismatch");
        anyhow::ensure!(self.r.len() == m, "r len mismatch");
        anyhow::ensure!(self.c_e.len() == m, "c_e len mismatch");
        anyhow::ensure!(self.cand_k >= 1 && self.cand_k <= m, "cand_k out of range");
        anyhow::ensure!(self.cand_edges.len() == n * self.cand_k, "cand_edges len mismatch");
        anyhow::ensure!(self.cand_costs.len() == n * self.cand_k, "cand_costs len mismatch");
        anyhow::ensure!(self.c_e.iter().all(|&c| c >= 0.0 && c.is_finite()), "bad c_e");
        anyhow::ensure!(self.lambda.iter().all(|&v| v >= 0.0 && v.is_finite()), "bad lambda");
        anyhow::ensure!(self.r.iter().all(|&v| !v.is_nan() && v >= 0.0), "bad r");
        anyhow::ensure!(self.cand_edges.iter().all(|&j| (j as usize) < m), "bad candidate edge");
        Ok(())
    }

    /// Build the candidate lists from positions. Deterministic for any
    /// worker count: each chunk of devices is a fixed index range, and
    /// the per-device top-k under the (cost, edge id) total order is
    /// unique.
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        device_pos: Vec<GeoPoint>,
        edge_pos: Vec<GeoPoint>,
        lambda: Workload,
        r: Capacity,
        c_e: Vec<f64>,
        l: f64,
        t_min: usize,
        cand_k: usize,
        workers: usize,
    ) -> anyhow::Result<SparseInstance> {
        let (n, m) = (device_pos.len(), edge_pos.len());
        anyhow::ensure!(n > 0 && m > 0, "empty instance");
        let cand_k = cand_k.clamp(1, m);
        let pr = Proj::for_edges(&edge_pos);
        let exy: Vec<(f64, f64)> = edge_pos.iter().map(|&p| pr.xy(p)).collect();
        let free_radius_km = FREE_RADIUS_KM;

        let workers = if workers == 0 {
            pool::default_workers()
        } else {
            workers
        };
        let pairs: Vec<(u32, f64)> = pool::scoped_chunk_map(workers, n, 4096, |range| {
            let mut out = Vec::with_capacity(range.len() * cand_k);
            let mut scratch: Vec<(f64, u32)> = Vec::with_capacity(m);
            for i in range {
                let (px, py) = pr.xy(device_pos[i]);
                scratch.clear();
                for (j, &(ex, ey)) in exy.iter().enumerate() {
                    let (dx, dy) = (px - ex, py - ey);
                    scratch.push(((dx * dx + dy * dy).sqrt(), j as u32));
                }
                if cand_k < m {
                    scratch.select_nth_unstable_by(cand_k - 1, by_dist_then_id);
                    scratch.truncate(cand_k);
                }
                scratch.sort_by(by_dist_then_id);
                for &(d, j) in &scratch {
                    out.push((j, if d <= free_radius_km { 0.0 } else { d }));
                }
            }
            out
        });
        let mut cand_edges = Vec::with_capacity(pairs.len());
        let mut cand_costs = Vec::with_capacity(pairs.len());
        for (j, c) in pairs {
            cand_edges.push(j);
            cand_costs.push(c);
        }
        let inst = SparseInstance {
            device_pos,
            edge_pos,
            c_e,
            lambda,
            r,
            l,
            t_min,
            free_radius_km,
            cand_k,
            cand_edges,
            cand_costs,
        };
        inst.validate()?;
        Ok(inst)
    }

    /// Synthetic metro-scale instance family for the scaling benchmarks
    /// and the sharded-solver tests: `m` edge sites uniform over a bbox
    /// whose area grows with m (constant edge density), each device
    /// Gaussian-scattered (σ = 2 km) around a uniformly chosen anchor
    /// edge. Capacities are sized per edge from the anchored demand with
    /// 1.6× headroom, so instances stay regionally — not just globally —
    /// feasible. Deterministic in `seed` alone (the candidate build uses
    /// no RNG, so worker count cannot leak in).
    pub fn clustered(n: usize, m: usize, seed: u64, cand_k: usize) -> SparseInstance {
        assert!(n > 0 && m > 0);
        let mut rng = Rng::new(seed);
        // Scale the LA bbox so edge density stays ~8 edges per base box.
        let scale = ((m as f64) / 8.0).sqrt().max(1.0);
        let (lat0, lon0) = (34.0, -118.5);
        let (dlat, dlon) = (0.2 * scale, 0.3 * scale);
        let edge_pos: Vec<GeoPoint> = (0..m)
            .map(|_| GeoPoint {
                lat: lat0 + rng.f64() * dlat,
                lon: lon0 + rng.f64() * dlon,
            })
            .collect();
        let sigma_deg = 2.0 / KM_PER_DEG;
        let mut device_pos = Vec::with_capacity(n);
        let mut anchor_load = vec![0.0f64; m];
        let mut lambda = Vec::with_capacity(n);
        for _ in 0..n {
            let a = rng.below(m);
            let p = GeoPoint {
                lat: edge_pos[a].lat + rng.normal() * sigma_deg,
                lon: edge_pos[a].lon + rng.normal() * sigma_deg,
            };
            let lam = rng.uniform(0.5, 2.0);
            anchor_load[a] += lam;
            lambda.push(lam);
            device_pos.push(p);
        }
        let r: Capacity = anchor_load.iter().map(|&load| 1.6 * load + 1.0).collect();
        let c_e: Vec<f64> = (0..m).map(|_| rng.uniform(15.0, 35.0)).collect();
        SparseInstance::build(device_pos, edge_pos, lambda.into(), r, c_e, 2.0, n, cand_k, 0)
            .expect("clustered generator produces valid instances")
    }

    /// Materialize the dense equivalent (tests, and the small-instance
    /// fast path in `solver::solve_sparse`). Panics above
    /// [`DENSE_MATERIALIZE_MAX`] x-variables — that is the situation the
    /// sparse representation exists to avoid.
    pub fn to_dense(&self) -> Instance {
        let (n, m) = (self.n(), self.m());
        assert!(
            n.saturating_mul(m) <= DENSE_MATERIALIZE_MAX,
            "refusing to materialize a {n}x{m} dense instance; use Mode::Sharded"
        );
        let pr = self.proj();
        let c_d = DenseMatrix::from_fn(n, m, |i, j| self.pair_cost(&pr, i, j));
        let mut inst = Instance {
            c_d,
            c_e: self.c_e.clone(),
            lambda: self.lambda.clone(),
            r: self.r.clone(),
            l: self.l,
            t_min: self.t_min,
            meta: InstanceMeta::default(),
        };
        inst.validate().expect("sparse instance materialized invalid");
        inst.meta.validated = true;
        inst
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::haversine_km;

    #[test]
    fn equirect_tracks_haversine_at_metro_scale() {
        let a = GeoPoint { lat: 34.02, lon: -118.45 };
        let b = GeoPoint { lat: 34.17, lon: -118.23 };
        let pr = Proj::for_edges(&[a, b]);
        let d_eq = pr.dist_km(a, b);
        let d_hv = haversine_km(a, b);
        assert!((d_eq - d_hv).abs() < 0.05 * d_hv, "{d_eq} vs {d_hv}");
    }

    #[test]
    fn clustered_builds_valid_and_deterministic() {
        let a = SparseInstance::clustered(200, 8, 42, 4);
        let b = SparseInstance::clustered(200, 8, 42, 4);
        a.validate().unwrap();
        assert_eq!(a.cand_edges, b.cand_edges);
        assert_eq!(
            a.cand_costs.iter().map(|c| c.to_bits()).collect::<Vec<_>>(),
            b.cand_costs.iter().map(|c| c.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(a.t_min, 200);
        assert_eq!(a.cand_k, 4);
    }

    #[test]
    fn candidates_are_cost_ascending_and_nearest() {
        let inst = SparseInstance::clustered(100, 10, 7, 5);
        let pr = inst.proj();
        for i in 0..inst.n() {
            let cand: Vec<(usize, f64)> = inst.candidates(i).collect();
            assert_eq!(cand.len(), 5);
            for w in cand.windows(2) {
                assert!(w[0].1 <= w[1].1 + 1e-12);
            }
            // The worst candidate beats (or ties) every non-candidate.
            let worst = cand.last().unwrap().1;
            let in_list: Vec<usize> = cand.iter().map(|&(j, _)| j).collect();
            for j in 0..inst.m() {
                if !in_list.contains(&j) {
                    assert!(inst.pair_cost(&pr, i, j) >= worst - 1e-9);
                }
            }
        }
    }

    #[test]
    fn candidate_costs_match_pair_cost() {
        let inst = SparseInstance::clustered(60, 6, 3, 3);
        let pr = inst.proj();
        for i in 0..inst.n() {
            for (j, c) in inst.candidates(i) {
                assert!((c - inst.pair_cost(&pr, i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn candidate_build_identical_across_worker_counts() {
        let inst = SparseInstance::clustered(150, 12, 5, 6);
        let one = SparseInstance::build(
            inst.device_pos.clone(),
            inst.edge_pos.clone(),
            inst.lambda.clone(),
            inst.r.clone(),
            inst.c_e.clone(),
            inst.l,
            inst.t_min,
            inst.cand_k,
            1,
        )
        .unwrap();
        let eight = SparseInstance::build(
            inst.device_pos.clone(),
            inst.edge_pos.clone(),
            inst.lambda.clone(),
            inst.r.clone(),
            inst.c_e.clone(),
            inst.l,
            inst.t_min,
            inst.cand_k,
            8,
        )
        .unwrap();
        assert_eq!(one.cand_edges, eight.cand_edges);
        assert_eq!(
            one.cand_costs.iter().map(|c| c.to_bits()).collect::<Vec<_>>(),
            eight.cand_costs.iter().map(|c| c.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn to_dense_matches_implicit_costs_and_validates() {
        let inst = SparseInstance::clustered(50, 5, 11, 3);
        let dense = inst.to_dense();
        assert!(dense.meta.validated);
        let pr = inst.proj();
        for i in 0..inst.n() {
            for j in 0..inst.m() {
                assert_eq!(dense.c_d[i][j].to_bits(), inst.pair_cost(&pr, i, j).to_bits());
            }
        }
        assert_eq!(dense.t_min, inst.t_min);
    }

    #[test]
    fn memory_is_sublinear_in_nm() {
        let inst = SparseInstance::clustered(400, 32, 1, 8);
        assert!(inst.candidate_bytes() < inst.dense_equiv_bytes() / 2);
    }
}
