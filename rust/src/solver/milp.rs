//! HFLOP → LP-relaxation encoder (Eq. 1–7 with integrality relaxed).
//!
//! Variable layout: `x_ij ↦ i*m + j` for i<n, j<m; `y_j ↦ n*m + j`.
//! Branch & bound passes down variable fixings which are encoded as
//! equality rows. Two linking styles:
//!
//! * **disaggregated** — `x_ij ≤ y_j` for every pair (tight bound, n·m
//!   rows); used while `n·m` stays small.
//! * **aggregated** — `Σ_i x_ij ≤ n·y_j` plus the capacity row
//!   `Σ_i λ_i x_ij ≤ r_j y_j` (weaker but only 2m rows).

use super::lp::{Cmp, Lp};
use crate::hflop::Instance;

/// Index of x_ij in the LP variable vector.
#[inline]
pub fn xv(i: usize, j: usize, m: usize) -> usize {
    i * m + j
}

/// Index of y_j in the LP variable vector.
#[inline]
pub fn yv(j: usize, n: usize, m: usize) -> usize {
    n * m + j
}

/// Total LP variables.
pub fn n_vars(inst: &Instance) -> usize {
    inst.n() * inst.m() + inst.m()
}

/// A variable fixing (from branching): var index → 0.0 or 1.0.
pub type Fixing = (usize, f64);

/// Build the LP relaxation. `disaggregate` picks the linking style.
pub fn build_relaxation(inst: &Instance, fixings: &[Fixing], disaggregate: bool) -> Lp {
    let (n, m) = (inst.n(), inst.m());
    let mut lp = Lp::new(n_vars(inst));

    // Objective (Eq. 1) — row-slice walk over the flat cost matrix.
    for i in 0..n {
        let row = inst.c_d.row(i);
        for j in 0..m {
            lp.set_obj(xv(i, j, m), inst.l * row[j]);
        }
    }
    for j in 0..m {
        lp.set_obj(yv(j, n, m), inst.c_e[j]);
    }

    // (5) each device with at most one aggregator.
    for i in 0..n {
        lp.add_row((0..m).map(|j| (xv(i, j, m), 1.0)).collect(), Cmp::Le, 1.0);
    }

    // (2)/(3) linking + (4) capacity.
    for j in 0..m {
        if disaggregate {
            for i in 0..n {
                lp.add_row(
                    vec![(xv(i, j, m), 1.0), (yv(j, n, m), -1.0)],
                    Cmp::Le,
                    0.0,
                );
            }
        } else {
            lp.add_row(
                (0..n)
                    .map(|i| (xv(i, j, m), 1.0))
                    .chain([(yv(j, n, m), -(n as f64))])
                    .collect(),
                Cmp::Le,
                0.0,
            );
        }
        // Capacity, tightened with the y linking (valid since x_ij ≤ y_j).
        if inst.r[j].is_finite() {
            lp.add_row(
                (0..n)
                    .map(|i| (xv(i, j, m), inst.lambda[i]))
                    .chain([(yv(j, n, m), -inst.r[j])])
                    .collect(),
                Cmp::Le,
                0.0,
            );
        }
    }

    // (6) minimum participation.
    if inst.t_min > 0 {
        lp.add_row(
            (0..n)
                .flat_map(|i| (0..m).map(move |j| (xv(i, j, m), 1.0)))
                .collect(),
            Cmp::Ge,
            inst.t_min as f64,
        );
    }

    // y_j <= 1 (x_ij <= 1 follows from (5)).
    for j in 0..m {
        lp.add_row(vec![(yv(j, n, m), 1.0)], Cmp::Le, 1.0);
    }

    // Branching fixings.
    for &(var, val) in fixings {
        lp.add_row(vec![(var, 1.0)], Cmp::Eq, val);
    }

    lp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hflop::InstanceBuilder;
    use crate::solver::lp::LpResult;

    #[test]
    fn index_layout_bijective() {
        let (n, m) = (5, 3);
        let mut seen = std::collections::HashSet::new();
        for i in 0..n {
            for j in 0..m {
                assert!(seen.insert(xv(i, j, m)));
            }
        }
        for j in 0..m {
            assert!(seen.insert(yv(j, n, m)));
        }
        assert_eq!(seen.len(), n * m + m);
        assert_eq!(*seen.iter().max().unwrap(), n * m + m - 1);
    }

    #[test]
    fn relaxation_solves_and_lower_bounds() {
        let inst = InstanceBuilder::unit_cost(8, 3, 1).build();
        for disagg in [true, false] {
            let lp = build_relaxation(&inst, &[], disagg);
            match lp.solve() {
                LpResult::Optimal { obj, x } => {
                    assert!(obj >= -1e-9);
                    // All y <= 1.
                    for j in 0..3 {
                        assert!(x[yv(j, 8, 3)] <= 1.0 + 1e-6);
                    }
                    // Participation satisfied.
                    let total: f64 = (0..8)
                        .flat_map(|i| (0..3).map(move |j| (i, j)))
                        .map(|(i, j)| x[xv(i, j, 3)])
                        .sum();
                    assert!(total >= 8.0 - 1e-6);
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn disaggregated_bound_at_least_aggregated() {
        let inst = InstanceBuilder::random(10, 3, 2).t_min(8).build();
        let oa = match build_relaxation(&inst, &[], false).solve() {
            LpResult::Optimal { obj, .. } => obj,
            o => panic!("{o:?}"),
        };
        let od = match build_relaxation(&inst, &[], true).solve() {
            LpResult::Optimal { obj, .. } => obj,
            o => panic!("{o:?}"),
        };
        assert!(od >= oa - 1e-6, "disagg {od} agg {oa}");
    }

    #[test]
    fn fixing_y_zero_forces_x_zero() {
        // Uncapacitated so closing edge 0 stays feasible with t_min = n.
        let inst = InstanceBuilder::unit_cost(6, 2, 3).uncapacitated().build();
        let (n, m) = (6, 2);
        let lp = build_relaxation(&inst, &[(yv(0, n, m), 0.0)], true);
        match lp.solve() {
            LpResult::Optimal { x, .. } => {
                for i in 0..n {
                    assert!(x[xv(i, 0, m)] < 1e-6);
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn infeasible_when_all_edges_closed() {
        let inst = InstanceBuilder::unit_cost(4, 2, 4).build();
        let fixings = vec![(yv(0, 4, 2), 0.0), (yv(1, 4, 2), 0.0)];
        let lp = build_relaxation(&inst, &fixings, true);
        assert_eq!(lp.solve(), LpResult::Infeasible);
    }

    #[test]
    fn uncapacitated_skips_capacity_rows() {
        let inst = InstanceBuilder::unit_cost(4, 2, 5).uncapacitated().build();
        let lp_u = build_relaxation(&inst, &[], true);
        let capped = InstanceBuilder::unit_cost(4, 2, 5).build();
        let lp_c = build_relaxation(&capped, &[], true);
        assert!(lp_u.rows.len() < lp_c.rows.len());
    }
}
