//! Local search for HFLOP in the style of Arya et al. (STOC'01) facility
//! location local search — the large-instance heuristic path the paper's
//! §IV-C points to.
//!
//! Moves over the open-edge set: **open** a closed edge, **close** an open
//! edge, **swap** an open edge for a closed one. After each candidate move
//! the assignment is re-completed with the shared capacity-aware greedy;
//! the move is kept iff total cost strictly improves. Terminates at a
//! local optimum or after `max_rounds` sweeps.

use super::greedy::greedy;
use super::solution::{complete_assignment, Assignment};
use crate::hflop::Instance;

#[derive(Debug, Clone)]
pub struct LocalSearchOptions {
    pub max_rounds: usize,
}

impl Default for LocalSearchOptions {
    fn default() -> Self {
        LocalSearchOptions { max_rounds: 50 }
    }
}

#[derive(Debug, Clone)]
pub struct LocalSearchOutcome {
    pub best: Option<Assignment>,
    pub cost: f64,
    pub rounds: usize,
    pub moves: usize,
}

/// Run local search starting from the greedy solution (or all-open if
/// greedy fails).
pub fn local_search(inst: &Instance, opts: &LocalSearchOptions) -> LocalSearchOutcome {
    let m = inst.m();
    let start = greedy(inst);
    let (mut open, mut best_cost, mut best) = match start.best {
        Some(sol) => (sol.open.clone(), start.cost, Some(sol)),
        None => match complete_assignment(inst, &vec![true; m]) {
            Some(sol) => (sol.open.clone(), sol.cost(inst), Some(sol)),
            None => {
                return LocalSearchOutcome { best: None, cost: f64::INFINITY, rounds: 0, moves: 0 }
            }
        },
    };

    let mut moves = 0usize;
    let mut rounds = 0usize;
    for round in 0..opts.max_rounds {
        rounds = round + 1;
        let mut improved = false;

        // Candidate move generator: open / close / swap.
        let mut candidates: Vec<Vec<bool>> = Vec::new();
        for j in 0..m {
            let mut s = open.clone();
            s[j] = !s[j];
            candidates.push(s); // open or close j
        }
        for a in 0..m {
            if !open[a] {
                continue;
            }
            for b in 0..m {
                if open[b] {
                    continue;
                }
                let mut s = open.clone();
                s[a] = false;
                s[b] = true;
                candidates.push(s); // swap a -> b
            }
        }

        for cand in candidates {
            if !cand.iter().any(|&o| o) {
                continue; // all-closed can never serve t_min > 0
            }
            if let Some(sol) = complete_assignment(inst, &cand) {
                let c = sol.cost(inst);
                if c < best_cost - 1e-12 {
                    best_cost = c;
                    open = sol.open.clone();
                    best = Some(sol);
                    improved = true;
                    moves += 1;
                    break; // first-improvement; restart sweep
                }
            }
        }
        if !improved {
            break;
        }
    }

    LocalSearchOutcome { best, cost: best_cost, rounds, moves }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hflop::InstanceBuilder;
    use crate::solver::brute::brute_force;
    use crate::solver::greedy::greedy;

    #[test]
    fn improves_or_matches_greedy() {
        for seed in 0..6 {
            let inst = InstanceBuilder::random(12, 4, seed).t_min(10).build();
            let g = greedy(&inst);
            let ls = local_search(&inst, &LocalSearchOptions::default());
            if g.cost.is_finite() {
                assert!(ls.cost <= g.cost + 1e-9, "seed {seed}");
            }
        }
    }

    #[test]
    fn close_to_optimal_on_small_instances() {
        let mut total_gap = 0.0;
        let mut cnt = 0;
        for seed in 0..8 {
            let inst = InstanceBuilder::unit_cost(9, 3, seed).build();
            let ls = local_search(&inst, &LocalSearchOptions::default());
            let (_, opt) = brute_force(&inst).unwrap();
            assert!(ls.cost >= opt - 1e-9);
            total_gap += (ls.cost - opt) / opt.max(1e-9);
            cnt += 1;
        }
        // Average optimality gap on this family must be small.
        assert!(total_gap / cnt as f64 <= 0.15, "avg gap {}", total_gap / cnt as f64);
    }

    #[test]
    fn result_feasible() {
        let inst = InstanceBuilder::unit_cost(60, 8, 3).build();
        let ls = local_search(&inst, &LocalSearchOptions::default());
        ls.best.unwrap().check_feasible(&inst).unwrap();
    }

    #[test]
    fn handles_infeasible() {
        let mut inst = InstanceBuilder::unit_cost(5, 2, 4).build();
        for r in inst.r.iter_mut() {
            *r = 0.0;
        }
        let ls = local_search(&inst, &LocalSearchOptions::default());
        assert!(ls.best.is_none());
    }

    #[test]
    fn round_limit_respected() {
        let inst = InstanceBuilder::random(30, 6, 5).t_min(28).build();
        let ls = local_search(&inst, &LocalSearchOptions { max_rounds: 2 });
        assert!(ls.rounds <= 2);
    }
}
