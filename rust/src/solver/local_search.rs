//! Local search for HFLOP in the style of Arya et al. (STOC'01) facility
//! location local search — the large-instance heuristic path the paper's
//! §IV-C points to.
//!
//! Moves over the open-edge set: **open** a closed edge, **close** an open
//! edge, **swap** an open edge for a closed one, interleaved with
//! per-device reassignment sweeps. Two engines share the move structure:
//!
//! * **Completion** (the seed algorithm): every facility candidate
//!   re-completes the whole assignment with the shared capacity-aware
//!   greedy and re-scores it from scratch — O(n·m) per candidate. Richer
//!   per-candidate reshuffling, affordable only on small instances.
//! * **Incremental**: an [`IncrementalEvaluator`] carries residual
//!   capacities and the running cost, so each candidate is a transaction
//!   of O(1)-scored device moves that is kept if the accumulated delta
//!   improves and rolled back otherwise. No completion re-runs on the hot
//!   path — this is what lets local search scale to thousands of devices.
//!
//! `LsMode::Auto` (the default) picks Completion below
//! [`INCREMENTAL_ABOVE`] x-variables and Incremental beyond. Both engines
//! only ever accept strictly improving moves, so `cost ≤ greedy cost`
//! holds for each.

use super::greedy::greedy;
use super::solution::{
    close_empty_edges, complete_assignment, refine_in_place, Assignment, IncrementalEvaluator,
};
use crate::hflop::Instance;

/// `n·m` above which `LsMode::Auto` switches to the incremental engine.
pub const INCREMENTAL_ABOVE: usize = 512;

/// Which move-scoring engine to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LsMode {
    /// Completion below [`INCREMENTAL_ABOVE`] x-variables, incremental
    /// beyond.
    Auto,
    /// Full re-completion + re-score per candidate (the seed behavior).
    Completion,
    /// O(1) delta scoring via [`IncrementalEvaluator`].
    Incremental,
}

#[derive(Debug, Clone)]
pub struct LocalSearchOptions {
    pub max_rounds: usize,
    pub mode: LsMode,
}

impl Default for LocalSearchOptions {
    fn default() -> Self {
        LocalSearchOptions { max_rounds: 50, mode: LsMode::Auto }
    }
}

#[derive(Debug, Clone)]
pub struct LocalSearchOutcome {
    pub best: Option<Assignment>,
    pub cost: f64,
    pub rounds: usize,
    pub moves: usize,
}

/// Run local search starting from the greedy solution (or all-open if
/// greedy fails).
pub fn local_search(inst: &Instance, opts: &LocalSearchOptions) -> LocalSearchOutcome {
    let incremental = match opts.mode {
        LsMode::Completion => false,
        LsMode::Incremental => true,
        LsMode::Auto => inst.n() * inst.m() > INCREMENTAL_ABOVE,
    };
    if incremental {
        incremental::run(inst, opts)
    } else {
        completion_run(inst, opts)
    }
}

/// The seed engine: re-complete + full re-score per candidate.
fn completion_run(inst: &Instance, opts: &LocalSearchOptions) -> LocalSearchOutcome {
    let m = inst.m();
    let start = greedy(inst);
    let (mut open, mut best_cost, mut best) = match start.best {
        Some(sol) => (sol.open.clone(), start.cost, Some(sol)),
        None => match complete_assignment(inst, &vec![true; m]) {
            Some(sol) => (sol.open.clone(), sol.cost(inst), Some(sol)),
            None => {
                return LocalSearchOutcome { best: None, cost: f64::INFINITY, rounds: 0, moves: 0 }
            }
        },
    };

    let mut moves = 0usize;
    let mut rounds = 0usize;
    for round in 0..opts.max_rounds {
        rounds = round + 1;
        let mut improved = false;

        // Candidate move generator: open / close / swap.
        let mut candidates: Vec<Vec<bool>> = Vec::new();
        for j in 0..m {
            let mut s = open.clone();
            s[j] = !s[j];
            candidates.push(s); // open or close j
        }
        for a in 0..m {
            if !open[a] {
                continue;
            }
            for b in 0..m {
                if open[b] {
                    continue;
                }
                let mut s = open.clone();
                s[a] = false;
                s[b] = true;
                candidates.push(s); // swap a -> b
            }
        }

        for cand in candidates {
            if !cand.iter().any(|&o| o) {
                continue; // all-closed can never serve t_min > 0
            }
            if let Some(sol) = complete_assignment(inst, &cand) {
                let c = sol.cost(inst);
                if c < best_cost - 1e-12 {
                    best_cost = c;
                    open = sol.open.clone();
                    best = Some(sol);
                    improved = true;
                    moves += 1;
                    break; // first-improvement; restart sweep
                }
            }
        }
        if !improved {
            break;
        }
    }

    LocalSearchOutcome { best, cost: best_cost, rounds, moves }
}

/// The O(1)-delta engine.
mod incremental {
    use super::*;

    pub(super) fn run(inst: &Instance, opts: &LocalSearchOptions) -> LocalSearchOutcome {
        let m = inst.m();
        let start = greedy(inst);
        let start_sol = match start.best {
            Some(sol) => sol,
            None => match complete_assignment(inst, &vec![true; m]) {
                Some(sol) => sol,
                None => {
                    return LocalSearchOutcome {
                        best: None,
                        cost: f64::INFINITY,
                        rounds: 0,
                        moves: 0,
                    }
                }
            },
        };

        let mut ev = IncrementalEvaluator::new(inst, &start_sol);
        let mut moves = refine_in_place(&mut ev);
        close_empty_edges(&mut ev);

        let mut rounds = 0usize;
        for round in 0..opts.max_rounds {
            rounds = round + 1;
            if !facility_round(&mut ev) {
                break;
            }
            moves += 1;
            moves += refine_in_place(&mut ev);
            close_empty_edges(&mut ev);
        }

        let best = ev.assignment();
        // Report a drift-free full recompute, not the running delta sum.
        let cost = best.cost(inst);
        LocalSearchOutcome { best: Some(best), cost, rounds, moves }
    }

    /// Try one first-improvement facility move (open, close, then swap).
    /// Returns true if a move was applied.
    fn facility_round(ev: &mut IncrementalEvaluator) -> bool {
        let m = ev.instance().m();
        for b in 0..m {
            if !ev.is_open(b) && try_open(ev, b) {
                return true;
            }
        }
        for a in 0..m {
            if ev.is_open(a) && try_close(ev, a) {
                return true;
            }
        }
        for a in 0..m {
            if !ev.is_open(a) {
                continue;
            }
            for b in 0..m {
                if !ev.is_open(b) && try_swap(ev, a, b) {
                    return true;
                }
            }
        }
        false
    }

    /// Open `b` and pull in every device that strictly prefers it (first
    /// come, capacity permitting). Keep iff the net delta improves.
    fn try_open(ev: &mut IncrementalEvaluator, b: usize) -> bool {
        let inst = ev.instance();
        let cost0 = ev.cost();
        ev.open_edge(b);
        let mut log: Vec<(usize, usize)> = Vec::new();
        for i in 0..inst.n() {
            let Some(cur) = ev.assign_of(i) else { continue };
            if cur == b {
                continue;
            }
            if inst.c_d[i][b] < inst.c_d[i][cur] - 1e-12
                && ev.residual(b) + 1e-9 >= inst.lambda[i]
            {
                ev.apply_reassign(i, b);
                log.push((i, cur));
            }
        }
        if ev.served(b) > 0 && ev.cost() < cost0 - 1e-12 {
            return true;
        }
        for &(i, old) in log.iter().rev() {
            ev.apply_reassign(i, old);
        }
        ev.close_edge(b);
        ev.reset_cost(cost0);
        false
    }

    /// Migrate every device off `a` and close it. Keep iff improving.
    fn try_close(ev: &mut IncrementalEvaluator, a: usize) -> bool {
        let cost0 = ev.cost();
        let Some(log) = migrate_off(ev, a) else {
            ev.reset_cost(cost0);
            return false;
        };
        ev.close_edge(a);
        if ev.cost() < cost0 - 1e-12 {
            return true;
        }
        ev.open_edge(a);
        undo_migrate(ev, a, &log);
        ev.reset_cost(cost0);
        false
    }

    /// Open `b`, migrate `a`'s devices (cheapest feasible target, which
    /// now includes `b`), close `a`. Keep iff improving and `b` is used.
    fn try_swap(ev: &mut IncrementalEvaluator, a: usize, b: usize) -> bool {
        let cost0 = ev.cost();
        ev.open_edge(b);
        let Some(log) = migrate_off(ev, a) else {
            ev.close_edge(b);
            ev.reset_cost(cost0);
            return false;
        };
        ev.close_edge(a);
        if ev.served(b) > 0 && ev.cost() < cost0 - 1e-12 {
            return true;
        }
        ev.open_edge(a);
        undo_migrate(ev, a, &log);
        ev.close_edge(b);
        ev.reset_cost(cost0);
        false
    }

    /// Move every device off `a`: cheapest feasible other open edge, or
    /// unassign when participation allows. On success returns the undo
    /// log (`(device, dropped)`); on failure rolls its own moves back and
    /// returns None (cost drift is the caller's `reset_cost` to fix).
    fn migrate_off(ev: &mut IncrementalEvaluator, a: usize) -> Option<Vec<(usize, bool)>> {
        let inst = ev.instance();
        let (n, m) = (inst.n(), inst.m());
        let mut log: Vec<(usize, bool)> = Vec::new();
        for i in 0..n {
            if ev.assign_of(i) != Some(a) {
                continue;
            }
            let row = inst.c_d.row(i);
            let mut best: Option<usize> = None;
            for j in 0..m {
                if j == a || !ev.is_open(j) || ev.residual(j) + 1e-9 < inst.lambda[i] {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some(b) => row[j] < row[b],
                };
                if better {
                    best = Some(j);
                }
            }
            match best {
                Some(j) => {
                    ev.apply_reassign(i, j);
                    log.push((i, false));
                }
                None if ev.n_assigned() > inst.t_min => {
                    ev.apply_unassign(i);
                    log.push((i, true));
                }
                None => {
                    undo_migrate(ev, a, &log);
                    return None;
                }
            }
        }
        Some(log)
    }

    fn undo_migrate(ev: &mut IncrementalEvaluator, a: usize, log: &[(usize, bool)]) {
        for &(i, dropped) in log.iter().rev() {
            if dropped {
                ev.apply_assign(i, a);
            } else {
                ev.apply_reassign(i, a);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hflop::InstanceBuilder;
    use crate::solver::brute::brute_force;
    use crate::solver::greedy::greedy;

    #[test]
    fn improves_or_matches_greedy() {
        for seed in 0..6 {
            let inst = InstanceBuilder::random(12, 4, seed).t_min(10).build();
            let g = greedy(&inst);
            let ls = local_search(&inst, &LocalSearchOptions::default());
            if g.cost.is_finite() {
                assert!(ls.cost <= g.cost + 1e-9, "seed {seed}");
            }
        }
    }

    #[test]
    fn close_to_optimal_on_small_instances() {
        let mut total_gap = 0.0;
        let mut cnt = 0;
        for seed in 0..8 {
            let inst = InstanceBuilder::unit_cost(9, 3, seed).build();
            let ls = local_search(&inst, &LocalSearchOptions::default());
            let (_, opt) = brute_force(&inst).unwrap();
            assert!(ls.cost >= opt - 1e-9);
            total_gap += (ls.cost - opt) / opt.max(1e-9);
            cnt += 1;
        }
        // Average optimality gap on this family must be small.
        assert!(total_gap / cnt as f64 <= 0.15, "avg gap {}", total_gap / cnt as f64);
    }

    #[test]
    fn result_feasible() {
        let inst = InstanceBuilder::unit_cost(60, 8, 3).build();
        let ls = local_search(&inst, &LocalSearchOptions::default());
        ls.best.unwrap().check_feasible(&inst).unwrap();
    }

    #[test]
    fn handles_infeasible() {
        let mut inst = InstanceBuilder::unit_cost(5, 2, 4).build();
        for r in inst.r.iter_mut() {
            *r = 0.0;
        }
        let ls = local_search(&inst, &LocalSearchOptions::default());
        assert!(ls.best.is_none());
    }

    #[test]
    fn round_limit_respected() {
        let inst = InstanceBuilder::random(30, 6, 5).t_min(28).build();
        let ls = local_search(&inst, &LocalSearchOptions { max_rounds: 2, ..Default::default() });
        assert!(ls.rounds <= 2);
    }

    #[test]
    fn incremental_feasible_and_not_worse_than_greedy() {
        for seed in [1u64, 5, 9] {
            let inst = InstanceBuilder::unit_cost(80, 8, seed).build();
            let g = greedy(&inst);
            let opts = LocalSearchOptions { mode: LsMode::Incremental, ..Default::default() };
            let ls = local_search(&inst, &opts);
            let sol = ls.best.expect("unit-cost instances are feasible");
            sol.check_feasible(&inst).unwrap();
            assert!(ls.cost <= g.cost + 1e-9, "seed {seed}: ls {} greedy {}", ls.cost, g.cost);
            assert!((ls.cost - sol.cost(&inst)).abs() < 1e-9);
        }
    }

    #[test]
    fn incremental_never_below_optimal() {
        for seed in 0..6 {
            let inst = InstanceBuilder::unit_cost(9, 3, seed).build();
            let opts = LocalSearchOptions { mode: LsMode::Incremental, ..Default::default() };
            let ls = local_search(&inst, &opts);
            let (_, opt) = brute_force(&inst).unwrap();
            assert!(ls.cost >= opt - 1e-9, "seed {seed}: {} < {opt}", ls.cost);
        }
    }

    #[test]
    fn incremental_handles_infeasible() {
        let mut inst = InstanceBuilder::unit_cost(5, 2, 4).build();
        for r in inst.r.iter_mut() {
            *r = 0.0;
        }
        let opts = LocalSearchOptions { mode: LsMode::Incremental, ..Default::default() };
        let ls = local_search(&inst, &opts);
        assert!(ls.best.is_none());
    }

    #[test]
    fn engines_agree_on_feasibility_and_direction() {
        // Both engines start from greedy and only accept improvements, so
        // each must land at or below the greedy cost; neither may violate
        // feasibility. (Their local optima may differ.)
        for seed in [2u64, 11, 23] {
            let inst = InstanceBuilder::random(25, 5, seed).t_min(22).build();
            let g = greedy(&inst);
            for mode in [LsMode::Completion, LsMode::Incremental] {
                let ls =
                    local_search(&inst, &LocalSearchOptions { mode, ..Default::default() });
                if let Some(sol) = &ls.best {
                    sol.check_feasible(&inst).unwrap();
                    if g.cost.is_finite() {
                        assert!(ls.cost <= g.cost + 1e-9, "seed {seed} mode {mode:?}");
                    }
                }
            }
        }
    }
}
