//! HFLOP solvers: exact branch & bound with LP-relaxation bounds (the role
//! CPLEX plays in the paper's Fig. 2), plus greedy and local-search
//! heuristics for large instances (§IV-C), an exhaustive oracle for tests,
//! and the in-tree dense simplex they all stand on.
//!
//! Entry points: [`solve`] on a dense [`Instance`] and [`solve_sparse`]
//! on a candidate-sparse [`SparseInstance`], both driven by
//! [`SolveOptions`] — `exact()`, `heuristic()`, `sharded()` or `auto()`
//! (exact while the instance is small enough, heuristic beyond, and —
//! for sparse instances — region-parallel sharded past
//! `auto_sharded_above` x-variables).

pub mod bb;
pub mod brute;
pub mod cache;
pub mod greedy;
pub mod local_search;
pub mod lp;
pub mod milp;
pub mod resolve;
pub mod sharded;
pub mod solution;
pub mod trust;

pub use bb::{branch_and_bound, BbOptions, BbOutcome};
pub use cache::SolveCache;
pub use local_search::{LocalSearchOptions, LsMode};
pub use resolve::{resolve, resolve_assignment, DirtySet};
pub use sharded::{aggregated_lp_bound, solve_sharded, ShardOptions, ShardStats, ShardedOutcome};
pub use solution::{complete_assignment, refine_assignment, Assignment, IncrementalEvaluator};
pub use trust::{solve_with_trust, TrustMatrix};

use crate::hflop::{Instance, SparseInstance};

/// Which algorithm (and budget) to use.
#[derive(Debug, Clone)]
pub struct SolveOptions {
    pub mode: Mode,
    pub bb: BbOptions,
    pub ls: local_search::LocalSearchOptions,
    /// `auto` switches to the heuristic above this many x-variables.
    pub auto_exact_below: usize,
    /// `auto` on a sparse instance switches to the sharded path above
    /// this many x-variables (n·m); below it the dense equivalent is
    /// materialized and solved with the regular stack.
    pub auto_sharded_above: usize,
    /// Knobs for the region-parallel sharded path.
    pub shard: ShardOptions,
    /// Reject machine-dependent termination (the default). With this
    /// set, an opt-in `bb.time_limit_s` is an invalid configuration:
    /// wall time steering which B&B incumbent wins breaks the repo's
    /// bit-reproducibility contract (DESIGN.md §9). Turn it off only
    /// for interactive "give me *an* answer in N seconds" use.
    pub deterministic: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Exact,
    Heuristic,
    /// Region-parallel sharded pipeline; sparse instances only.
    Sharded,
    Auto,
}

impl SolveOptions {
    pub fn exact() -> Self {
        SolveOptions {
            mode: Mode::Exact,
            bb: BbOptions::default(),
            ls: Default::default(),
            // Measured on this box: the aggregated-LP B&B stays fast up to
            // a few hundred x-variables on dense instances; beyond that the
            // local-search heuristic (within a few % of optimal on the
            // unit-cost family) is the right default.
            auto_exact_below: 320,
            // Past ~256k x-variables the dense row materialization alone
            // dominates; the sharded path keeps memory at O(n·k + m).
            auto_sharded_above: 262_144,
            shard: ShardOptions::default(),
            deterministic: true,
        }
    }

    pub fn heuristic() -> Self {
        SolveOptions { mode: Mode::Heuristic, ..Self::exact() }
    }

    pub fn sharded() -> Self {
        SolveOptions { mode: Mode::Sharded, ..Self::exact() }
    }

    pub fn auto() -> Self {
        SolveOptions { mode: Mode::Auto, ..Self::exact() }
    }
}

/// A solved HFLOP configuration.
#[derive(Debug, Clone)]
pub struct Solution {
    pub assignment: Assignment,
    pub cost: f64,
    /// True when produced by a completed branch & bound run.
    pub proven_optimal: bool,
    /// Explored B&B nodes (0 for heuristics).
    pub nodes: usize,
    pub wall_s: f64,
}

#[derive(Debug, thiserror::Error)]
pub enum SolveError {
    #[error("instance is infeasible: {0}")]
    Infeasible(String),
    #[error("invalid instance: {0}")]
    Invalid(String),
}

/// Solve an HFLOP instance.
///
/// Instances produced by `InstanceBuilder::build` were validated there
/// (`meta.validated`), so the entry check is a debug assertion only;
/// hand-constructed or hand-mutated instances still get the full hard
/// validation.
pub fn solve(inst: &Instance, opts: &SolveOptions) -> Result<Solution, SolveError> {
    check_deterministic(opts)?;
    if inst.meta.validated {
        debug_assert!(inst.validate().is_ok(), "validated instance failed re-validation");
    } else {
        inst.validate().map_err(|e| SolveError::Invalid(e.to_string()))?;
    }
    if !inst.capacity_feasible() {
        return Err(SolveError::Infeasible(
            "aggregate capacity below t_min demand".into(),
        ));
    }

    let use_exact = match opts.mode {
        Mode::Exact => true,
        Mode::Heuristic => false,
        Mode::Sharded => {
            return Err(SolveError::Invalid(
                "Mode::Sharded needs a SparseInstance; call solve_sparse".into(),
            ))
        }
        Mode::Auto => inst.n() * inst.m() <= opts.auto_exact_below,
    };

    if use_exact {
        let out = branch_and_bound(inst, &opts.bb);
        match out.best {
            Some(assignment) => Ok(Solution {
                cost: out.cost,
                assignment,
                proven_optimal: out.proven_optimal,
                nodes: out.nodes,
                wall_s: out.wall_s,
            }),
            None => Err(SolveError::Infeasible("branch & bound found no solution".into())),
        }
    } else {
        let (out, wall_s) = crate::util::time_it(|| local_search::local_search(inst, &opts.ls));
        match out.best {
            Some(assignment) => Ok(Solution {
                cost: out.cost,
                assignment,
                proven_optimal: false,
                nodes: 0,
                wall_s,
            }),
            None => Err(SolveError::Infeasible("local search found no solution".into())),
        }
    }
}

/// Deterministic mode forbids wall-clock B&B termination: identical
/// inputs must explore identical trees on every machine.
fn check_deterministic(opts: &SolveOptions) -> Result<(), SolveError> {
    if opts.deterministic && opts.bb.time_limit_s.is_some() {
        return Err(SolveError::Invalid(
            "bb.time_limit_s is wall-clock termination, which deterministic mode rejects; \
             use node_limit, or set SolveOptions::deterministic = false"
                .into(),
        ));
    }
    Ok(())
}

/// Result of [`solve_sparse`]: the solution, plus shard diagnostics when
/// the sharded path ran.
#[derive(Debug, Clone)]
pub struct SparseSolution {
    pub solution: Solution,
    pub sharded: Option<ShardStats>,
}

/// Solve a candidate-sparse instance. `Mode::Sharded` (or `Mode::Auto`
/// past `auto_sharded_above` x-variables) runs the region-parallel
/// pipeline without ever materializing the dense cost matrix; the other
/// modes materialize the dense equivalent and use the regular stack.
pub fn solve_sparse(
    sp: &SparseInstance,
    opts: &SolveOptions,
) -> Result<SparseSolution, SolveError> {
    check_deterministic(opts)?;
    let use_sharded = match opts.mode {
        Mode::Sharded => true,
        Mode::Auto => sp.n() * sp.m() > opts.auto_sharded_above,
        Mode::Exact | Mode::Heuristic => false,
    };
    if use_sharded {
        let out = solve_sharded(sp, opts)?;
        return Ok(SparseSolution { solution: out.solution, sharded: Some(out.stats) });
    }
    if sp.n() * sp.m() > crate::hflop::sparse::DENSE_MATERIALIZE_MAX {
        return Err(SolveError::Invalid(format!(
            "refusing to materialize a {}x{} dense instance; use Mode::Sharded",
            sp.n(),
            sp.m()
        )));
    }
    let dense = sp.to_dense();
    let solution = solve(&dense, opts)?;
    Ok(SparseSolution { solution, sharded: None })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::DenseMatrix;
    use crate::hflop::InstanceBuilder;

    #[test]
    fn exact_vs_heuristic_agreement_direction() {
        let inst = InstanceBuilder::unit_cost(15, 4, 2).build();
        let ex = solve(&inst, &SolveOptions::exact()).unwrap();
        let he = solve(&inst, &SolveOptions::heuristic()).unwrap();
        assert!(ex.proven_optimal);
        assert!(!he.proven_optimal);
        assert!(he.cost >= ex.cost - 1e-9);
        ex.assignment.check_feasible(&inst).unwrap();
        he.assignment.check_feasible(&inst).unwrap();
    }

    #[test]
    fn auto_picks_exact_for_small() {
        let inst = InstanceBuilder::unit_cost(10, 3, 3).build();
        let s = solve(&inst, &SolveOptions::auto()).unwrap();
        assert!(s.proven_optimal);
    }

    #[test]
    fn auto_picks_heuristic_for_large() {
        let inst = InstanceBuilder::unit_cost(300, 20, 4).build();
        let s = solve(&inst, &SolveOptions::auto()).unwrap();
        assert!(!s.proven_optimal);
        s.assignment.check_feasible(&inst).unwrap();
    }

    #[test]
    fn infeasible_reported() {
        let mut inst = InstanceBuilder::unit_cost(5, 2, 5).build();
        for r in inst.r.iter_mut() {
            *r = 0.01;
        }
        assert!(matches!(
            solve(&inst, &SolveOptions::exact()),
            Err(SolveError::Infeasible(_))
        ));
    }

    #[test]
    fn cost_matches_assignment_cost() {
        let inst = InstanceBuilder::random(12, 3, 6).t_min(10).build();
        let s = solve(&inst, &SolveOptions::exact()).unwrap();
        assert!((s.cost - s.assignment.cost(&inst)).abs() < 1e-9);
    }

    #[test]
    fn sharded_mode_on_dense_instance_errors() {
        let inst = InstanceBuilder::unit_cost(10, 3, 3).build();
        assert!(matches!(
            solve(&inst, &SolveOptions::sharded()),
            Err(SolveError::Invalid(_))
        ));
    }

    #[test]
    fn invalid_hand_built_instance_still_hard_errors() {
        // Literal construction skips build-time validation, so the solve
        // entry must catch the shape mismatch as a hard error.
        let inst = Instance {
            c_d: DenseMatrix::from_fn(2, 2, |_, _| 1.0),
            c_e: vec![1.0, 1.0],
            lambda: vec![1.0].into(), // wrong length: 1 != n = 2
            r: vec![5.0, 5.0].into(),
            l: 1.0,
            t_min: 1,
            meta: Default::default(),
        };
        assert!(matches!(
            solve(&inst, &SolveOptions::exact()),
            Err(SolveError::Invalid(_))
        ));
    }

    #[test]
    fn auto_routes_sparse_by_size() {
        let sp = SparseInstance::clustered(200, 6, 4, 3);
        // 200 * 6 = 1200 x-variables: below the default sharded cutoff,
        // so auto materializes the dense equivalent.
        let small = solve_sparse(&sp, &SolveOptions::auto()).unwrap();
        assert!(small.sharded.is_none());
        // Force the cutoff down and the same instance routes sharded.
        let mut opts = SolveOptions::auto();
        opts.auto_sharded_above = 0;
        let big = solve_sparse(&sp, &opts).unwrap();
        assert!(big.sharded.is_some());
        let dense = sp.to_dense();
        big.solution.assignment.check_feasible(&dense).unwrap();
    }

    #[test]
    fn explicit_sharded_mode_runs_sparse() {
        let sp = SparseInstance::clustered(150, 5, 6, 3);
        let out = solve_sparse(&sp, &SolveOptions::sharded()).unwrap();
        let stats = out.sharded.expect("sharded stats present");
        assert!(stats.regions >= 1);
        out.solution.assignment.check_feasible(&sp.to_dense()).unwrap();
    }
}
