//! HFLOP solvers: exact branch & bound with LP-relaxation bounds (the role
//! CPLEX plays in the paper's Fig. 2), plus greedy and local-search
//! heuristics for large instances (§IV-C), an exhaustive oracle for tests,
//! and the in-tree dense simplex they all stand on.
//!
//! Entry point: [`solve`] with [`SolveOptions`] — `exact()`, `heuristic()`
//! or `auto()` (exact while the instance is small enough, heuristic
//! beyond).

pub mod bb;
pub mod brute;
pub mod greedy;
pub mod local_search;
pub mod lp;
pub mod milp;
pub mod solution;
pub mod trust;

pub use bb::{branch_and_bound, BbOptions, BbOutcome};
pub use local_search::{LocalSearchOptions, LsMode};
pub use solution::{complete_assignment, refine_assignment, Assignment, IncrementalEvaluator};
pub use trust::{solve_with_trust, TrustMatrix};

use crate::hflop::Instance;

/// Which algorithm (and budget) to use.
#[derive(Debug, Clone)]
pub struct SolveOptions {
    pub mode: Mode,
    pub bb: BbOptions,
    pub ls: local_search::LocalSearchOptions,
    /// `auto` switches to the heuristic above this many x-variables.
    pub auto_exact_below: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Exact,
    Heuristic,
    Auto,
}

impl SolveOptions {
    pub fn exact() -> Self {
        SolveOptions {
            mode: Mode::Exact,
            bb: BbOptions::default(),
            ls: Default::default(),
            // Measured on this box: the aggregated-LP B&B stays fast up to
            // a few hundred x-variables on dense instances; beyond that the
            // local-search heuristic (within a few % of optimal on the
            // unit-cost family) is the right default.
            auto_exact_below: 320,
        }
    }

    pub fn heuristic() -> Self {
        SolveOptions { mode: Mode::Heuristic, ..Self::exact() }
    }

    pub fn auto() -> Self {
        SolveOptions { mode: Mode::Auto, ..Self::exact() }
    }
}

/// A solved HFLOP configuration.
#[derive(Debug, Clone)]
pub struct Solution {
    pub assignment: Assignment,
    pub cost: f64,
    /// True when produced by a completed branch & bound run.
    pub proven_optimal: bool,
    /// Explored B&B nodes (0 for heuristics).
    pub nodes: usize,
    pub wall_s: f64,
}

#[derive(Debug, thiserror::Error)]
pub enum SolveError {
    #[error("instance is infeasible: {0}")]
    Infeasible(String),
    #[error("invalid instance: {0}")]
    Invalid(String),
}

/// Solve an HFLOP instance.
pub fn solve(inst: &Instance, opts: &SolveOptions) -> Result<Solution, SolveError> {
    inst.validate().map_err(|e| SolveError::Invalid(e.to_string()))?;
    if !inst.capacity_feasible() {
        return Err(SolveError::Infeasible(
            "aggregate capacity below t_min demand".into(),
        ));
    }

    let use_exact = match opts.mode {
        Mode::Exact => true,
        Mode::Heuristic => false,
        Mode::Auto => inst.n() * inst.m() <= opts.auto_exact_below,
    };

    if use_exact {
        let out = branch_and_bound(inst, &opts.bb);
        match out.best {
            Some(assignment) => Ok(Solution {
                cost: out.cost,
                assignment,
                proven_optimal: out.proven_optimal,
                nodes: out.nodes,
                wall_s: out.wall_s,
            }),
            None => Err(SolveError::Infeasible("branch & bound found no solution".into())),
        }
    } else {
        let (out, wall_s) = crate::util::time_it(|| local_search::local_search(inst, &opts.ls));
        match out.best {
            Some(assignment) => Ok(Solution {
                cost: out.cost,
                assignment,
                proven_optimal: false,
                nodes: 0,
                wall_s,
            }),
            None => Err(SolveError::Infeasible("local search found no solution".into())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hflop::InstanceBuilder;

    #[test]
    fn exact_vs_heuristic_agreement_direction() {
        let inst = InstanceBuilder::unit_cost(15, 4, 2).build();
        let ex = solve(&inst, &SolveOptions::exact()).unwrap();
        let he = solve(&inst, &SolveOptions::heuristic()).unwrap();
        assert!(ex.proven_optimal);
        assert!(!he.proven_optimal);
        assert!(he.cost >= ex.cost - 1e-9);
        ex.assignment.check_feasible(&inst).unwrap();
        he.assignment.check_feasible(&inst).unwrap();
    }

    #[test]
    fn auto_picks_exact_for_small() {
        let inst = InstanceBuilder::unit_cost(10, 3, 3).build();
        let s = solve(&inst, &SolveOptions::auto()).unwrap();
        assert!(s.proven_optimal);
    }

    #[test]
    fn auto_picks_heuristic_for_large() {
        let inst = InstanceBuilder::unit_cost(300, 20, 4).build();
        let s = solve(&inst, &SolveOptions::auto()).unwrap();
        assert!(!s.proven_optimal);
        s.assignment.check_feasible(&inst).unwrap();
    }

    #[test]
    fn infeasible_reported() {
        let mut inst = InstanceBuilder::unit_cost(5, 2, 5).build();
        for r in inst.r.iter_mut() {
            *r = 0.01;
        }
        assert!(matches!(
            solve(&inst, &SolveOptions::exact()),
            Err(SolveError::Infeasible(_))
        ));
    }

    #[test]
    fn cost_matches_assignment_cost() {
        let inst = InstanceBuilder::random(12, 3, 6).t_min(10).build();
        let s = solve(&inst, &SolveOptions::exact()).unwrap();
        assert!((s.cost - s.assignment.cost(&inst)).abs() < 1e-9);
    }
}
