//! Capacity-aware greedy heuristic for HFLOP.
//!
//! §IV-C of the paper: exact solving "can become prohibitively expensive
//! computationally" at scale; "adaptations of heuristics and approximation
//! algorithms for versions of the facility location problem can be
//! considered". This is the classic add-greedy: starting from no open
//! aggregators, repeatedly open the edge host whose opening reduces total
//! cost the most (assignment re-completed each time by the shared
//! capacity-aware completion); stop at the first non-improving step.

use super::solution::{complete_assignment, Assignment};
use crate::hflop::Instance;

/// Greedy outcome (always feasible if some feasible solution exists among
/// the probed open sets).
#[derive(Debug, Clone)]
pub struct GreedyOutcome {
    pub best: Option<Assignment>,
    pub cost: f64,
    /// Open steps actually taken.
    pub steps: usize,
}

pub fn greedy(inst: &Instance) -> GreedyOutcome {
    let m = inst.m();
    let mut open = vec![false; m];
    let mut best: Option<Assignment> = None;
    let mut best_cost = f64::INFINITY;
    let mut steps = 0usize;

    // Phase A — feasibility bootstrap: while no open set admits t_min
    // assigned devices, open the edge with the largest capacity. (On the
    // paper's unit-cost family a single edge rarely fits all of T = n.)
    while best.is_none() && steps < m {
        match complete_assignment(inst, &open) {
            Some(sol) => {
                best_cost = sol.cost(inst);
                best = Some(sol);
            }
            None => {
                let next = (0..m)
                    .filter(|&j| !open[j])
                    .max_by(|&a, &b| inst.r[a].total_cmp(&inst.r[b]));
                match next {
                    Some(j) => {
                        open[j] = true;
                        steps += 1;
                    }
                    None => break,
                }
            }
        }
    }
    if best.is_none() {
        // All edges open and still infeasible.
        if let Some(sol) = complete_assignment(inst, &open) {
            best_cost = sol.cost(inst);
            best = Some(sol);
        } else {
            return GreedyOutcome { best: None, cost: f64::INFINITY, steps };
        }
    }
    // The bootstrap may have opened edges the completion then closed as
    // unused; resync to the completed solution's open set.
    open = best.as_ref().unwrap().open.clone();

    // Phase B — classic add-greedy: open the edge that reduces total cost
    // the most; stop at the first non-improving sweep.
    loop {
        let mut improved: Option<(usize, f64, Assignment)> = None;
        for j in 0..m {
            if open[j] {
                continue;
            }
            open[j] = true;
            if let Some(sol) = complete_assignment(inst, &open) {
                let c = sol.cost(inst);
                let better_than_probe =
                    improved.as_ref().map(|(_, bc, _)| c < *bc - 1e-12).unwrap_or(true);
                if c < best_cost - 1e-12 && better_than_probe {
                    improved = Some((j, c, sol));
                }
            }
            open[j] = false;
        }
        match improved {
            Some((j, c, sol)) => {
                open[j] = true;
                best_cost = c;
                best = Some(sol);
                steps += 1;
            }
            None => break,
        }
        if steps >= 2 * m {
            break;
        }
    }

    GreedyOutcome { best, cost: best_cost, steps }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hflop::InstanceBuilder;
    use crate::solver::brute::brute_force;

    #[test]
    fn feasible_on_unit_cost() {
        let inst = InstanceBuilder::unit_cost(40, 6, 1).build();
        let g = greedy(&inst);
        let sol = g.best.expect("feasible");
        sol.check_feasible(&inst).unwrap();
        assert!(g.cost.is_finite());
    }

    #[test]
    fn never_better_than_optimal() {
        for seed in 0..8 {
            let inst = InstanceBuilder::random(7, 3, seed).t_min(6).build();
            let g = greedy(&inst);
            if let Some((_, opt)) = brute_force(&inst) {
                assert!(
                    g.cost >= opt - 1e-9,
                    "seed {seed}: greedy {} below optimal {opt}",
                    g.cost
                );
            }
        }
    }

    #[test]
    fn reasonable_gap_on_unit_cost() {
        // On the paper's unit-cost family greedy should be close to
        // optimal (within 30%) — it mirrors facility-location add-greedy's
        // known behaviour.
        for seed in 0..4 {
            let inst = InstanceBuilder::unit_cost(10, 3, seed).build();
            let g = greedy(&inst);
            let (_, opt) = brute_force(&inst).unwrap();
            assert!(g.cost <= opt * 1.3 + 1e-9, "seed {seed}: {} vs {opt}", g.cost);
        }
    }

    #[test]
    fn infeasible_gives_none() {
        let mut inst = InstanceBuilder::unit_cost(5, 2, 9).build();
        for r in inst.r.iter_mut() {
            *r = 0.0;
        }
        let g = greedy(&inst);
        assert!(g.best.is_none());
        assert!(g.cost.is_infinite());
    }

    #[test]
    fn opens_no_more_than_m(){
        let inst = InstanceBuilder::unit_cost(30, 4, 10).build();
        let g = greedy(&inst);
        assert!(g.steps <= 4);
    }
}
