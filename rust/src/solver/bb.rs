//! Exact branch & bound for HFLOP (the role CPLEX plays in the paper).
//!
//! Best-first search over binary fixings with LP-relaxation lower bounds
//! (`milp.rs` + the in-tree simplex). Branching prefers the most
//! fractional `y_j` (facility decisions dominate the structure); when all
//! `y` are integral it branches on the most fractional `x_ij`. Incumbents
//! come from rounding each node's LP (open `y_j ≥ 0.5`, complete with the
//! capacity-aware greedy) so good feasible solutions appear early and the
//! search prunes aggressively.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::milp::{build_relaxation, n_vars, xv, yv, Fixing};
use super::lp::LpResult;
use super::solution::{complete_assignment, refine_assignment, Assignment};
use crate::hflop::Instance;
use crate::util::WallClock;

/// Branch & bound configuration.
#[derive(Debug, Clone)]
pub struct BbOptions {
    /// Use `x_ij ≤ y_j` (tight) linking while `n·m ≤` this threshold.
    pub disaggregate_below: usize,
    /// Give up after this many explored nodes (returns best-so-far,
    /// `proven_optimal = false`). This is the *deterministic* budget:
    /// the same instance and options explore the same tree everywhere.
    pub node_limit: usize,
    /// Opt-in wall-clock budget in seconds. `None` (the default) means
    /// termination is governed solely by `node_limit`; `Some(s)` makes
    /// which incumbent wins machine-dependent, so deterministic
    /// `SolveOptions` reject it (`wall_s` stays measurement-only).
    pub time_limit_s: Option<f64>,
    /// Absolute optimality gap below which a node is pruned.
    pub abs_gap: f64,
}

impl Default for BbOptions {
    fn default() -> Self {
        BbOptions {
            // The dense tableau makes the disaggregated linking (n·m rows)
            // expensive well before its tighter bound pays off; measured
            // crossover on this box is a few hundred x-vars (§Perf).
            disaggregate_below: 400,
            node_limit: 200_000,
            time_limit_s: None,
            abs_gap: 1e-6,
        }
    }
}

/// Result of an exact solve.
#[derive(Debug, Clone)]
pub struct BbOutcome {
    pub best: Option<Assignment>,
    pub cost: f64,
    pub proven_optimal: bool,
    pub nodes: usize,
    pub lp_solves: usize,
    pub wall_s: f64,
}

struct Node {
    bound: f64,
    fixings: Vec<Fixing>,
}

impl PartialEq for Node {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound
    }
}
impl Eq for Node {}
impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Node {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; we want the smallest bound first.
        // total_cmp: bounds include ±inf sentinels and must order totally.
        other.bound.total_cmp(&self.bound)
    }
}

const INT_TOL: f64 = 1e-6;

fn is_integral(v: f64) -> bool {
    (v - v.round()).abs() < INT_TOL
}

/// Round an LP point to a feasible assignment (may fail).
fn round_lp(inst: &Instance, x: &[f64]) -> Option<Assignment> {
    let (n, m) = (inst.n(), inst.m());
    let mut open: Vec<bool> = (0..m).map(|j| x[yv(j, n, m)] >= 0.5).collect();
    if !open.iter().any(|&o| o) {
        // Open the single most-loaded fractional y.
        if let Some(j) = (0..m).max_by(|&a, &b| {
            x[yv(a, n, m)].total_cmp(&x[yv(b, n, m)])
        }) {
            open[j] = true;
        }
    }
    // Try progressively opening more edges if completion fails.
    loop {
        if let Some(sol) = complete_assignment(inst, &open) {
            // Polish with the O(1)-delta device sweeps before handing the
            // incumbent up — tighter upper bounds prune harder.
            return Some(refine_assignment(inst, &sol));
        }
        // Open the best closed edge by fractional value; stop when none.
        let next = (0..m)
            .filter(|&j| !open[j])
            .max_by(|&a, &b| x[yv(a, n, m)].total_cmp(&x[yv(b, n, m)]));
        match next {
            Some(j) => open[j] = true,
            None => return None,
        }
    }
}

/// Pick the branching variable: most fractional y first, else most
/// fractional x.
fn pick_branch_var(inst: &Instance, x: &[f64]) -> Option<usize> {
    let (n, m) = (inst.n(), inst.m());
    let frac = |v: f64| (v - v.round()).abs();
    let ybest = (0..m)
        .map(|j| yv(j, n, m))
        .filter(|&v| !is_integral(x[v]))
        .max_by(|&a, &b| frac(x[a]).total_cmp(&frac(x[b])));
    if ybest.is_some() {
        return ybest;
    }
    (0..n * m)
        .filter(|&v| !is_integral(x[v]))
        .max_by(|&a, &b| frac(x[a]).total_cmp(&frac(x[b])))
}

/// Extract an integral LP point as an Assignment.
fn extract_integral(inst: &Instance, x: &[f64]) -> Assignment {
    let (n, m) = (inst.n(), inst.m());
    let open = (0..m).map(|j| x[yv(j, n, m)] > 0.5).collect();
    let assign = (0..n)
        .map(|i| (0..m).find(|&j| x[xv(i, j, m)] > 0.5))
        .collect();
    Assignment { assign, open }
}

/// Solve HFLOP exactly by branch & bound.
pub fn branch_and_bound(inst: &Instance, opts: &BbOptions) -> BbOutcome {
    let clock = WallClock::start();
    let disagg = n_vars(inst) <= opts.disaggregate_below;

    let mut lp_solves = 0usize;
    let mut nodes = 0usize;
    let mut incumbent: Option<Assignment> = None;
    let mut incumbent_cost = f64::INFINITY;

    // Root incumbent: local search (greedy + open/close/swap). A strong
    // initial upper bound is what keeps the search tree small on
    // high-density instances (§Perf).
    let ls = crate::solver::local_search::local_search(
        inst,
        &crate::solver::local_search::LocalSearchOptions::default(),
    );
    if let Some(sol) = ls.best {
        incumbent_cost = ls.cost;
        incumbent = Some(sol);
    } else if let Some(sol) = complete_assignment(inst, &vec![true; inst.m()]) {
        incumbent_cost = sol.cost(inst);
        incumbent = Some(sol);
    }

    let mut heap = BinaryHeap::new();
    heap.push(Node { bound: f64::NEG_INFINITY, fixings: Vec::new() });

    let mut proven = true;
    while let Some(node) = heap.pop() {
        if node.bound >= incumbent_cost - opts.abs_gap {
            continue; // pruned by bound (heap is bound-ordered: all done)
        }
        let out_of_time = opts.time_limit_s.is_some_and(|lim| clock.elapsed_s() > lim);
        if nodes >= opts.node_limit || out_of_time {
            proven = false;
            break;
        }
        nodes += 1;

        let lp = build_relaxation(inst, &node.fixings, disagg);
        lp_solves += 1;
        let (x, bound) = match lp.solve() {
            LpResult::Optimal { x, obj } => (x, obj),
            LpResult::Infeasible => continue,
            LpResult::Unbounded => {
                // Cannot happen: objective is non-negative. Treat as prune.
                continue;
            }
        };
        if bound >= incumbent_cost - opts.abs_gap {
            continue;
        }

        match pick_branch_var(inst, &x) {
            None => {
                // Integral LP point: candidate optimal for this subtree.
                let sol = extract_integral(inst, &x);
                if sol.check_feasible(inst).is_ok() {
                    let c = sol.cost(inst);
                    if c < incumbent_cost {
                        incumbent_cost = c;
                        incumbent = Some(sol);
                    }
                } else if let Some(sol) = round_lp(inst, &x) {
                    let c = sol.cost(inst);
                    if c < incumbent_cost {
                        incumbent_cost = c;
                        incumbent = Some(sol);
                    }
                }
            }
            Some(var) => {
                // Rounding heuristic for incumbents.
                if let Some(sol) = round_lp(inst, &x) {
                    let c = sol.cost(inst);
                    if c < incumbent_cost && sol.check_feasible(inst).is_ok() {
                        incumbent_cost = c;
                        incumbent = Some(sol);
                    }
                }
                for val in [x[var].round().clamp(0.0, 1.0), 1.0 - x[var].round().clamp(0.0, 1.0)]
                {
                    let mut fixings = node.fixings.clone();
                    fixings.push((var, val));
                    heap.push(Node { bound, fixings });
                }
            }
        }
    }

    BbOutcome {
        cost: incumbent_cost,
        best: incumbent,
        proven_optimal: proven,
        nodes,
        lp_solves,
        wall_s: clock.elapsed_s(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hflop::InstanceBuilder;
    use crate::solver::brute::brute_force;

    #[test]
    fn matches_brute_force_on_tiny_instances() {
        for seed in 0..8 {
            let inst = InstanceBuilder::random(6, 3, seed).t_min(5).build();
            let bf = brute_force(&inst);
            let bb = branch_and_bound(&inst, &BbOptions::default());
            assert!(bb.proven_optimal);
            match (bf, bb.best) {
                (Some((_, bf_cost)), Some(sol)) => {
                    sol.check_feasible(&inst).unwrap();
                    assert!(
                        (bb.cost - bf_cost).abs() < 1e-6,
                        "seed {seed}: bb {} brute {}",
                        bb.cost,
                        bf_cost
                    );
                }
                (None, None) => {}
                (bf, bb) => panic!("seed {seed}: brute {bf:?} vs bb {bb:?}"),
            }
        }
    }

    #[test]
    fn matches_brute_force_unit_cost() {
        for seed in 0..5 {
            let inst = InstanceBuilder::unit_cost(8, 3, seed).build();
            let bf = brute_force(&inst).expect("feasible");
            let bb = branch_and_bound(&inst, &BbOptions::default());
            assert!(bb.proven_optimal);
            assert!((bb.cost - bf.1).abs() < 1e-6, "seed {seed}");
        }
    }

    #[test]
    fn solution_is_feasible_and_bounded_by_greedy() {
        let inst = InstanceBuilder::unit_cost(30, 5, 11).build();
        let bb = branch_and_bound(&inst, &BbOptions::default());
        let sol = bb.best.unwrap();
        sol.check_feasible(&inst).unwrap();
        let greedy = complete_assignment(&inst, &vec![true; 5]).unwrap();
        assert!(bb.cost <= greedy.cost(&inst) + 1e-9);
    }

    #[test]
    fn infeasible_instance_returns_none() {
        let mut inst = InstanceBuilder::unit_cost(5, 2, 12).build();
        for r in inst.r.iter_mut() {
            *r = 0.1; // nobody fits, t_min = 5
        }
        let bb = branch_and_bound(&inst, &BbOptions::default());
        assert!(bb.best.is_none());
        assert!(bb.cost.is_infinite());
    }

    #[test]
    fn node_limit_degrades_gracefully() {
        let inst = InstanceBuilder::random(20, 5, 13).t_min(18).build();
        let opts = BbOptions { node_limit: 3, ..Default::default() };
        let bb = branch_and_bound(&inst, &opts);
        // With a tiny node budget we still get the greedy incumbent.
        assert!(bb.best.is_some());
    }

    #[test]
    fn uncapacitated_never_costlier_than_capacitated() {
        for seed in [1, 7, 21] {
            let capped = InstanceBuilder::unit_cost(12, 4, seed).build();
            let uncap = InstanceBuilder::unit_cost(12, 4, seed).uncapacitated().build();
            let c = branch_and_bound(&capped, &BbOptions::default());
            let u = branch_and_bound(&uncap, &BbOptions::default());
            assert!(c.proven_optimal && u.proven_optimal);
            assert!(u.cost <= c.cost + 1e-9, "seed {seed}: u {} c {}", u.cost, c.cost);
        }
    }
}
